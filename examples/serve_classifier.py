"""Serving demo: the hashed classifier behind the network front end.

Trains the paper's b-bit hashed linear model, stands up the fused
encode→score engine (``HashedClassifierEngine``) *and* the stdlib-only
HTTP tier on top (``serving.server.ScoreServer``), then exercises the
service the way an operator would — entirely over HTTP:

  * ``POST /score`` — batch scoring; every response carries the model
    version its scores were computed against;
  * ``POST /score_ndjson`` — the streaming endpoint: one chunked JSON
    line per doc as each resolves;
  * ``GET /status`` — rolling p50/p95/p99, rows/s, per-lane occupancy,
    ``compile_misses``, per-tenant rows, admission counters;
  * ``POST /reload`` — versioned hot-reload from a published
    checkpoint, mid-traffic, with zero dropped requests;
  * 429 + ``Retry-After`` when a request exceeds the in-flight budget;
  * duplicate traffic: the minhash-keyed score cache short-circuits
    repeat documents (band-signature probe, exact packed-code guard)
    with bitwise-identical scores, visible as ``dedup`` counters in
    ``GET /status``;
  * graceful drain: ``request_drain()`` (the SIGTERM path) answers all
    in-flight work before the socket closes.

Engine knobs come from ``configs.rcv1_oph.CONFIG.serve_kwargs()``, the
HTTP knobs from ``CONFIG.http_kwargs()``, both scaled to this demo
corpus.  The in-process replay path (no HTTP) lives in
``launch/serve.py --mode classifier`` without ``--http``.

Run:  PYTHONPATH=src python examples/serve_classifier.py
"""
import tempfile
import time

import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs.rcv1_oph import CONFIG
from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.models.linear import BBitLinearConfig
from repro.serving import (HTTPStatusError, HashedClassifierEngine,
                           ScoreClient, ScoreServer)
from repro.train import train_bbit_liblinear


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=3000, max_triples_per_doc=1500)
    rows, labels = generate_arrays(700, cfg)
    k, b = 64, 8
    scheme = "minwise"
    codes = preprocess_rows(rows, k=k, b=b, seed=1, chunk=256,
                            scheme=scheme)
    lcfg = BBitLinearConfig(k=k, b=b)
    res = train_bbit_liblinear(codes[:500], labels[:500], codes[500:],
                               labels[500:], lcfg, loss="logistic",
                               C=1.0, max_iter=25)
    print(f"trained model: test acc {res.test_acc:.3f}")

    # paper-scale serve knobs, buckets scaled to this corpus' nnz range
    eng = HashedClassifierEngine(
        res.params, lcfg, seed=1, version="demo-v0",
        **CONFIG.serve_kwargs(scheme=scheme, max_wait_ms=3.0,
                              nnz_buckets=(512, 2048, 8192),
                              max_batch=64),
        **CONFIG.dedup_kwargs(dedup_cache=True, dedup_entries=1024))
    print(f"engine up: {len(eng.devices)} replica(s), "
          f"{len(eng.nnz_buckets)}x{len(eng.row_buckets)} lanes "
          f"precompiled in {eng.precompile_seconds:.2f}s")

    srv = ScoreServer(eng, **CONFIG.http_kwargs(port=0))  # ephemeral port
    srv.start_in_thread()
    print(f"serving on http://{srv.host}:{srv.port}")
    client = ScoreClient(srv.host, srv.port)

    # -- batch scoring over HTTP, 20 docs per request ---------------------
    n_req, per = 10, 20
    t0 = time.perf_counter()
    preds = []
    for i in range(n_req):
        docs = [rows[500 + (i * per + j) % 200] for j in range(per)]
        resp = client.score(docs, tenant="demo")
        preds.extend(float(np.ravel(s)[0]) for s in resp["scores"])
    dt = time.perf_counter() - t0
    acc = float(np.mean((np.array(preds) > 0).astype(int)
                        == labels[500:500 + n_req * per]))
    print(f"scored {n_req * per} docs over {n_req} HTTP requests in "
          f"{dt:.2f}s (version {resp['version']}); accuracy={acc:.3f}")

    # -- streaming endpoint ----------------------------------------------
    lines = client.score_ndjson([rows[500 + j] for j in range(8)])
    print(f"ndjson stream: {len(lines)} lines, first="
          f"{{'i': {lines[0]['i']}, 'version': {lines[0]['version']!r}}}")

    # -- live stats -------------------------------------------------------
    st = client.status()
    e = st["engine"]
    print(f"/status: health={st['health']} p50={e['p50_ms']:.1f}ms "
          f"p95={e['p95_ms']:.1f}ms rows/s={e['rows_per_s']:.0f} "
          f"compile_misses={e['compile_misses']} "
          f"tenants={e['per_tenant_rows']}")

    # -- backpressure: one request bigger than the in-flight budget -------
    try:
        client.score([[1, 2, 3]] * (srv.admission.limit + 1))
    except HTTPStatusError as err:
        print(f"oversized request rejected: HTTP {err.status}, "
              f"Retry-After {err.retry_after_s}s")

    # -- versioned hot-reload mid-traffic ---------------------------------
    res2 = train_bbit_liblinear(codes[:400], labels[:400], codes[500:],
                                labels[500:], lcfg, loss="logistic",
                                C=1.0, max_iter=25)
    ckpt_dir = tempfile.mkdtemp(prefix="serve_demo_ckpt_")
    ckpt.publish_params(ckpt_dir, 1, res2.params)
    info = client.reload(ckpt_dir, version="demo-v1")
    resp = client.score([rows[500]])
    print(f"hot-reloaded to {info['version']} "
          f"(reload #{info['reloads']}); new scores tagged "
          f"{resp['version']!r}")

    # -- duplicate traffic: the viral-document short-circuit --------------
    # the same doc posted over and over costs one host hash pass + a
    # dict probe instead of a device round-trip, and the cached score
    # is bitwise-identical to a fresh dispatch (band probe + exact
    # packed-code guard); the hot-reload above also invalidated every
    # score cached under demo-v0
    viral = rows[510]
    fresh = float(np.ravel(client.score([viral])["scores"][0])[0])
    repeats = [float(np.ravel(client.score([viral] * 10)["scores"][j])[0])
               for j in range(10)]
    d = client.status()["dedup"]
    print(f"duplicate traffic: 10 repeats all "
          f"{'bitwise-equal' if all(r == fresh for r in repeats) else 'DIVERGED'}"
          f" to the fresh score; cache hits={d['hits']} "
          f"misses={d['misses']} entries={d['entries']} "
          f"invalidations={d['invalidations']} (reload wiped demo-v0)")

    # -- graceful drain (the SIGTERM path) --------------------------------
    srv.request_drain()
    srv.wait_finished(timeout=30)
    print(f"drained clean={srv.drained_clean}; "
          f"{srv.http_requests} HTTP requests served")


if __name__ == "__main__":
    main()
