"""Serving demo: the fused hash-and-score classification service.

Trains the paper's b-bit hashed linear model, then serves raw sparse
documents through ``HashedClassifierEngine``'s rebuilt hot path:

  * ONE jitted device pass per micro-batch (fused hash → b-bit → pack
    → packed-logits scoring; no (B, k) int32 code matrix on the
    kernel path);
  * per-nnz-bucket batching lanes — a giant document pads only its own
    lane, never a small batch's;
  * all (row × nnz) bucket shapes precompiled at engine startup, so
    the demo's traffic below never hits a compile spike
    (``compile_misses`` stays 0);
  * dispatch/resolve overlap: batch N+1 is padded while the device
    scores batch N (``pipeline_depth``);
  * ``replicas=N`` round-robins lanes across N devices (run with
    XLA_FLAGS=--xla_force_host_platform_device_count=2 to try it on
    fake CPU devices).

Engine knobs come from ``configs.rcv1_oph.CONFIG.serve_kwargs()``,
scaled down to this demo corpus.

Run:  PYTHONPATH=src python examples/serve_classifier.py
"""
import time

import numpy as np

from repro.configs.rcv1_oph import CONFIG
from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.models.linear import BBitLinearConfig
from repro.serving import HashedClassifierEngine
from repro.train import train_bbit_liblinear


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=3000, max_triples_per_doc=1500)
    rows, labels = generate_arrays(700, cfg)
    k, b = 64, 8
    scheme = "minwise"
    codes = preprocess_rows(rows, k=k, b=b, seed=1, chunk=256,
                            scheme=scheme)
    lcfg = BBitLinearConfig(k=k, b=b)
    res = train_bbit_liblinear(codes[:500], labels[:500], codes[500:],
                               labels[500:], lcfg, loss="logistic",
                               C=1.0, max_iter=25)
    print(f"trained model: test acc {res.test_acc:.3f}")

    # paper-scale serve knobs, buckets scaled to this corpus' nnz range
    eng = HashedClassifierEngine(
        res.params, lcfg, seed=1,
        **CONFIG.serve_kwargs(scheme=scheme, max_wait_ms=3.0,
                              nnz_buckets=(512, 2048, 8192),
                              max_batch=64))
    print(f"engine up: {len(eng.devices)} replica(s), "
          f"{len(eng.nnz_buckets)}x{len(eng.row_buckets)} lanes "
          f"precompiled in {eng.precompile_seconds:.2f}s")

    n_req = 200
    t0 = time.perf_counter()
    lat = []
    futs = []
    for i in range(n_req):
        t_sub = time.perf_counter()
        fut = eng.submit(rows[500 + i % 200])
        futs.append((fut, t_sub))
    preds = []
    for fut, t_sub in futs:
        preds.append(float(fut.result(timeout=120)))
        lat.append(time.perf_counter() - t_sub)
    dt = time.perf_counter() - t0
    acc = float(np.mean((np.array(preds) > 0).astype(int)
                        == labels[500:500 + n_req]))
    lat_ms = np.array(lat) * 1e3
    print(f"served {n_req} requests in {dt:.2f}s "
          f"({n_req/dt:.0f} req/s) across {eng.batcher.batches_run} "
          f"batches, {eng.compile_misses} serve-time compiles")
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms; accuracy={acc:.3f}")
    eng.close()


if __name__ == "__main__":
    main()
