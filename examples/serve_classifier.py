"""Serving demo: hash-and-score classification service with dynamic
batching — the paper's model deployed the way search infrastructure
deploys minwise hashing (one-time hashed representation, reused).

Run:  PYTHONPATH=src python examples/serve_classifier.py
"""
import time

import numpy as np

import jax

from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.models.linear import BBitLinearConfig
from repro.serving import HashedClassifierEngine
from repro.train import train_bbit_liblinear


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=3000, max_triples_per_doc=1500)
    rows, labels = generate_arrays(700, cfg)
    k, b = 64, 8
    codes = preprocess_rows(rows, k=k, b=b, seed=1, chunk=256)
    lcfg = BBitLinearConfig(k=k, b=b)
    res = train_bbit_liblinear(codes[:500], labels[:500], codes[500:],
                               labels[500:], lcfg, loss="logistic",
                               C=1.0, max_iter=25)
    print(f"trained model: test acc {res.test_acc:.3f}")

    eng = HashedClassifierEngine(res.params, lcfg, seed=1,
                                 max_batch=64, max_wait_ms=3.0)
    # warmup (compile the shape buckets)
    [f.result(timeout=120) for f in [eng.submit(rows[0])] * 1]

    n_req = 200
    t0 = time.perf_counter()
    lat = []
    futs = []
    for i in range(n_req):
        t_sub = time.perf_counter()
        fut = eng.submit(rows[500 + i % 200])
        futs.append((fut, t_sub))
    preds = []
    for fut, t_sub in futs:
        preds.append(float(fut.result(timeout=120)))
        lat.append(time.perf_counter() - t_sub)
    dt = time.perf_counter() - t0
    acc = float(np.mean((np.array(preds) > 0).astype(int)
                        == labels[500:500 + n_req]))
    lat_ms = np.array(lat) * 1e3
    print(f"served {n_req} requests in {dt:.2f}s "
          f"({n_req/dt:.0f} req/s) across {eng.batcher.batches_run} "
          f"batches")
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms; accuracy={acc:.3f}")
    eng.close()


if __name__ == "__main__":
    main()
