"""Figure 5/6 style comparison: b-bit minwise hashing vs the VW
algorithm at EQUAL STORAGE (the paper's central empirical claim).

Run:  PYTHONPATH=src python examples/compare_vw_bbit.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core.vw import vw_hash_sparse
from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.data.packing import pad_rows
from repro.models.linear import BBitLinearConfig, VWLinearConfig
from repro.train import train_bbit_liblinear, train_vw_liblinear


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels = generate_arrays(1000, cfg)
    n_tr = 500

    print(f"{'method':28s} {'bits/doc':>9s} {'test acc':>9s}")
    print("-" * 50)

    for (k, b) in [(30, 12), (64, 8), (128, 8)]:
        codes = preprocess_rows(rows, k=k, b=b, seed=1, chunk=256)
        res = train_bbit_liblinear(
            codes[:n_tr], labels[:n_tr], codes[n_tr:], labels[n_tr:],
            BBitLinearConfig(k=k, b=b), loss="logistic", C=1.0,
            max_iter=25)
        print(f"b-bit minwise  k={k:<4d} b={b:<3d} {k*b:>9d} "
              f"{res.test_acc:>9.3f}")

    order = np.argsort([len(r) for r in rows])
    for m in (12, 32, 128, 1024):
        sk = np.empty((len(rows), m), np.float32)
        for lo in range(0, len(rows), 256):
            sel = order[lo:lo + 256]
            idx, nnz = pad_rows([rows[i] for i in sel])
            mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
            sk[sel] = np.asarray(vw_hash_sparse(
                jnp.asarray(idx), jnp.asarray(mask), None, m, seed=2))
        res = train_vw_liblinear(
            sk[:n_tr], labels[:n_tr], sk[n_tr:], labels[n_tr:],
            VWLinearConfig(m=m), loss="logistic", C=1.0, max_iter=25)
        print(f"VW hashing     m={m:<8d} {32*m:>9d} {res.test_acc:>9.3f}")

    print("\npaper's claim: at the same storage budget, b-bit minwise"
          "\nhashing dominates VW; VW needs orders of magnitude more"
          "\nbins to catch up (compare 360-1024-bit rows).")


if __name__ == "__main__":
    main()
