"""Train WITHOUT ever holding the dataset: shards in, shards through.

The paper's 200 GB pipeline end to end, in miniature:

  1. ``preprocess_and_save`` streams raw documents → packed format-v3
     shards (PR 2: fused device encode, O(one shard) memory);
  2. ``fit_streaming`` (PR 3, overlapped in PR 4) trains straight off
     those shards — host-side batch assembly (mmap fault-in, shuffle,
     slice, transfer) runs in an async producer thread ``prefetch``
     steps ahead of the device, each minibatch crosses as ceil(k·b/8)
     packed bytes and STAYS packed into the forward
     (``bbit_logits_packed``: in-register unpack on the kernel path),
     with Polyak tail averaging and VW-style progressive validation;
  3. prefetch depth is provably cosmetic: the inline run
     (``prefetch=0``) reproduces the overlapped one bit-for-bit;
  4. a simulated kill (``stop_after_shards``) + resume from the
     shard-boundary checkpoint reproduces the uninterrupted run
     bit-for-bit;
  5. surviving a crash (PR 7): a deterministic fault plan
     (``ft.faults``) tears the first checkpoint write and kills a
     mid-shard train step; ``run_supervised`` quarantines the damaged
     checkpoint, restores the newest valid one after a capped backoff,
     replays the stream — and still lands on the same bits as the
     uninterrupted run.

At no point does the (n, k) training matrix exist in memory.  On a
multi-device host (``XLA_FLAGS=--xla_force_host_platform_device_count=2``
fakes one), add ``data_parallel=2`` to the ``fit_streaming`` calls to
shard each epoch's shard groups across devices under ``shard_map``
with a ``psum_mean`` gradient all-reduce.

Run:  PYTHONPATH=src python examples/stream_train.py
"""
import tempfile

import jax.numpy as jnp

from repro.configs.rcv1_oph import CONFIG
from repro.data import (SynthRcv1Config, generate_arrays,
                        preprocess_and_save, preprocess_rows,
                        shard_row_counts)
from repro.ft import BackoffPolicy, FaultEvent, FaultPlan, faults
from repro.models.linear import BBitLinearConfig, predict_classes
from repro.train import RestartPolicy, fit_streaming, run_supervised
from repro.train.metrics import accuracy, trees_bitwise_equal


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels = generate_arrays(600, cfg)
    k, b, n_tr, n_shards = 64, 8, 400, 8
    lcfg = BBitLinearConfig(k=k, b=b)

    with tempfile.TemporaryDirectory() as work:
        root, ck = work + "/hashed", work + "/ckpt"
        stats = preprocess_and_save(root, rows[:n_tr], labels[:n_tr],
                                    k=k, b=b, scheme=CONFIG.scheme,
                                    seed=1, n_shards=n_shards, chunk=128)
        counts = shard_row_counts(root)
        print(f"{stats['n']} docs → {len(counts)} packed shards "
              f"({min(counts)}–{max(counts)} rows each, "
              f"{stats['mnnz_per_s']:.1f} Mnnz/s)")

        # paper-scale knobs from the config, shrunk to this demo corpus
        # batch must fit the smallest shard (~50 rows here) — the
        # trainer refuses oversized batches up front
        kw = CONFIG.stream_kwargs(epochs=4, batch_size=32, lr=5e-3,
                                  seed=0, ckpt_every_shards=1)
        res = fit_streaming(root, lcfg, **kw)
        inline = fit_streaming(root, lcfg, **dict(kw, prefetch=0))
        same_pf = trees_bitwise_equal(res.params, inline.params)
        print(f"prefetch pipeline vs inline: bit-identical={same_pf}")
        assert same_pf
        codes_te = preprocess_rows(rows[n_tr:], k=k, b=b,
                                   scheme=CONFIG.scheme, seed=1, chunk=128)
        acc_raw = accuracy(predict_classes(
            res.params, jnp.asarray(codes_te), lcfg), labels[n_tr:])
        acc_avg = accuracy(predict_classes(
            res.avg_params, jnp.asarray(codes_te), lcfg), labels[n_tr:])
        print(f"streamed {res.examples_seen} examples in "
              f"{res.n_steps} steps ({res.train_seconds:.2f}s): "
              f"progressive acc {res.progressive_acc:.3f}, "
              f"test acc {acc_raw:.3f} (raw) / {acc_avg:.3f} (averaged)")

        print("kill after 5 shards → resume from the checkpoint…")
        part = fit_streaming(root, lcfg, ckpt_dir=ck,
                             stop_after_shards=5, **kw)
        resumed = fit_streaming(root, lcfg, ckpt_dir=ck, **kw)
        same = trees_bitwise_equal(res.params, resumed.params)
        print(f"  interrupted at shard {part.shards_processed}, resumed "
              f"to step {resumed.n_steps}: bit-identical={same}")
        assert same and not part.completed and resumed.completed
        assert acc_avg > 0.9

        # -------- surviving a crash: the supervised restart loop ----
        # A scripted disaster: the FIRST checkpoint write is torn (the
        # payload never hits disk though the rename did), the process
        # dies, and once restarted it dies AGAIN mid-shard at step 40.
        # run_supervised absorbs both: the torn checkpoint fails its
        # CRC check, is quarantined under <ckpt_dir>/quarantine/, and
        # training replays from the newest valid state — bit-identical
        # to the run that never crashed, because batch replay is a pure
        # function of (seed, epoch, position).
        print("surviving a crash: torn checkpoint write + mid-shard "
              "kill under run_supervised…")
        plan = FaultPlan([FaultEvent(site="ckpt_write", times=1),
                          FaultEvent(site="train_step", step=40,
                                     times=1)])
        policy = RestartPolicy(
            max_restarts=3,
            backoff=BackoffPolicy(base_s=0.05, cap_s=0.5))
        with faults.arm(plan):
            sup = run_supervised(root, lcfg, policy=policy,
                                 ckpt_dir=work + "/ckpt_crash", **kw)
        healed = trees_bitwise_equal(res.params, sup.result.params)
        print(f"  {sup.restarts} restarts "
              f"({[c.error for c in sup.crashes]}), "
              f"recovered bit-identical={healed}")
        assert healed and sup.restarts == 2

if __name__ == "__main__":
    main()
