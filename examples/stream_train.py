"""Train WITHOUT ever holding the dataset: shards in, shards through.

The paper's 200 GB pipeline end to end, in miniature:

  1. ``preprocess_and_save`` streams raw documents → packed format-v3
     shards (PR 2: fused device encode, O(one shard) memory);
  2. ``fit_streaming`` (PR 3) trains straight off those shards — each
     minibatch crosses to the device as ceil(k·b/8) packed bytes and
     is widened there by ``unpack_codes_jnp`` inside the jitted step,
     with Polyak tail averaging and VW-style progressive validation;
  3. a simulated kill (``stop_after_shards``) + resume from the
     shard-boundary checkpoint reproduces the uninterrupted run
     bit-for-bit.

At no point does the (n, k) training matrix exist in memory.

Run:  PYTHONPATH=src python examples/stream_train.py
"""
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.rcv1_oph import CONFIG
from repro.data import (SynthRcv1Config, generate_arrays,
                        preprocess_and_save, preprocess_rows,
                        shard_row_counts)
from repro.models.linear import BBitLinearConfig, predict_classes
from repro.train import fit_streaming
from repro.train.metrics import accuracy


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels = generate_arrays(600, cfg)
    k, b, n_tr, n_shards = 64, 8, 400, 8
    lcfg = BBitLinearConfig(k=k, b=b)

    with tempfile.TemporaryDirectory() as work:
        root, ck = work + "/hashed", work + "/ckpt"
        stats = preprocess_and_save(root, rows[:n_tr], labels[:n_tr],
                                    k=k, b=b, scheme=CONFIG.scheme,
                                    seed=1, n_shards=n_shards, chunk=128)
        counts = shard_row_counts(root)
        print(f"{stats['n']} docs → {len(counts)} packed shards "
              f"({min(counts)}–{max(counts)} rows each, "
              f"{stats['mnnz_per_s']:.1f} Mnnz/s)")

        # paper-scale knobs from the config, shrunk to this demo corpus
        kw = CONFIG.stream_kwargs(epochs=4, batch_size=128, lr=5e-3,
                                  seed=0, ckpt_every_shards=1)
        res = fit_streaming(root, lcfg, **kw)
        codes_te = preprocess_rows(rows[n_tr:], k=k, b=b,
                                   scheme=CONFIG.scheme, seed=1, chunk=128)
        acc_raw = accuracy(predict_classes(
            res.params, jnp.asarray(codes_te), lcfg), labels[n_tr:])
        acc_avg = accuracy(predict_classes(
            res.avg_params, jnp.asarray(codes_te), lcfg), labels[n_tr:])
        print(f"streamed {res.examples_seen} examples in "
              f"{res.n_steps} steps ({res.train_seconds:.2f}s): "
              f"progressive acc {res.progressive_acc:.3f}, "
              f"test acc {acc_raw:.3f} (raw) / {acc_avg:.3f} (averaged)")

        print("kill after 5 shards → resume from the checkpoint…")
        part = fit_streaming(root, lcfg, ckpt_dir=ck,
                             stop_after_shards=5, **kw)
        resumed = fit_streaming(root, lcfg, ckpt_dir=ck, **kw)
        same = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(res.params),
                            jax.tree.leaves(resumed.params)))
        print(f"  interrupted at shard {part.shards_processed}, resumed "
              f"to step {resumed.n_steps}: bit-identical={same}")
        assert same and not part.completed and resumed.completed
        assert acc_avg > 0.9

if __name__ == "__main__":
    main()
