"""End-to-end driver: train a ~100M-parameter hashed linear model for a
few hundred steps (the paper's workload kind, at the assignment's
"~100M params, few hundred steps" scale).

Model: 16-class classifier over b=12-bit codes with k=512 hashes →
weight table 512 × 4096 × 16 ≈ 33.6M weights… scaled to ~100M via
k=1536.  Uses minibatch AdamW (the distributed path's optimizer),
checkpointing every 50 steps, and the straggler watchdog.

Run:  PYTHONPATH=src python examples/train_rcv1_bbit.py [--steps 300]
"""
import argparse
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.data.loader import HashedCodesLoader
from repro.ft.watchdog import StepWatchdog
from repro.models.linear import (
    BBitLinearConfig, init_bbit_linear, predict_classes, bbit_logits,
)
from repro.optim.optimizers import make_optimizer
from repro.train.losses import mean_loss_fn
from repro.train.metrics import accuracy
from repro.train.steps import init_state, build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--k", type=int, default=1536)
    ap.add_argument("--b", type=int, default=12)
    ap.add_argument("--n-docs", type=int, default=3000)
    ap.add_argument("--workdir", default="artifacts/example_100m")
    args = ap.parse_args()

    n_classes = 16
    lcfg = BBitLinearConfig(k=args.k, b=args.b, n_classes=n_classes)
    print(f"model: k={args.k} × 2^{args.b} × {n_classes} classes = "
          f"{lcfg.n_weights/1e6:.1f}M parameters")

    cfg = SynthRcv1Config(seed=5, n_classes=n_classes, topic_tokens=200,
                          background_frac=0.3, max_pairs_per_doc=3000,
                          max_triples_per_doc=1500)
    t0 = time.time()
    rows, labels = generate_arrays(args.n_docs, cfg)
    print(f"corpus: {len(rows)} docs in {time.time()-t0:.0f}s")
    t0 = time.time()
    codes = preprocess_rows(rows, k=args.k, b=args.b, seed=1, chunk=256)
    print(f"hashing (one-time): {time.time()-t0:.0f}s "
          f"→ {args.k*args.b} bits/doc")

    n_te = args.n_docs // 5
    tr = slice(0, args.n_docs - n_te)
    te = slice(args.n_docs - n_te, None)
    opt = make_optimizer("adamw", 3e-3)
    loss_fn = mean_loss_fn(lambda p, c: bbit_logits(p, c, lcfg),
                           "softmax", l2=1e-7)
    step_fn = build_train_step(loss_fn, opt)
    state = init_state(init_bbit_linear(lcfg, jax.random.key(0)), opt)
    loader = HashedCodesLoader(codes[tr], labels[tr], batch_size=256,
                               seed=0)
    wd = StepWatchdog()
    ckpt_dir = os.path.join(args.workdir, "ckpt")
    t0 = time.time()
    losses = []
    for step, bc, by in loader.batches(0):
        if step >= args.steps:
            break
        wd.start_step()
        state, loss = step_fn(state, jnp.asarray(bc.astype(np.int32)),
                              jnp.asarray(by))
        wd.end_step(step)
        losses.append(float(loss))
        if (step + 1) % 50 == 0:
            ckpt.save(ckpt_dir, step + 1, state)
            print(f"step {step+1}: loss={np.mean(losses[-50:]):.4f} "
                  f"({(step+1)/(time.time()-t0):.1f} steps/s)")
    te_acc = accuracy(predict_classes(
        state.params, jnp.asarray(codes[te].astype(np.int32)), lcfg),
        labels[te])
    print(f"done: {args.steps} steps in {time.time()-t0:.0f}s; "
          f"test acc (16-way) = {te_acc:.3f}; "
          f"stragglers flagged = {len(wd.flagged_steps)}")


if __name__ == "__main__":
    main()
