"""OPH vs k-permutation minwise: same accuracy, ~k× cheaper hashing.

Reproduces the repo's quickstart pipeline twice — once with the paper's
k-permutation preprocessing and once with one permutation hashing
(arXiv:1208.1259, densified per arXiv:1406.4784) — and reports hashing
wall time, hash-evaluation counts, and test accuracy side by side, then
serves the OPH model through the scheme-aware engine.  Finally it runs
the fused streaming path (``preprocess_and_save``: device-side b-bit
packing, double-buffered chunks, incremental v3 shards) and shows the
recorded Mnnz/s plus the per-shard ``iter_hashed`` evaluation loop.

Run:  PYTHONPATH=src python examples/oph_preprocess.py
"""
import tempfile
import time

import numpy as np

from repro.core.schemes import make_scheme
from repro.data import (SynthRcv1Config, generate_arrays, iter_hashed,
                        preprocess_and_save, preprocess_rows)
from repro.models.linear import BBitLinearConfig
from repro.serving import HashedClassifierEngine
from repro.train import train_bbit_liblinear


def main() -> None:
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels = generate_arrays(600, cfg)
    total_nnz = int(sum(len(r) for r in rows))
    k, b = 256, 8            # k matches configs/rcv1_oph (power of two)
    n_tr = 300
    lcfg = BBitLinearConfig(k=k, b=b)

    print(f"{len(rows)} docs, {total_nnz} nonzeros; k={k}, b={b}")
    results = {}
    for scheme in ("minwise", "oph"):
        # first call compiles one trace per chunk shape; time the warm
        # second pass — the steady state a 200GB-scale run amortizes to
        preprocess_rows(rows, k=k, b=b, scheme=scheme, seed=1, chunk=256)
        t0 = time.perf_counter()
        codes = preprocess_rows(rows, k=k, b=b, scheme=scheme, seed=1,
                                chunk=256)
        dt = time.perf_counter() - t0
        evals = total_nnz * make_scheme(scheme, k, 1).hash_evals_per_nonzero
        res = train_bbit_liblinear(codes[:n_tr], labels[:n_tr],
                                   codes[n_tr:], labels[n_tr:],
                                   lcfg, loss="logistic", C=1.0,
                                   max_iter=30)
        results[scheme] = res
        print(f"  {scheme:8s}: hashing {dt:6.2f}s "
              f"({evals / 1e6:7.1f}M hash evals)  "
              f"test_acc={res.test_acc:.3f}")

    print("serving the OPH model (scheme-aware engine)…")
    eng = HashedClassifierEngine(results["oph"].params, lcfg, seed=1,
                                 scheme="oph",
                                 nnz_buckets=(2048, 8192),
                                 row_buckets=(1, 32))
    futs = [eng.submit(r) for r in rows[n_tr:n_tr + 32]]
    scores = np.array([f.result(timeout=60) for f in futs])
    acc = float(np.mean((scores > 0).astype(int) == labels[n_tr:n_tr + 32]))
    print(f"  served 32 requests in {eng.batcher.batches_run} batch(es); "
          f"accuracy {acc:.3f}")
    eng.close()

    print("fused streaming preprocess → v3 shards (packed bytes only "
          "leave the device)…")
    with tempfile.TemporaryDirectory() as d:
        stats = preprocess_and_save(d, rows, labels, k=k, b=b,
                                    scheme="oph", seed=1, chunk=256,
                                    n_shards=4)
        print(f"  {stats['n']} docs → 4 shards in "
              f"{stats['seconds_hashing']:.2f}s "
              f"({stats['mnnz_per_s']:.1f} Mnnz/s recorded in meta.json)")
        import jax.numpy as jnp
        from repro.models.linear import bbit_logits
        correct = total = 0
        w = results["oph"].params
        # shard-at-a-time evaluation: RAM stays O(one shard)
        for shard_codes, shard_labels, _ in iter_hashed(d):
            s = np.asarray(bbit_logits(w, jnp.asarray(
                shard_codes.astype(np.int32)), lcfg))[:, 0]
            correct += int(np.sum((s > 0).astype(int) == shard_labels))
            total += len(shard_labels)
        print(f"  shard-streamed eval accuracy {correct / total:.3f} "
              f"({total} docs, no full-matrix load)")

    assert results["oph"].test_acc > 0.85
    assert abs(results["oph"].test_acc - results["minwise"].test_acc) < 0.05


if __name__ == "__main__":
    main()
