"""Beyond-paper demo: the paper's b-bit hashing as LM embedding
compression.

A reduced internlm2-family decoder is trained twice on the same
synthetic token stream: once with a dense (vocab × d) embedding, once
with the b-bit hashed embedding (k tables of 2^b rows — the paper's
n·b·k storage argument applied to the embedding matrix).  Losses track
each other while the hashed table is a fraction of the dense size.

Run:  PYTHONPATH=src python examples/lm_hashed_embeddings.py
"""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.lm_synth import lm_example_stream
from repro.launch.smoke_configs import reduced_config
from repro.models.api import get_model_api
from repro.optim.optimizers import make_optimizer
from repro.train.steps import init_state, build_train_step


def train(cfg, steps=60, batch=8, seq=64, seed=0):
    api = get_model_api(cfg)
    opt = make_optimizer("adamw", 3e-3)
    state = init_state(api.init_params(jax.random.key(seed)), opt)
    step_fn = build_train_step(
        lambda p, b_: api.loss_fn(p, b_, None), opt)
    losses = []
    for step, toks, tgts in lm_example_stream(batch, seq, cfg.vocab,
                                              seed=seed):
        if step >= steps:
            break
        state, loss = step_fn(state, {"tokens": jnp.asarray(toks),
                                      "targets": jnp.asarray(tgts)})
        losses.append(float(loss))
    return losses, state


def embed_params_size(state):
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            state.params)[0]:
        if "embed" in str(path):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n
    return total


def main() -> None:
    base = reduced_config(get_config("internlm2-1.8b"))
    base = dataclasses.replace(base, vocab=8192)
    dense = base
    hashed = dataclasses.replace(base, embedding="bbit_hash",
                                 hash_k=8, hash_b=8)
    print("training dense-embedding model…")
    l_dense, s_dense = train(dense)
    print("training bbit-hashed-embedding model…")
    l_hash, s_hash = train(hashed)
    n_dense = embed_params_size(s_dense)
    n_hash = embed_params_size(s_hash)
    print(f"\nembedding params: dense={n_dense/1e3:.0f}k "
          f"hashed={n_hash/1e3:.0f}k "
          f"({n_dense/max(n_hash,1):.1f}× compression)")
    print(f"final loss: dense={np.mean(l_dense[-10:]):.3f} "
          f"hashed={np.mean(l_hash[-10:]):.3f}")
    print("loss curves (every 10 steps):")
    for i in range(0, len(l_dense), 10):
        print(f"  step {i:3d}: dense={l_dense[i]:.3f} "
              f"hashed={l_hash[i]:.3f}")


if __name__ == "__main__":
    main()
