"""Quickstart: the paper's pipeline in ~60 seconds on CPU.

  synthetic expanded-rcv1 docs → k×b-bit minwise hashing (one-time)
  → LIBLINEAR-style TRON training (Eq. 9) → test accuracy
  → same hashed model served with dynamic batching
  → the same engine behind the network front end (HTTP).

Serve over HTTP (step 5 here, full tour in
examples/serve_classifier.py):

    srv = ScoreServer(eng, port=0)        # 0 → ephemeral port
    srv.start_in_thread()
    client = ScoreClient(srv.host, srv.port)
    client.score([[12, 99, 1024], ...])   # {"scores", "version", ...}
    client.status()                       # p50/p95/p99, rows/s, lanes
    client.reload(ckpt_dir)               # versioned weight hot-swap
    srv.request_drain()                   # SIGTERM path: finish, then stop

or from the command line:

    PYTHONPATH=src python -m repro.launch.serve \
        --mode classifier --http --port 8077
    curl -s localhost:8077/status | python -m json.tool

Calibrate once, run fast (step 6 here): every implementation choice —
Pallas kernel vs XLA fallback, packed logits kernel vs unpack, serving
micro-batch sizing — routes through ``repro.perf``.  Measure this box
once and every launcher picks the measured winner:

    PYTHONPATH=src python -m repro.launch.calibrate \
        --out artifacts/perf/profile.json --budget-s 60
    PYTHONPATH=src python -m repro.launch.train --mode stream \
        --profile artifacts/perf/profile.json
    # or: export REPRO_PROFILE=artifacts/perf/profile.json

No profile (or a profile from a different machine) is always safe: the
static heuristics this repo has always shipped apply, bit-identically.

Duplicate traffic (step 7 here): repeat documents short-circuit
through the minhash-keyed score cache — band-signature probe, exact
packed-code guard, scores bitwise-identical to a fresh dispatch.  Full
HTTP tour (``GET /status`` dedup counters, hot-reload invalidation) in
examples/serve_classifier.py.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.models.linear import BBitLinearConfig
from repro.train import train_bbit_liblinear
from repro.serving import HashedClassifierEngine, ScoreClient, ScoreServer


def main() -> None:
    print("1) generating synthetic expanded-rcv1 corpus "
          "(unigrams + pairs + 1/30 triples)…")
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels = generate_arrays(800, cfg)
    nnz = [len(r) for r in rows]
    print(f"   {len(rows)} docs; nnz median={int(np.median(nnz))} "
          f"mean={int(np.mean(nnz))}; D=2^30")

    k, b = 64, 8
    print(f"2) one-time preprocessing: k={k} min-hashes, lowest b={b} "
          f"bits each → {k*b} bits/doc…")
    codes = preprocess_rows(rows, k=k, b=b, seed=1, chunk=256)

    print("3) training logistic regression (TRON, the LIBLINEAR "
          "solver) on the hashed codes…")
    n_tr = 400
    lcfg = BBitLinearConfig(k=k, b=b)
    res = train_bbit_liblinear(codes[:n_tr], labels[:n_tr],
                               codes[n_tr:], labels[n_tr:],
                               lcfg, loss="logistic", C=1.0, max_iter=30)
    print(f"   test accuracy = {res.test_acc:.3f} "
          f"({res.n_iter} TRON iterations, {res.train_seconds:.1f}s)")

    print("4) serving the trained model (fused hash → score, batched)…")
    # buckets sized to this demo corpus so the startup precompile
    # stays snappy (defaults target production-scale nnz ranges)
    eng = HashedClassifierEngine(res.params, lcfg, seed=1,
                                 nnz_buckets=(2048, 8192),
                                 row_buckets=(1, 32))
    futs = [eng.submit(r) for r in rows[n_tr:n_tr + 32]]
    scores = np.array([f.result(timeout=60) for f in futs])
    pred = (scores > 0).astype(int)
    acc = float(np.mean(pred == labels[n_tr:n_tr + 32]))
    print(f"   served 32 requests in {eng.batcher.batches_run} batch(es); "
          f"accuracy {acc:.3f}")

    print("5) same engine over HTTP (batch scores + live /status)…")
    srv = ScoreServer(eng, port=0)
    srv.start_in_thread()
    client = ScoreClient(srv.host, srv.port)
    resp = client.score(rows[n_tr:n_tr + 8])
    st = client.status()
    print(f"   POST /score → 8 scores tagged {resp['version']!r}; "
          f"GET /status → health={st['health']} "
          f"p50={st['engine']['p50_ms']:.1f}ms")
    srv.request_drain()               # drains the engine too
    srv.wait_finished(timeout=30)

    print("6) calibrate once, run fast: measuring this box's dispatch "
          "cost table (budget-capped)…")
    import tempfile

    from repro import perf
    table = perf.calibrate(k=k, b_values=(b,), schemes=("minwise",),
                           encode_rows=(32,), encode_widths=(128,),
                           logits_rows=(64,), include_serving=False,
                           trials=2, budget_s=15.0)
    with tempfile.TemporaryDirectory() as td:
        path = f"{td}/profile.json"
        table.save(path)                      # versioned, device-keyed
        perf.maybe_load_profile(path)         # what --profile does
        rep = perf.dispatch_report()
    print(f"   {len(table.entries)} measured entries in "
          f"{table.meta['calibrate_seconds']}s; dispatch now profile-"
          f"driven (table {rep['table_version']!r}) — wrong-device or "
          f"missing profiles fall back to the static heuristics")

    print("7) duplicate traffic: the minhash-keyed score cache "
          "(full HTTP demo in examples/serve_classifier.py)…")
    dedup_eng = HashedClassifierEngine(res.params, lcfg, seed=1,
                                       nnz_buckets=(2048, 8192),
                                       row_buckets=(1, 32),
                                       dedup_cache=True,
                                       dedup_entries=128)
    viral = rows[n_tr]
    fresh = float(dedup_eng.submit(viral).result(timeout=60))
    repeats = [float(f.result(timeout=60))
               for f in dedup_eng.submit_many([viral] * 8)]
    d = dedup_eng.stats()["dedup"]
    dedup_eng.close()
    assert all(r == fresh for r in repeats)
    print(f"   8 repeats of one viral doc → {d['hits']} cache hits, "
          f"every score bitwise-equal to the fresh dispatch, no "
          f"device round-trip on a hit")

    assert res.test_acc > 0.85


if __name__ == "__main__":
    main()
