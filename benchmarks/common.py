"""Shared corpus/cache/timing utilities for the paper benchmarks."""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, List, Tuple

import numpy as np

CACHE: Dict = {}

QUICK = os.environ.get("BENCH_FULL", "0") != "1"

# --smoke tier (benchmarks.run --smoke / CI): tiny shapes, parity-only
# assertions, no trajectory JSON written.
SMOKE = os.environ.get("BENCH_SMOKE", "0") == "1"


def corpus(n_docs: int = None, seed: int = 11):
    """Synthetic expanded-rcv1 corpus (cached per size)."""
    from repro.data import SynthRcv1Config, generate_arrays
    n_docs = n_docs or (800 if QUICK else 3000)
    key = ("corpus", n_docs, seed)
    if key not in CACHE:
        cfg = SynthRcv1Config(seed=seed, topic_tokens=150,
                              background_frac=0.35,
                              max_pairs_per_doc=6000,
                              max_triples_per_doc=3000)
        CACHE[key] = generate_arrays(n_docs, cfg)
    return CACHE[key]


def hashed_codes(k: int, b: int, seed: int = 1, scheme: str = "minwise"):
    from repro.data import preprocess_rows
    rows, labels = corpus()
    key = ("codes", k, b, seed, scheme, len(rows))
    if key not in CACHE:
        CACHE[key] = preprocess_rows(rows, k=k, b=b, seed=seed, chunk=256,
                                     scheme=scheme)
    return CACHE[key], labels


def vw_sketches(m: int, seed: int = 2):
    import jax.numpy as jnp
    from repro.core.vw import vw_hash_sparse
    from repro.data.packing import pad_rows
    rows, labels = corpus()
    key = ("vw", m, seed, len(rows))
    if key not in CACHE:
        order = np.argsort([len(r) for r in rows])
        sk = np.empty((len(rows), m), np.float32)
        for lo in range(0, len(rows), 256):
            sel = order[lo:lo + 256]
            idx, nnz = pad_rows([rows[i] for i in sel])
            mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
            sk[sel] = np.asarray(vw_hash_sparse(
                jnp.asarray(idx), jnp.asarray(mask), None, m, seed=seed))
        CACHE[key] = sk
    return CACHE[key], labels


def split(arrays_labels):
    x, y = arrays_labels
    n_tr = len(y) // 2                      # paper: 50/50 split (Table 1)
    return x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def emit(rows: List[Tuple[str, float, str]]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
