"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  QUICK grids by default;
``BENCH_FULL=1`` restores the paper's full sweeps.  Select subsets with
``python -m benchmarks.run fig1 fig8 table2``.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import kernel_bench, paper_figures, roofline_report

    suites = {
        "fig1": paper_figures.fig1_fig2_svm,
        "fig3": paper_figures.fig3_fig4_logistic,
        "fig5": paper_figures.fig5_fig6_vw_vs_bbit,
        "fig7": paper_figures.fig7_train_time_vw_vs_bbit,
        "fig8": paper_figures.fig8_universal_vs_permutations,
        "table2": paper_figures.table2_preprocessing_cost,
        "variance": paper_figures.variance_check,
        "compact": paper_figures.compact_index_trick,
        "kernels_minhash": kernel_bench.minhash_bench,
        "kernels_bbit": kernel_bench.bbit_linear_bench,
        "kernels_vw": kernel_bench.vw_sketch_bench,
        "roofline": roofline_report.roofline_rows,
    }
    selected = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        try:
            suites[name]()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
