"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  QUICK grids by default;
``BENCH_FULL=1`` restores the paper's full sweeps.  Select subsets with
``python -m benchmarks.run fig1 fig8 table2``.

OPH suites additionally write ``BENCH_oph.json`` (override the path
with ``BENCH_OPH_JSON``) so the preprocessing-throughput trajectory is
machine-readable across commits.
"""
import json
import os
import sys
import traceback

# Suites whose records feed the OPH perf-trajectory file.
OPH_SUITES = ("kernels_oph", "oph_curve")


def _write_oph_json(records) -> None:
    path = os.environ.get("BENCH_OPH_JSON", "BENCH_oph.json")
    payload = {
        "bench": "oph",
        "records": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in records
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(records)} records)", file=sys.stderr)


def main() -> None:
    from benchmarks import kernel_bench, paper_figures, roofline_report

    suites = {
        "fig1": paper_figures.fig1_fig2_svm,
        "fig3": paper_figures.fig3_fig4_logistic,
        "fig5": paper_figures.fig5_fig6_vw_vs_bbit,
        "fig7": paper_figures.fig7_train_time_vw_vs_bbit,
        "fig8": paper_figures.fig8_universal_vs_permutations,
        "table2": paper_figures.table2_preprocessing_cost,
        "variance": paper_figures.variance_check,
        "compact": paper_figures.compact_index_trick,
        "oph_curve": paper_figures.oph_vs_minwise_vs_vw,
        "kernels_minhash": kernel_bench.minhash_bench,
        "kernels_oph": kernel_bench.oph_bench,
        "kernels_bbit": kernel_bench.bbit_linear_bench,
        "kernels_vw": kernel_bench.vw_sketch_bench,
        "roofline": roofline_report.roofline_rows,
    }
    selected = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failures = 0
    oph_records, oph_failed = [], False
    for name in selected:
        try:
            rows = suites[name]()
            if name in OPH_SUITES and rows:
                oph_records.extend(rows)
        except Exception:  # noqa: BLE001
            failures += 1
            oph_failed = oph_failed or name in OPH_SUITES
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if oph_records and not oph_failed:
        _write_oph_json(oph_records)
    elif oph_failed:
        # never clobber a complete trajectory file with partial records
        print("# BENCH_oph.json not written (an OPH suite failed)",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
