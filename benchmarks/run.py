"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  QUICK grids by default;
``BENCH_FULL=1`` restores the paper's full sweeps.  Select subsets with
``python -m benchmarks.run fig1 fig8 table2``.

``python -m benchmarks.run --smoke`` is the CI tier: tiny shapes
(``BENCH_SMOKE=1``), interpret-mode fused-kernel parity canaries, a
preprocessing-pipeline parity pass, and a budget-capped cost-model
calibration + profile round-trip (dispatch_preprocess) — fast enough
for every merge, and any bit mismatch fails the run.  Smoke mode never
writes trajectory JSON files.

OPH suites write ``BENCH_oph.json``, the preprocess suite writes
``BENCH_preprocess.json``, the streaming-trainer suite writes
``BENCH_streaming.json``, the serving suite writes
``BENCH_serving.json`` and the retrieval suite writes
``BENCH_retrieval.json`` (override paths with ``BENCH_OPH_JSON`` /
``BENCH_PREPROCESS_JSON`` / ``BENCH_STREAMING_JSON`` /
``BENCH_SERVING_JSON`` / ``BENCH_RETRIEVAL_JSON``) so the
preprocessing-, training-, serving- and retrieval-throughput
trajectories are machine-readable across commits.
"""
import json
import os
import sys
import traceback

# Suites whose records feed the perf-trajectory files.
OPH_SUITES = ("kernels_oph", "oph_curve")
PREPROCESS_SUITES = ("preprocess", "dispatch_preprocess")
STREAMING_SUITES = ("streaming", "multihost")
SERVING_SUITES = ("serving", "dispatch_serving")
RETRIEVAL_SUITES = ("retrieval",)

SMOKE_DEFAULT = ["kernels_fused", "preprocess", "streaming", "serving",
                 "retrieval", "dispatch_preprocess"]


def _write_json(path_env: str, default: str, bench: str, records) -> None:
    path = os.environ.get(path_env, default)
    payload = {
        "bench": bench,
        "records": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in records
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(records)} records)", file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    if smoke:
        argv = [a for a in argv if a != "--smoke"]
        os.environ["BENCH_SMOKE"] = "1"   # before benchmarks.* imports

    from benchmarks import (dispatch_bench, kernel_bench, paper_figures,
                            preprocess_bench, retrieval_bench,
                            roofline_report, serving_bench,
                            streaming_bench)

    suites = {
        "fig1": paper_figures.fig1_fig2_svm,
        "fig3": paper_figures.fig3_fig4_logistic,
        "fig5": paper_figures.fig5_fig6_vw_vs_bbit,
        "fig7": paper_figures.fig7_train_time_vw_vs_bbit,
        "fig8": paper_figures.fig8_universal_vs_permutations,
        "table2": paper_figures.table2_preprocessing_cost,
        "variance": paper_figures.variance_check,
        "compact": paper_figures.compact_index_trick,
        "oph_curve": paper_figures.oph_vs_minwise_vs_vw,
        "kernels_minhash": kernel_bench.minhash_bench,
        "kernels_oph": kernel_bench.oph_bench,
        "kernels_fused": kernel_bench.fused_encode_bench,
        "kernels_bbit": kernel_bench.bbit_linear_bench,
        "kernels_vw": kernel_bench.vw_sketch_bench,
        "roofline": roofline_report.roofline_rows,
        "preprocess": preprocess_bench.preprocess_bench,
        "streaming": streaming_bench.streaming_bench,
        "multihost": streaming_bench.multihost_bench,
        "serving": serving_bench.serving_bench,
        "retrieval": retrieval_bench.retrieval_bench,
        "dispatch_preprocess": dispatch_bench.dispatch_preprocess_bench,
        "dispatch_serving": dispatch_bench.dispatch_serving_bench,
    }
    if argv:
        selected = argv
    elif smoke:
        selected = SMOKE_DEFAULT
    else:
        selected = list(suites)
    print("name,us_per_call,derived")
    failures = 0
    trajectories = {           # suite group → (records, failed flag)
        "oph": [OPH_SUITES, [], False],
        "preprocess": [PREPROCESS_SUITES, [], False],
        "streaming": [STREAMING_SUITES, [], False],
        "serving": [SERVING_SUITES, [], False],
        "retrieval": [RETRIEVAL_SUITES, [], False],
    }
    for name in selected:
        try:
            rows = suites[name]()
            for group in trajectories.values():
                if name in group[0] and rows:
                    group[1].extend(rows)
        except Exception:  # noqa: BLE001
            failures += 1
            for group in trajectories.values():
                group[2] = group[2] or name in group[0]
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if not smoke:              # tiny smoke shapes must never clobber
        if trajectories["oph"][1] and not trajectories["oph"][2]:
            _write_json("BENCH_OPH_JSON", "BENCH_oph.json", "oph",
                        trajectories["oph"][1])
        if (trajectories["preprocess"][1]
                and not trajectories["preprocess"][2]):
            _write_json("BENCH_PREPROCESS_JSON", "BENCH_preprocess.json",
                        "preprocess", trajectories["preprocess"][1])
        if (trajectories["streaming"][1]
                and not trajectories["streaming"][2]):
            _write_json("BENCH_STREAMING_JSON", "BENCH_streaming.json",
                        "streaming", trajectories["streaming"][1])
        if (trajectories["serving"][1]
                and not trajectories["serving"][2]):
            _write_json("BENCH_SERVING_JSON", "BENCH_serving.json",
                        "serving", trajectories["serving"][1])
        if (trajectories["retrieval"][1]
                and not trajectories["retrieval"][2]):
            _write_json("BENCH_RETRIEVAL_JSON", "BENCH_retrieval.json",
                        "retrieval", trajectories["retrieval"][1])
    for key, (group_suites, records, failed) in trajectories.items():
        if failed:
            # never clobber a complete trajectory file with partials
            print(f"# BENCH_{key}.json not written (a suite failed)",
                  file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
