"""One-pass streaming trainer vs the in-memory SGD path.

The paper's 200 GB scenario in miniature: preprocess a synthetic
expanded-rcv1 corpus into a multi-shard format-v3 archive, then train

  * ``streaming`` — ``fit_streaming``: one pass straight off the
    mmap'd packed shards (codes widened on device inside the train
    step), Polyak tail averaging, progressive validation;
  * ``in_memory`` — ``load_hashed`` the whole code matrix, then the
    classic ``train_bbit_sgd`` minibatch loop (same epochs / batch /
    lr, so the comparison isolates the streaming machinery).

Derived columns carry rows/s, the one-pass progressive accuracy (the
number VW reports online), held-out test accuracy for both paths and
the streaming/in-memory throughput ratio.  Suite ``streaming`` feeds
``BENCH_streaming.json`` via benchmarks.run.

``--smoke`` (CI) runs a tiny archive instead and asserts the
determinism contract: two identical runs produce bit-identical params,
and a kill (``stop_after_shards``) + resume reproduces the
uninterrupted run exactly — any drift fails the merge.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, SMOKE, corpus, emit

K = 64
B = 8
N_SHARDS = 8
BATCH = 64
LR = 5e-3
EPOCHS = 1                    # one pass — the online regime
N_DOCS = 24 if SMOKE else (800 if QUICK else 3000)


def _setup(root, n_docs, k, b, n_shards):
    """Fills ``root`` (caller-owned temp dir) with a packed archive of
    the corpus' training half; returns (codes_te, labels_te, n_tr) —
    only the held-out half is hashed in memory."""
    from repro.data import preprocess_and_save, preprocess_rows
    rows, labels = corpus(n_docs)
    n_tr = len(rows) // 2
    codes_te = preprocess_rows(rows[n_tr:], k=k, b=b, seed=1, chunk=256)
    preprocess_and_save(root, rows[:n_tr], labels[:n_tr], k=k, b=b,
                        seed=1, n_shards=n_shards, chunk=256)
    return codes_te, labels[n_tr:], n_tr


def _test_acc(params, codes_te, labels_te, lcfg):
    import jax.numpy as jnp
    from repro.models.linear import predict_classes
    from repro.train.metrics import accuracy
    return accuracy(predict_classes(params, jnp.asarray(codes_te), lcfg),
                    labels_te)


def _smoke() -> list:
    import jax
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming
    with tempfile.TemporaryDirectory(prefix="stream_bench_") as root:
        _, _, n_tr = _setup(root, N_DOCS, 16, 4, 2)
        lcfg = BBitLinearConfig(k=16, b=4)
        kw = dict(epochs=2, batch_size=8, lr=LR, seed=0)
        a = fit_streaming(root, lcfg, **kw)
        b = fit_streaming(root, lcfg, **kw)
        for x, y in zip(jax.tree.leaves(a.params),
                        jax.tree.leaves(b.params)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                "streaming run is not deterministic"
        with tempfile.TemporaryDirectory() as ck:
            part = fit_streaming(root, lcfg, ckpt_dir=ck,
                                 stop_after_shards=1, **kw)
            assert not part.completed
            resumed = fit_streaming(root, lcfg, ckpt_dir=ck, **kw)
            for x, y in zip(jax.tree.leaves(a.params),
                            jax.tree.leaves(resumed.params)):
                assert np.array_equal(np.asarray(x), np.asarray(y)), \
                    "kill/resume drifted from the uninterrupted run"
    return emit([("streaming/smoke_determinism_k16_b4", 0.0,
                  f"rows={n_tr};resume_bit_identical=1")])


def streaming_bench() -> list:
    if SMOKE:
        return _smoke()
    from repro.configs.rcv1_oph import CONFIG
    from repro.data import load_hashed
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming, train_bbit_sgd
    with tempfile.TemporaryDirectory(prefix="stream_bench_") as root:
        codes_te, labels_te, n_tr = _setup(root, N_DOCS, K, B, N_SHARDS)
        lcfg = BBitLinearConfig(k=K, b=B)

        # config supplies epochs (one pass) + averaging window; the
        # bench corpus is small so batch/lr shrink with it
        res = fit_streaming(root, lcfg, **CONFIG.stream_kwargs(
            epochs=EPOCHS, batch_size=BATCH, lr=LR), seed=0)
        t_stream = res.train_seconds
        rows_s_stream = res.examples_seen / max(t_stream, 1e-9)
        acc_stream = _test_acc(res.eval_params, codes_te, labels_te,
                               lcfg)

        t0 = time.perf_counter()
        codes_tr, labels_tr, _ = load_hashed(root)
        t_load = time.perf_counter() - t0
        mem = train_bbit_sgd(codes_tr, labels_tr, codes_te, labels_te,
                             lcfg, epochs=EPOCHS, batch_size=BATCH,
                             lr=LR, seed=0)
        rows_s_mem = (EPOCHS * n_tr) / max(mem.train_seconds, 1e-9)

    return emit([
        (f"streaming/onepass_k{K}_b{B}_stream", t_stream * 1e6,
         f"rows_per_s={rows_s_stream:.0f};"
         f"progressive_acc={res.progressive_acc:.4f};"
         f"test_acc={acc_stream:.4f};shards={N_SHARDS}"),
        (f"streaming/onepass_k{K}_b{B}_in_memory",
         (t_load + mem.train_seconds) * 1e6,
         f"rows_per_s={rows_s_mem:.0f};test_acc={mem.test_acc:.4f};"
         f"load_s={t_load:.3f};"
         f"stream_vs_mem={rows_s_stream / max(rows_s_mem, 1e-9):.2f}x"),
    ])


if __name__ == "__main__":
    streaming_bench()
