"""Streaming-trainer benchmarks: overlap, data parallelism, baselines.

The paper's 200 GB scenario in miniature: preprocess a synthetic
expanded-rcv1 corpus into a multi-shard format-v3 archive, then train

  * ``prefetch_off`` / ``prefetch_on`` — ``fit_streaming`` with the
    host-side pipeline inline vs running in the async producer thread
    (``data.prefetch``), measuring what overlap buys on this box.
    Honest caveat: the bench archive is tiny and page-cache-hot, so
    there is no real I/O to hide — what remains is GIL-held Python
    batch bookkeeping vs thread/queue overhead, and the ratio hovers
    around 1× (run-to-run 0.9–1.4× observed).  The feature targets the
    paper's regime — archives that fault in from disk — which this
    box cannot exhibit; the record tracks that the pipeline at least
    never LOSES materially;
  * ``dp2`` — the same corpus run data-parallel over 2 host-platform
    devices (``XLA_FLAGS=--xla_force_host_platform_device_count=2``,
    ``shard_map`` + ``psum_mean``): accuracy/counters parity at the
    paper config;
  * ``scaling_serial`` / ``scaling_dp2`` — 1→2 device weak scaling
    (fixed per-device batch) on a synthetic throughput archive.  The
    per-device batch must be large: each all-reduce rendezvous costs
    ~1.6 ms on a fake-device CPU mesh, and only compute-bound steps
    amortize it (at B=64/device DP measures BELOW 1× for exactly this
    reason — which is why the corpus-config ``dp2`` record documents
    accuracy parity, not speed);
  * ``onepass …_stream`` / ``…_in_memory`` — the PR-3 legacy pair:
    one-pass streaming vs ``load_hashed`` + ``train_bbit_sgd``;
  * ``ckpt_write`` / ``time_to_recover`` — the crash-safety tax and
    payoff: the durable (tmp+fsync+rename, per-leaf CRC32) checkpoint
    write/restore cost at this model size, and the wall clock for the
    supervised restart loop to recover from an injected mid-run crash
    and finish bit-identical to the uninterrupted run.

Each overlap/scaling variant runs in its OWN subprocess (fresh compile
cache, own XLA device count) and fits TWICE: the first (cold) call
pays compile, the second (warm) call is the steady-state rows/s the
derived columns report — the number the paper's "loading should be
hidden behind compute" claim is about.  Workers also assert their two
fits are bit-identical (a determinism canary on every bench run).

``--smoke`` (CI) runs a tiny archive instead and asserts the
determinism contract: prefetch-on equals prefetch-off BITWISE, two
identical runs produce bit-identical params, a kill
(``stop_after_shards``) + resume reproduces the uninterrupted run
exactly, and an injected-crash round (torn first checkpoint write +
a mid-shard process crash, ``ft.faults``) self-heals under
``run_supervised`` back to the same bits — any drift fails the merge.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, SMOKE, corpus, emit

K = 64
B = 8
N_SHARDS = 8
BATCH = 64
LR = 5e-3
EPOCHS = 1                    # one pass — the online regime
WARM_EPOCHS = 10              # steady-state timing runs
N_DOCS = 24 if SMOKE else (1600 if QUICK else 3000)
# device-scaling pair: per-device batch big enough that compute
# amortizes the per-step collective rendezvous
SCALE_BATCH = 4096
SCALE_SHARDS = 2
SCALE_EPOCHS = 12


def _setup(root, n_docs, k, b, n_shards):
    """Fills ``root`` (caller-owned temp dir) with a packed archive of
    the corpus' training half; returns (codes_te, labels_te, n_tr) —
    only the held-out half is hashed in memory."""
    from repro.data import preprocess_and_save, preprocess_rows
    rows, labels = corpus(n_docs)
    n_tr = len(rows) // 2
    codes_te = preprocess_rows(rows[n_tr:], k=k, b=b, seed=1, chunk=256)
    preprocess_and_save(root, rows[:n_tr], labels[:n_tr], k=k, b=b,
                        seed=1, n_shards=n_shards, chunk=256)
    return codes_te, labels[n_tr:], n_tr


def _test_acc(params, codes_te, labels_te, lcfg):
    import jax.numpy as jnp
    from repro.models.linear import predict_classes
    from repro.train.metrics import accuracy
    return accuracy(predict_classes(params, jnp.asarray(codes_te), lcfg),
                    labels_te)


def _setup_scaling(root, rows_per_shard, n_shards, k, b):
    """Throughput-only archive: many short random docs, hashed fast —
    rows sized so one shard holds a full SCALE_BATCH minibatch.  Labels
    are arbitrary (no accuracy is reported off this archive)."""
    from repro.data import preprocess_and_save
    rng = np.random.default_rng(7)
    n = rows_per_shard * n_shards
    rows = [rng.integers(0, 1 << 24, size=rng.integers(16, 48))
            .astype(np.int32) for _ in range(n)]
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    preprocess_and_save(root, rows, labels, k=k, b=b, seed=1,
                        n_shards=n_shards, chunk=2048)


# ------------------------------------------------------ worker side -------
def _summarize(run, cold, lcfg, te_path):
    """``cold=None`` when this variant never ran its own cold fit (the
    overlap worker pays compile once, under the ON pipeline)."""
    import jax
    out = {
        "rows_per_s": run.examples_seen / max(run.train_seconds, 1e-9),
        "warm_s": run.train_seconds,
        "steps": run.n_steps,
        "progressive_acc": run.progressive_acc,
        "devices": len(jax.devices()),
    }
    if cold is not None:
        out["cold_s"] = cold.train_seconds
    if te_path:
        te = np.load(te_path)
        out["test_acc"] = float(_test_acc(
            run.eval_params, te["codes"], te["labels"], lcfg))
    return out


def _assert_same_params(a, b):
    from repro.train import trees_bitwise_equal
    assert trees_bitwise_equal(a.params, b.params), \
        "bench fits are not deterministic"


def _worker(cfg: dict) -> None:
    """Runs inside a fresh subprocess (XLA_FLAGS set by the parent):
    cold fit (pays compile) + warm fits (steady state), bit-identity
    asserted between every pair, held-out accuracy on the reported
    result.  Prints one JSON line on stdout.

    ``mode="single"``: best-of-3 warm fits (fastest ≈ least
    contended).  ``mode="overlap"``: alternates prefetch-OFF and
    prefetch-ON fits in the SAME process — they share the cached
    jitted step, so only the pipeline differs — and reports the
    adjacent pair with the smallest combined time; box-level load
    swings (±40 % observed across subprocesses on this shared
    2-core machine) cancel out of the ratio.
    """
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming

    lcfg = BBitLinearConfig(k=cfg["k"], b=cfg["b"])
    kw = dict(epochs=cfg["epochs"], batch_size=cfg["batch"],
              lr=cfg["lr"], seed=0, data_parallel=cfg["data_parallel"])
    if cfg.get("mode", "single") == "overlap":
        cold = fit_streaming(cfg["root"], lcfg, prefetch=cfg["prefetch"],
                             **kw)
        best = None
        for _ in range(3):
            off = fit_streaming(cfg["root"], lcfg, prefetch=0, **kw)
            on = fit_streaming(cfg["root"], lcfg,
                               prefetch=cfg["prefetch"], **kw)
            _assert_same_params(cold, off)
            _assert_same_params(off, on)
            combined = off.train_seconds + on.train_seconds
            if best is None or combined < best[0]:
                best = (combined, off, on)
        _, off, on = best
        print(json.dumps({
            "off": _summarize(off, None, lcfg, cfg["te_path"]),
            "on": _summarize(on, cold, lcfg, cfg["te_path"]),
        }))
        return
    cold = fit_streaming(cfg["root"], lcfg, prefetch=cfg["prefetch"],
                         **kw)
    warm = None
    for _ in range(3):
        run = fit_streaming(cfg["root"], lcfg,
                            prefetch=cfg["prefetch"], **kw)
        _assert_same_params(cold, run)
        if warm is None or run.train_seconds < warm.train_seconds:
            warm = run
    print(json.dumps(_summarize(warm, cold, lcfg, cfg["te_path"])))


def _paired(run_a, run_b, rounds=2):
    """Runs the (baseline, variant) worker pair ``rounds`` times
    back-to-back and returns the round with the smallest combined warm
    time.  Ratios on this shared box are meaningless unless both sides
    see the same load window — independent best-of runs routinely
    catch one lucky and one contended measurement."""
    best = None
    for _ in range(rounds):
        a, b = run_a(), run_b()
        combined = a["warm_s"] + b["warm_s"]
        if best is None or combined < best[0]:
            best = (combined, a, b)
    return best[1], best[2]


def _run_worker(root, te_path, *, prefetch, data_parallel, devices,
                batch=BATCH, epochs=WARM_EPOCHS, mode="single"):
    cfg = dict(root=root, te_path=te_path, k=K, b=B, batch=batch, lr=LR,
               epochs=epochs, prefetch=prefetch,
               data_parallel=data_parallel, mode=mode)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.streaming_bench",
         "--worker", json.dumps(cfg)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=here)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench worker failed\nSTDOUT:\n{proc.stdout[-2000:]}\n"
            f"STDERR:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


# ------------------------------------------------------- smoke tier -------
def _smoke() -> list:
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming, trees_bitwise_equal as same

    with tempfile.TemporaryDirectory(prefix="stream_bench_") as root:
        _, _, n_tr = _setup(root, N_DOCS, 16, 4, 2)
        lcfg = BBitLinearConfig(k=16, b=4)
        kw = dict(epochs=2, batch_size=4, lr=LR, seed=0)
        off = fit_streaming(root, lcfg, prefetch=0, **kw)
        on = fit_streaming(root, lcfg, prefetch=2, **kw)
        assert same(off.params, on.params), \
            "prefetch-on drifted from prefetch-off"
        assert (off.examples_seen == on.examples_seen
                and off.progressive_acc == on.progressive_acc), \
            "prefetch changed the progressive-validation counters"
        again = fit_streaming(root, lcfg, prefetch=2, **kw)
        assert same(on.params, again.params), \
            "streaming run is not deterministic"
        with tempfile.TemporaryDirectory() as ck:
            part = fit_streaming(root, lcfg, ckpt_dir=ck,
                                 stop_after_shards=1, **kw)
            assert not part.completed
            resumed = fit_streaming(root, lcfg, ckpt_dir=ck, **kw)
            assert same(on.params, resumed.params), \
                "kill/resume drifted from the uninterrupted run"
        # injected-crash round: tear the first checkpoint write AND
        # kill a mid-shard step — the supervised restart loop must
        # quarantine, fall back, replay, and still land bit-identical
        from repro.ft import BackoffPolicy, FaultEvent, FaultPlan, faults
        from repro.train import RestartPolicy, run_supervised
        with tempfile.TemporaryDirectory() as ck:
            plan = FaultPlan([FaultEvent(site="ckpt_write", times=1),
                              FaultEvent(site="train_step", step=5,
                                         times=1)])
            pol = RestartPolicy(max_restarts=3,
                                backoff=BackoffPolicy(base_s=0.005,
                                                      factor=2.0,
                                                      cap_s=0.02,
                                                      jitter_frac=0.0))
            with faults.arm(plan):
                sup = run_supervised(root, lcfg, policy=pol,
                                     ckpt_dir=ck, **kw)
            assert sup.restarts == 2, sup.crashes
            assert same(on.params, sup.result.params), \
                "supervised crash-recovery drifted from the " \
                "uninterrupted run"
            assert (on.examples_seen == sup.result.examples_seen
                    and on.progressive_acc
                    == sup.result.progressive_acc), \
                "crash recovery broke the progressive counters"
    return emit([("streaming/smoke_determinism_k16_b4", 0.0,
                  f"rows={n_tr};resume_bit_identical=1;"
                  "prefetch_bit_identical=1;"
                  f"supervised_crash_bit_identical=1;"
                  f"injected_restarts={sup.restarts}")])


# -------------------------------------------------------- full tier -------
def streaming_bench() -> list:
    if SMOKE:
        return _smoke()
    from repro.configs.rcv1_oph import CONFIG
    from repro.data import load_hashed
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming, train_bbit_sgd
    with tempfile.TemporaryDirectory(prefix="stream_bench_") as root:
        codes_te, labels_te, n_tr = _setup(root, N_DOCS, K, B, N_SHARDS)
        te_path = os.path.join(root, "heldout.npz")
        np.savez(te_path, codes=codes_te, labels=labels_te)
        lcfg = BBitLinearConfig(k=K, b=B)

        # prefetch off/on alternate INSIDE one worker process (shared
        # cached step, adjacent load windows) — the only measurement
        # structure that survives this box's noise
        pair = _run_worker(root, te_path, prefetch=2, data_parallel=None,
                           devices=1, mode="overlap")
        off, on = pair["off"], pair["on"]
        dp2 = _run_worker(root, te_path, prefetch=2, data_parallel=2,
                          devices=2)
        overlap = on["rows_per_s"] / max(off["rows_per_s"], 1e-9)

        # 1→2 device weak scaling at a compute-bound per-device batch
        scale_root = os.path.join(root, "scaling")
        _setup_scaling(scale_root, SCALE_BATCH, SCALE_SHARDS, K, B)
        s1, s2 = _paired(
            lambda: _run_worker(scale_root, None, prefetch=2,
                                data_parallel=None, devices=1,
                                batch=SCALE_BATCH, epochs=SCALE_EPOCHS),
            lambda: _run_worker(scale_root, None, prefetch=2,
                                data_parallel=2, devices=2,
                                batch=SCALE_BATCH, epochs=SCALE_EPOCHS))
        scaling = s2["rows_per_s"] / max(s1["rows_per_s"], 1e-9)

        # PR-3 legacy pair: one-pass streaming vs load-then-SGD
        res = fit_streaming(root, lcfg, **CONFIG.stream_kwargs(
            epochs=EPOCHS, batch_size=BATCH, lr=LR,
            data_parallel=None), seed=0)
        t_stream = res.train_seconds
        rows_s_stream = res.examples_seen / max(t_stream, 1e-9)
        acc_stream = _test_acc(res.eval_params, codes_te, labels_te,
                               lcfg)

        t0 = time.perf_counter()
        codes_tr, labels_tr, _ = load_hashed(root)
        t_load = time.perf_counter() - t0
        mem = train_bbit_sgd(codes_tr, labels_tr, codes_te, labels_te,
                             lcfg, epochs=EPOCHS, batch_size=BATCH,
                             lr=LR, seed=0)
        rows_s_mem = (EPOCHS * n_tr) / max(mem.train_seconds, 1e-9)

        # crash-safety records (PR 7): the durable (fsync + CRC)
        # checkpoint write/restore cost at this model size, and the
        # wall clock to recover from an injected mid-run crash under
        # the supervised restart loop (backoff + quarantine-checked
        # restore + replay to completion of the interrupted pass).
        import jax
        from repro.ckpt import checkpoint as ckpt_mod
        from repro.ft import BackoffPolicy, FaultEvent, FaultPlan, faults
        from repro.train import (RestartPolicy, run_supervised,
                                 trees_bitwise_equal)
        state_tree = {"params": [np.asarray(x)
                                 for x in jax.tree.leaves(res.params)]}
        ck_io = os.path.join(root, "ckpt_io_bench")
        t_saves, t_restores = [], []
        for i in range(5):
            t0 = time.perf_counter()
            ckpt_mod.save(ck_io, i + 1, state_tree)
            t_saves.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            ckpt_mod.restore(ck_io, state_tree)
            t_restores.append(time.perf_counter() - t0)
        t_save = float(np.median(t_saves))
        t_restore = float(np.median(t_restores))

        ck_rec = os.path.join(root, "ckpt_recover_bench")
        crash_step = res.n_steps // 2
        plan = FaultPlan([FaultEvent(site="train_step", step=crash_step,
                                     times=1)])
        pol = RestartPolicy(max_restarts=2,
                            backoff=BackoffPolicy(base_s=0.005,
                                                  factor=2.0, cap_s=0.02,
                                                  jitter_frac=0.0))
        with faults.arm(plan):
            sup = run_supervised(root, lcfg, policy=pol, ckpt_dir=ck_rec,
                                 **CONFIG.stream_kwargs(
                                     epochs=EPOCHS, batch_size=BATCH,
                                     lr=LR, data_parallel=None), seed=0)
        assert sup.restarts == 1
        assert trees_bitwise_equal(res.params, sup.result.params), \
            "supervised crash-recovery drifted from the plain run"
        t_recover = sup.crashes[0].recover_s
        n_saves = max(1, res.shards_processed // CONFIG.ckpt_every_shards)
        ckpt_overhead = t_save * n_saves / max(t_stream, 1e-9)

    return emit([
        (f"streaming/prefetch_off_k{K}_b{B}", off["warm_s"] * 1e6,
         f"rows_per_s={off['rows_per_s']:.0f};"
         f"steps={off['steps']};test_acc={off['test_acc']:.4f}"),
        (f"streaming/prefetch_on_k{K}_b{B}", on["warm_s"] * 1e6,
         f"rows_per_s={on['rows_per_s']:.0f};overlap_vs_off={overlap:.2f}x;"
         f"cold_s={on['cold_s']:.3f};test_acc={on['test_acc']:.4f};"
         "note=page_cache_hot_no_real_io_to_hide"),
        (f"streaming/dp2_k{K}_b{B}", dp2["warm_s"] * 1e6,
         f"rows_per_s={dp2['rows_per_s']:.0f};devices={dp2['devices']};"
         f"test_acc={dp2['test_acc']:.4f};"
         f"progressive_acc={dp2['progressive_acc']:.4f}"),
        (f"streaming/scaling_serial_k{K}_b{B}_B{SCALE_BATCH}",
         s1["warm_s"] * 1e6,
         f"rows_per_s={s1['rows_per_s']:.0f};steps={s1['steps']}"),
        (f"streaming/scaling_dp2_k{K}_b{B}_B{SCALE_BATCH}",
         s2["warm_s"] * 1e6,
         f"rows_per_s={s2['rows_per_s']:.0f};"
         f"scaling_1to2dev={scaling:.2f}x;devices={s2['devices']};"
         "note=weak_scaling_fixed_per_device_batch"),
        (f"streaming/onepass_k{K}_b{B}_stream", t_stream * 1e6,
         f"rows_per_s={rows_s_stream:.0f};"
         f"progressive_acc={res.progressive_acc:.4f};"
         f"test_acc={acc_stream:.4f};shards={N_SHARDS}"),
        (f"streaming/onepass_k{K}_b{B}_in_memory",
         (t_load + mem.train_seconds) * 1e6,
         f"rows_per_s={rows_s_mem:.0f};test_acc={mem.test_acc:.4f};"
         f"load_s={t_load:.3f};"
         f"stream_vs_mem={rows_s_stream / max(rows_s_mem, 1e-9):.2f}x"),
        (f"streaming/ckpt_write_k{K}_b{B}", t_save * 1e6,
         f"restore_us={t_restore * 1e6:.0f};fsync=1;crc32=1;"
         f"leaves={len(state_tree['params'])};ring_keep=3;"
         f"onepass_overhead={ckpt_overhead:.4f}x"),
        (f"streaming/time_to_recover_k{K}_b{B}", t_recover * 1e6,
         f"crash_step={crash_step};restarts={sup.restarts};"
         f"bit_identical=1;"
         "note=backoff+validated_restore+replay_to_completion"),
    ])


def multihost_bench() -> list:
    """PR-10 records: a REAL 2-process ``jax.distributed`` localhost
    gang (gloo CPU collectives, per-rank shard ownership, coordinated
    checkpoints) vs the bit-identical single-process elastic fold, and
    the gang's time-to-recover from a ``kill -9`` mid-run under
    gang-restart supervision.  Gang wall-clock includes worker spawn +
    jax import + distributed init (the real cost of a gang attempt);
    the derived ``train_s`` column is the inner fit time."""
    if SMOKE:
        return []
    import jax

    from repro.ft import BackoffPolicy, FaultEvent, FaultPlan
    from repro.models.linear import BBitLinearConfig
    from repro.train import (RestartPolicy, fit_streaming,
                             run_multiprocess_supervised)

    fit = dict(epochs=EPOCHS, batch_size=BATCH, lr=LR, data_parallel=2,
               elastic=True, prefetch=0, seed=0)
    pol = RestartPolicy(max_restarts=2,
                        backoff=BackoffPolicy(base_s=0.05, cap_s=0.5))
    with tempfile.TemporaryDirectory(prefix="mh_bench_") as root:
        _setup(root, N_DOCS, K, B, N_SHARDS)
        lcfg = BBitLinearConfig(k=K, b=B)

        t0 = time.perf_counter()
        ref = fit_streaming(root, lcfg, **fit)
        t_serial = time.perf_counter() - t0
        rows_serial = ref.examples_seen / max(t_serial, 1e-9)
        ref_leaves = [np.asarray(x) for x in jax.tree.leaves(ref.params)]

        t0 = time.perf_counter()
        clean = run_multiprocess_supervised(
            root, lcfg, procs=2, run_dir=os.path.join(root, "gang"),
            policy=pol, ckpt_dir=os.path.join(root, "gang", "ckpt"),
            **fit)
        t_gang = time.perf_counter() - t0
        assert clean.restarts == 0
        rec = clean.result
        got = np.load(clean.params_paths[0])
        assert all(np.array_equal(got[f"p{i}"], leaf)
                   for i, leaf in enumerate(ref_leaves)), \
            "2-process gang drifted from the single-process fold"
        rows_gang = rec["examples_seen"] / max(rec["train_seconds"],
                                               1e-9)

        crash_step = rec["n_steps"] // 2
        plan = FaultPlan([FaultEvent(site="proc_kill", step=crash_step,
                                     rank=1, times=1)])
        killed = run_multiprocess_supervised(
            root, lcfg, procs=2, run_dir=os.path.join(root, "gang_kill"),
            policy=pol, fault_spec=plan.to_spec(),
            ckpt_dir=os.path.join(root, "gang_kill", "ckpt"), **fit)
        assert killed.restarts == 1
        got = np.load(killed.params_paths[0])
        assert all(np.array_equal(got[f"p{i}"], leaf)
                   for i, leaf in enumerate(ref_leaves)), \
            "gang kill-9 recovery drifted from the uninterrupted run"
        t_recover = killed.crashes[0].recover_s

    return emit([
        (f"streaming/multihost_serial_ref_k{K}_b{B}", t_serial * 1e6,
         f"rows_per_s={rows_serial:.0f};steps={ref.n_steps};"
         "note=1proc_elastic_fold_of_dp2"),
        (f"streaming/multihost_gang2_k{K}_b{B}", t_gang * 1e6,
         f"rows_per_s_inner={rows_gang:.0f};"
         f"train_s={rec['train_seconds']:.3f};procs=2;"
         f"bitwise_vs_serial=1;"
         f"spawn_overhead_s={t_gang - rec['train_seconds']:.3f};"
         "note=wall_includes_spawn+jax_import+dist_init"),
        (f"streaming/multihost_time_to_recover_k{K}_b{B}",
         t_recover * 1e6,
         f"crash_step={crash_step};restarts=1;bit_identical=1;"
         "note=kill9_rank1+gang_respawn+coordinated_restore+replay"),
    ])


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        _worker(json.loads(sys.argv[2]))
    else:
        streaming_bench()
