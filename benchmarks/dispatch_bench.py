"""Cost-model dispatch: measured-profile choices vs the static policy.

Two paired suites (PR-8), one per trajectory file:

  * ``dispatch_preprocess`` — the one-time hashing pass (fused
    encode→pack, the paper's Table-2 cost) run twice on identical data:
    once under the static platform heuristics, once with a freshly
    calibrated cost profile installed.  Outputs are asserted
    bit-identical before timing is trusted; the derived column records
    which implementation each policy picked, so a profile that merely
    *confirms* the heuristic (the common case on a machine whose
    fallback is the measured winner) is visible as such.
  * ``dispatch_serving``  — the fused serving engine with its static
    pow-2 row-bucket grid vs the per-lane grid + drain caps derived
    from a measured ``serve_score`` curve, scoring the same ragged
    request stream (scores asserted identical — micro-batch shape must
    never change results).

``--smoke`` runs the calibration machinery itself: a budget-capped
``perf.calibrate`` pass at tiny shapes, profile save→load round-trip,
and identical-decision checks — no timings, no trajectory JSON.

Caveat carried in every derived column: 2-core CI boxes time with ~2×
swing, so paired same-process measurements (and ``best-of``) are used,
and on CPU the honest expectation is parity — the cost model's win
condition here is "never slower than static, identical bytes", with
the actual selection upside reserved for boxes where the measured
winner differs from the heuristic.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, SMOKE, corpus, emit

K = 256
B = 8
SCHEME = "oph"
N_DOCS = 24 if SMOKE else (400 if QUICK else 1500)
SERVE_NNZ_BUCKETS = (512, 2048)
SERVE_MAX_BATCH = 16
REPEATS = 3
SERVE_REPEATS = 5      # ~25 ms passes: min-of-5 tames 2-core box noise


def _calibrated_profile(tmp_dir=None, budget_s=30.0):
    """Budget-capped calibration at this bench's shapes; returns the
    loaded-from-disk table (exercising the round trip) when a dir is
    given, else the in-memory table."""
    from repro import perf
    table = perf.calibrate(
        k=K, b_values=(B,), schemes=(SCHEME,),
        encode_rows=(64,), encode_widths=(256, 1024),
        logits_rows=(256,), max_batch=SERVE_MAX_BATCH,
        nnz_buckets=SERVE_NNZ_BUCKETS, trials=2, budget_s=budget_s,
        seed=0, table_version="bench")
    if tmp_dir is not None:
        path = f"{tmp_dir}/profile.json"
        table.save(path)
        table = perf.CostTable.load(path)
    return table


def _smoke():
    """Budget-capped calibration + profile round-trip (the CI tier)."""
    import tempfile

    from repro import perf
    perf.reset()
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        table = _calibrated_profile(td, budget_s=60.0)
        dt = time.perf_counter() - t0
        assert table.entries, "calibration produced an empty table"
        assert table.matches_device()
        shape = {"scheme": SCHEME, "k": K, "b": B, "rows": 64, "nnz": 256}
        perf.set_profile(table)
        before = perf.choose("encode_packed", shape)
        perf.reset()
        perf.set_profile(perf.CostTable.load(f"{td}/profile.json"))
        assert perf.choose("encode_packed", shape) == before, \
            "profile round-trip changed a decision"
        # an exhausted budget must still yield a valid (empty) table
        empty = perf.calibrate(k=K, b_values=(B,), schemes=(SCHEME,),
                               encode_rows=(16,), encode_widths=(32,),
                               logits_rows=(16,), nnz_buckets=(32,),
                               trials=1, budget_s=0.0, seed=0)
        assert empty.entries == {}
    perf.reset()
    return emit([(
        "dispatch/smoke_calibrate_roundtrip", dt * 1e6,
        f"entries={len(table.entries)};decision={before};budget_capped=1")])


def _encode_pass(rows):
    from repro.data import preprocess_rows_packed
    packed, _ = preprocess_rows_packed(rows, K, B, scheme=SCHEME, seed=1,
                                       chunk=64)
    return packed


def dispatch_preprocess_bench():
    from repro import perf
    if SMOKE:
        return _smoke()
    rows, _ = corpus(N_DOCS)
    perf.reset()
    out_static = _encode_pass(rows)          # warm the jit caches once
    static_impl = _any_encode_choice(perf)
    table = _calibrated_profile()
    perf.set_profile(table)
    out_model = _encode_pass(rows)
    model_impl = _any_encode_choice(perf)
    # interleaved rounds: both policies see the same box-load envelope
    t_static = t_model = float("inf")
    for _ in range(REPEATS):
        perf.clear_profile()
        t_static = min(t_static, _timed(_encode_pass, rows)[1])
        perf.set_profile(table)
        t_model = min(t_model, _timed(_encode_pass, rows)[1])
    rep = perf.dispatch_report()
    perf.reset()
    assert np.array_equal(out_static, out_model), \
        "cost-model dispatch changed preprocessing bytes"
    nnz = sum(len(r) for r in rows)
    caveat = "box=2core_interleaved_best_of_%d" % REPEATS
    return emit([
        (f"dispatch/preprocess_k{K}_b{B}_static", t_static * 1e6,
         f"Mnnz_per_s={nnz / t_static / 1e6:.1f};impl={static_impl};"
         f"{caveat}"),
        (f"dispatch/preprocess_k{K}_b{B}_costmodel", t_model * 1e6,
         f"Mnnz_per_s={nnz / t_model / 1e6:.1f};impl={model_impl};"
         f"profile_hits={rep['hits']};"
         f"speedup_vs_static={t_static / t_model:.2f}x;{caveat}"),
    ])


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def _any_encode_choice(perf):
    rep = perf.dispatch_report()
    for key, impl in rep["choices"].items():
        if key.startswith("encode_packed|"):
            return impl
    return "?"


def _ragged_docs(rng, n):
    return [np.unique(rng.integers(0, 1 << 26, size=s))
            for s in rng.integers(16, 1800, size=n)]


def dispatch_serving_bench():
    from repro import perf
    if SMOKE:
        return emit([("dispatch/serving_smoke_skipped", 0.0,
                      "covered_by=dispatch_preprocess_smoke")])
    import jax
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import HashedClassifierEngine

    cfg = BBitLinearConfig(k=K, b=B)
    params = init_bbit_linear(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    docs = _ragged_docs(rng, 256 if QUICK else 768)
    kw = dict(seed=1, max_batch=SERVE_MAX_BATCH, max_wait_ms=1.0,
              scheme=SCHEME, nnz_buckets=SERVE_NNZ_BUCKETS,
              row_buckets=None)

    # both engines built and warmed up-front (dispatch choices bake in
    # at trace time), then timed in interleaved rounds so the 2-core
    # box's load envelope hits static and cost-model passes alike
    perf.reset()
    eng_s = HashedClassifierEngine(params, cfg, **kw)
    perf.set_profile(_calibrated_profile())
    eng_m = HashedClassifierEngine(params, cfg, **kw)
    try:
        s_static, s_model = eng_s.score_docs(docs), eng_m.score_docs(docs)
        t_static = t_model = float("inf")
        for _ in range(SERVE_REPEATS):
            t_static = min(t_static, _timed(eng_s.score_docs, docs)[1])
            t_model = min(t_model, _timed(eng_m.score_docs, docs)[1])
        st_static, st_model = eng_s.stats(), eng_m.stats()
    finally:
        eng_s.close()
        eng_m.close()
        perf.reset()
    assert np.array_equal(s_static, s_model), \
        "profile-derived micro-batching changed scores"
    caveat = "box=2core_interleaved_best_of_%d" % SERVE_REPEATS
    n = len(docs)
    return emit([
        (f"dispatch/serving_k{K}_b{B}_static", t_static / n * 1e6,
         f"docs_per_s={n / t_static:.0f};"
         f"row_buckets={'/'.join(map(str, st_static['row_buckets']))};"
         f"{caveat}"),
        (f"dispatch/serving_k{K}_b{B}_costmodel", t_model / n * 1e6,
         f"docs_per_s={n / t_model:.0f};"
         f"lane_row_buckets={_fmt_lanes(st_model['lane_row_buckets'])};"
         f"lane_caps={_fmt_lanes(st_model['lane_caps'])};"
         f"speedup_vs_static={t_static / t_model:.2f}x;{caveat}"),
    ])


def _fmt_lanes(lanes):
    if not lanes:
        return "static"
    return "|".join(
        f"{m}:{'/'.join(map(str, v)) if isinstance(v, list) else v}"
        for m, v in sorted(lanes.items(), key=lambda kv: int(kv[0])))
