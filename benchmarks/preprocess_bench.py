"""End-to-end preprocessing throughput: PR-1 baseline vs fused pipeline.

Measures the full raw-rows → packed-bytes pass (the paper's one-time
Table-2 cost — paid exactly once per dataset, so compile time IS part
of the cost) two ways on identical data:

  * ``baseline`` — the PR-1 pipeline: length-sorted chunks padded to
    exact 128-multiples (a fresh jit shape — and XLA compile — for
    nearly every distinct chunk width), unfused encode returning
    full-width uint16 codes to the host, then host-side numpy
    ``pack_codes`` over the whole matrix (the v2 save path);
  * ``fused``    — the PR-2 pipeline (``preprocess_rows_packed``):
    fixed-width nnz tiles streamed through O(1) compiled graphs
    (``core.schemes._stream_tiles``), hash→b-bit→pack fused on the
    device, double-buffered dispatch, only ceil(k·b/8) bytes per row
    synced.

Each (variant, b) cell runs in a FRESH subprocess so jit caches never
leak between measurements: ``cold`` is the first pass (the one-time
preprocessing number), ``warm`` a second pass in the same process (the
steady state a many-chunk 200GB run amortizes to).  Derived columns
carry Mnnz/s, the fused/baseline speedup, and host↔device bytes per
row.  Outputs are asserted bit-identical before timing is trusted.

Suite ``preprocess`` feeds ``BENCH_preprocess.json`` via benchmarks.run
(skipped in ``--smoke`` mode, which runs one tiny in-process parity
pass instead, so CI shapes never clobber the tracked trajectory).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import QUICK, SMOKE, corpus, emit

K = 256
SCHEME = "oph"     # the ROADMAP hot path; minwise differs only in-kernel
# Many small chunks = the 200GB regime in miniature: enough distinct
# chunk widths that the PR-1 per-width recompile pathology is visible.
CHUNK = 64
N_DOCS = 24 if SMOKE else (800 if QUICK else 3000)


def _baseline_preprocess(rows, k, b, *, scheme=SCHEME, seed=1,
                         chunk=CHUNK):
    """The PR-1 pipeline, reproduced exactly (see module docstring)."""
    from repro.core.bbit import pack_codes
    from repro.core.schemes import make_scheme
    from repro.data.packing import pad_rows
    sch = make_scheme(scheme, k, seed)
    out = np.empty((len(rows), k), dtype=np.uint16)
    order = np.argsort([len(r) for r in rows], kind="stable")
    for lo in range(0, len(rows), chunk):
        sel = order[lo: lo + chunk]
        idx, nnz = pad_rows([rows[i] for i in sel])   # exact width: one
        out[sel] = sch.encode_padded(idx, nnz, b)     # jit shape per m
    return pack_codes(out, b)                         # host-side pack


def _fused_preprocess(rows, k, b, *, seed=1, chunk=CHUNK):
    from repro.data import preprocess_rows_packed
    packed, _ = preprocess_rows_packed(rows, k, b, scheme=SCHEME,
                                       seed=seed, chunk=chunk)
    return packed


def _measure(variant: str, b: int) -> dict:
    """Cold + warm wall time of one variant — run me in a fresh process."""
    rows, _ = corpus(N_DOCS)
    fn = _baseline_preprocess if variant == "baseline" else _fused_preprocess
    t0 = time.perf_counter()
    out = fn(rows, K, b)
    cold = time.perf_counter() - t0
    warm = float("inf")          # best-of-3: robust to CI box noise
    for _ in range(3):
        t0 = time.perf_counter()
        out2 = fn(rows, K, b)
        warm = min(warm, time.perf_counter() - t0)
        assert np.array_equal(out, out2)
    import hashlib
    return dict(cold=cold, warm=warm,
                nnz=int(sum(len(r) for r in rows)),
                digest=hashlib.sha1(
                    np.ascontiguousarray(out).tobytes()).hexdigest())


def _measure_subprocess(variant: str, b: int, repeats: int = 2) -> dict:
    """Best-of-``repeats`` fresh-process measurements (2-core CI boxes
    make single cold timings swing ~2×; min-of-N is the usual cure)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    best = None
    for _ in range(repeats):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.preprocess_bench", variant,
             str(b)],
            capture_output=True, text=True, env=env, check=True)
        r = json.loads(out.stdout.splitlines()[-1])
        if best is None:
            best = r
        else:
            assert r["digest"] == best["digest"]
            best["cold"] = min(best["cold"], r["cold"])
            best["warm"] = min(best["warm"], r["warm"])
    return best


def preprocess_bench():
    if SMOKE:
        # tiny in-process parity pass: catches pipeline breakage in CI
        rows, _ = corpus(N_DOCS)
        base = _baseline_preprocess(rows, K, 8, chunk=8)
        fused = _fused_preprocess(rows, K, 8, chunk=8)
        assert np.array_equal(base, fused), "fused != baseline bytes"
        return emit([("preprocess/smoke_parity_k%d_b8" % K, 0.0,
                      f"rows={len(rows)};bit_identical=1")])
    recs = []
    for b in (1, 8):
        base = _measure_subprocess("baseline", b)
        fused = _measure_subprocess("fused", b)
        assert base["digest"] == fused["digest"], "output bytes differ"
        nnz = base["nnz"]
        bytes_row = (K * b + 7) // 8
        for phase in ("cold", "warm"):
            dt_b, dt_f = base[phase], fused[phase]
            recs.append((
                f"preprocess/{phase}_k{K}_b{b}_baseline", dt_b * 1e6,
                f"Mnnz_per_s={nnz / dt_b / 1e6:.1f};bytes_per_row={K * 4}"))
            recs.append((
                f"preprocess/{phase}_k{K}_b{b}_fused", dt_f * 1e6,
                f"Mnnz_per_s={nnz / dt_f / 1e6:.1f};"
                f"bytes_per_row={bytes_row};"
                f"speedup_vs_baseline={dt_b / dt_f:.1f}x"))
    return emit(recs)


if __name__ == "__main__":
    print(json.dumps(_measure(sys.argv[1], int(sys.argv[2]))))
