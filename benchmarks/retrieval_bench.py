"""Banded-LSH retrieval benchmarks: recall vs brute force, QPS, memory.

The packed b-bit codes the serving tier already stores are an LSH
sketch, so near-duplicate retrieval falls out of the same bytes
(``retrieval/``): r-rows-per-band keys gathered straight from the
packed codes bucket documents, and candidates are ranked by packed
Hamming similarity on device (``kernels/hamming.py`` via the
``hamming_topk`` dispatch op).

Full tier — for each ``rows_per_band`` r on one hashed corpus:

  * recall@k of ``BandedLSHIndex.query`` against ground truth ranked
    by BRUTE-FORCE true resemblance |A∩B|/|A∪B| over the raw token
    sets (not the sketch — so the number folds in both the banding
    loss and the b-bit estimation error);
  * the same recall for a full Hamming scan over every stored code
    (r-independent; isolates the banding loss from the sketch error);
  * query throughput (QPS, steady state after one warmup sweep),
    mean candidate fraction per probe, index build rate, and the
    index's own ``bytes_est`` accounting — the recall/QPS/memory
    trade as r moves.

Queries are an adversarial half/half mix: perturbed near-duplicates
of corpus documents (10% token churn — these MUST be found) and fresh
unrelated documents (nothing to find; they probe the cand-frac cost).

``--smoke`` / ``BENCH_SMOKE=1`` (CI) asserts the bit contracts on tiny
shapes: band keys gathered from packed bytes ≡ keys recomputed from
unpacked codes across aligned AND unaligned b×r grids, exact-duplicate
retrieval at rank 1 with similarity 1.0 plus near-duplicate recall on
a tiny corpus, and the serving dedup-cache contract end-to-end — a
cache HIT returns bitwise the floats a fresh cacheless dispatch
produces, without touching the batcher.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import QUICK, SMOKE, emit

K = 256
B = 8
SEED = 1
TOP_K = 10
# r must divide K and keep r*B <= 56 band bits (one uint64 gather),
# so with B=8 the legal sweep is r in {1, 2, 4}
ROWS_PER_BAND = (1, 2, 4)
N_CORPUS = 1200 if QUICK else 4000
N_QUERIES = 48 if QUICK else 150
DROP_FRAC = 0.1
ENCODE_CHUNK = 256


def _encode_packed(scheme, rows, b: int) -> np.ndarray:
    """Host-side packed codes (bit-identical to the device encode),
    chunked so padding stays bounded by the widest doc per chunk."""
    from repro.data.packing import pad_rows
    out = []
    for lo in range(0, len(rows), ENCODE_CHUNK):
        idx, nnz = pad_rows(rows[lo:lo + ENCODE_CHUNK], pad_to_multiple=1)
        packed, _ = scheme.encode_packed_numpy(idx, nnz, b)
        out.append(packed)
    return np.concatenate(out, axis=0)


def _perturb(rng, doc: np.ndarray, drop_frac: float) -> np.ndarray:
    keep = doc[rng.random(doc.size) > drop_frac]
    extra = rng.integers(0, 1 << 30,
                         size=max(1, int(doc.size * drop_frac)))
    return np.unique(np.concatenate([keep, extra.astype(doc.dtype)]))


def _resemblance_topk(queries, docs, k: int) -> list:
    """Ground truth: top-k corpus ids by |A∩B|/|A∪B| per query."""
    truth = []
    for q in queries:
        sims = np.empty(len(docs), np.float64)
        for j, d in enumerate(docs):
            inter = np.intersect1d(q, d, assume_unique=True).size
            sims[j] = inter / (q.size + d.size - inter)
        truth.append(np.argsort(-sims)[:k])
    return truth


def _recall(got_ids, truth_ids) -> float:
    hits = sum(len(set(int(i) for i in g) & set(int(i) for i in t))
               for g, t in zip(got_ids, truth_ids))
    return hits / (len(truth_ids) * len(truth_ids[0]))


# ------------------------------------------------------- smoke tier -------
def _smoke() -> list:
    from repro.core.bbit import pack_codes
    from repro.core.schemes import make_scheme
    from repro.retrieval import (BandedLSHIndex, band_keys_packed,
                                 band_keys_ref)

    # band keys straight from packed bytes ≡ keys from unpacked codes,
    # aligned (r*b % 8 == 0) and unaligned grids alike
    rng = np.random.default_rng(0)
    checked = 0
    for b in (1, 2, 3, 4, 8, 12):
        for r in (1, 2, 4):
            k = 24
            codes = rng.integers(0, 1 << b, size=(16, k)).astype(np.uint16)
            got = band_keys_packed(pack_codes(codes, b), k, b, r)
            want = band_keys_ref(codes, b, r)
            assert np.array_equal(got, want), \
                f"band keys drifted from reference (b={b}, r={r})"
            checked += 1

    # retrieval sanity on a tiny corpus: the exact duplicate is rank 1
    # at similarity 1.0; a 10%-churn near-duplicate lands in the top k
    scheme = make_scheme("oph", 64, SEED)
    docs = [np.unique(rng.integers(0, 1 << 24,
                                   size=int(rng.integers(40, 120))))
            for _ in range(32)]
    packed = _encode_packed(scheme, docs, 4)
    index = BandedLSHIndex(k=64, b=4, rows_per_band=4)
    index.insert(list(range(len(docs))), packed)
    ids, sims = index.query(packed[5], top_k=3)
    assert ids[0] == 5 and float(sims[0]) == 1.0, \
        "exact duplicate not rank-1/sim-1.0"
    near = _perturb(rng, docs[7], DROP_FRAC)
    q = _encode_packed(scheme, [near], 4)[0]
    ids, _ = index.query(q, top_k=5)
    assert 7 in [int(i) for i in ids], "near-duplicate missed at top-5"

    hit_parity = _smoke_dedup_hit_parity()
    return emit([
        ("retrieval/smoke_band_parity", 0.0,
         f"grids_bitwise_identical={checked};"
         "note=packed_gather_vs_unpacked_reference"),
        ("retrieval/smoke_recall_sanity_k64_b4", 0.0,
         "exact_dup_rank1_sim1=1;near_dup_top5=1"),
        hit_parity,
    ])


def _smoke_dedup_hit_parity() -> tuple:
    """Serving dedup-cache contract: second submit of the same doc is a
    HIT, returns bitwise the fresh cacheless floats, and never reaches
    the batcher."""
    import jax
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import HashedClassifierEngine

    rng = np.random.default_rng(3)
    docs = [np.unique(rng.integers(0, 1 << 24,
                                   size=int(rng.integers(10, 60))))
            for _ in range(6)]
    lcfg = BBitLinearConfig(k=16, b=4)
    params = init_bbit_linear(lcfg, jax.random.key(2))
    eng = HashedClassifierEngine(params, lcfg, seed=3, scheme="oph",
                                 max_batch=4, max_wait_ms=2.0,
                                 nnz_buckets=(128,), row_buckets=(1, 4),
                                 precompile=False, dedup_cache=True,
                                 dedup_entries=32)
    try:
        for d in docs:
            eng.submit(d).result(timeout=120)      # fill
        batches = eng.batcher.batches_run
        for d in docs:
            want = float(eng.score_docs([d])[0])   # bypasses the cache
            got = float(eng.submit(d).result(timeout=120))
            assert got == want, "cache hit != fresh dispatch bitwise"
        st = eng.stats()["dedup"]
        assert st["hits"] >= len(docs), f"expected hits, got {st}"
        assert eng.batcher.batches_run == batches, \
            "cache hit reached the batcher"
    finally:
        eng.close()
    return ("retrieval/smoke_dedup_hit_parity_k16_b4", 0.0,
            "hit_bitwise_eq_fresh=1;no_dispatch_on_hit=1;"
            f"hits={st['hits']};guard_rejects={st['guard_rejects']}")


# -------------------------------------------------------- full tier -------
def retrieval_bench() -> list:
    if SMOKE:
        return _smoke()
    from benchmarks.common import corpus
    from repro.core.schemes import make_scheme
    from repro.kernels import ops
    from repro.retrieval import BandedLSHIndex

    rng = np.random.default_rng(SEED)
    docs, _ = corpus(N_CORPUS)
    docs = list(docs)
    scheme = make_scheme("oph", K, SEED)
    t0 = time.perf_counter()
    packed = _encode_packed(scheme, docs, B)
    encode_s = time.perf_counter() - t0

    # half near-duplicates (must be found), half fresh docs (cost probe)
    q_docs, dup_of = [], []
    for i in range(N_QUERIES):
        if i % 2 == 0:
            j = int(rng.integers(0, len(docs)))
            q_docs.append(_perturb(rng, docs[j], DROP_FRAC))
            dup_of.append(j)
        else:
            q_docs.append(np.unique(rng.integers(
                0, 1 << 30, size=int(rng.integers(50, 3000)))))
            dup_of.append(-1)
    q_packed = _encode_packed(scheme, q_docs, B)
    t0 = time.perf_counter()
    truth = _resemblance_topk(q_docs, docs, TOP_K)
    truth_s = time.perf_counter() - t0
    dup_found_denom = sum(1 for j in dup_of if j >= 0)

    # r-independent ceiling: full Hamming scan over every stored code
    ids_all = np.arange(len(docs))
    t0 = time.perf_counter()
    scan = [ops.hamming_topk(q, packed, k=K, bits=B, topk=TOP_K)[0]
            for q in q_packed]
    scan = [np.asarray(s) for s in scan]          # block on device
    scan_s = time.perf_counter() - t0
    scan_recall = _recall(scan, truth)

    rows = [
        (f"retrieval/bruteforce_scan_k{K}_b{B}",
         scan_s / N_QUERIES * 1e6,
         f"recall_at_{TOP_K}={scan_recall:.3f};"
         f"qps={N_QUERIES / scan_s:.0f};n={len(docs)};"
         f"encode_s={encode_s:.2f};truth_s={truth_s:.2f};"
         "note=sketch_error_only_ceiling_for_banded_recall"),
    ]
    for r in ROWS_PER_BAND:
        index = BandedLSHIndex(k=K, b=B, rows_per_band=r)
        t0 = time.perf_counter()
        index.insert(list(ids_all), packed)
        build_s = time.perf_counter() - t0
        cand_frac = np.mean([len(index.candidates(q)) / len(docs)
                             for q in q_packed])
        for q in q_packed:                         # warmup (compiles)
            index.query(q, top_k=TOP_K)
        t0 = time.perf_counter()
        got = [index.query(q, top_k=TOP_K)[0] for q in q_packed]
        query_s = time.perf_counter() - t0
        recall = _recall([np.asarray(g) for g in got], truth)
        dup_found = sum(
            1 for g, j in zip(got, dup_of)
            if j >= 0 and j in [int(x) for x in g]) / dup_found_denom
        st = index.stats()
        rows.append(
            (f"retrieval/banded_r{r}_k{K}_b{B}",
             query_s / N_QUERIES * 1e6,
             f"recall_at_{TOP_K}={recall:.3f};"
             f"near_dup_found={dup_found:.3f};"
             f"qps={N_QUERIES / query_s:.0f};"
             f"cand_frac={cand_frac:.4f};"
             f"build_rows_per_s={len(docs) / build_s:.0f};"
             f"bytes_est={st['bytes_est']};bands={st['bands']};"
             f"band_bits={st['band_bits']};n={len(docs)}"))
    return emit(rows)


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        _smoke()
    else:
        retrieval_bench()
