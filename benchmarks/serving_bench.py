"""Serving-engine benchmarks: fused hot path, micro-batching, replicas.

The paper's inference-cost claim (tiny b-bit codes → tiny per-request
compute) measured as a service.  Closed- and open-loop load generators
drive ``HashedClassifierEngine`` and record p50/p95/p99 request latency
plus steady-state rows/s for:

  * ``legacy_closed``   — the PR-1-era path reconstructed: a single-
    queue ``DynamicBatcher`` (one lane: every batch pads to its widest
    document) feeding the unfused ``encode_jnp`` → ``bbit_logits``
    scorer that materializes the (B, k) int32 code matrix;
  * ``fused_closed``    — the rebuilt hot path: per-nnz-bucket lanes,
    precompiled shapes, dispatch/resolve overlap, and ONE jitted
    ``encode_packed_jit`` → ``bbit_scores_packed`` device pass;
  * ``fused_nobatch``   — the same fused scorer called per request
    (batch size 1), isolating what micro-batching itself buys;
  * ``fused_open``      — open-loop (submit as fast as possible),
    the saturation throughput + tail-latency view;
  * ``replicas1/2``     — 1 vs 2 engine replicas over fake CPU
    devices, open-loop (throughput scaling without collectives);
  * ``http_open_loop``  — the same fused engine behind the stdlib
    asyncio HTTP tier (``serving.server.ScoreServer``): concurrent
    keep-alive clients hammering batch ``POST /score``, measuring the
    full network path (parse → admission → batcher → device → JSON),
    ending in a graceful drain;
  * ``dedup_open_dup*`` — cache-on vs cache-off A/B over zipf-
    duplicated open-loop traffic at duplication ratios 0 / 0.5 / 0.9
    (``serving/dedup.py``: band-signature probe, exact packed-code
    guard).  The LRU is sized below the corpus so dup=0 measures pure
    cache overhead (hit rate ~0) and dup=0.9 measures the short-
    circuit; a bitwise canary asserts every cache HIT returns exactly
    the floats a fresh cacheless dispatch produces.

Measurement structure (the only one that survives this shared box's
noise, same as streaming_bench): the legacy/fused/nobatch/open variants
alternate back-to-back INSIDE one subprocess round and the round with
the smallest combined wall time is reported, so both sides of every
ratio see the same load window.  The replica pair needs two processes
(device count is process-global) and uses paired rounds instead.
Every worker asserts fused scores equal the reference scorer's
BITWISE at identical batch shapes, and that the steady state hit only
precompiled shapes (``compile_misses == 0``) — a recompile fails the
bench.

Honest caveats baked into the records: this is a 2-core shared CPU box
— closed-loop clients, the batcher threads and the "device" all
compete for the same cores (GIL included), and 2 fake devices share
the 2 cores, so replica "scaling" mostly measures contention (≈1× is
expected here; the feature targets real multi-accelerator hosts).

``--smoke`` (CI) asserts the parity contracts on tiny shapes: fused ≡
reference bitwise across schemes × b, batched ≡ direct, empty-doc
semantics, and close() leaves no future unresolved — plus the e2e
network contract: a SUBPROCESS server (deterministic params from
``init_bbit_linear(cfg, jax.random.key(n))``, reconstructible in the
parent) is driven over real HTTP and must show bitwise score parity
vs the parent's same-shape oracle, a deterministic 429 on an
oversized request, an exact mid-traffic ``/reload`` (every response
one version, bitwise vs that version's oracle), ``compile_misses ==
0``, and a clean SIGTERM drain (exit 0, nothing dropped).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np

from benchmarks.common import QUICK, SMOKE, corpus, emit

K = 64
B = 8
MAX_BATCH = 32
MAX_WAIT_MS = 2.0
CLIENTS = 8
N_DOCS = 24 if SMOKE else (300 if QUICK else 600)
N_REQ = 400 if QUICK else 1200
ROUNDS = 3
NNZ_BUCKETS = (512, 2048, 8192)
ROW_BUCKETS = (1, 8, MAX_BATCH)
# duplicate-traffic A/B: a zipf-weighted hot pool of viral documents
# mixed into a cold sweep at a controlled duplication ratio.  The cache
# is sized well below the corpus (hot pool + in-flight cold churn) so
# at dup=0 the LRU evicts everything before it repeats — hit rate ~0 —
# while at dup=0.9 the hot pool stays resident: the bench measures the
# bounded cache, not an unbounded memo of the whole corpus.
DEDUP_RATIOS = (0.0, 0.5, 0.9)
DEDUP_HOT = 64
DEDUP_ENTRIES = 256
# both A/B engines run the same batching window, wider than the main
# bench's: at high duplication the cache strips 90% of traffic off the
# device, so the residual cold misses trickle in and need a longer
# coalescing window to form full batches (2ms windows at a 10% miss
# rate dispatch ~2-row device batches — all launch overhead)
DEDUP_MAX_WAIT_MS = 16.0
# the cache-on wall at dup=0.9 is tens of milliseconds per round, so a
# single scheduler stall on this shared box swings the A/B ratio by
# 20%+ — the dedup leg measures more rounds than the other suites and
# keeps each side's best (minimum) wall, the estimator closest to the
# noise-free value since noise only ever adds time
DEDUP_ROUNDS = 5


def _pcts(lat_s) -> dict:
    ms = np.asarray(lat_s) * 1e3
    return {"p50_ms": float(np.percentile(ms, 50)),
            "p95_ms": float(np.percentile(ms, 95)),
            "p99_ms": float(np.percentile(ms, 99))}


def _closed_loop(submit_wait, docs, n_req, clients) -> dict:
    """``clients`` threads each submit-and-wait over their share of the
    request stream; per-request latency is submit→result."""
    lats = [[] for _ in range(clients)]
    errs = []

    def client(c):
        try:
            for i in range(c, n_req, clients):
                t0 = time.perf_counter()
                submit_wait(docs[i % len(docs)])
                lats[c].append(time.perf_counter() - t0)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    flat = [x for l in lats for x in l]
    return {"wall_s": wall, "rows_per_s": n_req / wall, **_pcts(flat)}


def _open_loop(engine, docs, n_req) -> dict:
    """Submit everything as fast as the queue accepts, resolve off the
    completion callbacks — saturation throughput + tail latency."""
    done = [0.0] * n_req
    futs = []
    t0 = time.perf_counter()
    for i in range(n_req):
        t_sub = time.perf_counter()

        def cb(f, i=i, t_sub=t_sub):
            done[i] = time.perf_counter() - t_sub

        fut = engine.submit(docs[i % len(docs)])
        fut.add_done_callback(cb)
        futs.append(fut)
    for f in futs:
        f.result(timeout=600)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "rows_per_s": n_req / wall, **_pcts(done)}


def _make_docs(n_docs):
    rows, _ = corpus(n_docs)
    return rows


def _dup_stream(n_req: int, dup_ratio: float, n_docs: int,
                hot: int, seed: int) -> np.ndarray:
    """Request indices: fraction ``dup_ratio`` drawn zipf(s=1)-style
    from docs[:hot]; the rest sweep docs[hot:] round-robin (cold)."""
    rng = np.random.default_rng(seed)
    is_hot = rng.random(n_req) < dup_ratio
    p = 1.0 / np.arange(1, hot + 1, dtype=np.float64)
    hot_picks = rng.choice(hot, size=n_req, p=p / p.sum())
    cold = (np.cumsum(~is_hot) - 1) % max(n_docs - hot, 1)
    return np.where(is_hot, hot_picks, hot + cold)


def _make_engines(docs, *, replicas=1, legacy=True):
    import jax
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import DynamicBatcher, HashedClassifierEngine

    lcfg = BBitLinearConfig(k=K, b=B)
    params = init_bbit_linear(lcfg, jax.random.key(0))
    kw = dict(seed=1, max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
              nnz_buckets=NNZ_BUCKETS, row_buckets=ROW_BUCKETS,
              replicas=replicas)
    t0 = time.perf_counter()
    fused = HashedClassifierEngine(params, lcfg, fused=True, **kw)
    cold_fused = time.perf_counter() - t0
    out = {"fused": fused, "cold_fused_s": cold_fused}
    if legacy:
        t0 = time.perf_counter()
        ref = HashedClassifierEngine(params, lcfg, fused=False, **kw)
        out["ref"] = ref
        out["cold_legacy_s"] = time.perf_counter() - t0
        # the PR-1-era serving front half: ONE queue, every batch padded
        # to its widest member, scored through the unfused path
        out["legacy_batcher"] = DynamicBatcher(
            lambda batch: list(ref.score_docs(batch)),
            max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS)
    # bitwise parity canary on every bench run (identical batch shape)
    sample = docs[:16]
    a = fused.score_docs(sample)
    if legacy:
        r = out["ref"].score_docs(sample)
        assert np.array_equal(a, r), "fused scores drifted from reference"
    return out


# ------------------------------------------------------ worker side -------
def _http_load(port: int, docs, n_req: int, clients: int,
               per: int) -> dict:
    """Concurrent keep-alive HTTP clients each firing ``per``-doc batch
    ``POST /score`` requests as fast as responses come back; latency is
    the full network round-trip."""
    from repro.serving import ScoreClient

    reqs = max(clients, n_req // per)
    lats = [[] for _ in range(clients)]
    errs = []

    def client(c):
        cl = ScoreClient("127.0.0.1", port, timeout=600)
        try:
            for i in range(c, reqs, clients):
                batch = [docs[(i * per + j) % len(docs)]
                         for j in range(per)]
                t0 = time.perf_counter()
                cl.score(batch)
                lats[c].append(time.perf_counter() - t0)
        except Exception as e:  # noqa: BLE001
            errs.append(e)
        finally:
            cl.close()

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    flat = [x for l in lats for x in l]
    return {"wall_s": wall, "rows_per_s": len(flat) * per / wall,
            "requests": len(flat), **_pcts(flat)}


def _http_server_worker(cfg: dict) -> None:
    """Deterministic tiny engine behind ``ScoreServer``; prints one
    ``LISTENING <host> <port>`` line, serves until SIGTERM, then prints
    ``DRAINED <0|1>``.  Params come from ``jax.random.key(param_key)``
    so the parent process can rebuild the exact same model as its
    bitwise oracle."""
    import jax
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import HashedClassifierEngine, ScoreServer

    lcfg = BBitLinearConfig(k=16, b=4)
    params = init_bbit_linear(lcfg, jax.random.key(cfg["param_key"]))
    eng = HashedClassifierEngine(params, lcfg, seed=3, scheme="oph",
                                 max_batch=8, max_wait_ms=20.0,
                                 nnz_buckets=(64,), version="v0")
    srv = ScoreServer(
        eng, port=0,
        on_started=lambda s: print(f"LISTENING {s.host} {s.port}",
                                   flush=True))
    srv.run()                      # SIGTERM → graceful drain → returns
    print(f"DRAINED {int(bool(srv.drained_clean))}", flush=True)


def _worker(cfg: dict) -> None:
    if cfg["mode"] == "http_server":
        _http_server_worker(cfg)
        return

    docs = _make_docs(cfg["n_docs"])
    n_req = cfg["n_req"]

    if cfg["mode"] == "http":
        from repro.serving import ScoreServer
        eng = _make_engines(docs, legacy=False)
        fused = eng["fused"]
        srv = ScoreServer(fused, port=0)
        srv.start_in_thread()
        per = 8
        _http_load(srv.port, docs, n_req, cfg["clients"], per)  # warmup
        best = None
        for _ in range(ROUNDS):
            r = _http_load(srv.port, docs, n_req, cfg["clients"], per)
            if best is None or r["wall_s"] < best["wall_s"]:
                best = r
        assert fused.compile_misses == 0, "steady state recompiled"
        snap = fused.stats()
        srv.request_drain()
        assert srv.wait_finished(timeout=120), "drain hung"
        print(json.dumps({
            "open": best, "cold_s": eng["cold_fused_s"],
            "docs_per_request": per,
            "drained_clean": bool(srv.drained_clean),
            "rejected_rows": srv.admission.rejected,
            "engine_p50_ms": snap["p50_ms"]}))
        return

    if cfg["mode"] == "replicas":
        eng = _make_engines(docs, replicas=cfg["replicas"],
                            legacy=False)
        fused = eng["fused"]
        _open_loop(fused, docs, n_req)            # warmup
        best = None
        for _ in range(ROUNDS):
            r = _open_loop(fused, docs, n_req)
            if best is None or r["wall_s"] < best["wall_s"]:
                best = r
        assert fused.compile_misses == 0, "steady state recompiled"
        assert min(fused.device_batches) >= 1
        fused.close()
        print(json.dumps({"open": best, "devices": len(fused.devices),
                          "cold_s": eng["cold_fused_s"]}))
        return

    if cfg["mode"] == "dedup":
        # rounds need enough steady state for the sparse-miss
        # coalescing windows to amortize (a 400-req round is mostly
        # window tail), so the dedup A/B uses a fixed floor even in
        # QUICK mode; the cold sweep must never repeat a doc across
        # rounds (a repeat is a duplicate — at dup=0 there must be
        # none), so the corpus holds enough docs for every round's
        # disjoint window
        n_req = max(n_req, 2000)
        _dedup_worker(_make_docs(DEDUP_HOT + (DEDUP_ROUNDS + 1) * n_req),
                      n_req)
        return

    eng = _make_engines(docs)
    fused, legacy = eng["fused"], eng["legacy_batcher"]

    def run_legacy():
        return _closed_loop(
            lambda d: legacy.submit(d).result(timeout=600),
            docs, n_req, cfg["clients"])

    def run_fused():
        return _closed_loop(
            lambda d: fused.submit(d).result(timeout=600),
            docs, n_req, cfg["clients"])

    def run_nobatch():
        return _closed_loop(lambda d: fused.score_docs([d]),
                            docs, n_req, cfg["clients"])

    # warmup, then alternate all variants inside each round so every
    # ratio compares adjacent load windows
    run_legacy(), run_fused(), run_nobatch(), _open_loop(fused, docs,
                                                         n_req)
    best = None
    for _ in range(ROUNDS):
        r = {"legacy": run_legacy(), "fused": run_fused(),
             "nobatch": run_nobatch(),
             "open": _open_loop(fused, docs, n_req)}
        combined = r["legacy"]["wall_s"] + r["fused"]["wall_s"]
        if best is None or combined < best[0]:
            best = (combined, r)
    out = best[1]
    assert fused.compile_misses == 0, "steady state recompiled"
    fused.close()
    legacy.close()
    eng["ref"].close()
    out.update(cold_fused_s=eng["cold_fused_s"],
               cold_legacy_s=eng["cold_legacy_s"],
               fused_batches=fused.batcher.batches_run)
    print(json.dumps(out))


def _open_loop_many(engine, docs, n_req: int, per: int) -> dict:
    """Open loop through the batch front door (``submit_many`` in
    ``per``-doc requests — how HTTP traffic actually arrives): with the
    cache on, each request keys in ONE vectorized host-encode pass."""
    done = [0.0] * n_req
    futs = []
    t0 = time.perf_counter()
    for lo in range(0, n_req, per):
        batch = [docs[i % len(docs)]
                 for i in range(lo, min(lo + per, n_req))]
        t_sub = time.perf_counter()
        for j, fut in enumerate(engine.submit_many(batch)):
            def cb(f, i=lo + j, t_sub=t_sub):
                done[i] = time.perf_counter() - t_sub

            fut.add_done_callback(cb)
            futs.append(fut)
    # end of stream: don't leave the tail request waiting out a full
    # coalescing window (identical call for both A/B engines)
    engine.flush()
    for f in futs:
        f.result(timeout=600)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "rows_per_s": n_req / wall, **_pcts(done)}


def _dedup_worker(docs, n_req: int) -> None:
    """Cache-on vs cache-off A/B over zipf-duplicated open-loop traffic
    at each duplication ratio — interleaved rounds like the rest of the
    file, but each side reports its best (minimum) wall across rounds
    (see DEDUP_ROUNDS) — plus the bitwise canary: a cache HIT must
    return the exact floats a fresh cacheless dispatch produces."""
    import jax
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import HashedClassifierEngine

    lcfg = BBitLinearConfig(k=K, b=B)
    params = init_bbit_linear(lcfg, jax.random.key(0))
    kw = dict(seed=1, scheme="oph", max_batch=MAX_BATCH,
              max_wait_ms=DEDUP_MAX_WAIT_MS, nnz_buckets=NNZ_BUCKETS,
              row_buckets=ROW_BUCKETS)
    on = HashedClassifierEngine(params, lcfg, dedup_cache=True,
                                dedup_entries=DEDUP_ENTRIES, **kw)
    off = HashedClassifierEngine(params, lcfg, **kw)
    out = {}
    # two full lanes per request: the off engine dispatches each as an
    # immediately-full batch (window never waits), the on engine keys
    # the whole request in one vectorized host pass
    per = 2 * MAX_BATCH
    for dup in DEDUP_RATIOS:
        # one continuous stream sliced into per-round windows: cold
        # docs never repeat across rounds (only the hot pool does)
        seq = _dup_stream((DEDUP_ROUNDS + 1) * n_req, dup, len(docs),
                          DEDUP_HOT, seed=7)
        rounds = [[docs[i] for i in seq[r * n_req:(r + 1) * n_req]]
                  for r in range(DEDUP_ROUNDS + 1)]
        _open_loop_many(on, rounds[0], n_req, per)
        _open_loop_many(off, rounds[0], n_req, per)
        d0 = on.dedup.stats()
        best_on = best_off = None
        for stream in rounds[1:]:
            # rounds stay interleaved so both sides see the same load
            # pattern; each side then keeps its own minimum wall (see
            # the DEDUP_ROUNDS note — box noise only ever adds time)
            a = _open_loop_many(on, stream, n_req, per)
            b = _open_loop_many(off, stream, n_req, per)
            if best_on is None or a["wall_s"] < best_on["wall_s"]:
                best_on = a
            if best_off is None or b["wall_s"] < best_off["wall_s"]:
                best_off = b
        d1 = on.dedup.stats()
        probes = (d1["hits"] + d1["misses"]) - (d0["hits"] + d0["misses"])
        hit_rate = (d1["hits"] - d0["hits"]) / max(probes, 1)
        out[f"{dup:.1f}"] = {
            "on": best_on, "off": best_off, "hit_rate": hit_rate,
            "speedup": (best_on["rows_per_s"]
                        / max(best_off["rows_per_s"], 1e-9))}
    # bitwise canary: hot docs are resident now — a hit must equal the
    # engine's own cacheless oracle path float-for-float
    hits_before = on.dedup.stats()["hits"]
    for d in docs[:8]:
        want = float(on.score_docs([d])[0])        # bypasses the cache
        got = float(on.submit(d).result(timeout=600))
        assert got == want, "cache hit drifted from fresh dispatch"
    assert on.dedup.stats()["hits"] >= hits_before + 8, \
        "canary docs were not cache hits"
    assert on.compile_misses == 0 and off.compile_misses == 0
    snap = dict(on.dedup.stats())
    on.close(), off.close()
    snap.pop("hit_nnz", None)
    print(json.dumps({"ratios": out, "cache": snap,
                      "hot": DEDUP_HOT, "entries": DEDUP_ENTRIES}))


def _worker_env(devices: int) -> tuple:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return env, here


def _run_worker(mode: str, *, devices: int, replicas: int = 1) -> dict:
    cfg = dict(mode=mode, n_docs=N_DOCS, n_req=N_REQ, clients=CLIENTS,
               replicas=replicas)
    env, here = _worker_env(devices)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_bench",
         "--worker", json.dumps(cfg)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=here)
    if proc.returncode != 0:
        raise RuntimeError(
            f"serving bench worker failed\nSTDOUT:\n{proc.stdout[-2000:]}\n"
            f"STDERR:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _paired(run_a, run_b, rounds=2):
    """Smallest-combined-wall round of a cross-process pair (see
    streaming_bench: independent best-ofs routinely pair one lucky and
    one contended window)."""
    best = None
    for _ in range(rounds):
        a, b = run_a(), run_b()
        combined = a["open"]["wall_s"] + b["open"]["wall_s"]
        if best is None or combined < best[0]:
            best = (combined, a, b)
    return best[1], best[2]


# ------------------------------------------------------- smoke tier -------
def _smoke() -> list:
    import jax
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import HashedClassifierEngine

    rng = np.random.default_rng(0)
    docs = [np.unique(rng.integers(0, 1 << 24,
                                   size=int(rng.integers(1, 80))))
            for _ in range(12)]
    checked = 0
    for scheme in ("minwise", "oph", "oph_zero"):
        for b in (2, 8):
            cfg = BBitLinearConfig(k=16, b=b)
            params = init_bbit_linear(cfg, jax.random.key(b))
            kw = dict(seed=3, scheme=scheme, precompile=False,
                      nnz_buckets=(128,), row_buckets=(16,))
            fused = HashedClassifierEngine(params, cfg, fused=True, **kw)
            ref = HashedClassifierEngine(params, cfg, fused=False, **kw)
            a, r = fused.score_docs(docs), ref.score_docs(docs)
            assert np.array_equal(a, r), \
                f"fused != reference bitwise ({scheme}, b={b})"
            fused.close(), ref.close()
            checked += 1

    # batched-vs-direct + steady-state no-recompile + clean close
    cfg = BBitLinearConfig(k=16, b=8)
    params = init_bbit_linear(cfg, jax.random.key(0))
    eng = HashedClassifierEngine(params, cfg, seed=3, max_batch=4,
                                 max_wait_ms=2, nnz_buckets=(128,),
                                 row_buckets=(1, 2, 4))
    oracle = [float(eng.score_docs([d])[0]) for d in docs]
    futs = [eng.submit(d) for d in docs]
    got = [float(f.result(timeout=120)) for f in futs]
    np.testing.assert_allclose(got, oracle, atol=1e-5)
    assert eng.compile_misses == 0, "smoke traffic recompiled"
    tail = eng.submit(docs[0])
    eng.close()
    assert tail.done(), "close left a future unresolved"
    return emit([
        ("serving/smoke_fused_parity_k16", 0.0,
         f"pairs_bitwise_identical={checked};batched_matches_direct=1;"
         "close_flushes=1;compile_misses=0"),
        _smoke_http_e2e(),
    ])


def _smoke_http_e2e() -> tuple:
    """End-to-end network contract against a real server SUBPROCESS:
    bitwise parity, deterministic 429, exact mid-traffic hot-reload,
    compile_misses == 0, clean SIGTERM drain."""
    import re
    import signal
    import tempfile

    import jax
    from repro.ckpt import checkpoint as ckpt
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    from repro.serving import (HashedClassifierEngine, HTTPStatusError,
                               ScoreClient)
    from repro.serving.reload import WeightSet

    param_key = 5
    lcfg = BBitLinearConfig(k=16, b=4)
    rng = np.random.default_rng(123)
    docs = [np.sort(rng.choice(100000, size=int(rng.integers(5, 50)),
                               replace=False)) for _ in range(8)]

    # the parent rebuilds the server's exact deterministic model and
    # computes both single-version oracles at the server's batch shape
    # (8-doc full batches — bitwise parity is shape-for-shape)
    params = init_bbit_linear(lcfg, jax.random.key(param_key))
    new_params = init_bbit_linear(lcfg, jax.random.key(param_key + 1))
    oracle = HashedClassifierEngine(params, lcfg, seed=3, scheme="oph",
                                    max_batch=8, max_wait_ms=20.0,
                                    nnz_buckets=(64,))
    want_v0 = np.asarray(oracle.score_docs(docs), np.float64).ravel()
    w_new = WeightSet(version="staged", params=tuple(
        jax.device_put(new_params, d) for d in oracle.devices))
    want_v1 = np.asarray(oracle.score_docs(docs, weights=w_new),
                         np.float64).ravel()
    oracle.close()
    assert not np.array_equal(want_v0, want_v1)

    env, here = _worker_env(devices=1)
    proc = subprocess.Popen(
        [sys.executable, "-m", "benchmarks.serving_bench", "--worker",
         json.dumps({"mode": "http_server", "param_key": param_key})],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=here)
    try:
        port = None
        deadline = time.time() + 300
        while time.time() < deadline:
            line = proc.stdout.readline()
            if not line:
                break
            m = re.match(r"LISTENING (\S+) (\d+)", line)
            if m:
                port = int(m.group(2))
                break
        assert port, "server subprocess never reported LISTENING"
        client = ScoreClient("127.0.0.1", port, timeout=120)

        # bitwise parity across the process + network boundary
        r = client.score(docs)
        assert r["version"] == "v0"
        got = np.asarray(r["scores"], np.float64).ravel()
        assert np.array_equal(got, want_v0), "HTTP scores != oracle"

        # deterministic 429: one request larger than the whole budget
        limit = client.status()["admission"]["limit"]
        try:
            client.score([[1, 2, 3]] * (limit + 1))
            raise AssertionError("oversized request was not rejected")
        except HTTPStatusError as e:
            assert e.status == 429 and e.retry_after_s > 0

        # mid-traffic hot-reload: responses before/after are each one
        # exact version, bitwise against that version's oracle
        tmp = tempfile.mkdtemp(prefix="smoke_http_ckpt_")
        ckpt.publish_params(tmp, 7, new_params)
        for _ in range(3):
            client.score(docs)
        info = client.reload(tmp)
        assert info["version"] == "ckpt-7" and info["previous"] == "v0"
        for _ in range(3):
            r = client.score(docs)
            assert r["version"] == "ckpt-7"
            got = np.asarray(r["scores"], np.float64).ravel()
            assert np.array_equal(got, want_v1), \
                "post-reload scores != new oracle"

        st = client.status()
        assert st["health"] == "ok"
        assert st["engine"]["compile_misses"] == 0
        assert st["engine"]["reloads"] == 1
        client.close()

        # SIGTERM → graceful drain → exit 0 with a clean-drain report
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
        assert proc.returncode == 0, f"server exited {proc.returncode}"
        assert "DRAINED 1" in out, f"drain not clean: {out[-500:]}"
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate(timeout=30)
    return ("serving/smoke_http_e2e_k16", 0.0,
            "bitwise_parity=1;deterministic_429=1;hot_reload_exact=1;"
            "compile_misses=0;sigterm_drain_clean=1")


# -------------------------------------------------------- full tier -------
def serving_bench() -> list:
    if SMOKE:
        return _smoke()
    ab = _run_worker("serve", devices=1)
    http = _run_worker("http", devices=1)
    dedup = _run_worker("dedup", devices=1)
    rep1, rep2 = _paired(
        lambda: _run_worker("replicas", devices=1, replicas=1),
        lambda: _run_worker("replicas", devices=2, replicas=2))
    leg, fus, nob, opn = (ab["legacy"], ab["fused"], ab["nobatch"],
                          ab["open"])
    fused_vs_legacy = fus["rows_per_s"] / max(leg["rows_per_s"], 1e-9)
    batch_vs_nobatch = fus["rows_per_s"] / max(nob["rows_per_s"], 1e-9)
    scaling = (rep2["open"]["rows_per_s"]
               / max(rep1["open"]["rows_per_s"], 1e-9))

    def lat(v):
        return (f"p50_ms={v['p50_ms']:.2f};p95_ms={v['p95_ms']:.2f};"
                f"p99_ms={v['p99_ms']:.2f};rows_per_s={v['rows_per_s']:.0f}")

    dedup_rows = []
    for ratio, r in sorted(dedup["ratios"].items()):
        on, off = r["on"], r["off"]
        dedup_rows.append(
            (f"serving/dedup_open_dup{ratio}_k{K}_b{B}",
             on["wall_s"] * 1e6,
             f"rows_per_s_on={on['rows_per_s']:.0f};"
             f"rows_per_s_off={off['rows_per_s']:.0f};"
             f"speedup_on_vs_off={r['speedup']:.2f}x;"
             f"hit_rate={r['hit_rate']:.3f};"
             f"hot={dedup['hot']};entries={dedup['entries']};"
             "hit_bitwise_eq_fresh=1;"
             "note=zipf_hot_pool_open_loop_bounded_lru"))
    return emit([
        (f"serving/legacy_closed_k{K}_b{B}", leg["wall_s"] * 1e6,
         f"{lat(leg)};clients={CLIENTS};"
         f"cold_s={ab['cold_legacy_s']:.2f};"
         "note=single_lane_widest_doc_padding_unfused_scorer"),
        (f"serving/fused_closed_k{K}_b{B}", fus["wall_s"] * 1e6,
         f"{lat(fus)};fused_vs_legacy={fused_vs_legacy:.2f}x;"
         f"cold_s={ab['cold_fused_s']:.2f};"
         f"batches={ab['fused_batches']};compile_misses=0;"
         "note=shared_2core_box_clients_and_device_contend"),
        (f"serving/fused_nobatch_closed_k{K}_b{B}",
         nob["wall_s"] * 1e6,
         f"{lat(nob)};batch_vs_nobatch={batch_vs_nobatch:.2f}x"),
        (f"serving/fused_open_k{K}_b{B}", opn["wall_s"] * 1e6,
         f"{lat(opn)};note=open_loop_saturation"),
        (f"serving/http_open_loop_k{K}_b{B}",
         http["open"]["wall_s"] * 1e6,
         f"{lat(http['open'])};clients={CLIENTS};"
         f"docs_per_request={http['docs_per_request']};"
         f"requests={http['open']['requests']};"
         f"drained_clean={int(http['drained_clean'])};"
         f"rejected_rows={http['rejected_rows']};"
         "note=stdlib_asyncio_http_tier_full_network_path"),
        (f"serving/replicas1_open_k{K}_b{B}",
         rep1["open"]["wall_s"] * 1e6,
         f"{lat(rep1['open'])};devices={rep1['devices']}"),
        (f"serving/replicas2_open_k{K}_b{B}",
         rep2["open"]["wall_s"] * 1e6,
         f"{lat(rep2['open'])};devices={rep2['devices']};"
         f"scaling_1to2dev={scaling:.2f}x;"
         "note=2_fake_devices_share_2_cores_scaling_measures_contention"),
    ] + dedup_rows)


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        _worker(json.loads(sys.argv[2]))
    else:
        serving_bench()
