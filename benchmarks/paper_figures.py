"""One benchmark per paper table/figure (scaled reproduction).

Each ``fig*/table*`` function returns CSV rows
``(name, us_per_call, derived)`` where ``us_per_call`` is the training
(or processing) time and ``derived`` carries the figure's y-value
(test accuracy / ratio), so the paper's curves can be re-plotted from
the CSV. QUICK mode (default) trims the grids; BENCH_FULL=1 restores
the paper's full sweeps.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    QUICK, corpus, emit, hashed_codes, split, timed, vw_sketches,
)

C_GRID = [0.1, 1.0] if QUICK else [0.01, 0.1, 1.0, 10.0, 100.0]
B_GRID = [1, 8, 12] if QUICK else [1, 2, 4, 8, 12, 16]
K_GRID = [30, 128] if QUICK else [30, 100, 200, 300, 500]
M_GRID = [16, 64, 256, 1024] if QUICK else [32, 64, 128, 256, 512,
                                            1024, 4096, 16384]


def _fit_bbit(k, b, C, loss):
    from repro.models.linear import BBitLinearConfig
    from repro.train import train_bbit_liblinear
    codes, labels = hashed_codes(k, b)
    ctr, ytr, cte, yte = split((codes, labels))
    res = train_bbit_liblinear(
        ctr, ytr, cte, yte, BBitLinearConfig(k=k, b=b), loss=loss, C=C,
        max_iter=25)
    return res


def _fit_vw(m, C, loss):
    from repro.models.linear import VWLinearConfig
    from repro.train import train_vw_liblinear
    sk, labels = vw_sketches(m)
    xtr, ytr, xte, yte = split((sk, labels))
    return train_vw_liblinear(xtr, ytr, xte, yte, VWLinearConfig(m=m),
                              loss=loss, C=C, max_iter=25)


def _acc_time_grid(loss, fig_acc, fig_time):
    rows = []
    for b in B_GRID:
        for k in K_GRID:
            for C in C_GRID:
                res = _fit_bbit(k, b, C, loss)
                tag = f"b={b},k={k},C={C}"
                rows.append((f"{fig_acc}/{tag}",
                             res.train_seconds * 1e6,
                             f"test_acc={res.test_acc:.4f}"))
                rows.append((f"{fig_time}/{tag}",
                             res.train_seconds * 1e6,
                             f"train_s={res.train_seconds:.3f}"))
    return emit(rows)


def fig1_fig2_svm():
    """Fig 1 (SVM accuracy) + Fig 2 (SVM train time) vs C for (b, k)."""
    return _acc_time_grid("squared_hinge", "fig1_svm_acc", "fig2_svm_time")


def fig3_fig4_logistic():
    """Fig 3 (LR accuracy) + Fig 4 (LR train time)."""
    return _acc_time_grid("logistic", "fig3_lr_acc", "fig4_lr_time")


def fig5_fig6_vw_vs_bbit():
    """Figs 5-6: accuracy vs k — VW (solid) vs b-bit (dashed), same C.

    ``derived`` includes storage bits/example so the same-storage
    comparison (paper §5.3) can be read off directly.
    """
    rows = []
    for loss, fig in (("squared_hinge", "fig5_svm"), ("logistic",
                                                      "fig6_lr")):
        for m in M_GRID:
            res = _fit_vw(m, 1.0, loss)
            rows.append((f"{fig}/vw_m={m}", res.train_seconds * 1e6,
                         f"test_acc={res.test_acc:.4f};bits={32*m}"))
        for b in (8, 12):
            for k in K_GRID:
                res = _fit_bbit(k, b, 1.0, loss)
                rows.append((f"{fig}/bbit_b={b}_k={k}",
                             res.train_seconds * 1e6,
                             f"test_acc={res.test_acc:.4f};bits={b*k}"))
    return emit(rows)


def fig7_train_time_vw_vs_bbit():
    """Fig 7: train time at matched k — VW vs 8-bit minwise hashing."""
    rows = []
    for m in M_GRID:
        res = _fit_vw(m, 1.0, "squared_hinge")
        rows.append((f"fig7/vw_m={m}", res.train_seconds * 1e6,
                     f"train_s={res.train_seconds:.3f}"))
    for k in K_GRID:
        res = _fit_bbit(k, 8, 1.0, "squared_hinge")
        rows.append((f"fig7/bbit8_k={k}", res.train_seconds * 1e6,
                     f"train_s={res.train_seconds:.3f}"))
    return emit(rows)


def fig8_universal_vs_permutations():
    """Fig 8: permutations vs 2-universal families, test accuracy.

    Small-D corpus (no expansion) so explicit permutations exist.
    """
    import jax.numpy as jnp
    from repro.core import make_hash_family, minhash_numpy, bbit_codes
    from repro.core.minhash import minhash_jnp
    from repro.data import SynthRcv1Config, generate_arrays
    from repro.data.packing import pad_rows
    from repro.models.linear import BBitLinearConfig
    from repro.train import train_bbit_liblinear

    dim = 4096
    cfg = SynthRcv1Config(seed=23, vocab=dim, topic_tokens=120,
                          background_frac=0.35, pair_expansion=False,
                          triple_expansion=False)
    rows_docs, labels = generate_arrays(600 if QUICK else 2000, cfg)
    # un-expanded docs: indices already < vocab
    idx, nnz = pad_rows(rows_docs)
    mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
    k, b = 64, 8
    out = []
    for kind in ("permutation", "mod_prime", "multiply_shift"):
        if kind == "multiply_shift":
            fam = make_hash_family(kind, k, seed=3)
            a, bb = fam.params()
            z = np.asarray(minhash_jnp(jnp.asarray(idx), jnp.asarray(mask),
                                       a, bb))
        else:
            fam = make_hash_family(kind, k, seed=3, dim=dim)
            z = minhash_numpy(idx, mask, fam)
        codes = np.asarray(bbit_codes(z, b))
        ctr, ytr, cte, yte = split((codes, labels))
        res, dt = timed(train_bbit_liblinear, ctr, ytr, cte, yte,
                        BBitLinearConfig(k=k, b=b), loss="logistic",
                        C=1.0, max_iter=25)
        out.append((f"fig8/{kind}", dt * 1e6,
                    f"test_acc={res.test_acc:.4f}"))
    return emit(out)


def oph_vs_minwise_vs_vw():
    """OPH vs k-permutation minwise vs VW at matched storage.

    The OPH analogue of Figs 5-6: for each k (power of two), train the
    b-bit linear model on densified-OPH codes and on k-permutation
    minwise codes (same k·b bits/example), plus VW at the
    storage-equivalent bucket count m = k·b/32 (paper §5.3).  ``derived``
    carries test accuracy, bits/example, and hash evals per nonzero —
    OPH should track minwise accuracy at 1/k of its hashing cost.
    """
    from repro.models.linear import BBitLinearConfig
    from repro.train import train_bbit_liblinear
    b = 8
    k_grid = [64, 128] if QUICK else [64, 128, 256, 512]
    rows = []
    for k in k_grid:
        for scheme, evals in (("minwise", k), ("oph", 1)):
            codes, labels = hashed_codes(k, b, scheme=scheme)
            ctr, ytr, cte, yte = split((codes, labels))
            res = train_bbit_liblinear(
                ctr, ytr, cte, yte, BBitLinearConfig(k=k, b=b),
                loss="logistic", C=1.0, max_iter=25)
            rows.append((f"oph_curve/{scheme}_k={k}_b={b}",
                         res.train_seconds * 1e6,
                         f"test_acc={res.test_acc:.4f};bits={k * b};"
                         f"hash_evals_per_nnz={evals}"))
        m = max(k * b // 32, 2)
        res = _fit_vw(m, 1.0, "logistic")
        rows.append((f"oph_curve/vw_m={m}", res.train_seconds * 1e6,
                     f"test_acc={res.test_acc:.4f};bits={32 * m};"
                     f"hash_evals_per_nnz=1"))
    return emit(rows)


def table2_preprocessing_cost():
    """Table 2: data loading vs (one-time) preprocessing cost.

    'gpu' column analogue: the Pallas-kernel path measured per-byte on
    the accelerator is reported via the kernel microbench; here we
    report wall times for LibSVM load vs k=64 hashing on this host.
    """
    import tempfile
    from repro.data import (preprocess_rows, write_shards, read_shards)
    rows_docs, labels = corpus()
    with tempfile.TemporaryDirectory() as td:
        _, t_write = timed(write_shards, td, rows_docs, labels, 4)
        (loaded, _), t_load = timed(read_shards,
                                    [f"{td}/shard_{i:05d}.libsvm"
                                     for i in range(4)])
    _, t_hash = timed(preprocess_rows, rows_docs, 64, 8, chunk=256)
    out = [
        ("table2/data_loading", t_load * 1e6, f"seconds={t_load:.2f}"),
        ("table2/preprocess_k64", t_hash * 1e6,
         f"seconds={t_hash:.2f};ratio_vs_load={t_hash / t_load:.2f}"),
    ]
    return emit(out)


def variance_check():
    """§2/§5 variance laws: empirical/theory ratios (≈1.0)."""
    import jax.numpy as jnp
    from repro.core import (SparseBatch, MultiplyShiftHash, minhash_batch,
                            bbit_codes, vw_hash_batch, vw_inner_product,
                            resemblance)
    from repro.core.estimators import BBitLaw, var_vw
    rng = np.random.default_rng(0)
    common = rng.choice(4096, size=700, replace=False)
    s1, s2 = set(common[:500]), set(common[200:])
    r = resemblance(s1, s2)
    batch = SparseBatch.from_lists([sorted(s1), sorted(s2)], dim=4096)
    k, b = 128, 2
    law = BBitLaw(b=b, r1=0.0, r2=0.0)
    r_hats = []
    n_seeds = 150 if QUICK else 500
    for seed in range(n_seeds):
        fam = MultiplyShiftHash.make(k, seed)
        z = np.asarray(minhash_batch(batch, fam))
        codes = np.asarray(bbit_codes(z, b))
        r_hats.append(law.r_hat(float(np.mean(codes[0] == codes[1]))))
    ratio_b = np.var(r_hats) / law.var_rb(r, k)
    u1 = np.zeros(4096, np.float32); u1[list(s1)] = 1
    u2 = np.zeros(4096, np.float32); u2[list(s2)] = 1
    ests = [float(vw_inner_product(*vw_hash_batch(batch, m=256, seed=i)))
            for i in range(n_seeds)]
    ratio_vw = np.var(ests) / var_vw(u1, u2, 256, 1.0)
    return emit([
        ("variance/bbit_eq7", 0.0, f"emp_over_theory={ratio_b:.3f}"),
        ("variance/vw_eq16", 0.0, f"emp_over_theory={ratio_vw:.3f}"),
    ])


def compact_index_trick():
    """§5.4: VW-on-top-of-bbit compact indexing preserves accuracy."""
    import jax.numpy as jnp
    from repro.core.expansion import compact_index
    from repro.models.linear import VWLinearConfig
    from repro.train import train_vw_liblinear
    codes, labels = hashed_codes(128, 16)
    m = 2048
    sk = np.asarray(compact_index(jnp.asarray(codes.astype(np.int32)),
                                  b=16, m=m))
    xtr, ytr, xte, yte = split((sk, labels))
    res, dt = timed(train_vw_liblinear, xtr, ytr, xte, yte,
                    VWLinearConfig(m=m), loss="logistic", C=1.0,
                    max_iter=25)
    return emit([("compact_index/b16_k128_m2048", dt * 1e6,
                  f"test_acc={res.test_acc:.4f}")])
