"""Kernel microbenchmarks: jnp (XLA-CPU) production path timings + the
arithmetic each Pallas kernel must sustain on TPU (derived columns).

Interpret-mode Pallas timings are NOT meaningful performance numbers
(python-per-grid-step); the jnp oracle path is what actually runs on
this host, and the derived column reports the work per call so TPU
projections can be made (bytes/FLOP counts are hardware-independent).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed


def minhash_bench():
    from repro.core.minhash import minhash_jnp
    rng = np.random.default_rng(0)
    rows = []
    for (n, m, k) in [(256, 1024, 64), (256, 1024, 512),
                      (1024, 4096, 200)]:
        idx = jnp.asarray(rng.integers(0, 1 << 30, (n, m)).astype(np.int32))
        mask = jnp.ones((n, m), bool)
        a = jnp.asarray((rng.integers(0, 1 << 32, k, dtype=np.uint64) | 1
                         ).astype(np.uint32))
        b = jnp.asarray(rng.integers(0, 1 << 32, k, dtype=np.uint64
                                     ).astype(np.uint32))
        fn = jax.jit(lambda i, ms: minhash_jnp(i, ms, a, b))
        fn(idx, mask).block_until_ready()
        _, dt = timed(lambda: fn(idx, mask).block_until_ready(),
                      repeats=3)
        hashes = n * m * k
        rows.append((f"kernel/minhash_n{n}_m{m}_k{k}", dt * 1e6,
                     f"Mhash_per_s={hashes / dt / 1e6:.0f}"))
    return emit(rows)


def oph_bench():
    """OPH vs k-permutation minwise preprocessing at identical shapes.

    The derived column carries preprocessing throughput (Mnnz/s) plus
    the head-to-head ``speedup_vs_minwise`` on this host and the
    hash-evaluation ratio (exactly k — the Table-2 cost driver OPH
    removes).  k=256 matches configs/rcv1_oph.
    """
    import functools
    from repro.core.minhash import minhash_jnp
    from repro.core.oph import (OPHHash, densify_rotation,
                                oph_bin_minima_jnp)
    rng = np.random.default_rng(3)
    rows = []
    for (n, m, k) in [(256, 1024, 256), (1024, 4096, 256),
                      (256, 1024, 512)]:
        idx = jnp.asarray(rng.integers(0, 1 << 30, (n, m)).astype(np.int32))
        mask = jnp.ones((n, m), bool)
        a = jnp.asarray((rng.integers(0, 1 << 32, k, dtype=np.uint64) | 1
                         ).astype(np.uint32))
        b = jnp.asarray(rng.integers(0, 1 << 32, k, dtype=np.uint64
                                     ).astype(np.uint32))
        f_min = jax.jit(lambda i, ms: minhash_jnp(i, ms, a, b))
        f_min(idx, mask).block_until_ready()
        _, dt_min = timed(lambda: f_min(idx, mask).block_until_ready(),
                          repeats=3)
        fam = OPHHash.make(k, seed=3)
        a1, b1 = fam.params()
        f_oph = jax.jit(functools.partial(
            lambda i, ms, kk: densify_rotation(
                *oph_bin_minima_jnp(i, ms, a1, b1, kk))[0], kk=k))
        f_oph(idx, mask).block_until_ready()
        _, dt_oph = timed(lambda: f_oph(idx, mask).block_until_ready(),
                          repeats=3)
        rows.append((
            f"kernel/oph_n{n}_m{m}_k{k}", dt_oph * 1e6,
            f"Mnnz_per_s={n * m / dt_oph / 1e6:.0f};"
            f"speedup_vs_minwise={dt_min / dt_oph:.1f}x;"
            f"hash_evals_ratio={k}"))
    return emit(rows)


def fused_encode_bench():
    """Fused hash→b-bit→pack encode: interpret-mode Pallas parity canary
    plus XLA fused-path throughput.

    The parity block runs the fused kernels (tiny shapes, interpret
    mode) against the unfused reference and RAISES on any bit mismatch
    — this is what ``benchmarks.run --smoke`` executes in CI, so fused-
    kernel breakage fails the suite pre-merge.  Throughput rows time the
    XLA fused path (`encode_packed`) that actually runs on this host.
    """
    from benchmarks.common import SMOKE
    from repro.core.bbit import pack_codes
    from repro.core.oph import (OPHHash, densify_rotation_numpy,
                                oph_bin_minima_numpy)
    from repro.core.schemes import make_scheme
    from repro.kernels.fused_encode import (minhash_pack_pallas,
                                            oph_pack_pallas)
    rng = np.random.default_rng(4)
    checks = 0
    for bits in (1, 8):
        n, m, k = 5, 40, 16
        idx = rng.integers(0, 1 << 30, (n, m)).astype(np.int32)
        nnz = rng.integers(0, m + 1, (n,)).astype(np.int32)
        mask = np.arange(m)[None, :] < nnz[:, None]
        a = (rng.integers(0, 1 << 32, k, dtype=np.uint64) | 1
             ).astype(np.uint32)
        bv = rng.integers(0, 1 << 32, k, dtype=np.uint64).astype(np.uint32)
        got = np.asarray(minhash_pack_pallas(
            jnp.asarray(idx), jnp.asarray(nnz), jnp.asarray(a),
            jnp.asarray(bv), bits=bits, interpret=True))
        from repro.kernels import ref
        z = np.asarray(ref.minhash(jnp.asarray(idx), jnp.asarray(nnz),
                                   jnp.asarray(a), jnp.asarray(bv)))
        want = pack_codes((z & ((1 << bits) - 1)).astype(np.uint16), bits)
        if not np.array_equal(got, want):
            raise AssertionError(f"fused minwise mismatch at b={bits}")
        fam = OPHHash.make(k, 3)
        av, bvv = fam.params()
        v, e = oph_bin_minima_numpy(idx, mask, fam)
        for densify in (True, False):
            gp, ge = oph_pack_pallas(jnp.asarray(idx), jnp.asarray(nnz),
                                     av, bvv, k=k, bits=bits,
                                     densify=densify, interpret=True)
            if densify:
                dv, _ = densify_rotation_numpy(v, e)
                wantp = pack_codes(
                    (dv & ((1 << bits) - 1)).astype(np.uint16), bits)
            else:
                wantp = pack_codes(
                    np.where(e, 0, v & ((1 << bits) - 1)).astype(np.uint16),
                    bits)
            if not (np.array_equal(np.asarray(gp), wantp)
                    and np.array_equal(np.asarray(ge),
                                       np.packbits(e, axis=1))):
                raise AssertionError(
                    f"fused oph mismatch at b={bits} densify={densify}")
        checks += 3
    rows = [("kernel/fused_parity_interpret", 0.0,
             f"checks={checks};bit_identical=1")]
    if SMOKE:
        return emit(rows)
    for (n, m, k, bits) in [(256, 1024, 256, 1), (256, 1024, 256, 8)]:
        idx = rng.integers(0, 1 << 30, (n, m)).astype(np.int32)
        nnz = np.full(n, m, np.int32)
        sch = make_scheme("oph", k, 3)
        sch.encode_packed(idx, nnz, bits)          # warm the jit caches
        _, dt = timed(lambda: sch.encode_packed(idx, nnz, bits),
                      repeats=3)
        rows.append((
            f"kernel/fused_oph_packed_n{n}_m{m}_k{k}_b{bits}", dt * 1e6,
            f"Mnnz_per_s={n * m / dt / 1e6:.0f};"
            f"bytes_per_row={(k * bits + 7) // 8}"))
    return emit(rows)


def bbit_linear_bench():
    from repro.kernels import ref
    rng = np.random.default_rng(1)
    rows = []
    for (n, k, b, c) in [(4096, 200, 8, 2), (4096, 500, 12, 2)]:
        v = 1 << b
        codes = jnp.asarray(rng.integers(0, v, (n, k)).astype(np.int32))
        w = jnp.asarray(rng.normal(size=(k, v, c)).astype(np.float32))
        fn = jax.jit(ref.bbit_linear_fwd)
        fn(codes, w).block_until_ready()
        _, dt = timed(lambda: fn(codes, w).block_until_ready(), repeats=5)
        rows.append((f"kernel/bbit_linear_n{n}_k{k}_b{b}", dt * 1e6,
                     f"Mlookup_per_s={n * k / dt / 1e6:.0f}"))
    return emit(rows)


def vw_sketch_bench():
    from repro.core.vw import vw_hash_sparse
    rng = np.random.default_rng(2)
    rows = []
    for (n, m, buckets) in [(1024, 2048, 1024), (256, 8192, 16384)]:
        idx = jnp.asarray(rng.integers(0, 1 << 30, (n, m)).astype(np.int32))
        mask = jnp.ones((n, m), bool)
        fn = jax.jit(lambda i, ms: vw_hash_sparse(i, ms, None, buckets))
        fn(idx, mask).block_until_ready()
        _, dt = timed(lambda: fn(idx, mask).block_until_ready(),
                      repeats=3)
        rows.append((f"kernel/vw_sketch_n{n}_m{m}_M{buckets}", dt * 1e6,
                     f"Mnnz_per_s={n * m / dt / 1e6:.0f}"))
    return emit(rows)
