"""Roofline summary bench: reads artifacts/dryrun JSONs and emits the
per-cell terms as CSV (the table EXPERIMENTS.md §Roofline renders)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def roofline_rows(art_dir: str = "artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        name = f"{rec.get('arch')}×{rec.get('shape')}×{rec.get('mesh')}"
        if rec.get("status") == "skipped":
            rows.append((f"dryrun/{name}", 0.0, "skipped"))
            continue
        if rec.get("status") != "ok":
            rows.append((f"dryrun/{name}", 0.0,
                         f"error={rec.get('error', '?')[:60]}"))
            continue
        mem = rec.get("memory", {})
        rl = rec.get("roofline", {})
        derived = (f"fits={mem.get('fits')};"
                   f"resident_gib={mem.get('resident_bytes', 0)/2**30:.2f};"
                   f"dominant={rl.get('dominant')};"
                   f"bound_s={rl.get('step_lower_bound_s', 0):.3g};"
                   f"frac={rl.get('roofline_fraction', 0):.3f}")
        rows.append((f"dryrun/{name}",
                     rec.get("compile_seconds", 0) * 1e6, derived))
    if not rows:
        rows.append(("dryrun/none", 0.0, "no artifacts; run "
                     "python -m repro.launch.dryrun --all"))
    return emit(rows)
