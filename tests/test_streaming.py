"""Streaming trainer over packed shards: device unpack parity, packed
batch iteration, one-pass accuracy vs the in-memory SGD path, Polyak
averaging, kill/resume bitwise determinism, and the async-prefetch
determinism contract (prefetch depth never changes results)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bbit import (
    pack_codes, pack_codes_jnp, pack_mask_jnp, unpack_codes,
    unpack_codes_jnp, unpack_mask_jnp,
)
from repro.data import (
    SynthRcv1Config, ThreadedPrefetcher, generate_arrays,
    iter_hashed_batches, load_hashed, preprocess_and_save,
    preprocess_rows, shard_row_counts,
)
from repro.models.linear import BBitLinearConfig, predict_classes
from repro.train import fit_streaming, train_bbit_sgd
from repro.train.metrics import accuracy


# ---------------------------------------------------------------- unpack --
@pytest.mark.parametrize("b", [1, 2, 4, 8, 3, 6, 12])
@pytest.mark.parametrize("k", [1, 16, 63, 128])
def test_unpack_codes_jnp_inverts_both_packers(b, k):
    rng = np.random.default_rng(b * 131 + k)
    codes = rng.integers(0, 1 << b, size=(9, k)).astype(np.uint16)
    packed = pack_codes(codes, b)
    assert np.array_equal(packed, np.asarray(pack_codes_jnp(
        jnp.asarray(codes), b)))
    got = np.asarray(unpack_codes_jnp(jnp.asarray(packed), k, b))
    assert np.array_equal(got, codes)
    assert np.array_equal(got, unpack_codes(packed, k, b))


@pytest.mark.parametrize("k", [1, 8, 37, 256])
def test_unpack_mask_jnp_inverts_packbits(k):
    rng = np.random.default_rng(k)
    mask = rng.integers(0, 2, size=(7, k)).astype(bool)
    packed = np.packbits(mask, axis=1)
    assert np.array_equal(packed, np.asarray(pack_mask_jnp(
        jnp.asarray(mask))))
    assert np.array_equal(
        np.asarray(unpack_mask_jnp(jnp.asarray(packed), k)), mask)


# ------------------------------------------------------------ corpus ------
@pytest.fixture(scope="module")
def corpus():
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    return generate_arrays(600, cfg)


@pytest.fixture(scope="module")
def archive(corpus, tmp_path_factory):
    """400-row / 5-shard v3 archive + full 600-row code matrix."""
    rows, labels = corpus
    codes = preprocess_rows(rows, k=64, b=8, seed=1, chunk=256)
    d = str(tmp_path_factory.mktemp("arch"))
    preprocess_and_save(d, rows[:400], labels[:400], k=64, b=8, seed=1,
                        n_shards=5, chunk=128)
    return d, codes, labels


# ----------------------------------------------------- batch iterator -----
def test_iter_hashed_batches_covers_every_row_once(archive):
    d, codes, labels = archive
    seen = {}
    for pk, lb, rid, em in iter_hashed_batches(d, 48):
        assert em is None
        assert len(pk) == len(lb) == len(rid) <= 48
        for r, c, l in zip(rid, unpack_codes(pk, 64, 8), lb):
            assert int(r) not in seen
            seen[int(r)] = (c, int(l))
    assert sorted(seen) == list(range(400))
    for r, (c, l) in seen.items():
        assert np.array_equal(c, codes[r]) and l == labels[r]


def test_iter_hashed_batches_permutation_is_deterministic(archive):
    d, _, _ = archive
    a = [tuple(rid) for _, _, rid, _ in iter_hashed_batches(
        d, 32, perm_seed=9)]
    b = [tuple(rid) for _, _, rid, _ in iter_hashed_batches(
        d, 32, perm_seed=9)]
    c = [tuple(rid) for _, _, rid, _ in iter_hashed_batches(
        d, 32, perm_seed=10)]
    assert a == b and a != c
    assert sorted(r for t in a for r in t) == list(range(400))


def test_shard_row_counts_matches_archive(archive):
    d, _, _ = archive
    counts = shard_row_counts(d)
    assert sum(counts) == 400 and len(counts) == 5


# --------------------------------------------------- streaming trainer ----
def test_fit_streaming_matches_in_memory_sgd(archive):
    """Acceptance: multi-shard streaming within ±0.5% of the in-memory
    SGD path, holding only packed shards resident."""
    d, codes, labels = archive
    lcfg = BBitLinearConfig(k=64, b=8)
    res = fit_streaming(d, lcfg, epochs=8, batch_size=64, lr=5e-3, seed=0)
    stream_acc = accuracy(
        predict_classes(res.params, jnp.asarray(codes[400:]), lcfg),
        labels[400:])
    mem = train_bbit_sgd(codes[:400], labels[:400], codes[400:],
                         labels[400:], lcfg, epochs=8, batch_size=64,
                         lr=5e-3)
    assert abs(stream_acc - mem.test_acc) <= 0.005 + 1e-9, (
        stream_acc, mem.test_acc)
    assert stream_acc > 0.9
    # progressive validation saw every example once per epoch
    assert res.examples_seen == 8 * 400
    assert 0.5 < res.progressive_acc <= 1.0
    # tail-averaged iterate generalizes too
    avg_acc = accuracy(
        predict_classes(res.avg_params, jnp.asarray(codes[400:]), lcfg),
        labels[400:])
    assert avg_acc > 0.9


def test_fit_streaming_resume_is_bitwise_identical(archive, tmp_path):
    d, _, _ = archive
    lcfg = BBitLinearConfig(k=64, b=8)
    kw = dict(epochs=2, batch_size=64, lr=5e-3, seed=3)
    straight = fit_streaming(d, lcfg, **kw)
    ck = str(tmp_path / "ck")
    part = fit_streaming(d, lcfg, ckpt_dir=ck, stop_after_shards=3, **kw)
    assert not part.completed and part.shards_processed == 3
    resumed = fit_streaming(d, lcfg, ckpt_dir=ck, **kw)
    assert resumed.completed
    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(straight.avg_params),
                    jax.tree.leaves(resumed.avg_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert straight.n_steps == resumed.n_steps
    assert straight.examples_seen == resumed.examples_seen
    assert abs(straight.progressive_acc - resumed.progressive_acc) < 1e-12


def test_fit_streaming_oph_zero_empty_mask_path(corpus, tmp_path):
    rows, labels = corpus
    d = str(tmp_path / "z")
    preprocess_and_save(d, rows[:200], labels[:200], k=32, b=6, seed=1,
                        scheme="oph_zero", n_shards=3, chunk=64)
    lcfg = BBitLinearConfig(k=32, b=6)
    res = fit_streaming(d, lcfg, epochs=4, batch_size=64, lr=5e-3, seed=0)
    spe = sum(-(-c // 64) for c in shard_row_counts(d))
    assert res.completed and res.n_steps == 4 * spe
    assert res.progressive_acc > 0.5


def test_fit_streaming_rejects_incompatible_checkpoint(archive, tmp_path):
    """Resuming with different hyperparameters must fail loudly, not
    silently restart from scratch over the old checkpoints."""
    d, _, _ = archive
    lcfg = BBitLinearConfig(k=64, b=8)
    ck = str(tmp_path / "ck")
    fit_streaming(d, lcfg, epochs=1, batch_size=64, optimizer="adamw",
                  ckpt_dir=ck, stop_after_shards=2)
    # structural mismatch: different optimizer state tree
    with pytest.raises(ValueError, match="incompatible"):
        fit_streaming(d, lcfg, epochs=1, batch_size=64, optimizer="sgd",
                      ckpt_dir=ck)
    # semantic mismatch: identical tree structure, different batching —
    # must not silently resume with a divergent replay
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        fit_streaming(d, lcfg, epochs=1, batch_size=32,
                      optimizer="adamw", ckpt_dir=ck)
    # model-config semantics (same param shapes!) are fingerprinted too
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        fit_streaming(d, BBitLinearConfig(k=64, b=8, normalize=True),
                      epochs=1, batch_size=64, optimizer="adamw",
                      ckpt_dir=ck)
    # resume=False into a populated ckpt_dir: the fresh run's low step
    # numbers would be pruned under the old run's — refuse
    with pytest.raises(ValueError, match="already holds checkpoints"):
        fit_streaming(d, lcfg, epochs=1, batch_size=64,
                      optimizer="adamw", ckpt_dir=ck, resume=False)


def test_fit_streaming_rejects_mismatched_config_and_empty_archive(
        archive, tmp_path):
    d, _, _ = archive
    with pytest.raises(ValueError, match="does not match archive"):
        fit_streaming(d, BBitLinearConfig(k=32, b=8))
    e = str(tmp_path / "empty")
    preprocess_and_save(e, [], np.zeros((0,), np.int32), k=16, b=8)
    with pytest.raises(ValueError, match="empty archive"):
        fit_streaming(e, BBitLinearConfig(k=16, b=8))
    with pytest.raises(ValueError, match="ckpt_every_shards"):
        fit_streaming(d, BBitLinearConfig(k=64, b=8), ckpt_dir="/tmp/x",
                      ckpt_every_shards=0)
    with pytest.raises(ValueError, match="binary-only"):
        fit_streaming(d, BBitLinearConfig(k=64, b=8, n_classes=4),
                      loss="logistic")
    with pytest.raises(ValueError, match="stop_after_shards"):
        fit_streaming(d, BBitLinearConfig(k=64, b=8), stop_after_shards=2)


# ----------------------------------------------------- async prefetch -----
from repro.train.metrics import trees_bitwise_equal as _leaves_equal  # noqa: E402


def test_fit_streaming_prefetch_is_bit_identical_to_inline(archive):
    """The determinism contract: the producer→queue→device pipeline
    changes when host work happens, never what is produced."""
    d, _, _ = archive
    lcfg = BBitLinearConfig(k=64, b=8)
    kw = dict(epochs=2, batch_size=64, lr=5e-3, seed=7)
    inline = fit_streaming(d, lcfg, prefetch=0, **kw)
    for depth in (1, 3):
        pf = fit_streaming(d, lcfg, prefetch=depth, **kw)
        assert _leaves_equal(inline.params, pf.params), depth
        assert _leaves_equal(inline.avg_params, pf.avg_params), depth
        assert pf.n_steps == inline.n_steps
        assert pf.examples_seen == inline.examples_seen
        assert abs(pf.progressive_acc - inline.progressive_acc) < 1e-12


def test_fit_streaming_prefetch_checkpoints_interchange(archive, tmp_path):
    """A run killed under one prefetch depth resumes under another —
    depth is excluded from the run fingerprint by design."""
    d, _, _ = archive
    lcfg = BBitLinearConfig(k=64, b=8)
    kw = dict(epochs=2, batch_size=64, lr=5e-3, seed=5)
    straight = fit_streaming(d, lcfg, prefetch=0, **kw)
    ck = str(tmp_path / "ck")
    part = fit_streaming(d, lcfg, ckpt_dir=ck, stop_after_shards=3,
                         prefetch=0, **kw)
    assert not part.completed
    resumed = fit_streaming(d, lcfg, ckpt_dir=ck, prefetch=3, **kw)
    assert resumed.completed
    assert _leaves_equal(straight.params, resumed.params)
    assert _leaves_equal(straight.avg_params, resumed.avg_params)


def test_threaded_prefetcher_propagates_errors_and_closes():
    def boom():
        yield 1
        yield 2
        raise RuntimeError("producer exploded")

    pf = ThreadedPrefetcher(boom(), depth=2)
    assert next(pf) == 1 and next(pf) == 2
    with pytest.raises(RuntimeError, match="producer exploded"):
        next(pf)
    pf.close()                       # idempotent after error

    # early close unblocks a producer stuck on a full queue
    def endless():
        i = 0
        while True:
            yield i
            i += 1

    pf = ThreadedPrefetcher(endless(), depth=1)
    assert next(pf) == 0
    pf.close()
    assert not pf._thread.is_alive()
    # next() after close must terminate, not block on the drained queue
    with pytest.raises(StopIteration):
        next(pf)

    with pytest.raises(ValueError, match="depth"):
        ThreadedPrefetcher(iter([]), depth=0)


# ------------------------------------------------- oversized batches ------
def test_iter_hashed_batches_rejects_batch_larger_than_shard(archive):
    """Regression: batch_size > shard rows used to silently yield one
    short batch per shard instead of the requested minibatch size."""
    d, _, _ = archive                          # 5 shards × 80 rows
    with pytest.raises(ValueError, match="exceeds shard"):
        next(iter(iter_hashed_batches(d, 81)))
    # the trainer surfaces it up front, before any step runs
    with pytest.raises(ValueError, match="lower batch_size"):
        fit_streaming(d, BBitLinearConfig(k=64, b=8), batch_size=81)
    # boundary: batch_size == smallest shard is fine
    batches = list(iter_hashed_batches(d, 80))
    assert len(batches) == 5 and all(len(b[1]) == 80 for b in batches)


# ------------------------------------------------------ averaging hook ----
def test_polyak_average_equals_mean_of_iterates():
    from repro.optim import make_optimizer
    from repro.train import (build_averaged_train_step, init_averaged_state,
                             mean_loss_fn)
    from repro.models.linear import bbit_logits, init_bbit_linear
    lcfg = BBitLinearConfig(k=8, b=4)
    opt = make_optimizer("sgd", 0.1)
    loss_fn = mean_loss_fn(lambda p, c: bbit_logits(p, c, lcfg),
                           "logistic")
    step = build_averaged_train_step(loss_fn, opt, donate=False)
    astate = init_averaged_state(init_bbit_linear(lcfg, jax.random.key(0)),
                                 opt)
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, size=(32, 8)).astype(np.uint16)
    y = (codes.sum(axis=1) % 2).astype(np.int32)
    iterates = []
    for t in range(6):
        active = np.float32(t >= 2)          # tail: average steps 2..5
        astate, _ = step(astate, active, jnp.asarray(codes),
                         jnp.asarray(y))
        if t >= 2:
            iterates.append(jax.tree.map(np.asarray, astate.state.params))
    assert float(astate.avg_count) == 4.0
    want = jax.tree.map(lambda *xs: np.mean(np.stack(xs), axis=0),
                        *iterates)
    for a, b in zip(jax.tree.leaves(astate.avg_params),
                    jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), b, rtol=1e-6, atol=1e-7)
