"""Solvers + end-to-end linear training on hashed features."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
from repro.models.linear import (
    BBitLinearConfig, VWLinearConfig, init_bbit_linear, bbit_logits,
)
from repro.optim.tron import tron_minimize
from repro.train import (
    train_bbit_liblinear, train_vw_liblinear, train_bbit_sgd,
)
from repro.train.losses import liblinear_objective


@pytest.fixture(scope="module")
def hashed_data():
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels = generate_arrays(600, cfg)
    codes = preprocess_rows(rows, k=64, b=8, seed=1, chunk=256)
    return codes, labels


def test_tron_matches_scipy_on_logistic():
    """TRON vs scipy L-BFGS on the same LIBLINEAR objective."""
    from scipy.optimize import minimize as scipy_minimize
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 12)).astype(np.float64)
    w_true = rng.normal(size=12)
    y01 = (X @ w_true + 0.3 * rng.normal(size=200) > 0).astype(np.float64)
    y = 2 * y01 - 1
    C = 0.7

    def f_np(w):
        m = y * (X @ w)
        return 0.5 * w @ w + C * np.sum(np.log1p(np.exp(-m)))

    res_sp = scipy_minimize(f_np, np.zeros(12), method="L-BFGS-B",
                            options=dict(maxiter=500, ftol=1e-12))

    Xj = jnp.asarray(X.astype(np.float32))
    yj = jnp.asarray(y.astype(np.float32))

    def f_jax(w):
        m = yj * (Xj @ w)
        return 0.5 * w @ w + C * jnp.sum(jnp.logaddexp(0.0, -m))

    # f32 arithmetic bounds the reachable gradient norm; compare the
    # optimum against scipy's f64 solution rather than the flag
    res = tron_minimize(f_jax, jnp.zeros(12, jnp.float32), max_iter=100,
                        grad_tol=1e-4)
    assert abs(res.fun - res_sp.fun) / abs(res_sp.fun) < 1e-3
    np.testing.assert_allclose(np.asarray(res.params), res_sp.x,
                               atol=1e-1)


def test_tron_objective_monotone(hashed_data):
    codes, labels = hashed_data
    lcfg = BBitLinearConfig(k=64, b=8)
    obj = liblinear_objective(
        lambda p, c: bbit_logits(p, c, lcfg), "logistic", 1.0)
    cj, yj = jnp.asarray(codes.astype(np.int32)), jnp.asarray(labels)
    res = tron_minimize(lambda p: obj(p, cj, yj),
                        init_bbit_linear(lcfg), max_iter=15)
    assert all(b <= a + 1e-6 for a, b in zip(res.trace, res.trace[1:]))


def test_paper_claim_bbit_high_accuracy(hashed_data):
    """Qualitative Fig-1/3 claim: small k with b=8-12 reaches high acc."""
    codes, labels = hashed_data
    n_tr = 400
    res = train_bbit_liblinear(
        codes[:n_tr], labels[:n_tr], codes[n_tr:], labels[n_tr:],
        BBitLinearConfig(k=64, b=8), loss="logistic", C=1.0, max_iter=30)
    assert res.test_acc > 0.9, res


def test_paper_claim_bbit_beats_vw_same_storage(hashed_data):
    """Figs 5-6: b-bit ≫ VW at equal storage bits."""
    from repro.core.vw import vw_hash_sparse
    from repro.data import SynthRcv1Config, generate_arrays
    from repro.data.packing import pad_rows
    codes, labels = hashed_data
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=4000, max_triples_per_doc=2000)
    rows, labels2 = generate_arrays(600, cfg)
    assert np.array_equal(labels, labels2)
    # same storage: 64 hashes × 8 bits = 512 bits = 16 float32 VW bins
    m = 16
    idx, nnz = pad_rows(rows)
    mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
    sk = np.asarray(vw_hash_sparse(jnp.asarray(idx), jnp.asarray(mask),
                                   None, m, seed=2))
    n_tr = 400
    res_vw = train_vw_liblinear(sk[:n_tr], labels[:n_tr], sk[n_tr:],
                                labels[n_tr:], VWLinearConfig(m=m),
                                loss="logistic", C=1.0, max_iter=30)
    res_bb = train_bbit_liblinear(
        codes[:n_tr], labels[:n_tr], codes[n_tr:], labels[n_tr:],
        BBitLinearConfig(k=64, b=8), loss="logistic", C=1.0, max_iter=30)
    assert res_bb.test_acc > res_vw.test_acc + 0.05, (res_bb.test_acc,
                                                      res_vw.test_acc)


def test_svm_squared_hinge_trains(hashed_data):
    codes, labels = hashed_data
    n_tr = 400
    res = train_bbit_liblinear(
        codes[:n_tr], labels[:n_tr], codes[n_tr:], labels[n_tr:],
        BBitLinearConfig(k=64, b=8), loss="squared_hinge", C=1.0,
        max_iter=30)
    assert res.test_acc > 0.85


def test_sgd_path_trains(hashed_data):
    codes, labels = hashed_data
    n_tr = 400
    res = train_bbit_sgd(
        codes[:n_tr], labels[:n_tr], codes[n_tr:], labels[n_tr:],
        BBitLinearConfig(k=64, b=8), epochs=8, batch_size=64, lr=5e-3)
    assert res.test_acc > 0.85


def test_sgd_includes_tail_batch(hashed_data):
    """Regression: the final partial minibatch used to be dropped each
    epoch — 400 rows at batch 64 must take ceil(400/64)=7 steps/epoch."""
    codes, labels = hashed_data
    res = train_bbit_sgd(
        codes[:400], labels[:400], codes[400:], labels[400:],
        BBitLinearConfig(k=64, b=8), epochs=2, batch_size=64, lr=5e-3)
    assert res.n_iter == 2 * 7


def test_sgd_trains_when_n_below_batch_size(hashed_data):
    """Regression: n < batch_size used to run ZERO steps and hand back
    the untrained init params inside a plausible-looking FitResult."""
    from repro.models.linear import init_bbit_linear
    codes, labels = hashed_data
    lcfg = BBitLinearConfig(k=64, b=8)
    res = train_bbit_sgd(
        codes[:100], labels[:100], codes[400:], labels[400:],
        lcfg, epochs=3, batch_size=256, lr=5e-3, seed=4)
    assert res.n_iter == 3            # one (tail) step per epoch
    init = init_bbit_linear(lcfg, jax.random.key(4))
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(init)))
    assert changed, "params untouched — SGD never stepped"


def test_sgd_rejects_degenerate_inputs(hashed_data):
    codes, labels = hashed_data
    lcfg = BBitLinearConfig(k=64, b=8)
    with pytest.raises(ValueError, match="empty training set"):
        train_bbit_sgd(codes[:0], labels[:0], codes[400:], labels[400:],
                       lcfg)
    with pytest.raises(ValueError, match="epochs"):
        train_bbit_sgd(codes[:100], labels[:100], codes[400:],
                       labels[400:], lcfg, epochs=0)
