"""Data-parallel streaming: psum_mean dtype law, dp-step gradient math
vs a single-device reference, uneven shard groups (zero-row devices),
and kill/resume bitwise determinism under shard_map.

Subprocess tests run on 2 fake XLA devices (the main pytest process
keeps its single real device — see conftest).  The in-process variants
at the bottom only run when the process ALREADY sees ≥ 2 devices: CI's
multi-device tier-1 job sets XLA_FLAGS=--xla_force_host_platform_
device_count=2 so the shard_map path is exercised on CPU-only runners
without subprocess indirection."""
import numpy as np
import pytest

import jax

from conftest import run_in_subprocess

_DP_COMMON = """
    import tempfile, numpy as np, jax, jax.numpy as jnp
    from repro.data import (SynthRcv1Config, generate_arrays,
                            preprocess_and_save, shard_row_counts)
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming

    def make_archive(d, n_docs=240, k=16, b=4, n_shards=3, scheme="minwise"):
        cfg = SynthRcv1Config(seed=11, topic_tokens=150,
                              background_frac=0.35,
                              max_pairs_per_doc=2000,
                              max_triples_per_doc=1000)
        rows, labels = generate_arrays(n_docs, cfg)
        preprocess_and_save(d, rows, labels, k=k, b=b, seed=1,
                            n_shards=n_shards, scheme=scheme, chunk=64)
        return rows, labels
"""


def test_psum_mean_preserves_dtype_under_shard_map():
    """Satellite fix: psum(x)/psum(1) used to promote bf16 → f32 via
    weak int typing; the count must cast to x.dtype.  Also checks the
    pytree form (whole gradient trees all-reduce in one call)."""
    run_in_subprocess("""
        import functools, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        from repro.distributed import psum_mean
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(2)
        tree = {"a": jnp.arange(8, dtype=jnp.bfloat16).reshape(2, 4),
                "b": jnp.ones((2, 3), jnp.float32)}

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P("data"),), out_specs=P(None))
        def mean(t):
            out = psum_mean(jax.tree.map(lambda x: x[0], t), "data")
            return jax.tree.map(lambda x: x[None], out)

        out = mean(tree)
        assert out["a"].dtype == jnp.bfloat16, out["a"].dtype
        assert out["b"].dtype == jnp.float32
        want = np.asarray(tree["a"], np.float32).mean(axis=0)
        np.testing.assert_allclose(
            np.asarray(out["a"][0], np.float32), want, atol=0.05)
        np.testing.assert_allclose(np.asarray(out["b"][0]), 1.0)
        print("OK")
    """, devices=2)


def test_dp_step_matches_single_device_gradient_math():
    """One dp step over ragged device batches == one plain step over
    the concatenated valid rows (global row-weighted mean + L2)."""
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_data_mesh
        from repro.models.linear import (BBitLinearConfig, bbit_logits_packed,
                                         init_bbit_linear)
        from repro.optim.optimizers import make_optimizer
        from repro.train import (build_dp_averaged_train_step,
                                 device_put_sharded, init_averaged_state,
                                 mean_loss_with_preds_fn,
                                 sum_loss_with_hits_fn)
        from repro.core.bbit import pack_codes
        k, b, B, l2 = 16, 4, 6, 1e-3
        cfg = BBitLinearConfig(k=k, b=b)
        fwd = lambda p, pk: bbit_logits_packed(p, pk, cfg)
        mesh = make_data_mesh(2)
        opt = make_optimizer("sgd", 0.1)
        step = build_dp_averaged_train_step(
            sum_loss_with_hits_fn(fwd, "logistic"), opt, mesh, l2=l2,
            donate=False)
        rng = np.random.default_rng(0)
        codes = rng.integers(0, 16, size=(2, B, k)).astype(np.uint16)
        packed = np.stack([pack_codes(c, b) for c in codes])
        labels = rng.integers(0, 2, size=(2, B)).astype(np.int32)
        valid = np.ones((2, B), bool)
        valid[1, 2:] = False               # ragged: device 1 has 2 rows
        astate = init_averaged_state(
            init_bbit_linear(cfg, jax.random.key(0)), opt)
        a2, (loss, hits) = step(
            astate, np.float32(1.0),
            device_put_sharded(packed, mesh),
            device_put_sharded(labels, mesh),
            device_put_sharded(valid, mesh))
        # reference: one plain step over the 8 concatenated valid rows
        sel = valid.reshape(-1)
        flat = packed.reshape(-1, packed.shape[-1])[sel]
        flab = labels.reshape(-1)[sel]
        lf = mean_loss_with_preds_fn(fwd, "logistic", l2=l2)
        (rl, rpred), g = jax.value_and_grad(lf, has_aux=True)(
            astate.state.params, jnp.asarray(flat), jnp.asarray(flab))
        newp = jax.tree.map(lambda p, gg: p - 0.1 * gg,
                            astate.state.params, g)
        for x, y in zip(jax.tree.leaves(a2.state.params),
                        jax.tree.leaves(newp)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)
        assert abs(float(loss) - float(rl)) < 1e-6
        assert int(hits) == int(np.sum(np.asarray(rpred) == flab))
        # Polyak average joined exactly once with the updated params
        assert float(a2.avg_count) == 1.0
        for x, y in zip(jax.tree.leaves(a2.avg_params),
                        jax.tree.leaves(a2.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)
        print("OK")
    """, devices=2)


def test_dp_streaming_uneven_shards_and_resume():
    """5 shards on 2 devices (short final group → one device idles with
    zero rows), oph_zero masks included: no collective hang, exact
    example accounting, bitwise run-to-run + kill/resume determinism,
    and refusal to resume on a different topology."""
    run_in_subprocess(_DP_COMMON + """
    with tempfile.TemporaryDirectory() as d:
        make_archive(d, n_docs=250, n_shards=5, scheme="oph_zero")
        counts = shard_row_counts(d)
        assert len(counts) == 5
        lcfg = BBitLinearConfig(k=16, b=4)
        kw = dict(epochs=2, batch_size=32, lr=5e-3, seed=0)
        dp = fit_streaming(d, lcfg, data_parallel=2, **kw)
        assert dp.completed and dp.examples_seen == 2 * sum(counts)
        assert dp.shards_processed == 10
        assert 0.5 < dp.progressive_acc <= 1.0
        dp2 = fit_streaming(d, lcfg, data_parallel=2, **kw)
        eq = lambda a, b: all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        assert eq(dp.params, dp2.params) and eq(dp.avg_params,
                                                dp2.avg_params)
        with tempfile.TemporaryDirectory() as ck:
            part = fit_streaming(d, lcfg, data_parallel=2, ckpt_dir=ck,
                                 stop_after_shards=3, **kw)
            # group granularity: 3 requested rounds up to 2 groups
            assert not part.completed and part.shards_processed == 4
            res = fit_streaming(d, lcfg, data_parallel=2, ckpt_dir=ck,
                                **kw)
            assert res.completed and eq(dp.params, res.params)
            assert eq(dp.avg_params, res.avg_params)
            assert res.n_steps == dp.n_steps
            assert res.examples_seen == dp.examples_seen
            assert abs(res.progressive_acc - dp.progressive_acc) < 1e-12
            # topology is fingerprinted: serial resume must refuse
            try:
                fit_streaming(d, lcfg, ckpt_dir=ck, **kw)
                raise SystemExit("serial resume of a dp checkpoint "
                                 "was not refused")
            except ValueError as e:
                assert "incompatible" in str(e)
        print("OK")
    """, devices=2)


def test_dp_streaming_single_device_world_matches_semantics():
    """world=1 exercises the whole shard_map/psum path on one device;
    progressive accounting must match the serial schedule exactly."""
    run_in_subprocess(_DP_COMMON + """
    with tempfile.TemporaryDirectory() as d:
        make_archive(d, n_docs=200, n_shards=2)
        counts = shard_row_counts(d)
        lcfg = BBitLinearConfig(k=16, b=4)
        kw = dict(epochs=2, batch_size=32, lr=5e-3, seed=0)
        one = fit_streaming(d, lcfg, data_parallel=1, **kw)
        ser = fit_streaming(d, lcfg, **kw)
        assert one.n_steps == ser.n_steps
        assert one.examples_seen == ser.examples_seen
        # same batches, same math up to padded-batch summation order
        assert abs(one.progressive_acc - ser.progressive_acc) < 0.02
        print("OK")
    """, devices=2)


# ------------------------------------------------ in-process (CI tier) ----
needs_two = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (CI multi-device job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=2)")


@needs_two
def test_dp_fit_streaming_in_process(tmp_path):
    from repro.data import (SynthRcv1Config, generate_arrays,
                            preprocess_and_save)
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming

    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=2000, max_triples_per_doc=1000)
    rows, labels = generate_arrays(150, cfg)
    d = str(tmp_path / "arch")
    preprocess_and_save(d, rows, labels, k=16, b=4, seed=1, n_shards=3,
                        chunk=64)
    res = fit_streaming(d, BBitLinearConfig(k=16, b=4), epochs=2,
                        batch_size=32, lr=5e-3, seed=0, data_parallel=2)
    assert res.completed and res.examples_seen == 2 * 150
    assert 0.5 < res.progressive_acc <= 1.0


@needs_two
def test_psum_mean_dtype_in_process():
    import functools
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from repro.distributed import psum_mean
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh(2)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(None))
    def mean(x):
        return psum_mean(x[0], "data")[None]

    x = jnp.asarray(np.arange(8).reshape(2, 4), jnp.bfloat16)
    out = mean(x)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out[0], np.float32),
                               [2.0, 3.0, 4.0, 5.0])
