"""Cost-model dispatch (perf/): profile-absent choices are bit-identical
to the historical static policy; forced implementations produce
identical (encode: bitwise, logits: allclose-at-kernel-tolerance)
results; profiles round-trip save→load→same-decisions and are rejected
when corrupt or keyed to another device; the serving engine derives its
micro-batch grid from a measured serve_score curve.

Exactness contract mirrors the seed suites: encode ops emit integers so
pallas-vs-xla must be np.array_equal (test_fused_encode.py); logits
kernels re-associate a float sum so kernel-vs-gather is allclose at the
tolerance test_kernels.py validates, while the unpack fallback is the
same contraction as the widened gather and stays bitwise
(test_packed_linear.py)."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import perf
from repro.core.bbit import pack_codes
from repro.core.schemes import make_scheme
from repro.models.linear import (
    BBitLinearConfig, bbit_logits, bbit_logits_packed, init_bbit_linear,
    logits_impl, logits_packed_impl,
)
from repro.perf import (
    BBIT_KERNEL_MAX_V, CostTable, ProfileError, device_fingerprint,
)
from repro.perf.cost_model import OPS, shape_bucket

ON_TPU = jax.default_backend() == "tpu"


@pytest.fixture(autouse=True)
def _clean_dispatch(monkeypatch):
    monkeypatch.delenv(perf.ENV_DISPATCH, raising=False)
    monkeypatch.delenv(perf.ENV_PROFILE, raising=False)
    perf.reset()
    yield
    perf.reset()


def _encode_case(scheme, b, k=16, rows=5, width=12, seed=0):
    rng = np.random.default_rng(seed * 331 + b)
    idx = rng.integers(0, 1 << 30, size=(rows, width)).astype(np.int32)
    nnz = rng.integers(1, width + 1, size=(rows,)).astype(np.int32)
    return make_scheme(scheme, k, seed), jnp.asarray(idx), jnp.asarray(nnz)


# ---------------------------------------------------------------------------
# no profile, no overrides ⇒ the historical static policy, verbatim


def test_no_profile_reproduces_static_policy():
    shape = {"scheme": "oph", "k": 16, "b": 8, "v": 256, "rows": 64,
             "nnz": 128}
    tpu_arm = {"encode": "pallas", "encode_packed": "pallas",
               "logits": "kernel", "logits_packed": "kernel"}
    cpu_arm = {"encode": "xla", "encode_packed": "xla",
               "logits": "gather", "logits_packed": "unpack"}
    for op in tpu_arm:
        want = tpu_arm[op] if ON_TPU else cpu_arm[op]
        assert perf.choose(op, shape) == want
    # ops-layer choices are capability-first: kernel/bwd arms run on
    # every backend (interpret off-TPU), exactly the seed behavior
    assert perf.choose("logits_bwd", shape) == "kernel"
    assert perf.choose("logits_packed_bwd", shape) == "kernel"
    assert perf.choose("pallas_mode") == (
        "compiled" if ON_TPU else "interpret")
    rep = perf.dispatch_report()
    assert rep["profile_loaded"] is False and rep["hits"] == 0
    assert rep["fallbacks"] == 7


def test_eligibility_filters_before_any_override():
    # b=3 can't pack; 2^b over the kernel ceiling can't one-hot; OPH
    # with non-pow-2 bins can't use the scatter-min kernel
    assert OPS["encode_packed"].eligible(
        {"scheme": "minwise", "k": 16, "b": 3}) == ("xla",)
    assert OPS["encode"].eligible(
        {"scheme": "oph", "k": 200, "b": 8}) == ("xla",)
    assert OPS["logits"].eligible(
        {"v": BBIT_KERNEL_MAX_V * 2}) == ("gather",)
    # forcing the ineligible arm is ignored, not crashed into
    assert perf.choose("encode_packed",
                       {"scheme": "minwise", "k": 16, "b": 3},
                       impl="pallas") == "xla"
    with perf.forced(logits="kernel"):
        assert perf.choose("logits", {"v": 1 << 16}) == "gather"
    assert perf.dispatch_report()["ineligible_overrides"] == 1


# ---------------------------------------------------------------------------
# forced implementations agree


@pytest.mark.parametrize("scheme", ["minwise", "oph", "oph_zero"])
@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_forced_encode_impls_bitwise_identical(scheme, b):
    sch, idx, nnz = _encode_case(scheme, b)

    def _run():
        packed, p_empty = sch.encode_packed_device(idx, nnz, b)
        codes, c_empty = sch.encode_device(idx, nnz, b)
        return (np.asarray(packed),
                None if p_empty is None else np.asarray(p_empty),
                np.asarray(codes),
                None if c_empty is None else np.asarray(c_empty))

    with perf.forced(encode_packed="pallas", encode="pallas"):
        pallas_out = _run()
    with perf.forced(encode_packed="xla", encode="xla"):
        xla_out = _run()
    for got, want in zip(pallas_out, xla_out):
        if got is None or want is None:
            assert got is None and want is None
        else:
            assert np.array_equal(got, want)


@pytest.mark.parametrize("b", [2, 4, 8])
def test_forced_logits_impls_agree(b):
    k, v, rows = 16, 1 << b, 9
    cfg = BBitLinearConfig(k=k, b=b)
    params = init_bbit_linear(cfg, jax.random.key(b))
    rng = np.random.default_rng(b)
    codes = rng.integers(0, v, size=(rows, k)).astype(np.uint16)
    wide = jnp.asarray(codes.astype(np.int32))
    packed = jnp.asarray(pack_codes(codes, b))
    with perf.forced(logits="kernel", logits_packed="kernel"):
        lk = np.asarray(bbit_logits(params, wide, cfg))
        pk = np.asarray(bbit_logits_packed(params, packed, cfg))
    with perf.forced(logits="gather", logits_packed="unpack"):
        lg = np.asarray(bbit_logits(params, wide, cfg))
        pu = np.asarray(bbit_logits_packed(params, packed, cfg))
    # kernel re-associates the float sum: allclose at the seed tolerance
    np.testing.assert_allclose(lk, lg, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(pk, pu, rtol=1e-4, atol=1e-4)
    # the unpack fallback IS the widened gather on unpacked codes
    assert np.array_equal(pu, lg)


def test_use_kernel_config_maps_to_explicit_impl():
    cfg_never = BBitLinearConfig(k=16, b=8, use_kernel="never")
    cfg_always = BBitLinearConfig(k=16, b=8, use_kernel="always")
    assert logits_impl(cfg_never) == "gather"
    assert logits_packed_impl(cfg_never) == "unpack"
    assert logits_impl(cfg_always) == "kernel"
    assert logits_packed_impl(cfg_always) == "kernel"
    # explicit config beats a forced context and the env var
    with perf.forced(logits="kernel"):
        assert logits_impl(cfg_never) == "gather"
    os.environ[perf.ENV_DISPATCH] = "logits_packed=kernel"
    try:
        assert logits_packed_impl(cfg_never) == "unpack"
    finally:
        del os.environ[perf.ENV_DISPATCH]


def test_env_dispatch_and_precedence(monkeypatch):
    shape = {"k": 16, "b": 8, "v": 256}
    monkeypatch.setenv(perf.ENV_DISPATCH,
                       "logits=kernel, logits_packed=kernel")
    assert perf.choose("logits", shape) == "kernel"
    assert perf.choose("logits_packed", shape) == "kernel"
    # forced context beats env; explicit impl beats both
    with perf.forced(logits="gather"):
        assert perf.choose("logits", shape) == "gather"
        assert perf.choose("logits", shape, impl="kernel") == "kernel"
    rep = perf.dispatch_report()
    assert rep["overrides"] == 4


# ---------------------------------------------------------------------------
# profiles: round-trip, rejection, decisions


def _table(entries, fp=None, version="t1"):
    return CostTable(fingerprint=fp or device_fingerprint(),
                     entries=dict(entries), table_version=version)


def test_profile_roundtrip_identical_decisions(tmp_path):
    shape = {"k": 16, "b": 8, "v": 256, "rows": 64}
    bucket = shape_bucket(shape)
    table = _table({
        CostTable.key("logits", "kernel", bucket): 0.002,
        CostTable.key("logits", "gather", bucket): 0.005,
        CostTable.key("encode_packed", "pallas",
                      shape_bucket({"scheme": "oph", "k": 16, "b": 8,
                                    "rows": 64, "nnz": 128})): 0.001,
        CostTable.key("encode_packed", "xla",
                      shape_bucket({"scheme": "oph", "k": 16, "b": 8,
                                    "rows": 64, "nnz": 128})): 0.004,
    })
    path = str(tmp_path / "profile.json")
    table.save(path)
    loaded = CostTable.load(path)
    assert loaded.entries == table.entries
    assert loaded.table_version == table.table_version

    perf.set_profile(table)
    first = (perf.choose("logits", shape),
             perf.choose("encode_packed", {"scheme": "oph", "k": 16,
                                           "b": 8, "rows": 64,
                                           "nnz": 128}))
    perf.reset()
    assert perf.maybe_load_profile(path) is True
    second = (perf.choose("logits", shape),
              perf.choose("encode_packed", {"scheme": "oph", "k": 16,
                                            "b": 8, "rows": 64,
                                            "nnz": 128}))
    assert first == second == ("kernel", "pallas")
    rep = perf.dispatch_report()
    assert rep["profile_loaded"] and rep["hits"] == 2
    # measured argmin actually drives the arm: flip the costs
    flipped = _table({k: (0.005 if v == 0.002 else 0.002 if v == 0.005
                          else v) for k, v in table.entries.items()})
    perf.set_profile(flipped)
    assert perf.choose("logits", shape) == "gather"


def test_partial_profile_falls_back_to_heuristic():
    shape = {"k": 16, "b": 8, "v": 256, "rows": 64}
    # only one arm measured ⇒ no profile decision for this bucket
    perf.set_profile(_table({
        CostTable.key("logits", "kernel", shape_bucket(shape)): 0.001}))
    want = "kernel" if ON_TPU else "gather"
    assert perf.choose("logits", shape) == want
    rep = perf.dispatch_report()
    assert rep["hits"] == 0 and rep["fallbacks"] == 1


def test_profile_never_flips_uncalibrated_ops():
    shape = {"k": 16, "b": 8, "v": 256, "rows": 64}
    bucket = shape_bucket(shape)
    perf.set_profile(_table({
        # a hand-crafted profile claiming the ref bwd is faster must
        # not change training numerics
        CostTable.key("logits_bwd", "kernel", bucket): 9.0,
        CostTable.key("logits_bwd", "ref", bucket): 0.1}))
    assert perf.choose("logits_bwd", shape) == "kernel"


def test_corrupt_and_mismatched_profiles_rejected(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(ProfileError):
        CostTable.load(str(bad))
    wrong_schema = tmp_path / "schema.json"
    wrong_schema.write_text(json.dumps({"schema": 999, "entries": {},
                                        "fingerprint": {}}))
    with pytest.raises(ProfileError):
        CostTable.load(str(wrong_schema))
    alien = tmp_path / "alien.json"
    other = _table({}, fp={"backend": "tpu", "device_kind": "TPU v6",
                           "device_count": 8, "jax": "0.0.0"})
    other.save(str(alien))
    with pytest.raises(ProfileError):
        perf.set_profile(str(alien), strict=True)
    # launchers degrade instead of crashing
    for p in (bad, wrong_schema, alien):
        assert perf.maybe_load_profile(str(p)) is False
    assert perf.maybe_load_profile(str(tmp_path / "missing.json")) is False
    assert perf.dispatch_report()["profile_loaded"] is False


def test_shape_bucketing_pow2_rounds_data_sizes():
    a = shape_bucket({"rows": 65, "nnz": 1000, "k": 200, "b": 8})
    assert a == "b=8,k=200,nnz=1024,rows=128"
    assert shape_bucket({"rows": 128, "nnz": 1024, "k": 200, "b": 8}) == a
    assert shape_bucket(None) == "-"


# ---------------------------------------------------------------------------
# micro-batch sizing off a serve_score curve


def _serve_table(curve_fn, nnz_buckets=(32,), max_batch=8, k=16, b=8,
                 scheme="minwise"):
    entries = {}
    for m in nnz_buckets:
        for r in (1, 2, 4, 8):
            entries[CostTable.key(
                "serve_score", "fused",
                shape_bucket({"scheme": scheme, "k": k, "b": b,
                              "rows": r, "nnz": m}))] = curve_fn(r)
    return _table(entries)


def test_row_bucket_suggestions_from_curve_shape():
    # flat curve: a small dispatch costs as much as a big one — every
    # bucket below max is pruned, and the throughput cap is max_batch
    flat = _serve_table(lambda r: 1.0)
    assert perf.suggest_row_buckets(16, 8, "minwise", 8, (32,),
                                    table=flat) == {32: (8,)}
    assert perf.suggest_lane_caps(16, 8, "minwise", 8, (32,),
                                  table=flat) == {32: 8}
    # linear curve: each halving saves ≥15% — keep the whole grid; but
    # cost-per-row ties, so the drain cap stays at max batch (bigger
    # batches amortize per-dispatch overhead the curve can't see)
    linear = _serve_table(lambda r: float(r))
    assert perf.suggest_row_buckets(16, 8, "minwise", 8, (32,),
                                    table=linear) == {32: (1, 2, 4, 8)}
    assert perf.suggest_lane_caps(16, 8, "minwise", 8, (32,),
                                  table=linear) == {32: 8}
    # a >10% genuine small-batch cost-per-row win lowers the cap
    convex = _serve_table(lambda r: {1: 1.0, 2: 2.5, 4: 6.0,
                                     8: 16.0}[r])
    assert perf.suggest_lane_caps(16, 8, "minwise", 8, (32,),
                                  table=convex) == {32: 1}
    # incomplete coverage ⇒ None (caller keeps the static grid)
    assert perf.suggest_row_buckets(16, 8, "minwise", 8, (32, 64),
                                    table=flat) is None


def test_engine_consumes_profile_and_reports_dispatch():
    from repro.serving import HashedClassifierEngine
    perf.set_profile(_serve_table(lambda r: 1.0))
    cfg = BBitLinearConfig(k=16, b=8)
    params = init_bbit_linear(cfg, jax.random.key(0))
    eng = HashedClassifierEngine(params, cfg, seed=0, max_batch=8,
                                 max_wait_ms=1, nnz_buckets=(32,),
                                 row_buckets=None)
    try:
        st = eng.stats()
        assert st["lane_row_buckets"] == {"32": [8]}
        assert st["lane_caps"] == {"32": 8}
        assert st["dispatch"]["profile_loaded"] is True
        rng = np.random.default_rng(0)
        docs = [np.unique(rng.integers(0, 1 << 20, size=s))
                for s in (3, 20, 7)]
        scores = eng.score_docs(docs)
        assert scores.shape == (3,)
    finally:
        eng.close()


def test_engine_without_profile_keeps_static_grid():
    from repro.serving import HashedClassifierEngine
    cfg = BBitLinearConfig(k=16, b=8)
    params = init_bbit_linear(cfg, jax.random.key(0))
    eng = HashedClassifierEngine(params, cfg, seed=0, max_batch=8,
                                 max_wait_ms=1, nnz_buckets=(32,),
                                 row_buckets=None)
    try:
        st = eng.stats()
        assert st["lane_row_buckets"] == {}
        assert st["row_buckets"] == [1, 2, 4, 8]
        assert st["dispatch"]["profile_loaded"] is False
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# calibration: budget-capped, deterministic, round-trippable


def test_calibrate_smoke_budget_and_roundtrip(tmp_path):
    table = perf.calibrate(k=16, b_values=(8,), schemes=("oph",),
                           encode_rows=(4,), encode_widths=(16,),
                           logits_rows=(8,), max_batch=4,
                           nnz_buckets=(16,), trials=1, budget_s=120.0,
                           seed=0)
    assert table.entries and table.matches_device()
    assert table.meta["n_entries"] == len(table.entries)
    # every calibrated-op bucket has all eligible arms (budget allowed)
    per_bucket = {}
    for key in table.entries:
        op, impl, bucket = key.split("|", 2)
        per_bucket.setdefault((op, bucket), set()).add(impl)
    for (op, bucket), impls in per_bucket.items():
        if op != "serve_score":
            assert len(impls) == 2, (op, bucket, impls)
    path = str(tmp_path / "p.json")
    table.save(path)
    assert CostTable.load(path).entries == table.entries
    summary = perf.summarize(table)
    assert summary["entries"] == len(table.entries)
    # an exhausted budget yields an empty (but valid, saveable) table
    empty = perf.calibrate(k=16, b_values=(8,), schemes=("oph",),
                           encode_rows=(4,), encode_widths=(16,),
                           logits_rows=(8,), nnz_buckets=(16,),
                           trials=1, budget_s=0.0, seed=0)
    assert empty.entries == {}
    perf.set_profile(empty)   # loads fine; every choice falls back
    assert perf.choose("logits", {"k": 16, "b": 8, "v": 256}) == (
        "kernel" if ON_TPU else "gather")
