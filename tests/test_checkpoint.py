"""Fault tolerance: atomic checkpoints, bitwise restart, elastic reshard,
failure injection, straggler watchdog."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.ft.watchdog import StepWatchdog, FailureInjector
from repro.models.linear import BBitLinearConfig, init_bbit_linear, bbit_logits
from repro.optim.optimizers import make_optimizer
from repro.train.losses import mean_loss_fn
from repro.train.steps import init_state, build_train_step
from repro.data.loader import HashedCodesLoader


def _training_setup(seed=0):
    lcfg = BBitLinearConfig(k=16, b=4)
    opt = make_optimizer("adamw", 1e-2)
    loss_fn = mean_loss_fn(lambda p, c: bbit_logits(p, c, lcfg),
                           "logistic", l2=1e-6)
    step_fn = build_train_step(loss_fn, opt, donate=False)
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(256, 16)).astype(np.uint16)
    labels = (codes.sum(axis=1) % 2).astype(np.int32)
    loader = HashedCodesLoader(codes, labels, batch_size=32, seed=seed)
    state = init_state(init_bbit_linear(lcfg, jax.random.key(seed)), opt)
    return step_fn, loader, state


def _run(step_fn, loader, state, start, stop, ckpt_dir=None, every=5,
         fail_at=None):
    injector = FailureInjector(fail_at)
    for step, bc, by in loader.batches(start_step=start):
        if step >= stop:
            break
        injector.maybe_fail(step)
        state, _ = step_fn(state, jnp.asarray(bc.astype(np.int32)),
                           jnp.asarray(by))
        if ckpt_dir and (step + 1) % every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    return state


def test_save_restore_roundtrip(tmp_path):
    step_fn, loader, state = _training_setup()
    state = _run(step_fn, loader, state, 0, 7)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    template = jax.tree.map(jnp.zeros_like, state)
    restored, step = ckpt.restore(d, template)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_restart_is_bitwise_identical(tmp_path):
    """kill at step 12 → resume from ckpt → same params as straight run."""
    d = str(tmp_path / "ck")
    # straight run to 20
    step_fn, loader, state0 = _training_setup()
    straight = _run(step_fn, loader, state0, 0, 20)
    # interrupted run: crash at 12, checkpoints every 5
    step_fn2, loader2, state1 = _training_setup()
    with pytest.raises(RuntimeError):
        _run(step_fn2, loader2, state1, 0, 20, ckpt_dir=d, every=5,
             fail_at=12)
    # restart: restore latest (step 10) and replay
    step_fn3, loader3, state2 = _training_setup()
    restored, start = ckpt.restore(d, state2)
    assert start == 10
    resumed = _run(step_fn3, loader3, restored, start, 20)
    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_pruning(tmp_path):
    step_fn, loader, state = _training_setup()
    d = str(tmp_path / "ck")
    for s in (5, 10, 15, 20):
        ckpt.save(d, s, state, keep_last=2)
    assert ckpt.latest_step(d) == 20
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("step_"))
    assert steps == [15, 20]


def test_atomicity_no_partial_dirs(tmp_path):
    step_fn, loader, state = _training_setup()
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state)
    assert not any(p.startswith(".tmp") for p in os.listdir(d))


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written on one topology restores onto another."""
    from repro.ckpt.elastic import mesh_from_available_devices, reshard
    step_fn, loader, state = _training_setup()
    state = _run(step_fn, loader, state, 0, 3)
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state)
    mesh = mesh_from_available_devices(model_parallel=1, max_devices=1)
    restored, _ = ckpt.restore(d, state)
    from jax.sharding import NamedSharding, PartitionSpec as P
    placed = reshard(restored, NamedSharding(mesh, P()))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(placed)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_flags_and_escalates():
    wd = StepWatchdog(threshold=2.0, window=16, escalate_after=2)
    for s in range(10):
        wd.end_step(s, duration=0.1)
    assert not wd.flagged_steps
    wd.end_step(10, duration=0.5)        # 5× median
    wd.end_step(11, duration=0.5)
    assert wd.flagged_steps == [10, 11]
    assert wd.escalations == [11]        # escalated after 2 consecutive


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at=3)
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second pass: no re-fire (restart semantics)
