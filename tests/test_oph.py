"""One Permutation Hashing subsystem: numpy/jnp/Pallas parity, the
collision-probability ≈ resemblance law, densification correctness,
dataset round-trips, serving parity, and the 1-hash-eval-per-nonzero
cost claim (the k× preprocessing saving over the paper's scheme)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import resemblance
from repro.core.minhash import minhash_numpy
from repro.core.oph import (
    OPH_EMPTY_CODE,
    OPHHash,
    densify_rotation,
    densify_rotation_numpy,
    oph_bin_minima_jnp,
    oph_bin_minima_numpy,
    oph_codes_numpy,
    oph_collision_probability,
    split_zero_codes,
)
from repro.core.schemes import make_scheme
from repro.core.universal_hash import ModPrimeHash
from repro.kernels.oph import oph_pallas

RNG = np.random.default_rng(0)


def _mk(n, m, k, seed=0, min_nnz=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 1 << 30, size=(n, m)).astype(np.int32)
    nnz = rng.integers(min_nnz, m + 1, size=(n,)).astype(np.int32)
    mask = np.arange(m)[None, :] < nnz[:, None]
    fam = OPHHash.make(k, seed + 1)
    return idx, nnz, mask, fam


def _mk_pair(rng, dim, f1, f2, overlap):
    common = rng.choice(dim, size=f1 + f2 - overlap, replace=False)
    s1 = sorted(int(x) for x in common[:f1])
    s2 = sorted(int(x) for x in common[f1 - overlap:])
    idx = np.zeros((2, max(f1, f2)), np.int32)
    mask = np.zeros((2, max(f1, f2)), bool)
    for i, s in enumerate((s1, s2)):
        idx[i, :len(s)] = s
        mask[i, :len(s)] = True
    return idx, mask, resemblance(set(s1), set(s2))


@pytest.mark.parametrize("n,m,k", [
    (1, 1, 2), (4, 16, 8), (10, 300, 64), (9, 513, 128), (3, 1024, 256),
])
def test_oph_numpy_jnp_pallas_parity(n, m, k):
    """The three implementations are bit-exact, empty rows included."""
    idx, nnz, mask, fam = _mk(n, m, k, seed=n * 100 + m + k)
    a, b = fam.params()
    v_np, e_np = oph_bin_minima_numpy(idx, mask, fam)
    v_j, e_j = oph_bin_minima_jnp(jnp.asarray(idx), jnp.asarray(mask),
                                  a, b, k)
    v_p = oph_pallas(jnp.asarray(idx), jnp.asarray(nnz), a, b, k=k,
                     interpret=True)
    assert np.array_equal(v_np, np.asarray(v_j))
    assert np.array_equal(e_np, np.asarray(e_j))
    assert np.array_equal(v_np, np.asarray(v_p))
    # densification parity on the same minima
    d_np, de_np = densify_rotation_numpy(v_np, e_np)
    d_j, de_j = densify_rotation(jnp.asarray(v_np), jnp.asarray(e_np))
    assert np.array_equal(d_np, np.asarray(d_j))
    assert np.array_equal(de_np, np.asarray(de_j))


def test_oph_requires_power_of_two_bins():
    with pytest.raises(ValueError):
        OPHHash.make(48, 0)
    with pytest.raises(ValueError):
        oph_pallas(jnp.zeros((1, 4), jnp.int32), jnp.ones((1,), jnp.int32),
                   jnp.ones((1,), jnp.uint32), jnp.zeros((1,), jnp.uint32),
                   k=6, interpret=True)


def test_collision_probability_matches_resemblance():
    """Both empty-bin strategies estimate R within Monte-Carlo error
    (the OPH analogue of the existing minwise collision harness)."""
    rng = np.random.default_rng(5)
    idx, mask, r = _mk_pair(rng, 1 << 16, 500, 400, 250)
    k = 256
    n_seeds = 20
    est_zero, est_dense = [], []
    for seed in range(n_seeds):
        fam = OPHHash.make(k, seed)
        v, e = oph_bin_minima_numpy(idx, mask, fam)
        est_zero.append(
            oph_collision_probability(v[0], e[0], v[1], e[1]))
        dv, _ = densify_rotation_numpy(v, e)
        est_dense.append(float(np.mean(dv[0] == dv[1])))
    sigma = np.sqrt(r * (1 - r) / (k * n_seeds))
    assert abs(np.mean(est_zero) - r) < 5 * sigma
    # densification redistributes (doesn't discard) signal: same mean,
    # somewhat larger variance → looser bound
    assert abs(np.mean(est_dense) - r) < 8 * sigma


def test_densification_fills_sparse_rows():
    """Rows with nnz < k bins: every bin gets a valid code, values
    follow the rotation rule H[j] = H[j+t mod k] + t·C."""
    k = 16
    idx, nnz, mask, fam = _mk(6, 5, k, seed=2, min_nnz=1)  # nnz ≤ 5 < 16
    v, e = oph_bin_minima_numpy(idx, mask, fam)
    assert e.any()                      # sparse rows do leave empty bins
    d, de = densify_rotation_numpy(v, e)
    assert not de.any()
    assert (d != np.uint32(0xFFFFFFFF)).all()
    C = 0x9E3779B1
    for i in range(v.shape[0]):
        for j in range(k):
            t = 0
            while e[i, (j + t) % k]:
                t += 1
            want = (int(v[i, (j + t) % k]) + t * C) & 0xFFFFFFFF
            assert int(d[i, j]) == want, (i, j, t)
    # a fully-empty row stays fully empty (sentinel, not garbage)
    v0 = np.full((1, k), np.uint32(0xFFFFFFFF))
    d0, de0 = densify_rotation_numpy(v0, v0 == np.uint32(0xFFFFFFFF))
    assert de0.all() and (d0 == np.uint32(0xFFFFFFFF)).all()


def test_zero_coding_codes_and_split():
    idx, nnz, mask, fam = _mk(4, 6, 32, seed=3, min_nnz=1)
    codes = oph_codes_numpy(idx, mask, fam, b=8, densify=False)
    assert (codes == OPH_EMPTY_CODE).any()
    safe, empty = split_zero_codes(codes)
    assert safe.max() < 256
    assert np.array_equal(empty, codes == OPH_EMPTY_CODE)
    with pytest.raises(ValueError):
        oph_codes_numpy(idx, mask, fam, b=16, densify=False)


def test_one_hash_eval_per_nonzero_vs_k():
    """THE cost claim: OPH issues 1 hash evaluation per nonzero where
    the paper's k-permutation pass issues k (counted, not inferred)."""
    k = 64
    idx, nnz, mask, fam = _mk(8, 40, k, seed=4, min_nnz=1)
    counts = {"oph": 0, "minwise": 0}

    import repro.core.oph as oph_mod
    orig_hash = oph_mod._hash_u32

    def counting_hash(t, a, b):
        counts["oph"] += np.asarray(t).size
        return orig_hash(t, a, b)

    orig_call = ModPrimeHash.__call__

    def counting_call(self, t):
        out = orig_call(self, t)
        counts["minwise"] += out.size
        return out

    try:
        oph_mod._hash_u32 = counting_hash
        ModPrimeHash.__call__ = counting_call
        oph_bin_minima_numpy(idx, mask, fam)
        minhash_numpy(idx, mask, ModPrimeHash.make(k, 0))
    finally:
        oph_mod._hash_u32 = orig_hash
        ModPrimeHash.__call__ = orig_call

    assert counts["oph"] == idx.size                 # 1 eval / nonzero
    assert counts["minwise"] == idx.size * k         # k evals / nonzero


@pytest.mark.parametrize("scheme", ["oph", "oph_zero"])
def test_hashed_dataset_roundtrip_oph(tmp_path, scheme):
    """preprocess → bit-packed shards → load restores codes, scheme and
    (for zero-coding) the empty-bin sentinel; meta is version 3
    (streaming v3 shards since PR 2)."""
    from repro.data import load_hashed, preprocess_and_save, preprocess_rows
    rng = np.random.default_rng(7)
    rows = [np.unique(rng.integers(0, 1 << 28,
                                   size=rng.integers(3, 120)))
            for _ in range(50)]
    labels = rng.integers(0, 2, 50).astype(np.int32)
    d = str(tmp_path / scheme)
    stats = preprocess_and_save(d, rows, labels, k=32, b=6,
                                scheme=scheme, n_shards=3)
    assert stats["scheme"] == scheme
    codes, l2, meta = load_hashed(d)
    assert meta["scheme"] == scheme and meta["format_version"] == 4
    assert np.array_equal(l2, labels)
    want = preprocess_rows(rows, k=32, b=6, scheme=scheme)
    assert np.array_equal(codes, want)
    if scheme == "oph_zero":
        assert (codes == OPH_EMPTY_CODE).any()
        safe, _ = split_zero_codes(codes)
        assert safe.max() < 64
    else:
        assert codes.max() < 64


def test_oph_resemblance_tracks_minwise_on_synthetic_rcv1():
    """preprocess_rows(scheme='oph') codes estimate the same pairwise
    resemblance as the minwise path within statistical tolerance."""
    from repro.data import SynthRcv1Config, generate_arrays, preprocess_rows
    cfg = SynthRcv1Config(seed=9, max_pairs_per_doc=2000,
                          max_triples_per_doc=1000)
    rows, _ = generate_arrays(20, cfg)
    k, b = 256, 8
    c_min = preprocess_rows(rows, k=k, b=b, scheme="minwise", seed=1)
    c_oph = preprocess_rows(rows, k=k, b=b, scheme="oph", seed=1)
    rng = np.random.default_rng(1)
    for _ in range(10):
        i, j = rng.integers(0, len(rows), 2)
        if i == j:
            continue
        p_min = float(np.mean(c_min[i] == c_min[j]))
        p_oph = float(np.mean(c_oph[i] == c_oph[j]))
        # both estimate P_b = R + (1-R)/2^b; k=256 ⇒ σ ≈ 0.03
        assert abs(p_min - p_oph) < 6 * np.sqrt(0.25 / k), (i, j)


def test_engine_oph_scores_match_direct_path():
    """Scheme-aware serving: engine(scheme='oph'/'oph_zero') equals the
    direct jnp encode + logits path."""
    import jax
    from repro.models.linear import (BBitLinearConfig, bbit_logits,
                                     init_bbit_linear)
    from repro.serving import HashedClassifierEngine
    import repro.data.packing as packing
    cfg = BBitLinearConfig(k=16, b=6)
    params = init_bbit_linear(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    docs = [np.unique(rng.integers(0, 1 << 20,
                                   size=rng.integers(5, 60)))
            for _ in range(12)]
    for scheme in ("oph", "oph_zero"):
        eng = HashedClassifierEngine(params, cfg, seed=4, max_batch=8,
                                     max_wait_ms=5, scheme=scheme)
        futs = [eng.submit(d) for d in docs]
        got = np.array([f.result(timeout=30) for f in futs])
        sch = make_scheme(scheme, cfg.k, 4)
        want = []
        for d in docs:
            idx, nnz = packing.pad_rows([d], pad_to_multiple=1)
            mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
            codes, empty = sch.encode_jnp(jnp.asarray(idx),
                                          jnp.asarray(mask), cfg.b)
            want.append(float(
                bbit_logits(params, codes, cfg, empty=empty)[0, 0]))
        np.testing.assert_allclose(got, np.array(want), atol=1e-5,
                                   err_msg=scheme)
        eng.close()


def test_scheme_registry():
    assert set(make_scheme(s, 8, 0).name
               for s in ("minwise", "oph", "oph_zero")) \
        == {"minwise", "oph", "oph_zero"}
    assert make_scheme("minwise", 8, 0).hash_evals_per_nonzero == 8
    assert make_scheme("oph", 8, 0).hash_evals_per_nonzero == 1
    with pytest.raises(ValueError):
        make_scheme("nope", 8, 0)
