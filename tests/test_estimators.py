"""Property tests for every closed-form law the paper states.

Monte-Carlo estimates from the *actual hashing code* are checked
against Eqs (1)/(2), Theorem 1 (3)-(5), (6)/(7), (12)/(13), (15)/(16).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import (
    SparseBatch, MultiplyShiftHash, minhash_batch, bbit_codes,
    resemblance, vw_hash_batch, vw_inner_product,
    rp_project_batch, rp_inner_product,
)
from repro.core.estimators import (
    BBitLaw, bbit_law_sparse_limit, var_rm, var_rp, var_vw,
    storage_equivalent_k_vw,
)


def _make_pair(rng, dim, f1, f2, overlap):
    common = rng.choice(dim, size=f1 + f2 - overlap, replace=False)
    s1 = set(int(x) for x in common[:f1])
    s2 = set(int(x) for x in common[f1 - overlap:])
    return s1, s2


@settings(max_examples=8, deadline=None)
@given(
    f1=st.integers(80, 400), f2=st.integers(80, 400),
    frac=st.floats(0.1, 0.9), seed=st.integers(0, 2**31 - 1),
)
def test_minwise_estimator_unbiased(f1, f2, frac, seed):
    rng = np.random.default_rng(seed)
    overlap = max(1, int(frac * min(f1, f2)))
    s1, s2 = _make_pair(rng, 1 << 16, f1, f2, overlap)
    r = resemblance(s1, s2)
    k = 1500
    fam = MultiplyShiftHash.make(k, seed)
    batch = SparseBatch.from_lists([sorted(s1), sorted(s2)], dim=1 << 16)
    z = np.asarray(minhash_batch(batch, fam))
    r_hat = float(np.mean(z[0] == z[1]))
    # Eq (1)/(2): within 5 sigma
    assert abs(r_hat - r) < 5 * np.sqrt(var_rm(r, k)) + 1e-9


@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_theorem1_collision_law(b):
    rng = np.random.default_rng(b)
    s1, s2 = _make_pair(rng, 1 << 16, 500, 400, 200)
    r = resemblance(s1, s2)
    k = 3000
    fam = MultiplyShiftHash.make(k, seed=7)
    batch = SparseBatch.from_lists([sorted(s1), sorted(s2)], dim=1 << 16)
    z = np.asarray(minhash_batch(batch, fam))
    codes = np.asarray(bbit_codes(z, b))
    pb_hat = float(np.mean(codes[0] == codes[1]))
    # hash range is 2^32 → r1, r2 ≈ 0: the sparse limit Eq (5) applies
    pb_theory = bbit_law_sparse_limit(b)(r)
    sigma = np.sqrt(pb_theory * (1 - pb_theory) / k)
    assert abs(pb_hat - pb_theory) < 5 * sigma
    # full Theorem 1 with the true (tiny) sparsities agrees with Eq (5)
    law = BBitLaw(b=b, r1=len(s1) / 2**32, r2=len(s2) / 2**32)
    assert abs(law.pb(r) - pb_theory) < 1e-6


def test_var_rb_formula_matches_simulation():
    rng = np.random.default_rng(0)
    s1, s2 = _make_pair(rng, 1 << 16, 300, 300, 150)
    r = resemblance(s1, s2)
    b, k = 2, 200
    law = BBitLaw(b=b, r1=0.0, r2=0.0)
    batch = SparseBatch.from_lists([sorted(s1), sorted(s2)], dim=1 << 16)
    r_hats = []
    for seed in range(400):
        fam = MultiplyShiftHash.make(k, seed)
        z = np.asarray(minhash_batch(batch, fam))
        codes = np.asarray(bbit_codes(z, b))
        pb_hat = float(np.mean(codes[0] == codes[1]))
        r_hats.append(law.r_hat(pb_hat))
    emp_mean = np.mean(r_hats)
    emp_var = np.var(r_hats)
    assert abs(emp_mean - r) < 0.02          # Eq (6) unbiased
    theory = law.var_rb(r, k)
    assert 0.6 * theory < emp_var < 1.6 * theory   # Eq (7)


@pytest.mark.parametrize("s", [1, 3])
def test_vw_unbiased_and_variance(s):
    rng = np.random.default_rng(1)
    s1, s2 = _make_pair(rng, 4096, 500, 400, 250)
    u1 = np.zeros(4096, np.float32); u1[list(s1)] = 1
    u2 = np.zeros(4096, np.float32); u2[list(s2)] = 1
    a = float(u1 @ u2)
    batch = SparseBatch.from_lists([sorted(s1), sorted(s2)], dim=4096)
    ests = [float(vw_inner_product(*vw_hash_batch(batch, m=256, s=s,
                                                  seed=i)))
            for i in range(300)]
    se = np.sqrt(var_vw(u1, u2, 256, s) / 300)
    assert abs(np.mean(ests) - a) < 5 * se       # Eq (15)
    emp = np.var(ests)
    theory = var_vw(u1, u2, 256, s)              # Eq (16)
    assert 0.5 * theory < emp < 1.8 * theory


def test_rp_unbiased_and_variance():
    rng = np.random.default_rng(2)
    s1, s2 = _make_pair(rng, 4096, 600, 500, 300)
    u1 = np.zeros(4096, np.float32); u1[list(s1)] = 1
    u2 = np.zeros(4096, np.float32); u2[list(s2)] = 1
    a = float(u1 @ u2)
    batch = SparseBatch.from_lists([sorted(s1), sorted(s2)], dim=4096)
    ests = [float(rp_inner_product(*rp_project_batch(batch, k=256,
                                                     seed=i)))
            for i in range(300)]
    se = np.sqrt(var_rp(u1, u2, 256, 1.0) / 300)
    assert abs(np.mean(ests) - a) < 5 * se       # Eq (12)
    emp = np.var(ests)
    theory = var_rp(u1, u2, 256, 1.0)            # Eq (13)
    assert 0.5 * theory < emp < 1.8 * theory


def test_vw_equals_rp_variance_at_s1():
    """Paper §5.2: at s=1, Eq (16) reduces to Eq (13)."""
    rng = np.random.default_rng(3)
    u1 = rng.normal(size=512).astype(np.float32)
    u2 = rng.normal(size=512).astype(np.float32)
    assert np.isclose(var_vw(u1, u2, 64, 1.0), var_rp(u1, u2, 64, 1.0))


def test_storage_equivalence_math():
    """Paper §5.3: same-storage comparison used in Figs 5-6."""
    assert storage_equivalent_k_vw(200, 8) == 50     # 1600 bits / 32
    assert storage_equivalent_k_vw(30, 12) == 11
