"""Serving: dynamic batcher semantics + hashed-classifier engine parity
+ greedy LM generation."""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving import DynamicBatcher, HashedClassifierEngine, \
    greedy_generate


def test_dynamic_batcher_batches_and_resolves():
    calls = []

    def run(xs):
        calls.append(len(xs))
        return [x * 2 for x in xs]

    b = DynamicBatcher(run, max_batch=8, max_wait_ms=20)
    futs = [b.submit(i) for i in range(20)]
    results = [f.result(timeout=5) for f in futs]
    assert results == [2 * i for i in range(20)]
    assert b.requests_served == 20
    assert max(calls) > 1          # batching actually happened
    b.close()


def test_engine_scores_match_direct_path():
    from repro.core.minhash import minhash_jnp
    from repro.core.universal_hash import MultiplyShiftHash
    from repro.models.linear import (BBitLinearConfig, init_bbit_linear,
                                     bbit_logits)
    cfg = BBitLinearConfig(k=16, b=6)
    params = init_bbit_linear(cfg, jax.random.key(0))
    eng = HashedClassifierEngine(params, cfg, seed=4, max_batch=16,
                                 max_wait_ms=10)
    rng = np.random.default_rng(0)
    docs = [np.unique(rng.integers(0, 1 << 20, size=rng.integers(5, 60)))
            for _ in range(24)]
    futs = [eng.submit(d) for d in docs]
    got = np.array([f.result(timeout=30) for f in futs])
    # direct path
    fam = MultiplyShiftHash.make(16, 4)
    a, b_ = fam.params()
    import repro.data.packing as packing
    want = []
    for d in docs:
        idx, nnz = packing.pad_rows([d], pad_to_multiple=1)
        m = idx.shape[1]
        mask = np.arange(m)[None, :] < nnz[:, None]
        z = minhash_jnp(jnp.asarray(idx), jnp.asarray(mask), a, b_)
        codes = (np.asarray(z) & 63).astype(np.int32)
        want.append(float(bbit_logits(params, jnp.asarray(codes), cfg)[0, 0]))
    np.testing.assert_allclose(got, np.array(want), atol=1e-5)
    eng.close()


def test_engine_survives_nnz_over_largest_bucket():
    """Regression: a document with nnz > the largest pad bucket (32768)
    used to get an ``idx`` wider than its ``mask``, crashing the
    batcher thread inside the jitted ``_score``.  The bucket now grows
    to the next power of two and scoring stays consistent."""
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    cfg = BBitLinearConfig(k=8, b=4)
    params = init_bbit_linear(cfg, jax.random.key(1))
    eng = HashedClassifierEngine(params, cfg, seed=3, max_batch=4,
                                 max_wait_ms=5)
    rng = np.random.default_rng(0)
    big = np.unique(rng.integers(0, 1 << 28, size=40000))
    assert len(big) > 32768
    small = np.unique(rng.integers(0, 1 << 20, size=30))
    futs = [eng.submit(d) for d in (small, big, small)]
    vals = [float(f.result(timeout=120)) for f in futs]
    assert all(np.isfinite(v) for v in vals)
    # identical docs must score identically regardless of batch mates
    assert vals[0] == vals[2]
    eng.close()


def test_greedy_generate_consistency():
    """Generation via prefill+decode == argmax over forward_train."""
    from repro.configs.base import ArchConfig
    from repro.models.api import get_model_api
    from repro.models import transformer as T
    cfg = ArchConfig(name="g", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", attn_q_chunk=8, attn_kv_chunk=8)
    api = get_model_api(cfg)
    params = api.init_params(jax.random.key(3))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 64, size=(2, 6)).astype(np.int32)
    toks = greedy_generate(api, params, prompt, max_new=5, max_len=16)
    assert toks.shape == (2, 11)
    # reference: repeatedly run the full forward
    cur = prompt.copy()
    for _ in range(5):
        logits = T.forward_train(params, jnp.asarray(cur), cfg)
        nxt = np.argmax(np.asarray(logits[:, -1]), axis=-1)
        cur = np.concatenate([cur, nxt[:, None].astype(np.int32)], axis=1)
    assert np.array_equal(toks, cur)
