"""Serving: batcher semantics (incl. deterministic close), hashed-
classifier engine parity, input validation + empty-doc semantics, and
greedy LM generation."""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serving import BucketBatcher, DynamicBatcher, \
    HashedClassifierEngine, greedy_generate


def test_dynamic_batcher_batches_and_resolves():
    calls = []

    def run(xs):
        calls.append(len(xs))
        return [x * 2 for x in xs]

    b = DynamicBatcher(run, max_batch=8, max_wait_ms=20)
    futs = [b.submit(i) for i in range(20)]
    results = [f.result(timeout=5) for f in futs]
    assert results == [2 * i for i in range(20)]
    assert b.requests_served == 20
    assert max(calls) > 1          # batching actually happened
    b.close()


def test_dynamic_batcher_close_flushes_pending_with_racing_submitter():
    """Regression: ``close()`` used to just flip a flag — requests
    submitted just before close hung on unresolved futures forever.
    Now close flushes (or fails) every accepted future and joins the
    worker; submits that lose the race raise instead of hanging."""
    def slow_run(xs):
        time.sleep(0.005)
        return [x + 1 for x in xs]

    b = DynamicBatcher(slow_run, max_batch=4, max_wait_ms=1)
    accepted, rejected = [], []

    def submitter():
        for i in range(200):
            try:
                accepted.append((i, b.submit(i)))
            except RuntimeError:
                rejected.append(i)
                return
            time.sleep(0.0005)

    t = threading.Thread(target=submitter)
    t.start()
    time.sleep(0.02)               # let a backlog build up
    b.close()
    t.join(timeout=10)
    assert not t.is_alive()
    assert rejected or len(accepted) == 200
    # every accepted future is DONE after close() returns — none hang
    for i, f in accepted:
        assert f.done()
        assert f.result(timeout=0) == i + 1
    assert not b._worker.is_alive()
    with pytest.raises(RuntimeError):
        b.submit(0)


def test_dynamic_batcher_close_is_idempotent_and_fails_cleanly():
    def boom(xs):
        raise ValueError("kaput")

    b = DynamicBatcher(boom, max_batch=4, max_wait_ms=1)
    fut = b.submit(1)
    b.close()
    b.close()
    with pytest.raises(ValueError, match="kaput"):
        fut.result(timeout=0)


def test_bucket_batcher_lane_isolation_and_close():
    """Items batch only with same-lane peers; close flushes all lanes."""
    seen = []

    def dispatch(key, items):
        seen.append((key, list(items)))
        return [i * 10 for i in items]

    b = BucketBatcher(dispatch, lambda h: h, route=lambda x: x % 2,
                      max_batch=8, max_wait_ms=50, depth=2)
    futs = [b.submit(i) for i in range(12)]
    got = [f.result(timeout=5) for f in futs]
    assert got == [i * 10 for i in range(12)]
    for key, items in seen:
        assert all(i % 2 == key for i in items)   # no cross-lane mixing
    assert b.requests_served == 12
    b.close()
    with pytest.raises(RuntimeError):
        b.submit(1)


def test_batchers_survive_client_cancelled_futures():
    """A client that cancel()s a pending future must not kill the
    worker threads or poison its batch-mates' results (set_result /
    set_exception on a cancelled future raises InvalidStateError)."""
    gate = threading.Event()

    def slow_dispatch(key, items):
        gate.wait(timeout=10)
        if key == "boom":
            raise RuntimeError("boom")
        return items

    b = BucketBatcher(slow_dispatch, lambda h: h,
                      route=lambda x: "boom" if x == "boom" else "ok",
                      max_batch=8, max_wait_ms=1)
    victim = b.submit("a")
    mates = [b.submit(x) for x in ("b", "c")]
    err_victim = b.submit("boom")
    assert victim.cancel() and err_victim.cancel()
    gate.set()
    assert [f.result(timeout=10) for f in mates] == ["b", "c"]
    # the drain thread survived the cancelled-future error batch too
    assert b.submit("d").result(timeout=10) == "d"
    b.close()

    d = DynamicBatcher(lambda xs: [x * 2 for x in xs],
                       max_batch=8, max_wait_ms=20)
    fut = d.submit(1)
    fut.cancel()
    ok = d.submit(2)
    assert ok.result(timeout=10) == 4
    d.close()


def test_bucket_batcher_full_lane_beats_unripe_older_head():
    """A lane hitting max_batch dispatches immediately even while a
    different lane's older-but-not-ripe head is still waiting."""
    b = BucketBatcher(lambda key, items: (key, list(items)),
                      lambda h: [h[0]] * len(h[1]),
                      route=lambda x: x[0],
                      max_batch=4, max_wait_ms=3000)
    slow = b.submit(("slow", 0))       # older head, lane never fills
    fast = [b.submit(("fast", i)) for i in range(4)]   # fills its lane
    t0 = time.perf_counter()
    for f in fast:
        assert f.result(timeout=10) == "fast"
    assert time.perf_counter() - t0 < 1.0, \
        "full lane waited behind another lane's max_wait"
    assert not slow.done()             # its max_wait hasn't elapsed
    b.close()
    assert slow.result(timeout=0) == "slow"


def test_bucket_batcher_dispatch_error_fails_only_that_batch():
    def dispatch(key, items):
        if key == 1:
            raise RuntimeError("lane down")
        return items

    b = BucketBatcher(dispatch, lambda h: h, route=lambda x: x % 2,
                      max_batch=4, max_wait_ms=1)
    ok = b.submit(2)
    bad = b.submit(3)
    assert ok.result(timeout=5) == 2
    with pytest.raises(RuntimeError, match="lane down"):
        bad.result(timeout=5)
    b.close()


def _small_engine(params, cfg, **kw):
    kw.setdefault("nnz_buckets", (64, 128))
    kw.setdefault("row_buckets", (1, 2, 4, 8, 16))
    return HashedClassifierEngine(params, cfg, **kw)


def test_engine_scores_match_direct_path():
    from repro.core.minhash import minhash_jnp
    from repro.core.universal_hash import MultiplyShiftHash
    from repro.models.linear import (BBitLinearConfig, init_bbit_linear,
                                     bbit_logits)
    cfg = BBitLinearConfig(k=16, b=6)
    params = init_bbit_linear(cfg, jax.random.key(0))
    eng = _small_engine(params, cfg, seed=4, max_batch=16, max_wait_ms=10)
    rng = np.random.default_rng(0)
    docs = [np.unique(rng.integers(0, 1 << 20, size=rng.integers(5, 60)))
            for _ in range(24)]
    futs = [eng.submit(d) for d in docs]
    got = np.array([f.result(timeout=30) for f in futs])
    # direct path
    fam = MultiplyShiftHash.make(16, 4)
    a, b_ = fam.params()
    import repro.data.packing as packing
    want = []
    for d in docs:
        idx, nnz = packing.pad_rows([d], pad_to_multiple=1)
        m = idx.shape[1]
        mask = np.arange(m)[None, :] < nnz[:, None]
        z = minhash_jnp(jnp.asarray(idx), jnp.asarray(mask), a, b_)
        codes = (np.asarray(z) & 63).astype(np.int32)
        want.append(float(bbit_logits(params, jnp.asarray(codes), cfg)[0, 0]))
    np.testing.assert_allclose(got, np.array(want), atol=1e-5)
    assert eng.compile_misses == 0     # precompiled lanes covered all
    eng.close()


def test_engine_survives_nnz_over_largest_bucket():
    """Regression: a document with nnz > the largest pad bucket used to
    get an ``idx`` wider than its mask, crashing the batcher thread
    inside the jitted scorer.  The bucket now grows to the next power
    of two and scoring stays consistent."""
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    cfg = BBitLinearConfig(k=8, b=4)
    params = init_bbit_linear(cfg, jax.random.key(1))
    eng = HashedClassifierEngine(params, cfg, seed=3, max_batch=4,
                                 max_wait_ms=5, precompile=False,
                                 nnz_buckets=(128, 32768),
                                 row_buckets=(1, 2, 4))
    rng = np.random.default_rng(0)
    big = np.unique(rng.integers(0, 1 << 28, size=40000))
    assert len(big) > 32768
    small = np.unique(rng.integers(0, 1 << 20, size=30))
    futs = [eng.submit(d) for d in (small, big, small)]
    vals = [float(f.result(timeout=120)) for f in futs]
    assert all(np.isfinite(v) for v in vals)
    # identical docs must score identically regardless of batch mates
    assert vals[0] == vals[2]
    eng.close()


def test_engine_validates_submissions():
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    cfg = BBitLinearConfig(k=8, b=4)
    params = init_bbit_linear(cfg, jax.random.key(0))
    eng = HashedClassifierEngine(params, cfg, precompile=False,
                                 nnz_buckets=(32,), row_buckets=(1,))
    with pytest.raises(TypeError, match="integer"):
        eng.submit(np.array([0.5, 1.5]))
    with pytest.raises(TypeError, match="1-D"):
        eng.submit(np.arange(4).reshape(2, 2))
    with pytest.raises(ValueError, match="negative"):
        eng.submit(np.array([3, -1]))
    # minwise has no empty-doc semantics → rejected at submit
    with pytest.raises(ValueError, match="empty document"):
        eng.submit(np.array([], dtype=np.int64))
    eng.close()


def test_empty_doc_semantics_by_scheme():
    """nnz=0 used to reach the scorer and produce scheme-dependent
    garbage.  Now: zero-coded OPH serves it through the all-empty-bins
    path (score == bias exactly); minwise and densified OPH reject."""
    from repro.models.linear import BBitLinearConfig, init_bbit_linear
    cfg = BBitLinearConfig(k=16, b=4)
    params = init_bbit_linear(cfg, jax.random.key(2))
    params = {"table": params["table"],
              "bias": jnp.asarray([0.375], jnp.float32)}
    empty = np.array([], dtype=np.int64)

    for scheme in ("minwise", "oph"):
        eng = HashedClassifierEngine(params, cfg, scheme=scheme,
                                     precompile=False,
                                     nnz_buckets=(32,), row_buckets=(1,))
        with pytest.raises(ValueError, match="empty document"):
            eng.submit(empty)
        eng.close()

    eng = HashedClassifierEngine(params, cfg, scheme="oph_zero",
                                 precompile=False,
                                 nnz_buckets=(32,), row_buckets=(1, 2))
    got = eng.submit(empty).result(timeout=60)
    bias = float(np.asarray(params["bias"])[0])
    assert float(got) == bias
    # and an empty doc next to a real one doesn't perturb either
    real = np.arange(1, 9, dtype=np.int64)
    alone = eng.score_docs([real])[0]
    futs = [eng.submit(real), eng.submit(empty)]
    pair = [f.result(timeout=60) for f in futs]
    np.testing.assert_allclose(float(pair[0]), float(alone), atol=1e-5)
    assert float(pair[1]) == bias
    eng.close()


def test_greedy_generate_consistency():
    """Generation via prefill+decode == argmax over forward_train."""
    from repro.configs.base import ArchConfig
    from repro.models.api import get_model_api
    from repro.models import transformer as T
    cfg = ArchConfig(name="g", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", attn_q_chunk=8, attn_kv_chunk=8)
    api = get_model_api(cfg)
    params = api.init_params(jax.random.key(3))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 64, size=(2, 6)).astype(np.int32)
    toks = greedy_generate(api, params, prompt, max_new=5, max_len=16)
    assert toks.shape == (2, 11)
    # reference: repeatedly run the full forward
    cur = prompt.copy()
    for _ in range(5):
        logits = T.forward_train(params, jnp.asarray(cur), cfg)
        nxt = np.argmax(np.asarray(logits[:, -1]), axis=-1)
        cur = np.concatenate([cur, nxt[:, None].astype(np.int32)], axis=1)
    assert np.array_equal(toks, cur)
