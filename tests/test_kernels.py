"""Per-kernel interpret-mode validation: shape/dtype sweeps + hypothesis
property tests against the ref.py jnp oracles."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.minhash import minhash_pallas
from repro.kernels.bbit_linear import (
    bbit_linear_fwd_pallas, bbit_linear_bwd_dw_pallas,
)
from repro.kernels.vw_sketch import vw_sketch_pallas

RNG = np.random.default_rng(0)


def _mk_minhash(n, m, k, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 1 << 30, size=(n, m)).astype(np.int32)
    nnz = rng.integers(1, m + 1, size=(n,)).astype(np.int32)
    a = (rng.integers(0, 1 << 32, size=k, dtype=np.uint64) | 1
         ).astype(np.uint32)
    b = rng.integers(0, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32)
    return (jnp.asarray(idx), jnp.asarray(nnz), jnp.asarray(a),
            jnp.asarray(b))


@pytest.mark.parametrize("n,m,k", [
    (1, 1, 1), (4, 16, 8), (10, 300, 50), (16, 1024, 200), (3, 7, 130),
    (9, 513, 129),
])
def test_minhash_kernel_exact(n, m, k):
    idx, nnz, a, b = _mk_minhash(n, m, k, seed=n * 1000 + m + k)
    got = minhash_pallas(idx, nnz, a, b, interpret=True)
    want = ref.minhash(idx, nnz, a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 12), m=st.integers(1, 64), k=st.integers(1, 40),
       bn=st.sampled_from([2, 8]), bk=st.sampled_from([8, 128]),
       bm=st.sampled_from([16, 256]))
def test_minhash_kernel_block_shape_sweep(n, m, k, bn, bk, bm):
    idx, nnz, a, b = _mk_minhash(n, m, k, seed=n + m * 7 + k * 13)
    got = minhash_pallas(idx, nnz, a, b, block_n=bn, block_k=bk,
                         block_m=bm, interpret=True)
    want = ref.minhash(idx, nnz, a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_minhash_kernel_matches_core_jnp():
    """Kernel ≡ the chunked jnp path used by CPU preprocessing."""
    from repro.core.minhash import minhash_jnp
    idx, nnz, a, b = _mk_minhash(6, 200, 70, seed=3)
    mask = jnp.arange(200)[None, :] < nnz[:, None]
    want = minhash_jnp(idx, mask, a, b, k_chunk=32, m_chunk=64)
    got = minhash_pallas(idx, nnz, a, b, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,k,b,c", [
    (16, 8, 2, 1), (64, 30, 4, 3), (100, 200, 8, 2), (32, 10, 12, 5),
    (1, 1, 1, 1),
])
def test_bbit_linear_fwd_bwd(n, k, b, c):
    rng = np.random.default_rng(n + k + b + c)
    v = 1 << b
    codes = jnp.asarray(rng.integers(0, v, size=(n, k)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(k, v, c)).astype(np.float32))
    got = bbit_linear_fwd_pallas(codes, w, interpret=True)
    want = ref.bbit_linear_fwd(codes, w)
    np.testing.assert_allclose(got, want, atol=1e-4)
    dout = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    got_dw = bbit_linear_bwd_dw_pallas(codes, dout, v, interpret=True)
    want_dw = ref.bbit_linear_bwd_dw(codes, dout, v)
    np.testing.assert_allclose(got_dw, want_dw, atol=1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_bbit_linear_weight_dtypes(dtype):
    rng = np.random.default_rng(5)
    codes = jnp.asarray(rng.integers(0, 16, size=(32, 20)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(20, 16, 2))).astype(dtype)
    got = bbit_linear_fwd_pallas(codes, w, interpret=True)
    want = ref.bbit_linear_fwd(codes, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2 if dtype == "bfloat16" else 1e-4)


def test_bbit_linear_custom_vjp_gradient():
    rng = np.random.default_rng(6)
    codes = jnp.asarray(rng.integers(0, 16, size=(24, 12)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(12, 16, 3)).astype(np.float32))

    def loss_k(w):
        return jnp.sum(jnp.tanh(ops.bbit_linear(codes, w)))

    def loss_r(w):
        return jnp.sum(jnp.tanh(ref.bbit_linear_fwd(codes, w)))

    g1 = jax.grad(loss_k)(w)
    g2 = jax.grad(loss_r)(w)
    np.testing.assert_allclose(g1, g2, atol=1e-4)


@pytest.mark.parametrize("n,m,buckets", [
    (8, 64, 32), (12, 300, 1024), (4, 50, 4096), (1, 1, 2),
])
def test_vw_sketch_kernel(n, m, buckets):
    rng = np.random.default_rng(n + m)
    idx = jnp.asarray(rng.integers(0, 1 << 30, size=(n, m)).astype(np.int32))
    val = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    nnz = jnp.asarray(rng.integers(1, m + 1, size=(n,)).astype(np.int32))
    got = vw_sketch_pallas(idx, val, nnz, buckets, seed=3, interpret=True)
    want = ref.vw_sketch(idx, val, nnz, buckets, seed=3)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_vw_sketch_matches_core_vw():
    """Kernel bucket/sign streams ≡ repro.core.vw (pow-2 m)."""
    from repro.core.vw import vw_hash_sparse
    rng = np.random.default_rng(9)
    idx = jnp.asarray(rng.integers(0, 1 << 30, size=(6, 40)).astype(np.int32))
    nnz = jnp.asarray(rng.integers(1, 41, size=(6,)).astype(np.int32))
    mask = jnp.arange(40)[None, :] < nnz[:, None]
    got = vw_sketch_pallas(idx, jnp.ones((6, 40), jnp.float32), nnz, 64,
                           seed=2, interpret=True)
    want = vw_hash_sparse(idx, mask, None, 64, seed=2)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_ops_dispatch_large_b_falls_back():
    """b=16 (V=65536) exceeds the kernel threshold → gather path."""
    rng = np.random.default_rng(10)
    codes = jnp.asarray(rng.integers(0, 1 << 16, size=(4, 6)
                                     ).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(6, 1 << 16, 1)).astype(np.float32))
    got = ops.bbit_linear(codes, w)
    want = ref.bbit_linear_fwd(codes, w)
    np.testing.assert_allclose(got, want, atol=1e-4)
