"""Fused serving hot path: fused-vs-reference bitwise parity sweeps,
steady-state no-recompile contract, multi-threaded stress, and replica
parallelism (subprocess on 2 fake devices + in-process CI-tier
variants, same convention as test_dp_streaming)."""
import threading

import numpy as np
import pytest

import jax

from conftest import run_in_subprocess

from repro.models.linear import BBitLinearConfig, init_bbit_linear
from repro.serving import HashedClassifierEngine


def _ragged_docs(rng, n, lo=1, hi=200):
    return [np.unique(rng.integers(0, 1 << 24,
                                   size=int(rng.integers(lo, hi))))
            for _ in range(n)]


@pytest.mark.parametrize("scheme", ["minwise", "oph", "oph_zero"])
@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_fused_scores_bit_identical_to_reference(scheme, b):
    """The tentpole parity contract: one fused encode_packed_jit →
    bbit_scores_packed dispatch produces BITWISE the same scores as
    the encode_jnp → bbit_logits two-step, over ragged nnz."""
    cfg = BBitLinearConfig(k=16, b=b)
    params = init_bbit_linear(cfg, jax.random.key(b))
    docs = _ragged_docs(np.random.default_rng(b), 9, hi=150)
    kw = dict(seed=7, scheme=scheme, precompile=False,
              nnz_buckets=(256,), row_buckets=(16,))
    fused = HashedClassifierEngine(params, cfg, fused=True, **kw)
    ref = HashedClassifierEngine(params, cfg, fused=False, **kw)
    a = fused.score_docs(docs)
    r = ref.score_docs(docs)
    assert a.dtype == r.dtype and a.shape == r.shape
    assert np.array_equal(a, r), f"fused drifted: {np.abs(a - r).max()}"
    fused.close()
    ref.close()


def test_fused_parity_non_byte_aligned_b():
    """b=6 exercises the general (non-byte-aligned) pack/unpack path."""
    cfg = BBitLinearConfig(k=16, b=6)
    params = init_bbit_linear(cfg, jax.random.key(0))
    docs = _ragged_docs(np.random.default_rng(3), 6, hi=100)
    kw = dict(seed=2, precompile=False, nnz_buckets=(128,),
              row_buckets=(8,))
    fused = HashedClassifierEngine(params, cfg, fused=True, **kw)
    ref = HashedClassifierEngine(params, cfg, fused=False, **kw)
    assert np.array_equal(fused.score_docs(docs), ref.score_docs(docs))
    fused.close()
    ref.close()


def test_steady_state_never_recompiles():
    """Precompiled lanes cover every (row, nnz) bucket combination:
    traffic inside the configured buckets must hit compiled code only."""
    cfg = BBitLinearConfig(k=16, b=8)
    params = init_bbit_linear(cfg, jax.random.key(1))
    eng = HashedClassifierEngine(params, cfg, seed=5, max_batch=4,
                                 max_wait_ms=1,
                                 nnz_buckets=(32, 128),
                                 row_buckets=(1, 2, 4))
    assert eng.precompile_seconds > 0
    rng = np.random.default_rng(0)
    futs = [eng.submit(np.unique(rng.integers(0, 1 << 20, size=s)))
            for s in (3, 30, 100, 5, 90, 17, 128, 1)]
    for f in futs:
        f.result(timeout=60)
    assert eng.compile_misses == 0
    assert eng.batcher.batches_run >= 2
    eng.close()


def test_concurrent_submitters_all_resolve_and_match_oracle():
    """Many threads × mixed doc sizes: every future resolves, and each
    score matches the single-request oracle regardless of batch mates
    or lane routing."""
    cfg = BBitLinearConfig(k=16, b=8)
    params = init_bbit_linear(cfg, jax.random.key(2))
    eng = HashedClassifierEngine(params, cfg, seed=9, max_batch=8,
                                 max_wait_ms=2,
                                 nnz_buckets=(32, 128, 512),
                                 row_buckets=(1, 2, 4, 8))
    rng = np.random.default_rng(42)
    docs = _ragged_docs(rng, 36, lo=1, hi=400)
    oracle = np.array([float(eng.score_docs([d])[0]) for d in docs])

    results = [None] * len(docs)
    errors = []

    def client(ids):
        try:
            futs = [(i, eng.submit(docs[i])) for i in ids]
            for i, f in futs:
                results[i] = float(f.result(timeout=120))
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client,
                                args=(range(t, len(docs), 6),))
               for t in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
        assert not t.is_alive()
    assert not errors
    assert all(r is not None for r in results)
    np.testing.assert_allclose(np.array(results), oracle, atol=1e-5)
    eng.close()


def test_replicas_round_robin_subprocess():
    """2 replicas on 2 fake devices: params device_put once per
    replica, batches round-robin across both, scores match the
    device-0 oracle."""
    run_in_subprocess("""
        import numpy as np, jax
        from repro.models.linear import BBitLinearConfig, init_bbit_linear
        from repro.serving import HashedClassifierEngine

        cfg = BBitLinearConfig(k=16, b=8)
        params = init_bbit_linear(cfg, jax.random.key(0))
        rng = np.random.default_rng(1)
        docs = [np.unique(rng.integers(0, 1 << 20,
                                       size=int(rng.integers(4, 60))))
                for _ in range(40)]
        eng = HashedClassifierEngine(
            params, cfg, seed=1, max_batch=4, max_wait_ms=2, replicas=2,
            nnz_buckets=(64,), row_buckets=(1, 2, 4))
        assert len(eng.devices) == 2
        futs = [eng.submit(d) for d in docs]
        got = np.array([float(f.result(timeout=120)) for f in futs])
        want = np.array([float(eng.score_docs([d], device_index=0)[0])
                         for d in docs])
        np.testing.assert_allclose(got, want, atol=1e-5)
        assert min(eng.device_batches) >= 1, eng.device_batches
        assert eng.compile_misses == 0
        eng.close()
    """, devices=2)


# ------------------------------------------------ in-process (CI tier) ----
needs_two = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (CI multi-device job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=2)")


@needs_two
def test_replicas_in_process():
    cfg = BBitLinearConfig(k=8, b=4)
    params = init_bbit_linear(cfg, jax.random.key(4))
    eng = HashedClassifierEngine(params, cfg, seed=3, max_batch=2,
                                 max_wait_ms=1, replicas=2,
                                 nnz_buckets=(32,), row_buckets=(1, 2))
    rng = np.random.default_rng(5)
    docs = [np.unique(rng.integers(0, 1 << 20, size=12))
            for _ in range(12)]
    futs = [eng.submit(d) for d in docs]
    got = np.array([float(f.result(timeout=120)) for f in futs])
    want = np.array([float(eng.score_docs([d], device_index=1)[0])
                     for d in docs])
    np.testing.assert_allclose(got, want, atol=1e-5)
    assert min(eng.device_batches) >= 1
    eng.close()
