"""Data pipeline: synthetic rcv1 construction, LibSVM IO, loaders."""
import os

import numpy as np
import pytest

from repro.core import resemblance
from repro.data import (
    SynthRcv1Config, generate_arrays, write_shards, read_shards,
    write_libsvm, read_libsvm, pad_rows, preprocess_and_save, load_hashed,
    preprocess_rows, HashedCodesLoader,
)


@pytest.fixture(scope="module")
def corpus():
    cfg = SynthRcv1Config(seed=7, max_pairs_per_doc=4000,
                          max_triples_per_doc=2000)
    return generate_arrays(150, cfg), cfg


def test_expansion_structure(corpus):
    (rows, labels), cfg = corpus
    lens = np.array([len(r) for r in rows])
    # heavy tail: mean well above median (paper Table 1: 3051 vs 12062)
    assert lens.mean() > 1.3 * np.median(lens)
    # expanded ids exceed the unigram space (pair/triple features exist)
    assert max(r.max() for r in rows) > cfg.vocab
    # deterministic regeneration
    rows2, labels2 = generate_arrays(150, SynthRcv1Config(
        seed=7, max_pairs_per_doc=4000, max_triples_per_doc=2000))
    assert all(np.array_equal(a, b) for a, b in zip(rows, rows2))
    assert np.array_equal(labels, labels2)


def test_resemblance_separability(corpus):
    (rows, labels), _ = corpus
    rng = np.random.default_rng(0)
    same, diff = [], []
    for _ in range(200):
        i, j = rng.integers(0, len(rows), 2)
        if i == j:
            continue
        r = resemblance(set(rows[i]), set(rows[j]))
        (same if labels[i] == labels[j] else diff).append(r)
    assert np.mean(same) > 2 * max(np.mean(diff), 1e-9)


def test_libsvm_roundtrip(tmp_path, corpus):
    (rows, labels), _ = corpus
    paths = write_shards(str(tmp_path), rows[:40], labels[:40], n_shards=3)
    r2, l2 = read_shards(paths)
    assert sorted(map(tuple, r2)) == sorted(map(tuple, rows[:40]))
    assert sorted(l2) == sorted(labels[:40])


def test_libsvm_values_roundtrip(tmp_path):
    p = str(tmp_path / "v.libsvm")
    rows = [np.array([1, 5, 9]), np.array([2])]
    vals = [np.array([0.5, 1.25, -2.0]), np.array([3.0])]
    write_libsvm(p, rows, [1, 0], values=vals)
    out = list(read_libsvm(p, with_values=True))
    assert np.array_equal(out[0][0], rows[0])
    assert np.allclose(out[0][2], vals[0])


def test_pad_rows_contiguous():
    idx, nnz = pad_rows([np.array([3, 1 << 33]), np.array([7, 8, 9])],
                        pad_to_multiple=4)
    assert idx.shape == (2, 4)
    assert nnz.tolist() == [2, 3]
    assert idx[0, 0] == 3 and idx[0, 1] == ((1 << 33) & ((1 << 31) - 1))


def test_hashed_dataset_roundtrip(tmp_path, corpus):
    (rows, labels), _ = corpus
    d = str(tmp_path / "h")
    preprocess_and_save(d, rows, labels, k=32, b=6, n_shards=2)
    codes, l2, meta = load_hashed(d)
    assert codes.shape == (len(rows), 32) and codes.max() < 64
    # hashing is deterministic given (family, seed)
    codes2 = preprocess_rows(rows, k=32, b=6)
    assert np.array_equal(codes, codes2)


def test_shard_writer_rejects_mixed_empty(tmp_path):
    """Regression: mixing empty=None and non-None appends on an
    oph_zero stream silently desynced .empty.npy rows from the codes."""
    from repro.data import HashedShardWriter
    w = HashedShardWriter(str(tmp_path / "w"), 16, 8, n_total=8)
    w.append(np.arange(2), np.zeros((2, 16), np.uint8),
             np.zeros(2, np.int32), np.zeros((2, 2), np.uint8))
    with pytest.raises(ValueError, match="inconsistent empty"):
        w.append(np.arange(2, 4), np.zeros((2, 16), np.uint8),
                 np.zeros(2, np.int32), None)
    # the reverse direction too
    w2 = HashedShardWriter(str(tmp_path / "w2"), 16, 8, n_total=8)
    w2.append(np.arange(2), np.zeros((2, 16), np.uint8),
              np.zeros(2, np.int32), None)
    with pytest.raises(ValueError, match="inconsistent empty"):
        w2.append(np.arange(2, 4), np.zeros((2, 16), np.uint8),
                  np.zeros(2, np.int32), np.zeros((2, 2), np.uint8))
    # and mismatched row counts are caught at append time
    with pytest.raises(ValueError, match="row mismatch"):
        w2.append(np.arange(3), np.zeros((2, 16), np.uint8),
                  np.zeros(2, np.int32))
    # a failed FIRST append must not commit the empty-mask mode: a
    # corrected retry without a mask is still a legitimate stream
    w3 = HashedShardWriter(str(tmp_path / "w3"), 16, 8, n_total=8)
    with pytest.raises(ValueError, match="row mismatch"):
        w3.append(np.arange(2), np.zeros((2, 16), np.uint8),
                  np.zeros(2, np.int32), np.zeros((3, 2), np.uint8))
    w3.append(np.arange(2), np.zeros((2, 16), np.uint8),
              np.zeros(2, np.int32), None)


def test_load_hashed_empty_archive(tmp_path):
    """Regression: a 0-shard archive used to raise a bare
    np.concatenate ValueError instead of a clear empty result."""
    d = str(tmp_path / "empty")
    preprocess_and_save(d, [], np.zeros((0,), np.int32), k=16, b=8)
    codes, labels, meta = load_hashed(d)
    assert codes.shape == (0, 16) and codes.dtype == np.uint16
    assert labels.shape == (0,) and meta["shards"] == 0


def test_loader_restart_and_sharding():
    codes = (np.arange(2000) % 251).astype(np.uint16).reshape(200, 10)
    y = np.arange(200, dtype=np.int32)
    full = list(HashedCodesLoader(codes, y, 16, seed=3).batches(0, epochs=2))
    resumed = list(HashedCodesLoader(codes, y, 16, seed=3).batches(9,
                                                                   epochs=2))
    for a, b in zip(full[9:], resumed):
        assert a[0] == b[0]
        assert np.array_equal(a[1], b[1]) and np.array_equal(a[2], b[2])
    # host sharding partitions each epoch's rows disjointly
    l0 = HashedCodesLoader(codes, y, 16, seed=3, shard_id=0, num_shards=2)
    l1 = HashedCodesLoader(codes, y, 16, seed=3, shard_id=1, num_shards=2)
    ids0 = {int(r[0]) for _, _, r in l0.batches(0, epochs=1)
            for r in [r]}  # labels are unique row ids
    rows0 = set()
    for _, _, lab in l0.batches(0, epochs=1):
        rows0.update(lab.tolist())
    rows1 = set()
    for _, _, lab in l1.batches(0, epochs=1):
        rows1.update(lab.tolist())
    assert not rows0 & rows1
    # straggler hedging covers the slow worker's rows (modulo at most
    # one drop-remainder batch of the merged stream)
    lb = HashedCodesLoader(codes, y, 16, seed=3, shard_id=0, num_shards=2,
                           backup_of=1)
    rows_b = set()
    for _, _, lab in lb.batches(0, epochs=1):
        rows_b.update(lab.tolist())
    assert rows0 <= rows_b
    assert len(rows1 - rows_b) < 16
