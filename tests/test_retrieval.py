"""Banded LSH over packed b-bit codes + the Hamming top-k kernel.

Covers the retrieval half of the dedup/retrieval subsystem: band-key
extraction straight from the packed bitstream (bit-exact vs the
unpacked reference, including non-byte-aligned b·r), the banded
inverted index's insert/query/delete lifecycle, and the
``hamming_topk`` op — Pallas vs XLA parity, dispatch-report presence,
and the loud ineligible-force fallback shared by every dispatched op.
"""
import numpy as np
import pytest

import repro.perf as perf
from repro.core.bbit import pack_codes, packed_width
from repro.kernels import ops
from repro.kernels.hamming import (hamming_distance_pallas,
                                   hamming_distance_xla)
from repro.retrieval import BandedLSHIndex
from repro.retrieval.bands import (band_geometry, band_keys_packed,
                                   band_keys_ref, band_signature)


@pytest.fixture(autouse=True)
def _clean_dispatch(monkeypatch):
    monkeypatch.delenv(perf.ENV_DISPATCH, raising=False)
    monkeypatch.delenv(perf.ENV_PROFILE, raising=False)
    perf.reset()
    yield
    perf.reset()


def _codes(n, k, b, seed=0):
    rng = np.random.default_rng(seed * 7919 + k * 31 + b)
    return rng.integers(0, 1 << b, size=(n, k)).astype(np.uint16)


# ---------------------------------------------------------------------------
# band keys


@pytest.mark.parametrize("b", [1, 2, 3, 4, 8, 12])
@pytest.mark.parametrize("r", [1, 2, 4])
def test_band_keys_packed_match_unpacked_reference(b, r):
    k = 24
    codes = _codes(17, k, b, seed=b * 10 + r)
    got = band_keys_packed(pack_codes(codes, b), k, b, r)
    want = band_keys_ref(codes, b, r)
    assert got.dtype == np.uint64
    assert got.shape == (17, k // r)
    np.testing.assert_array_equal(got, want)


def test_band_keys_unaligned_vs_whole_byte_paths():
    # r*b = 24 exercises the whole-byte fast path, r*b = 12 the
    # unaligned uint64 gather — same reference for both
    k, b = 16, 3
    codes = _codes(9, k, b)
    packed = pack_codes(codes, b)
    for r in (4, 8):
        np.testing.assert_array_equal(
            band_keys_packed(packed, k, b, r), band_keys_ref(codes, b, r))


def test_band_geometry_rejects_bad_shapes():
    assert band_geometry(16, 4, 4) == 4
    with pytest.raises(ValueError, match="divide"):
        band_geometry(16, 4, 3)
    with pytest.raises(ValueError, match="exceeds"):
        band_geometry(64, 8, 8)          # 64 band bits > 56
    with pytest.raises(ValueError, match=">= 1"):
        band_geometry(16, 4, 0)


def test_band_signature_is_prefix_of_band_keys():
    k, b, r = 16, 4, 2
    codes = _codes(3, k, b)
    packed = pack_codes(codes, b)
    keys = band_keys_packed(packed, k, b, r)
    sig = band_signature(packed[1], k, b, r, probe_bands=3)
    assert sig == tuple(int(x) for x in keys[1, :3])
    full = band_signature(packed[1], k, b, r)
    assert full == tuple(int(x) for x in keys[1])


# ---------------------------------------------------------------------------
# banded inverted index


def test_index_insert_query_delete_lifecycle():
    k, b, r = 16, 4, 2
    codes = _codes(40, k, b, seed=5)
    packed = pack_codes(codes, b)
    idx = BandedLSHIndex(k=k, b=b, rows_per_band=r)
    ids = [f"doc{i}" for i in range(40)]
    idx.insert(ids, packed)
    assert len(idx) == 40

    # an indexed row retrieves itself at rank 1, similarity exactly 1
    got_ids, sims = idx.query(packed[7], top_k=5)
    assert got_ids[0] == "doc7"
    assert sims[0] == pytest.approx(1.0)
    assert np.all(np.diff(sims) <= 1e-6)        # descending

    assert idx.delete(["doc7", "nope"]) == 1
    assert len(idx) == 39
    got_ids, _ = idx.query(packed[7], top_k=5)
    assert "doc7" not in got_ids

    st = idx.stats()
    assert st["entries"] == 39 and st["bands"] == k // r
    assert st["bytes_est"] > 0


def test_index_rejects_wrong_width():
    idx = BandedLSHIndex(k=16, b=4, rows_per_band=2)
    with pytest.raises(ValueError, match="width"):
        idx.query(np.zeros(3, np.uint8))


def test_index_near_duplicate_lands_in_topk():
    # flip one code of a row: differs in <= b bits of k*b, still
    # collides in most bands and ranks directly under the exact copy
    k, b, r = 32, 4, 2
    codes = _codes(64, k, b, seed=9)
    near = codes[3].copy()
    near[0] ^= 1
    idx = BandedLSHIndex(k=k, b=b, rows_per_band=r)
    idx.insert(list(range(64)), pack_codes(codes, b))
    ids, sims = idx.query(pack_codes(near[None, :], b)[0], top_k=5)
    assert 3 in ids
    assert sims[list(ids).index(3)] >= 1.0 - (b / (k * b)) - 1e-6


# ---------------------------------------------------------------------------
# hamming_topk kernel + dispatch


@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_hamming_pallas_matches_xla(b):
    k = 32
    codes = _codes(50, k, b, seed=b)
    packed = pack_codes(codes, b)
    q = packed[11]
    d_pal = np.asarray(hamming_distance_pallas(q, packed, interpret=True))
    d_xla = np.asarray(hamming_distance_xla(q, packed))
    np.testing.assert_array_equal(d_pal, d_xla)
    assert d_pal[11] == 0


def test_hamming_topk_matches_brute_force():
    k, b = 32, 4
    codes = _codes(60, k, b, seed=2)
    packed = pack_codes(codes, b)
    q = packed[0]
    idx, sims = ops.hamming_topk(q, packed, k=k, bits=b, topk=10)
    idx, sims = np.asarray(idx), np.asarray(sims)
    # brute force over unpacked codes' bitstream
    dist = np.asarray(hamming_distance_xla(q, packed))
    order = np.argsort(dist, kind="stable")[:10]
    np.testing.assert_array_equal(np.sort(dist[idx]), dist[order])
    np.testing.assert_allclose(sims, 1.0 - dist[idx] / (k * b), rtol=1e-6)
    assert idx[0] == 0 and sims[0] == pytest.approx(1.0)


def test_hamming_topk_in_dispatch_report_with_loud_fallback():
    shape = {"b": 8, "k": 32, "rows": 50, "width": 32}
    assert perf.choose("hamming_topk", shape) in ("pallas", "xla")
    rep = perf.dispatch_report()
    assert any(key.startswith("hamming_topk") for key in rep["choices"])
    # forcing the Pallas arm on an unpacked-ineligible b is ignored
    # loudly (counted), not crashed into — same contract as encode
    before = rep["ineligible_overrides"]
    got = perf.choose("hamming_topk",
                      {"b": 3, "k": 32, "rows": 50, "width": 12},
                      impl="pallas")
    assert got == "xla"
    assert perf.dispatch_report()["ineligible_overrides"] == before + 1


def test_index_recall_tracks_brute_force_resemblance():
    # queries are token-space near-duplicates; the banded index must
    # put the perturbed source in the top-3 of nearly every query
    from repro.core.schemes import make_scheme
    from repro.data.packing import pad_rows

    rng = np.random.default_rng(4)
    k, b, r = 64, 4, 2
    docs = [np.unique(rng.choice(1 << 20, size=200, replace=False))
            for _ in range(48)]
    scheme = make_scheme("oph", k=k, seed=3)
    idx_rows, nnz = pad_rows(docs, pad_to_multiple=1)
    packed, _ = scheme.encode_packed_numpy(idx_rows, nnz, b)
    index = BandedLSHIndex(k=k, b=b, rows_per_band=r)
    index.insert(list(range(len(docs))), packed)

    found = 0
    n_q = 16
    for qi in range(n_q):
        keep = rng.random(docs[qi].size) > 0.08      # ~8% token churn
        q_doc = docs[qi][keep]
        qi_rows, q_nnz = pad_rows([q_doc], pad_to_multiple=1)
        q_packed, _ = scheme.encode_packed_numpy(qi_rows, q_nnz, b)
        ids, _ = index.query(q_packed[0], top_k=3)
        found += qi in ids
    assert found >= n_q - 2
