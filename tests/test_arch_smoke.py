"""Per-assigned-architecture smoke tests (assignment requirement).

Each of the 10 archs instantiates a REDUCED config of the same family
and runs one forward/train step on CPU, asserting output shapes and
no NaNs; decode parity is additionally checked for one arch per family.
The FULL configs are exercised by the dry-run only.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.archs import ALL_ARCHS
from repro.configs.base import get_config
from repro.launch.smoke_configs import reduced_config
from repro.models.api import get_model_api


def _batch_for(api, batch, seq, rng):
    shapes = api.batch_shapes(batch, seq)
    out = {}
    for k, v in shapes.items():
        if v.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, api.cfg.vocab, v.shape).astype(np.int32))
        else:
            out[k] = jnp.asarray(
                rng.normal(size=v.shape).astype(np.float32)).astype(v.dtype)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    full = get_config(arch)
    cfg = reduced_config(full)
    # family/topology preserved by the reduction
    assert cfg.family == full.family
    assert cfg.is_moe == full.is_moe
    assert cfg.rope_variant == full.rope_variant
    api = get_model_api(cfg)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = api.init_params(jax.random.key(0))
    batch = _batch_for(api, 2, 16, rng)

    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, None))(params)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch

    # one optimizer step decreases nothing structurally (shape check)
    from repro.launch.steps import make_optimizer_for
    from repro.train.steps import TrainState
    opt = make_optimizer_for(cfg)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    new_p, new_o = opt.update(grads, state.opt_state, params, state.step)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = reduced_config(get_config(arch))
    api = get_model_api(cfg)
    rng = np.random.default_rng(1)
    params = api.init_params(jax.random.key(1))
    batch = _batch_for(api, 2, 12, rng)
    pre_batch = {k: v for k, v in batch.items() if k != "targets"}
    logits, cache = api.prefill(params, pre_batch)
    assert logits.shape == (2, cfg.vocab)
    assert not np.isnan(np.asarray(logits, dtype=np.float32)).any()
    # decode one token against the prefix cache (shape-level contract)
    tok = {"token": batch["tokens"][:, :1]}
    full_cache = api.init_cache(2, 16)

    def grow(full_leaf, pre_leaf):
        if full_leaf.shape == pre_leaf.shape:
            return pre_leaf.astype(full_leaf.dtype)
        axes = [i for i, (a, c) in enumerate(
            zip(full_leaf.shape, pre_leaf.shape)) if a != c]
        return jax.lax.dynamic_update_slice_in_dim(
            full_leaf, pre_leaf.astype(full_leaf.dtype), 0, axis=axes[0])

    cache = jax.tree.map(grow, full_cache, cache)
    lg, new_cache = api.decode_step(params, tok, cache,
                                    jnp.asarray(12, jnp.int32))
    assert lg.shape == (2, cfg.vocab)
    assert not np.isnan(np.asarray(lg, dtype=np.float32)).any()


def test_full_configs_match_assignment_table():
    """The exact values from the assignment, verbatim."""
    rows = {
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for arch, (nl, d, h, kv, ff, v) in rows.items():
        cfg = get_config(arch)
        assert cfg.n_layers == nl, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    # MoE specifics
    k = get_config("kimi-k2-1t-a32b")
    assert k.moe_experts == 384 and k.moe_top_k == 8
    g = get_config("granite-moe-3b-a800m")
    assert g.moe_experts == 40 and g.moe_top_k == 8
    z = get_config("zamba2-7b")
    assert z.ssm_state == 64
    # long_500k policy: only sub-quadratic archs run it
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if arch in ("zamba2-7b", "xlstm-350m"):
            assert "long_500k" not in cfg.skip_shapes
        else:
            assert "long_500k" in cfg.skip_shapes


def test_param_counts_near_nameplate():
    """n_params() within tolerance of the arch's nameplate size."""
    expect = {
        "kimi-k2-1t-a32b": (1.0e12, 0.15),
        "deepseek-67b": (67e9, 0.1),
        "granite-moe-3b-a800m": (3e9, 0.25),
        "chatglm3-6b": (6e9, 0.25),
        "yi-9b": (9e9, 0.15),
        "internlm2-1.8b": (1.8e9, 0.15),
        "zamba2-7b": (7e9, 0.3),
        "xlstm-350m": (350e6, 0.5),
        "qwen2-vl-2b": (2e9, 0.25),
        "seamless-m4t-large-v2": (2.3e9, 0.4),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).n_params()
        assert abs(n - target) / target < tol, (arch, n, target)
