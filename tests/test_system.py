"""End-to-end behaviour tests for the paper's system."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _run_train(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=timeout, env=env)
    return proc


def test_linear_pipeline_end_to_end(tmp_path):
    """generate → hash (one-time) → train → checkpoint → ≥90% test acc."""
    proc = _run_train(["--mode", "linear", "--workdir", str(tmp_path),
                       "--n-docs", "600", "--k", "64", "--b", "8",
                       "--steps", "60", "--batch-size", "64"])
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "test_acc=" in proc.stdout
    acc = float(proc.stdout.split("test_acc=")[1].split()[0])
    assert acc > 0.9, proc.stdout
    # preprocessing is cached: a second run skips hashing
    proc2 = _run_train(["--mode", "linear", "--workdir", str(tmp_path),
                        "--n-docs", "600", "--k", "64", "--b", "8",
                        "--steps", "60", "--batch-size", "64"])
    assert proc2.returncode == 0
    assert "preprocessed" not in proc2.stdout     # reused (§6 economics)


def test_failure_injection_and_resume(tmp_path):
    """Crash mid-training → relaunch → resumes from checkpoint."""
    proc = _run_train(["--mode", "linear", "--workdir", str(tmp_path),
                       "--n-docs", "400", "--k", "32", "--b", "6",
                       "--steps", "40", "--batch-size", "64",
                       "--ckpt-every", "10", "--fail-at", "25"])
    assert proc.returncode != 0       # injected crash
    proc2 = _run_train(["--mode", "linear", "--workdir", str(tmp_path),
                        "--n-docs", "400", "--k", "32", "--b", "6",
                        "--steps", "40", "--batch-size", "64",
                        "--ckpt-every", "10"])
    assert proc2.returncode == 0, proc2.stderr[-3000:]
    assert "resumed from step 20" in proc2.stdout


def test_lm_training_loss_decreases(tmp_path):
    proc = _run_train(["--mode", "lm", "--workdir", str(tmp_path),
                       "--arch", "internlm2-1.8b", "--steps", "30",
                       "--batch-size", "8", "--seq-len", "64"])
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if "loss" in l][-1]
    first = float(line.split("loss ")[1].split(" ->")[0])
    last = float(line.split("-> ")[1].split()[0])
    assert last < first - 0.5, line
