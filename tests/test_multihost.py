"""Multi-host elastic streaming training: the crash-equivalence matrix
over REAL ``jax.distributed`` localhost gangs, plus the PR's satellite
coverage (per-rank backoff, straggler escalation, EF gradient
compression, offline archive fsck).

Every gang test spawns ``procs`` actual OS processes (``train.worker``
via ``run_multiprocess_supervised``), each its own jax runtime joined
through a localhost coordinator with gloo CPU collectives — not fake
devices in one process.  The equivalence claims lean on the
sum-then-scale reduction in ``train.data_parallel``: for power-of-two
realizations of the same logical schedule the update is bitwise
invariant, so a 2-process×1-device gang, a 1-process×1-device fold-2
run, and any kill/resume splice of the two must produce IDENTICAL
parameters.
"""
import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.ckpt.elastic import process_fold
from repro.data.hashed_dataset import preprocess_and_save
from repro.ft.faults import FaultEvent, FaultPlan
from repro.ft.retry import BackoffPolicy
from repro.ft.watchdog import StepWatchdog
from repro.models.linear import BBitLinearConfig
from repro.distributed.runtime import (
    ProcessRuntime, heartbeat, init_runtime, process_slot_range,
    read_heartbeats,
)
from repro.train.streaming import fit_streaming
from repro.train.supervisor import (
    RestartPolicy, run_multiprocess_supervised, run_supervised,
)

K, B, N_DOCS, N_SHARDS, BATCH = 64, 8, 400, 8, 32
CFG = BBitLinearConfig(k=K, b=B)
# the shared hyperparameters of every run in the equivalence matrix
FIT = dict(epochs=1, batch_size=BATCH, data_parallel=2, elastic=True,
           prefetch=0, seed=0)
POLICY = RestartPolicy(max_restarts=3,
                       backoff=BackoffPolicy(base_s=0.05, cap_s=0.5))


def _make_archive(root, *, signal=False, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, size=N_DOCS)
    rows = []
    for y in labels:
        lo = int(y) * 500 if signal else 0
        width = 500 if signal else 1000
        rows.append(rng.integers(lo, lo + width,
                                 size=int(rng.integers(5, 30))).tolist())
    preprocess_and_save(root, rows, labels, k=K, b=B, scheme="oph",
                        n_shards=N_SHARDS)
    return root


def _leaves(tree):
    return [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(tree)]


def _assert_matches_baseline(params_npz_path, baseline):
    got = np.load(params_npz_path)
    for i, leaf in enumerate(baseline["params"]):
        assert np.array_equal(got[f"p{i}"], leaf), f"params leaf {i}"
    for i, leaf in enumerate(baseline["avg"]):
        assert np.array_equal(got[f"a{i}"], leaf), f"avg leaf {i}"


@pytest.fixture(scope="module")
def archive(tmp_path_factory):
    return _make_archive(str(tmp_path_factory.mktemp("mh_archive")))


@pytest.fixture(scope="module")
def baseline(archive):
    """The single-process ground truth: 1 device folding both logical
    slots (the elastic path every gang topology must match bitwise)."""
    res = fit_streaming(archive, CFG, **FIT)
    return {"params": _leaves(res.params), "avg": _leaves(res.avg_params),
            "n_steps": res.n_steps, "examples_seen": res.examples_seen,
            "shards_processed": res.shards_processed,
            "progressive_acc": res.progressive_acc}


def _gang(archive, run_dir, *, procs=2, local_devices=1, fault=None,
          **overrides):
    kw = dict(FIT)
    kw.update(overrides)
    return run_multiprocess_supervised(
        archive, CFG, procs=procs, run_dir=run_dir,
        local_devices=local_devices, policy=POLICY,
        fault_spec=fault.to_spec() if fault else None,
        ckpt_dir=os.path.join(run_dir, "ckpt"), **kw)


# ------------------------------------------------------- unit layer ----

def test_process_slot_range_contiguous_and_even():
    assert process_slot_range(8, 2, 0) == (0, 4)
    assert process_slot_range(8, 2, 1) == (4, 8)
    assert process_slot_range(2, 1, 0) == (0, 2)
    with pytest.raises(ValueError, match="evenly"):
        process_slot_range(3, 2, 0)


def test_process_fold_three_levels():
    # 8 logical slots over 2 procs x 2 local devices: 4-slot blocks,
    # 2 mesh devices per proc, fold 2 on each
    assert process_fold(8, 2, 2) == (4, 2, 4)
    # non-elastic refuses folding
    with pytest.raises(ValueError, match="elastic"):
        process_fold(8, 2, 2, elastic=False)
    assert process_fold(2, 2, 1, elastic=False) == (1, 1, 2)
    with pytest.raises(ValueError, match="evenly"):
        process_fold(3, 2, 1)


def test_init_runtime_validation():
    with pytest.raises(ValueError, match="coordinator"):
        init_runtime(procs=2, rank=0, coordinator=None)
    with pytest.raises(ValueError, match="rank"):
        init_runtime(procs=2, rank=5, coordinator="127.0.0.1:1")


def test_heartbeats_roundtrip(tmp_path):
    rt0 = ProcessRuntime(procs=2, rank=0, run_dir=str(tmp_path))
    rt1 = ProcessRuntime(procs=2, rank=1, run_dir=str(tmp_path))
    heartbeat(rt0, step=7, shards_done=3)
    heartbeat(rt1, step=7, shards_done=4, phase="ckpt")
    hb = read_heartbeats(str(tmp_path))
    assert set(hb) == {0, 1}
    assert hb[0]["shards_done"] == 3 and hb[1]["phase"] == "ckpt"
    assert read_heartbeats(str(tmp_path / "missing")) == {}


def test_backoff_for_rank_breaks_lockstep():
    base = BackoffPolicy(base_s=0.5, cap_s=10.0, jitter_frac=0.5, seed=3)
    # deterministic per (seed, rank) ...
    assert (base.for_rank(1).delay_s(0) == base.for_rank(1).delay_s(0))
    # ... shape-preserving ...
    assert base.for_rank(4).cap_s == base.cap_s
    # ... de-correlated: distinct ranks get distinct jitter streams
    delays = {base.for_rank(r).delay_s(2) for r in range(6)}
    assert len(delays) == 6
    # rank seeds come from SeedSequence, not seed+rank: neighbouring
    # base seeds must not alias neighbouring ranks
    assert (BackoffPolicy(seed=4).for_rank(0).seed
            != BackoffPolicy(seed=3).for_rank(1).seed)


# -------------------------------------------- crash-equivalence matrix --

def test_gang_matches_single_process(archive, baseline, tmp_path):
    run = _gang(archive, str(tmp_path / "gang"))
    assert run.restarts == 0
    rec = run.result
    assert rec["n_steps"] == baseline["n_steps"]
    assert rec["examples_seen"] == baseline["examples_seen"]
    assert rec["shards_processed"] == baseline["shards_processed"]
    assert rec["progressive_acc"] == pytest.approx(
        baseline["progressive_acc"])
    assert rec["lineage"][-1]["procs"] == 2
    # both ranks trained the identical replicated model, and it is
    # bitwise the single-process fold-2 model
    _assert_matches_baseline(run.params_paths[0], baseline)
    _assert_matches_baseline(run.params_paths[1], baseline)
    # boundary heartbeats landed for both ranks
    hb = read_heartbeats(str(tmp_path / "gang"))
    assert set(hb) == {0, 1}


def test_gang_worker_kill9_recovers_bitwise(archive, baseline, tmp_path):
    # kill -9 the NON-leader mid-epoch: a real SIGKILL, no cleanup
    plan = FaultPlan([FaultEvent(site="proc_kill", step=5, rank=1,
                                 times=1)])
    run = _gang(archive, str(tmp_path / "gang"), fault=plan)
    assert run.restarts == 1
    assert "signal 9" in run.crashes[0].error
    assert run.result["n_steps"] == baseline["n_steps"]
    _assert_matches_baseline(run.params_paths[0], baseline)
    _assert_matches_baseline(run.params_paths[1], baseline)


def test_gang_leader_killed_during_manifest_commit(archive, baseline,
                                                   tmp_path):
    # rank 0 dies AFTER all rank payloads are staged, BEFORE the step
    # manifest commits — the torn-coordination window; the previous
    # committed step must stay authoritative and the replay must splice
    # bit-exactly
    plan = FaultPlan([FaultEvent(site="manifest_write", at_save=4,
                                 rank=0, times=1)])
    run = _gang(archive, str(tmp_path / "gang"), fault=plan)
    assert run.restarts >= 1
    assert run.result["shards_processed"] == baseline["shards_processed"]
    _assert_matches_baseline(run.params_paths[0], baseline)


def test_gang_torn_rank_payload_quarantined(archive, baseline, tmp_path):
    # rank 1's payload is torn AFTER its rename (CRCs recorded from
    # memory): the commit succeeds, the respawned rank 1 must detect
    # the tear on restore, quarantine its OWN payload and fall back to
    # rank 0's replicated copy
    plan = FaultPlan([FaultEvent(site="ckpt_write", at_save=2, rank=1,
                                 times=1)])
    run_dir = str(tmp_path / "gang")
    run = _gang(archive, run_dir, fault=plan)
    assert run.restarts == 1
    _assert_matches_baseline(run.params_paths[0], baseline)
    _assert_matches_baseline(run.params_paths[1], baseline)
    # the injected tear actually fired on attempt 0 ...
    logs = [os.path.join(run_dir, f) for f in os.listdir(run_dir)
            if f.startswith("log_rank1")]
    text = "".join(open(p, errors="replace").read() for p in logs)
    assert "injected torn rank-1 checkpoint write" in text
    # ... and the respawned rank 1 quarantined its OWN payload before
    # falling back to rank 0's replicated copy.  (The quarantined
    # directory itself is later removed with its step by keep_last
    # pruning, so the restore-time log is the durable evidence.)
    assert "quarantining" in text and "rank_00001.quarantined" in text


def test_elastic_gang_to_single_process(archive, baseline, tmp_path):
    # 2-process gang checkpoints mid-run; a 1-process run adopts the
    # coordinated checkpoint and finishes — N -> M (M < N) elastic
    # process resume, bit-identical with exact counter continuity
    run_dir = str(tmp_path / "gang")
    part = _gang(archive, run_dir, stop_after_shards=4)
    assert part.result["completed"] is False
    assert part.result["shards_processed"] == 4
    res = fit_streaming(archive, CFG,
                        ckpt_dir=os.path.join(run_dir, "ckpt"), **FIT)
    assert res.completed and res.shards_processed == N_SHARDS
    assert res.examples_seen == baseline["examples_seen"]
    assert res.n_steps == baseline["n_steps"]
    for got, want in zip(_leaves(res.params), baseline["params"]):
        assert np.array_equal(got, want)
    for got, want in zip(_leaves(res.avg_params), baseline["avg"]):
        assert np.array_equal(got, want)
    # the lineage names both realizations, oldest first
    procs_seen = [r["procs"] for r in res.topology_lineage]
    assert procs_seen == [2, 1]
    # a non-elastic resume across gang sizes must refuse loudly
    with pytest.raises(ValueError, match="elastic=True"):
        fit_streaming(archive, CFG,
                      ckpt_dir=os.path.join(run_dir, "ckpt"),
                      **{**FIT, "elastic": False})


def test_elastic_single_process_to_gang(archive, baseline, tmp_path):
    # the reverse splice: a single-process run checkpoints (plain
    # layout) mid-run; a 2-process gang adopts it and finishes —
    # 1 -> N elastic process resume over the SAME checkpoint directory
    run_dir = str(tmp_path / "gang")
    ckpt_dir = os.path.join(run_dir, "ckpt")
    part = fit_streaming(archive, CFG, ckpt_dir=ckpt_dir,
                         stop_after_shards=4, **FIT)
    assert part.completed is False and part.shards_processed == 4
    run = _gang(archive, run_dir)
    rec = run.result
    assert rec["completed"] and rec["shards_processed"] == N_SHARDS
    assert rec["n_steps"] == baseline["n_steps"]
    assert rec["examples_seen"] == baseline["examples_seen"]
    _assert_matches_baseline(run.params_paths[0], baseline)
    procs_seen = [r["procs"] for r in rec["lineage"]]
    assert procs_seen == [1, 2]


def test_gang_two_by_two_deterministic(archive, tmp_path):
    # 2 procs x 2 fake devices: a 4-way reduction is not bitwise equal
    # to the 2-way baseline (float add is non-associative across a
    # different reduction tree), so THIS topology's claim is
    # determinism within the fixed topology + rank agreement
    r1 = _gang(archive, str(tmp_path / "g1"), local_devices=2,
               data_parallel=4)
    r2 = _gang(archive, str(tmp_path / "g2"), local_devices=2,
               data_parallel=4)
    a0, a1 = np.load(r1.params_paths[0]), np.load(r1.params_paths[1])
    b0 = np.load(r2.params_paths[0])
    for key in a0.files:
        assert np.array_equal(a0[key], a1[key])   # ranks agree
        assert np.array_equal(a0[key], b0[key])   # runs agree
    assert r1.result["lineage"][-1] == {
        "logical": 4, "physical": 4, "procs": 2, "devices": 4,
        "from_step": 0}


# ------------------------------------------------------- satellites ----

def test_straggler_escalation_counted(archive, tmp_path):
    # two consecutive injected 0.3s steps against a ~ms median must
    # escalate; the counter surfaces on SupervisedRun
    from repro.ft import faults

    plan = FaultPlan([
        FaultEvent(site="slow_step", step=s, delay_s=0.3, times=1)
        for s in (10, 11)])
    wd = StepWatchdog(threshold=3.0, window=16, escalate_after=2)
    with faults.arm(plan):
        sup = run_supervised(
            archive, CFG, policy=POLICY, watchdog=wd,
            ckpt_dir=str(tmp_path / "ckpt"),
            **{**FIT, "epochs": 2})
    assert sup.result.completed
    assert sup.straggler_escalations >= 1
    assert sup.restarts == 0


def test_grad_compress_parity_and_off_bitwise(tmp_path):
    # a separable corpus (class-disjoint token ranges): the exact run
    # learns it, and the int8 EF-compressed all-reduce must track it
    root = _make_archive(str(tmp_path / "sig"), signal=True, seed=1)
    exact = fit_streaming(root, CFG, **{**FIT, "epochs": 2})
    off = fit_streaming(root, CFG, grad_compress=None,
                        **{**FIT, "epochs": 2})
    # grad_compress=None IS the exact path, bitwise
    for got, want in zip(_leaves(off.params), _leaves(exact.params)):
        assert np.array_equal(got, want)
    comp = fit_streaming(root, CFG, grad_compress=8,
                         **{**FIT, "epochs": 2})
    assert exact.progressive_acc > 0.8
    assert comp.progressive_acc >= exact.progressive_acc - 0.05
    # engaged (different numerics) but deterministic
    assert not all(
        np.array_equal(a, b) for a, b in
        zip(_leaves(comp.params), _leaves(exact.params)))
    comp2 = fit_streaming(root, CFG, grad_compress=8,
                          **{**FIT, "epochs": 2})
    for got, want in zip(_leaves(comp2.params), _leaves(comp.params)):
        assert np.array_equal(got, want)
    # compression without a gradient all-reduce is a config error
    with pytest.raises(ValueError, match="data_parallel"):
        fit_streaming(root, CFG, grad_compress=8, epochs=1,
                      batch_size=BATCH)
    with pytest.raises(ValueError, match="grad_compress"):
        fit_streaming(root, CFG, grad_compress=4, **FIT)


def test_fsck_clean_corrupt_quarantine(tmp_path, capsys):
    from repro.launch.fsck import fsck_archive, main

    root = _make_archive(str(tmp_path / "arch"), seed=2)
    assert main([root]) == 0
    report = fsck_archive(root)
    assert report["verified"] == N_SHARDS and not report["corrupt"]

    # flip bytes deep inside shard 3's packed codes
    victim = os.path.join(root, "hashed_00003.codes.npy")
    with open(victim, "r+b") as f:
        f.seek(-16, os.SEEK_END)
        f.write(b"\xff" * 8)
    assert main([root]) == 1
    report = fsck_archive(root)
    assert 3 in report["corrupt"] and report["verified"] == N_SHARDS - 1

    report = fsck_archive(root, quarantine=True)
    assert report["quarantined"][3]
    assert not os.path.exists(victim)
    assert all(os.path.exists(p) for p in report["quarantined"][3])
    # a directory without meta.json is not an archive
    assert main([str(tmp_path / "nothing")]) == 2


def test_multiprocess_requires_dp_and_ckpt(archive, tmp_path):
    with pytest.raises(ValueError, match="ckpt_dir"):
        run_multiprocess_supervised(archive, CFG, procs=2,
                                    run_dir=str(tmp_path), **FIT)
    rt = ProcessRuntime(procs=2, rank=0)
    with pytest.raises(ValueError, match="data_parallel"):
        fit_streaming(archive, CFG, runtime=rt, epochs=1,
                      batch_size=BATCH)
