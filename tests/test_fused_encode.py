"""Fused encode pipeline: the hash→b-bit→pack kernels must be
bit-identical to the unfused reference (bbit_codes ∘ minhash/oph +
pack_codes), across b ∈ {1,2,4,8}, ragged nnz (empty rows included),
k that is not a lane multiple, and oph_zero empty-bin masks; plus the
streaming writer / loader / iterator built on top of them."""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.bbit import (
    pack_codes,
    pack_codes_jnp,
    pack_mask_jnp,
    packed_width,
    unpack_codes,
)
from repro.core.oph import (
    OPH_EMPTY_CODE,
    OPHHash,
    densify_rotation_numpy,
    oph_bin_minima_numpy,
)
from repro.core.schemes import make_scheme
from repro.data.packing import bucket_width, pad_rows
from repro.kernels import ref
from repro.kernels.fused_encode import minhash_pack_pallas, oph_pack_pallas

B_FUSED = (1, 2, 4, 8)


def _mk_minwise(n, m, k, seed):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 1 << 30, size=(n, m)).astype(np.int32)
    nnz = rng.integers(0, m + 1, size=(n,)).astype(np.int32)  # ragged, 0 ok
    a = (rng.integers(0, 1 << 32, size=k, dtype=np.uint64) | 1
         ).astype(np.uint32)
    b = rng.integers(0, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32)
    return idx, nnz, a, b


def _ref_minwise_packed(idx, nnz, a, b, bits):
    z = np.asarray(ref.minhash(jnp.asarray(idx), jnp.asarray(nnz),
                               jnp.asarray(a), jnp.asarray(b)))
    return pack_codes((z & ((1 << bits) - 1)).astype(np.uint16), bits)


# ---------------------------------------------------------------------------
# Packers: device twins are bit-exact against the numpy reference.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b", [1, 2, 3, 4, 6, 8, 12, 16])
def test_pack_codes_jnp_matches_numpy(b):
    rng = np.random.default_rng(b)
    codes = rng.integers(0, 1 << b, size=(7, 37)).astype(np.uint16)
    got = np.asarray(pack_codes_jnp(jnp.asarray(codes), b))
    want = pack_codes(codes, b)
    assert np.array_equal(got, want)
    assert got.shape[1] == packed_width(37, b)
    assert np.array_equal(unpack_codes(got, 37, b), codes)


def test_pack_mask_jnp_matches_packbits():
    rng = np.random.default_rng(0)
    mask = rng.random((6, 43)) < 0.3
    assert np.array_equal(np.asarray(pack_mask_jnp(jnp.asarray(mask))),
                          np.packbits(mask, axis=1))


# ---------------------------------------------------------------------------
# Fused minwise kernel ≡ pack_codes ∘ bbit_codes ∘ minhash.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,k", [
    (1, 1, 1), (4, 16, 8), (3, 40, 33),      # k not a multiple of 8
    (6, 300, 130), (2, 9, 7), (5, 64, 129),  # k not a lane multiple
])
@pytest.mark.parametrize("bits", B_FUSED)
def test_fused_minwise_bit_identical(n, m, k, bits):
    idx, nnz, a, b = _mk_minwise(n, m, k, seed=n * 100 + m + k + bits)
    got = minhash_pack_pallas(jnp.asarray(idx), jnp.asarray(nnz),
                              jnp.asarray(a), jnp.asarray(b),
                              bits=bits, interpret=True)
    want = _ref_minwise_packed(idx, nnz, a, b, bits)
    assert got.shape == (n, packed_width(k, bits))
    assert np.array_equal(np.asarray(got), want)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 8), m=st.integers(1, 48), k=st.integers(1, 40),
       bits=st.sampled_from(B_FUSED), bn=st.sampled_from([2, 8]),
       bm=st.sampled_from([16, 256]))
def test_fused_minwise_property(n, m, k, bits, bn, bm):
    idx, nnz, a, b = _mk_minwise(n, m, k, seed=n + m * 5 + k * 11 + bits)
    got = minhash_pack_pallas(jnp.asarray(idx), jnp.asarray(nnz),
                              jnp.asarray(a), jnp.asarray(b), bits=bits,
                              block_n=bn, block_m=bm, interpret=True)
    want = _ref_minwise_packed(idx, nnz, a, b, bits)
    assert np.array_equal(np.asarray(got), want)


def test_fused_rejects_straddling_b():
    idx, nnz, a, b = _mk_minwise(2, 4, 4, seed=0)
    with pytest.raises(ValueError):
        minhash_pack_pallas(jnp.asarray(idx), jnp.asarray(nnz),
                            jnp.asarray(a), jnp.asarray(b), bits=6,
                            interpret=True)


# ---------------------------------------------------------------------------
# Fused OPH kernel ≡ pack_codes ∘ (densify | zero-code) ∘ bin minima.
# ---------------------------------------------------------------------------
def _ref_oph_packed(idx, nnz, fam, bits, densify):
    mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
    v, e = oph_bin_minima_numpy(idx, mask, fam)
    if densify:
        dv, _ = densify_rotation_numpy(v, e)
        codes = (dv & ((1 << bits) - 1)).astype(np.uint16)
    else:
        codes = np.where(e, 0, v & ((1 << bits) - 1)).astype(np.uint16)
    return pack_codes(codes, bits), np.packbits(e, axis=1)


@pytest.mark.parametrize("n,m,k", [
    (1, 1, 2), (4, 16, 8), (6, 5, 64),       # nnz ≪ k: empty bins
    (3, 300, 256), (5, 40, 128),
])
@pytest.mark.parametrize("bits", B_FUSED)
@pytest.mark.parametrize("densify", [True, False])
def test_fused_oph_bit_identical(n, m, k, bits, densify):
    rng = np.random.default_rng(n * 100 + m + k + bits)
    idx = rng.integers(0, 1 << 30, size=(n, m)).astype(np.int32)
    nnz = rng.integers(0, m + 1, size=(n,)).astype(np.int32)
    fam = OPHHash.make(k, seed=n + k)
    a, b = fam.params()
    got_p, got_e = oph_pack_pallas(jnp.asarray(idx), jnp.asarray(nnz),
                                   a, b, k=k, bits=bits, densify=densify,
                                   interpret=True)
    want_p, want_e = _ref_oph_packed(idx, nnz, fam, bits, densify)
    assert np.array_equal(np.asarray(got_p), want_p)
    assert np.array_equal(np.asarray(got_e), want_e)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 6), m=st.integers(1, 40),
       k=st.sampled_from([2, 8, 32, 64]), bits=st.sampled_from(B_FUSED),
       densify=st.sampled_from([True, False]))
def test_fused_oph_property(n, m, k, bits, densify):
    """Ragged nnz (empty rows included) + oph_zero empty-bin masks."""
    rng = np.random.default_rng(n + m * 3 + k * 7 + bits)
    idx = rng.integers(0, 1 << 30, size=(n, m)).astype(np.int32)
    nnz = rng.integers(0, m + 1, size=(n,)).astype(np.int32)
    fam = OPHHash.make(k, seed=m + bits)
    a, b = fam.params()
    got_p, got_e = oph_pack_pallas(jnp.asarray(idx), jnp.asarray(nnz),
                                   a, b, k=k, bits=bits, densify=densify,
                                   interpret=True)
    want_p, want_e = _ref_oph_packed(idx, nnz, fam, bits, densify)
    assert np.array_equal(np.asarray(got_p), want_p)
    assert np.array_equal(np.asarray(got_e), want_e)


# ---------------------------------------------------------------------------
# Scheme layer: encode_packed ≡ pack_codes ∘ encode_padded, every scheme.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["minwise", "oph", "oph_zero"])
@pytest.mark.parametrize("b", [1, 6, 8])
def test_scheme_encode_packed_matches_padded(scheme, b):
    rng = np.random.default_rng(3)
    rows = [np.unique(rng.integers(0, 1 << 28, size=rng.integers(0, 60)))
            for _ in range(18)]
    idx, nnz = pad_rows(rows, pad_to_multiple=1)
    sch = make_scheme(scheme, 32, 5)
    codes = sch.encode_padded(idx, nnz, b)
    packed, empty = sch.encode_packed(idx, nnz, b)
    if scheme == "oph_zero":
        want = np.where(codes == OPH_EMPTY_CODE, 0, codes)
        assert np.array_equal(
            empty, np.packbits(codes == OPH_EMPTY_CODE, axis=1))
    else:
        want = codes & ((1 << b) - 1)   # 'oph' all-empty rows: sentinel
        assert empty is None            # low bits are all-ones both ways
    assert np.array_equal(packed, pack_codes(want.astype(np.uint16), b))


# ---------------------------------------------------------------------------
# Streaming pipeline: packed path ≡ compat path, shards round-trip.
# ---------------------------------------------------------------------------
def _corpus(n=40, seed=9):
    rng = np.random.default_rng(seed)
    rows = [np.unique(rng.integers(0, 1 << 28, size=rng.integers(1, 150)))
            for _ in range(n)]
    return rows, rng.integers(0, 2, n).astype(np.int32)


@pytest.mark.parametrize("scheme", ["minwise", "oph", "oph_zero"])
def test_preprocess_rows_packed_matches_unpacked(scheme):
    from repro.data import preprocess_rows, preprocess_rows_packed
    rows, _ = _corpus()
    codes = preprocess_rows(rows, 32, 8, scheme=scheme, chunk=16)
    packed, empty = preprocess_rows_packed(rows, 32, 8, scheme=scheme,
                                           chunk=16)
    if scheme == "oph_zero":
        ref_codes = np.where(codes == OPH_EMPTY_CODE, 0, codes)
        assert np.array_equal(
            empty, np.packbits(codes == OPH_EMPTY_CODE, axis=1))
    else:
        ref_codes, _ = codes & 255, None
        assert empty is None
    assert np.array_equal(
        packed, pack_codes(ref_codes.astype(np.uint16), 8))


@pytest.mark.parametrize("scheme", ["minwise", "oph_zero"])
def test_streaming_save_restores_order_and_iterates(tmp_path, scheme):
    from repro.data import (iter_hashed, load_hashed, preprocess_and_save,
                            preprocess_rows)
    rows, labels = _corpus(50)
    d = str(tmp_path / scheme)
    stats = preprocess_and_save(d, rows, labels, k=32, b=8, scheme=scheme,
                                n_shards=4, chunk=16)
    assert stats["mnnz_per_s"] > 0 and stats["seconds_hashing"] > 0
    codes, l2, meta = load_hashed(d)
    assert meta["format_version"] == 4 and meta["shards"] == 4
    assert meta["packed_width"] == packed_width(32, 8)
    assert "mnnz_per_s" in meta       # throughput recorded next to data
    assert np.array_equal(l2, labels)
    assert np.array_equal(codes, preprocess_rows(rows, 32, 8,
                                                 scheme=scheme))
    # per-shard mmap iterator: covers every row exactly once, no concat
    seen = []
    for c, lab, rids in iter_hashed(d):
        assert len(c) <= -(-50 // 4) and c.shape[1] == 32
        assert np.array_equal(c, codes[rids])
        assert np.array_equal(lab, labels[rids])
        seen.extend(rids.tolist())
    assert sorted(seen) == list(range(50))


def test_streaming_writer_v2_archives_still_load(tmp_path):
    """The bulk v2 writer and old archives stay readable (and iterable)."""
    from repro.data import iter_hashed, load_hashed, preprocess_rows, \
        save_hashed
    rows, labels = _corpus(30)
    codes = preprocess_rows(rows, 16, 4, scheme="oph")
    d = str(tmp_path / "v2")
    save_hashed(d, codes, labels, 16, 4, scheme="oph", n_shards=3)
    c2, l2, meta = load_hashed(d)
    assert meta["format_version"] == 2
    assert np.array_equal(c2 & 15, codes & 15)
    assert np.array_equal(l2, labels)
    for c, lab, rids in iter_hashed(d):
        assert np.array_equal(c & 15, codes[rids] & 15)


# ---------------------------------------------------------------------------
# Shape bucketing: O(log m) jit variants instead of one per chunk.
# ---------------------------------------------------------------------------
def test_bucket_width_pow2():
    assert bucket_width(1) == 128 and bucket_width(128) == 128
    assert bucket_width(129) == 256 and bucket_width(300) == 512
    widths = {bucket_width(m) for m in range(1, 5000)}
    assert widths == {128, 256, 512, 1024, 2048, 4096, 8192}


def test_pad_rows_bucketed_width():
    rows = [np.arange(300), np.arange(5)]
    idx, nnz = pad_rows(rows, bucket=True)
    assert idx.shape[1] == 512            # next pow2 above 300
    assert nnz.tolist() == [300, 5]
    idx2, _ = pad_rows([np.arange(3)], bucket=True)
    assert idx2.shape[1] == 128           # floor at one lane tile
