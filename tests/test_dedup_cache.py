"""Duplicate-traffic score cache: host/device encode parity, the
probe/guard/version contract, engine short-circuit behavior, batch
front door parity, `/status` key coverage, and the histogram helpers
the cache and batcher share."""
import numpy as np
import pytest

import jax

from conftest import run_in_subprocess
from repro.core.schemes import make_scheme
from repro.data.packing import pad_rows
from repro.models.linear import BBitLinearConfig, init_bbit_linear
from repro.serving import (HashedClassifierEngine, NnzHistogram,
                           ScoreClient, ScoreServer, StatsWindow)
from repro.serving.dedup import DedupCache


def _docs(n, seed=0, lo=5, hi=60, space=1 << 20):
    rng = np.random.default_rng(seed)
    return [np.unique(rng.choice(space, size=int(rng.integers(lo, hi)),
                                 replace=False)).astype(np.int64)
            for _ in range(n)]


def _engine(scheme="oph", k=16, b=4, key=0, **kw):
    cfg = BBitLinearConfig(k=k, b=b)
    params = init_bbit_linear(cfg, jax.random.key(key))
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait_ms", 5.0)
    kw.setdefault("nnz_buckets", (64, 256))
    kw.setdefault("row_buckets", (1, 2, 4, 8))
    kw.setdefault("precompile", False)
    return HashedClassifierEngine(params, cfg, seed=1, scheme=scheme,
                                  **kw)


# ---------------------------------------------------------------------------
# host encode ≡ device encode (the property the guard's soundness
# rests on: byte-equality on the host transfers to score-equality on
# the device)


@pytest.mark.parametrize("scheme", ["minwise", "oph", "oph_zero"])
@pytest.mark.parametrize("b", [2, 8])
def test_host_encode_bitwise_matches_device(scheme, b):
    k = 16
    sch = make_scheme(scheme, k=k, seed=7)
    docs = _docs(12, seed=b)
    if scheme == "oph_zero":
        docs[3] = np.array([], dtype=np.int64)   # empty-doc semantics
    idx, nnz = pad_rows(docs, pad_to_multiple=1)
    p_host, e_host = sch.encode_packed_numpy(idx, nnz, b)
    p_dev, e_dev = sch.encode_packed_jit(idx, nnz, b)
    np.testing.assert_array_equal(p_host, np.asarray(p_dev))
    if e_host is None:
        assert e_dev is None
    else:
        np.testing.assert_array_equal(e_host, np.asarray(e_dev))


def test_host_encode_is_pad_width_invariant():
    # a key computed inside any batch must equal the key computed alone
    sch = make_scheme("oph", k=16, seed=7)
    docs = _docs(6, seed=3)
    idx_all, nnz_all = pad_rows(docs, pad_to_multiple=1)
    p_all, _ = sch.encode_packed_numpy(idx_all, nnz_all, 4)
    for i, d in enumerate(docs):
        idx1, nnz1 = pad_rows([d], pad_to_multiple=1)
        p1, _ = sch.encode_packed_numpy(idx1, nnz1, 4)
        np.testing.assert_array_equal(p_all[i], p1[0])


def test_ragged_encode_matches_padded():
    sch = make_scheme("oph", k=16, seed=7)
    docs = _docs(9, seed=5)
    idx, nnz = pad_rows(docs, pad_to_multiple=1)
    p_pad, _ = sch.encode_packed_numpy(idx, nnz, 4)
    lens = np.array([d.size for d in docs], dtype=np.int64)
    tokens = (np.concatenate(docs)
              & np.int64((1 << 31) - 1)).astype(np.int32)
    p_rag, _ = sch.encode_packed_numpy_ragged(tokens, lens, 4)
    np.testing.assert_array_equal(p_pad, p_rag)


# ---------------------------------------------------------------------------
# cache unit behavior


def test_cache_guard_rejects_band_collisions():
    c = DedupCache(max_entries=8, version="v0")
    sig = (1, 2, 3)
    c.put(sig, b"codesA", None, 0.5, "v0")
    assert c.get(sig, b"codesA", None, "v0") == 0.5
    # same probe signature, different full code: guarded miss
    assert c.get(sig, b"codesB", None, "v0") is None
    st = c.stats()
    assert st["guard_rejects"] == 1 and st["hits"] == 1


def test_cache_lru_eviction_and_bytes():
    c = DedupCache(max_entries=2, version="v0")
    for i in range(3):
        c.put((i,), bytes([i]), None, float(i), "v0")
    st = c.stats()
    assert st["entries"] == 2 and st["evictions"] == 1
    assert c.get((0,), bytes([0]), None, "v0") is None   # evicted (LRU)
    assert c.get((2,), bytes([2]), None, "v0") == 2.0
    assert st["bytes"] > 0


def test_cache_version_pinning_and_stale_put():
    c = DedupCache(max_entries=8, version="v0")
    c.put((1,), b"x", None, 1.0, "v0")
    c.invalidate("v1")
    assert c.get((1,), b"x", None, "v1") is None
    c.put((1,), b"x", None, 1.0, "v0")       # late put from old version
    assert c.stats()["stale_drops"] == 1
    assert c.get((1,), b"x", None, "v1") is None


def test_get_many_matches_get():
    c1 = DedupCache(max_entries=8, version="v0")
    c2 = DedupCache(max_entries=8, version="v0")
    for c in (c1, c2):
        c.put((1,), b"a", None, 1.0, "v0")
        c.put((2,), b"b", b"m", 2.0, "v0")
    keys = [((1,), b"a", None), ((2,), b"b", b"m"),
            ((1,), b"zzz", None), ((9,), b"a", None)]
    got = c1.get_many(keys, "v0", sizes=[4, 5, 6, 7])
    want = [c2.get(s, p, e, "v0", nnz=n)
            for (s, p, e), n in zip(keys, [4, 5, 6, 7])]
    assert got == want
    for key in ("hits", "misses", "guard_rejects", "hit_nnz"):
        assert c1.stats()[key] == c2.stats()[key]


# ---------------------------------------------------------------------------
# engine short-circuit


def test_engine_hit_skips_device_and_is_bitwise_identical():
    eng = _engine(dedup_cache=True, dedup_entries=64)
    docs = _docs(6, seed=11)
    for d in docs:
        eng.submit(d).result(timeout=60)
    runs_before = eng.batcher.batches_run
    for d in docs:
        want = float(eng.score_docs([d])[0])
        got = float(eng.submit(d).result(timeout=60))
        assert got == want                   # bitwise, not approx
    assert eng.batcher.batches_run == runs_before
    st = eng.dedup.stats()
    assert st["hits"] >= len(docs) and st["guard_rejects"] == 0
    assert eng.stats()["dedup"]["hits"] == st["hits"]
    eng.close()


def test_swap_weights_invalidates_cache():
    eng = _engine(dedup_cache=True, dedup_entries=64, key=0)
    d = _docs(1, seed=2)[0]
    old = float(eng.submit(d).result(timeout=60))
    assert float(eng.submit(d).result(timeout=60)) == old   # cached
    cfg = BBitLinearConfig(k=16, b=4)
    eng.swap_weights(init_bbit_linear(cfg, jax.random.key(9)), "v9")
    assert eng.dedup.stats()["invalidations"] == 1
    new = float(eng.submit(d).result(timeout=60))
    assert new != old            # re-scored under the new weights
    assert new == float(eng.score_docs([d])[0])
    eng.close()


@pytest.mark.parametrize("dedup", [False, True])
def test_submit_many_matches_submit(dedup):
    eng = _engine(dedup_cache=dedup, dedup_entries=64)
    docs = _docs(10, seed=4)
    stream = docs + docs[:4]                  # duplicates in-batch
    want = [float(eng.submit(d).result(timeout=60)) for d in stream]
    got = [float(f.result(timeout=60))
           for f in eng.submit_many(stream)]
    if dedup:
        # every submit_many row is a cache hit on the scores the
        # submit pass just filled: bitwise, not approx
        assert got == want
    else:
        # without the cache the two passes batch into different padded
        # row buckets — bit-identity only holds per shape (PR-5), so
        # plain-path parity is numerical
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    eng.close()


def test_submit_many_validates_like_submit():
    eng = _engine(dedup_cache=True, dedup_entries=64)
    with pytest.raises(ValueError, match="negative"):
        eng.submit_many([np.array([3, -1])])
    with pytest.raises(TypeError, match="1-D"):
        eng.submit_many([np.arange(4).reshape(2, 2)])
    eng.close()


def test_multi_device_round_robin_keeps_cache_coherent():
    run_in_subprocess("""
        import numpy as np, jax
        assert jax.device_count() == 2
        from repro.models.linear import BBitLinearConfig, init_bbit_linear
        from repro.serving import HashedClassifierEngine
        cfg = BBitLinearConfig(k=16, b=4)
        params = init_bbit_linear(cfg, jax.random.key(0))
        eng = HashedClassifierEngine(
            params, cfg, seed=1, scheme="oph", max_batch=4,
            max_wait_ms=5.0, nnz_buckets=(64,), row_buckets=(1, 2, 4),
            precompile=False, dedup_cache=True, dedup_entries=32)
        rng = np.random.default_rng(0)
        docs = [np.unique(rng.choice(1 << 20, size=20)).astype(np.int64)
                for _ in range(6)]
        # misses round-robin across both devices; each repeat must hit
        # the shared cache no matter which device scored it first
        for d in docs:
            eng.submit(d).result(timeout=120)
        runs = eng.batcher.batches_run
        for d in docs:
            want = float(eng.score_docs([d])[0])
            assert float(eng.submit(d).result(timeout=120)) == want
        assert eng.batcher.batches_run == runs
        assert eng.dedup.stats()["hits"] >= len(docs)
        assert sum(eng.device_batches) >= 2   # both devices exercised
        eng.close()
    """, devices=2)


# ---------------------------------------------------------------------------
# /status exposure


def test_status_keys_superset_of_engine_stats():
    eng = _engine(dedup_cache=True, dedup_entries=64)
    srv = ScoreServer(eng, port=0)
    srv.start_in_thread()
    try:
        client = ScoreClient("127.0.0.1", srv.port)
        client.score([[1, 5, 9]])
        status = client.status()
        missing = set(eng.stats()) - set(status)
        assert not missing, f"/status lost engine keys: {missing}"
        assert status["dedup"]["enabled"] is not False
        for key in ("hits", "misses", "entries", "bytes"):
            assert key in status["dedup"]
        client.close()
    finally:
        srv.request_drain()
        assert srv.wait_finished(timeout=30)


# ---------------------------------------------------------------------------
# histogram / stats helpers


def test_suggest_buckets_degenerate_inputs():
    h = NnzHistogram()
    assert h.suggest_buckets() is None                  # no samples
    h.record(10)
    assert h.suggest_buckets(min_samples=2) is None     # below floor
    h2 = NnzHistogram()
    for _ in range(100):
        h2.record(33)                                   # single bin
    got = h2.suggest_buckets(min_samples=64)
    assert got is not None and len(got) == 1 and got[0] >= 33
    h3 = NnzHistogram()
    for n in (4, 64, 1024):
        for _ in range(50):
            h3.record(n)                                # equal masses
    grid = h3.suggest_buckets(max_buckets=3, min_samples=64)
    assert grid is not None and list(grid) == sorted(grid)
    assert grid[-1] >= 1024
    with pytest.raises(ValueError, match="max_buckets"):
        h3.suggest_buckets(max_buckets=0)


def test_nnz_histogram_record_many_matches_record():
    a, b = NnzHistogram(), NnzHistogram()
    sizes = [0, 1, 2, 3, 100, 4096]
    for n in sizes:
        a.record(n)
    b.record_many(sizes)
    assert a.counts() == b.counts()
    b.record_many([])
    assert a.counts() == b.counts()


def test_stats_window_record_batch_matches_record():
    a, b = StatsWindow(size=16), StatsWindow(size=16)
    for _ in range(5):
        a.record(0.002, rows=1, tenant="t")
    b.record_batch(0.002, 5, tenant="t")
    sa, sb = a.snapshot(), b.snapshot()
    assert sa["count"] == sb["count"] == 5
    assert sa["p50_ms"] == pytest.approx(sb["p50_ms"])
    assert sa["per_tenant_rows"] == sb["per_tenant_rows"]
    b.record_batch(0.001, 0)                 # no-op
    assert b.snapshot()["count"] == 5
