"""Hash-family exactness/determinism + the paper's §7 claim (2-universal
hashing ≈ true permutations for learning)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import (
    SparseBatch, ModPrimeHash, MultiplyShiftHash, PermutationHash,
    make_hash_family, minhash_batch, minhash_numpy, bbit_codes,
    pack_codes, unpack_codes, storage_bits, resemblance,
)
from repro.core.universal_hash import MERSENNE61, _mulmod_mersenne61


@settings(max_examples=30, deadline=None)
@given(a=st.integers(0, int(MERSENNE61) - 1),
       b=st.integers(0, (1 << 31) - 1))
def test_mersenne_mulmod_exact(a, b):
    got = _mulmod_mersenne61(np.uint64(a), np.uint64(b))
    assert int(got) == (a * b) % int(MERSENNE61)


def test_mod_prime_matches_eq17():
    """h(t) = (c1 + c2·t) mod p — exact vs python big ints."""
    fam = ModPrimeHash.make(16, seed=5)
    rng = np.random.default_rng(0)
    t = rng.integers(0, 1 << 31, size=64)
    got = fam(t)
    p = int(MERSENNE61)
    for i, tt in enumerate(t):
        for j in range(16):
            want = (int(fam.c1[j]) + int(fam.c2[j]) * int(tt)) % p
            assert int(got[i, j]) == want


def test_families_deterministic():
    for kind in ("multiply_shift", "mod_prime"):
        f1 = make_hash_family(kind, 8, seed=3)
        f2 = make_hash_family(kind, 8, seed=3)
        t = np.arange(100)
        if kind == "multiply_shift":
            assert np.array_equal(np.asarray(f1(jnp.asarray(t))),
                                  np.asarray(f2(jnp.asarray(t))))
        else:
            assert np.array_equal(f1(t), f2(t))


def test_multiply_shift_low_bits_uniform():
    """b-bit codes use the LOW bits — they must be uniform (fmix32)."""
    fam = MultiplyShiftHash.make(4, seed=11)
    h = np.asarray(fam(jnp.arange(200_000, dtype=jnp.int32)))
    for b in (1, 2, 4):
        codes = h & ((1 << b) - 1)
        counts = np.stack([np.bincount(codes[:, j], minlength=1 << b)
                           for j in range(4)])
        expected = 200_000 / (1 << b)
        chi2 = ((counts - expected) ** 2 / expected).sum(axis=1)
        # dof = 2^b - 1; generous 99.9% bound per column
        assert (chi2 < 10 + 6 * (1 << b)).all(), chi2


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 20), k=st.integers(1, 64),
       b=st.integers(1, 16), seed=st.integers(0, 1 << 30))
def test_pack_unpack_roundtrip(n, k, b, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << b, size=(n, k)).astype(np.uint16)
    packed = pack_codes(codes, b)
    assert packed.shape[1] == (k * b + 7) // 8    # exactly n·b·k bits
    assert np.array_equal(unpack_codes(packed, k, b), codes)
    assert storage_bits(n, k, b) == n * b * k


def test_universal_hashing_vs_permutations_fig8():
    """Paper Fig 8: 2-universal families track true permutations.

    Compared on the resemblance-estimation task itself (the quantity
    learning quality is driven by): both families' R̂ estimates must
    agree with the exact R within matched Monte-Carlo error.
    """
    dim = 4096
    rng = np.random.default_rng(4)
    common = rng.choice(dim, size=700, replace=False)
    s1, s2 = set(common[:500]), set(common[200:])
    r = resemblance(s1, s2)
    rows = [sorted(s1), sorted(s2)]
    idx = np.zeros((2, 512), np.int32)
    mask = np.zeros((2, 512), bool)
    for i, row in enumerate(rows):
        idx[i, :len(row)] = row
        mask[i, :len(row)] = True
    k = 600
    est = {}
    for kind in ("permutation", "mod_prime"):
        fam = make_hash_family(kind, k, seed=9, dim=dim)
        z = minhash_numpy(idx, mask, fam)
        est[kind] = float(np.mean(z[0] == z[1]))
    fam = MultiplyShiftHash.make(k, seed=9)
    batch = SparseBatch.from_lists(rows, dim=dim)
    z = np.asarray(minhash_batch(batch, fam))
    est["multiply_shift"] = float(np.mean(z[0] == z[1]))
    sigma = np.sqrt(r * (1 - r) / k)
    for kind, e in est.items():
        assert abs(e - r) < 4 * sigma, (kind, e, r)
