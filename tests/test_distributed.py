"""Distributed-runtime tests on 8 fake devices (subprocess-isolated so
the main test process keeps its single real device)."""
import pytest

from conftest import run_in_subprocess


def test_grad_compression_and_hlo_accounting():
    run_in_subprocess("""
        import functools, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:        # jax<0.5 keeps it in experimental
            from jax.experimental.shard_map import shard_map
        from repro.distributed import (
            compressed_allreduce_mean, collective_bytes_from_hlo,
            collective_stats_from_hlo)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        g = jnp.arange(4*64, dtype=jnp.float32).reshape(4, 64) / 100.
        e = jnp.zeros((4, 64), jnp.float32)
        @functools.partial(shard_map, mesh=mesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)))
        def cr(g, e):
            mg, ne = compressed_allreduce_mean(g[0], e[0], "data", bits=8)
            return mg[None], ne[None]
        mg, ne = cr(g, e)
        want = jnp.mean(g, axis=0)
        assert float(jnp.abs(mg[0]-want).max()) < 0.02
        # error feedback: long-run mean drift vanishes
        tot = jnp.zeros(64); ee = e
        for _ in range(30):
            m, ee = cr(g, ee); tot = tot + m[0]
        assert float(jnp.abs(tot/30 - want).max()) < 1e-3
        # wire payload is int8 (the b-bit story): all-gathers present,
        # and the int8 payload dominates the f32 scales
        hlo = jax.jit(cr).lower(g, e).compile().as_text()
        stats = collective_stats_from_hlo(hlo)
        assert any(s["op"] == "all-gather" for s in stats)
        total = collective_bytes_from_hlo(hlo)["total"]
        assert total < 4 * 64 * 4 * 4  # far below fp32 all-gather cost
        # 1-bit mode
        @functools.partial(shard_map, mesh=mesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)))
        def cr1(g, e):
            mg, ne = compressed_allreduce_mean(g[0], e[0], "data", bits=1)
            return mg[None], ne[None]
        tot = jnp.zeros(64); ee = e
        for _ in range(60):
            m, ee = cr1(g, ee); tot = tot + m[0]
        # sign-compression converges in running mean (Cesàro); per-tensor
        # scale makes it slower than int8 — generous bound
        assert float(jnp.abs(tot/60 - want).max()) < 0.15
        print("OK")
    """)


def test_sequence_parallel_primitives():
    run_in_subprocess("""
        import functools, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:        # jax<0.5 keeps it in experimental
            from jax.experimental.shard_map import shard_map
        from repro.distributed import (merge_partial_attention,
                                       seq_parallel_ssm_scan)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        scores = np.random.default_rng(2).normal(size=(2, 32)).astype('f')
        V = np.random.default_rng(3).normal(size=(32, 5)).astype('f')
        full = jax.nn.softmax(jnp.asarray(scores), -1) @ jnp.asarray(V)
        @functools.partial(shard_map, mesh=mesh,
            in_specs=(P(None, "data"), P("data", None)),
            out_specs=P(None, None))
        def att(s, v):
            lm = jnp.max(s, -1); le = jnp.exp(s - lm[:, None])
            return merge_partial_attention(lm, jnp.sum(le, -1), le @ v,
                                           "data")
        out = att(jnp.asarray(scores), jnp.asarray(V))
        assert float(jnp.abs(out - full).max()) < 1e-5
        # SSM prefix composition across shards
        A = np.random.default_rng(4).uniform(.5, .99, (4, 3)).astype('f')
        B = np.random.default_rng(5).normal(size=(4, 3)).astype('f')
        h0 = np.ones(3, 'f')
        @functools.partial(shard_map, mesh=mesh,
            in_specs=(P("data", None), P("data", None), P(None)),
            out_specs=P("data", None))
        def sp(a, b, h):
            out = seq_parallel_ssm_scan(a[0], b[0], h, "data",
                                        jax.lax.axis_index("data"))
            return out[None]
        hins = np.asarray(sp(jnp.asarray(A), jnp.asarray(B),
                             jnp.asarray(h0)))
        h = h0.copy(); want = []
        for i in range(4):
            want.append(h.copy()); h = A[i]*h + B[i]
        assert np.abs(hins - np.stack(want)).max() < 1e-5
        print("OK")
    """)


def test_pipeline_parallel_gpipe():
    run_in_subprocess("""
        import functools, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:        # jax<0.5 keeps it in experimental
            from jax.experimental.shard_map import shard_map
        from repro.distributed import pipelined_apply
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        M, mb, dim = 6, 2, 8
        x = np.random.default_rng(6).normal(size=(M, mb, dim)).astype('f')
        W = np.random.default_rng(7).normal(size=(4, dim, dim)
                                            ).astype('f') * 0.3
        def stage(p, x): return jnp.tanh(x @ p[0])
        @functools.partial(shard_map, mesh=mesh,
            in_specs=(P("data", None, None), P(None, None, None)),
            out_specs=P(None, None, None))
        def pipe(w, xm):
            return pipelined_apply(stage, (w,), xm, axis_name="data")
        got = pipe(jnp.asarray(W), jnp.asarray(x))
        want = jnp.asarray(x)
        for i in range(4):
            want = jnp.tanh(want @ W[i])
        assert float(jnp.abs(got - want).max()) < 1e-5
        # differentiability (training through the pipeline)
        def loss(w): return jnp.sum(pipe(w, jnp.asarray(x)) ** 2)
        g = jax.grad(loss)(jnp.asarray(W))
        assert np.isfinite(np.asarray(g)).all() and float(
            jnp.abs(g).sum()) > 0
        print("OK")
    """)


def test_moe_ep_parity_and_elastic_mesh():
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ArchConfig
        import repro.models.moe as M
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        M.EXPERT_PAD_TO = 2
        cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=16,
                         n_heads=2, n_kv_heads=2, d_ff=0, vocab=64,
                         moe_experts=6, moe_top_k=2, moe_d_ff=32,
                         moe_capacity=8.0, dtype="float32")
        params = M.init_moe_params(cfg, jax.random.key(0), jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 8, 16)).astype('f'))
        y_dense = M.moe_ffn(x, params, cfg, mesh=None)
        ps = M.moe_param_pspecs(cfg, dp_axes=("pod", "data"))
        p_sh = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), ps,
            is_leaf=lambda s: isinstance(s, P)))
        x_sh = jax.device_put(x, NamedSharding(
            mesh, P(("pod", "data"), None, None)))
        y = jax.jit(lambda a, b: M.moe_ffn(a, b, cfg, mesh=mesh))(x_sh, p_sh)
        assert float(jnp.abs(y - y_dense).max()) < 1e-4
        # elastic: same model on a smaller mesh gives identical results
        from repro.ckpt.elastic import mesh_from_available_devices
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        p2 = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh2, P(*[e if e != "pod" else None
                                               for e in s])),
            M.moe_param_pspecs(cfg, dp_axes=("data",)),
            is_leaf=lambda s: isinstance(s, P)))
        x2 = jax.device_put(x, NamedSharding(mesh2, P("data", None, None)))
        y2 = jax.jit(lambda a, b: M.moe_ffn(a, b, cfg, mesh=mesh2))(x2, p2)
        assert float(jnp.abs(y2 - y_dense).max()) < 1e-4
        print("OK")
    """)


def test_linear_model_distributed_step():
    """The paper's workload end-to-end on a (data, model) mesh: TP over
    k hash functions + DP over examples; loss matches single-device."""
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.rcv1_bbit import PaperConfig
        from repro.launch.steps import build_linear_train_step
        from repro.launch.mesh import make_test_mesh
        paper = PaperConfig(k=16, b=4, global_batch=32)
        mesh = make_test_mesh(4, 2)
        jitted, state_shapes, state_ps, _ = build_linear_train_step(
            paper, mesh)
        # real arrays
        from repro.models.linear import BBitLinearConfig, init_bbit_linear
        from repro.optim.optimizers import adamw, AdamWConfig
        from repro.train.steps import TrainState
        lcfg = BBitLinearConfig(k=16, b=4, use_kernel="never")
        opt = adamw(1e-2, AdamWConfig())
        params = init_bbit_linear(lcfg)
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32))
        rng = np.random.default_rng(0)
        codes = jnp.asarray(rng.integers(0, 16, (32, 16)).astype('i4'))
        labels = jnp.asarray((rng.random(32) > .5).astype('i4'))
        # single-device reference BEFORE the step: the jitted step
        # donates the state, deleting the params buffers
        from repro.train.losses import mean_loss_fn
        from repro.models.linear import bbit_logits
        lf = mean_loss_fn(lambda p, c: bbit_logits(p, c, lcfg),
                          "logistic", l2=1e-7)
        ref_loss = float(lf(params, codes, labels))
        with mesh:
            new_state, loss = jitted(state, codes, labels)
        assert np.isfinite(float(loss))
        assert abs(float(loss) - ref_loss) < 1e-5
        print("OK")
    """)


def test_moe_weight_stationary_serving_parity():
    """§Perf dispatch: experts 2D-sharded, tokens travel — must equal
    the dense fallback exactly (ample capacity)."""
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ArchConfig
        import repro.models.moe as M
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        M.EXPERT_PAD_TO = 8
        cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=16,
                         n_heads=2, n_kv_heads=2, d_ff=0, vocab=64,
                         moe_experts=6, moe_top_k=2, moe_d_ff=32,
                         moe_capacity=8.0, dtype="float32",
                         moe_serving_dispatch="weight_stationary",
                         moe_pad_to=8)
        params = M.init_moe_params(cfg, jax.random.key(0), jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 8, 16)).astype('f'))
        y_dense = M.moe_ffn(x, params, cfg, mesh=None)
        ps = M.moe_param_pspecs(cfg, dp_axes=("data",))
        p_sh = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), ps,
            is_leaf=lambda s: isinstance(s, P)))
        x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None,
                                                       None)))
        y = jax.jit(lambda a, b: M.moe_ffn(a, b, cfg, mesh=mesh,
                                           serving=True))(x_sh, p_sh)
        assert float(jnp.abs(y - y_dense).max()) < 1e-4
        print("OK")
    """)


def test_kv_repeat_decode_parity():
    """§Perf: KV-head replication is an exact GQA transform."""
    run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ArchConfig
        from repro.models import transformer as T
        cfg = ArchConfig(name="d", family="dense", n_layers=2, d_model=32,
                         n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                         dtype="float32", attn_q_chunk=8, attn_kv_chunk=8,
                         kv_repeat_to=4)
        p = T.init_decoder_params(cfg, jax.random.key(1))
        toks = jnp.asarray(np.random.default_rng(1).integers(
            0, 64, (2, 12)).astype(np.int32))
        logits = T.forward_train(p, toks, cfg)
        lg_p, cache = T.prefill(p, toks[:, :8], cfg)
        assert cache["k"].shape[3] == 4
        full = T.init_cache(cfg, 2, 12, dtype=jnp.float32)
        cache = jax.tree.map(
            lambda f, pre: jax.lax.dynamic_update_slice_in_dim(
                f, pre.astype(f.dtype), 0, axis=2), full, cache)
        errs = [float(jnp.abs(lg_p - logits[:, 7]).max())]
        c = cache
        for t in range(8, 12):
            lg, c = T.decode_step(p, toks[:, t:t+1], c,
                                  jnp.asarray(t, jnp.int32), cfg)
            errs.append(float(jnp.abs(lg - logits[:, t]).max()))
        assert max(errs) < 2e-3, errs
        print("OK")
    """, devices=1)
