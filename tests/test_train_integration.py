"""Integration: optimizers, quantized state, microbatching, loaders."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.optimizers import adamw, sgd, AdamWConfig
from repro.optim.quantized_state import (
    QuantizedArray, quantize, dequantize, moment_pspec,
)
from repro.train.steps import (
    init_state, build_train_step, build_microbatched_train_step,
)


def _quadratic_problem():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    # realizable target: the least-squares optimum is 0, so the
    # convergence assertion measures the optimizer, not the residual
    w_true = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    target = A @ w_true + 0.3

    def loss(params, idx):
        pred = A[idx] @ params["w"] + params["b"]
        return jnp.mean((pred - target[idx]) ** 2)

    params = {"w": jnp.zeros(8), "b": jnp.zeros(())}
    return loss, params


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_all_moment_dtypes(moment_dtype):
    loss, params = _quadratic_problem()
    opt = adamw(0.05, AdamWConfig(moment_dtype=moment_dtype))
    state = init_state(params, opt)
    step = build_train_step(loss, opt, donate=False)
    idx = jnp.arange(16)
    losses = []
    for _ in range(200):
        state, l = step(state, idx)
        losses.append(float(l))
    assert losses[-1] < 0.05 * losses[0], (moment_dtype, losses[-1])


def test_quantize_roundtrip_accuracy():
    rng = np.random.default_rng(1)
    for shape in [(8,), (4, 256), (3, 5, 128), ()]:
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        qa = quantize(x)
        back = dequantize(qa)
        scale = float(jnp.max(jnp.abs(x))) if x.size else 1.0
        assert float(jnp.abs(back - x).max()) <= scale / 127 + 1e-7


def test_moment_pspec_structure():
    from jax.sharding import PartitionSpec as P
    mp = moment_pspec(P("model", "data"), "int8")
    assert isinstance(mp, QuantizedArray)
    assert tuple(mp.q) == ("model", "data")
    assert tuple(mp.scale) == ("model", None)
    assert moment_pspec(P("model"), "float32") == P("model")


def test_microbatched_equals_full_batch():
    loss, params = _quadratic_problem()
    opt = sgd(0.1)
    state_a = init_state(params, opt)
    state_b = init_state(params, opt)
    full = build_train_step(loss, opt, donate=False)
    micro = build_microbatched_train_step(loss, opt, n_micro=4)
    idx = jnp.arange(16)
    sa, la = full(state_a, idx)
    sb, lb = micro(state_b, idx)
    # microbatched grad is the mean of per-microbatch grads — for a
    # mean-loss this equals the full-batch grad
    np.testing.assert_allclose(np.asarray(sa.params["w"]),
                               np.asarray(sb.params["w"]), atol=1e-6)
    assert abs(float(la) - float(lb)) < 1e-6


def test_tron_hvp_consistency():
    """Analytic linear-model HVP == autodiff jvp-of-grad HVP."""
    from repro.models.linear import (BBitLinearConfig, init_bbit_linear,
                                     bbit_logits)
    from repro.train.linear_trainer import make_liblinear_hvp
    from repro.train.losses import liblinear_objective
    from jax.flatten_util import ravel_pytree
    rng = np.random.default_rng(2)
    cfg = BBitLinearConfig(k=6, b=3, use_kernel="never")
    codes = jnp.asarray(rng.integers(0, 8, (40, 6)).astype(np.int32))
    labels = jnp.asarray((rng.random(40) > 0.5).astype(np.int32))
    fwd = lambda p, c: bbit_logits(p, c, cfg)
    obj = liblinear_objective(fwd, "logistic", 0.5)
    params = init_bbit_linear(cfg, jax.random.key(0))
    flat, unravel = ravel_pytree(params)
    hvp = make_liblinear_hvp(fwd, "logistic", 0.5, codes, labels)
    v = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    got = ravel_pytree(hvp(params, v))[0]

    def f_flat(w):
        return obj(unravel(w), codes, labels)

    want = jax.jvp(jax.grad(f_flat), (flat,),
                   (ravel_pytree(v)[0],))[1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
