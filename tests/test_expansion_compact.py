"""Expansion identities (paper §3) + §5.4 compact indexing."""
import numpy as np
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.expansion import (
    expand, expansion_offsets, linear_forward, pb_hat, compact_index,
)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 8), k=st.integers(1, 24), b=st.integers(1, 8),
       o=st.integers(1, 4), seed=st.integers(0, 1 << 30))
def test_gather_forward_equals_expansion_dot(n, k, b, o, seed):
    """w·x over the virtual 2^b·k expansion == k gathers (paper §3)."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << b, (n, k)).astype(np.uint16))
    w = jnp.asarray(rng.normal(size=(k, 1 << b, o)).astype(np.float32))
    lhs = expand(codes, b) @ w.reshape(k * (1 << b), o)
    rhs = linear_forward(codes, w, b)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-4)


def test_expansion_has_exactly_k_ones():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 16, (5, 30)).astype(np.uint16))
    e = expand(codes, 4)
    assert np.all(np.asarray(e.sum(axis=1)) == 30)
    # inner product = k · P̂_b  (paper §2: the estimator as a dot product)
    c2 = jnp.asarray(rng.integers(0, 16, (5, 30)).astype(np.uint16))
    e2 = expand(c2, 4)
    dots = np.asarray(jnp.sum(e * e2, axis=1))
    pb = np.asarray(pb_hat(codes, c2))
    np.testing.assert_allclose(dots, 30 * pb, atol=1e-5)


def test_expansion_offsets_disjoint_blocks():
    codes = jnp.asarray([[0, 3], [1, 2]], dtype=jnp.uint16)
    offs = np.asarray(expansion_offsets(codes, 2))
    assert offs.tolist() == [[0, 7], [1, 6]]


def test_compact_index_preserves_inner_products():
    """§5.4: VW over the virtual expansion is unbiased for k·P̂_b."""
    rng = np.random.default_rng(1)
    k, b, m = 64, 12, 512
    c1 = jnp.asarray(rng.integers(0, 1 << b, (1, k)).astype(np.uint16))
    # second code vector agreeing on exactly half the positions
    c2 = np.asarray(c1).copy()
    flip = rng.choice(k, size=k // 2, replace=False)
    c2[0, flip] = (c2[0, flip] + 1) % (1 << b)
    c2 = jnp.asarray(c2)
    true_dot = float(k * pb_hat(c1, c2)[0])
    ests = []
    for seed in range(300):
        s1 = compact_index(c1.astype(jnp.int32), b, m,
                           seed_a=seed * 2 + 1, seed_b=seed * 7 + 3)
        s2 = compact_index(c2.astype(jnp.int32), b, m,
                           seed_a=seed * 2 + 1, seed_b=seed * 7 + 3)
        ests.append(float(jnp.sum(s1 * s2)))
    assert abs(np.mean(ests) - true_dot) < 0.15 * k
