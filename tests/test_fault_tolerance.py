"""Crash-safe, self-healing streaming training (PR 7).

Crash-equivalence matrix: a run killed at {a shard boundary, mid-shard,
during a checkpoint write} and resumed on {the same topology, elastic
2→1 fake devices, elastic 1→2} must produce BIT-IDENTICAL final
parameters and exact progressive-counter continuity vs an uninterrupted
run.  Plus: torn-checkpoint quarantine + fallback, corrupt-shard
detection (CRC fsck + bounded read retry), prefetcher error context,
the straggler watchdog on an injected slow step, and the ScoreClient's
opt-in 429 retry against a live server.

Elastic cases run on 1/2 fake XLA devices in subprocesses (conftest);
the serial cases run in-process on the main interpreter's single
device."""
import json
import os
import threading
import time

import numpy as np
import pytest

import jax

from conftest import run_in_subprocess

from repro.ckpt import checkpoint as ckpt
from repro.data import (SynthRcv1Config, ShardCorruptionError,
                        ShardReadError, ShardStreamError, generate_arrays,
                        preprocess_and_save, verify_shard)
from repro.data import hashed_dataset
from repro.data.prefetch import ThreadedPrefetcher
from repro.ft import (BackoffPolicy, FaultEvent, FaultPlan, InjectedCrash,
                      StepWatchdog, faults)
from repro.models.linear import BBitLinearConfig
from repro.train import (RestartPolicy, fit_streaming, run_supervised,
                         trees_bitwise_equal)

_KW = dict(epochs=2, batch_size=32, lr=5e-3, seed=0)
_LCFG = BBitLinearConfig(k=16, b=4)


def _build_archive(root, n_docs=160, n_shards=2, scheme="minwise"):
    cfg = SynthRcv1Config(seed=11, topic_tokens=150, background_frac=0.35,
                          max_pairs_per_doc=2000, max_triples_per_doc=1000)
    rows, labels = generate_arrays(n_docs, cfg)
    os.makedirs(root, exist_ok=True)
    preprocess_and_save(root, rows, labels, k=16, b=4, seed=1,
                        n_shards=n_shards, scheme=scheme, chunk=64)
    return root


@pytest.fixture(scope="module")
def arch(tmp_path_factory):
    """160 docs / 2 shards: 3 serial steps per shard, 12 total over 2
    epochs — small enough for in-process runs, big enough that "mid-
    shard" and "shard boundary" are distinct step indices."""
    return _build_archive(str(tmp_path_factory.mktemp("ft") / "arch"))


def _counters_equal(a, b):
    assert a.n_steps == b.n_steps
    assert a.examples_seen == b.examples_seen
    assert a.shards_processed == b.shards_processed
    assert abs(a.progressive_acc - b.progressive_acc) < 1e-12


# ------------------------------------------------- fault harness ----

def test_unarmed_and_unmatched_plans_are_inert(arch):
    """No plan armed (the production default) and an armed plan whose
    events never match must both leave the run bit-identical."""
    assert faults.active() is None
    ref = fit_streaming(arch, _LCFG, **_KW)
    plan = FaultPlan([FaultEvent(site="train_step", step=10**9),
                      FaultEvent(site="shard_read", shard=999),
                      FaultEvent(site="ckpt_write", at_save=10**9)])
    with faults.arm(plan):
        armed = fit_streaming(arch, _LCFG, **_KW)
    assert faults.active() is None
    assert all(e.fired == 0 for e in plan.events)
    assert trees_bitwise_equal(ref.params, armed.params)
    assert trees_bitwise_equal(ref.avg_params, armed.avg_params)
    _counters_equal(ref, armed)


# ------------------------------- supervised crash equivalence ----

def _fast_policy(max_restarts=3):
    return RestartPolicy(max_restarts=max_restarts,
                         backoff=BackoffPolicy(base_s=0.005, factor=2.0,
                                               cap_s=0.02, jitter_frac=0.0))


def test_supervised_crashes_are_bit_equivalent(arch, tmp_path):
    """Two injected process-crashes — one on the first step after a
    shard-boundary checkpoint (step 3), one mid-shard (step 8) — and
    the supervised run still finishes bit-identical to an uninterrupted
    run, with exact counter continuity."""
    ref = fit_streaming(arch, _LCFG, **_KW)
    ck = str(tmp_path / "ck")
    plan = FaultPlan([FaultEvent(site="train_step", step=3, times=1),
                      FaultEvent(site="train_step", step=8, times=1)])
    with faults.arm(plan):
        sup = run_supervised(arch, _LCFG, policy=_fast_policy(),
                             ckpt_dir=ck, **_KW)
    assert [e.fired for e in plan.events] == [1, 1]
    assert sup.restarts == 2 and len(sup.crashes) == 2
    assert all(c.error.startswith("InjectedCrash") for c in sup.crashes)
    assert all(c.recover_s > 0 for c in sup.crashes)
    assert sup.result.completed
    assert trees_bitwise_equal(ref.params, sup.result.params)
    assert trees_bitwise_equal(ref.avg_params, sup.result.avg_params)
    _counters_equal(ref, sup.result)


def test_supervised_torn_checkpoint_write_recovers(arch, tmp_path):
    """The first checkpoint write is torn (payload truncated AFTER the
    atomic rename — the fsync-less failure mode) and the process dies;
    the restarted attempt must quarantine the damaged checkpoint, fall
    back to a fresh start, and still finish bit-identical."""
    ref = fit_streaming(arch, _LCFG, **_KW)
    ck = str(tmp_path / "ck")
    plan = FaultPlan([FaultEvent(site="ckpt_write", times=1)])
    with faults.arm(plan):
        sup = run_supervised(arch, _LCFG, policy=_fast_policy(),
                             ckpt_dir=ck, **_KW)
    assert plan.events[0].fired == 1 and sup.restarts == 1
    assert trees_bitwise_equal(ref.params, sup.result.params)
    assert trees_bitwise_equal(ref.avg_params, sup.result.avg_params)
    _counters_equal(ref, sup.result)
    q = os.path.join(ck, ckpt.QUARANTINE_SUBDIR)
    assert os.path.isdir(q) and len(os.listdir(q)) == 1
    # the retried (clean) saves are restorable
    assert ckpt.latest_step(ck) == ref.shards_processed


def test_supervised_gives_up_after_max_restarts(arch, tmp_path):
    """A persistent crash (times=None — every attempt dies at step 0)
    exhausts the restart budget and re-raises."""
    plan = FaultPlan([FaultEvent(site="train_step", step=0, times=None)])
    with faults.arm(plan):
        with pytest.raises(InjectedCrash):
            run_supervised(arch, _LCFG, policy=_fast_policy(max_restarts=2),
                           ckpt_dir=str(tmp_path / "ck"), **_KW)
    assert plan.events[0].fired == 3  # initial attempt + 2 restarts


def test_supervised_refuses_unrecoverable_setups(arch, tmp_path):
    with pytest.raises(ValueError, match="ckpt_dir"):
        run_supervised(arch, _LCFG, **_KW)
    with pytest.raises(ValueError, match="resume"):
        run_supervised(arch, _LCFG, ckpt_dir=str(tmp_path / "ck"),
                       resume=False, **_KW)
    # config errors are deterministic — never retried
    t0 = time.perf_counter()
    with pytest.raises(ValueError, match="does not match archive"):
        run_supervised(arch, BBitLinearConfig(k=8, b=4),
                       ckpt_dir=str(tmp_path / "ck"), **_KW)
    assert time.perf_counter() - t0 < 5.0


# ------------------------------------- torn-checkpoint fallback ----

def test_restore_quarantines_corrupt_and_falls_back(tmp_path):
    ck = str(tmp_path / "ck")
    t1 = {"a": np.arange(6, dtype=np.float32), "b": np.ones(3, np.int64)}
    t2 = {"a": np.full(6, 7.0, np.float32), "b": np.zeros(3, np.int64)}
    ckpt.save(ck, 1, t1)
    ckpt.save(ck, 2, t2)
    # silent bit-rot: rewrite the payload with same-shape zeros — the
    # npz parses fine, only the recorded CRC32s catch it
    p = os.path.join(ck, "step_00000002", "ckpt.npz")
    with np.load(p) as z:
        zeroed = {k: np.zeros_like(z[k]) for k in z.files}
    np.savez(p, **zeroed)
    # an explicitly requested step never falls back
    with pytest.raises(ckpt.CorruptCheckpointError, match="CRC mismatch"):
        ckpt.restore(ck, t1, step=2)
    # default restore: quarantine step 2, fall back to step 1
    got, step = ckpt.restore(ck, t1)
    assert step == 1
    assert np.array_equal(got["a"], t1["a"])
    assert np.array_equal(got["b"], t1["b"])
    q = os.path.join(ck, ckpt.QUARANTINE_SUBDIR)
    assert os.listdir(q) == ["step_00000002"]
    assert ckpt.latest_step(ck) == 1
    # truncation (the torn write) trips the parser, not just the CRC
    p1 = os.path.join(ck, "step_00000001", "ckpt.npz")
    with open(p1, "r+b") as f:
        f.truncate(max(1, os.path.getsize(p1) * 3 // 5))
    with pytest.raises(FileNotFoundError, match="no valid checkpoints"):
        ckpt.restore(ck, t1)
    assert len(os.listdir(q)) == 2


def test_checkpoint_meta_records_crcs_and_lineage_extras(tmp_path):
    ck = str(tmp_path / "ck")
    tree = {"w": np.arange(4, dtype=np.float32)}
    ckpt.save(ck, 5, tree, extra_meta={"lineage": [{"logical": 2}]})
    meta = ckpt.load_meta(ck, 5)
    assert meta["ckpt_format"] == ckpt.CKPT_FORMAT == 4
    assert meta["lineage"] == [{"logical": 2}]
    assert set(meta["crc32"]) == {"leaf_00000"}


# ----------------------------------------- shard read durability ----

def test_transient_shard_read_fault_is_absorbed(arch):
    """Two injected IOErrors on the first shard open: the reader's
    bounded retry (2 retries = 3 attempts) absorbs them; the run is
    bit-identical to a fault-free one and nothing is quarantined."""
    ref = fit_streaming(arch, _LCFG, **_KW)
    plan = FaultPlan([FaultEvent(site="shard_read", times=2)])
    with faults.arm(plan):
        got = fit_streaming(arch, _LCFG, **_KW)
    assert plan.events[0].fired == 2
    assert trees_bitwise_equal(ref.params, got.params)
    _counters_equal(ref, got)
    assert arch not in hashed_dataset.quarantined_shards


def test_persistent_shard_fault_quarantines_with_context(arch):
    """A persistent read failure (times=None, a dead disk block)
    exhausts the retries; through the background prefetcher the trainer
    still sees a ShardStreamError naming (shard, epoch, position) with
    the reader's ShardReadError chained as the cause."""
    plan = FaultPlan([FaultEvent(site="shard_read", shard=1, times=None)])
    try:
        with faults.arm(plan):
            with pytest.raises(ShardStreamError) as exc:
                fit_streaming(arch, _LCFG, prefetch=2, **_KW)
        e = exc.value
        assert e.shard == 1 and e.epoch == 0 and 0 <= e.position < 2
        assert isinstance(e.__cause__, ShardReadError)
        assert e.__cause__.attempts == hashed_dataset.READ_RETRIES + 1
        assert e.__cause__.__traceback__ is not None
        assert 1 in hashed_dataset.quarantined_shards.get(arch, [])
    finally:
        hashed_dataset.quarantined_shards.pop(arch, None)


def test_verify_shard_fsck_catches_bit_flip(tmp_path):
    root = _build_archive(str(tmp_path / "arch"), n_docs=80, n_shards=2)
    meta = hashed_dataset._read_meta(root)
    assert meta["format_version"] == 4
    assert len(meta["shard_checksums"]) == 2
    assert set(verify_shard(root, 0)) >= {"codes", "labels", "rows"}
    # flip one payload byte past the npy header of shard 1's codes
    p = os.path.join(root, "hashed_00001.codes.npy")
    with open(p, "r+b") as f:
        f.seek(os.path.getsize(p) - 1)
        last = f.read(1)
        f.seek(os.path.getsize(p) - 1)
        f.write(bytes([last[0] ^ 0xFF]))
    with pytest.raises(ShardCorruptionError, match="codes"):
        verify_shard(root, 1)
    assert set(verify_shard(root, 0)) >= {"codes"}  # shard 0 untouched


# ------------------------------------------- prefetcher liveness ----

def test_prefetcher_raises_when_producer_dies_without_sentinel():
    """A producer killed before it can post its error/done sentinel
    (interpreter teardown, thread kill) must surface as an error in the
    consumer instead of a forever-blocking queue.get."""
    pf = ThreadedPrefetcher.__new__(ThreadedPrefetcher)
    import queue as _q
    pf._q = _q.Queue(maxsize=1)
    pf._stop = threading.Event()
    pf._done = False
    pf._thread = threading.Thread(target=lambda: None)
    pf._thread.start()
    pf._thread.join()
    with pytest.raises(RuntimeError, match="died without delivering"):
        next(pf)
    with pytest.raises(StopIteration):
        next(pf)


# -------------------------------------------- straggler watchdog ----

def test_watchdog_flags_injected_slow_step(arch):
    """An injected 0.3 s stall on step 10 (the rolling window is warm
    by then) is flagged and escalated by the shared watchdog."""
    wd = StepWatchdog(threshold=3.0, window=32, escalate_after=1)
    plan = FaultPlan([FaultEvent(site="slow_step", step=10, delay_s=0.3)])
    with faults.arm(plan):
        res = fit_streaming(arch, _LCFG, watchdog=wd, **_KW)
    assert res.completed and plan.events[0].fired == 1
    assert 10 in wd.flagged_steps
    assert 10 in wd.escalations
    assert len(wd.window) == min(res.n_steps, 32)


# ------------------------------------ elastic crash-equivalence ----

_ELASTIC_KW = "epochs=2, batch_size=32, lr=5e-3, seed=0"


@pytest.fixture(scope="module")
def elastic_ref(tmp_path_factory):
    """240 docs / 4 shards, logical world 2 → 2 steps per shard slot,
    2 groups per epoch, 8 steps over 2 epochs.  The reference is an
    uninterrupted elastic run on 2 fake devices; its params/counters
    are materialized so other subprocesses (1 or 2 devices) can compare
    bitwise."""
    base = tmp_path_factory.mktemp("ft_elastic")
    root = _build_archive(str(base / "arch"), n_docs=240, n_shards=4)
    ref = str(base / "ref")
    os.makedirs(ref)
    run_in_subprocess(f"""
        import json, numpy as np, jax
        from repro.models.linear import BBitLinearConfig
        from repro.train import fit_streaming
        r = fit_streaming({root!r}, BBitLinearConfig(k=16, b=4),
                          data_parallel=2, elastic=True, {_ELASTIC_KW})
        assert r.completed and len(jax.devices()) == 2
        np.savez({ref!r} + "/params.npz",
                 *[np.asarray(x) for x in jax.tree.leaves(r.params)])
        np.savez({ref!r} + "/avg.npz",
                 *[np.asarray(x) for x in jax.tree.leaves(r.avg_params)])
        with open({ref!r} + "/counters.json", "w") as f:
            json.dump(dict(n_steps=r.n_steps, seen=r.examples_seen,
                           acc=r.progressive_acc,
                           shards=r.shards_processed), f)
        print("OK")
    """, devices=2)
    return root, ref


_ELASTIC_COMPARE = """
    def compare(r, ref):
        import json, numpy as np, jax
        for name, tree in (("params", r.params), ("avg", r.avg_params)):
            want = np.load(ref + "/" + name + ".npz")
            got = [np.asarray(x) for x in jax.tree.leaves(tree)]
            assert len(got) == len(want.files)
            for a, k in zip(got, want.files):
                assert np.array_equal(a, want[k]), (name, k)
        with open(ref + "/counters.json") as f:
            c = json.load(f)
        assert r.n_steps == c["n_steps"]
        assert r.examples_seen == c["seen"]
        assert r.shards_processed == c["shards"]
        assert abs(r.progressive_acc - c["acc"]) < 1e-12
"""


def test_elastic_midshard_crash_resumes_2_to_1(elastic_ref, tmp_path):
    """Killed mid-group on 2 devices (step 5 of 8), resumed to
    completion on ONE device under the same logical world: bit-identical
    params, exact counters, and a lineage recording both realizations."""
    root, ref = elastic_ref
    ck = str(tmp_path / "ck")
    run_in_subprocess(f"""
        from repro.ft import FaultEvent, FaultPlan, InjectedCrash, faults
        from repro.models.linear import BBitLinearConfig
        from repro.train import fit_streaming
        plan = FaultPlan([FaultEvent(site="train_step", step=5, times=1)])
        try:
            with faults.arm(plan):
                fit_streaming({root!r}, BBitLinearConfig(k=16, b=4),
                              data_parallel=2, elastic=True,
                              ckpt_dir={ck!r}, {_ELASTIC_KW})
            raise SystemExit("injected crash did not fire")
        except InjectedCrash:
            pass
        assert plan.events[0].fired == 1
        print("OK")
    """, devices=2)
    run_in_subprocess(_ELASTIC_COMPARE + f"""
    import jax
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming
    assert len(jax.devices()) == 1
    r = fit_streaming({root!r}, BBitLinearConfig(k=16, b=4),
                      data_parallel=2, elastic=True, ckpt_dir={ck!r},
                      {_ELASTIC_KW})
    assert r.completed
    compare(r, {ref!r})
    phys = [(e["logical"], e["physical"]) for e in r.topology_lineage]
    assert (2, 2) in phys and phys[-1] == (2, 1), phys
    print("OK")
    """, devices=1)


def test_elastic_torn_ckpt_on_1_resumes_on_2(elastic_ref, tmp_path):
    """The other direction plus a torn write: a supervised 1-device run
    (logical world 2 folded onto it) tears its first checkpoint, self-
    heals, stops at the epoch boundary; a 2-device run adopts the
    checkpoint's schedule and finishes bit-identical to the
    2-device-throughout reference."""
    root, ref = elastic_ref
    ck = str(tmp_path / "ck")
    run_in_subprocess(f"""
        import os
        from repro.ft import (BackoffPolicy, FaultEvent, FaultPlan,
                              faults)
        from repro.models.linear import BBitLinearConfig
        from repro.train import RestartPolicy, run_supervised
        plan = FaultPlan([FaultEvent(site="ckpt_write", times=1)])
        pol = RestartPolicy(max_restarts=2,
                            backoff=BackoffPolicy(base_s=0.005,
                                                  factor=2.0, cap_s=0.02,
                                                  jitter_frac=0.0))
        with faults.arm(plan):
            sup = run_supervised({root!r}, BBitLinearConfig(k=16, b=4),
                                 policy=pol, ckpt_dir={ck!r},
                                 data_parallel=2, elastic=True,
                                 stop_after_shards=4, {_ELASTIC_KW})
        assert sup.restarts == 1 and plan.events[0].fired == 1
        assert not sup.result.completed
        assert sup.result.shards_processed == 4
        q = os.path.join({ck!r}, "quarantine")
        assert os.path.isdir(q) and len(os.listdir(q)) == 1
        print("OK")
    """, devices=1)
    run_in_subprocess(_ELASTIC_COMPARE + f"""
    import jax
    from repro.models.linear import BBitLinearConfig
    from repro.train import fit_streaming
    assert len(jax.devices()) == 2
    r = fit_streaming({root!r}, BBitLinearConfig(k=16, b=4),
                      data_parallel=2, elastic=True, ckpt_dir={ck!r},
                      {_ELASTIC_KW})
    assert r.completed
    compare(r, {ref!r})
    phys = [(e["logical"], e["physical"]) for e in r.topology_lineage]
    assert phys[0] == (2, 1) and phys[-1] == (2, 2), phys
    print("OK")
    """, devices=2)


def test_non_elastic_resume_still_refuses_topology_change(elastic_ref,
                                                          tmp_path):
    """Without elastic=True the old contract holds: a dp checkpoint
    resumed on a smaller world fails loudly (and names the fix)."""
    root, _ref = elastic_ref
    ck = str(tmp_path / "ck")
    run_in_subprocess(f"""
        from repro.models.linear import BBitLinearConfig
        from repro.train import fit_streaming
        part = fit_streaming({root!r}, BBitLinearConfig(k=16, b=4),
                             data_parallel=2, ckpt_dir={ck!r},
                             stop_after_shards=2, {_ELASTIC_KW})
        assert not part.completed
        print("OK")
    """, devices=2)
    run_in_subprocess(f"""
        from repro.models.linear import BBitLinearConfig
        from repro.train import fit_streaming
        try:
            fit_streaming({root!r}, BBitLinearConfig(k=16, b=4),
                          data_parallel=2, ckpt_dir={ck!r},
                          {_ELASTIC_KW})
            raise SystemExit("2-device schedule ran on 1 device "
                             "without elastic=True")
        except ValueError as e:
            assert "elastic" in str(e), e
        print("OK")
    """, devices=1)


# --------------------------------------- serving client retry ----

def test_score_client_retries_admission_rejection():
    """Opt-in bounded retry on 429: with the server's in-flight budget
    held, a retries=0 client fails immediately while a retrying client
    honors Retry-After/backoff and succeeds once the budget frees up."""
    from repro.serving import (AdmissionController, HTTPStatusError,
                               ScoreClient, ScoreServer)
    from repro.models.linear import init_bbit_linear
    from repro.serving import HashedClassifierEngine

    cfg = BBitLinearConfig(k=8, b=4)
    eng = HashedClassifierEngine(
        init_bbit_linear(cfg, jax.random.key(0)), cfg, seed=3,
        scheme="oph", max_batch=8, max_wait_ms=5.0)
    ctrl = AdmissionController(limit=8, retry_after_s=0.05)
    srv = ScoreServer(eng, port=0, admission=ctrl)
    srv.start_in_thread()
    try:
        ctrl.acquire(8)  # exhaust the in-flight budget by hand
        plain = ScoreClient("127.0.0.1", srv.port)
        with pytest.raises(HTTPStatusError) as exc:
            plain.score([[1, 2, 3]])
        assert exc.value.status == 429
        assert exc.value.retry_after_s and exc.value.retry_after_s > 0
        plain.close()

        retrier = ScoreClient(
            "127.0.0.1", srv.port, retries=6,
            backoff=BackoffPolicy(base_s=0.05, factor=2.0, cap_s=0.25,
                                  jitter_frac=0.0))
        rejected_before = ctrl.rejected
        t = threading.Timer(0.25, ctrl.release, args=(8,))
        t.start()
        try:
            out = retrier.score([[1, 2, 3], [4, 5, 6]])
        finally:
            t.join()
            retrier.close()
        assert len(out["scores"]) == 2
        assert ctrl.rejected > rejected_before  # it really was refused
    finally:
        srv.request_drain()
        assert srv.wait_finished(timeout=30)
