"""Numeric ground-truth tests for the sequence mixers (SSD, mLSTM,
blockwise attention) — the checks that anchored development."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import blockwise_attention
from repro.models.ssm import ssd_chunked
from repro.models.xlstm import _mlstm_core


def _naive_attention(q, k, v, causal=True, q_offset=0, kv_valid=None):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).reshape(
        b, h, sq, k.shape[1]) / np.sqrt(d)
    qpos = q_offset + np.arange(sq)
    kpos = np.arange(k.shape[1])
    mask = np.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if kv_valid is not None:
        mask &= kpos[None, :] < kv_valid
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    pg = p.reshape(b, kvh, g, sq, k.shape[1])
    return jnp.einsum("bkgqs,bskd->bqkgd", pg, v).reshape(b, sq, h, d)


@pytest.mark.parametrize("impl", ["loop", "scan"])
@pytest.mark.parametrize("qc,kc", [(8, 16), (16, 8), (64, 64)])
def test_blockwise_attention_exact(impl, qc, kc):
    rng = np.random.default_rng(qc * 100 + kc)
    q = jnp.asarray(rng.normal(size=(2, 37, 8, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 53, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 53, 2, 16)).astype(np.float32))
    for causal, off, kvlen in [(True, 16, None), (False, 0, None),
                               (False, 0, 29)]:
        got = blockwise_attention(q, k, v, causal=causal, q_offset=off,
                                  kv_valid_len=kvlen, q_chunk=qc,
                                  kv_chunk=kc, impl=impl)
        want = _naive_attention(q, k, v, causal=causal, q_offset=off,
                                kv_valid=kvlen)
        assert float(jnp.abs(got - want).max()) < 2e-5


def test_ssd_chunked_vs_recurrence():
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 37, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, H)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)).astype(np.float32))
    bi = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    ci = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(B, H, N, P)).astype(np.float32))
    y_want = np.zeros((B, S, H, P), np.float32)
    h = np.asarray(h0).copy()
    for t in range(S):
        dec = np.exp(np.asarray(dt)[:, t] * np.asarray(a)[None])
        h = h * dec[:, :, None, None] + np.einsum(
            "bn,bhp,bh->bhnp", np.asarray(bi)[:, t],
            np.asarray(x)[:, t], np.asarray(dt)[:, t])
        y_want[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(ci)[:, t], h)
    for chunk in (8, 37, 64):
        y, hf = ssd_chunked(x, dt, a, bi, ci, h0, chunk=chunk)
        assert float(jnp.abs(y - y_want).max()) < 1e-4
        assert float(jnp.abs(hf - h).max()) < 1e-4


def test_mlstm_chunked_vs_recurrence():
    rng = np.random.default_rng(1)
    B, S, H, P = 2, 29, 3, 4
    q = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32)
                    ) / np.sqrt(P)
    v = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    ir = jnp.asarray(rng.normal(size=(B, S, H)).astype(np.float32))
    fr = jnp.asarray(rng.normal(size=(B, S, H)).astype(np.float32) + 2)
    y_want = np.zeros((B, S, H, P))
    logf = np.log(1 / (1 + np.exp(-np.asarray(fr))))
    for b in range(B):
        for h in range(H):
            C = np.zeros((P, P)); n = np.zeros(P); m = -1e30
            for t in range(S):
                m_new = max(m + logf[b, t, h], float(ir[b, t, h]))
                C = C * np.exp(m + logf[b, t, h] - m_new) \
                    + np.exp(float(ir[b, t, h]) - m_new) \
                    * np.outer(v[b, t, h], k[b, t, h])
                n = n * np.exp(m + logf[b, t, h] - m_new) \
                    + np.exp(float(ir[b, t, h]) - m_new) * k[b, t, h]
                m = m_new
                num = C @ q[b, t, h]
                den = max(abs(float(n @ q[b, t, h])), np.exp(-m))
                y_want[b, t, h] = num / den
    for chunk in (4, 29, 64):
        got, _ = _mlstm_core(q, k, v, ir, fr, None, chunk)
        assert float(jnp.abs(got - y_want).max()) < 1e-4
    # split-state continuation
    g1, st = _mlstm_core(q[:, :13], k[:, :13], v[:, :13], ir[:, :13],
                         fr[:, :13], None, 8)
    g2, _ = _mlstm_core(q[:, 13:], k[:, 13:], v[:, 13:], ir[:, 13:],
                        fr[:, 13:], st, 8)
    err = float(jnp.abs(jnp.concatenate([g1, g2], 1) - y_want).max())
    assert err < 1e-4


def test_mamba2_prefill_decode_parity():
    from repro.models.ssm import (init_mamba2_params, mamba2_forward,
                                  mamba2_decode_step)
    cfg = ArchConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                     n_heads=4, n_kv_heads=4, d_ff=64, vocab=100,
                     ssm_state=8, ssm_head_dim=8, ssm_expand=2,
                     dtype="float32")
    params = init_mamba2_params(cfg, jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 13, 32)).astype(np.float32))
    y_all, (hT, convT) = mamba2_forward(params, x, cfg, chunk=4)
    st = (jnp.zeros((2, 8, 8, 8), jnp.float32),
          jnp.zeros((2, 3, 80), jnp.float32))
    ys = []
    for t in range(13):
        y1, st = mamba2_decode_step(params, x[:, t:t + 1], cfg, st)
        ys.append(y1)
    err = float(jnp.abs(y_all - jnp.concatenate(ys, 1)).max())
    assert err < 1e-3
    assert float(jnp.abs(hT - st[0]).max()) < 1e-3
