"""Packed-input fused logits: the Pallas kernels (interpret mode) and
the XLA fallback must agree with the widened reference across b, ragged
``oph_zero`` masks, and non-lane-multiple k.

Exactness contract: the packed kernels are BIT-exact vs the widened
kernels (identical contraction order, only the input format differs);
vs the gather reference — a mathematically equal but differently
associated sum — they are allclose, matching the tolerance the widened
kernels themselves are validated to in test_kernels.py."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.bbit import pack_codes, unpack_codes_jnp
from repro.kernels import ops, ref
from repro.kernels.bbit_linear import (
    bbit_linear_bwd_dw_pallas,
    bbit_linear_fwd_pallas,
    bbit_linear_packed_bwd_dw_pallas,
    bbit_linear_packed_fwd_pallas,
)
from repro.models.linear import (
    BBitLinearConfig, bbit_logits, bbit_logits_packed, init_bbit_linear,
)


def _case(b, k, n=17, c=3, seed=None, empty_frac=0.0):
    rng = np.random.default_rng(b * 1031 + k if seed is None else seed)
    v = 1 << b
    codes = rng.integers(0, v, size=(n, k)).astype(np.uint16)
    packed = jnp.asarray(pack_codes(codes, b))
    weights = jnp.asarray(rng.normal(size=(k, v, c)).astype(np.float32))
    empty = None
    if empty_frac:
        # ragged: wildly different empty counts per row, incl. all-empty
        mask = rng.random((n, k)) < empty_frac
        mask[0] = True
        mask[1] = False
        empty = jnp.asarray(np.packbits(mask, axis=1))
    dout = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    return codes, packed, weights, empty, dout


@pytest.mark.parametrize("b", [1, 2, 4, 8])
@pytest.mark.parametrize("k", [1, 8, 37, 63, 64])
def test_packed_kernel_bit_exact_vs_widened_kernel(b, k):
    codes, packed, weights, _, dout = _case(b, k)
    v = 1 << b
    want = bbit_linear_fwd_pallas(jnp.asarray(codes.astype(np.int32)),
                                  weights, interpret=True)
    got = bbit_linear_packed_fwd_pallas(packed, weights, k=k, bits=b,
                                        interpret=True)
    assert np.array_equal(np.asarray(want), np.asarray(got))
    dwant = bbit_linear_bwd_dw_pallas(jnp.asarray(codes.astype(np.int32)),
                                      dout, v, interpret=True)
    dgot = bbit_linear_packed_bwd_dw_pallas(packed, dout, v, k=k, bits=b,
                                            interpret=True)
    assert np.array_equal(np.asarray(dwant), np.asarray(dgot))


@pytest.mark.parametrize("b", [1, 2, 4, 8])
@pytest.mark.parametrize("k", [8, 37, 64])
@pytest.mark.parametrize("empty_frac", [0.3, 0.9])
def test_packed_kernel_masked_matches_reference(b, k, empty_frac):
    _, packed, weights, empty, dout = _case(b, k, empty_frac=empty_frac)
    v = 1 << b
    want = ref.bbit_linear_packed_fwd(packed, weights, k, b, empty=empty)
    got = bbit_linear_packed_fwd_pallas(packed, weights, k=k, bits=b,
                                        empty=empty, interpret=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
    dwant = ref.bbit_linear_packed_bwd_dw(packed, dout, v, k, b,
                                          empty=empty)
    dgot = bbit_linear_packed_bwd_dw_pallas(packed, dout, v, k=k, bits=b,
                                            empty=empty, interpret=True)
    np.testing.assert_allclose(np.asarray(dwant), np.asarray(dgot),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("masked", [False, True])
def test_packed_custom_vjp_grads_match_reference(masked):
    k, b = 16, 4
    _, packed, weights, empty, _ = _case(b, k,
                                         empty_frac=0.4 if masked else 0.0)

    def loss_kernel(w):
        return jnp.sum(ops.bbit_linear_packed(packed, w, k, b,
                                              empty=empty) ** 2)

    def loss_ref(w):
        return jnp.sum(ref.bbit_linear_packed_fwd(packed, w, k, b,
                                                  empty=empty) ** 2)

    g = jax.grad(loss_kernel)(weights)
    gref = jax.grad(loss_ref)(weights)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gref),
                               rtol=1e-4, atol=1e-4)


def test_packed_fallback_used_for_non_byte_aligned_b():
    # b=3 codes straddle bytes — dispatch must fall to the XLA path and
    # still match the widened gather exactly
    k, b, v = 16, 3, 8
    rng = np.random.default_rng(0)
    codes = rng.integers(0, v, size=(9, k)).astype(np.uint16)
    packed = jnp.asarray(pack_codes(codes, b))
    weights = jnp.asarray(rng.normal(size=(k, v, 2)).astype(np.float32))
    got = ops.bbit_linear_packed(packed, weights, k, b)
    want = ref.bbit_linear_fwd(jnp.asarray(codes.astype(np.int32)),
                               weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("use_kernel", ["never", "always"])
@pytest.mark.parametrize("masked", [False, True])
def test_bbit_logits_packed_matches_widened_logits(use_kernel, masked):
    """Model-level parity on BOTH dispatch paths (fallback and
    interpret-mode kernel), with bias + normalize applied."""
    k, b = 24, 4
    codes, packed, _, empty, _ = _case(b, k,
                                       empty_frac=0.5 if masked else 0.0)
    cfg = BBitLinearConfig(k=k, b=b, use_kernel=use_kernel,
                           normalize=True)
    params = init_bbit_linear(cfg, jax.random.key(3))
    from repro.core.bbit import unpack_mask_jnp
    wide = bbit_logits(
        params, unpack_codes_jnp(packed, k, b).astype(jnp.int32), cfg,
        empty=None if empty is None else unpack_mask_jnp(empty, k))
    got = bbit_logits_packed(params, packed, cfg, empty_packed=empty)
    np.testing.assert_allclose(np.asarray(wide), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
    if use_kernel == "never" and not masked:
        # the streaming trainer's CPU path: bit-identical to the old
        # explicit unpack + gather two-step
        assert np.array_equal(np.asarray(wide), np.asarray(got))
