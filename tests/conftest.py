"""Shared fixtures. NOTE: no global XLA device-count flags here — smoke
tests and benches must see the real single CPU device; multi-device
tests spawn subprocesses with their own XLA_FLAGS."""
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.fixture(scope="session")
def repo_src() -> str:
    return os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 900):
    """Runs python code in a fresh process with N fake XLA devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout[-4000:]}\n"
        f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout
