"""HTTP serving tier: admission/backpressure, stats correctness,
graceful drain under load, versioned hot-reload exactness, adaptive
bucket convergence, and watchdog-backed health."""
import json
import tempfile
import threading
import time

import numpy as np
import pytest

import jax

from repro.ckpt import checkpoint as ckpt
from repro.models.linear import BBitLinearConfig, init_bbit_linear
from repro.serving import (AdmissionController, BucketBatcher, Draining,
                           HashedClassifierEngine, HTTPStatusError,
                           NnzHistogram, Overloaded, ScoreClient,
                           ScoreServer, StatsWindow, VersionedScore)


def _mk_engine(key=0, version="v0", **kw):
    cfg = BBitLinearConfig(k=8, b=4)
    params = init_bbit_linear(cfg, jax.random.key(key))
    kw.setdefault("scheme", "oph")
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait_ms", 20.0)
    kw.setdefault("nnz_buckets", (16, 64))
    return HashedClassifierEngine(params, cfg, seed=3, version=version,
                                  **kw), cfg


# Bitwise notes: per-row scores are bit-identical GIVEN the same padded
# batch shape (PR-5's contract); XLA may differ in the last ulp across
# row-bucket shapes.  Bitwise tests therefore send exactly ``max_batch``
# same-lane docs per request — the lane fills and dispatches as ONE
# deterministic full batch, the same shape ``score_docs`` pads the
# oracle to.


def _docs(n, rng=None, lo=3, hi=14):
    rng = rng or np.random.default_rng(5)
    return [np.sort(rng.choice(50000, size=int(rng.integers(lo, hi)),
                               replace=False)) for _ in range(n)]


@pytest.fixture(scope="module")
def served():
    eng, _cfg = _mk_engine()
    srv = ScoreServer(eng, port=0)
    srv.start_in_thread()
    client = ScoreClient("127.0.0.1", srv.port)
    yield eng, srv, client
    client.close()
    srv.request_drain()
    assert srv.wait_finished(timeout=30)


# ------------------------------------------------------------- stats ----

def test_stats_window_percentiles_match_numpy():
    w = StatsWindow(256)
    rng = np.random.default_rng(0)
    lats = rng.gamma(2.0, 0.01, size=200)
    for x in lats:
        w.record(float(x), rows=2, tenant="t")
    s = w.snapshot()
    assert s["count"] == 200
    for q, key in ((50, "p50_ms"), (95, "p95_ms"), (99, "p99_ms")):
        assert s[key] == pytest.approx(
            float(np.percentile(lats * 1e3, q)), rel=1e-6)
    assert s["per_tenant_rows"] == {"t": 400}


def test_stats_window_wraps_to_most_recent():
    w = StatsWindow(8)
    for x in [5.0] * 8 + [1.0] * 8:   # old epoch fully overwritten
        w.record(x)
    s = w.snapshot()
    assert s["count"] == 16           # lifetime count
    assert s["window"] == 8
    assert s["p99_ms"] == pytest.approx(1000.0)


def test_nnz_histogram_suggests_tight_buckets():
    h = NnzHistogram()
    rng = np.random.default_rng(1)
    for n in rng.integers(3, 30, size=500):
        h.record(int(n))
    assert h.suggest_buckets(min_samples=1000) is None  # not enough yet
    got = h.suggest_buckets(max_buckets=4, min_samples=64)
    assert got and max(got) <= 32     # pow-2 edges covering nnz<30
    assert list(got) == sorted(got)


# --------------------------------------------------------- admission ----

def test_admission_rejects_fast_and_drains():
    a = AdmissionController(limit=4, retry_after_s=0.2)
    a.acquire(3)
    with pytest.raises(Overloaded) as exc:
        a.acquire(2)                  # 3+2 > 4
    assert exc.value.retry_after_s == pytest.approx(0.2)
    a.acquire(1)                      # exactly at the limit is fine
    a.begin_drain()
    with pytest.raises(Draining):
        a.acquire(1)
    assert not a.wait_idle(timeout=0.05)   # 4 rows still held
    a.release(3)
    a.release(1)
    assert a.wait_idle(timeout=5)
    snap = a.snapshot()
    assert snap == {"inflight": 0, "limit": 4, "draining": True,
                    "admitted": 4, "rejected": 2, "refused_draining": 1}


# -------------------------------------------------------- HTTP basics ----

def test_http_score_bitwise_matches_oracle(served):
    eng, _srv, client = served
    docs = _docs(8)                   # exactly max_batch → one full batch
    resp = client.score(docs, tenant="alpha")
    want = np.asarray(eng.score_docs(docs), np.float64)
    assert resp["version"] == "v0"
    assert np.array_equal(np.asarray(resp["scores"], np.float64).ravel(),
                          want.ravel())


def test_http_ndjson_streams_in_order_with_versions(served):
    eng, _srv, client = served
    docs = _docs(8, rng=np.random.default_rng(9))
    lines = client.score_ndjson(docs)
    assert [ln["i"] for ln in lines] == list(range(8))
    assert all(ln["version"] == "v0" for ln in lines)
    want = np.asarray(eng.score_docs(docs), np.float64)
    got = np.asarray([ln["score"] for ln in lines], np.float64)
    assert np.array_equal(got.ravel(), want.ravel())


def test_http_rejects_malformed_input(served):
    _eng, _srv, client = served
    for bad in ({"docs": []}, {"docs": "nope"}, {"docs": [["a"]]},
                {"docs": [[-3, 4]]}):
        with pytest.raises(HTTPStatusError) as exc:
            client._json_call("POST", "/score", bad)
        assert exc.value.status == 400
    with pytest.raises(HTTPStatusError) as exc:
        client._json_call("GET", "/nope")
    assert exc.value.status == 404
    with pytest.raises(HTTPStatusError) as exc:
        client._json_call("GET", "/score")
    assert exc.value.status == 405


def test_http_429_backpressure_with_retry_after(served):
    _eng, srv, client = served
    with pytest.raises(HTTPStatusError) as exc:
        client.score([[1, 2, 3]] * (srv.admission.limit + 1))
    assert exc.value.status == 429
    assert exc.value.retry_after_s and exc.value.retry_after_s > 0
    assert srv.admission.rejected >= srv.admission.limit + 1


def test_http_status_reflects_traffic(served):
    eng, _srv, client = served
    before = client.status()["engine"]["count"]
    lats = []
    for _ in range(6):
        t0 = time.perf_counter()
        client.score(_docs(4), tenant="beta")
        lats.append(time.perf_counter() - t0)
    st = client.status()
    e = st["engine"]
    assert st["health"] == "ok"
    assert e["count"] == before + 24
    assert e["per_tenant_rows"]["beta"] == 24
    assert 0 < e["p50_ms"] <= e["p95_ms"] <= e["p99_ms"]
    # engine-side latency is submit→resolve; it must sit below the
    # client-observed HTTP round-trip for the same traffic
    assert e["p50_ms"] <= float(np.percentile(np.array(lats) * 1e3, 99))
    assert e["compile_misses"] == 0
    assert st["admission"]["inflight"] == 0
    hz = client.healthz()
    assert hz["health"] == "ok"


# ------------------------------------------------------------ reload ----

def test_hot_reload_versions_are_exact_under_traffic():
    from repro.serving.reload import WeightSet

    eng, cfg = _mk_engine(key=0, version="old")
    new_params = init_bbit_linear(cfg, jax.random.key(7))
    srv = ScoreServer(eng, port=0)
    srv.start_in_thread()
    docs = _docs(8, rng=np.random.default_rng(3))  # one full batch
    # both single-version oracles from the SAME engine, each pinned to
    # its WeightSet, same (8, nnz_bucket) shape the server batches at
    want_old = np.asarray(
        eng.score_docs(docs, weights=eng.current_weights()), np.float64)
    w_new = WeightSet(version="staged", params=tuple(
        jax.device_put(new_params, d) for d in eng.devices))
    want_new = np.asarray(eng.score_docs(docs, weights=w_new),
                          np.float64)
    assert not np.array_equal(want_old, want_new)

    tmp = tempfile.mkdtemp()
    ckpt.publish_params(tmp, 9, new_params)

    stop = threading.Event()
    failures, seen_versions = [], set()

    def hammer():
        c = ScoreClient("127.0.0.1", srv.port)
        while not stop.is_set():
            r = c.score(docs)
            got = np.asarray(r["scores"], np.float64).ravel()
            seen_versions.add(r["version"])
            if r["version"] == "old":
                want = want_old
            elif r["version"] == "ckpt-9":
                want = want_new
            else:
                failures.append(("unknown-version", r["version"]))
                continue
            if not np.array_equal(got, want.ravel()):
                failures.append((r["version"], got.tolist()))
        c.close()

    t = threading.Thread(target=hammer)
    t.start()
    ctl = ScoreClient("127.0.0.1", srv.port)
    time.sleep(0.15)
    info = ctl.reload(tmp)           # mid-traffic swap
    assert info["version"] == "ckpt-9" and info["previous"] == "old"
    time.sleep(0.15)
    stop.set()
    t.join(timeout=30)
    assert not t.is_alive()
    assert not failures, failures[:2]
    assert seen_versions == {"old", "ckpt-9"}   # traffic saw both sides
    ctl.close()
    srv.request_drain()
    assert srv.wait_finished(timeout=30)


def test_reload_errors_leave_weights_untouched(served):
    eng, _srv, client = served
    before = eng.version
    with pytest.raises(HTTPStatusError) as exc:
        client.reload(tempfile.mkdtemp())         # nothing there
    assert exc.value.status == 404
    wrong = init_bbit_linear(BBitLinearConfig(k=16, b=4),
                             jax.random.key(1))
    tmp = tempfile.mkdtemp()
    ckpt.publish_params(tmp, 1, wrong)            # k mismatch
    with pytest.raises(HTTPStatusError) as exc:
        client.reload(tmp)
    assert exc.value.status == 409
    assert eng.version == before


def test_mixed_version_batch_is_repaired_to_one_version():
    """If a reload lands between one request's micro-batches, /score
    re-scores pinned to one WeightSet — the response never mixes."""
    from repro.serving.reload import WeightSet

    class StubEngine:
        version = "w2"

        def __init__(self):
            self.pinned_calls = []
            self._w = WeightSet(version="w2", params=(None,))

        def submit(self, doc, tenant=None):
            import concurrent.futures
            f = concurrent.futures.Future()
            # deterministically mixed: half old, half new
            v = "w1" if len(self.pinned_calls) == 0 and doc[0] % 2 else "w2"
            f.set_result(VersionedScore(float(doc[0]), v))
            return f

        def current_weights(self):
            return self._w

        def score_docs(self, docs, weights=None):
            self.pinned_calls.append(weights)
            return np.asarray([float(d[0]) * 10 for d in docs],
                              np.float32)

        def stats(self):
            return {"version": self.version, "health": {"state": "ok"}}

        def close(self):
            pass

    eng = StubEngine()
    srv = ScoreServer(eng, port=0,
                      admission=AdmissionController(limit=64))
    srv.start_in_thread()
    client = ScoreClient("127.0.0.1", srv.port)
    resp = client.score([[1], [2], [3], [4]])
    assert resp["version"] == "w2"
    assert eng.pinned_calls == [eng._w]     # repair used the pinned set
    assert resp["scores"] == [10.0, 20.0, 30.0, 40.0]
    client.close()
    srv.request_drain()
    assert srv.wait_finished(timeout=10)


# ------------------------------------------------------------- drain ----

def test_graceful_drain_under_load_drops_nothing():
    eng, _cfg = _mk_engine(max_wait_ms=5.0)
    srv = ScoreServer(eng, port=0)
    srv.start_in_thread()
    results, errors = [], []
    stop = threading.Event()

    def hammer(seed):
        c = ScoreClient("127.0.0.1", srv.port, timeout=30)
        docs = _docs(4, rng=np.random.default_rng(seed))
        while not stop.is_set():
            try:
                r = c.score(docs)
                results.append(len(r["scores"]))
            except HTTPStatusError as e:
                if e.status == 503:       # refused during drain — fine
                    return
                errors.append(e)
                return
            except OSError:               # socket closed post-drain
                return
        c.close()

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.3)                       # real load in flight
    srv.request_drain()
    assert srv.wait_finished(timeout=30)
    stop.set()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    assert not errors, errors[:2]
    assert results                         # traffic actually flowed
    assert all(n == 4 for n in results)    # every 200 was complete
    assert srv.drained_clean is True
    assert srv.admission.snapshot()["inflight"] == 0


# ------------------------------------------------- adaptive buckets ----

def test_adaptive_buckets_converge_on_skewed_workload():
    eng, _cfg = _mk_engine(nnz_buckets=(2048, 8192),
                           max_batch=4)     # grid far too wide
    before = eng.nnz_buckets
    docs = _docs(96, rng=np.random.default_rng(2), lo=3, hi=14)
    for f in [eng.submit(d) for d in docs]:
        f.result(timeout=60)
    got = eng.adapt_buckets(max_buckets=3)
    assert eng.rebuckets == 1
    assert got != before and max(got) <= 16   # converged to the traffic
    # post-rebucket traffic scores correctly on the new lanes with no
    # serve-time compiles (adapt precompiled them first); groups of
    # exactly max_batch same-lane docs → deterministic full batches,
    # bitwise-comparable to the same-shape score_docs oracle
    misses = eng.compile_misses
    rng = np.random.default_rng(8)
    for _ in range(3):
        group = _docs(4, rng=rng, lo=9, hi=14)   # all route to lane 16
        futs = [eng.submit(d) for d in group]
        got_scores = np.asarray([float(f.result(timeout=60))
                                 for f in futs], np.float64)
        want = np.asarray(eng.score_docs(group), np.float64)
        assert np.array_equal(got_scores.ravel(), want.ravel())
    assert eng.compile_misses == misses
    eng.close()


def test_adapt_every_triggers_background_rebucket():
    eng, _cfg = _mk_engine(nnz_buckets=(2048, 8192), max_batch=4,
                           adapt_every=80)
    docs = _docs(200, rng=np.random.default_rng(4), lo=3, hi=14)
    for f in [eng.submit(d) for d in docs]:
        f.result(timeout=60)
    deadline = time.time() + 30
    while eng.rebuckets == 0 and time.time() < deadline:
        time.sleep(0.05)
    assert eng.rebuckets >= 1
    assert max(eng.nnz_buckets) <= 16
    eng.close()


# ---------------------------------------------------------- watchdog ----

def test_stalled_resolve_flips_health_degraded():
    gate = threading.Event()

    def dispatch(key, items):
        return items

    def resolve(handle):
        gate.wait(5)                   # a wedged device sync
        return [x * 2 for x in handle]

    b = BucketBatcher(dispatch, resolve, route=lambda x: 1, max_batch=2,
                      max_wait_ms=1.0, stall_after_s=0.05)
    assert b.health()["state"] == "ok"
    fut = b.submit(3)
    deadline = time.time() + 5
    while b.health()["state"] == "ok" and time.time() < deadline:
        time.sleep(0.01)
    h = b.health()
    assert h["state"] == "degraded"
    assert h["stalled_thread"] == "resolve"
    assert h["stalled_s"] >= 0.05
    gate.set()
    assert fut.result(timeout=10) == 6
    # recovers once unwedged (the resolver clears its live stall stamp
    # just after resolving futures — poll briefly)
    deadline = time.time() + 5
    while b.health()["state"] != "ok" and time.time() < deadline:
        time.sleep(0.01)
    assert b.health()["state"] == "ok"
    b.close()


def test_degraded_health_surfaces_in_status_endpoint():
    eng, _cfg = _mk_engine()
    srv = ScoreServer(eng, port=0)
    srv.start_in_thread()
    client = ScoreClient("127.0.0.1", srv.port)
    # wedge the batcher's resolve by monkeypatching the live timestamp
    eng.batcher._resolve_started = time.perf_counter() - 60.0
    eng.batcher.stall_after_s = 1.0
    st = client.status()
    assert st["health"] == "degraded"
    with pytest.raises(HTTPStatusError) as exc:
        client.healthz()
    assert exc.value.status == 503
    eng.batcher._resolve_started = None
    assert client.status()["health"] == "ok"
    client.close()
    srv.request_drain()
    assert srv.wait_finished(timeout=30)
