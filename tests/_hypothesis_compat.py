"""``hypothesis`` shim: the real library when installed, otherwise a
tiny deterministic fallback so the tier-1 suite runs without the
optional dependency.

Fallback semantics: ``@given(x=st.integers(...))`` reruns the test body
``max_examples`` times with draws from a fixed-seed numpy Generator —
plain parametrized sampling, no shrinking, no database.  Only the
strategy/settings surface this repo's tests use is implemented
(``integers``, ``floats``, ``sampled_from``; ``settings(max_examples,
deadline)``).  Install the ``test`` extra (``pip install -e .[test]``)
to get real property-based exploration.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(
                lambda rng: elems[int(rng.integers(len(elems)))])

    st = _Strategies()

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # Zero-arg runner: pytest must not mistake the strategy
            # parameters for fixtures, so no functools.wraps here.
            def runner():
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                rng = _np.random.default_rng(0xB81F)
                for _ in range(n):
                    fn(**{name: s.draw(rng)
                          for name, s in strategies.items()})
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
