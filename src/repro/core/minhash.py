"""Minwise hashing (paper §2) over padded sparse batches.

Two execution paths:

  * ``minhash_jnp``   — pure-jnp, uint32 multiply-shift family, chunked
                        over k to bound memory.  This is also the oracle
                        the Pallas kernel (`repro.kernels.minhash`) is
                        validated against.
  * ``minhash_numpy`` — exact mod-Mersenne(2^61-1) family (the paper's
                        Eq. 17), used by the offline preprocessing path
                        of the data pipeline.

Both return the raw min-hash values z_j = min_{t∈S} h_j(t); b-bit code
extraction lives in ``repro.core.bbit``.
"""
from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SparseBatch
from repro.core.universal_hash import (
    ModPrimeHash,
    MultiplyShiftHash,
    PermutationHash,
    _fmix32,
)

UINT32_MAX = jnp.uint32(0xFFFFFFFF)


@functools.partial(jax.jit, static_argnames=("k_chunk", "m_chunk"))
def minhash_jnp(
    indices: jax.Array,
    mask: jax.Array,
    a: jax.Array,
    b: jax.Array,
    k_chunk: int = 128,
    m_chunk: int = 512,
) -> jax.Array:
    """Min-hash of each row's valid indices under k multiply-shift hashes.

    Args:
      indices: int32 (n, m) padded nonzero feature ids.
      mask:    bool  (n, m).
      a, b:    uint32 (k,) multiply-shift parameters (a odd).
      k_chunk, m_chunk: tile sizes; the live intermediate is
        (n, m_chunk, k_chunk) — double chunking keeps heavy-tailed
        documents (huge max_nnz) from exploding memory.

    Returns:
      uint32 (n, k) min-hash values (UINT32_MAX for empty rows).
    """
    n, m = indices.shape
    k = a.shape[0]
    pad_k = (-k) % k_chunk
    a_p = jnp.pad(a, (0, pad_k), constant_values=1)
    b_p = jnp.pad(b, (0, pad_k), constant_values=0)
    nk = (k + pad_k) // k_chunk
    a_c = a_p.reshape(nk, k_chunk)
    b_c = b_p.reshape(nk, k_chunk)

    pad_m = (-m) % m_chunk
    tu = jnp.pad(indices.astype(jnp.uint32), ((0, 0), (0, pad_m)))
    mk = jnp.pad(mask, ((0, 0), (0, pad_m)))
    nm = (m + pad_m) // m_chunk
    tu = tu.reshape(n, nm, m_chunk)
    mk = mk.reshape(n, nm, m_chunk)

    def one_k_chunk(carry, ab):
        ac, bc = ab

        def one_m_chunk(best, tm):
            t, mm = tm                          # (n, m_chunk) each
            h = _fmix32(ac[None, None, :] * t[:, :, None]
                        + bc[None, None, :])    # (n, m_chunk, k_chunk)
            h = jnp.where(mm[:, :, None], h, UINT32_MAX)
            return jnp.minimum(best, jnp.min(h, axis=1)), ()

        init = jnp.full((n, k_chunk), UINT32_MAX, jnp.uint32)
        best, _ = jax.lax.scan(
            one_m_chunk, init,
            (jnp.moveaxis(tu, 1, 0), jnp.moveaxis(mk, 1, 0)))
        return carry, best

    _, outs = jax.lax.scan(one_k_chunk, 0, (a_c, b_c))
    out = jnp.moveaxis(outs, 0, 1).reshape(n, nk * k_chunk)
    return out[:, :k]


def minhash_batch(batch: SparseBatch, family: MultiplyShiftHash,
                  k_chunk: int = 128) -> jax.Array:
    a, b = family.params()
    return minhash_jnp(batch.indices, batch.mask, a, b, k_chunk=k_chunk)


def minhash_numpy(
    indices: np.ndarray,
    mask: np.ndarray,
    family: Union[ModPrimeHash, PermutationHash],
    k_chunk: int = 64,
) -> np.ndarray:
    """Exact offline min-hash (paper Eq. 17 family or true permutations).

    Returns uint64 (n, k).
    """
    n, m = indices.shape
    k = family.k
    out = np.full((n, k), np.iinfo(np.uint64).max, dtype=np.uint64)
    sentinel = np.uint64(np.iinfo(np.uint64).max)
    for start in range(0, k, k_chunk):
        stop = min(start + k_chunk, k)
        if isinstance(family, ModPrimeHash):
            sub = ModPrimeHash(c1=family.c1[start:stop],
                               c2=family.c2[start:stop])
        else:
            sub = PermutationHash(perms=family.perms[start:stop])
        h = sub(indices).astype(np.uint64)  # (n, m, kc)
        h = np.where(mask[:, :, None], h, sentinel)
        out[:, start:stop] = h.min(axis=1)
    return out


def collision_probability(z1: np.ndarray, z2: np.ndarray) -> float:
    """\\hat{R}_M — fraction of matching min-hashes (paper Eq. 1)."""
    return float(np.mean(z1 == z2))
