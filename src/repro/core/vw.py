"""The VW hashing algorithm (paper §5.2) — signed feature hashing.

g_j = Σ_i u_i · r_i · 1{h(i)=j}   (paper Eq. 14), with r_i from the
two-point ±1 distribution (s=1) or the general sparse distribution
(Eq. 11) for the s≥1 study of [22].  Unbiased for inner products
(Eq. 15) with variance Eq. 16 — the formulas are in
``repro.core.estimators`` and property-tested against this code.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import SparseBatch
from repro.core.universal_hash import _fmix32


def _bucket_and_sign(indices: jax.Array, m: int, seed: int):
    """Per-feature bucket in [0, m) and ±1 sign, from two hash streams."""
    iu = indices.astype(jnp.uint32)
    hb = _fmix32(iu * jnp.uint32(0x9E3779B1) + jnp.uint32(seed * 2 + 1))
    hs = _fmix32(iu ^ jnp.uint32(0x7FEB352D + seed))
    bucket = (hb % jnp.uint32(m)).astype(jnp.int32)
    sign = jnp.where((hs >> jnp.uint32(31)) & 1 == 1, 1.0, -1.0).astype(
        jnp.float32
    )
    return bucket, sign


def _r_values(sign: jax.Array, indices: jax.Array, s: int, seed: int):
    """General r_i of Eq. (10)/(11): ±√s w.p. 1/(2s) each, else 0."""
    if s == 1:
        return sign
    iu = indices.astype(jnp.uint32)
    hz = _fmix32(iu * jnp.uint32(0x2545F491) + jnp.uint32(seed + 7))
    # keep with probability 1/s
    u = hz.astype(jnp.float32) / jnp.float32(2.0 ** 32)
    keep = u < (1.0 / s)
    return jnp.where(keep, sign * jnp.sqrt(jnp.float32(s)), 0.0)


@functools.partial(jax.jit, static_argnames=("m", "s", "seed"))
def vw_hash_sparse(
    indices: jax.Array,
    mask: jax.Array,
    values: Optional[jax.Array],
    m: int,
    s: int = 1,
    seed: int = 0,
) -> jax.Array:
    """VW-hashes a padded sparse batch into float32 (n, m) sketches."""
    n, _ = indices.shape
    bucket, sign = _bucket_and_sign(indices, m, seed)
    r = _r_values(sign, indices, s, seed)
    vals = values if values is not None else jnp.ones_like(r)
    contrib = jnp.where(mask, vals * r, 0.0)
    out = jnp.zeros((n, m), dtype=jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], indices.shape)
    return out.at[rows, bucket].add(contrib)


def vw_hash_batch(batch: SparseBatch, m: int, s: int = 1,
                  seed: int = 0) -> jax.Array:
    return vw_hash_sparse(batch.indices, batch.mask, batch.values, m=m,
                          s=s, seed=seed)


def vw_inner_product(g1: jax.Array, g2: jax.Array) -> jax.Array:
    """â_vw = Σ_j g1_j · g2_j (paper Eq. 15) — NOT averaged over k."""
    return jnp.sum(g1 * g2, axis=-1)
