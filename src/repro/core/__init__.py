"""Core library: the paper's contribution as composable JAX modules."""
from repro.core.types import SparseBatch, resemblance
from repro.core.universal_hash import (
    ModPrimeHash,
    MultiplyShiftHash,
    PermutationHash,
    make_hash_family,
)
from repro.core.minhash import (
    minhash_jnp,
    minhash_batch,
    minhash_numpy,
    collision_probability,
)
from repro.core.bbit import (
    bbit_codes,
    pack_codes,
    unpack_codes,
    storage_bits,
    vw_storage_bits,
    codes_agree,
)
from repro.core.expansion import (
    expand,
    expansion_offsets,
    linear_forward,
    pb_hat,
    compact_index,
)
from repro.core.oph import (
    OPH_EMPTY_CODE,
    OPHHash,
    densify_rotation,
    densify_rotation_numpy,
    oph_bin_minima_jnp,
    oph_bin_minima_numpy,
    oph_codes_numpy,
    oph_collision_probability,
    oph_codes_agree,
    split_zero_codes,
)
from repro.core.schemes import (
    SCHEMES,
    HashingScheme,
    make_scheme,
    register_scheme,
)
from repro.core.vw import vw_hash_sparse, vw_hash_batch, vw_inner_product
from repro.core.random_projection import (
    rp_project_sparse,
    rp_project_batch,
    rp_inner_product,
)
from repro.core import estimators

__all__ = [
    "SparseBatch", "resemblance",
    "ModPrimeHash", "MultiplyShiftHash", "PermutationHash",
    "make_hash_family",
    "minhash_jnp", "minhash_batch", "minhash_numpy",
    "collision_probability",
    "bbit_codes", "pack_codes", "unpack_codes", "storage_bits",
    "vw_storage_bits", "codes_agree",
    "OPH_EMPTY_CODE", "OPHHash", "densify_rotation",
    "densify_rotation_numpy", "oph_bin_minima_jnp", "oph_bin_minima_numpy",
    "oph_codes_numpy", "oph_collision_probability", "oph_codes_agree",
    "split_zero_codes",
    "SCHEMES", "HashingScheme", "make_scheme", "register_scheme",
    "expand", "expansion_offsets", "linear_forward", "pb_hat",
    "compact_index",
    "vw_hash_sparse", "vw_hash_batch", "vw_inner_product",
    "rp_project_sparse", "rp_project_batch", "rp_inner_product",
    "estimators",
]
