"""Random projections (paper §5.1) — the other baseline family.

v_j = Σ_i u_i · r_ij with E r=0, Var r=1, E r³=0, E r⁴=s (paper Eq. 10);
the sparse-projection distribution of Eq. 11 for general s.  The
estimator â_rp = (1/k) Σ_j v1_j v2_j is unbiased (Eq. 12) with variance
Eq. 13.  We never materialize the D×k matrix: r_ij is derived from a
counter-based hash of (i, j), so the projection is a deterministic
function of (seed, D, k) exactly like production systems do it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import SparseBatch
from repro.core.universal_hash import _fmix32


def _r_ij(indices: jax.Array, j: jax.Array, s: int, seed: int) -> jax.Array:
    """r for feature ids ``indices`` [..., 1] and projection ids j [k]."""
    iu = indices.astype(jnp.uint32)[..., None]
    ju = j.astype(jnp.uint32)
    # Double-mix combiner: a single xor/multiply combine of (i, j) leaves
    # measurable sign correlations (≈19σ bias on the Eq. 12 estimator);
    # pre-mixing i with the seed then re-mixing with j is empirically
    # unbiased (<0.3σ over 100 seeds — see tests/test_estimators.py).
    h = _fmix32(_fmix32(iu + jnp.uint32(seed) * jnp.uint32(0x632BE59B))
                + ju * jnp.uint32(0x9E3779B9))
    sign = jnp.where((h >> jnp.uint32(31)) & 1 == 1, 1.0, -1.0).astype(
        jnp.float32
    )
    if s == 1:
        return sign
    u = (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.float32) / jnp.float32(2.0**31)
    keep = u < (1.0 / s)
    return jnp.where(keep, sign * jnp.sqrt(jnp.float32(s)), 0.0)


@functools.partial(jax.jit, static_argnames=("k", "s", "seed", "j_chunk"))
def rp_project_sparse(
    indices: jax.Array,
    mask: jax.Array,
    values,
    k: int,
    s: int = 1,
    seed: int = 0,
    j_chunk: int = 128,
) -> jax.Array:
    """Projects a padded sparse batch to float32 (n, k)."""
    vals = values if values is not None else jnp.ones(
        indices.shape, jnp.float32
    )
    vals = jnp.where(mask, vals, 0.0)

    pad = (-k) % j_chunk
    n_chunks = (k + pad) // j_chunk

    def one_chunk(carry, c):
        j = c * j_chunk + jnp.arange(j_chunk, dtype=jnp.uint32)
        r = _r_ij(indices, j, s, seed)            # (n, m, j_chunk)
        out = jnp.einsum("nm,nmj->nj", vals, r)
        return carry, out

    _, outs = jax.lax.scan(one_chunk, 0, jnp.arange(n_chunks))
    out = jnp.moveaxis(outs, 0, 1).reshape(indices.shape[0],
                                           n_chunks * j_chunk)
    return out[:, :k]


def rp_project_batch(batch: SparseBatch, k: int, s: int = 1,
                     seed: int = 0) -> jax.Array:
    return rp_project_sparse(batch.indices, batch.mask, batch.values,
                             k=k, s=s, seed=seed)


def rp_inner_product(v1: jax.Array, v2: jax.Array) -> jax.Array:
    """â_rp,s = (1/k) Σ_j v1_j v2_j (paper Eq. 12)."""
    return jnp.mean(v1 * v2, axis=-1)
