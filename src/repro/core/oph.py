"""One Permutation Hashing (OPH): k min-hashes from a SINGLE hash pass.

The k-permutation scheme this repo reproduces (paper §2/§6) evaluates k
independent hashes per nonzero — preprocessing cost O(k·nnz).  "One
Permutation Hashing" (Li, Owen & Zhang, arXiv:1208.1259) observes that a
single permutation, split into k contiguous bins, yields k (nearly)
independent minima from ONE hash evaluation per nonzero: cost O(nnz),
a k× reduction of the dominant one-time expense in the paper's Table 2.
"b-Bit Minwise Hashing in Practice" (arXiv:1205.2958) confirms this is
the pipeline that matters at 200GB scale.

We simulate the permutation with one multiply-shift + murmur-finalizer
hash h: U32 → U32 (the same TPU-native family the k-permutation kernel
uses); the bin of feature t is the top log2(k) bits of h(t), and the
"position within the permutation" is h(t) itself, so the per-bin minimum
``min_{t∈S, bin(t)=j} h(t)`` is exactly the OPH statistic with range
2^32.  k must be a power of two so binning is a shift — lane-aligned on
the VPU and bias-free.

Empty bins — a sparse document may miss some of the k bins — are handled
by both strategies from the literature, and the tradeoff is the reason
both exist:

  * **zero-coding** (arXiv:1208.1259 §6): an empty bin contributes
    *nothing* — its one-hot block in the expanded feature vector is all
    zeros, and resemblance is estimated as

        R̂ = N_match / (k − N_emp)            (jointly-empty bins dropped)

    Statistically the cleanest estimator (unbiased given the bin
    layout, smaller variance than k-permutation minwise at equal k),
    but the code matrix is *ragged*: downstream consumers must carry an
    empty mask (we reserve ``OPH_EMPTY_CODE`` in the uint16 code
    domain, so b ≤ 15).

  * **densification by rotation** (Shrivastava & Li, arXiv:1406.4784):
    an empty bin borrows the minimum of the nearest non-empty bin to
    its right (circularly), offset by ``distance · _ROT_C`` so that two
    documents borrowing from different distances do not collide by
    construction.  Every document then emits exactly k valid codes —
    the output is drop-in compatible with every k-permutation consumer
    (fixed-width bit-packed shards, the serving engine) at the price of
    slightly higher estimator variance for very sparse rows.

Default scheme ``"oph"`` is the densified variant (fixed-width, safe
everywhere); ``"oph_zero"`` keeps the sharper estimator for consumers
that understand the mask.  Scheme selection lives in
``repro.core.schemes``; the Pallas kernel in ``repro.kernels.oph``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.universal_hash import _fmix32 as _fmix32_jnp

UINT32_MAX_NP = np.uint32(0xFFFFFFFF)
UINT32_MAX = jnp.uint32(0xFFFFFFFF)

# Reserved uint16 code marking an empty bin under zero-coding.  Valid
# b-bit codes occupy [0, 2^b); oph_zero therefore requires b <= 15.
OPH_EMPTY_CODE = np.uint16(0xFFFF)

# Rotation offset constant (odd => full-period in Z_2^32): decorrelates
# values borrowed across different distances (arXiv:1406.4784 §3).
# Mirrored by the in-kernel densification in kernels/fused_encode.py —
# the two must stay bit-identical (tests/test_fused_encode.py enforces).
_ROT_C = 0x9E3779B1


def _check_k(k: int) -> int:
    """OPH bins must be a power of two; returns the bin shift 32-log2(k)."""
    if k < 2 or (k & (k - 1)) != 0:
        raise ValueError(f"OPH needs k = power of two >= 2, got {k}")
    return 32 - (int(k).bit_length() - 1)


def _hash_u32(t: np.ndarray, a: int, b: int) -> np.ndarray:
    """Numpy uint32 multiply-shift + murmur finalizer (== kernels' fmix32).

    Module-level on purpose: tests count hash-family invocations through
    this single choke point to verify the 1-eval-per-nonzero claim.
    """
    h = (np.uint32(a) * t.astype(np.uint32) + np.uint32(b)).astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    h = (h * np.uint32(0x85EBCA6B)).astype(np.uint32)
    h = h ^ (h >> np.uint32(13))
    h = (h * np.uint32(0xC2B2AE35)).astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    return h


@dataclasses.dataclass(frozen=True)
class OPHHash:
    """The single hash function of an OPH family: ONE (a, b) pair, k bins.

    Contrast with ``MultiplyShiftHash`` which stores k pairs — the whole
    point is that OPH needs one.
    """

    a: int          # odd uint32 multiplier
    b: int
    k: int          # number of bins (power of two)

    @staticmethod
    def make(k: int, seed: int) -> "OPHHash":
        _check_k(k)
        rng = np.random.default_rng(np.random.SeedSequence(seed))
        a = int(rng.integers(0, 1 << 32, dtype=np.uint64) | 1)
        b = int(rng.integers(0, 1 << 32, dtype=np.uint64))
        return OPHHash(a=a, b=b, k=k)

    @property
    def shift(self) -> int:
        return _check_k(self.k)

    def params(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray([self.a], dtype=jnp.uint32),
                jnp.asarray([self.b], dtype=jnp.uint32))

    def __call__(self, t: np.ndarray) -> np.ndarray:
        return _hash_u32(np.asarray(t), self.a, self.b)


# ---------------------------------------------------------------------------
# Bin minima — numpy oracle and jit-able jnp path.
# ---------------------------------------------------------------------------
def oph_bin_minima_numpy(
    indices: np.ndarray, mask: np.ndarray, fam: OPHHash,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-bin minima of h over each row's valid indices (numpy oracle).

    Args:
      indices: int (n, m) padded feature ids; mask: bool (n, m).
      fam: the single-hash OPH family.

    Returns:
      (vals uint32 (n, k), empty bool (n, k)); empty bins hold
      UINT32_MAX.  One hash evaluation per (padded) nonzero.
    """
    n, m = indices.shape
    shift = fam.shift
    h = fam(indices)                                   # (n, m) — ONE eval
    bins = (h >> np.uint32(shift)).astype(np.int64)
    vals = np.full((n, fam.k), UINT32_MAX_NP, dtype=np.uint32)
    hv = np.where(mask, h, UINT32_MAX_NP)
    rows = np.broadcast_to(np.arange(n)[:, None], (n, m))
    np.minimum.at(vals, (rows.ravel(), bins.ravel()), hv.ravel())
    return vals, vals == UINT32_MAX_NP


def oph_bin_minima_ragged_numpy(
    tokens: np.ndarray, lens: np.ndarray, fam: OPHHash,
) -> Tuple[np.ndarray, np.ndarray]:
    """Ragged twin of ``oph_bin_minima_numpy``: one flat hash pass over
    the concatenation of every row's VALID ids (no pad lanes, no mask)
    and one flat scatter-min into (n, k).  Bit-identical minima — the
    padded oracle's masked lanes only ever contribute the UINT32_MAX
    init value, so dropping them changes nothing.  This is the serving
    dedup cache's key path: per-row cost tracks the row's true nnz
    instead of the widest doc in the batch.

    Args:
      tokens: int (sum(lens),) concatenated feature ids, row-major.
      lens: int (n,) true nonzero count per row.
      fam: the single-hash OPH family.

    Returns:
      (vals uint32 (n, k), empty bool (n, k)).
    """
    n = int(lens.shape[0])
    h = fam(tokens)                                # ONE eval per nonzero
    bins = (h >> np.uint32(fam.shift)).astype(np.int64)
    rows = np.repeat(np.arange(n, dtype=np.int64),
                     np.asarray(lens, dtype=np.int64))
    vals = np.full(n * fam.k, UINT32_MAX_NP, dtype=np.uint32)
    np.minimum.at(vals, rows * np.int64(fam.k) + bins, h)
    vals = vals.reshape(n, fam.k)
    return vals, vals == UINT32_MAX_NP


@functools.partial(jax.jit, static_argnames=("k",))
def oph_bin_minima_jnp(
    indices: jax.Array,
    mask: jax.Array,
    a: jax.Array,
    b: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """jnp path (XLA-compiled; the CPU production path and the oracle
    the Pallas kernel is validated against).

    Because the bin id is the TOP log2(k) bits of h, sorting a row
    groups its bins contiguously in ascending order and the per-bin
    minimum is simply the first element at each bin boundary — so this
    is a sort + k binary searches instead of a scatter-min, which XLA
    executes ~2× faster than ``.at[].min`` on CPU (and either way ~k×
    fewer hash evaluations than ``minhash_jnp``).

    Args:
      indices: int32 (n, m) padded feature ids; mask: bool (n, m).
      a, b: uint32 (1,) single multiply-shift parameters.
      k: number of bins (power of two, static).

    Returns:
      (vals uint32 (n, k), empty bool (n, k)).
    """
    shift = _check_k(k)
    h = _fmix32_jnp(a[0] * indices.astype(jnp.uint32) + b[0])   # (n, m)
    hv = jnp.sort(jnp.where(mask, h, UINT32_MAX), axis=1)
    bounds = jnp.arange(k, dtype=jnp.uint32) << jnp.uint32(shift)
    pos = jax.vmap(lambda row: jnp.searchsorted(row, bounds))(hv)  # (n, k)
    m = hv.shape[1]
    got = jnp.take_along_axis(hv, jnp.minimum(pos, m - 1), axis=1)
    hit = ((pos < m) & (got != UINT32_MAX)
           & ((got >> jnp.uint32(shift))
              == jnp.arange(k, dtype=jnp.uint32)[None, :]))
    return jnp.where(hit, got, UINT32_MAX), ~hit


# ---------------------------------------------------------------------------
# Empty-bin handling.
# ---------------------------------------------------------------------------
def densify_rotation(
    vals: jax.Array, empty: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Rotation densification (arXiv:1406.4784), jit-able.

    Each empty bin j takes ``vals[src] + dist·_ROT_C`` where src is the
    nearest non-empty bin to the right (circular) at distance dist.
    Rows with no non-empty bin at all stay fully empty (all-sentinel).

    Returns (dense vals uint32 (n, k), still_empty bool (n, k)) —
    still_empty is True only on all-empty rows.
    """
    n, k = vals.shape
    ne2 = jnp.concatenate([~empty, ~empty], axis=1)            # (n, 2k)
    iota2 = jnp.arange(2 * k, dtype=jnp.int32)
    cand = jnp.where(ne2, iota2[None, :], jnp.int32(2 * k))
    # next non-empty position at-or-after j: reverse cumulative min
    nxt = jax.lax.cummin(cand[:, ::-1], axis=1)[:, ::-1][:, :k]  # (n, k)
    dist = nxt - jnp.arange(k, dtype=jnp.int32)[None, :]
    src = jnp.where(nxt < 2 * k, nxt % k, 0)
    borrowed = jnp.take_along_axis(vals, src, axis=1)
    borrowed = borrowed + dist.astype(jnp.uint32) * jnp.uint32(_ROT_C)
    all_empty = jnp.all(empty, axis=1, keepdims=True)
    out = jnp.where(all_empty | (nxt >= 2 * k), UINT32_MAX, borrowed)
    return out, jnp.broadcast_to(all_empty, (n, k))


def densify_rotation_numpy(
    vals: np.ndarray, empty: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy twin of ``densify_rotation`` (bit-exact)."""
    n, k = vals.shape
    ne2 = np.concatenate([~empty, ~empty], axis=1)
    iota2 = np.arange(2 * k, dtype=np.int64)
    cand = np.where(ne2, iota2[None, :], 2 * k)
    nxt = np.minimum.accumulate(cand[:, ::-1], axis=1)[:, ::-1][:, :k]
    dist = nxt - np.arange(k, dtype=np.int64)[None, :]
    src = np.where(nxt < 2 * k, nxt % k, 0)
    borrowed = np.take_along_axis(vals, src, axis=1)
    borrowed = (borrowed
                + (dist.astype(np.uint32) * np.uint32(_ROT_C)).astype(
                    np.uint32)).astype(np.uint32)
    all_empty = empty.all(axis=1, keepdims=True)
    out = np.where(all_empty | (nxt >= 2 * k), UINT32_MAX_NP, borrowed)
    return out.astype(np.uint32), np.broadcast_to(all_empty, (n, k)).copy()


def oph_codes_numpy(
    indices: np.ndarray,
    mask: np.ndarray,
    fam: OPHHash,
    b: int,
    *,
    densify: bool = True,
) -> np.ndarray:
    """End-to-end numpy OPH → uint16 b-bit codes.

    Densified: every bin yields a valid code in [0, 2^b).  Zero-coding
    (densify=False): empty bins hold ``OPH_EMPTY_CODE`` (needs b ≤ 15).
    """
    if not densify and b > 15:
        raise ValueError("oph_zero reserves 0xFFFF: b must be <= 15")
    vals, empty = oph_bin_minima_numpy(indices, mask, fam)
    if densify:
        vals, empty = densify_rotation_numpy(vals, empty)
    codes = (vals & np.uint32((1 << b) - 1)).astype(np.uint16)
    return np.where(empty, OPH_EMPTY_CODE, codes)


# ---------------------------------------------------------------------------
# Estimators.
# ---------------------------------------------------------------------------
def oph_collision_probability(
    v1: np.ndarray, e1: np.ndarray, v2: np.ndarray, e2: np.ndarray,
) -> float:
    """Zero-coding resemblance estimator (arXiv:1208.1259 Eq. 3):

        R̂ = N_match / (k − N_emp),

    matches counted on jointly non-empty bins, jointly-empty bins
    excluded from the denominator.  Input is raw (vals, empty) pairs.
    """
    both = ~(np.asarray(e1) | np.asarray(e2))
    n_emp = int(np.sum(np.asarray(e1) & np.asarray(e2)))
    denom = v1.shape[-1] - n_emp
    if denom <= 0:
        return 0.0
    return float(np.sum((np.asarray(v1) == np.asarray(v2)) & both) / denom)


def split_zero_codes(codes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(codes-with-sentinel uint16) → (gather-safe codes, empty mask).

    Inverse of the sentinel embedding: empty bins become index 0 (their
    contribution is zeroed via the mask by ``bbit_logits``).
    """
    empty = codes == OPH_EMPTY_CODE
    return np.where(empty, np.uint16(0), codes), empty


def oph_codes_agree(c1: np.ndarray, c2: np.ndarray) -> float:
    """b-bit analog of ``oph_collision_probability`` on uint16 codes
    (``OPH_EMPTY_CODE``-aware, for zero-coded code matrices)."""
    e1 = c1 == OPH_EMPTY_CODE
    e2 = c2 == OPH_EMPTY_CODE
    both = ~(e1 | e2)
    denom = c1.shape[-1] - int(np.sum(e1 & e2))
    if denom <= 0:
        return 0.0
    return float(np.sum((c1 == c2) & both) / denom)
