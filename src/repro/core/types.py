"""Shared data containers for the hashing/learning pipeline.

The paper's data model is *sparse binary* vectors (sets of nonzero
feature indices).  We represent a batch of such sets as padded index
arrays plus a validity mask, which is the TPU-friendly layout (fixed
shapes, no ragged buffers).  An optional ``values`` field carries
real-valued features for the VW / random-projection baselines, which
are not restricted to binary data (paper §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseBatch:
    """A batch of sparse (binary or weighted) feature vectors.

    Attributes:
      indices: int32 (n, max_nnz) feature ids; padded entries arbitrary.
      mask:    bool  (n, max_nnz) True for valid entries.
      values:  optional float32 (n, max_nnz); None means binary data.
      dim:     the ambient dimensionality D (static python int).
    """

    indices: jax.Array
    mask: jax.Array
    values: Optional[jax.Array] = None
    dim: int = 0

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.indices, self.mask, self.values), self.dim

    @classmethod
    def tree_unflatten(cls, dim, children):
        indices, mask, values = children
        return cls(indices=indices, mask=mask, values=values, dim=dim)

    # -- convenience -------------------------------------------------------
    @property
    def n(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]

    def nnz(self) -> jax.Array:
        return jnp.sum(self.mask, axis=1)

    @classmethod
    def from_lists(
        cls,
        rows: Sequence[Sequence[int]],
        dim: int,
        values: Optional[Sequence[Sequence[float]]] = None,
        max_nnz: Optional[int] = None,
        pad_to_multiple: int = 8,
    ) -> "SparseBatch":
        """Builds a padded batch from python lists of nonzero indices."""
        n = len(rows)
        m = max((len(r) for r in rows), default=1)
        m = max(m, 1)
        if max_nnz is not None:
            m = max_nnz
        if pad_to_multiple > 1:
            m = ((m + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple
        idx = np.zeros((n, m), dtype=np.int32)
        msk = np.zeros((n, m), dtype=bool)
        val = np.zeros((n, m), dtype=np.float32) if values is not None else None
        for i, r in enumerate(rows):
            r = list(r)[:m]
            idx[i, : len(r)] = np.asarray(r, dtype=np.int32)
            msk[i, : len(r)] = True
            if values is not None:
                v = list(values[i])[:m]
                val[i, : len(v)] = np.asarray(v, dtype=np.float32)
        return cls(
            indices=jnp.asarray(idx),
            mask=jnp.asarray(msk),
            values=None if val is None else jnp.asarray(val),
            dim=dim,
        )

    def to_dense(self) -> jax.Array:
        """Materializes the batch as a dense (n, dim) float32 matrix.

        Only for tests / small benchmarks — never for the real pipeline.
        """
        vals = self.values if self.values is not None else jnp.ones_like(
            self.indices, dtype=jnp.float32
        )
        vals = jnp.where(self.mask, vals, 0.0)
        out = jnp.zeros((self.n, self.dim), dtype=jnp.float32)
        rows = jnp.broadcast_to(
            jnp.arange(self.n)[:, None], self.indices.shape
        )
        # Padded entries write 0.0 at (row, idx) — harmless because binary
        # data never repeats an index and adding zero is a no-op.
        return out.at[rows, self.indices].add(vals)


def resemblance(a: set, b: set) -> float:
    """Exact resemblance R = |A∩B| / |A∪B| (paper Eq. before (1))."""
    if not a and not b:
        return 1.0
    return len(a & b) / float(len(a | b))
