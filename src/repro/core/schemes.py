"""Hashing-scheme registry: minwise (k-permutation) vs OPH, one API.

A *scheme* is the full recipe sparse-document → (n, k) b-bit code
matrix.  The paper's pipeline hard-codes one recipe (k multiply-shift
permutations, §2/§6); OPH (arXiv:1208.1259) is a second, k×-cheaper
recipe producing statistically equivalent codes.  Everything downstream
of preprocessing — bit-packed shards, the liblinear trainer, the
serving engine — consumes codes through this registry so schemes stay
interchangeable:

    sch = make_scheme("oph", k=256, seed=0)
    codes = sch.encode_padded(idx, nnz, b=8)        # offline, numpy in/out
    codes, empty = sch.encode_jnp(idx, mask, b=8)   # jit-able, serving

``encode_jnp`` returns an optional per-bin ``empty`` mask (only the
zero-coded OPH variant produces one; ``None`` otherwise) which
``bbit_logits`` uses to zero out empty-bin contributions.
Registered schemes: ``minwise``, ``oph`` (densified), ``oph_zero``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.minhash import minhash_jnp
from repro.core.oph import (
    OPH_EMPTY_CODE,
    OPHHash,
    densify_rotation,
    oph_bin_minima_jnp,
)
from repro.core.universal_hash import MultiplyShiftHash

SCHEMES: Dict[str, Type["HashingScheme"]] = {}


def register_scheme(name: str):
    def deco(cls):
        cls.name = name
        SCHEMES[name] = cls
        return cls
    return deco


def make_scheme(name: str, k: int, seed: int) -> "HashingScheme":
    if name not in SCHEMES:
        raise ValueError(
            f"unknown hashing scheme {name!r}; have {sorted(SCHEMES)}")
    return SCHEMES[name](k=k, seed=seed)


class HashingScheme:
    """Base: sparse rows → (n, k) uint16 b-bit codes."""

    name: str = "?"

    def __init__(self, k: int, seed: int):
        self.k = k
        self.seed = seed

    @property
    def hash_evals_per_nonzero(self) -> int:
        """Hash evaluations issued per nonzero (the Table-2 cost driver)."""
        raise NotImplementedError

    def encode_jnp(
        self, indices: jax.Array, mask: jax.Array, b: int,
    ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """jit-able path → (codes int32 (n, k), empty mask or None)."""
        raise NotImplementedError

    def encode_padded(
        self, indices: np.ndarray, nnz: np.ndarray, b: int,
        *, use_kernel: bool = True,
    ) -> np.ndarray:
        """Offline path for one padded chunk → uint16 (n, k) codes.

        Kernel-backed on TPU; XLA-compiled jnp elsewhere (interpret-mode
        Pallas would crawl on CPU).  Zero-coded schemes mark empty bins
        with ``OPH_EMPTY_CODE`` in the returned matrix.
        """
        raise NotImplementedError


@register_scheme("minwise")
class MinwiseScheme(HashingScheme):
    """The paper's scheme: k independent multiply-shift permutations."""

    def __init__(self, k: int, seed: int):
        super().__init__(k, seed)
        self.family = MultiplyShiftHash.make(k, seed)
        self._a, self._b = self.family.params()

    @property
    def hash_evals_per_nonzero(self) -> int:
        return self.k

    def encode_jnp(self, indices, mask, b):
        z = minhash_jnp(indices, mask, self._a, self._b)
        codes = (z & jnp.uint32((1 << b) - 1)).astype(jnp.int32)
        return codes, None

    def encode_padded(self, indices, nnz, b, *, use_kernel=True):
        if use_kernel and jax.default_backend() == "tpu":
            from repro.kernels import ops
            codes = ops.minhash_bbit(
                jnp.asarray(indices), jnp.asarray(nnz),
                self._a, self._b, b)
            return np.asarray(codes).astype(np.uint16)
        m = indices.shape[1]
        mask = jnp.arange(m, dtype=jnp.int32)[None, :] \
            < jnp.asarray(nnz)[:, None]
        codes, _ = self.encode_jnp(jnp.asarray(indices), mask, b)
        return np.asarray(codes).astype(np.uint16)


@register_scheme("oph")
class OPHScheme(HashingScheme):
    """One-permutation hashing, densified by rotation: k valid codes
    from ONE hash evaluation per nonzero."""

    densify: bool = True

    def __init__(self, k: int, seed: int):
        super().__init__(k, seed)
        self.family = OPHHash.make(k, seed)
        self._a, self._b = self.family.params()

    @property
    def hash_evals_per_nonzero(self) -> int:
        return 1

    def _finish(self, vals, empty, b):
        if not self.densify and b > 15:
            raise ValueError("oph_zero reserves 0xFFFF: b must be <= 15")
        if self.densify:
            vals, empty = densify_rotation(vals, empty)
            codes = (vals & jnp.uint32((1 << b) - 1)).astype(jnp.int32)
            return codes, None       # fixed-width: minwise-compatible
        codes = (vals & jnp.uint32((1 << b) - 1)).astype(jnp.int32)
        return codes, empty

    def encode_jnp(self, indices, mask, b):
        vals, empty = oph_bin_minima_jnp(
            indices, mask, self._a, self._b, self.k)
        return self._finish(vals, empty, b)

    def encode_padded(self, indices, nnz, b, *, use_kernel=True):
        m = indices.shape[1]
        if use_kernel and jax.default_backend() == "tpu":
            from repro.kernels import ops
            vals = ops.oph(jnp.asarray(indices), jnp.asarray(nnz),
                           self._a, self._b, self.k)
            empty = vals == jnp.uint32(0xFFFFFFFF)
            codes, empty = self._finish(vals, empty, b)
        else:
            mask = jnp.arange(m, dtype=jnp.int32)[None, :] \
                < jnp.asarray(nnz)[:, None]
            codes, empty = self.encode_jnp(jnp.asarray(indices), mask, b)
        out = np.asarray(codes).astype(np.uint16)
        if empty is not None:
            out[np.asarray(empty)] = OPH_EMPTY_CODE
        return out


@register_scheme("oph_zero")
class OPHZeroScheme(OPHScheme):
    """Zero-coded OPH: empty bins carry no signal (ragged codes +
    ``OPH_EMPTY_CODE`` sentinel / empty mask)."""

    densify = False
