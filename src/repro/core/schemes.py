"""Hashing-scheme registry: minwise (k-permutation) vs OPH, one API.

A *scheme* is the full recipe sparse-document → (n, k) b-bit code
matrix.  The paper's pipeline hard-codes one recipe (k multiply-shift
permutations, §2/§6); OPH (arXiv:1208.1259) is a second, k×-cheaper
recipe producing statistically equivalent codes.  Everything downstream
of preprocessing — bit-packed shards, the liblinear trainer, the
serving engine — consumes codes through this registry so schemes stay
interchangeable:

    sch = make_scheme("oph", k=256, seed=0)
    codes = sch.encode_padded(idx, nnz, b=8)        # offline, numpy in/out
    codes, empty = sch.encode_jnp(idx, mask, b=8)   # jit-able, serving
    packed, em = sch.encode_packed(idx, nnz, b=8)   # on-disk bytes direct

``encode_jnp`` returns an optional per-bin ``empty`` mask (only the
zero-coded OPH variant produces one; ``None`` otherwise) which
``bbit_logits`` uses to zero out empty-bin contributions.
Registered schemes: ``minwise``, ``oph`` (densified), ``oph_zero``.

The ``*_device`` variants return un-synced jax arrays so the streaming
preprocessor can keep several chunks in flight (double buffering);
``encode_packed*`` is the device-resident hot path — hash, b-bit mask
and byte packing fused on the accelerator (Pallas kernel on TPU, XLA
elsewhere), so only ``n·ceil(k·b/8)`` bytes cross to the host.
``encode_packed_jit`` is the same fused recipe as a traceable function
(no host-side tile loop): the serving engine composes it with
``bbit_logits_packed`` into ONE jitted raw-docs→scores dispatch per
shape bucket, byte-identical to the offline writers.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bbit import pack_codes_jnp, pack_mask_jnp
from repro.core.minhash import minhash_jnp
from repro.core.oph import (
    OPH_EMPTY_CODE,
    OPHHash,
    densify_rotation,
    oph_bin_minima_jnp,
)
from repro.core.universal_hash import MultiplyShiftHash, _fmix32_numpy

SCHEMES: Dict[str, Type["HashingScheme"]] = {}


def register_scheme(name: str):
    def deco(cls):
        cls.name = name
        SCHEMES[name] = cls
        return cls
    return deco


def make_scheme(name: str, k: int, seed: int) -> "HashingScheme":
    if name not in SCHEMES:
        raise ValueError(
            f"unknown hashing scheme {name!r}; have {sorted(SCHEMES)}")
    return SCHEMES[name](k=k, seed=seed)


def _prefix_mask(indices: jax.Array, nnz) -> jax.Array:
    m = indices.shape[1]
    return (jnp.arange(m, dtype=jnp.int32)[None, :]
            < jnp.asarray(nnz)[:, None])


# -- tiled XLA encode: compile-count O(1) in the pad width ------------------
#
# The packed path streams fixed-width nonzero tiles through ONE compiled
# minima graph and accumulates the running min on the device — the same
# structure as the Pallas kernels' nnz grid dimension.  Pad width then
# never appears in a jit signature: a heavy-tailed corpus compiles ONE
# tile graph + one finisher (per row bucket) instead of one graph per
# chunk width (the PR-1 recompile pathology).  Tiles past every row's
# nnz are skipped on the host, so over-padded chunks cost nothing.
ENCODE_TILE_M = 512


@functools.partial(jax.jit, static_argnames=("k",))
def _oph_tile_step(vals, tile, nnz, col0, a, bv, k):
    """vals ← min(vals, bin minima of one nonzero tile): ONE dispatch
    (and one compiled graph per tile width) per tile."""
    col = col0 + jnp.arange(tile.shape[1], dtype=jnp.int32)
    mask = col[None, :] < nnz[:, None]
    t, _ = oph_bin_minima_jnp(tile, mask, a, bv, k)
    return jnp.minimum(vals, t)


@jax.jit
def _minwise_tile_step(vals, tile, nnz, col0, a, bv):
    col = col0 + jnp.arange(tile.shape[1], dtype=jnp.int32)
    mask = col[None, :] < nnz[:, None]
    return jnp.minimum(vals, minhash_jnp(tile, mask, a, bv))


def _stream_tiles(indices: np.ndarray, nnz, k: int, tile_step):
    """Running min of ``tile_step`` over fixed-width nonzero tiles.

    A tile fully past ``max(nnz)`` is all-padding (its mask is all
    False) and contributes only sentinels — skipped on the host, so the
    effective hashed width is ceil(max_nnz/T)·T however generously the
    chunk was padded.
    """
    indices = np.asarray(indices)
    n, m = indices.shape
    nnz = np.asarray(nnz)
    nnz_j = jnp.asarray(nnz)
    vals = jnp.full((n, k), jnp.uint32(0xFFFFFFFF), jnp.uint32)
    T = ENCODE_TILE_M
    m_live = min(m, int(nnz.max(initial=0)))
    for lo in range(0, m_live, T):
        span = min(T, m - lo)
        if span == T:
            tile = indices[:, lo: lo + T]
        else:
            tile = np.zeros((n, T), dtype=indices.dtype)
            tile[:, :span] = indices[:, lo: lo + span]
        vals = tile_step(vals, jnp.asarray(tile), nnz_j,
                         jnp.asarray(np.int32(lo)))
    return vals


@functools.partial(jax.jit, static_argnames=("b",))
def _minwise_finish_packed(z, b):
    codes = (z & jnp.uint32((1 << b) - 1)).astype(jnp.uint16)
    return pack_codes_jnp(codes, b)


@functools.partial(jax.jit, static_argnames=("b", "densify"))
def _oph_finish_packed(vals, b, densify):
    empty = vals == jnp.uint32(0xFFFFFFFF)
    mask_b = jnp.uint32((1 << b) - 1)
    if densify:
        vals, _ = densify_rotation(vals, empty)
        # all-empty rows keep the sentinel → all-ones low bits, exactly
        # what packing the OPH_EMPTY_CODE-marked reference matrix yields
        codes = (vals & mask_b).astype(jnp.uint16)
    else:
        codes = jnp.where(empty, jnp.uint16(0),
                          (vals & mask_b).astype(jnp.uint16))
    return pack_codes_jnp(codes, b), pack_mask_jnp(empty)


class HashingScheme:
    """Base: sparse rows → (n, k) uint16 b-bit codes."""

    name: str = "?"

    def __init__(self, k: int, seed: int):
        self.k = k
        self.seed = seed

    @property
    def hash_evals_per_nonzero(self) -> int:
        """Hash evaluations issued per nonzero (the Table-2 cost driver)."""
        raise NotImplementedError

    # -- dispatch (routed through the perf cost model) ----------------------

    def _encode_shape(self, indices, b: int) -> dict:
        return {"scheme": self.name, "k": self.k, "b": int(b),
                "rows": int(indices.shape[0]),
                "nnz": int(indices.shape[1])}

    def _choose_encode(self, indices, b: int, use_kernel: bool) -> str:
        """Kernel-vs-XLA choice for unpacked encode.  ``use_kernel=False``
        pins the XLA arm (the historical contract); True defers to
        ``perf.choose`` — heuristic (TPU→Pallas) unless a profile says
        otherwise."""
        from repro import perf
        return perf.choose("encode", self._encode_shape(indices, b),
                           impl=None if use_kernel else "xla")

    def _fused_pack(self, indices, b: int, use_kernel: bool = True) -> bool:
        """Fused encode→pack choice — shared with the serving engine via
        ``ops.fused_encode_on_device`` (the single predicate both the
        offline writers and the jitted hot path branch on)."""
        from repro.kernels import ops
        return ops.fused_encode_on_device(
            int(b), scheme=self.name, k=self.k,
            rows=int(indices.shape[0]), nnz=int(indices.shape[1]),
            impl=None if use_kernel else "xla")

    def encode_jnp(
        self, indices: jax.Array, mask: jax.Array, b: int,
    ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """jit-able path → (codes int32 (n, k), empty mask or None)."""
        raise NotImplementedError

    def encode_device(
        self, indices, nnz, b: int, *, use_kernel: bool = True,
    ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """One padded chunk → un-synced (codes, empty|None) jax arrays.

        Kernel-backed on TPU; XLA-compiled jnp elsewhere (interpret-mode
        Pallas would crawl on CPU).  Dispatch returns immediately, so
        callers can pipeline chunks (double buffering) before syncing.
        """
        raise NotImplementedError

    def encode_packed_device(
        self, indices, nnz, b: int, *, use_kernel: bool = True,
    ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """One padded chunk → un-synced (packed uint8 (n, ceil(k·b/8)),
        packed empty bitmask or None) — the fused device-resident path.

        Bytes are bit-identical to ``pack_codes`` over ``encode_padded``
        output (and ``np.packbits`` over the empty mask): the shard
        writer appends them verbatim.
        """
        raise NotImplementedError

    def encode_packed_jit(
        self, indices: jax.Array, nnz: jax.Array, b: int,
    ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """Traceable fused encode→pack — the serving hot path's front
        half → (packed uint8 (n, ceil(k·b/8)), packed empty | None).

        Unlike ``encode_packed_device`` (a host-side driver that streams
        fixed-width tiles through its own jitted steps) this composes
        INSIDE a caller's jit, so an engine can fuse raw padded docs →
        packed codes → ``bbit_logits_packed`` scores into one device
        dispatch.  Dispatch mirrors ``ops.fused_encode_on_device``: the
        Pallas fused kernel on TPU, pure-XLA hash+pack elsewhere.
        Output bytes are bit-identical to ``encode_packed_device``'s.
        """
        raise NotImplementedError

    def encode_padded(
        self, indices: np.ndarray, nnz: np.ndarray, b: int,
        *, use_kernel: bool = True,
    ) -> np.ndarray:
        """Offline path for one padded chunk → uint16 (n, k) codes.

        Zero-coded schemes mark empty bins with ``OPH_EMPTY_CODE`` in
        the returned matrix.
        """
        codes, empty = self.encode_device(indices, nnz, b,
                                          use_kernel=use_kernel)
        out = np.asarray(codes).astype(np.uint16)
        if empty is not None:
            out[np.asarray(empty)] = OPH_EMPTY_CODE
        return out

    def encode_packed(
        self, indices: np.ndarray, nnz: np.ndarray, b: int,
        *, use_kernel: bool = True,
    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Synchronous ``encode_packed_device`` → numpy arrays."""
        packed, empty = self.encode_packed_device(indices, nnz, b,
                                                  use_kernel=use_kernel)
        return (np.asarray(packed),
                None if empty is None else np.asarray(empty))

    def encode_packed_numpy(
        self, indices: np.ndarray, nnz: np.ndarray, b: int,
    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Pure-numpy twin of ``encode_packed``: bit-identical bytes,
        zero device dispatches.

        This is the serving dedup cache's key path — a single document
        is fingerprinted with ONE host-side hash pass (no padded device
        round-trip), and because the bytes equal the device encode
        bit-for-bit, packed-code equality on the host transfers exactly
        to score equality on the device (tests/test_dedup_cache.py
        enforces the parity per scheme).  Pad width never affects the
        output (padding is masked), so callers may pad however is
        cheapest.
        """
        raise NotImplementedError


@register_scheme("minwise")
class MinwiseScheme(HashingScheme):
    """The paper's scheme: k independent multiply-shift permutations."""

    def __init__(self, k: int, seed: int):
        super().__init__(k, seed)
        self.family = MultiplyShiftHash.make(k, seed)
        self._a, self._b = self.family.params()

    @property
    def hash_evals_per_nonzero(self) -> int:
        return self.k

    def encode_jnp(self, indices, mask, b):
        z = minhash_jnp(indices, mask, self._a, self._b)
        codes = (z & jnp.uint32((1 << b) - 1)).astype(jnp.int32)
        return codes, None

    def encode_device(self, indices, nnz, b, *, use_kernel=True):
        indices = jnp.asarray(indices)
        if self._choose_encode(indices, b, use_kernel) == "pallas":
            from repro.kernels import ops
            return ops.minhash_bbit(indices, jnp.asarray(nnz),
                                    self._a, self._b, b), None
        codes, _ = self.encode_jnp(indices, _prefix_mask(indices, nnz), b)
        return codes, None

    def encode_packed_device(self, indices, nnz, b, *, use_kernel=True):
        from repro.kernels import ops
        if self._fused_pack(indices, b, use_kernel):
            return ops.minhash_packed(jnp.asarray(indices),
                                      jnp.asarray(nnz),
                                      self._a, self._b, b), None
        z = _stream_tiles(
            indices, nnz, self.k,
            lambda v, t, nz, c0: _minwise_tile_step(v, t, nz, c0,
                                                    self._a, self._b))
        return _minwise_finish_packed(z, b), None

    def encode_packed_jit(self, indices, nnz, b):
        from repro.kernels import ops
        if self._fused_pack(indices, b):
            return ops.minhash_packed(indices, nnz,
                                      self._a, self._b, b), None
        z = minhash_jnp(indices, _prefix_mask(indices, nnz),
                        self._a, self._b)
        return _minwise_finish_packed(z, b), None

    # k-chunking bounds the (n, m, chunk) intermediate the same way
    # minhash_jnp's m_chunk/k_chunk tiling does on device.
    _NUMPY_K_CHUNK = 64

    def encode_packed_numpy(self, indices, nnz, b):
        from repro.core.bbit import pack_codes
        indices = np.asarray(indices)
        n, m = indices.shape
        mask = (np.arange(m, dtype=np.int64)[None, :]
                < np.asarray(nnz, dtype=np.int64)[:, None])
        t = indices.astype(np.uint32)[:, :, None]
        a_np = np.asarray(self.family.a, dtype=np.uint32)
        b_np = np.asarray(self.family.b, dtype=np.uint32)
        z = np.empty((n, self.k), dtype=np.uint32)
        sentinel = np.uint32(0xFFFFFFFF)
        for lo in range(0, self.k, self._NUMPY_K_CHUNK):
            hi = min(lo + self._NUMPY_K_CHUNK, self.k)
            h = _fmix32_numpy(a_np[None, None, lo:hi] * t
                              + b_np[None, None, lo:hi])
            z[:, lo:hi] = np.where(mask[:, :, None], h, sentinel).min(axis=1)
        codes = (z & np.uint32((1 << b) - 1)).astype(np.uint16)
        return pack_codes(codes, b), None


@register_scheme("oph")
class OPHScheme(HashingScheme):
    """One-permutation hashing, densified by rotation: k valid codes
    from ONE hash evaluation per nonzero."""

    densify: bool = True

    def __init__(self, k: int, seed: int):
        super().__init__(k, seed)
        self.family = OPHHash.make(k, seed)
        self._a, self._b = self.family.params()

    @property
    def hash_evals_per_nonzero(self) -> int:
        return 1

    def _finish(self, vals, empty, b):
        if not self.densify and b > 15:
            raise ValueError("oph_zero reserves 0xFFFF: b must be <= 15")
        if self.densify:
            vals, empty = densify_rotation(vals, empty)
            codes = (vals & jnp.uint32((1 << b) - 1)).astype(jnp.int32)
            return codes, None       # fixed-width: minwise-compatible
        codes = (vals & jnp.uint32((1 << b) - 1)).astype(jnp.int32)
        return codes, empty

    def encode_jnp(self, indices, mask, b):
        vals, empty = oph_bin_minima_jnp(
            indices, mask, self._a, self._b, self.k)
        return self._finish(vals, empty, b)

    def encode_device(self, indices, nnz, b, *, use_kernel=True):
        indices = jnp.asarray(indices)
        if self._choose_encode(indices, b, use_kernel) == "pallas":
            from repro.kernels import ops
            vals = ops.oph(indices, jnp.asarray(nnz),
                           self._a, self._b, self.k)
            empty = vals == jnp.uint32(0xFFFFFFFF)
            return self._finish(vals, empty, b)
        return self.encode_jnp(indices, _prefix_mask(indices, nnz), b)

    def encode_packed_device(self, indices, nnz, b, *, use_kernel=True):
        from repro.kernels import ops
        if not self.densify and b > 15:
            raise ValueError("oph_zero reserves 0xFFFF: b must be <= 15")
        if self._fused_pack(indices, b, use_kernel):
            packed, empty = ops.oph_packed(
                jnp.asarray(indices), jnp.asarray(nnz),
                self._a, self._b, self.k, b,
                densify=self.densify)
            return packed, (None if self.densify else empty)
        vals = _stream_tiles(
            indices, nnz, self.k,
            lambda v, t, nz, c0: _oph_tile_step(v, t, nz, c0, self._a,
                                                self._b, self.k))
        packed, empty = _oph_finish_packed(vals, b, self.densify)
        return packed, (None if self.densify else empty)

    def encode_packed_jit(self, indices, nnz, b):
        from repro.kernels import ops
        if not self.densify and b > 15:
            raise ValueError("oph_zero reserves 0xFFFF: b must be <= 15")
        if self._fused_pack(indices, b):
            packed, empty = ops.oph_packed(indices, nnz, self._a,
                                           self._b, self.k, b,
                                           densify=self.densify)
            return packed, (None if self.densify else empty)
        vals, _ = oph_bin_minima_jnp(
            indices, _prefix_mask(indices, nnz), self._a, self._b, self.k)
        packed, empty = _oph_finish_packed(vals, b, self.densify)
        return packed, (None if self.densify else empty)

    def encode_packed_numpy(self, indices, nnz, b):
        indices = np.asarray(indices)
        n, m = indices.shape
        lens = np.minimum(np.asarray(nnz, dtype=np.int64), m)
        mask = np.arange(m, dtype=np.int64)[None, :] < lens[:, None]
        # ragged fast path: hash + scatter-min touch only real nonzeros
        # (a padded pass spends most of its time on the pad lanes of
        # the widest doc in the batch) — bit-identical minima
        return self.encode_packed_numpy_ragged(indices[mask], lens, b)

    def encode_packed_numpy_ragged(self, tokens, lens, b):
        """Ragged host encode: ``tokens`` is the row-major concat of
        every doc's (already id-folded) nonzeros, ``lens`` the per-doc
        counts.  Same bytes as ``encode_packed_numpy`` with no pad
        lanes materialized at all — the serving dedup key path calls
        this directly so per-row cost tracks true nnz."""
        from repro.core.bbit import pack_codes
        from repro.core.oph import (OPH_EMPTY_CODE,
                                    densify_rotation_numpy,
                                    oph_bin_minima_ragged_numpy,
                                    split_zero_codes)
        if not self.densify and b > 15:
            raise ValueError("oph_zero reserves 0xFFFF: b must be <= 15")
        vals, empty = oph_bin_minima_ragged_numpy(tokens, lens,
                                                  self.family)
        if self.densify:
            # rotation densify is row-independent and the identity on
            # fully-occupied rows (the common case at real document
            # sizes: P(empty bin) = (1-1/k)^nnz), so only rows that
            # actually have an empty bin go through it
            need = empty.any(axis=1)
            if need.any():
                sub_vals, sub_empty = densify_rotation_numpy(
                    vals[need], empty[need])
                vals[need] = sub_vals
                empty[need] = sub_empty
        codes = (vals & np.uint32((1 << b) - 1)).astype(np.uint16)
        codes = np.where(empty, OPH_EMPTY_CODE, codes)
        if self.densify:
            # all-empty rows keep OPH_EMPTY_CODE → all-ones low b bits,
            # matching _oph_finish_packed's sentinel bytes exactly
            return pack_codes(codes, b), None
        codes0, empty = split_zero_codes(codes)
        return pack_codes(codes0, b), np.packbits(empty, axis=1)


@register_scheme("oph_zero")
class OPHZeroScheme(OPHScheme):
    """Zero-coded OPH: empty bins carry no signal (ragged codes +
    ``OPH_EMPTY_CODE`` sentinel / empty mask)."""

    densify = False
