"""The 2^b × k one-hot expansion (paper §3) and its gather-form equivalent.

The paper materializes, for each example, a ``2^b·k``-dim binary vector
with exactly k ones and feeds it to LIBLINEAR.  The inner product of two
such vectors equals ``k · \\hat{P}_b``.  We provide:

  * ``expand``            — the explicit expansion (tests / tiny data only).
  * ``linear_forward``    — w·x without materializing the expansion:
                            ``Σ_j W[j, code_j]`` (a gather).  This is the
                            production form; its equality with the
                            explicit expansion is unit-tested.
  * ``compact_index``     — the paper's §5.4 trick: a VW (signed feature
                            hashing) pass *on top of* the b-bit expansion
                            to shrink the index space when 2^b·k is much
                            larger than k, again without materializing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.universal_hash import _fmix32


def expand(codes: jax.Array, b: int) -> jax.Array:
    """uint16 (n, k) codes → float32 (n, k·2^b) one-hot expansion."""
    n, k = codes.shape
    onehot = jax.nn.one_hot(codes.astype(jnp.int32), 1 << b, dtype=jnp.float32)
    return onehot.reshape(n, k * (1 << b))


def expansion_offsets(codes: jax.Array, b: int) -> jax.Array:
    """Column index of each example's k ones in the expanded space."""
    k = codes.shape[-1]
    return (jnp.arange(k, dtype=jnp.int32) * (1 << b)
            + codes.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("b",))
def linear_forward(codes: jax.Array, weights: jax.Array, b: int,
                   scale: float = 1.0) -> jax.Array:
    """Logits of a linear model over the virtual expansion.

    Args:
      codes:   uint16 (n, k).
      weights: float (k, 2^b, n_out) weight table (the expanded weight
               vector reshaped; bias handled by caller).
      b:       bits per code.

    Returns:
      float (n, n_out) = expansion(codes) @ W_flat, computed as k gathers.
    """
    del b
    gathered = jnp.take_along_axis(
        weights[None],                                    # (1, k, 2^b, o)
        codes.astype(jnp.int32)[:, :, None, None],        # (n, k, 1, 1)
        axis=2,
    )[:, :, 0, :]                                         # (n, k, o)
    return gathered.sum(axis=1) * scale


def pb_hat(c1: jax.Array, c2: jax.Array) -> jax.Array:
    """\\hat{P}_b between two code rows/batches (paper Eq. 6)."""
    return jnp.mean((c1 == c2).astype(jnp.float32), axis=-1)


@functools.partial(jax.jit, static_argnames=("b", "m"))
def compact_index(codes: jax.Array, b: int, m: int, seed_a: int = 0x9E3779B1,
                  seed_b: int = 0x85EBCA77) -> jax.Array:
    """Paper §5.4: VW hashing applied on top of the b-bit expansion.

    Maps each of the k virtual ones (at column ``j·2^b + code_j``) to one
    of ``m`` buckets with a ±1 sign, *without* materializing the 2^b·k
    vector.  Output: float32 (n, m) — a compact, dense representation
    whose inner products are unbiased estimates of k·P̂_b (VW is
    unbiased, paper Eq. 15).  The paper reports this cuts 16-bit-hashing
    training time 2–3× via compact indexing.
    """
    cols = expansion_offsets(codes, b)                     # (n, k) int32
    cu = cols.astype(jnp.uint32)
    h = _fmix32(jnp.uint32(seed_a) * cu + jnp.uint32(seed_b))
    bucket = (h % jnp.uint32(m)).astype(jnp.int32)         # (n, k)
    # Independent sign stream (decorrelated from the bucket hash).
    hs = _fmix32(cu ^ jnp.uint32(0xDEADBEEF))
    sign = jnp.where((hs >> jnp.uint32(31)) & 1 == 1, 1.0, -1.0)
    n, k = codes.shape
    out = jnp.zeros((n, m), dtype=jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    return out.at[rows, bucket].add(sign)
