"""b-bit code extraction, storage packing, and storage accounting (paper §2-§3).

The whole point of the paper: keep only the lowest b bits of each
min-hash, so a dataset of n examples costs exactly ``n·b·k`` bits.
``pack_codes``/``unpack_codes`` realize that storage format bit-exactly;
the data pipeline uses it as the on-disk representation of the
preprocessed (hashed) dataset.

Two packers share one bit layout (row-major bitstream, LSB-first within
each byte): ``pack_codes`` is the numpy reference, ``pack_codes_jnp``
the jit-able device-side twin used by the fused encode pipeline so only
``n·ceil(k·b/8)`` packed bytes — not ``n·k`` full-width minima — ever
cross the host↔device boundary.  ``pack_mask_jnp`` is the device twin
of ``np.packbits`` (MSB-first) for the ``oph_zero`` empty-bin bitmask.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp


def bbit_codes(z: jax.Array, b: int) -> jax.Array:
    """Lowest b bits of each min-hash value → uint16 codes in [0, 2^b)."""
    if not 1 <= b <= 16:
        raise ValueError(f"b must be in [1, 16], got {b}")
    mask = (1 << b) - 1
    if isinstance(z, np.ndarray):
        return (z & np.asarray(mask, dtype=z.dtype)).astype(np.uint16)
    return (z & jnp.asarray(mask, dtype=z.dtype)).astype(jnp.uint16)


def storage_bits(n: int, k: int, b: int) -> int:
    """Exact storage of the hashed dataset: n·b·k bits (paper §3)."""
    return n * b * k


def vw_storage_bits(n: int, k: int, bits_per_entry: int = 32) -> int:
    """VW stores k dense (float/int) bins per example (paper §5.3)."""
    return n * k * bits_per_entry


def pack_codes(codes: np.ndarray, b: int) -> np.ndarray:
    """Bit-packs uint16 (n, k) codes (< 2^b) into a uint8 (n, ceil(k·b/8)).

    Row-major bitstream, LSB-first within each byte — the on-disk format
    of the preprocessed dataset (exactly n·b·k bits + row padding).
    """
    n, k = codes.shape
    codes = codes.astype(np.uint32)
    bits = ((codes[:, :, None] >> np.arange(b, dtype=np.uint32)[None, None, :])
            & 1).astype(np.uint8)          # (n, k, b) LSB-first
    flat = bits.reshape(n, k * b)
    pad = (-flat.shape[1]) % 8
    if pad:
        flat = np.pad(flat, ((0, 0), (0, pad)))
    flat = flat.reshape(n, -1, 8)
    weights = (1 << np.arange(8, dtype=np.uint16)).astype(np.uint8)
    return (flat * weights[None, None, :]).sum(axis=2).astype(np.uint8)


def packed_width(k: int, b: int) -> int:
    """Bytes per row of the packed code matrix: ceil(k·b/8)."""
    return (k * b + 7) // 8


def packed_mask_width(k: int) -> int:
    """Bytes per row of the packed ``oph_zero`` empty bitmask:
    ceil(k/8) (``np.packbits`` layout, MSB-first)."""
    return (k + 7) // 8


@functools.partial(jax.jit, static_argnames=("b",))
def pack_codes_jnp(codes: jax.Array, b: int) -> jax.Array:
    """Device-side ``pack_codes`` (bit-exact, jit-able) → uint8.

    For b ∈ {1, 2, 4, 8} each byte holds exactly 8/b whole codes, so
    packing is 8/b strided shift-ors (VPU-friendly; the same formula the
    fused Pallas kernels inline).  Other b go through the general
    bit-expansion, still fully on device.
    """
    n, k = codes.shape
    c = codes.astype(jnp.uint32)
    if 8 % b == 0:
        r = 8 // b
        pad = (-k) % r
        if pad:
            c = jnp.pad(c, ((0, 0), (0, pad)))
        out = jnp.zeros((n, c.shape[1] // r), jnp.uint32)
        for t in range(r):
            out = out | (c[:, t::r] << jnp.uint32(t * b))
        return out.astype(jnp.uint8)
    bits = ((c[:, :, None] >> jnp.arange(b, dtype=jnp.uint32)[None, None, :])
            & 1)
    flat = bits.reshape(n, k * b)
    pad = (-flat.shape[1]) % 8
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    flat = flat.reshape(n, -1, 8)
    weights = (1 << jnp.arange(8, dtype=jnp.uint32))
    return jnp.sum(flat * weights[None, None, :], axis=2).astype(jnp.uint8)


@jax.jit
def pack_mask_jnp(mask: jax.Array) -> jax.Array:
    """Device-side ``np.packbits(mask, axis=1)`` (MSB-first) → uint8."""
    n, k = mask.shape
    m = mask.astype(jnp.uint32)
    pad = (-k) % 8
    if pad:
        m = jnp.pad(m, ((0, 0), (0, pad)))
    out = jnp.zeros((n, m.shape[1] // 8), jnp.uint32)
    for t in range(8):
        out = out | (m[:, t::8] << jnp.uint32(7 - t))
    return out.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("k", "b"))
def unpack_codes_jnp(packed: jax.Array, k: int, b: int) -> jax.Array:
    """Device-side ``unpack_codes`` — bit-exact inverse of
    ``pack_codes_jnp`` → uint16 (n, k), jit-able.

    This is what lets training consume the on-disk packed shards
    directly: a minibatch crosses the host↔device boundary as
    ceil(k·b/8) bytes per row and is widened to (n, k) codes on the
    accelerator, inside the jitted train step.  For b ∈ {1, 2, 4, 8}
    each byte splits into 8/b strided shift-ands (the mirror image of
    the packer's shift-ors); other b go through the general bit
    expansion.
    """
    n = packed.shape[0]
    p = packed.astype(jnp.uint32)
    if 8 % b == 0:
        r = 8 // b
        mask = jnp.uint32((1 << b) - 1)
        # (n, w, r): code j·r+t sits in bits [t·b, (t+1)·b) of byte j
        cols = jnp.stack(
            [(p >> jnp.uint32(t * b)) & mask for t in range(r)], axis=2)
        return cols.reshape(n, -1)[:, :k].astype(jnp.uint16)
    bits = ((p[:, :, None] >> jnp.arange(8, dtype=jnp.uint32)[None, None, :])
            & 1)
    flat = bits.reshape(n, -1)[:, : k * b].reshape(n, k, b)
    weights = (1 << jnp.arange(b, dtype=jnp.uint32))
    return jnp.sum(flat * weights[None, None, :], axis=2).astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("k",))
def unpack_mask_jnp(packed: jax.Array, k: int) -> jax.Array:
    """Device-side ``np.unpackbits(..., axis=1, count=k)`` (MSB-first)
    → bool (n, k); the inverse of ``pack_mask_jnp`` for the
    ``oph_zero`` empty-bin bitmask."""
    n = packed.shape[0]
    p = packed.astype(jnp.uint32)
    cols = jnp.stack(
        [(p >> jnp.uint32(7 - t)) & 1 for t in range(8)], axis=2)
    return cols.reshape(n, -1)[:, :k].astype(bool)


def unpack_codes(packed: np.ndarray, k: int, b: int) -> np.ndarray:
    """Inverse of ``pack_codes`` → uint16 (n, k)."""
    n = packed.shape[0]
    bits = ((packed[:, :, None] >> np.arange(8, dtype=np.uint8)[None, None, :])
            & 1)
    flat = bits.reshape(n, -1)[:, : k * b].reshape(n, k, b)
    weights = (1 << np.arange(b, dtype=np.uint32))
    return (flat.astype(np.uint32) * weights[None, None, :]).sum(axis=2).astype(
        np.uint16
    )


def codes_agree(c1: jax.Array, c2: jax.Array) -> jax.Array:
    """\\hat{P}_b per pair: fraction of agreeing b-bit codes (paper Eq. 6)."""
    return jnp.mean((c1 == c2).astype(jnp.float32), axis=-1)
