"""Hash families used to simulate minwise-hashing permutations (paper §7).

The paper simulates k permutations with the simplest 2-universal family

    h_j(t) = ((c1_j + c2_j * t) mod p) mod D          (paper Eq. 17)

and verifies empirically (paper Fig. 8) that learning quality matches
true random permutations.  We provide three families:

  * ``ModPrimeHash``      — the paper's family, exact, p = 2^61 - 1
                            (Mersenne), evaluated in numpy uint64.  This
                            is the *offline preprocessing* family.
  * ``MultiplyShiftHash`` — Dietzfelbinger multiply-shift on uint32, the
                            TPU-native family used by the Pallas kernel
                            (no 64-bit arithmetic on the VPU).  A murmur
                            finalizer decorrelates the low bits because
                            b-bit minwise hashing keeps exactly those.
  * ``PermutationHash``   — explicit random permutations for small D,
                            the gold standard the paper's Fig. 8
                            comparison is anchored to.

All families are deterministic given (seed, k) and serializable — the
production property the paper highlights: store 2k numbers, not k
permutation tables.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

MERSENNE61 = np.uint64((1 << 61) - 1)


def _np_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(seed))


# ---------------------------------------------------------------------------
# Mod-prime (paper Eq. 17) — exact, numpy uint64, offline path.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModPrimeHash:
    """h_j(t) = ((c1_j + c2_j * t) mod p); p = 2^61-1 (Mersenne).

    The paper further reduces ``mod D``; for minwise hashing only the
    *ranking* matters, so we keep the full residue as the hash value
    (strictly finer ranking, identical collision statistics as D→∞,
    which is Theorem 1's regime).
    """

    c1: np.ndarray  # uint64 (k,)
    c2: np.ndarray  # uint64 (k,)

    @property
    def k(self) -> int:
        return int(self.c1.shape[0])

    @staticmethod
    def make(k: int, seed: int) -> "ModPrimeHash":
        rng = _np_rng(seed)
        p = int(MERSENNE61)
        c1 = rng.integers(0, p, size=k, dtype=np.uint64)
        c2 = rng.integers(1, p, size=k, dtype=np.uint64)
        return ModPrimeHash(c1=c1, c2=c2)

    def __call__(self, t: np.ndarray) -> np.ndarray:
        """t: int array [...], returns uint64 [..., k] hash values."""
        t = np.asarray(t, dtype=np.uint64)[..., None]  # [..., 1]
        # (c2 * t) mod p with p Mersenne: use python-int fallback-free
        # 128-bit-safe splitting: c2*t ≤ (2^61)^2 = 2^122 — numpy uint64
        # would overflow, so split t into 30-bit limbs.
        t_lo = t & np.uint64((1 << 30) - 1)
        t_hi = t >> np.uint64(30)
        # c2 * t = c2*t_hi*2^30 + c2*t_lo ; reduce each term mod p.
        lo = _mulmod_mersenne61(self.c2, t_lo)
        hi = _mulmod_mersenne61(self.c2, t_hi)
        hi = _mulmod_mersenne61(hi, np.uint64(1 << 30))
        s = _addmod_mersenne61(lo, hi)
        return _addmod_mersenne61(s, self.c1)


def _reduce_mersenne61(x: np.ndarray) -> np.ndarray:
    x = (x & MERSENNE61) + (x >> np.uint64(61))
    return np.where(x >= MERSENNE61, x - MERSENNE61, x)


def _addmod_mersenne61(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    s = a + b  # both < 2^61 so the uint64 sum cannot wrap
    return np.where(s >= MERSENNE61, s - MERSENNE61, s)


def _mulmod_mersenne61(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(a*b) mod (2^61-1) with a < 2^61, b < 2^31, no uint64 overflow."""
    a_lo = a & np.uint64((1 << 31) - 1)
    a_hi = a >> np.uint64(31)
    # a*b = a_hi*2^31*b + a_lo*b ; a_hi < 2^30, b < 2^31 → a_hi*b < 2^61 OK
    # a_lo*b < 2^62 OK.
    lo = _reduce_mersenne61(a_lo * b)
    hi = _reduce_mersenne61(a_hi * b)
    # hi * 2^31 mod p: shift then reduce (hi < p < 2^61; hi*2^31 overflows,
    # so split again: hi = h1*2^30 + h0)
    h0 = hi & np.uint64((1 << 30) - 1)
    h1 = hi >> np.uint64(30)
    part0 = _reduce_mersenne61(h0 << np.uint64(31))  # h0 < 2^30 → no wrap
    part1 = _reduce_mersenne61(h1)  # h1·2^(30+31) = h1·2^61 ≡ h1 (mod p)
    return _addmod_mersenne61(lo, _addmod_mersenne61(part0, part1))


# ---------------------------------------------------------------------------
# Multiply-shift (uint32) — the TPU / Pallas family.
# ---------------------------------------------------------------------------
def _fmix32(h: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 finalizer: full-avalanche mixing of a uint32 value."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _fmix32_numpy(h: np.ndarray) -> np.ndarray:
    """Numpy twin of ``_fmix32`` (bit-exact; uint32 wraparound)."""
    h = h.astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    h = (h * np.uint32(0x85EBCA6B)).astype(np.uint32)
    h = h ^ (h >> np.uint32(13))
    h = (h * np.uint32(0xC2B2AE35)).astype(np.uint32)
    h = h ^ (h >> np.uint32(16))
    return h


@dataclasses.dataclass(frozen=True)
class MultiplyShiftHash:
    """h_j(t) = fmix32(a_j * t + b_j  mod 2^32) on uint32.

    ``a_j`` odd.  Multiply-shift is 2-universal for the *high* output
    bits; the murmur finalizer redistributes so the *low* b bits (the
    ones b-bit minwise hashing stores) are equally well mixed.  Pure
    uint32 arithmetic → runs unchanged inside the Pallas TPU kernel.
    """

    a: Tuple[int, ...]  # odd multipliers, python ints for hashability
    b: Tuple[int, ...]

    @property
    def k(self) -> int:
        return len(self.a)

    @staticmethod
    def make(k: int, seed: int) -> "MultiplyShiftHash":
        rng = _np_rng(seed)
        a = (rng.integers(0, 1 << 32, size=k, dtype=np.uint64) | 1).astype(
            np.uint32
        )
        b = rng.integers(0, 1 << 32, size=k, dtype=np.uint64).astype(np.uint32)
        return MultiplyShiftHash(a=tuple(int(x) for x in a),
                                 b=tuple(int(x) for x in b))

    def params(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(self.a, dtype=jnp.uint32),
                jnp.asarray(self.b, dtype=jnp.uint32))

    def __call__(self, t: jnp.ndarray) -> jnp.ndarray:
        """t: int32/uint32 [...], returns uint32 [..., k]."""
        a, b = self.params()
        tu = t.astype(jnp.uint32)[..., None]
        return _fmix32(a * tu + b)


# ---------------------------------------------------------------------------
# True random permutations — gold standard for Fig. 8 style verification.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PermutationHash:
    """k explicit permutations of {0..D-1}; only feasible for small D."""

    perms: np.ndarray  # uint32 (k, D)

    @property
    def k(self) -> int:
        return int(self.perms.shape[0])

    @property
    def dim(self) -> int:
        return int(self.perms.shape[1])

    @staticmethod
    def make(k: int, dim: int, seed: int) -> "PermutationHash":
        rng = _np_rng(seed)
        perms = np.stack(
            [rng.permutation(dim).astype(np.uint32) for _ in range(k)]
        )
        return PermutationHash(perms=perms)

    def __call__(self, t: np.ndarray) -> np.ndarray:
        t = np.asarray(t)
        return np.moveaxis(self.perms[:, t], 0, -1)  # [..., k]


def make_hash_family(kind: str, k: int, seed: int, dim: int = 0):
    if kind == "mod_prime":
        return ModPrimeHash.make(k, seed)
    if kind == "multiply_shift":
        return MultiplyShiftHash.make(k, seed)
    if kind == "permutation":
        if dim <= 0:
            raise ValueError("permutation family needs dim > 0")
        return PermutationHash.make(k, dim, seed)
    raise ValueError(f"unknown hash family {kind!r}")
