"""Closed-form estimators and variance laws from the paper.

Every formula the paper states is implemented here as a pure function
and property-tested (tests/test_estimators.py) against Monte-Carlo
simulation of the actual hashing code — this is the mathematical
contract of the reproduction:

  Eq. (1)/(2)   minwise estimator R̂_M and its variance
  Theorem 1 / Eq. (3)-(5)  b-bit collision law P_b = C1 + (1-C2)·R
  Eq. (6)/(7)   R̂_b from P̂_b and Var(R̂_b)
  Eq. (13)      random-projection variance (general s)
  Eq. (16)      VW variance (general s) — equals (13) at s=1
"""
from __future__ import annotations

import dataclasses

import numpy as np


# -- minwise hashing (paper §2) ---------------------------------------------
def var_rm(R: float, k: int) -> float:
    """Var(R̂_M) = R(1-R)/k (paper Eq. 2)."""
    return R * (1.0 - R) / k


# -- b-bit minwise hashing (Theorem 1) --------------------------------------
@dataclasses.dataclass(frozen=True)
class BBitLaw:
    """The constants of Theorem 1 for a pair with sparsities r1, r2."""

    b: int
    r1: float
    r2: float

    @property
    def A1(self) -> float:
        return _A(self.r1, self.b)

    @property
    def A2(self) -> float:
        return _A(self.r2, self.b)

    @property
    def C1(self) -> float:
        r1, r2 = self.r1, self.r2
        if r1 + r2 == 0.0:            # the r→0 limit (paper Eq. 4)
            return 0.5 * (self.A1 + self.A2)
        return self.A1 * r2 / (r1 + r2) + self.A2 * r1 / (r1 + r2)

    @property
    def C2(self) -> float:
        r1, r2 = self.r1, self.r2
        if r1 + r2 == 0.0:
            return 0.5 * (self.A1 + self.A2)
        return self.A1 * r1 / (r1 + r2) + self.A2 * r2 / (r1 + r2)

    def pb(self, R: float) -> float:
        """P_b = C1 + (1 - C2)·R (paper Eq. 3)."""
        return self.C1 + (1.0 - self.C2) * R

    def r_hat(self, pb_hat: float) -> float:
        """R̂_b = (P̂_b - C1)/(1 - C2) (paper Eq. 6)."""
        return (pb_hat - self.C1) / (1.0 - self.C2)

    def var_rb(self, R: float, k: int) -> float:
        """Var(R̂_b) (paper Eq. 7)."""
        pb = self.pb(R)
        return pb * (1.0 - pb) / (k * (1.0 - self.C2) ** 2)


def _A(r: float, b: int) -> float:
    if r == 0.0:
        return 1.0 / (1 << b)  # the r→0 limit (paper Eq. 4)
    q = (1.0 - r) ** (1 << b)
    return r * (1.0 - r) ** ((1 << b) - 1) / (1.0 - q)


def bbit_law_sparse_limit(b: int):
    """The r1,r2→0 limit: P_b = 1/2^b + (1 - 1/2^b)·R (paper Eq. 5)."""
    inv = 1.0 / (1 << b)

    def pb(R: float) -> float:
        return inv + (1.0 - inv) * R

    return pb


# -- random projections (paper §5.1) ----------------------------------------
def var_rp(u1: np.ndarray, u2: np.ndarray, k: int, s: float = 1.0) -> float:
    """Var(â_rp,s) (paper Eq. 13)."""
    m1 = float(np.sum(u1 * u1))
    m2 = float(np.sum(u2 * u2))
    a = float(np.sum(u1 * u2))
    cross = float(np.sum((u1 * u2) ** 2))
    return (m1 * m2 + a * a + (s - 3.0) * cross) / k


# -- VW (paper §5.2) ---------------------------------------------------------
def var_vw(u1: np.ndarray, u2: np.ndarray, k: int, s: float = 1.0) -> float:
    """Var(â_vw,s) (paper Eq. 16); equals Eq. 13 at s=1."""
    m1 = float(np.sum(u1 * u1))
    m2 = float(np.sum(u2 * u2))
    a = float(np.sum(u1 * u2))
    cross = float(np.sum((u1 * u2) ** 2))
    return (s - 1.0) * cross + (m1 * m2 + a * a - 2.0 * cross) / k


def storage_equivalent_k_vw(k_bbit: int, b: int,
                            bits_per_vw_entry: int = 32) -> int:
    """VW bins affordable at the same storage as (k_bbit, b) codes."""
    return max(1, (k_bbit * b) // bits_per_vw_entry)
