"""High-level trainers reproducing the paper's LIBLINEAR experiments.

``train_bbit_liblinear``   — TRON on the exact Eq. (8)/(9) objective over
                             b-bit hashed codes (the paper's setup).
``train_vw_liblinear``     — same solver over VW sketches (paper §5.4).
``train_bbit_sgd``         — minibatch SGD/AdamW path for the scale-out
                             scenario (distributed, checkpointable).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.linear import (
    BBitLinearConfig, VWLinearConfig,
    init_bbit_linear, init_vw_linear,
    bbit_logits, vw_logits, predict_classes, vw_predict,
)
from repro.optim.tron import tron_minimize
from repro.optim.optimizers import make_optimizer
from repro.train.losses import (
    liblinear_objective, mean_loss_fn, LOSS_D2,
)
from repro.train.metrics import accuracy
from repro.train.steps import init_state, build_train_step


def make_liblinear_hvp(forward, loss: str, C: float, codes, labels):
    """Analytic Hv = v + C·Xᵀ(ℓ″(m)⊙Xv) for models *linear* in params.

    Works through custom_vjp kernels (uses only forward + VJP, no
    forward-mode AD) and matches LIBLINEAR's TRON Hessian exactly.
    """
    d2_fn = LOSS_D2[loss]
    y = 2.0 * labels.astype(jnp.float32) - 1.0

    def hvp(params, v):
        logits, vjp_fn = jax.vjp(lambda p: forward(p, codes), params)
        m = y * logits[:, 0]
        d2 = d2_fn(m)
        jv = forward(v, codes)[:, 0]        # J·v — forward is linear
        hv_logits = (C * d2 * jv)[:, None]
        hv = vjp_fn(hv_logits)[0]
        return jax.tree.map(
            lambda a, b: a.astype(jnp.float32) + b.astype(jnp.float32),
            v, hv)

    return hvp


@dataclasses.dataclass
class FitResult:
    params: object
    train_seconds: float
    train_acc: float
    test_acc: float
    n_iter: int
    objective: float


def train_bbit_liblinear(
    codes_tr: np.ndarray, y_tr: np.ndarray,
    codes_te: np.ndarray, y_te: np.ndarray,
    cfg: BBitLinearConfig, *,
    loss: str = "logistic",      # 'logistic' (Eq. 9) | 'squared_hinge' (Eq. 8)
    C: float = 1.0,
    max_iter: int = 60,
) -> FitResult:
    fwd = lambda p, c: bbit_logits(p, c, cfg)
    obj = liblinear_objective(fwd, loss, C)
    codes_tr_j = jnp.asarray(codes_tr)
    y_tr_j = jnp.asarray(y_tr)
    w0 = init_bbit_linear(cfg)
    hvp = make_liblinear_hvp(fwd, loss, C, codes_tr_j, y_tr_j)
    t0 = time.perf_counter()
    res = tron_minimize(lambda p: obj(p, codes_tr_j, y_tr_j), w0,
                        hvp=hvp, max_iter=max_iter)
    dt = time.perf_counter() - t0
    tr_acc = accuracy(predict_classes(res.params, codes_tr_j, cfg), y_tr)
    te_acc = accuracy(
        predict_classes(res.params, jnp.asarray(codes_te), cfg), y_te)
    return FitResult(res.params, dt, tr_acc, te_acc, res.n_iter, res.fun)


def train_vw_liblinear(
    sk_tr: np.ndarray, y_tr: np.ndarray,
    sk_te: np.ndarray, y_te: np.ndarray,
    cfg: VWLinearConfig, *,
    loss: str = "logistic",
    C: float = 1.0,
    max_iter: int = 60,
) -> FitResult:
    fwd = lambda p, x: vw_logits(p, x, cfg)
    obj = liblinear_objective(fwd, loss, C)
    x_tr = jnp.asarray(sk_tr)
    y_tr_j = jnp.asarray(y_tr)
    w0 = init_vw_linear(cfg)
    hvp = make_liblinear_hvp(fwd, loss, C, x_tr, y_tr_j)
    t0 = time.perf_counter()
    res = tron_minimize(lambda p: obj(p, x_tr, y_tr_j), w0,
                        hvp=hvp, max_iter=max_iter)
    dt = time.perf_counter() - t0
    tr_acc = accuracy(vw_predict(res.params, x_tr, cfg), y_tr)
    te_acc = accuracy(vw_predict(res.params, jnp.asarray(sk_te), cfg), y_te)
    return FitResult(res.params, dt, tr_acc, te_acc, res.n_iter, res.fun)


def train_bbit_sgd(
    codes_tr: np.ndarray, y_tr: np.ndarray,
    codes_te: np.ndarray, y_te: np.ndarray,
    cfg: BBitLinearConfig, *,
    loss: str = "logistic",
    optimizer: str = "adamw",
    lr: float = 1e-2,
    l2: float = 1e-6,
    epochs: int = 5,
    batch_size: int = 256,
    seed: int = 0,
) -> FitResult:
    n = codes_tr.shape[0]
    if n < 1:
        raise ValueError("train_bbit_sgd: empty training set")
    if epochs < 1:
        raise ValueError(f"train_bbit_sgd: epochs must be >= 1, got {epochs}")
    fwd = lambda p, c: bbit_logits(p, c, cfg)
    loss_fn = mean_loss_fn(fwd, loss, l2=l2)
    opt = make_optimizer(optimizer, lr)
    state = init_state(init_bbit_linear(cfg, jax.random.key(seed)), opt)
    step_fn = build_train_step(loss_fn, opt)
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    steps = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        # the final partial minibatch trains too: stepping to
        # n - batch_size + 1 would silently drop the tail each epoch
        # and perform ZERO steps whenever n < batch_size
        for lo in range(0, n, batch_size):
            sel = order[lo: lo + batch_size]
            state, _ = step_fn(state, jnp.asarray(codes_tr[sel]),
                               jnp.asarray(y_tr[sel]))
            steps += 1
    dt = time.perf_counter() - t0
    assert steps > 0, "SGD performed no steps — params are untrained"
    tr_acc = accuracy(
        predict_classes(state.params, jnp.asarray(codes_tr), cfg), y_tr)
    te_acc = accuracy(
        predict_classes(state.params, jnp.asarray(codes_te), cfg), y_te)
    return FitResult(state.params, dt, tr_acc, te_acc, steps, float("nan"))
