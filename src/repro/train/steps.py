"""Jitted train/eval step builders shared by linear and LM training.

``build_train_step`` is the plain SGD/AdamW step; ``build_averaged_
train_step`` wraps the same update with Polyak tail averaging
(``optim.averaging``) threaded through an ``AveragedTrainState`` — the
averaged-weights state the streaming trainer checkpoints, so a resumed
run continues the running mean bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.averaging import init_average, polyak_update
from repro.optim.optimizers import Optimizer


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def init_state(params, optimizer: Optimizer) -> TrainState:
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def build_train_step(loss_fn: Callable, optimizer: Optimizer,
                     donate: bool = True):
    """loss_fn(params, *batch) -> scalar.  Returns jitted step fn."""

    def step(state: TrainState, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, *batch)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        return TrainState(new_params, new_opt, state.step + 1), loss

    return jax.jit(step, donate_argnums=(0,) if donate else ())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AveragedTrainState:
    """TrainState plus the Polyak running mean of the parameters.

    ``avg_params`` is the f32 running mean over the steps where the
    averaging gate was active (tail averaging); ``avg_count`` the
    number of averaged steps.  Checkpointing the whole structure makes
    kill/resume reproduce the averaged iterate exactly.
    """

    state: TrainState
    avg_params: Any
    avg_count: jax.Array

    def tree_flatten(self):
        return (self.state, self.avg_params, self.avg_count), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def init_averaged_state(params, optimizer: Optimizer) -> AveragedTrainState:
    avg, count = init_average(params)
    return AveragedTrainState(state=init_state(params, optimizer),
                              avg_params=avg, avg_count=count)


def build_averaged_train_step(loss_fn: Callable, optimizer: Optimizer,
                              donate: bool = True, has_aux: bool = False):
    """``loss_fn(params, *batch) -> scalar`` (or ``(scalar, aux)`` with
    ``has_aux``); returns a jitted
    ``step(astate, active, *batch) -> (astate, loss | (loss, aux))``.

    ``active`` (0/1, traced — toggling it does NOT retrace) gates
    whether the post-update parameters join the Polyak average: pass 0
    during burn-in and 1 once the tail-averaging window opens.
    ``has_aux`` lets the loss return pre-update side products from the
    SAME forward pass — the streaming trainer rides its progressive-
    validation hit count through here instead of paying a second
    forward per batch.
    """

    def step(astate: AveragedTrainState, active, *batch):
        out, grads = jax.value_and_grad(loss_fn, has_aux=has_aux)(
            astate.state.params, *batch)
        new_params, new_opt = optimizer.update(
            grads, astate.state.opt_state, astate.state.params,
            astate.state.step)
        avg, count = polyak_update(astate.avg_params, astate.avg_count,
                                   new_params, active)
        new_state = TrainState(new_params, new_opt, astate.state.step + 1)
        return AveragedTrainState(new_state, avg, count), out

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def build_microbatched_train_step(loss_fn: Callable, optimizer: Optimizer,
                                  n_micro: int):
    """Gradient accumulation over n_micro microbatches via lax.scan.

    Batch arrays must have a leading dim divisible by n_micro; the
    scan keeps only one microbatch's activations live at a time —
    the activation-memory knob used by the big-arch dry-runs.
    """

    def step(state: TrainState, *batch):
        def reshape(x):
            return x.reshape((n_micro, x.shape[0] // n_micro)
                             + x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        grad_fn = jax.value_and_grad(loss_fn)

        def body(carry, mb):
            acc, loss_acc = carry
            loss, g = grad_fn(state.params, *mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + loss), ()

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        return (TrainState(new_params, new_opt, state.step + 1),
                loss_sum / n_micro)

    return jax.jit(step, donate_argnums=(0,))
