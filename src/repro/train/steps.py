"""Jitted train/eval step builders shared by linear and LM training."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def init_state(params, optimizer: Optimizer) -> TrainState:
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def build_train_step(loss_fn: Callable, optimizer: Optimizer,
                     donate: bool = True):
    """loss_fn(params, *batch) -> scalar.  Returns jitted step fn."""

    def step(state: TrainState, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, *batch)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        return TrainState(new_params, new_opt, state.step + 1), loss

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def build_microbatched_train_step(loss_fn: Callable, optimizer: Optimizer,
                                  n_micro: int):
    """Gradient accumulation over n_micro microbatches via lax.scan.

    Batch arrays must have a leading dim divisible by n_micro; the
    scan keeps only one microbatch's activations live at a time —
    the activation-memory knob used by the big-arch dry-runs.
    """

    def step(state: TrainState, *batch):
        def reshape(x):
            return x.reshape((n_micro, x.shape[0] // n_micro)
                             + x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        grad_fn = jax.value_and_grad(loss_fn)

        def body(carry, mb):
            acc, loss_acc = carry
            loss, g = grad_fn(state.params, *mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, loss_acc + loss), ()

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step)
        return (TrainState(new_params, new_opt, state.step + 1),
                loss_sum / n_micro)

    return jax.jit(step, donate_argnums=(0,))
