"""Data-parallel averaged train step: ``shard_map`` + ``psum_mean``.

The multi-device half of the streaming hot path (ROADMAP: "multi-host
data-parallel streaming over ``distributed/``"): each device of a 1-D
``("data",)`` mesh (``launch.mesh.make_data_mesh``) trains on its OWN
shard of the epoch — batches arrive stacked ``(world, B, …)`` from
``data.prefetch.group_batch_stream`` — while parameters stay
replicated:

  * every device computes the masked per-example SUM loss over its
    valid rows (``train.losses.sum_loss_with_hits_fn``; padding rows
    and shard-less devices contribute nothing);
  * the local gradient sums are pre-scaled by ``world / Σ_devices
    valid`` so the ``psum_mean`` gradient all-reduce
    (``distributed.collectives``) yields EXACTLY the gradient of the
    mean loss over the union of all devices' real rows — uneven tails
    and zero-row devices change the weighting not at all; the L2 term
    is added once AFTER the all-reduce (replicated params → identical
    on every device);
  * each step pays exactly TWO all-reduces — the (loss, hits, rows)
    scalar triple crosses stacked, the gradient tree crosses fused
    inside ``psum_mean`` — because collective setup cost, not payload,
    dominates small steps (hit counts ride as f32, exact far beyond
    any realistic batch); the trainer drains one replicated hits
    scalar per step exactly like the serial path;
  * the optimizer and Polyak-average update run on the all-reduced
    gradient with replicated inputs → parameters remain bitwise
    replicated without any weight broadcast, and a device that
    contributed zero rows still applies the identical global update
    (Polyak averaging cannot skew).

A device with NO valid rows this step is safe but a step where NO
device has rows cannot happen: ``group_batch_stream`` emits exactly
``max_d ceil(rows_d / B)`` steps per group, and the device attaining
the max has a non-empty batch at every one of them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                   # moved out of experimental ≥ 0.5
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.distributed.collectives import psum_mean
from repro.optim.averaging import polyak_update
from repro.optim.optimizers import Optimizer
from repro.train.steps import AveragedTrainState, TrainState

AXIS = "data"


def device_put_sharded(x, mesh: Mesh):
    """Places a stacked ``(world, …)`` host array with row d on device
    d (leading-axis sharding over the mesh's data axis)."""
    return jax.device_put(x, NamedSharding(mesh, P(AXIS)))


def build_dp_averaged_train_step(
    loss_sum_fn: Callable,
    optimizer: Optimizer,
    mesh: Mesh,
    *,
    l2: float = 0.0,
    donate: bool = True,
):
    """``loss_sum_fn(params, batch, labels, valid) -> (loss_sum, hits)``
    (per-device, masked sums); returns a jitted

        ``step(astate, active, batch, labels, valid)
            -> (astate, (mean_loss, hits))``

    where ``batch``/``labels``/``valid`` are stacked ``(world, B, …)``
    arrays sharded over the mesh (``device_put_sharded``), ``astate``
    is replicated, ``mean_loss`` is the global mean over valid rows
    (plus the L2 term, matching ``mean_loss_with_preds_fn``'s
    parameterization) and ``hits`` the global correct-prediction count
    — both replicated scalars.
    """
    world = mesh.shape[AXIS]

    def _local(astate: AveragedTrainState, active, batch, labels, valid):
        # per-device blocks arrive with a leading axis of 1 — peel it
        batch = jax.tree.map(lambda x: x[0], batch)
        labels, valid = labels[0], valid[0]
        vmask = valid.astype(jnp.float32)

        def local_objective(params):
            lsum, hits = loss_sum_fn(params, batch, labels, valid)
            return lsum, (lsum, hits)

        (_, (lsum, hits)), gsum = jax.value_and_grad(
            local_objective, has_aux=True)(astate.state.params)

        # exactly TWO all-reduces per step (collective setup dominates
        # small steps): the scalar triple crosses stacked, then the
        # whole gradient tree crosses fused inside psum_mean.
        scalars = jax.lax.psum(
            jnp.stack([lsum, hits.astype(jnp.float32),
                       jnp.sum(vmask)]), AXIS)
        lsum_g, hits_g, total = scalars[0], scalars[1], scalars[2]
        # pre-scale so psum_mean (= psum / world) lands on
        # psum(grad lsum) / total — the gradient of the mean loss over
        # the union of all devices' real rows.  The scale is cast to
        # each leaf's dtype: a strong-f32 multiply would widen bf16
        # grads before psum_mean's dtype preservation ever engages.
        scale = jnp.float32(world) / total
        grads = psum_mean(
            jax.tree.map(lambda g: g * scale.astype(g.dtype), gsum),
            AXIS)
        mean_loss = lsum_g / total
        if l2:
            # replicated params → identical reg term on every device;
            # added AFTER the all-reduce so it is counted exactly once
            grads = jax.tree.map(
                lambda g, p: g + (l2 * p.astype(jnp.float32))
                .astype(g.dtype),
                grads, astate.state.params)
            mean_loss = mean_loss + 0.5 * l2 * sum(
                jnp.sum(p.astype(jnp.float32) ** 2)
                for p in jax.tree.leaves(astate.state.params))
        hits = hits_g.astype(jnp.int32)

        new_params, new_opt = optimizer.update(
            grads, astate.state.opt_state, astate.state.params,
            astate.state.step)
        avg, count = polyak_update(astate.avg_params, astate.avg_count,
                                   new_params, active)
        new_state = TrainState(new_params, new_opt,
                               astate.state.step + 1)
        return (AveragedTrainState(new_state, avg, count),
                mean_loss, hits)

    smapped = shard_map(
        _local, mesh=mesh,
        in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(), P(), P()),
        # the packed-logits custom_vjp has no replication rule; outputs
        # are replicated by construction (post-psum values only)
        check_rep=False)

    def step(astate, active, batch, labels, valid):
        astate, loss, hits = smapped(astate, active, batch, labels,
                                     valid)
        return astate, (loss, hits)

    return jax.jit(step, donate_argnums=(0,) if donate else ())
