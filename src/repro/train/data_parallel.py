"""Data-parallel averaged train step: ``shard_map`` + ``psum_mean``.

The multi-device half of the streaming hot path (ROADMAP: "multi-host
data-parallel streaming over ``distributed/``"): each device of a 1-D
``("data",)`` mesh (``launch.mesh.make_data_mesh``) trains on its OWN
shard of the epoch — batches arrive stacked ``(world, B, …)`` from
``data.prefetch.group_batch_stream`` — while parameters stay
replicated:

  * every device computes the masked per-example SUM loss over its
    valid rows (``train.losses.sum_loss_with_hits_fn``; padding rows
    and shard-less devices contribute nothing);
  * the all-reduced gradient SUM is scaled by ``physical / Σ_devices
    valid`` once AFTER ``psum_mean`` (= psum / physical), landing on
    exactly the gradient of the mean loss over the union of all
    devices' real rows — uneven tails and zero-row devices change the
    weighting not at all.  Scaling after the reduction (sum-then-
    scale, not scale-then-sum) is what makes the update bitwise
    invariant to the physical device count: for power-of-two device
    counts the psum_mean division and the ``physical/total`` factor
    are exact power-of-two rescalings of the same gradient sum, so
    the same logical schedule produces bit-identical parameters
    whether its shard slots live on N devices or fold onto fewer
    (the elastic-resume property, tests/test_fault_tolerance.py).
    The L2 term is added once after the all-reduce (replicated params
    → identical on every device);
  * **elastic folding** (``logical_world > physical``): the stacked
    batch keeps its LOGICAL leading axis; each device receives a
    ``(fold, B, …)`` block and loops its ``fold = logical/physical``
    shard slots sequentially, accumulating loss/hit/row sums and the
    gradient sum in slot order before the collectives run — the
    schedule, and hence the replayed step sequence, is a function of
    the logical world only;
  * each step pays exactly TWO all-reduces — the (loss, hits, rows)
    scalar triple crosses stacked, the gradient tree crosses fused
    inside ``psum_mean`` — because collective setup cost, not payload,
    dominates small steps (hit counts ride as f32, exact far beyond
    any realistic batch); the trainer drains one replicated hits
    scalar per step exactly like the serial path;
  * the optimizer and Polyak-average update run on the all-reduced
    gradient with replicated inputs → parameters remain bitwise
    replicated without any weight broadcast, and a device that
    contributed zero rows still applies the identical global update
    (Polyak averaging cannot skew).

A device with NO valid rows this step is safe but a step where NO
device has rows cannot happen: ``group_batch_stream`` emits exactly
``max_d ceil(rows_d / B)`` steps per group, and the device attaining
the max has a non-empty batch at every one of them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                   # moved out of experimental ≥ 0.5
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.distributed.collectives import psum_mean
from repro.distributed.grad_compression import (
    tree_compressed_allreduce_mean,
)
from repro.optim.averaging import polyak_update
from repro.optim.optimizers import Optimizer
from repro.train.steps import AveragedTrainState, TrainState

AXIS = "data"


def init_dp_error_state(params, physical: int):
    """Zero error-feedback memory for the compressed all-reduce: one
    f32 copy of every param leaf PER DATA-MESH DEVICE, stacked on a
    leading ``physical`` axis (the memory is device-local state — each
    device accumulates its own quantization residual)."""
    return jax.tree.map(
        lambda p: jnp.zeros((physical,) + tuple(p.shape), jnp.float32),
        params)


def device_put_sharded(x, mesh: Mesh):
    """Places a stacked ``(world, …)`` host array with row d on device
    d (leading-axis sharding over the mesh's data axis)."""
    return jax.device_put(x, NamedSharding(mesh, P(AXIS)))


def device_put_process_local(x_local, mesh: Mesh, logical: int):
    """Assembles the global stacked ``(logical, …)`` array from this
    process's contiguous slot block (multi-process gangs).

    ``device_put`` can only address local devices; on a mesh spanning
    processes the global array is built from each process's local
    rows — valid because ``distributed.runtime.mesh_over_processes``
    orders devices by process, so process p's slots are exactly the
    leading-axis rows its mesh devices carry."""
    sh = NamedSharding(mesh, P(AXIS))
    global_shape = (logical,) + tuple(x_local.shape[1:])
    return jax.make_array_from_process_local_data(sh, x_local,
                                                  global_shape)


def build_dp_averaged_train_step(
    loss_sum_fn: Callable,
    optimizer: Optimizer,
    mesh: Mesh,
    *,
    l2: float = 0.0,
    donate: bool = True,
    logical_world: int = None,
    compress: "dict | None" = None,
):
    """``loss_sum_fn(params, batch, labels, valid) -> (loss_sum, hits)``
    (per-device, masked sums); returns a jitted

        ``step(carry, active, batch, labels, valid)
            -> (carry, (mean_loss, hits))``

    where ``batch``/``labels``/``valid`` are stacked
    ``(logical_world, B, …)`` arrays sharded over the mesh's data axis
    (``device_put_sharded``), ``carry`` is the replicated ``astate``,
    ``mean_loss`` is the global mean over valid rows (plus the L2
    term, matching ``mean_loss_with_preds_fn``'s parameterization) and
    ``hits`` the global correct-prediction count — both replicated
    scalars.

    ``logical_world`` (default: the mesh's data-axis size) may exceed
    the physical device count by an integer factor — each device then
    folds ``logical_world / physical`` shard slots sequentially (the
    elastic-resume path, see the module docstring).

    ``compress`` (e.g. ``{"bits": 8, "block": 256}``) swaps the exact
    fp32 ``psum_mean`` gradient exchange for the error-feedback
    compressed all-reduce (``distributed.grad_compression`` — int8
    blockwise-absmax or sign+scale on the wire, the paper family's
    b-bit storage argument applied to the gradient).  The carry then
    becomes ``(astate, err)`` with ``err`` the per-device residual
    memory from ``init_dp_error_state`` (leading ``physical`` axis,
    sharded over the mesh).  ``compress=None`` leaves the exact path
    byte-for-byte untouched.
    """
    physical = mesh.shape[AXIS]
    logical = physical if logical_world is None else int(logical_world)
    if logical % physical:
        raise ValueError(
            f"logical world {logical} is not a multiple of the mesh's "
            f"{physical} data-axis devices — shard slots cannot fold "
            "evenly")
    fold = logical // physical

    def _accumulate(params, batch, labels, valid):
        # per-device blocks arrive with a leading axis of ``fold``:
        # run each shard slot and accumulate sums in slot order
        def slot(params, f):
            batch_f = jax.tree.map(lambda x: x[f], batch)
            labels_f, valid_f = labels[f], valid[f]

            def local_objective(p):
                lsum, hits = loss_sum_fn(p, batch_f, labels_f, valid_f)
                return lsum, (lsum, hits)

            (_, (lsum, hits)), g = jax.value_and_grad(
                local_objective, has_aux=True)(params)
            return (lsum, hits.astype(jnp.float32),
                    jnp.sum(valid_f.astype(jnp.float32)), g)

        lsum, hits_f, rows, gsum = slot(params, 0)
        for f in range(1, fold):
            l_f, h_f, r_f, g_f = slot(params, f)
            lsum = lsum + l_f
            hits_f = hits_f + h_f
            rows = rows + r_f
            gsum = jax.tree.map(jnp.add, gsum, g_f)
        return lsum, hits_f, rows, gsum

    def _apply(astate, active, grads, lsum_g, hits_g, total):
        mean_loss = lsum_g / total
        if l2:
            # replicated params → identical reg term on every device;
            # added AFTER the all-reduce so it is counted exactly once
            grads = jax.tree.map(
                lambda g, p: g + (l2 * p.astype(jnp.float32))
                .astype(g.dtype),
                grads, astate.state.params)
            mean_loss = mean_loss + 0.5 * l2 * sum(
                jnp.sum(p.astype(jnp.float32) ** 2)
                for p in jax.tree.leaves(astate.state.params))
        hits = hits_g.astype(jnp.int32)

        new_params, new_opt = optimizer.update(
            grads, astate.state.opt_state, astate.state.params,
            astate.state.step)
        avg, count = polyak_update(astate.avg_params, astate.avg_count,
                                   new_params, active)
        new_state = TrainState(new_params, new_opt,
                               astate.state.step + 1)
        return (AveragedTrainState(new_state, avg, count),
                mean_loss, hits)

    def _local(astate: AveragedTrainState, active, batch, labels, valid):
        lsum, hits_f, rows, gsum = _accumulate(
            astate.state.params, batch, labels, valid)
        # exactly TWO all-reduces per step (collective setup dominates
        # small steps): the scalar triple crosses stacked, then the
        # whole gradient tree crosses fused inside psum_mean.
        scalars = jax.lax.psum(jnp.stack([lsum, hits_f, rows]), AXIS)
        lsum_g, hits_g, total = scalars[0], scalars[1], scalars[2]
        # scale AFTER the reduction: psum_mean (= psum / physical)
        # then × physical/total lands on psum(grad lsum) / total — the
        # gradient of the mean loss over the union of all devices'
        # real rows — via exact power-of-two rescalings, so the result
        # is bitwise independent of how the logical slots fold onto
        # physical devices.  The scale is cast to each leaf's dtype: a
        # strong-f32 multiply would widen bf16 grads.
        scale = jnp.float32(physical) / total
        grads = jax.tree.map(
            lambda g: g * scale.astype(g.dtype),
            psum_mean(gsum, AXIS))
        return _apply(astate, active, grads, lsum_g, hits_g, total)

    def _local_compressed(carry, active, batch, labels, valid):
        astate, err_blk = carry
        lsum, hits_f, rows, gsum = _accumulate(
            astate.state.params, batch, labels, valid)
        scalars = jax.lax.psum(jnp.stack([lsum, hits_f, rows]), AXIS)
        lsum_g, hits_g, total = scalars[0], scalars[1], scalars[2]
        # the gradient crosses quantized: EF all-reduce returns the
        # mean of the dequantized per-device sums (= psum_mean of the
        # quantized payload), so the same post-reduction
        # physical/total scaling applies; the residual stays local
        err = jax.tree.map(lambda x: x[0], err_blk)
        grads, new_err = tree_compressed_allreduce_mean(
            gsum, err, AXIS, block=int(compress.get("block", 256)),
            bits=int(compress.get("bits", 8)))
        scale = jnp.float32(physical) / total
        grads = jax.tree.map(
            lambda g: g * scale.astype(g.dtype), grads)
        astate, mean_loss, hits = _apply(astate, active, grads, lsum_g,
                                         hits_g, total)
        new_err_blk = jax.tree.map(lambda x: x[None], new_err)
        return (astate, new_err_blk), mean_loss, hits

    if compress is None:
        smapped = shard_map(
            _local, mesh=mesh,
            in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(), P(), P()),
            # the packed-logits custom_vjp has no replication rule;
            # outputs are replicated by construction (post-psum values
            # only)
            check_rep=False)
    else:
        smapped = shard_map(
            _local_compressed, mesh=mesh,
            in_specs=((P(), P(AXIS)), P(), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=((P(), P(AXIS)), P(), P()),
            check_rep=False)

    def step(carry, active, batch, labels, valid):
        carry, loss, hits = smapped(carry, active, batch, labels,
                                    valid)
        return carry, (loss, hits)

    return jax.jit(step, donate_argnums=(0,) if donate else ())
