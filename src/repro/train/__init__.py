"""Training substrate: losses, steps, metrics, trainers.

Two ways to fit the paper's linear models over hashed codes:

  * in-memory (``linear_trainer``): TRON on the exact LIBLINEAR
    objective, or minibatch SGD over a materialized code matrix;
  * streaming (``streaming.fit_streaming``): one-pass / multi-epoch
    SGD + Polyak tail averaging straight off format-v3 packed shard
    archives — codes are unpacked on the device inside the train step,
    progressive validation reports the VW-style one-pass accuracy, and
    shard-boundary checkpoints make kill/resume bit-exact.  This is
    the path for data that never fits in memory (the paper's 200 GB
    regime).
"""
from repro.train.losses import (
    logistic, hinge, squared_hinge, softmax_xent, binary_margins,
    liblinear_objective, mean_loss_fn, mean_loss_with_preds_fn,
    sum_loss_with_hits_fn, LOSSES,
)
from repro.train.data_parallel import (
    build_dp_averaged_train_step, device_put_sharded,
)
from repro.train.steps import (
    TrainState, init_state, build_train_step, build_microbatched_train_step,
    AveragedTrainState, init_averaged_state, build_averaged_train_step,
)
from repro.train.metrics import (
    accuracy, batched_accuracy, trees_bitwise_equal,
)
from repro.train.linear_trainer import (
    FitResult, train_bbit_liblinear, train_vw_liblinear, train_bbit_sgd,
)
from repro.train.streaming import StreamFitResult, fit_streaming
from repro.train.supervisor import (
    CrashRecord, MultiProcessRun, RestartPolicy, SupervisedRun,
    run_multiprocess_supervised, run_supervised,
)

__all__ = [
    "logistic", "hinge", "squared_hinge", "softmax_xent", "binary_margins",
    "liblinear_objective", "mean_loss_fn", "mean_loss_with_preds_fn",
    "sum_loss_with_hits_fn", "LOSSES",
    "build_dp_averaged_train_step", "device_put_sharded",
    "TrainState", "init_state", "build_train_step",
    "build_microbatched_train_step",
    "AveragedTrainState", "init_averaged_state", "build_averaged_train_step",
    "accuracy", "batched_accuracy", "trees_bitwise_equal",
    "FitResult", "train_bbit_liblinear", "train_vw_liblinear",
    "train_bbit_sgd",
    "StreamFitResult", "fit_streaming",
    "CrashRecord", "RestartPolicy", "SupervisedRun", "run_supervised",
    "MultiProcessRun", "run_multiprocess_supervised",
]
