"""Training substrate: losses, steps, metrics, trainers."""
from repro.train.losses import (
    logistic, hinge, squared_hinge, softmax_xent, binary_margins,
    liblinear_objective, mean_loss_fn, LOSSES,
)
from repro.train.steps import (
    TrainState, init_state, build_train_step, build_microbatched_train_step,
)
from repro.train.metrics import accuracy, batched_accuracy
from repro.train.linear_trainer import (
    FitResult, train_bbit_liblinear, train_vw_liblinear, train_bbit_sgd,
)

__all__ = [
    "logistic", "hinge", "squared_hinge", "softmax_xent", "binary_margins",
    "liblinear_objective", "mean_loss_fn", "LOSSES",
    "TrainState", "init_state", "build_train_step",
    "build_microbatched_train_step",
    "accuracy", "batched_accuracy",
    "FitResult", "train_bbit_liblinear", "train_vw_liblinear",
    "train_bbit_sgd",
]
