"""Per-rank worker entrypoint for multi-process gang training.

``python -m repro.train.worker --spec <path>`` is what
``train.supervisor.run_multiprocess_supervised`` (and
``launch.train --procs N``) execs once per rank.  The spec is a JSON
file fully describing one rank's run::

    {"root": ..., "cfg": {...BBitLinearConfig fields...},
     "fit": {...fit_streaming kwargs...},
     "procs": 2, "rank": 0, "coordinator": "127.0.0.1:12345",
     "run_dir": ..., "fault_spec": {...}, "fault_state": ...,
     "result_path": ..., "params_path": ...}

Order of operations matters and is the whole point of this module:

  1. **arm the fault plan** (``ft.faults.FaultPlan.from_spec`` — the
     per-rank ``fault_state`` file restores fired counts, so a
     ``times=1`` process kill does not re-fire after a gang respawn);
  2. **bootstrap the runtime** (``distributed.runtime.init_runtime``:
     gloo + ``jax.distributed.initialize`` + ``faults.set_rank`` —
     before any jax computation);
  3. train (``fit_streaming(..., runtime=rt)``);
  4. dump this rank's result record + final/averaged params.

Exit codes are the supervisor protocol: 0 = finished, **64** =
``ValueError`` (a configuration/compatibility error — deterministic,
the supervisor must NOT retry it), anything else (including signal
deaths) = a crash the supervisor may restart.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

CONFIG_ERROR_EXIT = 64


def _dump_params(path: str, result) -> None:
    import jax
    import numpy as np

    arrs = {}
    for i, leaf in enumerate(jax.tree.leaves(result.params)):
        arrs[f"p{i}"] = np.asarray(jax.device_get(leaf))
    if result.avg_params is not None:
        for i, leaf in enumerate(jax.tree.leaves(result.avg_params)):
            arrs[f"a{i}"] = np.asarray(jax.device_get(leaf))
    np.savez(path, **arrs)


def run_spec(spec: dict) -> int:
    """Executes one rank's spec; returns the process exit code."""
    from repro.ft import faults

    if spec.get("fault_spec"):
        plan = faults.FaultPlan.from_spec(spec["fault_spec"],
                                          spec.get("fault_state"))
        faults.arm_plan(plan)

    from repro.distributed.runtime import heartbeat, init_runtime

    rt = init_runtime(procs=int(spec.get("procs", 1)),
                      rank=int(spec.get("rank", 0)),
                      coordinator=spec.get("coordinator"),
                      run_dir=spec.get("run_dir"))

    from repro.models.linear import BBitLinearConfig
    from repro.train.streaming import fit_streaming

    cfg = BBitLinearConfig(**spec["cfg"])
    try:
        result = fit_streaming(spec["root"], cfg, runtime=rt,
                               **spec.get("fit", {}))
    except ValueError:
        traceback.print_exc()
        return CONFIG_ERROR_EXIT

    if spec.get("params_path"):
        _dump_params(spec["params_path"], result)
    if spec.get("result_path"):
        rec = {"rank": rt.rank, "procs": rt.procs,
               "n_steps": result.n_steps,
               "examples_seen": result.examples_seen,
               "shards_processed": result.shards_processed,
               "progressive_acc": result.progressive_acc,
               "completed": result.completed,
               "train_seconds": result.train_seconds,
               "lineage": result.topology_lineage}
        with open(spec["result_path"], "w") as f:
            json.dump(rec, f)
    heartbeat(rt, step=result.n_steps,
              shards_done=result.shards_processed, phase="done")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.train.worker",
        description="one rank of a multi-process streaming training "
                    "gang (spawned by train.supervisor)")
    ap.add_argument("--spec", required=True,
                    help="path to this rank's JSON spec")
    args = ap.parse_args(argv)
    with open(args.spec) as f:
        spec = json.load(f)
    return run_spec(spec)


if __name__ == "__main__":
    sys.exit(main())
