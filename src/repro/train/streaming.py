"""One-pass / multi-epoch streaming trainer over hashed shard archives.

The paper's headline scenario trains on 200 GB — data that never fits
in memory.  Preprocessing has streamed since PR 2 (``HashedShardWriter``
writes format-v3 packed shards in O(one shard) memory); this module
makes the TRAINING side stream too, closing the loop arXiv:1205.2958 §5
draws against VW's online mode:

  * ``fit_streaming`` iterates the archive one shard at a time through
    ``data.hashed_dataset.iter_hashed_batches`` (minibatches sliced
    off mmap'd packed bytes — the full (n, k) code matrix is never
    materialized, resident memory is one shard's packed pages + one
    minibatch);
  * minibatches cross the host↔device boundary PACKED — ceil(k·b/8)
    bytes per row — and are widened on the device by
    ``core.bbit.unpack_codes_jnp`` *inside* the jitted train step
    (``oph_zero`` archives also carry their packed empty bitmask,
    widened by ``unpack_mask_jnp`` and fed to ``bbit_logits``);
  * the update is plain minibatch SGD/AdamW through the existing
    ``build_train_step`` machinery, wrapped with Polyak *tail*
    averaging (``optim.averaging`` via ``build_averaged_train_step``)
    — the averaged iterate is the VW-style online baseline;
  * **progressive validation**: every example is scored with the
    current model BEFORE its gradient step, so ``progressive_acc`` is
    the honest one-pass generalization estimate VW reports online;
  * shard order is reshuffled and every shard's rows re-permuted each
    epoch, both as pure functions of ``(seed, epoch, shard)`` — so a
    restarted run replays identical batches;
  * ``ckpt_dir`` checkpoints the FULL ``AveragedTrainState`` + stream
    position at shard boundaries through ``ckpt.checkpoint``; a killed
    run resumes at the shard boundary and reproduces the uninterrupted
    run bit-for-bit (tested).

Typical use::

    stats = preprocess_and_save(root, rows, labels, k=256, b=8,
                                scheme="oph", n_shards=64)
    res = fit_streaming(root, BBitLinearConfig(k=256, b=8),
                        epochs=1, batch_size=1024,
                        ckpt_dir=root + "/ckpt")
    w = res.eval_params            # Polyak average (or raw iterate)
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core.bbit import unpack_codes_jnp, unpack_mask_jnp
from repro.data.hashed_dataset import (
    _read_meta, iter_hashed_batches, shard_row_counts,
)
from repro.models.linear import BBitLinearConfig, bbit_logits, init_bbit_linear
from repro.optim.averaging import average_or_none
from repro.optim.optimizers import make_optimizer
from repro.train.losses import mean_loss_with_preds_fn
from repro.train.steps import build_averaged_train_step, init_averaged_state


@dataclasses.dataclass
class StreamFitResult:
    params: Any                    # final SGD iterate
    avg_params: Optional[Any]      # Polyak tail average (None if unused)
    train_seconds: float
    progressive_acc: float         # one-pass accuracy, VW-style
    n_steps: int
    examples_seen: int
    shards_processed: int          # cumulative, survives resume
    completed: bool                # False when stop_after_shards hit

    @property
    def eval_params(self) -> Any:
        """The parameters to evaluate/serve: the averaged iterate when
        tail averaging ran, else the raw final iterate."""
        return self.avg_params if self.avg_params is not None else self.params


def _shard_order(seed: int, epoch: int, n_shards: int,
                 shuffle: bool) -> np.ndarray:
    if not shuffle:
        return np.arange(n_shards)
    rng = np.random.default_rng(np.random.SeedSequence((seed, epoch)))
    return rng.permutation(n_shards)


def fit_streaming(
    root: str,
    cfg: BBitLinearConfig,
    *,
    loss: str = "logistic",
    optimizer: str = "adamw",
    lr: float = 1e-2,
    l2: float = 1e-6,
    epochs: int = 1,
    batch_size: int = 256,
    seed: int = 0,
    average: bool = True,
    avg_start_frac: float = 0.5,
    shuffle_shards: bool = True,
    mmap: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every_shards: int = 1,
    resume: bool = True,
    stop_after_shards: Optional[int] = None,
) -> StreamFitResult:
    """Streams a format-v1/2/3 hashed archive through minibatch SGD.

    ``avg_start_frac`` opens the Polyak tail-averaging window after
    that fraction of the planned total steps (0.0 = average from the
    first step; ignored when ``average=False``).  ``stop_after_shards``
    (requires ``ckpt_dir``) processes at most that many shards IN THIS
    CALL, checkpoints and returns with ``completed=False`` — the
    deterministic "kill" used by the resume tests and benchmarks; call
    again with the same arguments to continue.  Resume requires the
    same archive and hyperparameters; the checkpoint stores the full
    averaged train state plus stream position and progressive-
    validation counters, so the continued run is bit-identical to an
    uninterrupted one.
    """
    meta = _read_meta(root)
    if meta.get("shards", 0) <= 0 or meta.get("n", 0) <= 0:
        raise ValueError(
            f"cannot stream-train on an empty archive at {root!r} "
            f"(n={meta.get('n')}, shards={meta.get('shards')})")
    k, b = meta["k"], meta["b"]
    if (cfg.k, cfg.b) != (k, b):
        raise ValueError(
            f"config (k={cfg.k}, b={cfg.b}) does not match archive "
            f"(k={k}, b={b})")
    if epochs < 1 or batch_size < 1 or ckpt_every_shards < 1:
        raise ValueError(
            "epochs, batch_size and ckpt_every_shards must be >= 1")
    if cfg.n_classes != 2 and loss != "softmax":
        raise ValueError(
            f"loss={loss!r} is binary-only; multiclass streaming "
            "(n_classes > 2) requires loss='softmax'")
    if cfg.n_classes == 2 and loss == "softmax":
        # a single-logit softmax is identically zero loss — the run
        # would "succeed" with untrained params
        raise ValueError(
            "loss='softmax' needs n_classes > 2; binary configs use a "
            "margin loss ('logistic', 'hinge', 'squared_hinge')")
    if stop_after_shards is not None and not ckpt_dir:
        raise ValueError(
            "stop_after_shards without ckpt_dir would discard the "
            "partial run — a repeat call could only restart from "
            "scratch, never continue")

    counts = shard_row_counts(root)
    n_shards = len(counts)
    steps_per_epoch = sum(-(-c // batch_size) for c in counts if c)
    total_steps = epochs * steps_per_epoch
    avg_start_step = (int(math.floor(avg_start_frac * total_steps))
                      if average else total_steps + 1)

    # oph_zero archives carry a packed per-row empty bitmask; batches
    # then travel as (codes_bytes, mask_bytes) tuples.  v3 answers this
    # from the filesystem, older formats from the recorded scheme —
    # neither touches shard data.
    if meta["format_version"] >= 3:
        has_empty = os.path.exists(
            os.path.join(root, "hashed_00000.empty.npy"))
    else:
        has_empty = meta.get("scheme") == "oph_zero"

    def fwd(params, batch):
        if has_empty:
            pk, em = batch
            codes = unpack_codes_jnp(pk, k, b).astype(jnp.int32)
            return bbit_logits(params, codes, cfg,
                               empty=unpack_mask_jnp(em, k))
        codes = unpack_codes_jnp(batch, k, b).astype(jnp.int32)
        return bbit_logits(params, codes, cfg)

    # shared minibatch loss + matching decision rule (one definition,
    # train/losses.py); the pre-update predictions ride the train
    # step's forward as a has_aux output — progressive validation
    # costs no second forward per batch.
    loss_with_preds = mean_loss_with_preds_fn(fwd, loss, l2=l2)

    def loss_and_hits(params, batch, labels):
        total, pred = loss_with_preds(params, batch, labels)
        return total, jnp.sum(pred == labels)

    opt = make_optimizer(optimizer, lr)
    step_fn = build_averaged_train_step(loss_and_hits, opt, has_aux=True)

    # a structural restore can succeed while the run semantics differ
    # (same model/optimizer shapes, different archive/batching/seed) —
    # fingerprint everything replay depends on and refuse a mismatch.
    fp_src = json.dumps(
        {"archive": {"n": meta["n"], "shards": n_shards, "k": k, "b": b,
                     "scheme": meta.get("scheme"),
                     "seed": meta.get("seed")},
         "cfg": dataclasses.asdict(cfg),
         "loss": loss, "optimizer": optimizer, "lr": lr, "l2": l2,
         "epochs": epochs, "batch_size": batch_size, "seed": seed,
         "average": average, "avg_start_step": avg_start_step,
         "shuffle_shards": shuffle_shards},
        sort_keys=True)
    fingerprint = np.int64(int.from_bytes(
        hashlib.sha256(fp_src.encode()).digest()[:8], "big") >> 1)

    astate = init_averaged_state(
        init_bbit_linear(cfg, jax.random.key(seed)), opt)
    epoch0, pos0, shards_done, hits, seen = 0, 0, 0, 0, 0
    if (ckpt_dir and not resume
            and ckpt.latest_step(ckpt_dir) is not None):
        # a fresh run's low step numbers would be pruned under the old
        # run's higher ones, and a later resume would silently pick up
        # the stale run — refuse rather than interleave two runs
        raise ValueError(
            f"ckpt_dir {ckpt_dir!r} already holds checkpoints (latest "
            f"step {ckpt.latest_step(ckpt_dir)}); with resume=False "
            "point at a fresh directory or delete the old run first")
    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        template = {"astate": astate, "epoch": np.int64(0),
                    "pos": np.int64(0), "shards_done": np.int64(0),
                    "hits": np.int64(0), "seen": np.int64(0),
                    "fingerprint": np.int64(0)}
        try:
            tree, _ = ckpt.restore(ckpt_dir, template)
        except ValueError as e:
            # restarting from scratch here would silently discard the
            # run the caller believes they are continuing
            raise ValueError(
                f"checkpoint under {ckpt_dir!r} is incompatible with "
                "this run's model/optimizer state (resume requires the "
                f"same archive and hyperparameters): {e}") from e
        if int(tree["fingerprint"]) != int(fingerprint):
            raise ValueError(
                f"checkpoint under {ckpt_dir!r} is incompatible: it was "
                "written by a run with different hyperparameters or a "
                "different archive (fingerprint mismatch) — resume "
                "requires identical settings")
        astate = tree["astate"]
        epoch0 = int(tree["epoch"])
        pos0 = int(tree["pos"])
        shards_done = int(tree["shards_done"])
        hits, seen = int(tree["hits"]), int(tree["seen"])

    def save_boundary(next_epoch: int, next_pos: int) -> None:
        tree = {"astate": astate, "epoch": np.int64(next_epoch),
                "pos": np.int64(next_pos),
                "shards_done": np.int64(shards_done),
                "hits": np.int64(hits), "seen": np.int64(seen),
                "fingerprint": fingerprint}
        ckpt.save(ckpt_dir, shards_done, tree)

    global_step = int(astate.state.step)
    processed_here = 0
    stopped = False
    t0 = time.perf_counter()
    for epoch in range(epoch0, epochs):
        order = _shard_order(seed, epoch, n_shards, shuffle_shards)
        for pos in range(pos0 if epoch == epoch0 else 0, n_shards):
            s = int(order[pos])
            shard_hits = []
            # (seed, epoch) + shard id seeds the within-shard
            # permutation — identical on replay, fresh every epoch
            for bp, bl, _rid, bem in iter_hashed_batches(
                    root, batch_size, shard_ids=[s],
                    perm_seed=(seed, epoch), mmap=mmap):
                if (bem is None) == has_empty:
                    raise ValueError(
                        f"shard {s} of {root!r} "
                        f"{'lacks' if bem is None else 'carries'} an "
                        "empty bitmask while shard 0 "
                        f"{'has one' if has_empty else 'does not'} — "
                        "archive written with desynced empty masks?")
                batch = ((jnp.asarray(bp), jnp.asarray(bem))
                         if has_empty else jnp.asarray(bp))
                active = np.float32(global_step >= avg_start_step)
                astate, (_, h) = step_fn(astate, active, batch,
                                         jnp.asarray(bl))
                # device scalars, drained once per shard: no per-step
                # host sync to break async dispatch overlap
                shard_hits.append(h)
                seen += len(bl)
                global_step += 1
            if shard_hits:
                hits += int(np.sum(jax.device_get(shard_hits)))
            shards_done += 1
            processed_here += 1
            next_epoch, next_pos = ((epoch, pos + 1)
                                    if pos + 1 < n_shards
                                    else (epoch + 1, 0))
            at_stop = (stop_after_shards is not None
                       and processed_here >= stop_after_shards)
            done = next_epoch >= epochs
            if ckpt_dir and (shards_done % ckpt_every_shards == 0
                             or at_stop or done):
                save_boundary(next_epoch, next_pos)
            if at_stop and not done:
                stopped = True
                break
        if stopped:
            break
    dt = time.perf_counter() - t0

    assert stopped or global_step > 0, "streaming run performed no steps"
    return StreamFitResult(
        params=astate.state.params,
        avg_params=average_or_none(astate.avg_params, astate.avg_count),
        train_seconds=dt,
        progressive_acc=hits / max(seen, 1),
        n_steps=global_step,
        examples_seen=seen,
        shards_processed=shards_done,
        completed=not stopped,
    )
