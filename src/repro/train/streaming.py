"""One-pass / multi-epoch streaming trainer over hashed shard archives.

The paper's headline scenario trains on 200 GB — data that never fits
in memory.  Preprocessing has streamed since PR 2 (``HashedShardWriter``
writes format-v3 packed shards in O(one shard) memory); PR 3 made the
TRAINING side stream; PR 4 makes it saturate the hardware, closing the
loop arXiv:1205.2958 §5 draws against VW's online mode:

  * ``fit_streaming`` iterates the archive one shard at a time through
    ``data.hashed_dataset.iter_hashed_batches`` (minibatches sliced
    off mmap'd packed bytes — the full (n, k) code matrix is never
    materialized, resident memory is one shard's packed pages + one
    minibatch);
  * **async prefetch** (``prefetch`` ≥ 1, the default): all host-side
    batch work — mmap fault-in, shuffle, slice, jax transfer — runs in
    a bounded producer thread ``prefetch`` steps ahead of the device
    (``data.prefetch``, the producer→queue→device pipeline).  The
    determinism contract: prefetch depth changes WHEN host work
    happens, never WHAT is produced — results are bit-identical to the
    inline path (``prefetch=0``) and checkpoints are interchangeable
    across depths;
  * minibatches cross the host↔device boundary PACKED — ceil(k·b/8)
    bytes per row — and stay packed into the forward:
    ``models.linear.bbit_logits_packed`` unpacks b-bit codes
    in-register on the kernel path (Pallas, TPU) or as a fused in-jit
    temporary elsewhere; ``oph_zero`` archives feed their packed empty
    bitmask to the same fused kernels;
  * **data parallelism** (``data_parallel=N``): the epoch's shard
    order is split into consecutive groups of N, one shard per device
    of a 1-D ``("data",)`` mesh; the averaged step runs under
    ``shard_map`` with a ``psum_mean`` gradient all-reduce and a
    ``psum`` over the progressive-validation hit counters
    (``train.data_parallel``).  Uneven groups are safe: a device
    holding fewer batches (or no shard) contributes zero-weight
    padding batches, keeping every collective full-strength while the
    global row-weighted mean gradient — and hence the Polyak average —
    stays exact.  The checkpoint fingerprint records the LOGICAL
    world size and shard-assignment policy; the physical device count
    is a sanctioned lineage record instead (see elastic resume below);
  * **elastic resume** (``elastic=True``): ``data_parallel=N`` is the
    LOGICAL schedule — N shard slots per group — while the PHYSICAL
    mesh uses whatever devices are alive
    (``ckpt.elastic.mesh_from_available_devices`` /
    ``physical_data_world``), each device folding
    ``N / physical`` slots sequentially
    (``train.data_parallel``'s fold step).  Because the gradient is
    scaled AFTER the all-reduce by exact power-of-two factors, a run
    checkpointed on N devices restores on M ≠ N bit-identically; each
    physical realization is appended to a topology-lineage record in
    the checkpoint's meta.json, and resume adopts the checkpoint's
    logical schedule rather than refusing.  Restored host arrays are
    placed back on the live mesh with ``ckpt.elastic.reshard``;
  * **durability** (PR 7): checkpoints are atomic (tmp + fsync +
    rename), CRC32-checksummed per leaf, and retained as a ring; on
    restore a torn/corrupt checkpoint is logged, quarantined and the
    newest valid one used instead — only when none survives does the
    run restart from scratch (loudly).  Shard reads retry transient
    I/O errors with bounded backoff; a dead prefetch producer
    surfaces as an exception, never a hang.  Armed
    ``repro.ft.faults`` plans can inject crashes / slow steps
    (``on_train_step``) deterministically; ``train.supervisor``
    restarts the run from the latest valid checkpoint under a capped
    backoff policy — an injected-crash supervised run ends with
    params bit-identical to an uninterrupted one
    (tests/test_fault_tolerance.py);
  * the update is plain minibatch SGD/AdamW through the existing
    ``build_train_step`` machinery, wrapped with Polyak *tail*
    averaging (``optim.averaging``) — the averaged iterate is the
    VW-style online baseline;
  * **progressive validation**: every example is scored with the
    current model BEFORE its gradient step, so ``progressive_acc`` is
    the honest one-pass generalization estimate VW reports online;
  * shard order is reshuffled and every shard's rows re-permuted each
    epoch, both as pure functions of ``(seed, epoch, shard)``
    (``data.prefetch.shard_order``) — so a restarted run replays
    identical batches;
  * ``ckpt_dir`` checkpoints the FULL ``AveragedTrainState`` + stream
    position at shard(-group) boundaries through ``ckpt.checkpoint``;
    a killed run resumes at the boundary and reproduces the
    uninterrupted run bit-for-bit (tested, serial and data-parallel).

Typical use::

    stats = preprocess_and_save(root, rows, labels, k=256, b=8,
                                scheme="oph", n_shards=64)
    res = fit_streaming(root, BBitLinearConfig(k=256, b=8),
                        epochs=1, batch_size=1024,
                        ckpt_dir=root + "/ckpt")
    w = res.eval_params            # Polyak average (or raw iterate)
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.ckpt import checkpoint as ckpt
from repro.ckpt import coordinated
from repro.ckpt.elastic import (
    mesh_from_available_devices, physical_data_world, process_fold,
    replicate_spec_tree, reshard,
)
from repro.core.bbit import packed_mask_width, packed_width
from repro.data.hashed_dataset import _read_meta, shard_row_counts
from repro.ft import faults
from repro.data.prefetch import (
    Boundary, StreamBatch, ThreadedPrefetcher, group_batch_stream,
    serial_batch_stream, shard_order,
)
from repro import perf
from repro.models.linear import (
    BBitLinearConfig, bbit_logits_packed, init_bbit_linear,
    logits_packed_impl,
)
from repro.optim.averaging import average_or_none
from repro.optim.optimizers import make_optimizer
from repro.distributed.runtime import (
    SHARD_OWNERSHIP, ProcessRuntime, current_runtime, heartbeat,
    mesh_over_processes, process_slot_range, replicate_across_processes,
)
from repro.train.data_parallel import (
    build_dp_averaged_train_step, device_put_process_local,
    device_put_sharded,
)
from repro.train.losses import mean_loss_with_preds_fn, sum_loss_with_hits_fn
from repro.train.steps import build_averaged_train_step, init_averaged_state


# jitted step functions keyed by their semantic parameters (mode,
# world, model config, mask presence, loss, optimizer, lr, l2) — see
# fit_streaming.  Each entry's jit cache pins its compiled executables,
# so the cache is FIFO-capped: a hyperparameter sweep wider than the
# cap just recompiles (the pre-cache behavior) instead of growing
# process memory without bound.
_STEP_CACHE: dict = {}
_STEP_CACHE_MAX = 8


@dataclasses.dataclass
class StreamFitResult:
    params: Any                    # final SGD iterate
    avg_params: Optional[Any]      # Polyak tail average (None if unused)
    train_seconds: float
    progressive_acc: float         # one-pass accuracy, VW-style
    n_steps: int
    examples_seen: int
    shards_processed: int          # cumulative, survives resume
    completed: bool                # False when stop_after_shards hit
    # every (logical, physical) realization this run has trained
    # under, oldest first — the sanctioned topology-lineage record
    # also stored in each checkpoint's meta.json
    topology_lineage: list = dataclasses.field(default_factory=list)
    # what the cost-model dispatch actually ran (impl per op + profile
    # identity) — recorded in each checkpoint's meta.json extras too,
    # NOT in the replay fingerprint (a profile swap must not invalidate
    # a resume; the numerics are impl-invariant within tolerance and
    # bit-identical on the packed-kernel/unpack pair used here)
    dispatch: Optional[dict] = None

    @property
    def eval_params(self) -> Any:
        """The parameters to evaluate/serve: the averaged iterate when
        tail averaging ran, else the raw final iterate."""
        return self.avg_params if self.avg_params is not None else self.params


def _planned_steps(counts, batch_size: int, *, epochs: int, seed: int,
                   shuffle: bool, world: int) -> int:
    """Total train steps the full run will take.

    Per group of ``world`` shards the devices run in lockstep for the
    LONGEST member, so each group costs max_d ceil(rows_d/B) — and
    because the grouping follows the per-epoch shard shuffle, each
    epoch's count depends on that epoch's order.  ``world=1`` (groups
    of one shard) reduces exactly to the serial Σ_shards ceil(rows/B),
    computed by the shuffle-independent short-cut.
    """
    n_shards = len(counts)
    ceil = [-(-c // batch_size) for c in counts]
    if world == 1:
        return epochs * sum(ceil)
    total = 0
    for epoch in range(epochs):
        order = shard_order(seed, epoch, n_shards, shuffle)
        for lo in range(0, n_shards, world):
            total += max(ceil[int(s)] for s in order[lo: lo + world])
    return total


def fit_streaming(
    root: str,
    cfg: BBitLinearConfig,
    *,
    loss: str = "logistic",
    optimizer: str = "adamw",
    lr: float = 1e-2,
    l2: float = 1e-6,
    epochs: int = 1,
    batch_size: int = 256,
    seed: int = 0,
    average: bool = True,
    avg_start_frac: float = 0.5,
    shuffle_shards: bool = True,
    mmap: bool = True,
    prefetch: int = 2,
    data_parallel: Optional[int] = None,
    elastic: bool = False,
    ckpt_dir: Optional[str] = None,
    ckpt_every_shards: int = 1,
    ckpt_keep_last: int = 3,
    resume: bool = True,
    stop_after_shards: Optional[int] = None,
    watchdog: Optional[Any] = None,
    runtime: Optional[ProcessRuntime] = None,
    grad_compress: Optional[int] = None,
    ckpt_barrier_timeout_s: float = 120.0,
) -> StreamFitResult:
    """Streams a format-v1/2/3 hashed archive through minibatch SGD.

    ``prefetch`` is the async pipeline depth: host-side batch assembly
    and jax transfer run that many steps ahead of the device in a
    background thread (0 = inline/serial; results are bit-identical
    either way).  ``data_parallel=N`` is the LOGICAL world: N disjoint
    shard slots per step with a ``psum_mean`` gradient all-reduce (see
    ``train.data_parallel``).  Without ``elastic`` it must equal the
    physical device count and the checkpoint fingerprint pins it, so a
    resume on a different topology fails loudly; with ``elastic=True``
    the N slots fold onto whatever devices are alive (bit-identically
    — power-of-two counts), a checkpointed run resumes on M ≠ N
    devices by adopting the checkpoint's logical schedule, and each
    physical realization is appended to the checkpoint's
    topology-lineage record (meta.json, ``StreamFitResult
    .topology_lineage``).  ``watchdog`` (a ``ft.watchdog
    .StepWatchdog``) observes per-step dispatch latency and escalates
    persistent stragglers; ``ckpt_keep_last`` sizes the retained
    checkpoint ring (the fallback set when the newest checkpoint is
    torn/corrupt — see ``ckpt.checkpoint``'s durability contract).
    ``avg_start_frac`` opens the Polyak
    tail-averaging window after that fraction of the planned total
    steps (0.0 = average from the first step; ignored when
    ``average=False``).  ``stop_after_shards`` (requires ``ckpt_dir``)
    processes at most that many shards IN THIS CALL (rounded up to a
    whole group under data parallelism), checkpoints and returns with
    ``completed=False`` — the deterministic "kill" used by the resume
    tests and benchmarks; call again with the same arguments to
    continue.  Resume requires the same archive and hyperparameters;
    the checkpoint stores the full averaged train state plus stream
    position and progressive-validation counters, so the continued run
    is bit-identical to an uninterrupted one.

    **Multi-process gangs**: under an initialized
    ``distributed.runtime`` (``runtime`` defaults to
    ``current_runtime()``) the ``data_parallel`` logical slots split
    into one contiguous block per process
    (``runtime.process_slot_range``) — each rank STREAMS only its own
    shards while the step-count/boundary bookkeeping stays global, so
    every rank takes the identical step sequence and the two
    all-reduces simply span the gang's mesh
    (``runtime.mesh_over_processes``).  Checkpoints become coordinated
    (``ckpt.coordinated``): every rank writes its own CRC'd payload
    into a staging directory and rank 0 commits the step with an
    atomic rename once all ``procs`` payloads landed
    (``ckpt_barrier_timeout_s`` bounds the wait).  Elastic resume
    extends across gang sizes: an N-process checkpoint resumes on
    M ≠ N processes (including 1) under ``elastic=True`` by adopting
    the checkpoint's logical schedule — bit-identically for
    power-of-two realizations — with the gang size appended to the
    topology lineage, never refused.

    ``grad_compress`` (8 or 1, data-parallel only) swaps the exact
    fp32 gradient all-reduce for the error-feedback compressed
    exchange (``distributed.grad_compression`` — int8 blockwise-absmax
    or sign+scale on the wire).  It changes the trained numerics (and
    so is part of the run fingerprint); ``None`` (default) leaves the
    exact path bitwise untouched.  The residual memory is NOT
    checkpointed — it resets to zero on resume, so compressed runs
    trade the bitwise-resume guarantee for bandwidth.
    """
    meta = _read_meta(root)
    if meta.get("shards", 0) <= 0 or meta.get("n", 0) <= 0:
        raise ValueError(
            f"cannot stream-train on an empty archive at {root!r} "
            f"(n={meta.get('n')}, shards={meta.get('shards')})")
    k, b = meta["k"], meta["b"]
    if (cfg.k, cfg.b) != (k, b):
        raise ValueError(
            f"config (k={cfg.k}, b={cfg.b}) does not match archive "
            f"(k={k}, b={b})")
    if epochs < 1 or batch_size < 1 or ckpt_every_shards < 1:
        raise ValueError(
            "epochs, batch_size and ckpt_every_shards must be >= 1")
    if prefetch < 0:
        raise ValueError(f"prefetch depth must be >= 0, got {prefetch}")
    if cfg.n_classes != 2 and loss != "softmax":
        raise ValueError(
            f"loss={loss!r} is binary-only; multiclass streaming "
            "(n_classes > 2) requires loss='softmax'")
    if cfg.n_classes == 2 and loss == "softmax":
        # a single-logit softmax is identically zero loss — the run
        # would "succeed" with untrained params
        raise ValueError(
            "loss='softmax' needs n_classes > 2; binary configs use a "
            "margin loss ('logistic', 'hinge', 'squared_hinge')")
    if stop_after_shards is not None and not ckpt_dir:
        raise ValueError(
            "stop_after_shards without ckpt_dir would discard the "
            "partial run — a repeat call could only restart from "
            "scratch, never continue")

    counts = shard_row_counts(root)
    n_shards = len(counts)
    small = [i for i, c in enumerate(counts) if 0 < c < batch_size]
    if small:
        raise ValueError(
            f"batch_size={batch_size} exceeds the {min(counts[i] for i in small)}"
            f" rows of shard(s) {small[:4]}{'…' if len(small) > 4 else ''}"
            f" in {root!r} — lower batch_size or re-shard the archive "
            "with fewer shards")

    # ``data_parallel`` names the LOGICAL schedule; the physical mesh
    # (and the step function) are built only after a possible elastic
    # adoption of a checkpoint's schedule below.
    dp = data_parallel is not None
    logical = int(data_parallel) if dp else 1

    rt = runtime if runtime is not None else (current_runtime()
                                              or ProcessRuntime())
    procs = rt.procs
    if procs > 1:
        if not dp:
            raise ValueError(
                f"a {procs}-process gang requires data_parallel — the "
                "serial schedule has no shard slots to split across "
                "processes")
        # validates logical % procs up front (the stream, mesh and
        # checkpoint protocol all assume even contiguous blocks)
        process_slot_range(logical, procs, rt.rank)
    if grad_compress is not None:
        if not dp:
            raise ValueError(
                "grad_compress applies to the data-parallel gradient "
                "all-reduce — pass data_parallel")
        if grad_compress not in (1, 8):
            raise ValueError(
                f"grad_compress must be 8 (int8 blockwise) or 1 "
                f"(sign+scale), got {grad_compress}")
    compress = (None if grad_compress is None
                else {"bits": int(grad_compress), "block": 256})

    # oph_zero archives carry a packed per-row empty bitmask; batches
    # then travel as (codes_bytes, mask_bytes) tuples.  v3 answers this
    # from the filesystem, older formats from the recorded scheme —
    # neither touches shard data.
    if meta["format_version"] >= 3:
        has_empty = os.path.exists(
            os.path.join(root, "hashed_00000.empty.npy"))
    else:
        has_empty = meta.get("scheme") == "oph_zero"

    # packed bytes straight into the forward — in-register unpack on
    # the kernel path, a fused in-jit temporary elsewhere; the host
    # never widens anything.
    def fwd(params, batch):
        if has_empty:
            pk, em = batch
            return bbit_logits_packed(params, pk, cfg, empty_packed=em)
        return bbit_logits_packed(params, batch, cfg)

    opt = make_optimizer(optimizer, lr)

    astate = init_averaged_state(
        init_bbit_linear(cfg, jax.random.key(seed)), opt)
    epoch0, pos0, shards_done, hits, seen = 0, 0, 0, 0, 0
    if (ckpt_dir and not resume
            and ckpt.latest_step(ckpt_dir) is not None):
        # a fresh run's low step numbers would be pruned under the old
        # run's higher ones, and a later resume would silently pick up
        # the stale run — refuse rather than interleave two runs
        raise ValueError(
            f"ckpt_dir {ckpt_dir!r} already holds checkpoints (latest "
            f"step {ckpt.latest_step(ckpt_dir)}); with resume=False "
            "point at a fresh directory or delete the old run first")
    restored_tree = None
    restored_step = None
    prior_lineage: list = []
    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        template = {"astate": astate, "epoch": np.int64(0),
                    "pos": np.int64(0), "shards_done": np.int64(0),
                    "hits": np.int64(0), "seen": np.int64(0),
                    "fingerprint": np.int64(0)}
        try:
            restored_tree, restored_step = ckpt.restore(ckpt_dir,
                                                        template)
        except FileNotFoundError:
            # every retained checkpoint failed validation: restore
            # quarantined each one (loudly, see ckpt.checkpoint) — the
            # only honest continuation is a fresh start from scratch
            restored_tree = None
        except ValueError as e:
            # restarting from scratch here would silently discard the
            # run the caller believes they are continuing
            raise ValueError(
                f"checkpoint under {ckpt_dir!r} is incompatible with "
                "this run's model/optimizer state (resume requires the "
                f"same archive and hyperparameters): {e}") from e
    if restored_tree is not None:
        smeta = ckpt.load_meta(ckpt_dir, restored_step) or {}
        sched = smeta.get("schedule")
        if sched is not None:
            ck_dp = bool(sched.get("dp"))
            ck_logical = int(sched.get("logical_world", 1))
            ck_procs = int(sched.get("procs", 1))
            if ck_procs != procs and not elastic:
                raise ValueError(
                    f"checkpoint under {ckpt_dir!r} was written by a "
                    f"{ck_procs}-process gang but this run has {procs} "
                    "process(es) — pass elastic=True to resume across "
                    "gang sizes")
            if (ck_dp, ck_logical) != (dp, logical):
                if not elastic:
                    raise ValueError(
                        f"checkpoint under {ckpt_dir!r} is incompatible:"
                        " it was written under "
                        + (f"data_parallel={ck_logical}" if ck_dp
                           else "the serial schedule")
                        + " but this run requested "
                        + (f"data_parallel={logical}" if dp
                           else "the serial schedule")
                        + " — pass elastic=True to adopt the "
                        "checkpoint's logical schedule on the current "
                        "devices")
                dp, logical = ck_dp, ck_logical
        prior_lineage = list(smeta.get("lineage", []))

    total_steps = _planned_steps(
        counts, batch_size, epochs=epochs, seed=seed,
        shuffle=shuffle_shards, world=logical)
    avg_start_step = (int(math.floor(avg_start_frac * total_steps))
                      if average else total_steps + 1)

    # a structural restore can succeed while the run semantics differ
    # (same model/optimizer shapes, different archive/batching/seed/
    # logical schedule) — fingerprint everything replay depends on and
    # refuse a mismatch.  prefetch depth is deliberately EXCLUDED: it
    # never changes the replayed step sequence, so checkpoints are
    # interchangeable across depths; the PHYSICAL device count is too
    # (the fold step makes the update a function of the logical
    # schedule alone) — it lives in the meta.json lineage record, not
    # the fingerprint.
    fingerprint = ckpt.run_fingerprint(
        {"archive": {"n": meta["n"], "shards": n_shards, "k": k, "b": b,
                     "scheme": meta.get("scheme"),
                     "seed": meta.get("seed")},
         "cfg": dataclasses.asdict(cfg),
         "loss": loss, "optimizer": optimizer, "lr": lr, "l2": l2,
         "epochs": epochs, "batch_size": batch_size, "seed": seed,
         "average": average, "avg_start_step": avg_start_step,
         "shuffle_shards": shuffle_shards,
         "world": logical,
         "shard_assignment": ("contiguous_groups" if dp else "serial"),
         # the slot→process mapping RULE is replay-relevant (a
         # different ownership policy would stream different shards per
         # rank); the gang SIZE is not — like the physical device
         # count it rides the lineage record, so checkpoints resume
         # across gang sizes
         "process_topology": {"shard_ownership": SHARD_OWNERSHIP},
         "grad_compress": (int(grad_compress) if grad_compress
                           else None)})

    if restored_tree is not None:
        if int(restored_tree["fingerprint"]) != int(fingerprint):
            raise ValueError(
                f"checkpoint under {ckpt_dir!r} is incompatible: it was "
                "written by a run with different hyperparameters, a "
                "different archive, or a different data-parallel "
                "topology (fingerprint mismatch) — resume requires "
                "identical settings")
        astate = restored_tree["astate"]
        epoch0 = int(restored_tree["epoch"])
        pos0 = int(restored_tree["pos"])
        shards_done = int(restored_tree["shards_done"])
        hits = int(restored_tree["hits"])
        seen = int(restored_tree["seen"])

    d_local = 1
    if dp:
        n_dev = len(jax.devices())
        if procs > 1:
            # three-level fold: logical slots → per-process contiguous
            # blocks → per-device fold within each process
            _, d_local, physical = process_fold(
                logical, procs, rt.local_devices, elastic=elastic)
            mesh = mesh_over_processes(d_local)
        else:
            if not elastic and logical > n_dev:
                raise ValueError(
                    f"data_parallel={logical} needs {logical} devices "
                    f"but only {n_dev} are visible — pass elastic=True "
                    "to fold the logical shard slots onto the "
                    "available devices")
            physical = physical_data_world(logical) if elastic else logical
            mesh = mesh_from_available_devices(model_parallel=1,
                                               max_devices=physical)
        if procs > 1:
            # a gang mesh spans devices this process cannot address:
            # both fresh and restored host state must be assembled
            # into global replicated arrays (plain device_put fails)
            astate = replicate_across_processes(astate, mesh)
        elif restored_tree is not None:
            # place the restored host arrays explicitly onto the live
            # mesh, fully replicated — the elastic-restore re-shard
            astate = reshard(astate, replicate_spec_tree(astate, mesh))
    else:
        physical = 1

    # the sanctioned topology-lineage record: every (logical, physical)
    # realization this run has trained under, appended on change and
    # stored in each checkpoint's meta.json next to the schedule
    lineage = list(prior_lineage)
    realization = {"logical": int(logical), "physical": int(physical),
                   "procs": int(procs),
                   "devices": int(len(jax.devices())),
                   "from_step": int(shards_done)}
    if not lineage or any(lineage[-1].get(key) != realization[key]
                          for key in ("logical", "physical", "procs")):
        lineage.append(realization)

    # the jitted step (and every compiled shape variant behind it) is
    # cached process-wide on the semantic step parameters: a fresh
    # closure per call would give each fit its own jit cache, silently
    # recompiling every step variant on every fit — measured at ~30×
    # the warm step cost on repeated bench/test fits.  The physical
    # world is part of the key: the same logical schedule folds into
    # differently-shaped per-device programs on different meshes.
    # resolve the packed-logits dispatch ONCE, up front: it pins the
    # trace (part of the step-cache key — a profile loaded between two
    # fits must not reuse a step traced for the other impl) and is the
    # run's dispatch-of-record in checkpoints + StreamFitResult
    chosen_impl = logits_packed_impl(cfg, rows=batch_size)
    _perf_rep = perf.dispatch_report()
    dispatch_record = {"logits_packed": chosen_impl,
                       "table_version": _perf_rep["table_version"],
                       "profile_loaded": _perf_rep["profile_loaded"]}

    step_key = ("dp" if dp else "serial", logical, physical, procs,
                cfg, has_empty, loss, optimizer, lr, l2, chosen_impl,
                grad_compress)
    step_fn = _STEP_CACHE.get(step_key)
    if step_fn is None:
        if dp:
            step_fn = build_dp_averaged_train_step(
                sum_loss_with_hits_fn(fwd, loss), opt, mesh, l2=l2,
                logical_world=logical, compress=compress)
        else:
            # shared minibatch loss + matching decision rule (one
            # definition, train/losses.py); the pre-update predictions
            # ride the train step's forward as a has_aux output —
            # progressive validation costs no second forward per batch.
            loss_with_preds = mean_loss_with_preds_fn(fwd, loss, l2=l2)

            def loss_and_hits(params, batch, labels):
                total, pred = loss_with_preds(params, batch, labels)
                return total, jnp.sum(pred == labels)

            step_fn = build_averaged_train_step(loss_and_hits, opt,
                                                has_aux=True)
        while len(_STEP_CACHE) >= _STEP_CACHE_MAX:
            _STEP_CACHE.pop(next(iter(_STEP_CACHE)))
        _STEP_CACHE[step_key] = step_fn

    # error-feedback residual memory for the compressed all-reduce:
    # per-device local state with a leading (physical,) axis sharded
    # over the mesh's data rows.  Deliberately NOT checkpointed — it
    # resets to zero on resume (see the docstring's tradeoff note).
    err0 = None
    if compress is not None:
        if procs > 1:
            err0 = jax.tree.map(
                lambda p: device_put_process_local(
                    np.zeros((d_local,) + tuple(p.shape), np.float32),
                    mesh, physical),
                astate.state.params)
        else:
            err_sh = NamedSharding(mesh, PartitionSpec("data"))
            err0 = jax.tree.map(
                lambda p: jax.device_put(
                    np.zeros((physical,) + tuple(p.shape), np.float32),
                    err_sh),
                astate.state.params)

    def save_boundary(next_epoch: int, next_pos: int) -> None:
        tree = {"astate": astate, "epoch": np.int64(next_epoch),
                "pos": np.int64(next_pos),
                "shards_done": np.int64(shards_done),
                "hits": np.int64(hits), "seen": np.int64(seen),
                "fingerprint": fingerprint}
        extra = {"schedule": {"dp": dp,
                              "logical_world": int(logical),
                              "procs": int(procs)},
                 "lineage": lineage,
                 "dispatch": dispatch_record}
        if procs > 1:
            # every rank writes its own CRC'd payload; rank 0 commits
            # the step once all payloads landed (ckpt.coordinated)
            coordinated.save_coordinated(
                ckpt_dir, shards_done, tree, rank=rt.rank, procs=procs,
                keep_last=ckpt_keep_last,
                barrier_timeout_s=ckpt_barrier_timeout_s,
                extra_meta=extra)
            if not rt.is_leader:
                return
        else:
            ckpt.save(ckpt_dir, shards_done, tree,
                      keep_last=ckpt_keep_last, extra_meta=extra)
        # also publish the current EVAL iterate (Polyak average once
        # the tail window opened, else the raw iterate) as a params-
        # only snapshot under <ckpt_dir>/serve — what a live server's
        # /reload (serving.reload) swaps in without a restart; rank 0
        # only in a gang (one server, one snapshot)
        serve_now = (astate.avg_params
                     if float(astate.avg_count) > 0
                     else astate.state.params)
        ckpt.publish_params(ckpt_dir, shards_done, serve_now)

    # ---- event stream: serial or grouped, inline or prefetched ------
    if dp:
        if procs > 1:
            # each rank streams ONLY its contiguous slot block; the
            # global stacked batch is assembled from every process's
            # local rows (mesh rows are process-contiguous by
            # construction, so local slots == local mesh rows)
            slot_range = process_slot_range(logical, procs, rt.rank)
            put = lambda x: device_put_process_local(  # noqa: E731
                x, mesh, logical)
        else:
            slot_range = None
            put = lambda x: device_put_sharded(x, mesh)  # noqa: E731

        def transfer(codes, empty, labels, valid):
            batch = ((put(codes), put(empty)) if has_empty
                     else put(codes))
            return (batch, put(labels), put(valid))

        stream = group_batch_stream(
            root, batch_size, seed=seed, epochs=epochs,
            n_shards=n_shards, counts=counts, world=logical,
            shuffle=shuffle_shards, start_epoch=epoch0, start_pos=pos0,
            has_empty=has_empty, packed_width=packed_width(k, b),
            mask_width=packed_mask_width(k), transfer=transfer,
            mmap=mmap, slot_range=slot_range)
    else:
        def transfer(bp, bem, bl):
            batch = ((jnp.asarray(bp), jnp.asarray(bem)) if has_empty
                     else jnp.asarray(bp))
            return (batch, jnp.asarray(bl))

        stream = serial_batch_stream(
            root, batch_size, seed=seed, epochs=epochs,
            n_shards=n_shards, shuffle=shuffle_shards,
            start_epoch=epoch0, start_pos=pos0, has_empty=has_empty,
            transfer=transfer, mmap=mmap)

    events = ThreadedPrefetcher(stream, prefetch) if prefetch else stream

    global_step = int(astate.state.step)
    processed_here = 0
    stopped = False
    pending_hits = []
    t0 = time.perf_counter()
    try:
        for ev in events:
            if isinstance(ev, StreamBatch):
                active = np.float32(global_step >= avg_start_step)
                if watchdog is not None:
                    watchdog.start_step()
                # inside the watchdog window: an injected slow step is
                # observed as step latency, an injected crash dies
                # mid-step — both as a real fault would
                if faults._ACTIVE is not None:
                    faults.on_train_step(global_step)
                if compress is not None:
                    (astate, err0), (_, h) = step_fn(
                        (astate, err0), active, *ev.args)
                else:
                    astate, (_, h) = step_fn(astate, active, *ev.args)
                if watchdog is not None:
                    # dispatch is async: this observes host-side step
                    # latency (enqueue + any producer stall), which is
                    # exactly where injected slow steps and starving
                    # input pipelines show up
                    watchdog.end_step(global_step)
                # device scalars, drained once per shard: no per-step
                # host sync to break async dispatch overlap
                pending_hits.append(h)
                seen += ev.n_rows
                global_step += 1
                continue
            assert isinstance(ev, Boundary)
            if pending_hits:
                hits += int(np.sum(jax.device_get(pending_hits)))
                pending_hits = []
            prev_done = shards_done
            shards_done += ev.shards_consumed
            processed_here += ev.shards_consumed
            if rt.is_multiprocess:
                heartbeat(rt, step=global_step,
                          shards_done=shards_done)
            at_stop = (stop_after_shards is not None
                       and processed_here >= stop_after_shards)
            done = ev.next_epoch >= epochs
            crossed = (shards_done // ckpt_every_shards
                       > prev_done // ckpt_every_shards)
            if ckpt_dir and (crossed or at_stop or done):
                save_boundary(ev.next_epoch, ev.next_pos)
            if at_stop and not done:
                stopped = True
                break
    finally:
        # ThreadedPrefetcher.close() joins the producer; a plain
        # generator's close() runs its cleanup NOW (dropping the open
        # mmap'd shard iterators) instead of waiting on GC
        events.close()
    dt = time.perf_counter() - t0

    assert stopped or global_step > 0, "streaming run performed no steps"
    return StreamFitResult(
        params=astate.state.params,
        avg_params=average_or_none(astate.avg_params, astate.avg_count),
        train_seconds=dt,
        progressive_acc=hits / max(seen, 1),
        n_steps=global_step,
        examples_seen=seen,
        shards_processed=shards_done,
        completed=not stopped,
        topology_lineage=lineage,
        dispatch=dispatch_record,
    )
