"""Evaluation metrics."""
from __future__ import annotations

from typing import Any

import numpy as np


def accuracy(pred: np.ndarray, labels: np.ndarray) -> float:
    return float(np.mean(np.asarray(pred) == np.asarray(labels)))


def trees_bitwise_equal(a: Any, b: Any) -> bool:
    """True iff two pytrees hold element-wise identical leaves — THE
    check behind every determinism contract in this repo (prefetch
    depth, kill/resume, run-to-run), defined once so the tests, the
    benchmark canaries and the examples can never drift apart."""
    import jax

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def batched_accuracy(predict_fn, inputs: np.ndarray, labels: np.ndarray,
                     batch: int = 4096) -> float:
    hits = 0
    for lo in range(0, inputs.shape[0], batch):
        p = np.asarray(predict_fn(inputs[lo: lo + batch]))
        hits += int((p == labels[lo: lo + batch]).sum())
    return hits / inputs.shape[0]
