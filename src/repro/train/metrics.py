"""Evaluation metrics."""
from __future__ import annotations

import numpy as np


def accuracy(pred: np.ndarray, labels: np.ndarray) -> float:
    return float(np.mean(np.asarray(pred) == np.asarray(labels)))


def batched_accuracy(predict_fn, inputs: np.ndarray, labels: np.ndarray,
                     batch: int = 4096) -> float:
    hits = 0
    for lo in range(0, inputs.shape[0], batch):
        p = np.asarray(predict_fn(inputs[lo: lo + batch]))
        hits += int((p == labels[lo: lo + batch]).sum())
    return hits / inputs.shape[0]
