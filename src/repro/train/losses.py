"""Objectives matching the paper's Eq. (8) (L2-SVM) and Eq. (9) (LR).

LIBLINEAR convention: f(w) = 0.5·wᵀw + C·Σᵢ ℓ(yᵢ, wᵀxᵢ) — a *sum* over
examples scaled by C, not a mean.  ``liblinear_objective`` reproduces it
exactly for TRON; the SGD path uses the equivalent mean-loss +
weight-decay parameterization.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def logistic(margins: jax.Array) -> jax.Array:
    """log(1 + e^{-m}), stable (paper Eq. 9)."""
    return jnp.logaddexp(0.0, -margins)


def hinge(margins: jax.Array) -> jax.Array:
    """max(1 - m, 0) — L1-loss SVM (paper Eq. 8)."""
    return jnp.maximum(1.0 - margins, 0.0)


def squared_hinge(margins: jax.Array) -> jax.Array:
    """max(1 - m, 0)^2 — L2-loss SVM (differentiable; LIBLINEAR -s 2)."""
    return jnp.maximum(1.0 - margins, 0.0) ** 2


LOSSES = {"logistic": logistic, "hinge": hinge,
          "squared_hinge": squared_hinge}


def _logistic_d2(m):
    s = jax.nn.sigmoid(m)
    return s * (1.0 - s)


def _squared_hinge_d2(m):
    # generalized Hessian (LIBLINEAR -s 2): 2·1{m < 1}
    return 2.0 * (m < 1.0).astype(jnp.float32)


#: second derivative of the loss wrt the margin — used by the analytic
#: TRON Hessian-vector product (Hv = v + C·Xᵀ(ℓ″(m)⊙Xv)).
LOSS_D2 = {"logistic": _logistic_d2, "squared_hinge": _squared_hinge_d2}


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-example CE for the multiclass path; labels int (n,)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold


def binary_margins(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """y·wᵀx with y ∈ {−1,+1} from {0,1} labels; logits (n,) or (n,1)."""
    if logits.ndim == 2:
        logits = logits[:, 0]
    y = 2.0 * labels.astype(jnp.float32) - 1.0
    return y * logits


def liblinear_objective(
    forward: Callable,
    loss_name: str,
    C: float,
):
    """Builds f(params) = 0.5‖w‖² + C·Σ ℓ — the exact paper objective.

    ``forward(params, codes) -> logits``; binary labels in {0,1}.
    """
    loss_fn = LOSSES[loss_name]

    def objective(params, codes, labels):
        logits = forward(params, codes)
        m = binary_margins(logits, labels)
        reg = 0.5 * sum(
            jnp.sum(p.astype(jnp.float32) ** 2)
            for p in jax.tree.leaves(params))
        return reg + C * jnp.sum(loss_fn(m))

    return objective


def _per_example(logits: jax.Array, labels: jax.Array, loss_name: str):
    """Per-example losses + the decision rule matching ``loss_name``
    (argmax for ``"softmax"``, sign of the margin logit otherwise) —
    the single definition both the serial and data-parallel minibatch
    losses wrap, so their progressive validation can never diverge."""
    if loss_name == "softmax":
        per = softmax_xent(logits, labels)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        per = LOSSES[loss_name](binary_margins(logits, labels))
        z = logits[:, 0] if logits.ndim == 2 else logits
        pred = (z > 0).astype(jnp.int32)
    return per, pred


def mean_loss_with_preds_fn(forward: Callable, loss_name: str,
                            l2: float = 0.0):
    """Mean-per-example loss + predicted classes from the SAME forward.

    The ``has_aux`` twin of ``mean_loss_fn``: returns ``(loss, pred)``
    where ``pred`` is the decision rule matching the loss — what the
    streaming trainer's progressive validation counts without paying a
    second forward pass.  This is the single definition of the
    minibatch loss parameterization; ``mean_loss_fn`` wraps it.
    """
    def f(params, codes, labels):
        per, pred = _per_example(forward(params, codes), labels,
                                 loss_name)
        loss = jnp.mean(per)
        if l2:
            loss = loss + 0.5 * l2 * sum(
                jnp.sum(p.astype(jnp.float32) ** 2)
                for p in jax.tree.leaves(params))
        return loss, pred
    return f


def sum_loss_with_hits_fn(forward: Callable, loss_name: str):
    """Masked per-example SUM loss + correct-prediction count.

    The data-parallel twin of ``mean_loss_with_preds_fn``: returns
    ``(loss_sum, hits)`` over the rows where ``valid`` is set, so
    ragged/padded device batches contribute exactly their real rows.
    The global mean (and the L2 term, which must not be summed once per
    device) is applied by ``train.data_parallel`` AFTER the cross-device
    ``psum`` — dividing here would bake in a per-device count that the
    all-reduce cannot undo when devices hold different row counts.
    """
    def f(params, codes, labels, valid):
        per, pred = _per_example(forward(params, codes), labels,
                                 loss_name)
        vm = valid.astype(per.dtype)
        loss_sum = jnp.sum(per * vm)
        hits = jnp.sum(jnp.where(valid, (pred == labels).astype(jnp.int32),
                                 0))
        return loss_sum, hits
    return f


def mean_loss_fn(forward: Callable, loss_name: str, l2: float = 0.0):
    """Mean-per-example loss (SGD/minibatch path), optional L2."""
    inner = mean_loss_with_preds_fn(forward, loss_name, l2)

    def f(params, codes, labels):
        return inner(params, codes, labels)[0]
    return f
