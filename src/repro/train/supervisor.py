"""Supervised restart loop: run ``fit_streaming`` until it finishes.

The single-host half of the ROADMAP's fault-tolerant training story:
a crash (injected or real) kills the fit mid-shard; the supervisor
waits out a capped exponential backoff (deterministic jitter,
``repro.ft.retry.BackoffPolicy``) and calls ``fit_streaming`` again
with ``resume=True`` — the trainer restores from the newest VALID
checkpoint (torn/corrupt ones are quarantined, see ``ckpt.checkpoint``)
and replays the stream from that boundary.  Because batch replay is a
pure function of ``(seed, epoch, position)``, the supervised run's
final parameters are bit-identical to an uninterrupted run — the
crash-equivalence property (tests/test_fault_tolerance.py) that makes
"the run survives production reality" a testable claim rather than a
hope.

What counts as a crash: any exception EXCEPT

  * ``ValueError`` — a configuration/compatibility error
    (archive/config mismatch, incompatible checkpoint): retrying can
    only fail identically, so it propagates immediately;
  * ``KeyboardInterrupt`` / ``SystemExit`` — the operator, not a
    fault.

A shared ``StepWatchdog`` (``repro.ft.watchdog``) rides along across
restarts, so straggler escalations accumulate over the whole supervised
run; its counters are surfaced on the returned ``SupervisedRun``.

``run_multiprocess_supervised`` is the multi-HOST half: it launches a
``procs``-wide gang of ``repro.train.worker`` subprocesses (each a real
OS process joined through ``jax.distributed``), watches them, and
**gang-restarts** on any worker death — a single rank cannot rejoin a
live gloo gang, so the whole gang is SIGKILLed and respawned from the
latest valid *coordinated* checkpoint (``ckpt.coordinated``).  Restart
spawns are staggered per rank through ``BackoffPolicy.for_rank`` so a
gang restart does not reproduce the thundering herd the jitter exists
to break.  Exit code 64 from any worker is the config-error protocol
(``train.worker``): deterministic, raised as ``ValueError``, never
retried.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import signal
import socket
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional

from repro.ft.retry import BackoffPolicy
from repro.ft.watchdog import StepWatchdog
from repro.train.streaming import StreamFitResult, fit_streaming
from repro.train.worker import CONFIG_ERROR_EXIT

__all__ = ["RestartPolicy", "CrashRecord", "SupervisedRun",
           "run_supervised", "MultiProcessRun",
           "run_multiprocess_supervised"]

log = logging.getLogger("repro.train.supervisor")


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How hard to try: at most ``max_restarts`` restarts, waiting out
    ``backoff.delay_s(attempt)`` before each one."""
    max_restarts: int = 3
    backoff: BackoffPolicy = BackoffPolicy(base_s=0.05, factor=2.0,
                                           cap_s=5.0, jitter_frac=0.1)


@dataclasses.dataclass
class CrashRecord:
    """One supervised crash: which restart followed it, what died, and
    how long the recovery (backoff + restore + replay to the crash
    point) took."""
    restart: int
    error: str
    backoff_s: float
    recover_s: float = 0.0


@dataclasses.dataclass
class SupervisedRun:
    result: StreamFitResult
    restarts: int
    crashes: List[CrashRecord]
    watchdog: StepWatchdog

    @property
    def straggler_escalations(self) -> int:
        return len(self.watchdog.escalations)


def run_supervised(
    root: str,
    cfg: Any,
    *,
    policy: Optional[RestartPolicy] = None,
    watchdog: Optional[StepWatchdog] = None,
    **fit_kwargs,
) -> SupervisedRun:
    """Runs ``fit_streaming(root, cfg, **fit_kwargs)`` under restart
    supervision; returns the finished result plus crash accounting.

    ``ckpt_dir`` is required — without checkpoints every restart would
    silently start over, which is exactly the failure mode this loop
    exists to prevent.  ``resume`` is forced True on every attempt
    (including the first: picking up a previous supervised run's
    checkpoints is the intended behavior).
    """
    if not fit_kwargs.get("ckpt_dir"):
        raise ValueError(
            "run_supervised requires ckpt_dir: without checkpoints a "
            "restart cannot resume and would retrain from scratch")
    if fit_kwargs.get("resume") is False:
        raise ValueError(
            "run_supervised forces resume=True — a supervised restart "
            "that refuses its own checkpoints cannot recover")
    fit_kwargs["resume"] = True
    policy = RestartPolicy() if policy is None else policy
    watchdog = StepWatchdog() if watchdog is None else watchdog
    crashes: List[CrashRecord] = []
    attempt = 0
    while True:
        t_try = time.perf_counter()
        try:
            result = fit_streaming(root, cfg, watchdog=watchdog,
                                   **fit_kwargs)
        except (KeyboardInterrupt, SystemExit):
            raise
        except ValueError:
            # config/compatibility error — deterministic, not a crash
            raise
        except Exception as e:  # noqa: BLE001 — the supervised surface
            if crashes:
                crashes[-1].recover_s += time.perf_counter() - t_try
            if attempt >= policy.max_restarts:
                log.error(
                    "giving up after %d restarts (%d crashes); last "
                    "error: %r", attempt, len(crashes) + 1, e)
                raise
            delay = policy.backoff.delay_s(attempt)
            log.warning(
                "training attempt %d crashed (%r) — restarting from "
                "the latest valid checkpoint in %.3fs "
                "(restart %d/%d)", attempt + 1, e, delay, attempt + 1,
                policy.max_restarts)
            crashes.append(CrashRecord(restart=attempt + 1,
                                       error=repr(e), backoff_s=delay))
            time.sleep(delay)
            attempt += 1
            continue
        if crashes:
            crashes[-1].recover_s += time.perf_counter() - t_try
        return SupervisedRun(result=result, restarts=attempt,
                             crashes=crashes, watchdog=watchdog)


# ------------------------------------------------ multi-process gang ----

@dataclasses.dataclass
class MultiProcessRun:
    """A finished gang run: per-rank result records (rank → the dict
    ``train.worker`` dumped), restart/crash accounting, and where each
    rank left its final params (``params_paths[rank]``)."""
    results: Dict[int, dict]
    params_paths: Dict[int, str]
    restarts: int
    crashes: List[CrashRecord]
    run_dir: str

    @property
    def result(self) -> dict:
        return self.results[0]


def _free_port() -> int:
    s = socket.socket()
    try:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]
    finally:
        s.close()


def _src_root() -> str:
    # <src>/repro/train/supervisor.py → <src>, so spawned workers
    # import the same tree regardless of the caller's cwd
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _kill_gang(children) -> None:
    for p in children:
        if p.poll() is None:
            try:
                p.send_signal(signal.SIGKILL)
            except OSError:
                pass
    for p in children:
        try:
            p.wait(timeout=30)
        except subprocess.TimeoutExpired:
            pass


def _tail(path: str, n: int = 12) -> str:
    try:
        with open(path, errors="replace") as f:
            return "".join(f.readlines()[-n:])
    except OSError:
        return "<no log>"


def run_multiprocess_supervised(
    root: str,
    cfg: Any,
    *,
    procs: int,
    run_dir: str,
    policy: Optional[RestartPolicy] = None,
    fault_spec: Optional[dict] = None,
    local_devices: int = 1,
    attempt_timeout_s: float = 600.0,
    **fit_kwargs,
) -> MultiProcessRun:
    """Runs ``fit_streaming(root, cfg, **fit_kwargs)`` as a
    ``procs``-process ``jax.distributed`` gang under gang-restart
    supervision.

    Each attempt binds a fresh coordinator port, writes one JSON spec
    per rank under ``run_dir`` and execs ``python -m
    repro.train.worker`` per rank (``local_devices`` fake CPU devices
    each, via ``XLA_FLAGS``).  The first non-zero worker exit kills
    the WHOLE gang (a dead rank cannot rejoin live collectives) and —
    within ``policy.max_restarts`` — respawns it; every worker resumes
    from the latest valid coordinated checkpoint, so the finished
    gang's params are bit-identical to an uninterrupted run's.
    ``fault_spec`` (``FaultPlan.to_spec``) ships a rank-targeted fault
    plan to every worker; fired counts persist per rank under
    ``run_dir`` so ``times=1`` kills do not re-fire after a respawn.
    """
    if procs < 1:
        raise ValueError(f"procs must be >= 1, got {procs}")
    if not fit_kwargs.get("ckpt_dir"):
        raise ValueError(
            "run_multiprocess_supervised requires ckpt_dir: a gang "
            "restart without checkpoints would retrain from scratch")
    if fit_kwargs.get("resume") is False:
        raise ValueError(
            "run_multiprocess_supervised forces resume=True — a gang "
            "restart that refuses its own checkpoints cannot recover")
    fit_kwargs["resume"] = True
    policy = RestartPolicy() if policy is None else policy
    os.makedirs(run_dir, exist_ok=True)

    import dataclasses as _dc
    cfg_dict = _dc.asdict(cfg)

    env_base = dict(os.environ)
    env_base["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(local_devices)}")
    env_base["JAX_PLATFORMS"] = "cpu"
    env_base["PYTHONPATH"] = (
        _src_root() + os.pathsep + env_base.get("PYTHONPATH", ""))

    crashes: List[CrashRecord] = []
    attempt = 0
    while True:
        coordinator = f"127.0.0.1:{_free_port()}"
        children, logs = [], []
        for r in range(procs):
            spec = {"root": root, "cfg": cfg_dict, "fit": fit_kwargs,
                    "procs": procs, "rank": r,
                    "coordinator": coordinator, "run_dir": run_dir,
                    "fault_spec": fault_spec,
                    "fault_state": os.path.join(
                        run_dir, f"fault_state_rank{r}.json"),
                    "result_path": os.path.join(
                        run_dir, f"result_rank{r}.json"),
                    "params_path": os.path.join(
                        run_dir, f"params_rank{r}.npz")}
            spec_path = os.path.join(run_dir, f"spec_rank{r}.json")
            with open(spec_path, "w") as f:
                json.dump(spec, f)
            if attempt > 0:
                # per-rank de-correlated stagger: a gang restart must
                # not relaunch every rank at the same instant
                time.sleep(policy.backoff.for_rank(r)
                           .delay_s(attempt - 1))
            log_path = os.path.join(run_dir,
                                    f"log_rank{r}_try{attempt}.txt")
            logs.append(log_path)
            lf = open(log_path, "w")
            # exec the worker FILE, not ``-m repro.train.worker``: -m
            # would import repro.train.__init__ (and with it the whole
            # jax training stack) before the worker can call
            # jax.distributed.initialize, which must precede any jax
            # computation
            worker_path = os.path.join(
                os.path.dirname(os.path.abspath(__file__)), "worker.py")
            children.append(subprocess.Popen(
                [sys.executable, worker_path, "--spec", spec_path],
                env=env_base, stdout=lf, stderr=subprocess.STDOUT,
                close_fds=True))
            lf.close()

        t_try = time.perf_counter()
        failure: Optional[str] = None
        while True:
            codes = [p.poll() for p in children]
            bad = [(r, c) for r, c in enumerate(codes)
                   if c not in (None, 0)]
            if bad:
                r, c = bad[0]
                if c == CONFIG_ERROR_EXIT:
                    _kill_gang(children)
                    raise ValueError(
                        f"gang rank {r} reported a configuration "
                        f"error (exit {c}):\n{_tail(logs[r])}")
                failure = (f"rank {r} died with "
                           + (f"signal {-c}" if c < 0 else f"exit {c}"))
                break
            if all(c == 0 for c in codes):
                break
            if time.perf_counter() - t_try > attempt_timeout_s:
                failure = (f"gang attempt timed out after "
                           f"{attempt_timeout_s:.0f}s")
                break
            time.sleep(0.02)
        if failure is None and all(p.poll() == 0 for p in children):
            if crashes:
                crashes[-1].recover_s += time.perf_counter() - t_try
            results, params = {}, {}
            for r in range(procs):
                with open(os.path.join(run_dir,
                                       f"result_rank{r}.json")) as f:
                    results[r] = json.load(f)
                params[r] = os.path.join(run_dir,
                                         f"params_rank{r}.npz")
            return MultiProcessRun(results=results, params_paths=params,
                                   restarts=attempt, crashes=crashes,
                                   run_dir=run_dir)
        _kill_gang(children)
        if crashes:
            crashes[-1].recover_s += time.perf_counter() - t_try
        if attempt >= policy.max_restarts:
            raise RuntimeError(
                f"gang gave up after {attempt} restarts: {failure}\n"
                + _tail(logs[0]))
        delay = policy.backoff.delay_s(attempt)
        log.warning("gang attempt %d failed (%s) — restarting in "
                    "%.3fs (restart %d/%d)", attempt + 1, failure,
                    delay, attempt + 1, policy.max_restarts)
        crashes.append(CrashRecord(restart=attempt + 1, error=failure,
                                   backoff_s=delay))
        time.sleep(delay)
        attempt += 1
