"""Supervised restart loop: run ``fit_streaming`` until it finishes.

The single-host half of the ROADMAP's fault-tolerant training story:
a crash (injected or real) kills the fit mid-shard; the supervisor
waits out a capped exponential backoff (deterministic jitter,
``repro.ft.retry.BackoffPolicy``) and calls ``fit_streaming`` again
with ``resume=True`` — the trainer restores from the newest VALID
checkpoint (torn/corrupt ones are quarantined, see ``ckpt.checkpoint``)
and replays the stream from that boundary.  Because batch replay is a
pure function of ``(seed, epoch, position)``, the supervised run's
final parameters are bit-identical to an uninterrupted run — the
crash-equivalence property (tests/test_fault_tolerance.py) that makes
"the run survives production reality" a testable claim rather than a
hope.

What counts as a crash: any exception EXCEPT

  * ``ValueError`` — a configuration/compatibility error
    (archive/config mismatch, incompatible checkpoint): retrying can
    only fail identically, so it propagates immediately;
  * ``KeyboardInterrupt`` / ``SystemExit`` — the operator, not a
    fault.

A shared ``StepWatchdog`` (``repro.ft.watchdog``) rides along across
restarts, so straggler escalations accumulate over the whole supervised
run; its counters are surfaced on the returned ``SupervisedRun``.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, List, Optional

from repro.ft.retry import BackoffPolicy
from repro.ft.watchdog import StepWatchdog
from repro.train.streaming import StreamFitResult, fit_streaming

__all__ = ["RestartPolicy", "CrashRecord", "SupervisedRun",
           "run_supervised"]

log = logging.getLogger("repro.train.supervisor")


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How hard to try: at most ``max_restarts`` restarts, waiting out
    ``backoff.delay_s(attempt)`` before each one."""
    max_restarts: int = 3
    backoff: BackoffPolicy = BackoffPolicy(base_s=0.05, factor=2.0,
                                           cap_s=5.0, jitter_frac=0.1)


@dataclasses.dataclass
class CrashRecord:
    """One supervised crash: which restart followed it, what died, and
    how long the recovery (backoff + restore + replay to the crash
    point) took."""
    restart: int
    error: str
    backoff_s: float
    recover_s: float = 0.0


@dataclasses.dataclass
class SupervisedRun:
    result: StreamFitResult
    restarts: int
    crashes: List[CrashRecord]
    watchdog: StepWatchdog

    @property
    def straggler_escalations(self) -> int:
        return len(self.watchdog.escalations)


def run_supervised(
    root: str,
    cfg: Any,
    *,
    policy: Optional[RestartPolicy] = None,
    watchdog: Optional[StepWatchdog] = None,
    **fit_kwargs,
) -> SupervisedRun:
    """Runs ``fit_streaming(root, cfg, **fit_kwargs)`` under restart
    supervision; returns the finished result plus crash accounting.

    ``ckpt_dir`` is required — without checkpoints every restart would
    silently start over, which is exactly the failure mode this loop
    exists to prevent.  ``resume`` is forced True on every attempt
    (including the first: picking up a previous supervised run's
    checkpoints is the intended behavior).
    """
    if not fit_kwargs.get("ckpt_dir"):
        raise ValueError(
            "run_supervised requires ckpt_dir: without checkpoints a "
            "restart cannot resume and would retrain from scratch")
    if fit_kwargs.get("resume") is False:
        raise ValueError(
            "run_supervised forces resume=True — a supervised restart "
            "that refuses its own checkpoints cannot recover")
    fit_kwargs["resume"] = True
    policy = RestartPolicy() if policy is None else policy
    watchdog = StepWatchdog() if watchdog is None else watchdog
    crashes: List[CrashRecord] = []
    attempt = 0
    while True:
        t_try = time.perf_counter()
        try:
            result = fit_streaming(root, cfg, watchdog=watchdog,
                                   **fit_kwargs)
        except (KeyboardInterrupt, SystemExit):
            raise
        except ValueError:
            # config/compatibility error — deterministic, not a crash
            raise
        except Exception as e:  # noqa: BLE001 — the supervised surface
            if crashes:
                crashes[-1].recover_s += time.perf_counter() - t_try
            if attempt >= policy.max_restarts:
                log.error(
                    "giving up after %d restarts (%d crashes); last "
                    "error: %r", attempt, len(crashes) + 1, e)
                raise
            delay = policy.backoff.delay_s(attempt)
            log.warning(
                "training attempt %d crashed (%r) — restarting from "
                "the latest valid checkpoint in %.3fs "
                "(restart %d/%d)", attempt + 1, e, delay, attempt + 1,
                policy.max_restarts)
            crashes.append(CrashRecord(restart=attempt + 1,
                                       error=repr(e), backoff_s=delay))
            time.sleep(delay)
            attempt += 1
            continue
        if crashes:
            crashes[-1].recover_s += time.perf_counter() - t_try
        return SupervisedRun(result=result, restarts=attempt,
                             crashes=crashes, watchdog=watchdog)
