"""Elastic re-sharding: move a restored host-numpy pytree onto any mesh.

A job checkpointed on one topology (e.g. 512 chips) restores on another
(e.g. 256 after losing a pod): checkpoints are topology-free host
arrays, and ``reshard`` places them under the *new* mesh's shardings.
``train.streaming.fit_streaming(elastic=True)`` wires this together
with ``mesh_from_available_devices`` + ``physical_data_world`` so a
restarted job simply uses whatever devices exist: the LOGICAL
data-parallel world (the shard-group schedule, pinned by the run
fingerprint) stays fixed while the PHYSICAL realization folds
``logical // physical`` shard slots onto each live device — the
elastic-scaling story for node failures.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def physical_data_world(logical: int,
                        n_devices: Optional[int] = None) -> int:
    """The data-mesh size a ``data_parallel=logical`` run uses on this
    host: the largest divisor of ``logical`` that fits the visible
    device count, so every device carries the same whole number of
    shard slots (``fold = logical // physical``)."""
    if logical < 1:
        raise ValueError(f"logical world must be >= 1, got {logical}")
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    p = min(logical, max(n, 1))
    while logical % p:
        p -= 1
    return p


def process_fold(logical: int, procs: int, local_devices: int, *,
                 elastic: bool = True) -> tuple:
    """The three-level elastic fold for a multi-process gang: logical
    shard slots → per-process slot blocks → per-device fold.

    Returns ``(local_slots, d_local, physical)`` where ``local_slots =
    logical // procs`` is each process's contiguous slot block,
    ``d_local`` the data-mesh devices each process contributes (the
    largest divisor of its slot count that fits its local devices —
    same rule as ``physical_data_world``, applied per process), and
    ``physical = procs · d_local`` the global data-mesh size.  Every
    process must see the same ``local_devices`` (the mesh needs a
    uniform per-process block); with ``elastic=False`` the slots must
    map 1:1 onto local devices.  Because the per-device update scales
    the gradient sum AFTER the all-reduce (``train.data_parallel``),
    any power-of-two realization of the same logical schedule —
    including across different gang sizes — is bit-identical.
    """
    if procs < 1:
        raise ValueError(f"procs must be >= 1, got {procs}")
    if logical % procs:
        raise ValueError(
            f"data_parallel={logical} logical shard slots cannot split "
            f"evenly over {procs} processes")
    local_slots = logical // procs
    if elastic:
        d_local = physical_data_world(local_slots, local_devices)
    else:
        if local_slots > local_devices:
            raise ValueError(
                f"{local_slots} shard slots per process need "
                f"{local_slots} local devices but only {local_devices} "
                "are visible — pass elastic=True to fold")
        d_local = local_slots
    return local_slots, d_local, procs * d_local


def reshard(tree: Any, shardings: Any) -> Any:
    """device_put every leaf under the matching sharding (or replicate).

    ``shardings`` is a matching pytree of NamedSharding (or a single
    sharding applied to all leaves).
    """
    if isinstance(shardings, (NamedSharding,)) or shardings is None:
        return jax.device_put(tree, shardings)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda x: x is None)


def mesh_from_available_devices(
    model_parallel: int = 1,
    max_devices: Optional[int] = None,
) -> Mesh:
    """Builds a (data, model) mesh from whatever devices are alive.

    data size = n_devices // model_parallel (elastic along data).
    """
    devs = jax.devices()
    if max_devices is not None:
        devs = devs[:max_devices]
    n = len(devs)
    if n % model_parallel:
        raise ValueError(
            f"{n} devices not divisible by model_parallel={model_parallel}")
    import numpy as np
    arr = np.asarray(devs).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, ("data", "model"))


def replicate_spec_tree(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda _: NamedSharding(mesh, P()), tree)
