"""Elastic re-sharding: move a restored host-numpy pytree onto any mesh.

A job checkpointed on one topology (e.g. 512 chips) restores on another
(e.g. 256 after losing a pod): checkpoints are topology-free host
arrays, and ``reshard`` places them under the *new* mesh's shardings.
The launcher (launch/train.py) wires this together with
``mesh_from_available_devices`` so a restarted job simply uses whatever
devices exist — the elastic-scaling story for node failures.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard(tree: Any, shardings: Any) -> Any:
    """device_put every leaf under the matching sharding (or replicate).

    ``shardings`` is a matching pytree of NamedSharding (or a single
    sharding applied to all leaves).
    """
    if isinstance(shardings, (NamedSharding,)) or shardings is None:
        return jax.device_put(tree, shardings)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda x: x is None)


def mesh_from_available_devices(
    model_parallel: int = 1,
    max_devices: Optional[int] = None,
) -> Mesh:
    """Builds a (data, model) mesh from whatever devices are alive.

    data size = n_devices // model_parallel (elastic along data).
    """
    devs = jax.devices()
    if max_devices is not None:
        devs = devs[:max_devices]
    n = len(devs)
    if n % model_parallel:
        raise ValueError(
            f"{n} devices not divisible by model_parallel={model_parallel}")
    import numpy as np
    arr = np.asarray(devs).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, ("data", "model"))


def replicate_spec_tree(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda _: NamedSharding(mesh, P()), tree)
