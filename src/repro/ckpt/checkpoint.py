"""Atomic, manifest-tracked checkpointing (no external deps).

Layout:
  <dir>/manifest.json            {"steps": [100, 200, ...], "keep": 3}
  <dir>/step_00000200/ckpt.npz   leaf_00000, leaf_00001, ...
  <dir>/step_00000200/meta.json  {"step": 200, "n_leaves": N}

Guarantees:
  * atomicity — writes go to ``.tmp-<step>`` and are ``os.rename``d into
    place, so a crash mid-save never corrupts the latest checkpoint;
  * keep-last-M pruning;
  * restore-into-template — leaves are matched positionally against the
    live pytree (params/opt_state built by model init), so restore works
    on any mesh: arrays land as host numpy and the launcher re-shards
    them (``elastic.reshard``) onto whatever device topology exists,
    enabling elastic restarts on a different pod count.

Restart determinism is tested end-to-end: save → kill → restore →
continue produces bitwise-identical parameters to an uninterrupted run
(tests/test_checkpoint.py), because the data loader replays batches as
a pure function of step.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def run_fingerprint(payload: dict) -> np.int64:
    """Stable int64 fingerprint of run-defining settings.

    JSON-canonicalized (sorted keys) SHA-256, truncated to 63 bits so it
    round-trips as a non-negative np.int64 checkpoint leaf.  A restored
    run compares the stored fingerprint against its own and refuses to
    continue on mismatch — this is how ``fit_streaming`` detects "same
    tree structure, different run semantics" (different archive,
    batching, seed, loss …).  Data-parallel runs additionally include
    their world size and shard-assignment policy in ``payload``, so a
    checkpoint written on N devices refuses to resume on M ≠ N (the
    batch schedule — hence the replayed step sequence — depends on the
    topology).
    """
    src = json.dumps(payload, sort_keys=True)
    return np.int64(
        int.from_bytes(hashlib.sha256(src.encode()).digest()[:8],
                       "big") >> 1)


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _manifest_path(root: str) -> str:
    return os.path.join(root, "manifest.json")


def _read_manifest(root: str) -> dict:
    try:
        with open(_manifest_path(root)) as f:
            return json.load(f)
    except FileNotFoundError:
        return {"steps": []}


def _write_manifest(root: str, manifest: dict) -> None:
    tmp = _manifest_path(root) + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, _manifest_path(root))


def save(root: str, step: int, tree: Any, keep_last: int = 3) -> str:
    """Saves a pytree snapshot; prunes old steps; returns the step dir."""
    os.makedirs(root, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    arrays = {f"leaf_{i:05d}": np.asarray(jax.device_get(x))
              for i, x in enumerate(leaves)}
    tmp = os.path.join(root, f".tmp-{step}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "ckpt.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": int(step), "n_leaves": len(leaves)}, f)
    final = _step_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    manifest = _read_manifest(root)
    steps = sorted(set(manifest.get("steps", [])) | {int(step)})
    while len(steps) > keep_last:
        victim = steps.pop(0)
        shutil.rmtree(_step_dir(root, victim), ignore_errors=True)
    _write_manifest(root, {"steps": steps, "keep": keep_last})
    return final


def latest_step(root: str) -> Optional[int]:
    steps = _read_manifest(root).get("steps", [])
    return max(steps) if steps else None


def restore(root: str, template: Any,
            step: Optional[int] = None) -> Tuple[Any, int]:
    """Loads leaves into the structure of ``template``; returns (tree, step)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = _step_dir(root, step)
    data = np.load(os.path.join(d, "ckpt.npz"))
    leaves_t, treedef = jax.tree.flatten(template)
    if len(leaves_t) != len(data.files):
        raise ValueError(
            f"checkpoint has {len(data.files)} leaves, template has "
            f"{len(leaves_t)} — incompatible structure")
    leaves = [np.asarray(data[f"leaf_{i:05d}"]).astype(
        np.asarray(leaves_t[i]).dtype).reshape(np.shape(leaves_t[i]))
        for i in range(len(leaves_t))]
    return treedef.unflatten(leaves), int(step)


def restore_if_exists(root: str, template: Any):
    try:
        return restore(root, template)
    except (FileNotFoundError, ValueError):
        return None


# --------------------------------------------------- serving handoff ----
# A training checkpoint is the FULL state (params + optimizer + stream
# position) restored against the trainer's own template; a serving
# process has none of that structure.  ``publish_params`` writes a
# params-only snapshot under <root>/serve with the same atomic-rename +
# manifest discipline, so the server side can restore it against
# nothing but its live param tree (``serving.reload``) — the handoff
# that lets a mid-run fit_streaming checkpoint go live with no restart.

SERVE_SUBDIR = "serve"


def publish_params(root: str, step: int, params: Any,
                   keep_last: int = 3) -> str:
    """Publish a serving-consumable params-only snapshot under
    ``<root>/serve``; returns the step dir."""
    return save(os.path.join(root, SERVE_SUBDIR), step, params,
                keep_last=keep_last)


def latest_published(root: str) -> Optional[int]:
    return latest_step(os.path.join(root, SERVE_SUBDIR))


def restore_published(root: str, template: Any,
                      step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore the latest (or given-step) published params snapshot."""
    return restore(os.path.join(root, SERVE_SUBDIR), template, step)
