"""Atomic, checksummed, manifest-tracked checkpointing (no external deps).

Layout (checkpoint format 4; format-3 directories restore unchanged):
  <dir>/manifest.json            {"steps": [100, 200, ...], "keep": 3}
  <dir>/step_00000200/ckpt.npz   leaf_00000, leaf_00001, ...
  <dir>/step_00000200/meta.json  {"step", "n_leaves", "ckpt_format": 4,
                                  "crc32": {leaf_00000: ..., ...},
                                  ...caller extra_meta (e.g. the
                                  trainer's schedule + topology
                                  lineage)}
  <dir>/quarantine/step_...      corrupt checkpoints moved aside by
                                 restore — never silently reused

Durability contract:

  * **atomic + durable publication** — leaves and metadata are written
    to ``.tmp-<step>``, fsync'd (file contents AND the directory
    entry), then ``os.rename``d into place.  A crash at any point
    leaves either the previous checkpoint set or the new one — never a
    half-visible directory;
  * **integrity** — every leaf's CRC32 is recorded in ``meta.json``;
    ``restore`` recomputes and compares, so a torn write that beat the
    fsync (or later disk corruption) is *detected*, not trained on;
  * **quarantine + fallback** — a corrupt newest checkpoint is logged,
    moved under ``<dir>/quarantine/`` and dropped from the manifest;
    ``restore`` then falls back to the newest remaining valid step (a
    ring of ``keep_last`` is retained for exactly this reason).  Only
    when *no* valid checkpoint remains does restore raise
    ``FileNotFoundError`` — the caller restarts from scratch, loudly;
  * **keep-last-M pruning** with the manifest as the single source of
    truth for which steps exist;
  * **restore-into-template** — leaves are matched positionally
    against the live pytree (params/opt_state built by model init), so
    restore works on any mesh: arrays land as host numpy and the
    trainer re-shards them (``elastic.reshard``) onto whatever device
    topology exists, enabling elastic restarts on a different device
    count.

Restart determinism is tested end-to-end: save → kill → restore →
continue produces bitwise-identical parameters to an uninterrupted run
(tests/test_checkpoint.py, tests/test_fault_tolerance.py), because the
data loader replays batches as a pure function of step.

Fault injection: when a ``repro.ft.faults.FaultPlan`` is armed, ``save``
consults the ``ckpt_write`` hook — a ``"torn"`` directive truncates the
payload after the atomic rename (the write that beat the fsync), then
raises ``InjectedCrash``; the unarmed cost is one global check.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import shutil
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.ft import faults

CKPT_FORMAT = 4
QUARANTINE_SUBDIR = "quarantine"

log = logging.getLogger("repro.ckpt")


class CorruptCheckpointError(RuntimeError):
    """A checkpoint failed validation (unreadable npz or CRC mismatch)."""


def run_fingerprint(payload: dict) -> np.int64:
    """Stable int64 fingerprint of run-defining settings.

    JSON-canonicalized (sorted keys) SHA-256, truncated to 63 bits so it
    round-trips as a non-negative np.int64 checkpoint leaf.  A restored
    run compares the stored fingerprint against its own and refuses to
    continue on mismatch — this is how ``fit_streaming`` detects "same
    tree structure, different run semantics" (different archive,
    batching, seed, loss …).  Data-parallel runs include their LOGICAL
    world size and shard-assignment policy in ``payload`` — the batch
    schedule (hence the replayed step sequence) depends on them.  The
    PHYSICAL device count is deliberately excluded: the fold-step math
    makes the update a pure function of the logical schedule, so a
    checkpoint written on N devices may resume on M ≠ N under
    ``elastic=True``; each physical realization is recorded as a
    sanctioned topology-lineage entry in the checkpoint's ``meta.json``
    (see ``fit_streaming``) instead of being refused.
    """
    src = json.dumps(payload, sort_keys=True)
    return np.int64(
        int.from_bytes(hashlib.sha256(src.encode()).digest()[:8],
                       "big") >> 1)


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _manifest_path(root: str) -> str:
    return os.path.join(root, "manifest.json")


def _read_manifest(root: str) -> dict:
    try:
        with open(_manifest_path(root)) as f:
            return json.load(f)
    except FileNotFoundError:
        return {"steps": []}


def _write_manifest(root: str, manifest: dict) -> None:
    tmp = _manifest_path(root) + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, _manifest_path(root))


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(root: str, step: int, tree: Any, keep_last: int = 3, *,
         extra_meta: Optional[dict] = None) -> str:
    """Saves a pytree snapshot; prunes old steps; returns the step dir.

    Writes leaves + per-leaf CRC32s to a ``.tmp-<step>`` staging dir,
    fsyncs file contents and the parent directory entry, then renames
    into place — atomic AND durable.  ``extra_meta`` entries are merged
    into ``meta.json`` (readable back via ``load_meta``); the trainer
    stores its schedule + topology lineage there.
    """
    os.makedirs(root, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    arrays = {f"leaf_{i:05d}": np.asarray(jax.device_get(x))
              for i, x in enumerate(leaves)}
    directive = faults.on_ckpt_write(step) if faults._ACTIVE is not None \
        else None
    tmp = os.path.join(root, f".tmp-{step}")
    os.makedirs(tmp, exist_ok=True)
    payload = os.path.join(tmp, "ckpt.npz")
    np.savez(payload, **arrays)
    meta = {"step": int(step), "n_leaves": len(leaves),
            "ckpt_format": CKPT_FORMAT,
            "crc32": {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                      for k, v in arrays.items()}}
    if extra_meta:
        meta.update(extra_meta)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if directive == "torn":
        # the injected failure mode: the rename becomes durable but the
        # payload pages never hit disk — model it by truncating AFTER
        # the write, skipping the payload fsync, and completing the
        # publication below before crashing
        size = os.path.getsize(payload)
        with open(payload, "r+b") as f:
            f.truncate(max(1, int(size * 0.6)))
    else:
        _fsync_path(payload)
    final = _step_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_path(root)

    manifest = _read_manifest(root)
    steps = sorted(set(manifest.get("steps", [])) | {int(step)})
    while len(steps) > keep_last:
        victim = steps.pop(0)
        shutil.rmtree(_step_dir(root, victim), ignore_errors=True)
    _write_manifest(root, {"steps": steps, "keep": keep_last})
    if directive == "torn":
        raise faults.InjectedCrash(
            f"injected torn checkpoint write at step {step}")
    return final


def latest_step(root: str) -> Optional[int]:
    steps = _read_manifest(root).get("steps", [])
    return max(steps) if steps else None


def load_meta(root: str, step: int) -> Optional[dict]:
    """The ``meta.json`` of one checkpoint step (None if unreadable) —
    how the trainer reads back its schedule + topology lineage."""
    try:
        with open(os.path.join(_step_dir(root, step), "meta.json")) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError, OSError):
        return None


def _quarantine(root: str, step: int, why: Exception) -> None:
    qdir = os.path.join(root, QUARANTINE_SUBDIR)
    os.makedirs(qdir, exist_ok=True)
    src = _step_dir(root, step)
    dst = os.path.join(qdir, os.path.basename(src))
    n = 1
    while os.path.exists(dst):
        dst = os.path.join(qdir, f"{os.path.basename(src)}.{n}")
        n += 1
    log.error("checkpoint step %d under %r is corrupt (%s) — "
              "quarantining to %r and falling back to the newest valid "
              "checkpoint", step, root, why, dst)
    try:
        os.rename(src, dst)
    except OSError:
        shutil.rmtree(src, ignore_errors=True)
    manifest = _read_manifest(root)
    steps = [s for s in manifest.get("steps", []) if int(s) != int(step)]
    _write_manifest(root, {"steps": steps,
                           "keep": manifest.get("keep", 3)})


def _load_validated(d: str, meta: Optional[dict]) -> dict:
    """npz → {name: array}, CRC-checked when the meta records CRCs.
    Raises ``CorruptCheckpointError`` on any parse/shape/CRC failure."""
    try:
        with np.load(os.path.join(d, "ckpt.npz")) as data:
            arrays = {name: np.asarray(data[name]) for name in data.files}
    except Exception as e:  # torn zip: BadZipFile/OSError/EOF/Value…
        raise CorruptCheckpointError(f"unreadable ckpt.npz: {e!r}") from e
    crcs = (meta or {}).get("crc32")
    if crcs:  # format-3 checkpoints predate CRCs: parse-check only
        for name, arr in arrays.items():
            want = crcs.get(name)
            got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if want is None or int(want) != got:
                raise CorruptCheckpointError(
                    f"CRC mismatch on {name} (recorded {want}, "
                    f"recomputed {got})")
    return arrays


def restore(root: str, template: Any, step: Optional[int] = None, *,
            validate: bool = True,
            fallback: Optional[bool] = None) -> Tuple[Any, int]:
    """Loads leaves into the structure of ``template``; returns
    ``(tree, step)``.

    With ``step=None`` (the default) candidates are walked newest
    first; a candidate failing validation (unreadable archive or CRC
    mismatch) is logged, quarantined under ``<root>/quarantine/`` and
    the next newest is tried (``fallback`` defaults to True here).
    When every candidate is corrupt, raises ``FileNotFoundError`` —
    same as an empty directory, so callers restart from scratch rather
    than train on garbage.  An explicitly requested ``step`` never
    falls back: corruption raises ``CorruptCheckpointError``.
    A template/leaf-count mismatch raises ``ValueError`` (structural
    incompatibility, NOT corruption — nothing is quarantined).

    A step written by a multi-process gang (``ckpt.coordinated`` —
    per-rank payloads, no top-level ``ckpt.npz``) restores through the
    same walk: this process's own rank payload is preferred, any valid
    rank's replicated payload is accepted, and only a step with NO
    valid payload counts as corrupt.  Plain and coordinated layouts
    are fully interchangeable — that is what lets a single process
    resume a gang's checkpoint (N→1) and a gang resume a
    single-process one (1→N).
    """
    if fallback is None:
        fallback = step is None
    if step is None:
        steps = sorted(_read_manifest(root).get("steps", []), reverse=True)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {root}")
    else:
        steps = [int(step)]
    last_err: Optional[Exception] = None
    for s in steps:
        d = _step_dir(root, s)
        try:
            from repro.ckpt import coordinated
            if coordinated.is_coordinated_dir(d):
                from repro.distributed.runtime import current_rank
                arrays = coordinated.load_step_arrays(
                    d, prefer_rank=current_rank())
            else:
                arrays = _load_validated(d, load_meta(root, s)
                                         if validate else None)
        except CorruptCheckpointError as e:
            last_err = e
            if not fallback:
                raise
            _quarantine(root, s, e)
            continue
        leaves_t, treedef = jax.tree.flatten(template)
        if len(leaves_t) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, template has "
                f"{len(leaves_t)} — incompatible structure")
        leaves = [np.asarray(arrays[f"leaf_{i:05d}"]).astype(
            np.asarray(leaves_t[i]).dtype).reshape(np.shape(leaves_t[i]))
            for i in range(len(leaves_t))]
        return treedef.unflatten(leaves), int(s)
    raise FileNotFoundError(
        f"no valid checkpoints under {root} (last corruption: "
        f"{last_err!r})")


def restore_if_exists(root: str, template: Any):
    try:
        return restore(root, template)
    except (FileNotFoundError, ValueError):
        return None


# --------------------------------------------------- serving handoff ----
# A training checkpoint is the FULL state (params + optimizer + stream
# position) restored against the trainer's own template; a serving
# process has none of that structure.  ``publish_params`` writes a
# params-only snapshot under <root>/serve with the same atomic-rename +
# checksum + manifest discipline, so the server side can restore it
# against nothing but its live param tree (``serving.reload``) — the
# handoff that lets a mid-run fit_streaming checkpoint go live with no
# restart.

SERVE_SUBDIR = "serve"


def publish_params(root: str, step: int, params: Any,
                   keep_last: int = 3) -> str:
    """Publish a serving-consumable params-only snapshot under
    ``<root>/serve``; returns the step dir."""
    return save(os.path.join(root, SERVE_SUBDIR), step, params,
                keep_last=keep_last)


def latest_published(root: str) -> Optional[int]:
    return latest_step(os.path.join(root, SERVE_SUBDIR))


def restore_published(root: str, template: Any,
                      step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore the latest (or given-step) published params snapshot."""
    return restore(os.path.join(root, SERVE_SUBDIR), template, step)
