"""Coordinated multi-rank checkpoints: per-rank payloads, one commit.

The gang variant of ``ckpt.checkpoint``'s durability contract.  A
``procs``-wide run checkpoints at the same shard boundary on every
rank (the logical schedule is global, so boundaries align); the step
directory then holds one CRC'd payload PER RANK plus a single step
manifest written by rank 0 — and only that manifest's atomic rename
commits the step:

  <dir>/manifest.json                 shared step index (rank-0 written)
  <dir>/step_S/rank_00000/ckpt.npz    rank 0's payload
  <dir>/step_S/rank_00000/meta.json   {"rank", "crc32", ...}
  <dir>/step_S/rank_00001/…           rank 1's payload
  <dir>/step_S/meta.json              the COMMIT RECORD: step, gang
                                      size, per-rank CRC index, plus
                                      the trainer's extra_meta
                                      (schedule + topology lineage)

Write protocol (``save_coordinated``):

  1. every rank stages its payload under ``<dir>/.stage-s<S>/
     rank_<r>`` — written to a rank-private tmp dir, fsync'd, renamed
     into the stage (atomic per rank);
  2. rank 0 polls until all ``procs`` rank payloads are present (the
     collectives keep ranks within one step of each other, so this
     barrier resolves in one boundary's worth of time; a
     ``barrier_timeout_s`` turns a genuinely dead rank into a loud
     error instead of a hang), assembles the step meta from the rank
     metas, fsyncs, then renames the whole stage to ``step_S`` and
     updates the shared manifest — the single commit point.  A crash
     anywhere before that rename (including the injected
     ``manifest_write`` rank-0 kill) leaves only a ``.stage-*``
     directory that no restore ever reads;
  3. non-zero ranks return after their payload lands — they do NOT
     wait for the commit.  If rank 0 dies mid-commit the gang dies at
     the next collective and the supervisor restarts everyone from the
     previous committed step; when the respawned gang replays back to
     that boundary each rank rename-replaces its payload in the
     leftover stage (never deleted up front — a visible stage may be a
     LIVE peer's in-flight write, and a stale payload is byte-identical
     under deterministic replay anyway) and rank 0 commits as usual.

Restore (``load_step_arrays``, reached through ``ckpt.checkpoint
.restore`` — the two layouts are interchangeable): the restoring
process prefers its OWN rank's payload; a torn/corrupt payload is
quarantined (moved aside, exactly PR 7's ring-fallback discipline) and
any other rank's valid payload is used instead — sound because the
trainer's checkpointed state is fully replicated across ranks.  Only
when EVERY rank payload fails validation does the step itself count as
corrupt and the walk falls back to the previous committed step.  A
single-process resume of a coordinated checkpoint (gang of N → 1) and
a gang resume of a plain checkpoint (1 → N) both work for the same
reason: any one payload IS the full state.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.ft import faults

log = logging.getLogger("repro.ckpt")

COORDINATED_FORMAT = 5

__all__ = ["save_coordinated", "load_step_arrays", "is_coordinated_dir",
           "COORDINATED_FORMAT"]


def _rank_name(rank: int) -> str:
    return f"rank_{rank:05d}"


def _stage_dir(root: str, step: int) -> str:
    return os.path.join(root, f".stage-s{step}")


def is_coordinated_dir(step_dir: str) -> bool:
    """A committed coordinated step: rank payloads, no top-level npz."""
    return (not os.path.exists(os.path.join(step_dir, "ckpt.npz"))
            and os.path.isdir(os.path.join(step_dir, _rank_name(0))))


def _write_rank_payload(stage: str, rank: int, step: int,
                        tree: Any) -> None:
    """Stage one rank's CRC'd payload atomically (tmp + fsync + rename).

    Honors the armed ``ckpt_write`` fault exactly like ``checkpoint
    .save``: a ``"torn"`` directive truncates the payload AFTER the
    rename (CRCs were recorded from the in-memory arrays, so restore
    detects the tear), then raises ``InjectedCrash``.
    """
    from repro.ckpt.checkpoint import _fsync_path

    leaves = jax.tree.leaves(tree)
    arrays = {f"leaf_{i:05d}": np.asarray(jax.device_get(x))
              for i, x in enumerate(leaves)}
    directive = faults.on_ckpt_write(step) if faults._ACTIVE is not None \
        else None
    tmp = os.path.join(stage, f".tmp-{_rank_name(rank)}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    payload = os.path.join(tmp, "ckpt.npz")
    np.savez(payload, **arrays)
    meta = {"rank": int(rank), "step": int(step),
            "n_leaves": len(leaves),
            "ckpt_format": COORDINATED_FORMAT,
            "crc32": {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                      for k, v in arrays.items()}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if directive == "torn":
        size = os.path.getsize(payload)
        with open(payload, "r+b") as f:
            f.truncate(max(1, int(size * 0.6)))
    else:
        _fsync_path(payload)
    final = os.path.join(stage, _rank_name(rank))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_path(stage)
    if directive == "torn":
        raise faults.InjectedCrash(
            f"injected torn rank-{rank} checkpoint write at step {step}")


def _rank_meta(stage: str, rank: int) -> Optional[dict]:
    try:
        with open(os.path.join(stage, _rank_name(rank),
                               "meta.json")) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError, OSError):
        return None


def save_coordinated(
    root: str,
    step: int,
    tree: Any,
    *,
    rank: int,
    procs: int,
    keep_last: int = 3,
    extra_meta: Optional[dict] = None,
    barrier_timeout_s: float = 120.0,
) -> Optional[str]:
    """One rank's half of a coordinated save; every rank of the gang
    calls it at the same boundary.  Returns the committed step dir on
    rank 0, ``None`` on other ranks (which return once their payload
    is staged)."""
    from repro.ckpt.checkpoint import (
        _fsync_path, _read_manifest, _step_dir, _write_manifest,
    )

    os.makedirs(root, exist_ok=True)
    stage = _stage_dir(root, step)
    # NO stale-stage cleanup here: ranks reach this boundary at
    # slightly different times, so a visible stage may be ANOTHER
    # rank's in-flight write for this very step — deleting it races.
    # A stage left by a gang that died at this step is harmless
    # instead: replay is deterministic, so a stale completed rank
    # payload is byte-identical to the one this attempt re-stages
    # (atomically, rename-replace) over it.
    os.makedirs(stage, exist_ok=True)
    _write_rank_payload(stage, rank, step, tree)
    if rank != 0:
        return None

    # ---- rank 0: wait for the gang, then commit ----------------------
    deadline = time.monotonic() + barrier_timeout_s
    metas = {}
    while len(metas) < procs:
        for r in range(procs):
            if r not in metas:
                m = _rank_meta(stage, r)
                if m is not None:
                    metas[r] = m
        if len(metas) == procs:
            break
        if time.monotonic() > deadline:
            raise RuntimeError(
                f"coordinated checkpoint at step {step} timed out "
                f"after {barrier_timeout_s:.0f}s waiting for rank "
                f"payloads {sorted(set(range(procs)) - set(metas))} "
                f"under {stage!r}")
        time.sleep(0.01)

    n_leaves = {m["n_leaves"] for m in metas.values()}
    if len(n_leaves) != 1:
        raise RuntimeError(
            f"coordinated checkpoint at step {step} has inconsistent "
            f"rank payloads (leaf counts {sorted(n_leaves)})")
    meta = {"step": int(step), "ckpt_format": COORDINATED_FORMAT,
            "procs": int(procs), "n_leaves": n_leaves.pop(),
            "rank_crc32": {str(r): metas[r]["crc32"]
                           for r in sorted(metas)}}
    if extra_meta:
        meta.update(extra_meta)
    with open(os.path.join(stage, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())

    # the injected rank-0 death window: payloads durable, manifest not
    if faults._ACTIVE is not None:
        faults.on_manifest_write(step)

    final = _step_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(stage, final)
    _fsync_path(root)

    manifest = _read_manifest(root)
    steps = sorted(set(manifest.get("steps", [])) | {int(step)})
    while len(steps) > keep_last:
        victim = steps.pop(0)
        shutil.rmtree(_step_dir(root, victim), ignore_errors=True)
    _write_manifest(root, {"steps": steps, "keep": keep_last})
    return final


# ------------------------------------------------------------ restore --

def _quarantine_rank_payload(step_dir: str, rank: int,
                             why: Exception) -> None:
    src = os.path.join(step_dir, _rank_name(rank))
    dst = src + ".quarantined"
    n = 1
    while os.path.exists(dst):
        dst = f"{src}.quarantined.{n}"
        n += 1
    log.error("rank-%d payload under %r is corrupt (%s) — quarantining "
              "to %r and falling back to another rank's replicated "
              "state", rank, step_dir, why, dst)
    try:
        os.rename(src, dst)
    except OSError:
        shutil.rmtree(src, ignore_errors=True)


def load_step_arrays(step_dir: str, *, prefer_rank: int = 0) -> dict:
    """A committed coordinated step's arrays, validated against the
    rank payload's recorded CRCs.

    Tries ``prefer_rank`` first (its payload is this process's own),
    then every other rank ascending — valid because the checkpointed
    trainer state is replicated.  The preferring process quarantines
    its OWN torn payload (moves it aside); other ranks' payloads are
    only read, never moved, so concurrent gang restores cannot race.
    Raises ``CorruptCheckpointError`` when no rank payload survives.
    """
    from repro.ckpt.checkpoint import (
        CorruptCheckpointError, _load_validated,
    )

    ranks = sorted(
        int(name[len("rank_"):]) for name in os.listdir(step_dir)
        if name.startswith("rank_") and not name.endswith(".tmp")
        and "quarantined" not in name
        and os.path.isdir(os.path.join(step_dir, name)))
    order = ([prefer_rank] if prefer_rank in ranks else []) + \
        [r for r in ranks if r != prefer_rank]
    last_err: Optional[Exception] = None
    for r in order:
        d = os.path.join(step_dir, _rank_name(r))
        try:
            return _load_validated(d, _rank_meta(step_dir, r))
        except CorruptCheckpointError as e:
            last_err = e
            if r == prefer_rank:
                _quarantine_rank_payload(step_dir, r, e)
            else:
                log.error("rank-%d payload under %r is corrupt (%s) — "
                          "trying the next rank", r, step_dir, e)
    raise CorruptCheckpointError(
        f"every rank payload under {step_dir!r} failed validation "
        f"(last: {last_err!r})")
