"""LibSVM text format IO — the paper's interchange format.

The paper measures data-loading time of the 200 GB LibSVM file as the
baseline every preprocessing cost is compared against (Table 2).  We
implement a streaming reader/writer with sharding so the Table-2
benchmark can be reproduced at any scale.
"""
from __future__ import annotations

import os
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np


def write_libsvm(
    path: str,
    rows: Iterable[np.ndarray],
    labels: Iterable[int],
    values: Optional[Iterable[np.ndarray]] = None,
) -> int:
    """Writes `label idx:val ...` lines (binary → val 1). Returns #rows."""
    n = 0
    with open(path, "w") as f:
        if values is None:
            for idx, y in zip(rows, labels):
                f.write(str(int(y)))
                f.write(" ")
                f.write(" ".join(f"{int(i)}:1" for i in idx))
                f.write("\n")
                n += 1
        else:
            for idx, y, val in zip(rows, labels, values):
                f.write(str(int(y)))
                f.write(" ")
                f.write(" ".join(
                    f"{int(i)}:{float(v):g}" for i, v in zip(idx, val)))
                f.write("\n")
                n += 1
    return n


def read_libsvm(
    path: str, with_values: bool = False
) -> Iterator[Tuple[np.ndarray, int, Optional[np.ndarray]]]:
    """Streams (indices int64, label, values|None) per line."""
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            y = int(float(parts[0]))
            idx = np.empty(len(parts) - 1, dtype=np.int64)
            val = np.empty(len(parts) - 1, dtype=np.float32) \
                if with_values else None
            for i, tok in enumerate(parts[1:]):
                a, _, b = tok.partition(":")
                idx[i] = int(a)
                if with_values:
                    val[i] = float(b)
            yield idx, y, val


def shard_paths(root: str, n_shards: int) -> List[str]:
    return [os.path.join(root, f"shard_{i:05d}.libsvm")
            for i in range(n_shards)]


def write_shards(
    root: str,
    rows: Sequence[np.ndarray],
    labels: Sequence[int],
    n_shards: int,
) -> List[str]:
    """Round-robin shards rows into n_shards LibSVM files."""
    os.makedirs(root, exist_ok=True)
    paths = shard_paths(root, n_shards)
    for s, p in enumerate(paths):
        sel = range(s, len(rows), n_shards)
        write_libsvm(p, [rows[i] for i in sel],
                     [labels[i] for i in sel])
    return paths


def read_shards(paths: Sequence[str]) -> Tuple[List[np.ndarray], np.ndarray]:
    rows: List[np.ndarray] = []
    labels: List[int] = []
    for p in paths:
        for idx, y, _ in read_libsvm(p):
            rows.append(idx)
            labels.append(y)
    return rows, np.asarray(labels, dtype=np.int32)
