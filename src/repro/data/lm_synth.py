"""Synthetic token/feature streams for the LM architecture zoo.

Used by per-arch smoke tests, the quickstart LM example, and any place
that needs deterministic token batches without real corpora.  Tokens
follow a Zipf law with short-range repetition structure so losses
actually decrease during smoke training.
"""
from __future__ import annotations

import numpy as np


def token_batch(
    batch: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    zipf_a: float = 1.2,
) -> np.ndarray:
    """int32 (batch, seq_len) Zipf tokens with local bigram structure."""
    rng = np.random.default_rng(np.random.SeedSequence(seed))
    ranks = rng.zipf(zipf_a, size=(batch, seq_len)).astype(np.int64)
    toks = (ranks - 1) % max(vocab - 2, 1) + 1  # reserve 0 for padding
    # inject bigram predictability: every other token repeats prev+1
    rep = rng.random((batch, seq_len)) < 0.3
    rep[:, 0] = False
    shifted = np.roll(toks, 1, axis=1) + 1
    toks = np.where(rep, shifted % vocab, toks)
    return toks.astype(np.int32)


def lm_example_stream(batch: int, seq_len: int, vocab: int, seed: int = 0):
    """Yields (step, tokens, targets) forever; targets are next-token."""
    step = 0
    while True:
        toks = token_batch(batch, seq_len + 1, vocab, seed=seed + step)
        yield step, toks[:, :-1], toks[:, 1:]
        step += 1
