"""Offline preprocessing: raw sparse rows → b-bit hashed dataset on disk.

This is the paper's §6 pipeline as a production feature: a one-time
hashing pass (kernel- or numpy-backed) producing bit-packed shards that
are then *reused* across every training experiment (C sweeps, train/test
splits) — the exact economics the paper argues for.  Shard format
(format_version 2):

  <root>/meta.json                 {format_version, scheme, k, b,
                                    family, seed, n, shards}
  <root>/hashed_00000.npz          codes: packed uint8 (rows, ceil(kb/8))
                                   labels: int32 (rows,)
                                   empty: packed uint8 (rows, ceil(k/8))
                                          [oph_zero only — empty-bin
                                           bitmask, np.packbits layout]

``scheme`` selects the hashing recipe (see ``repro.core.schemes``):
``minwise`` (the paper's k-permutation pass), ``oph`` (densified one
permutation hashing — k× fewer hash evaluations, same code format) or
``oph_zero`` (zero-coded OPH; empty bins are stored as a side bitmask
and surface as ``OPH_EMPTY_CODE`` in the unpacked matrix).  Version-1
archives (no ``format_version``/``scheme`` keys) load unchanged and are
interpreted as minwise.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.bbit import bbit_codes, pack_codes, unpack_codes
from repro.core.minhash import minhash_numpy
from repro.core.oph import OPH_EMPTY_CODE
from repro.core.schemes import make_scheme
from repro.core.universal_hash import make_hash_family
from repro.data.packing import pad_rows

FORMAT_VERSION = 2


def preprocess_rows(
    rows: Sequence[np.ndarray],
    k: int,
    b: int,
    *,
    scheme: str = "minwise",
    family: str = "multiply_shift",
    seed: int = 0,
    use_kernel: bool = True,
    chunk: int = 1024,
) -> np.ndarray:
    """Hashes rows → uint16 codes (n, k). Kernel path on the accelerator.

    ``scheme="minwise"`` is the paper's k-permutation pass (k hash
    evaluations per nonzero); ``scheme="oph"`` / ``"oph_zero"`` is one
    permutation hashing (ONE evaluation per nonzero).  ``family`` picks
    the exact offline families (mod_prime / permutation) for the
    minwise scheme only.
    """
    # Length-sort so each chunk pads to its own max nnz — heavy-tailed
    # documents (the rcv1 expansion's lognormal lengths) otherwise force
    # every chunk to the global max.
    order = np.argsort([len(r) for r in rows], kind="stable")
    out = np.empty((len(rows), k), dtype=np.uint16)
    if scheme == "minwise" and family != "multiply_shift":
        # exact offline families (mod-prime / permutation) in numpy
        fam = make_hash_family(family, k, seed)
        for lo in range(0, len(rows), chunk):
            sel = order[lo: lo + chunk]
            idx, nnz = pad_rows([rows[i] for i in sel], pad_to_multiple=1)
            mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
            z = minhash_numpy(idx, mask, fam)
            out[sel] = np.asarray(bbit_codes(z, b))
        return out
    if scheme != "minwise" and family != "multiply_shift":
        raise ValueError(f"scheme {scheme!r} only supports the "
                         "multiply_shift family")
    sch = make_scheme(scheme, k, seed)
    for lo in range(0, len(rows), chunk):
        sel = order[lo: lo + chunk]
        idx, nnz = pad_rows([rows[i] for i in sel])
        out[sel] = sch.encode_padded(idx, nnz, b, use_kernel=use_kernel)
    return out


def save_hashed(
    root: str,
    codes: np.ndarray,
    labels: np.ndarray,
    k: int,
    b: int,
    *,
    scheme: str = "minwise",
    family: str = "multiply_shift",
    seed: int = 0,
    n_shards: int = 1,
) -> None:
    os.makedirs(root, exist_ok=True)
    n = codes.shape[0]
    meta = dict(format_version=FORMAT_VERSION, scheme=scheme, k=k, b=b,
                family=family, seed=seed, n=int(n), shards=n_shards)
    with open(os.path.join(root, "meta.json"), "w") as f:
        json.dump(meta, f)
    empty = codes == OPH_EMPTY_CODE if scheme == "oph_zero" else None
    if empty is not None:
        codes = np.where(empty, np.uint16(0), codes)
    for s in range(n_shards):
        sel = np.arange(s, n, n_shards)
        arrays = dict(
            codes=pack_codes(codes[sel], b),
            labels=labels[sel].astype(np.int32),
        )
        if empty is not None:
            arrays["empty"] = np.packbits(empty[sel], axis=1)
        np.savez(os.path.join(root, f"hashed_{s:05d}.npz"), **arrays)


def load_hashed(
    root: str, shard_ids: Optional[Sequence[int]] = None
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Returns (codes uint16 (n,k), labels int32 (n,), meta).

    Loading all shards restores the ORIGINAL row order (shards are
    round-robin row subsets); loading a subset returns shard order.
    For ``oph_zero`` archives, empty bins carry ``OPH_EMPTY_CODE``
    (split them back out with ``repro.core.oph.split_zero_codes``).
    """
    with open(os.path.join(root, "meta.json")) as f:
        meta = json.load(f)
    meta.setdefault("format_version", 1)
    meta.setdefault("scheme", "minwise")      # v1 archives predate OPH
    all_shards = shard_ids is None
    ids = range(meta["shards"]) if all_shards else shard_ids
    all_codes, all_labels, sels = [], [], []
    for s in ids:
        z = np.load(os.path.join(root, f"hashed_{s:05d}.npz"))
        codes = unpack_codes(z["codes"], meta["k"], meta["b"])
        if "empty" in z:
            empty = np.unpackbits(
                z["empty"], axis=1, count=meta["k"]).astype(bool)
            codes = np.where(empty, OPH_EMPTY_CODE, codes)
        all_codes.append(codes)
        all_labels.append(z["labels"])
        sels.append(np.arange(s, meta["n"], meta["shards"]))
    codes = np.concatenate(all_codes)
    labels = np.concatenate(all_labels)
    if all_shards:
        order = np.concatenate(sels)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        codes, labels = codes[inv], labels[inv]
    return codes, labels, meta


def preprocess_and_save(
    root: str,
    rows: Sequence[np.ndarray],
    labels: np.ndarray,
    k: int,
    b: int,
    **kw,
) -> dict:
    """End-to-end preprocessing with timing (Table-2 instrumentation)."""
    t0 = time.perf_counter()
    codes = preprocess_rows(rows, k, b, **{
        kk: v for kk, v in kw.items()
        if kk in ("scheme", "family", "seed", "use_kernel", "chunk")})
    t_hash = time.perf_counter() - t0
    save_hashed(root, codes, labels, k, b,
                scheme=kw.get("scheme", "minwise"),
                family=kw.get("family", "multiply_shift"),
                seed=kw.get("seed", 0),
                n_shards=kw.get("n_shards", 1))
    return dict(seconds_hashing=t_hash, n=len(rows), k=k, b=b,
                scheme=kw.get("scheme", "minwise"))
