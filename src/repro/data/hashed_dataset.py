"""Offline preprocessing: raw sparse rows → b-bit hashed dataset on disk.

This is the paper's §6 pipeline as a production feature: a one-time
hashing pass producing bit-packed shards that are then *reused* across
every training experiment (C sweeps, train/test splits) — the exact
economics the paper argues for.  Since PR 2 the pass is **device-
resident and streaming**:

  * chunks are length-sorted and shape-bucketed (pad widths rounded up
    to powers of two, ``packing.bucket_width``) so jit compiles
    O(log max_nnz) variants instead of one per chunk;
  * each chunk is encoded by the fused hash→b-bit→pack path
    (``HashingScheme.encode_packed_device``: Pallas kernel on TPU, XLA
    elsewhere), so only ``n·ceil(k·b/8)`` packed bytes leave the
    device instead of the ``n·k·4``-byte minima the PR-1 pipeline
    round-tripped;
  * dispatch is double-buffered: chunk i+1 is enqueued while chunk i's
    result is synced and appended, and shards stream to disk through
    ``HashedShardWriter`` — the full (n, k) code matrix is never
    materialized.

Shard format (format_version 4, written by ``preprocess_and_save``;
v3 archives — same file layout minus checksums — read unchanged):

  <root>/meta.json                   {format_version, scheme, k, b,
                                      family, seed, n, shards,
                                      packed_width, shard_checksums,
                                      seconds_hashing, mnnz_per_s,
                                      total_nnz}
  <root>/hashed_00000.codes.npy      packed uint8 (rows, ceil(kb/8))
  <root>/hashed_00000.labels.npy     int32 (rows,)
  <root>/hashed_00000.rows.npy       int64 (rows,) original row ids
  <root>/hashed_00000.empty.npy      packed uint8 (rows, ceil(k/8))
                                     [oph_zero only — empty-bin
                                      bitmask, np.packbits layout]

Shards hold contiguous runs of the length-sorted processing order; the
``rows`` array records original positions, so a full ``load_hashed``
restores the original row order and ``iter_hashed`` streams shard-sized
pieces with ``np.load(mmap_mode=...)`` — no all-shards concatenation.
Plain ``.npy`` members (not ``.npz``) are what makes the mmap path
possible.

Durability contract (PR 7): ``HashedShardWriter`` records a CRC32 per
shard file in ``meta.json`` (``shard_checksums`` — the v3→v4 bump; v3
archives simply have none recorded); ``verify_shard`` recomputes and
compares on demand — an offline fsck, not a per-read tax on the mmap
hot path.  ``load_packed_shard`` retries transient ``OSError``s with
bounded deterministic backoff (``repro.ft.retry.BackoffPolicy``);
persistent failures raise ``ShardReadError`` with full (root, shard,
attempts) context after recording the shard in the module-level
``quarantined_shards`` registry — loud accounting, never a silent
skip.  When a ``repro.ft.faults.FaultPlan`` is armed, its
``shard_read`` events fire *inside* the retry scope, so a transient
injected ``IOError`` is absorbed exactly like a real one.

Training consumes the archive without EVER widening a full shard
(PR 3, the train-from-shards path):

  * ``load_packed_shard`` / ``iter_packed`` hand back the raw packed
    bytes (mmap'd for v3), labels, row ids and the packed ``oph_zero``
    empty bitmask;
  * ``iter_hashed_batches`` slices minibatches of packed rows straight
    off the mmap — resident memory is the touched pages of ONE shard
    and codes are widened on the *device* (``core.bbit
    .unpack_codes_jnp`` inside the jitted train step), which is what
    ``train.streaming.fit_streaming`` iterates;
  * ``shard_row_counts`` exposes per-shard row counts (mmap'd shape
    reads) so trainers can size epochs without loading data.

``scheme`` selects the hashing recipe (see ``repro.core.schemes``):
``minwise`` (the paper's k-permutation pass), ``oph`` (densified one
permutation hashing — k× fewer hash evaluations, same code format) or
``oph_zero`` (zero-coded OPH; empty bins are stored as a side bitmask
and surface as ``OPH_EMPTY_CODE`` in the unpacked matrix).  Version-1/2
archives (monolithic ``.npz`` shards, round-robin row subsets) load and
iterate unchanged; ``save_hashed`` still writes the version-2 layout
for callers that already hold a full code matrix.
"""
from __future__ import annotations

import collections
import json
import logging
import os
import time
import zlib
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bbit import bbit_codes, pack_codes, packed_width, unpack_codes
from repro.core.minhash import minhash_numpy
from repro.core.oph import OPH_EMPTY_CODE
from repro.core.schemes import make_scheme
from repro.core.universal_hash import make_hash_family
from repro.data.packing import pad_rows
from repro.ft import faults
from repro.ft.retry import BackoffPolicy

FORMAT_VERSION = 4

log = logging.getLogger("repro.data")

# transient-read policy: small, capped, jitter-free (deterministic)
READ_RETRIES = 2
READ_BACKOFF = BackoffPolicy(base_s=0.01, factor=2.0, cap_s=0.25,
                             jitter_frac=0.0)

# loud accounting for shards whose reads exhausted their retries —
# keyed by archive root, values are shard ids; reset per process.
quarantined_shards: Dict[str, List[int]] = {}


class ShardReadError(RuntimeError):
    """A shard read kept failing after bounded retries (persistent
    corruption / dead disk, as opposed to a transient hiccup)."""

    def __init__(self, msg: str, *, root: str, shard: int,
                 attempts: int):
        super().__init__(msg)
        self.root = root
        self.shard = shard
        self.attempts = attempts


class ShardCorruptionError(RuntimeError):
    """``verify_shard`` found bytes that contradict the recorded CRCs."""

# Chunks kept in flight on the device before the oldest is synced —
# depth 2 = classic double buffering (enqueue i+1 while i computes).
PIPELINE_DEPTH = 2


def _length_sorted_chunks(rows: Sequence[np.ndarray], chunk: int):
    """Yields index arrays of ≤``chunk`` rows, shortest documents first.

    Length-sorting keeps heavy-tailed corpora from padding every chunk
    to the global max nnz; pow-2 bucketing (``pad_rows(bucket=True)``)
    then caps the number of distinct jit shapes the sort produces.
    """
    order = np.argsort([len(r) for r in rows], kind="stable")
    for lo in range(0, len(rows), chunk):
        yield order[lo: lo + chunk]


def _stream_encoded(
    rows: Sequence[np.ndarray],
    k: int,
    b: int,
    *,
    scheme: str,
    family: str,
    seed: int,
    use_kernel: bool,
    chunk: int,
    packed: bool,
    depth: int = PIPELINE_DEPTH,
):
    """Yields (sel, codes, empty|None) per length-sorted chunk.

    ``packed=True`` streams fused uint8 bytes (the hot path);
    ``packed=False`` streams uint16 code matrices with the
    ``OPH_EMPTY_CODE`` sentinel applied (the compat path).  Up to
    ``depth`` chunks stay in flight on the device: jax dispatch is
    async, so chunk i+1's transfer+compute is enqueued before chunk i's
    result is synced to numpy.
    """
    if scheme == "minwise" and family != "multiply_shift":
        # exact offline families (mod-prime / permutation): numpy path
        fam = make_hash_family(family, k, seed)
        for sel in _length_sorted_chunks(rows, chunk):
            idx, nnz = pad_rows([rows[i] for i in sel], pad_to_multiple=1)
            mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
            codes = np.asarray(bbit_codes(minhash_numpy(idx, mask, fam), b))
            yield sel, (pack_codes(codes, b) if packed else codes), None
        return
    if scheme != "minwise" and family != "multiply_shift":
        raise ValueError(f"scheme {scheme!r} only supports the "
                         "multiply_shift family")
    sch = make_scheme(scheme, k, seed)

    def _materialize(sel, dev):
        vals, empty = dev
        if packed:
            # row padding (if any) falls off here
            return sel, np.asarray(vals)[: len(sel)], (
                None if empty is None else np.asarray(empty)[: len(sel)])
        out = np.asarray(vals).astype(np.uint16)
        if empty is not None:
            out[np.asarray(empty)] = OPH_EMPTY_CODE
        return sel, out, None

    pending = collections.deque()
    for sel in _length_sorted_chunks(rows, chunk):
        idx, nnz = pad_rows([rows[i] for i in sel], bucket=True)
        if packed:
            # bucket the ROW count too (ragged last chunk → next pow2,
            # nnz=0 filler rows) so every jit shape axis is bucketed
            n_pad = min(chunk, 1 << max(3, (len(sel) - 1).bit_length()))
            if n_pad > len(sel):
                idx = np.pad(idx, ((0, n_pad - len(sel)), (0, 0)))
                nnz = np.pad(nnz, (0, n_pad - len(sel)))
            dev = sch.encode_packed_device(idx, nnz, b,
                                           use_kernel=use_kernel)
        else:
            dev = sch.encode_device(idx, nnz, b, use_kernel=use_kernel)
        pending.append((sel, dev))
        if len(pending) >= depth:
            yield _materialize(*pending.popleft())
    while pending:
        yield _materialize(*pending.popleft())


def preprocess_rows(
    rows: Sequence[np.ndarray],
    k: int,
    b: int,
    *,
    scheme: str = "minwise",
    family: str = "multiply_shift",
    seed: int = 0,
    use_kernel: bool = True,
    chunk: int = 1024,
) -> np.ndarray:
    """Hashes rows → uint16 codes (n, k); in-memory compat path.

    ``scheme="minwise"`` is the paper's k-permutation pass (k hash
    evaluations per nonzero); ``scheme="oph"`` / ``"oph_zero"`` is one
    permutation hashing (ONE evaluation per nonzero).  ``family`` picks
    the exact offline families (mod_prime / permutation) for the
    minwise scheme only.  Prefer ``preprocess_rows_packed`` /
    ``preprocess_and_save`` for large corpora — they never materialize
    the full-width matrix.
    """
    out = np.empty((len(rows), k), dtype=np.uint16)
    for sel, codes, _ in _stream_encoded(
            rows, k, b, scheme=scheme, family=family, seed=seed,
            use_kernel=use_kernel, chunk=chunk, packed=False):
        out[sel] = codes
    return out


def preprocess_rows_packed(
    rows: Sequence[np.ndarray],
    k: int,
    b: int,
    *,
    scheme: str = "minwise",
    family: str = "multiply_shift",
    seed: int = 0,
    use_kernel: bool = True,
    chunk: int = 1024,
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Fused streaming encode → (packed uint8 (n, ceil(kb/8)),
    packed empty bitmask (n, ceil(k/8)) | None).

    Bit-identical to ``pack_codes(preprocess_rows(...), b)`` (and the
    shard writer's bytes), but the device emits the packed bytes
    directly — host↔device traffic per row is ceil(k·b/8) bytes, not
    k·2 (or the kernels' k·4 minima).
    """
    out = np.empty((len(rows), packed_width(k, b)), dtype=np.uint8)
    emp: Optional[np.ndarray] = None
    for sel, pk, em in _stream_encoded(
            rows, k, b, scheme=scheme, family=family, seed=seed,
            use_kernel=use_kernel, chunk=chunk, packed=True):
        out[sel] = pk
        if em is not None:
            if emp is None:
                emp = np.zeros((len(rows), (k + 7) // 8), dtype=np.uint8)
            emp[sel] = em
    return out, emp


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


class HashedShardWriter:
    """Streaming format-v3 shard writer: append packed chunks as they
    arrive, flush ``rows_per_shard``-row shards incrementally.

    Never holds more than one shard of rows — the writer is what lets
    ``preprocess_and_save`` run in O(shard) memory instead of
    materializing the (n, k) matrix the v2 writer packed at the end.
    """

    def __init__(
        self,
        root: str,
        k: int,
        b: int,
        *,
        n_total: int,
        scheme: str = "minwise",
        family: str = "multiply_shift",
        seed: int = 0,
        n_shards: int = 1,
    ):
        os.makedirs(root, exist_ok=True)
        self.root = root
        self.k, self.b = k, b
        self.scheme, self.family, self.seed = scheme, family, seed
        self.n_total = int(n_total)
        self.n_shards = n_shards
        self.rows_per_shard = max(1, -(-self.n_total // n_shards))
        self._codes, self._labels, self._rows, self._empty = [], [], [], []
        self._buffered = 0
        self._shard = 0
        self._closed = False
        self._checksums: List[dict] = []
        # None until the first append decides; every later append must
        # agree — an oph_zero stream that mixes empty=None and non-None
        # chunks would otherwise silently desync the per-shard
        # .empty.npy rows from the codes rows.
        self._has_empty: Optional[bool] = None

    def append(
        self,
        row_ids: np.ndarray,
        packed: np.ndarray,
        labels: np.ndarray,
        empty: Optional[np.ndarray] = None,
    ) -> None:
        row_ids = np.asarray(row_ids, dtype=np.int64)
        packed = np.ascontiguousarray(packed)
        labels = np.asarray(labels, dtype=np.int32)
        if not len(row_ids) == len(packed) == len(labels):
            raise ValueError(
                f"append row mismatch: {len(row_ids)} row_ids, "
                f"{len(packed)} code rows, {len(labels)} labels")
        has_empty = empty is not None
        if self._has_empty is not None and has_empty != self._has_empty:
            raise ValueError(
                "inconsistent empty mask: this writer has seen "
                f"empty={'arrays' if self._has_empty else 'None'} so far "
                f"but this append passes empty="
                f"{'an array' if has_empty else 'None'} — a shard's "
                ".empty.npy rows must stay in lockstep with its codes")
        if has_empty:
            empty = np.ascontiguousarray(empty)
            if len(empty) != len(row_ids):
                raise ValueError(
                    f"append row mismatch: {len(row_ids)} row_ids but "
                    f"{len(empty)} empty-mask rows")
        # commit the mode only after every validation passed — a failed
        # append must leave the writer reusable
        self._has_empty = has_empty
        if has_empty:
            self._empty.append(empty)
        self._codes.append(packed)
        self._labels.append(labels)
        self._rows.append(row_ids)
        self._buffered += len(row_ids)
        while self._buffered >= self.rows_per_shard:
            self._flush(self.rows_per_shard)

    def _take(self, parts, count):
        out, rest, got = [], [], 0
        for p in parts:
            if got >= count:
                rest.append(p)
            elif got + len(p) <= count:
                out.append(p)
                got += len(p)
            else:
                out.append(p[: count - got])
                rest.append(p[count - got:])
                got = count
        return np.concatenate(out) if out else None, rest

    def _flush(self, count: int) -> None:
        count = min(count, self._buffered)
        if count == 0:
            return
        base = os.path.join(self.root, f"hashed_{self._shard:05d}")
        codes, self._codes = self._take(self._codes, count)
        labels, self._labels = self._take(self._labels, count)
        rows, self._rows = self._take(self._rows, count)
        np.save(base + ".codes.npy", codes)
        np.save(base + ".labels.npy", labels)
        np.save(base + ".rows.npy", rows)
        crcs = {"codes": _crc(codes), "labels": _crc(labels),
                "rows": _crc(rows)}
        if self._has_empty:
            empty, self._empty = self._take(self._empty, count)
            np.save(base + ".empty.npy", empty)
            crcs["empty"] = _crc(empty)
        self._checksums.append(crcs)
        self._buffered -= count
        self._shard += 1

    def close(self, stats: Optional[dict] = None) -> dict:
        """Flushes the remainder and writes meta.json; returns meta."""
        if self._closed:
            raise RuntimeError("writer already closed")
        self._flush(self._buffered)
        self._closed = True
        meta = dict(format_version=FORMAT_VERSION, scheme=self.scheme,
                    k=self.k, b=self.b, family=self.family, seed=self.seed,
                    n=self.n_total, shards=self._shard,
                    packed_width=packed_width(self.k, self.b),
                    shard_checksums=self._checksums)
        if stats:
            meta.update(stats)
        with open(os.path.join(self.root, "meta.json"), "w") as f:
            json.dump(meta, f)
        return meta


def save_hashed(
    root: str,
    codes: np.ndarray,
    labels: np.ndarray,
    k: int,
    b: int,
    *,
    scheme: str = "minwise",
    family: str = "multiply_shift",
    seed: int = 0,
    n_shards: int = 1,
) -> None:
    """Version-2 bulk writer: an already-materialized (n, k) uint16 code
    matrix → round-robin ``.npz`` shards.  Kept for callers that hold
    full matrices; ``preprocess_and_save`` streams v3 shards instead.
    """
    os.makedirs(root, exist_ok=True)
    n = codes.shape[0]
    meta = dict(format_version=2, scheme=scheme, k=k, b=b,
                family=family, seed=seed, n=int(n), shards=n_shards)
    with open(os.path.join(root, "meta.json"), "w") as f:
        json.dump(meta, f)
    empty = codes == OPH_EMPTY_CODE if scheme == "oph_zero" else None
    if empty is not None:
        codes = np.where(empty, np.uint16(0), codes)
    for s in range(n_shards):
        # basic (strided) slicing — a view, unlike the O(rows) copy an
        # np.arange fancy index would make per shard
        arrays = dict(
            codes=pack_codes(codes[s::n_shards], b),
            labels=labels[s::n_shards].astype(np.int32),
        )
        if empty is not None:
            arrays["empty"] = np.packbits(empty[s::n_shards], axis=1)
        np.savez(os.path.join(root, f"hashed_{s:05d}.npz"), **arrays)


def _read_meta(root: str) -> dict:
    with open(os.path.join(root, "meta.json")) as f:
        meta = json.load(f)
    meta.setdefault("format_version", 1)
    meta.setdefault("scheme", "minwise")      # v1 archives predate OPH
    return meta


def _load_shard(
    root: str, meta: dict, s: int, mmap_mode: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One shard → (codes uint16 (rows, k), labels, original row ids).

    The widening twin of ``load_packed_shard`` — same bytes off disk
    (single source of truth for the shard layout), then host-side
    ``unpack_codes`` + the ``OPH_EMPTY_CODE`` sentinel."""
    k, b = meta["k"], meta["b"]
    packed, labels, rows, empty = load_packed_shard(
        root, s, meta=meta, mmap=mmap_mode is not None)
    codes = unpack_codes(np.asarray(packed), k, b)
    if empty is not None:
        mask = np.unpackbits(np.asarray(empty), axis=1,
                             count=k).astype(bool)
        codes = np.where(mask, OPH_EMPTY_CODE, codes)
    return codes, labels, rows


def iter_hashed(
    root: str,
    shard_ids: Optional[Sequence[int]] = None,
    *,
    mmap: bool = True,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yields (codes uint16 (rows, k), labels int32, original row ids)
    one shard at a time — evaluation over many shards without
    concatenating everything in RAM.

    For format-v3 archives the packed arrays are ``np.load``-ed with
    ``mmap_mode="r"`` (plain ``.npy`` members make this possible), so
    resident memory is one shard's *unpacked* codes regardless of
    dataset size.  v1/v2 ``.npz`` archives iterate per shard too (zip
    members can't mmap, but only one shard is ever decompressed).
    """
    meta = _read_meta(root)
    ids = range(meta["shards"]) if shard_ids is None else shard_ids
    mode = "r" if (mmap and meta["format_version"] >= 3) else None
    for s in ids:
        yield _load_shard(root, meta, s, mmap_mode=mode)


def load_packed_shard(
    root: str,
    s: int,
    *,
    meta: Optional[dict] = None,
    mmap: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """One shard WITHOUT widening: → (packed uint8 (rows, ceil(k·b/8)),
    labels int32, original row ids int64, packed empty bitmask | None).

    For format-v3 archives the packed arrays come back mmap'd
    (``mmap=True``), so touching a minibatch of rows faults in only
    those pages; the caller widens on the device with
    ``core.bbit.unpack_codes_jnp``.  v1/v2 ``.npz`` shards also store
    packed bytes — they decompress one shard but never unpack codes.
    """
    meta = _read_meta(root) if meta is None else meta
    if meta["format_version"] >= 3:
        mode = "r" if mmap else None

        def _open():
            if faults._ACTIVE is not None:
                faults.on_shard_read(root, s)
            base = os.path.join(root, f"hashed_{s:05d}")
            packed = np.load(base + ".codes.npy", mmap_mode=mode)
            labels = np.asarray(np.load(base + ".labels.npy",
                                        mmap_mode=mode))
            rows = np.asarray(np.load(base + ".rows.npy",
                                      mmap_mode=mode))
            epath = base + ".empty.npy"
            empty = (np.load(epath, mmap_mode=mode)
                     if os.path.exists(epath) else None)
            return packed, labels, rows, empty

        # bounded retry-with-backoff on transient I/O errors; a read
        # that keeps failing is recorded in ``quarantined_shards`` and
        # surfaces as ShardReadError with full context — never a
        # silent skip, never an unbounded hang.
        attempts = READ_RETRIES + 1
        for attempt in range(attempts):
            try:
                return _open()
            except FileNotFoundError:
                raise            # a missing shard file is not transient
            except OSError as e:
                last = e
                if attempt + 1 < attempts:
                    log.warning(
                        "transient error reading shard %d of %r "
                        "(attempt %d/%d): %s — retrying",
                        s, root, attempt + 1, attempts, e)
                    time.sleep(READ_BACKOFF.delay_s(attempt))
        quarantined_shards.setdefault(root, []).append(int(s))
        log.error(
            "shard %d of %r failed all %d read attempts — quarantined "
            "(run verify_shard to check recorded CRCs): %s",
            s, root, attempts, last)
        raise ShardReadError(
            f"shard {s} of {root!r} failed all {attempts} read "
            f"attempts: {last}", root=root, shard=int(s),
            attempts=attempts) from last
    z = np.load(os.path.join(root, f"hashed_{s:05d}.npz"))
    rows = np.arange(s, meta["n"], meta["shards"], dtype=np.int64)
    return (z["codes"], z["labels"], rows,
            z["empty"] if "empty" in z else None)


def verify_shard(root: str, s: int,
                 meta: Optional[dict] = None) -> Optional[dict]:
    """Recomputes shard ``s``'s file CRC32s against the ``meta.json``
    record (format v4+).  Returns the recomputed dict on success, None
    when the archive predates checksums (v3 and older), and raises
    ``ShardCorruptionError`` naming every mismatching file otherwise —
    the offline fsck behind the loud-quarantine story."""
    meta = _read_meta(root) if meta is None else meta
    recorded = meta.get("shard_checksums")
    if not recorded or s >= len(recorded):
        return None
    packed, labels, rows, empty = load_packed_shard(
        root, s, meta=meta, mmap=False)
    got = {"codes": _crc(packed), "labels": _crc(labels),
           "rows": _crc(rows)}
    if empty is not None:
        got["empty"] = _crc(empty)
    bad = [name for name, want in recorded[s].items()
           if got.get(name) != int(want)]
    if bad:
        quarantined_shards.setdefault(root, []).append(int(s))
        log.error("shard %d of %r is corrupt: CRC mismatch on %s",
                  s, root, bad)
        raise ShardCorruptionError(
            f"shard {s} of {root!r} is corrupt: CRC mismatch on "
            f"{bad} (recorded {recorded[s]}, recomputed {got})")
    return got


def iter_packed(
    root: str,
    shard_ids: Optional[Sequence[int]] = None,
    *,
    mmap: bool = True,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray,
                    Optional[np.ndarray]]]:
    """Yields ``load_packed_shard`` tuples one shard at a time."""
    meta = _read_meta(root)
    ids = range(meta["shards"]) if shard_ids is None else shard_ids
    for s in ids:
        yield load_packed_shard(root, s, meta=meta, mmap=mmap)


def iter_hashed_batches(
    root: str,
    batch_size: int,
    *,
    shard_ids: Optional[Sequence[int]] = None,
    perm_seed: Optional[int] = None,
    mmap: bool = True,
    drop_remainder: bool = False,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray,
                    Optional[np.ndarray]]]:
    """Minibatches of PACKED rows straight off the shards: yields
    (packed uint8 (B, ceil(k·b/8)), labels int32 (B,), original row
    ids int64 (B,), packed empty bitmask (B, ceil(k/8)) | None).

    The v3 shard arrays stay mmap'd; each batch fancy-indexes only its
    B rows, so resident memory is O(one batch + the touched pages of
    one shard) however large the archive — the iterator
    ``train.streaming.fit_streaming`` drives shard by shard.
    ``perm_seed`` (an int, or a tuple of ints such as the trainer's
    ``(seed, epoch)``) applies a deterministic within-shard row
    permutation — a pure function of (*perm_seed, shard id), so
    restarted consumers replay identical batches; the final partial
    batch of each shard is yielded, not dropped, unless
    ``drop_remainder``.
    """
    meta = _read_meta(root)
    ids = range(meta["shards"]) if shard_ids is None else shard_ids
    for s in ids:
        packed, labels, rows, empty = load_packed_shard(
            root, s, meta=meta, mmap=mmap)
        n = packed.shape[0]
        if n == 0:
            continue                  # empty shard: nothing to yield
        if batch_size > n:
            # silently yielding one short batch per shard hides a
            # misconfiguration: the caller asked for B-row minibatches
            # and would train on n-row ones instead
            raise ValueError(
                f"batch_size={batch_size} exceeds shard {s}'s {n} rows "
                f"({root!r}); use batch_size <= the smallest shard, or "
                "re-shard the archive with fewer shards")
        if perm_seed is None:
            order = np.arange(n)
        else:
            ent = (tuple(perm_seed) if isinstance(perm_seed, (tuple, list))
                   else (perm_seed,))
            order = np.random.default_rng(
                np.random.SeedSequence(ent + (int(s),))).permutation(n)
        stop = (n - batch_size + 1) if drop_remainder else n
        for lo in range(0, max(stop, 0), batch_size):
            sel = order[lo: lo + batch_size]
            yield (np.ascontiguousarray(packed[sel]), labels[sel],
                   rows[sel],
                   None if empty is None
                   else np.ascontiguousarray(empty[sel]))


def shard_row_counts(root: str) -> list:
    """Rows per shard, without loading shard data (v3: mmap'd shape
    reads; v1/v2: the round-robin formula)."""
    meta = _read_meta(root)
    if meta["format_version"] >= 3:
        return [int(np.load(os.path.join(root, f"hashed_{s:05d}.labels.npy"),
                            mmap_mode="r").shape[0])
                for s in range(meta["shards"])]
    return [len(range(s, meta["n"], meta["shards"]))
            for s in range(meta["shards"])]


def load_hashed(
    root: str, shard_ids: Optional[Sequence[int]] = None
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Returns (codes uint16 (n,k), labels int32 (n,), meta).

    Loading all shards restores the ORIGINAL row order (v3 shards carry
    explicit row ids; v1/v2 shards are round-robin row subsets);
    loading a subset returns shard order.  For ``oph_zero`` archives,
    empty bins carry ``OPH_EMPTY_CODE`` (split them back out with
    ``repro.core.oph.split_zero_codes``).  Prefer ``iter_hashed`` when
    the concatenated matrix would not fit in RAM.
    """
    meta = _read_meta(root)
    all_shards = shard_ids is None
    all_codes, all_labels, sels = [], [], []
    for codes, labels, rows in iter_hashed(root, shard_ids, mmap=False):
        all_codes.append(codes)
        all_labels.append(labels)
        sels.append(rows)
    if not all_codes:
        # 0-shard archive (or an empty shard_ids selection): a clear
        # empty result instead of np.concatenate's bare ValueError
        return (np.zeros((0, meta["k"]), np.uint16),
                np.zeros((0,), np.int32), meta)
    codes = np.concatenate(all_codes)
    labels = np.concatenate(all_labels)
    if all_shards:
        order = np.concatenate(sels)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        codes, labels = codes[inv], labels[inv]
    return codes, labels, meta


def preprocess_and_save(
    root: str,
    rows: Sequence[np.ndarray],
    labels: np.ndarray,
    k: int,
    b: int,
    **kw,
) -> dict:
    """End-to-end streaming preprocessing (Table-2 instrumentation).

    Fused encode (packed bytes off the device) → ``HashedShardWriter``;
    peak memory is O(pipeline depth · chunk + one shard), never the
    (n, k) matrix.  Timing covers hash+pack+write; ``seconds_hashing``
    and ``mnnz_per_s`` are recorded in meta.json so the preprocessing-
    throughput trajectory is tracked next to the data it produced.
    """
    scheme = kw.get("scheme", "minwise")
    family = kw.get("family", "multiply_shift")
    seed = kw.get("seed", 0)
    labels = np.asarray(labels)
    writer = HashedShardWriter(
        root, k, b, n_total=len(rows), scheme=scheme, family=family,
        seed=seed, n_shards=kw.get("n_shards", 1))
    total_nnz = int(sum(len(r) for r in rows))
    t0 = time.perf_counter()
    for sel, packed, empty in _stream_encoded(
            rows, k, b, scheme=scheme, family=family, seed=seed,
            use_kernel=kw.get("use_kernel", True),
            chunk=kw.get("chunk", 1024), packed=True):
        writer.append(sel, packed, labels[sel], empty)
    t_hash = time.perf_counter() - t0
    stats = dict(seconds_hashing=t_hash, total_nnz=total_nnz,
                 mnnz_per_s=total_nnz / max(t_hash, 1e-9) / 1e6)
    writer.close(stats)
    return dict(stats, n=len(rows), k=k, b=b, scheme=scheme)
