"""Offline preprocessing: raw sparse rows → b-bit hashed dataset on disk.

This is the paper's §6 pipeline as a production feature: a one-time
hashing pass (kernel- or numpy-backed) producing bit-packed shards that
are then *reused* across every training experiment (C sweeps, train/test
splits) — the exact economics the paper argues for.  Shard format:

  <root>/meta.json                 {k, b, family, seed, n, shards}
  <root>/hashed_00000.npz          codes: packed uint8 (rows, ceil(kb/8))
                                   labels: int32 (rows,)
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bbit import bbit_codes, pack_codes, unpack_codes
from repro.core.minhash import minhash_numpy
from repro.core.universal_hash import (
    MultiplyShiftHash, ModPrimeHash, make_hash_family,
)
from repro.data.packing import pad_rows


def preprocess_rows(
    rows: Sequence[np.ndarray],
    k: int,
    b: int,
    *,
    family: str = "multiply_shift",
    seed: int = 0,
    use_kernel: bool = True,
    chunk: int = 1024,
) -> np.ndarray:
    """Hashes rows → uint16 codes (n, k). Kernel path on the accelerator."""
    fam = make_hash_family(family, k, seed)
    out = np.empty((len(rows), k), dtype=np.uint16)
    # Length-sort so each chunk pads to its own max nnz — heavy-tailed
    # documents (the rcv1 expansion's lognormal lengths) otherwise force
    # every chunk to the global max.
    order = np.argsort([len(r) for r in rows], kind="stable")
    if family == "multiply_shift":
        import jax
        import jax.numpy as jnp
        from repro.core.minhash import minhash_jnp
        from repro.kernels import ops
        a, bb = fam.params()
        # On TPU the Pallas kernel is the fast path; on CPU, interpret
        # mode would crawl, so use the (equivalent, tested-equal)
        # double-chunked jnp implementation compiled by XLA.
        on_tpu = use_kernel and jax.default_backend() == "tpu"
        for lo in range(0, len(rows), chunk):
            sel = order[lo: lo + chunk]
            idx, nnz = pad_rows([rows[i] for i in sel])
            if on_tpu:
                codes = ops.minhash_bbit(
                    jnp.asarray(idx), jnp.asarray(nnz), a, bb, b)
            else:
                m = idx.shape[1]
                mask = jnp.arange(m, dtype=jnp.int32)[None, :] \
                    < jnp.asarray(nnz)[:, None]
                z = minhash_jnp(jnp.asarray(idx), mask, a, bb)
                codes = (z & jnp.uint32((1 << b) - 1)).astype(jnp.uint16)
            out[sel] = np.asarray(codes)
        return out
    # exact offline families (mod-prime / permutation) in numpy
    for lo in range(0, len(rows), chunk):
        sel = order[lo: lo + chunk]
        idx, nnz = pad_rows([rows[i] for i in sel], pad_to_multiple=1)
        mask = np.arange(idx.shape[1])[None, :] < nnz[:, None]
        z = minhash_numpy(idx, mask, fam)
        out[sel] = np.asarray(bbit_codes(z, b))
    return out


def save_hashed(
    root: str,
    codes: np.ndarray,
    labels: np.ndarray,
    k: int,
    b: int,
    *,
    family: str = "multiply_shift",
    seed: int = 0,
    n_shards: int = 1,
) -> None:
    os.makedirs(root, exist_ok=True)
    n = codes.shape[0]
    meta = dict(k=k, b=b, family=family, seed=seed, n=int(n),
                shards=n_shards)
    with open(os.path.join(root, "meta.json"), "w") as f:
        json.dump(meta, f)
    for s in range(n_shards):
        sel = np.arange(s, n, n_shards)
        np.savez(
            os.path.join(root, f"hashed_{s:05d}.npz"),
            codes=pack_codes(codes[sel], b),
            labels=labels[sel].astype(np.int32),
        )


def load_hashed(
    root: str, shard_ids: Optional[Sequence[int]] = None
) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Returns (codes uint16 (n,k), labels int32 (n,), meta).

    Loading all shards restores the ORIGINAL row order (shards are
    round-robin row subsets); loading a subset returns shard order.
    """
    with open(os.path.join(root, "meta.json")) as f:
        meta = json.load(f)
    all_shards = shard_ids is None
    ids = range(meta["shards"]) if all_shards else shard_ids
    all_codes, all_labels, sels = [], [], []
    for s in ids:
        z = np.load(os.path.join(root, f"hashed_{s:05d}.npz"))
        all_codes.append(unpack_codes(z["codes"], meta["k"], meta["b"]))
        all_labels.append(z["labels"])
        sels.append(np.arange(s, meta["n"], meta["shards"]))
    codes = np.concatenate(all_codes)
    labels = np.concatenate(all_labels)
    if all_shards:
        order = np.concatenate(sels)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        codes, labels = codes[inv], labels[inv]
    return codes, labels, meta


def preprocess_and_save(
    root: str,
    rows: Sequence[np.ndarray],
    labels: np.ndarray,
    k: int,
    b: int,
    **kw,
) -> dict:
    """End-to-end preprocessing with timing (Table-2 instrumentation)."""
    t0 = time.perf_counter()
    codes = preprocess_rows(rows, k, b, **{
        kk: v for kk, v in kw.items()
        if kk in ("family", "seed", "use_kernel", "chunk")})
    t_hash = time.perf_counter() - t0
    save_hashed(root, codes, labels, k, b,
                family=kw.get("family", "multiply_shift"),
                seed=kw.get("seed", 0),
                n_shards=kw.get("n_shards", 1))
    return dict(seconds_hashing=t_hash, n=len(rows), k=k, b=b)
