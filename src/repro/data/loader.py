"""Deterministic sharded data loader with restart skip and prefetch.

Production requirements served here (DESIGN.md §3):
  * host-sharded loading: worker (shard_id, num_shards) reads a disjoint
    row subset — the multi-host data-parallel input path;
  * deterministic global order: epoch shuffles are a pure function of
    (seed, epoch), so every host agrees without communication and a
    restarted job replays the exact same batches;
  * restart skip: ``start_step`` fast-forwards without touching data —
    checkpoint/resume yields bitwise-identical training (tested);
  * straggler hedging: ``backup_of`` lets a healthy worker double-read a
    slow worker's shard range (the classic backup-task mitigation);
  * background prefetch of the next batch (thread + queue).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


class HashedCodesLoader:
    """Iterates (codes uint16 (B,k), labels int32 (B,)) minibatches."""

    def __init__(
        self,
        codes: np.ndarray,
        labels: np.ndarray,
        batch_size: int,
        *,
        seed: int = 0,
        shard_id: int = 0,
        num_shards: int = 1,
        backup_of: Optional[int] = None,
        drop_remainder: bool = True,
    ):
        if codes.shape[0] != labels.shape[0]:
            raise ValueError("codes/labels row mismatch")
        self.codes = codes
        self.labels = labels
        self.batch_size = batch_size
        self.seed = seed
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.backup_of = backup_of
        self.drop_remainder = drop_remainder

    # -- deterministic order ------------------------------------------------
    def _epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, epoch)))
        order = rng.permutation(self.codes.shape[0])
        shards = [order[s:: self.num_shards] for s in range(self.num_shards)]
        mine = shards[self.shard_id]
        if self.backup_of is not None:
            # hedge: also cover the straggler's range (dedup at consumer)
            mine = np.concatenate([mine, shards[self.backup_of]])
        return mine

    def steps_per_epoch(self) -> int:
        n = self._epoch_order(0).shape[0]
        return n // self.batch_size if self.drop_remainder else (
            (n + self.batch_size - 1) // self.batch_size)

    def batches(
        self, start_step: int = 0, epochs: Optional[int] = None
    ) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
        """Yields (global_step, codes, labels) from ``start_step`` on."""
        spe = self.steps_per_epoch()
        step = start_step
        epoch = start_step // spe
        while epochs is None or epoch < epochs:
            order = self._epoch_order(epoch)
            local = step - epoch * spe
            for i in range(local, spe):
                sel = order[i * self.batch_size:(i + 1) * self.batch_size]
                yield step, self.codes[sel], self.labels[sel]
                step += 1
            epoch += 1

    def prefetching(self, *args, depth: int = 2, **kw):
        """Wraps ``batches`` with a background prefetch thread."""
        q: "queue.Queue" = queue.Queue(maxsize=depth)
        stop = object()

        def worker():
            try:
                for item in self.batches(*args, **kw):
                    q.put(item)
            finally:
                q.put(stop)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item


class SparseRowsLoader:
    """Same contract over raw padded sparse rows (pre-hashing path)."""

    def __init__(self, indices: np.ndarray, nnz: np.ndarray,
                 labels: np.ndarray, batch_size: int, *, seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1):
        self._inner = HashedCodesLoader(
            indices, labels, batch_size, seed=seed, shard_id=shard_id,
            num_shards=num_shards)
        self.nnz = nnz

    def batches(self, start_step: int = 0,
                epochs: Optional[int] = None):
        for step, idx, y in self._inner.batches(start_step, epochs):
            # recover row positions via the same order computation
            yield step, idx, self.nnz[
                self._row_ids(step)], y

    def _row_ids(self, step: int) -> np.ndarray:
        spe = self._inner.steps_per_epoch()
        epoch, local = divmod(step, spe)
        order = self._inner._epoch_order(epoch)
        bs = self._inner.batch_size
        return order[local * bs:(local + 1) * bs]
