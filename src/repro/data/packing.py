"""Padded-batch packing of variable-length sparse rows (TPU layout)."""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def bucket_width(m: int, floor: int = 128) -> int:
    """Round a pad width up to the next power of two (≥ ``floor``).

    Shape-bucketing for jit: length-sorted chunks otherwise produce a
    fresh pad width — and a fresh XLA compile — per chunk; bucketing
    bounds the number of distinct compiled shapes at O(log max_nnz).
    """
    m = max(int(m), max(floor, 1))
    return 1 << (m - 1).bit_length()


def pad_rows(
    rows: Sequence[np.ndarray],
    max_nnz: Optional[int] = None,
    pad_to_multiple: int = 128,
    clip: bool = True,
    bucket: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """rows → (indices int32 (n, m), nnz int32 (n,)); contiguous padding.

    Indices beyond 2^31-1 are folded into [0, 2^31) (the minhash kernel
    hashes them anyway, so folding only changes the pre-hash id space).
    ``bucket=True`` additionally rounds the pad width up to a power of
    two (see ``bucket_width``) so chunked callers compile O(log m) jit
    variants instead of one per chunk.
    """
    n = len(rows)
    lengths = np.asarray([len(r) for r in rows], dtype=np.int64)
    m = int(lengths.max(initial=1))
    if max_nnz is not None:
        m = min(m, max_nnz) if clip else max_nnz
    m = max(m, 1)
    if pad_to_multiple > 1:
        m = ((m + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple
    if bucket:
        m = bucket_width(m, floor=max(pad_to_multiple, 1))
    idx = np.zeros((n, m), dtype=np.int32)
    nnz = np.minimum(lengths, m).astype(np.int32)
    mask31 = np.int64((1 << 31) - 1)
    for i, r in enumerate(rows):
        k = int(nnz[i])
        idx[i, :k] = (np.asarray(r[:k], dtype=np.int64) & mask31).astype(
            np.int32)
    return idx, nnz


def batch_iterator(
    indices: np.ndarray,
    nnz: np.ndarray,
    labels: np.ndarray,
    batch_size: int,
    *,
    shuffle_seed: Optional[int] = None,
    drop_remainder: bool = True,
):
    """Yields (indices, nnz, labels) minibatches, optionally shuffled."""
    n = indices.shape[0]
    order = np.arange(n)
    if shuffle_seed is not None:
        np.random.default_rng(shuffle_seed).shuffle(order)
    stop = (n // batch_size) * batch_size if drop_remainder else n
    for lo in range(0, stop, batch_size):
        sel = order[lo: lo + batch_size]
        yield indices[sel], nnz[sel], labels[sel]
