"""Async shard/minibatch prefetch: the producer→queue→device pipeline.

PR 3's streaming trainer was strictly serial: every step waited on mmap
fault-in + host-side shuffle/slice before the device saw any work —
exactly the host-bound pattern the paper says must be hidden
("preprocessing/loading cost should be overlapped with or dominated by
compute", arXiv:1108.3072 §3; arXiv:1205.2958 §5 shows the online/VW
baseline is I/O-bound at scale).  This module moves ALL host-side batch
work off the training thread:

  producer thread                        consumer (train loop)
  ───────────────                        ─────────────────────
  walk the deterministic shard order ─┐
  mmap the shard, fault in its pages  │   bounded  ┌─ step(batch i)
  permute + slice the next minibatch  ├─▶ Queue   ─┤  step(batch i+1)
  jax transfer (device_put/asarray)  ─┘  (depth)   └─ drain hits / ckpt

The pieces:

  * ``shard_order`` — the epoch's shard permutation, a pure function of
    ``(seed, epoch)`` (moved here from ``train.streaming`` so producers
    and trainer share one definition);
  * ``serial_batch_stream`` / ``group_batch_stream`` — plain generators
    yielding ``StreamBatch`` / ``Boundary`` events for the
    single-device and data-parallel (shards grouped across devices)
    schedules.  The generator IS the serial path: running it inline
    (prefetch off) or through the thread (prefetch on) executes the
    same code on the same values;
  * ``ThreadedPrefetcher`` — wraps any event generator in a bounded
    daemon thread (``depth`` ≥ 1 items transferred ahead; depth 2 is
    classic double buffering).  Because the producer runs ``depth``
    items ahead, the NEXT shard's mmap pages start faulting in while
    the device is still training on the current shard's tail.

Determinism contract: prefetch changes WHEN host work happens, never
WHAT is produced — the event sequence (batch contents, row counts,
shard boundaries) is identical for any depth, including depth 0
(inline).  ``train.streaming.fit_streaming`` therefore produces
bit-identical parameters, progressive-validation counters and
checkpoints with prefetch on or off (tested), and a run checkpointed
under one depth resumes under any other.

Exceptions raised by the producer surface in the consumer at the point
of the failed event WITH the producer thread's original traceback; a
failure inside a shard's read/slice path is wrapped in
``ShardStreamError`` carrying (shard, epoch, position) context, so the
consumer learns exactly where the stream died.  Should the producer
thread die without managing to post its error sentinel, the consumer's
``next()`` detects the dead thread and raises instead of hanging on
the queue forever.  ``close()`` (also called when the consumer loop
exits early, e.g. ``stop_after_shards``) unblocks and joins the thread.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.data.hashed_dataset import iter_hashed_batches

__all__ = [
    "StreamBatch", "Boundary", "ShardStreamError", "shard_order",
    "serial_batch_stream", "group_batch_stream", "ThreadedPrefetcher",
]


class ShardStreamError(RuntimeError):
    """A shard's read/slice path failed inside a batch stream.

    Carries the (shard, epoch, position) the stream died at; the
    original failure is chained as ``__cause__`` with its full
    traceback (the batch streams raise via ``raise ... from``), so a
    consumer on the other side of a ``ThreadedPrefetcher`` sees both
    the where and the why.
    """

    def __init__(self, msg: str, *, shard: int, epoch: int,
                 position: int):
        super().__init__(msg)
        self.shard = shard
        self.epoch = epoch
        self.position = position


@dataclasses.dataclass
class StreamBatch:
    """One training step's worth of device-resident data.

    ``args`` is the positional tail of the train step call —
    ``(batch, labels)`` for the serial schedule, ``(batch, labels,
    valid)`` for the data-parallel one; ``n_rows`` counts the REAL
    examples inside (padding excluded) for progressive-validation
    bookkeeping.
    """
    args: tuple
    n_rows: int


@dataclasses.dataclass
class Boundary:
    """End of a shard (serial) or shard group (data-parallel): the
    trainer drains hit counters, advances ``shards_done`` by
    ``shards_consumed`` and may checkpoint at ``(next_epoch,
    next_pos)`` — the stream position a resumed run restarts from."""
    next_epoch: int
    next_pos: int
    shards_consumed: int


def shard_order(seed: int, epoch: int, n_shards: int,
                shuffle: bool) -> np.ndarray:
    """The epoch's shard visit order — a pure function of
    ``(seed, epoch)``, so a restarted run replays it exactly."""
    if not shuffle:
        return np.arange(n_shards)
    rng = np.random.default_rng(np.random.SeedSequence((seed, epoch)))
    return rng.permutation(n_shards)


def _mask_consistent(bem, has_empty: bool, shard: int, root: str) -> None:
    if (bem is None) == has_empty:
        raise ValueError(
            f"shard {shard} of {root!r} "
            f"{'lacks' if bem is None else 'carries'} an empty bitmask "
            f"while shard 0 {'has one' if has_empty else 'does not'} — "
            "archive written with desynced empty masks?")


def serial_batch_stream(
    root: str,
    batch_size: int,
    *,
    seed: int,
    epochs: int,
    n_shards: int,
    shuffle: bool,
    start_epoch: int,
    start_pos: int,
    has_empty: bool,
    transfer: Callable[..., tuple],
    mmap: bool = True,
) -> Iterator[Any]:
    """Single-device event stream: one shard at a time, minibatches in
    the deterministic ``(seed, epoch, shard)`` permutation.

    ``transfer(packed, empty|None, labels) -> (batch, labels)`` does
    the host→device move; it runs on whatever thread iterates this
    generator (the prefetch thread when wrapped, the train loop when
    inline) — same values either way.
    """
    for epoch in range(start_epoch, epochs):
        order = shard_order(seed, epoch, n_shards, shuffle)
        first = start_pos if epoch == start_epoch else 0
        for pos in range(first, n_shards):
            s = int(order[pos])
            try:
                for bp, bl, _rid, bem in iter_hashed_batches(
                        root, batch_size, shard_ids=[s],
                        perm_seed=(seed, epoch), mmap=mmap):
                    _mask_consistent(bem, has_empty, s, root)
                    yield StreamBatch(args=transfer(bp, bem, bl),
                                      n_rows=len(bl))
            except Exception as e:
                # GeneratorExit (consumer close) is BaseException —
                # deliberately not caught here
                raise ShardStreamError(
                    f"shard {s} failed at epoch {epoch} position {pos} "
                    f"of {root!r}: {e}", shard=s, epoch=epoch,
                    position=pos) from e
            next_epoch, next_pos = ((epoch, pos + 1)
                                    if pos + 1 < n_shards
                                    else (epoch + 1, 0))
            yield Boundary(next_epoch, next_pos, 1)


def group_batch_stream(
    root: str,
    batch_size: int,
    *,
    seed: int,
    epochs: int,
    n_shards: int,
    counts: Sequence[int],
    world: int,
    shuffle: bool,
    start_epoch: int,
    start_pos: int,
    has_empty: bool,
    packed_width: int,
    mask_width: int,
    transfer: Callable[..., tuple],
    mmap: bool = True,
    slot_range: "Optional[Tuple[int, int]]" = None,
) -> Iterator[Any]:
    """Data-parallel event stream: consecutive GROUPS of ``world``
    shards from the epoch order, one shard per device, in lockstep.

    Per global step, device d's next minibatch from its shard is
    stacked into row d of fixed-shape ``(world, B, …)`` arrays (fixed
    shapes → one jit trace for the whole run).  Shards in a group can
    hold different batch counts (uneven rows, short final group): a
    device whose shard is exhausted — or that got no shard at all —
    contributes an all-padding batch with ``valid`` all-False, so it
    keeps participating in every collective (an absent device would
    hang the all-reduce) while adding exactly zero gradient, zero
    hits and zero rows.  Per-shard batch contents equal the serial
    schedule's (same ``iter_hashed_batches`` permutation contract).

    ``slot_range=(lo, hi)`` is the multi-process ownership window
    (``distributed.runtime.process_slot_range``): this process reads
    ONLY the shards occupying slots [lo, hi) of each group and the
    stacked arrays carry just those ``hi - lo`` rows — the caller
    assembles the global batch from every process's block
    (``jax.make_array_from_process_local_data``).  Everything
    schedule-shaped stays GLOBAL regardless: ``n_rows`` counts the
    real examples across ALL slots (computed from ``counts``, no
    remote reads — the progressive-validation denominator must agree
    on every rank), the step count per group is the global
    ``max ceil(rows/B)``, and ``Boundary.shards_consumed`` is the full
    group size.

    ``start_pos`` must sit on a group boundary (a multiple of
    ``world``) — which is the only place the trainer checkpoints.
    """
    slot_lo, slot_hi = (0, world) if slot_range is None else slot_range
    if not (0 <= slot_lo < slot_hi <= world):
        raise ValueError(
            f"slot_range {slot_range} outside the [0, {world}) slots")
    if start_pos % world != 0 and start_pos < n_shards:
        raise ValueError(
            f"data-parallel resume position {start_pos} is not a "
            f"multiple of the world size {world} — checkpoint written "
            "under a different schedule?")
    local = slot_hi - slot_lo
    for epoch in range(start_epoch, epochs):
        order = shard_order(seed, epoch, n_shards, shuffle)
        first = start_pos if epoch == start_epoch else 0
        for lo in range(first, n_shards, world):
            group = [int(s) for s in order[lo: lo + world]]
            iters = {d: iter_hashed_batches(
                root, batch_size, shard_ids=[group[d]],
                perm_seed=(seed, epoch), mmap=mmap)
                for d in range(len(group)) if slot_lo <= d < slot_hi}
            n_batches = [-(-counts[s] // batch_size) for s in group]
            for t in range(max(n_batches)):
                codes = np.zeros((local, batch_size, packed_width),
                                 np.uint8)
                empty = (np.zeros((local, batch_size, mask_width),
                                  np.uint8) if has_empty else None)
                labels = np.zeros((local, batch_size), np.int32)
                valid = np.zeros((local, batch_size), bool)
                for d, it in iters.items():
                    if t >= n_batches[d]:
                        continue
                    try:
                        bp, bl, _rid, bem = next(it)
                        _mask_consistent(bem, has_empty, group[d], root)
                    except StopIteration as e:
                        raise RuntimeError(
                            f"shard {group[d]} yielded fewer batches "
                            f"than its row count promised") from e
                    except Exception as e:
                        raise ShardStreamError(
                            f"shard {group[d]} (device slot {d}) failed "
                            f"at epoch {epoch} position {lo + d} of "
                            f"{root!r}: {e}", shard=group[d],
                            epoch=epoch, position=lo + d) from e
                    m = len(bl)
                    codes[d - slot_lo, :m] = bp
                    labels[d - slot_lo, :m] = bl
                    valid[d - slot_lo, :m] = True
                    if has_empty:
                        empty[d - slot_lo, :m] = bem
                # the GLOBAL real-row count for this step — a pure
                # function of the row counts, so every process agrees
                # without reading each other's shards
                n_rows = sum(
                    min(batch_size, counts[group[d]] - t * batch_size)
                    for d in range(len(group)) if t < n_batches[d])
                yield StreamBatch(
                    args=transfer(codes, empty, labels, valid),
                    n_rows=n_rows)
            next_epoch, next_pos = ((epoch, lo + world)
                                    if lo + world < n_shards
                                    else (epoch + 1, 0))
            yield Boundary(next_epoch, next_pos, len(group))


class ThreadedPrefetcher:
    """Runs an event generator in a bounded background (daemon) thread.

    Up to ``depth`` produced items wait in the queue while the consumer
    trains — host slicing, page fault-in and the jax transfer for step
    i+1…i+depth overlap with step i's device compute.  Iteration
    yields exactly the wrapped generator's items in order; a producer
    exception re-raises at the corresponding point in the consumer.

    Always ``close()`` when abandoning the stream early (the trainer
    does this in a ``finally``): it unblocks a producer stuck on a full
    queue and joins the thread.  Exhausting the stream normally needs
    no cleanup but ``close()`` is idempotent and cheap.
    """

    def __init__(self, gen: Iterator[Any], depth: int = 2):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._done = False
        self._thread = threading.Thread(
            target=self._produce, args=(gen,), daemon=True,
            name="shard-prefetch")
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self, gen) -> None:
        try:
            for item in gen:
                if not self._put(("item", item)):
                    return
            self._put(("done", None))
        except BaseException as e:  # noqa: BLE001 — relayed to consumer
            self._put(("error", e))

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        while True:
            try:
                kind, val = self._q.get(timeout=0.25)
                break
            except queue.Empty:
                # the sentinel protocol means a live producer ALWAYS
                # eventually posts; a dead thread with an empty queue
                # means it was killed before its error/done sentinel
                # could land (e.g. interpreter teardown) — surface
                # that instead of blocking forever
                if not self._thread.is_alive():
                    self._done = True
                    raise RuntimeError(
                        "prefetch producer thread died without "
                        "delivering an event or error sentinel — "
                        "the stream is lost") from None
        if kind == "item":
            return val
        self._done = True
        if kind == "error":
            # re-raise the producer's exception with its original
            # traceback (it travelled on the exception object)
            raise val
        raise StopIteration

    def close(self) -> None:
        # mark exhausted FIRST: a next() issued after (or racing) close
        # must raise StopIteration, not block forever on a queue whose
        # done/error sentinel is being drained away below
        self._done = True
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        # wake a consumer that entered get() just before close
        try:
            self._q.put_nowait(("done", None))
        except queue.Full:
            pass
        self._thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
