"""Data pipeline: synthetic rcv1 expansion, LibSVM IO, hashing, loaders."""
from repro.data.synth_rcv1 import SynthRcv1Config, generate, generate_arrays
from repro.data.libsvm_io import (
    write_libsvm, read_libsvm, write_shards, read_shards, shard_paths,
)
from repro.data.packing import pad_rows, batch_iterator
from repro.data.hashed_dataset import (
    preprocess_rows, save_hashed, load_hashed, preprocess_and_save,
)
from repro.data.loader import HashedCodesLoader, SparseRowsLoader
from repro.data.lm_synth import token_batch, lm_example_stream

__all__ = [
    "SynthRcv1Config", "generate", "generate_arrays",
    "write_libsvm", "read_libsvm", "write_shards", "read_shards",
    "shard_paths", "pad_rows", "batch_iterator",
    "preprocess_rows", "save_hashed", "load_hashed", "preprocess_and_save",
    "HashedCodesLoader", "SparseRowsLoader",
    "token_batch", "lm_example_stream",
]
