"""Data pipeline: synthetic rcv1 expansion, LibSVM IO, hashing, loaders."""
from repro.data.synth_rcv1 import SynthRcv1Config, generate, generate_arrays
from repro.data.libsvm_io import (
    write_libsvm, read_libsvm, write_shards, read_shards, shard_paths,
)
from repro.data.packing import pad_rows, batch_iterator, bucket_width
from repro.data.hashed_dataset import (
    preprocess_rows, preprocess_rows_packed, save_hashed, load_hashed,
    iter_hashed, iter_packed, iter_hashed_batches, load_packed_shard,
    shard_row_counts, preprocess_and_save, verify_shard,
    HashedShardWriter, ShardCorruptionError, ShardReadError,
)
from repro.data.prefetch import (
    StreamBatch, Boundary, ShardStreamError, shard_order,
    serial_batch_stream, group_batch_stream, ThreadedPrefetcher,
)
from repro.data.loader import HashedCodesLoader, SparseRowsLoader
from repro.data.lm_synth import token_batch, lm_example_stream

__all__ = [
    "SynthRcv1Config", "generate", "generate_arrays",
    "write_libsvm", "read_libsvm", "write_shards", "read_shards",
    "shard_paths", "pad_rows", "batch_iterator", "bucket_width",
    "preprocess_rows", "preprocess_rows_packed", "save_hashed",
    "load_hashed", "iter_hashed", "iter_packed", "iter_hashed_batches",
    "load_packed_shard", "shard_row_counts", "preprocess_and_save",
    "verify_shard", "HashedShardWriter", "ShardCorruptionError",
    "ShardReadError",
    "StreamBatch", "Boundary", "ShardStreamError", "shard_order",
    "serial_batch_stream", "group_batch_stream", "ThreadedPrefetcher",
    "HashedCodesLoader", "SparseRowsLoader",
    "token_batch", "lm_example_stream",
]
