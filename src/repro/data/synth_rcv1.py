"""Synthetic regeneration of the paper's *expanded rcv1* construction.

The paper builds its 200 GB dataset as: original rcv1 features
+ ALL pairwise feature combinations + 1/30 of 3-way combinations
(paper §1, §4), giving n = 677,399 examples with D ≈ 1.01e9 and a
heavy-tailed nonzero count (median 3,051 / mean 12,062 — Table 1).

We regenerate that construction at configurable scale from synthetic
class-structured documents, preserving every property the paper's
claims depend on:

  * sparse binary features over a huge ambient D (indices hashed into
    2^30, mirroring rcv1-expanded's 1e9),
  * the unigram → +pairs → +1/30-of-triples expansion,
  * heavy-tailed document lengths (lognormal),
  * classes separable through set resemblance (documents of a class
    share topic tokens, so within-class resemblance > between-class).

Generation is deterministic given the seed and streams in chunks — no
materialized 200 GB required (though ``libsvm_io.write_shards`` can
write any amount to disk for the Table-2 loading benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

AMBIENT_DIM = 1 << 30  # expanded ids are hashed into [0, 2^30)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — deterministic id hashing for combos."""
    x = np.asarray(x, dtype=np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class SynthRcv1Config:
    n_classes: int = 2
    vocab: int = 20_000          # unigram feature space
    topic_tokens: int = 400      # class-defining tokens per class
    doc_len_log_mean: float = 3.6
    doc_len_log_sigma: float = 0.7   # lognormal → heavy-tailed lengths
    background_frac: float = 0.45    # tokens drawn from shared background
    pair_expansion: bool = True
    triple_expansion: bool = True
    triple_keep_denominator: int = 30  # paper: 1/30 of 3-way combos
    max_pairs_per_doc: int = 60_000
    max_triples_per_doc: int = 20_000
    seed: int = 0

    @property
    def dim(self) -> int:
        return AMBIENT_DIM


def _expand_doc(tokens: np.ndarray, cfg: SynthRcv1Config) -> np.ndarray:
    """unigrams + all pairs + 1/30 of triples, hashed into [0, 2^30)."""
    toks = np.unique(tokens.astype(np.uint64))
    feats = [toks]  # unigram ids occupy [0, vocab)

    if cfg.pair_expansion and len(toks) >= 2:
        i, j = np.triu_indices(len(toks), k=1)
        if len(i) > cfg.max_pairs_per_doc:
            keep = np.linspace(0, len(i) - 1, cfg.max_pairs_per_doc
                               ).astype(np.int64)
            i, j = i[keep], j[keep]
        pair_key = _mix64(toks[i] * np.uint64(1_000_003) + toks[j])
        pair_ids = (pair_key % np.uint64(AMBIENT_DIM - cfg.vocab)
                    ) + np.uint64(cfg.vocab)
        feats.append(pair_ids)

    if cfg.triple_expansion and len(toks) >= 3:
        # deterministic 1/30 subsample of all C(f,3) triples via hashing
        i, j = np.triu_indices(len(toks), k=1)
        if len(i) > cfg.max_triples_per_doc:
            keep = np.linspace(0, len(i) - 1, cfg.max_triples_per_doc
                               ).astype(np.int64)
            i, j = i[keep], j[keep]
        # pair each (i,j) with a third token chosen by rolling index — a
        # deterministic triple cover; keep iff hash % denominator == 0.
        third = toks[(i + j) % len(toks)]
        tri_key = _mix64(_mix64(toks[i] * np.uint64(7_368_787) + toks[j])
                         ^ third)
        keep = (tri_key % np.uint64(cfg.triple_keep_denominator)) == 0
        tri_ids = (tri_key[keep] % np.uint64(AMBIENT_DIM - cfg.vocab)
                   ) + np.uint64(cfg.vocab)
        feats.append(tri_ids)

    out = np.unique(np.concatenate(feats)).astype(np.int64)
    return out


def generate(
    n: int, cfg: SynthRcv1Config
) -> Iterator[Tuple[np.ndarray, int]]:
    """Yields (sorted nonzero indices int64, label) for n documents."""
    rng = np.random.default_rng(np.random.SeedSequence(cfg.seed))
    # class topic distributions: each class has its own token pool with
    # zipf-ish weights + a shared background pool.
    topics = [
        rng.choice(cfg.vocab, size=cfg.topic_tokens, replace=False)
        for _ in range(cfg.n_classes)
    ]
    zipf_w = 1.0 / np.arange(1, cfg.topic_tokens + 1) ** 0.9
    zipf_w /= zipf_w.sum()

    for _ in range(n):
        label = int(rng.integers(cfg.n_classes))
        length = max(8, int(rng.lognormal(cfg.doc_len_log_mean,
                                          cfg.doc_len_log_sigma)))
        n_bg = int(length * cfg.background_frac)
        n_topic = length - n_bg
        topic_toks = rng.choice(topics[label], size=n_topic, p=zipf_w)
        bg_toks = rng.integers(0, cfg.vocab, size=n_bg)
        tokens = np.concatenate([topic_toks, bg_toks])
        yield _expand_doc(tokens, cfg), label


def generate_arrays(
    n: int, cfg: SynthRcv1Config
) -> Tuple[List[np.ndarray], np.ndarray]:
    """Materializes n docs: (list of index arrays, labels int32 (n,))."""
    rows, labels = [], []
    for idx, y in generate(n, cfg):
        rows.append(idx)
        labels.append(y)
    return rows, np.asarray(labels, dtype=np.int32)
