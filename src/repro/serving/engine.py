"""Serving engines.

``HashedClassifierEngine`` — the paper's inference path as a service.
The headline claim (30 hashed values/point matching VW at 2^14,
arXiv:1108.3072) is ultimately an inference-cost argument: tiny codes
mean tiny per-request compute, IF the serving path doesn't squander it
on host round-trips and padding.  This engine serves raw sparse
documents through ONE fused device dispatch per micro-batch:

  raw idx/nnz ─▶ scheme.encode_packed_jit (hash → b-bit → pack; Pallas
  kernel on TPU, XLA elsewhere — ``perf.choose`` via
  ``ops.fused_encode_on_device``)
  ─▶ bbit_scores_packed (packed-input logits kernels) ─▶ scores

so on the kernel path no ``(B, k)`` int32 code matrix ever
materializes — codes travel packed (ceil(k·b/8) bytes/row) and unpack
in-register, exactly like the PR-4 training step.  Scores are
bit-identical to the reference ``encode_jnp`` + ``bbit_logits``
two-step (``fused=False`` keeps that path selectable for A/B benches).

Batching architecture (see ``serving.batcher.BucketBatcher``):

  * LANE ROUTING — ``submit`` validates the doc and routes it to an
    ``nnz``-bucket lane (pow-2-ish widths, growing past the largest
    bucket), so one giant document never inflates a whole batch's
    padding; drained batches pad rows to a pow-2 row bucket.
  * PRECOMPILE — every (row_bucket × nnz_bucket × replica) score
    function is compiled at engine startup, so steady-state serving
    never hits a first-request compile spike (``compile_misses`` counts
    any stray shape that does recompile, e.g. an over-bucket giant doc
    or a direct ``score_docs`` batch larger than ``max_batch``).
  * OVERLAP — the drain thread pads batch N+1 while the device runs
    batch N (async dispatch); a resolver thread owns the blocking
    device→host sync and future resolution.
  * REPLICAS — ``replicas=N`` device_puts the params once per device
    of a 1-D ``launch.mesh.make_replica_mesh`` mesh and round-robins
    micro-batches across them (no collectives; independent throughput
    scaling).

Input contract: docs are 1-D non-negative integer id arrays.  Empty
docs (nnz=0) are scheme-dependent: zero-coded OPH (``oph_zero``) scores
them through its all-empty-bins path (score = bias); schemes without
empty semantics (``minwise``, densified ``oph``) reject them at
``submit`` — their hash of an empty set is undefined sentinel garbage.

Operability (the network tier's substrate — see ``serving.server``):

  * VERSIONED WEIGHTS — the live params are one immutable
    ``serving.reload.WeightSet`` (version + per-replica device
    handles); ``swap_weights`` publishes a new set with a single
    reference swap, and a micro-batch dispatch reads the reference
    exactly once, so every score is computed against exactly one
    version — echoed on the result (``result.version``).
  * STATS — ``submit`` feeds a ``serving.stats.StatsWindow`` (rolling
    latency/rows/tenant window); ``stats()`` is the thread-safe
    snapshot behind ``GET /status``: p50/p95/p99, rows/s, per-lane
    occupancy, ``compile_misses``, per-tenant counts, batcher health.
  * ADAPTIVE BUCKETS — with ``adapt_every=N``, every N submits the
    engine re-derives the nnz lane grid from the batcher's observed
    size histogram (``adapt_buckets()``), precompiles any new shapes
    on a background thread, then swaps the grid — a skewed workload
    converges to tighter padding than the static config grid without
    a restart (traffic during the swap routes on whichever grid it
    caught; both are precompiled).

``greedy_generate`` — reference LM decode loop over any ModelAPI
(prefill + KV-cache decode), used by the serving example and tests.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import perf
from repro.core.schemes import make_scheme
from repro.data.packing import bucket_width, pad_rows
from repro.launch.mesh import make_replica_mesh
from repro.models.linear import (BBitLinearConfig, bbit_scores,
                                 bbit_scores_packed)
from repro.serving.batcher import BucketBatcher
from repro.serving.reload import WeightSet
from repro.serving.stats import StatsWindow

DEFAULT_NNZ_BUCKETS = (128, 512, 2048, 8192, 32768)


class VersionedScore(float):
    """A score that knows which model version produced it — a plain
    ``float`` everywhere (math, JSON, numpy) plus ``.version``."""
    __slots__ = ("version",)

    def __new__(cls, value, version: str):
        obj = super().__new__(cls, value)
        obj.version = version
        return obj


class VersionedVector(np.ndarray):
    """Multiclass twin of ``VersionedScore``: an ndarray row of scores
    carrying ``.version``."""

    def __new__(cls, arr, version: str):
        obj = np.asarray(arr).view(cls)
        obj.version = version
        return obj

    def __array_finalize__(self, obj):
        if obj is not None:
            self.version = getattr(obj, "version", None)


def _grow_bucket(n: int, buckets: Sequence[int]) -> int:
    """Pad width for an nnz of ``n``: the smallest fixed bucket that
    fits, growing by powers of two past the largest one.  Clamping to
    ``buckets[-1]`` instead would hand the scorer an ``idx`` wider than
    its ``nnz`` mask and corrupt giant-document scores."""
    for b in buckets:
        if n <= b:
            return b
    return bucket_width(n, floor=buckets[-1])


class HashedClassifierEngine:
    def __init__(self, params, cfg: BBitLinearConfig, seed: int = 0,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 scheme: str = "minwise", *,
                 fused: bool = True,
                 replicas: int = 1,
                 nnz_buckets: Sequence[int] = DEFAULT_NNZ_BUCKETS,
                 row_buckets: Optional[Sequence[int]] = None,
                 precompile: bool = True,
                 pipeline_depth: int = 2,
                 stats_window: int = 2048,
                 adapt_every: int = 0,
                 version: str = "v0",
                 dedup_cache: bool = False,
                 dedup_entries: int = 4096,
                 dedup_rows_per_band: int = 4,
                 dedup_probe_bands: int = 4):
        self.cfg = cfg
        self.scheme = make_scheme(scheme, cfg.k, seed)
        self.family = getattr(self.scheme, "family", None)
        self.fused = fused
        # duplicate-traffic short-circuit: band-signature probe + exact
        # packed-code guard, sitting after (host-side) encode and before
        # device dispatch — see serving/dedup.py for the contract
        self.dedup: Optional["DedupCache"] = None
        if dedup_cache:
            from repro.retrieval.bands import band_geometry
            from repro.serving.dedup import DedupCache
            band_geometry(cfg.k, cfg.b, dedup_rows_per_band)
            self.dedup = DedupCache(max_entries=dedup_entries,
                                    version=version)
            self._dedup_rows_per_band = int(dedup_rows_per_band)
            self._dedup_probe_bands = int(dedup_probe_bands)
        # zero-coded schemes give an empty doc exact semantics (every
        # bin empty → contributions masked out → score == bias)
        self._allows_empty = getattr(self.scheme, "densify", True) is False
        self.nnz_buckets = tuple(sorted(int(b) for b in nnz_buckets))
        if not self.nnz_buckets:
            raise ValueError("need at least one nnz bucket")
        # per-nnz-lane row buckets + drain caps from the measured
        # serve_score cost curve (perf profile); without one — or with
        # explicit row_buckets — the static pow-2 grid applies to every
        # lane, exactly the pre-cost-model behavior
        self._lane_row_buckets: Dict[int, Tuple[int, ...]] = {}
        self._lane_caps: Dict[int, int] = {}
        if row_buckets is None:
            top = bucket_width(max_batch, floor=1)
            row_buckets = tuple(1 << i for i in range(top.bit_length()))
            suggestion = perf.suggest_row_buckets(
                cfg.k, cfg.b, scheme, max_batch, self.nnz_buckets)
            if suggestion:
                self._lane_row_buckets = {
                    int(m): tuple(sorted(int(r) for r in rb))
                    for m, rb in suggestion.items()}
                row_buckets = tuple(sorted(
                    {r for rb in self._lane_row_buckets.values()
                     for r in rb}))
            caps = perf.suggest_lane_caps(
                cfg.k, cfg.b, scheme, max_batch, self.nnz_buckets)
            if caps:
                self._lane_caps = {int(m): int(c)
                                   for m, c in caps.items()}
        self.row_buckets = tuple(sorted(int(r) for r in row_buckets))

        self.mesh = make_replica_mesh(replicas)
        self.devices = list(self.mesh.devices.flat)
        # params replicated ONCE per version — each micro-batch reuses
        # its replica's resident copy, no per-request weight traffic;
        # the WeightSet is swapped atomically by ``swap_weights``
        self._weights = WeightSet(
            version=version,
            params=tuple(jax.device_put(params, d)
                         for d in self.devices),
            created_at=time.time())
        self.reloads = 0
        self._swap_lock = threading.Lock()
        self._rr = 0
        self._rr_lock = threading.Lock()
        self.device_batches = [0] * len(self.devices)
        self.stats_window = StatsWindow(stats_window)
        self.adapt_every = int(adapt_every)
        self.rebuckets = 0
        self._submits = 0
        self._adapting = threading.Event()
        self._started_at = time.time()

        scheme_obj, lcfg = self.scheme, cfg

        @jax.jit
        def _score_fused(idx, nnz, params):
            packed, empty = scheme_obj.encode_packed_jit(idx, nnz, lcfg.b)
            return bbit_scores_packed(params, packed, lcfg,
                                      empty_packed=empty)

        @jax.jit
        def _score_reference(idx, nnz, params):
            mask = (jnp.arange(idx.shape[1], dtype=jnp.int32)[None, :]
                    < nnz[:, None])
            codes, empty = scheme_obj.encode_jnp(idx, mask, lcfg.b)
            return bbit_scores(params, codes, lcfg, empty=empty)

        self._score_fused = _score_fused
        self._score_reference = _score_reference
        self._score_fn = _score_fused if fused else _score_reference

        self._compiled: set = set()
        self.compile_misses = 0
        self.precompile_seconds = 0.0
        if precompile:
            self._precompile()

        self.batcher = BucketBatcher(
            self._dispatch_batch, self._resolve_batch,
            route=lambda doc: self._nnz_bucket(len(doc)),
            max_batch=max_batch, max_wait_ms=max_wait_ms,
            depth=pipeline_depth, lane_caps=self._lane_caps)

    # ---------------------------------------------------------- buckets --
    def _nnz_bucket(self, n: int) -> int:
        return _grow_bucket(n, self.nnz_buckets)

    def _row_buckets_for(self, key: Optional[int]) -> Tuple[int, ...]:
        if key is not None:
            lane = self._lane_row_buckets.get(int(key))
            if lane:
                return lane
        return self.row_buckets

    def _row_bucket(self, n: int, key: Optional[int] = None) -> int:
        buckets = self._row_buckets_for(key)
        for r in buckets:
            if n <= r:
                return r
        return bucket_width(n, floor=buckets[-1])

    def _precompile(self) -> None:
        """Compile every (row_bucket, nnz_bucket, replica) lane shape up
        front — steady-state traffic then never pays a compile spike."""
        t0 = time.perf_counter()
        self._precompile_grid(self.nnz_buckets, self.row_buckets)
        self.precompile_seconds = time.perf_counter() - t0

    def _precompile_grid(self, nnz_buckets: Sequence[int],
                         row_buckets: Sequence[int]) -> None:
        """Compile any not-yet-seen shapes of a lane grid (jit caches
        by shape, so a weight swap never re-pays this)."""
        w = self._weights
        for d, dev in enumerate(self.devices):
            for m in nnz_buckets:
                idx = jax.device_put(np.zeros((1, m), np.int32), dev)
                nnz = jax.device_put(np.ones((1,), np.int32), dev)
                lane_rows = self._lane_row_buckets.get(int(m))
                for r in (lane_rows if lane_rows else row_buckets):
                    if (r, m, d) in self._compiled:
                        continue
                    ib = jnp.broadcast_to(idx, (r, m))
                    zb = jnp.broadcast_to(nnz, (r,))
                    self._score_fn(ib, zb, w.params[d]) \
                        .block_until_ready()
                    self._compiled.add((r, m, d))

    # ----------------------------------------------------------- scoring --
    def _validate(self, doc, *, check_neg: bool = True) -> np.ndarray:
        if (type(doc) is np.ndarray and doc.dtype == np.int64
                and doc.ndim == 1):
            # already the canonical dtype/shape: skip the generic
            # asarray/issubdtype machinery (measurable at batch rates)
            if check_neg and doc.size and int(doc.min()) < 0:
                raise ValueError("doc has negative feature indices")
            if doc.size == 0 and not self._allows_empty:
                raise ValueError(
                    f"empty document: scheme {self.scheme.name!r} has "
                    "no empty semantics (its min over zero hashes is "
                    "sentinel garbage) — reject upstream or serve with "
                    "the zero-coded 'oph_zero' scheme, whose "
                    "all-empty-bins path scores it as the bias")
            return doc
        arr = np.asarray(doc)
        if arr.ndim != 1 or not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(
                f"doc must be a 1-D integer id array, got shape "
                f"{arr.shape} dtype {arr.dtype}")
        # check_neg=False defers the negativity reduce to the caller's
        # ONE fused pass over the batch concat (submit_many) — a
        # per-row .min() is numpy fixed overhead at batch rates
        if check_neg and arr.size and int(arr.min()) < 0:
            raise ValueError("doc has negative feature indices")
        if arr.size == 0 and not self._allows_empty:
            raise ValueError(
                f"empty document: scheme {self.scheme.name!r} has no "
                "empty semantics (its min over zero hashes is sentinel "
                "garbage) — reject upstream or serve with the "
                "zero-coded 'oph_zero' scheme, whose all-empty-bins "
                "path scores it as the bias")
        return arr.astype(np.int64, copy=False)

    def _next_device(self) -> int:
        with self._rr_lock:
            d = self._rr % len(self.devices)
            self._rr += 1
        return d

    def _dispatch_batch(self, key: int, docs: List[np.ndarray],
                        device_index: Optional[int] = None,
                        weights: Optional[WeightSet] = None) -> Tuple:
        """Pad ``docs`` to the (row_bucket, key) lane shape and issue
        the fused scorer asynchronously (runs on the drain thread; the
        blocking sync happens in ``_resolve_batch``).  Reads the live
        ``WeightSet`` reference exactly ONCE, so the whole batch scores
        against one version even if a reload lands mid-flight."""
        w = self._weights if weights is None else weights
        n = len(docs)
        rows = self._row_bucket(n, key)
        # pad_rows owns the id-folding policy (indices ≥ 2^31 fold to
        # [0, 2^31), same as training-side preprocessing) — only the
        # row/width padding to the lane's bucket shape happens here
        packed_idx, packed_nnz = pad_rows(docs, pad_to_multiple=1)
        idx = np.zeros((rows, key), np.int32)
        nnz = np.zeros((rows,), np.int32)
        idx[:n, :packed_idx.shape[1]] = packed_idx
        nnz[:n] = packed_nnz
        d = self._next_device() if device_index is None else device_index
        dev = self.devices[d]
        self.device_batches[d] += 1
        scores = self._score_fn(jax.device_put(idx, dev),
                                jax.device_put(nnz, dev),
                                w.on(d))
        shape_key = (rows, key, d)
        if shape_key not in self._compiled:
            self.compile_misses += 1
            self._compiled.add(shape_key)
        return scores, n, w.version

    def _resolve_batch(self, handle: Tuple) -> List:
        scores, n, version = handle
        host = np.asarray(scores)
        if host.ndim == 1:
            return [VersionedScore(x, version) for x in host[:n]]
        return [VersionedVector(row, version) for row in host[:n]]

    # ----------------------------------------------------- dedup cache ----
    def _dedup_keys(self, arrs: Sequence[np.ndarray],
                    cat: Optional[np.ndarray] = None) -> List[Tuple]:
        """One host-side hash pass over a whole batch → each doc's
        (band-signature probe, full packed bytes, empty bytes) — the
        cache's (probe, guard) pairs.  The bytes are bit-identical to
        the device encode (same fold/mask semantics via ``pad_rows`` +
        ``encode_packed_numpy``), and the encode is pad-width
        invariant, so a key computed in any batch equals the key
        computed alone.  Batching exists because the per-doc cost is
        numpy FIXED overhead (~200µs of small-array calls, not
        arithmetic): one batched pass amortizes it to ~µs/row, which
        is what lets a cache hit undercut the device round trip."""
        from repro.retrieval.bands import band_keys_packed
        ragged = getattr(self.scheme, "encode_packed_numpy_ragged", None)
        if ragged is not None:
            # no padded intermediate at all: concat + fold (the exact
            # ``pad_rows`` id-folding policy) + one ragged encode
            lens = np.fromiter((a.size for a in arrs), dtype=np.int64,
                               count=len(arrs))
            if cat is None:
                cat = (np.concatenate(arrs) if len(arrs) > 1
                       else np.asarray(arrs[0]))
            tokens = (cat & np.int64((1 << 31) - 1)).astype(np.int32)
            packed, empty = ragged(tokens, lens, self.cfg.b)
        else:
            idx, nnz = pad_rows(list(arrs), pad_to_multiple=1)
            packed, empty = self.scheme.encode_packed_numpy(
                idx, nnz, self.cfg.b)
        keys = band_keys_packed(packed, self.cfg.k, self.cfg.b,
                                self._dedup_rows_per_band)
        sigs = keys[:, :self._dedup_probe_bands].tolist()
        return [(tuple(s), packed[i].tobytes(),
                 None if empty is None else empty[i].tobytes())
                for i, s in enumerate(sigs)]

    def _dedup_key(self, arr: np.ndarray):
        return self._dedup_keys([arr])[0]

    def _submit_dedup(self, arr: np.ndarray, key: Optional[Tuple] = None):
        """Cache short-circuit: a hit returns an already-resolved Future
        (no batcher, no device); a miss dispatches normally and fills
        the cache when its batch resolves.

        The cached object is the RESOLVED batcher Future itself, shared
        by every subsequent hit: a finished Future is effectively
        immutable (``add_done_callback`` invokes immediately instead of
        appending, ``cancel`` is a no-op), and handing it out directly
        skips the ~µs-scale ``threading.Condition`` allocation a fresh
        Future per hit would cost — which profiles as the hit path's
        single biggest line item once the encode is batched."""
        sig, packed, empty = self._dedup_key(arr) if key is None else key
        version = self._weights.version
        hit = self.dedup.get(sig, packed, empty, version, nnz=arr.size)
        if hit is not None:
            return hit
        return self._submit_dedup_miss(arr, (sig, packed, empty), version)

    def _submit_dedup_miss(self, arr: np.ndarray, key: Tuple,
                           version: str):
        """Miss leg of the dedup path: normal batcher dispatch plus a
        cache fill when the batch resolves."""
        sig, packed, empty = key
        fut = self.batcher.submit(arr)
        cache = self.dedup

        def _fill(f):
            if f.cancelled() or f.exception() is not None:
                return
            result = f.result()
            cache.put(sig, packed, empty, f,
                      getattr(result, "version", version))

        fut.add_done_callback(_fill)
        return fut

    # ------------------------------------------------------------- API ----
    def submit(self, doc: Sequence[int], tenant: Optional[str] = None):
        """Validate + route one doc; returns a Future of its score (a
        ``VersionedScore`` — a float carrying ``.version``).  Resolve
        latency and the optional ``tenant`` feed the stats window."""
        arr = self._validate(doc)
        t0 = time.perf_counter()
        if self.dedup is not None:
            fut = self._submit_dedup(arr)
        else:
            fut = self.batcher.submit(arr)

        def _record(f, t0=t0, tenant=tenant):
            self.stats_window.record(
                time.perf_counter() - t0, rows=1, tenant=tenant,
                error=(not f.cancelled()
                       and f.exception() is not None))

        fut.add_done_callback(_record)
        if self.adapt_every:
            self._submits += 1
            if self._submits % self.adapt_every == 0:
                self._adapt_async()
        return fut

    def submit_many(self, docs: Sequence[Sequence[int]],
                    tenant: Optional[str] = None) -> List[Future]:
        """Batch ``submit``: identical routing and results, but with
        the dedup cache enabled the whole batch's keys come from ONE
        vectorized host-encode pass (``_dedup_keys``) instead of a
        per-doc pass — the batch front door (HTTP ``POST /score``
        arrives batched already) is where duplicate short-circuiting
        actually pays.  With the cache off this is a plain loop."""
        arrs = [self._validate(d, check_neg=False) for d in docs]
        if not arrs:
            return []
        cat = (np.concatenate(arrs) if len(arrs) > 1
               else np.asarray(arrs[0]))
        if cat.size and int(cat.min()) < 0:
            raise ValueError("doc has negative feature indices")
        t0 = time.perf_counter()
        futs = []

        def _record(f, t0=t0, tenant=tenant):
            self.stats_window.record(
                time.perf_counter() - t0, rows=1, tenant=tenant,
                error=(not f.cancelled()
                       and f.exception() is not None))

        if self.dedup is not None:
            keys = self._dedup_keys(arrs, cat=cat)
            version = self._weights.version
            hits = self.dedup.get_many(keys, version,
                                       [a.size for a in arrs])
            n_hits = 0
            for i, arr in enumerate(arrs):
                hit = hits[i]
                if hit is not None:
                    # resolved shared Future; stats recorded in one
                    # batched call below instead of per-row callbacks
                    futs.append(hit)
                    n_hits += 1
                    continue
                fut = self._submit_dedup_miss(arr, keys[i], version)
                fut.add_done_callback(_record)
                futs.append(fut)
            if n_hits:
                self.stats_window.record_batch(
                    time.perf_counter() - t0, n_hits, tenant=tenant)
        else:
            for arr in arrs:
                fut = self.batcher.submit(arr)
                fut.add_done_callback(_record)
                futs.append(fut)
        if self.adapt_every:
            before = self._submits
            self._submits += len(arrs)
            if (before // self.adapt_every
                    != self._submits // self.adapt_every):
                self._adapt_async()
        return futs

    def score_docs(self, docs: Sequence[Sequence[int]],
                   device_index: Optional[int] = None,
                   weights: Optional[WeightSet] = None) -> np.ndarray:
        """Synchronous batch scoring, bypassing the batcher (the
        batcher-off baseline; also what tests use as the oracle).
        Thread-safe.  ``weights`` pins the batch to a specific
        ``WeightSet`` (version-exact oracles, mixed-version repair in
        the HTTP tier).  Batches wider than the configured buckets
        compile on first use (counted in ``compile_misses``)."""
        items = [self._validate(d) for d in docs]
        key = self._nnz_bucket(max((len(d) for d in items), default=1))
        handle = self._dispatch_batch(key, items,
                                      device_index=device_index,
                                      weights=weights)
        scores, n, _ = handle
        return np.asarray(scores)[:n]

    # ------------------------------------------------- versioned weights --
    @property
    def params(self):
        """The replica-0 resident params of the live version (template
        for checkpoint restores; back-compat accessor)."""
        return self._weights.params[0]

    @property
    def version(self) -> str:
        return self._weights.version

    def current_weights(self) -> WeightSet:
        """The live immutable WeightSet (pin it to score version-exact
        across a reload)."""
        return self._weights

    def swap_weights(self, params, version: Optional[str] = None) -> str:
        """Atomically publish a new weight version.

        The new set is fully staged off to the side (structure check
        against the live tree, device_put per replica, blocked until
        resident) and then swapped in with ONE reference assignment —
        concurrent batches score against exactly the old or exactly the
        new version, and in-flight batches keep the set they captured.
        Returns the new version string.
        """
        live = jax.tree.structure(self._weights.params[0])
        new = jax.tree.structure(params)
        if live != new:
            raise ValueError(
                f"swap_weights: params tree structure {new} does not "
                f"match the live tree {live} — same model config "
                "required for a hot swap")
        for a, b in zip(jax.tree.leaves(self._weights.params[0]),
                        jax.tree.leaves(params)):
            if tuple(np.shape(a)) != tuple(np.shape(b)):
                raise ValueError(
                    f"swap_weights: leaf shape {np.shape(b)} does not "
                    f"match the live leaf {np.shape(a)} — a hot swap "
                    "cannot change k/b/n_classes")
        with self._swap_lock:
            version = version or f"v{self.reloads + 1}"
            staged = tuple(jax.device_put(params, d)
                           for d in self.devices)
            for tree in staged:
                jax.block_until_ready(tree)
            self._weights = WeightSet(version=version, params=staged,
                                      created_at=time.time())
            if self.dedup is not None:
                # same critical section as the reference swap: no window
                # where new-version traffic can hit an old-version score
                self.dedup.invalidate(version)
            self.reloads += 1
        return version

    # ------------------------------------------------- adaptive buckets --
    def _adapt_async(self) -> None:
        """Kick one background re-derivation (submit must never block
        on precompiles; overlapping triggers collapse into one)."""
        if self._adapting.is_set():
            return
        self._adapting.set()

        def run():
            try:
                self.adapt_buckets()
            finally:
                self._adapting.clear()

        threading.Thread(target=run, daemon=True,
                         name="serve-adapt").start()

    def adapt_buckets(self, max_buckets: Optional[int] = None,
                      coverage: float = 0.995) -> Tuple[int, ...]:
        """Re-derive the nnz lane grid from observed traffic.

        Precompiles any new (row × nnz × replica) shapes FIRST, then
        swaps the grid, so post-swap traffic still never pays a
        serve-time compile.  No-op (returns the current grid) until the
        batcher has seen enough samples or when the suggestion matches
        the live grid.  Requests racing the swap route on whichever
        grid they caught — both grids' shapes are compiled.
        """
        suggestion = self.batcher.suggest_buckets(
            max_buckets=max_buckets or len(self.nnz_buckets),
            coverage=coverage)
        if not suggestion or tuple(suggestion) == self.nnz_buckets:
            return self.nnz_buckets
        self._precompile_grid(suggestion, self.row_buckets)
        self.nnz_buckets = tuple(suggestion)   # route() reads this live
        self.rebuckets += 1
        return self.nnz_buckets

    # -------------------------------------------------------- stats -------
    def stats(self) -> dict:
        """Thread-safe operability snapshot (the ``GET /status`` body):
        rolling latency percentiles + rows/s + per-tenant counts from
        the stats window, queue depths and per-lane occupancy, compile
        and reload counters, and the batcher's watchdog health."""
        snap = self.stats_window.snapshot()
        depths = self.batcher.depths()
        snap.update(
            version=self._weights.version,
            reloads=self.reloads,
            uptime_s=time.time() - self._started_at,
            compile_misses=self.compile_misses,
            precompile_seconds=self.precompile_seconds,
            batches_run=self.batcher.batches_run,
            requests_served=self.batcher.requests_served,
            device_batches=list(self.device_batches),
            lanes={str(k): v for k, v in depths["lanes"].items()},
            queued=depths["queued"],
            inflight_batches=depths["inflight_batches"],
            pipeline_depth=depths["depth"],
            nnz_buckets=list(self.nnz_buckets),
            row_buckets=list(self.row_buckets),
            lane_row_buckets={str(m): list(rb) for m, rb
                              in self._lane_row_buckets.items()},
            lane_caps={str(m): c for m, c in self._lane_caps.items()},
            rebuckets=self.rebuckets,
            health=self.batcher.health(),
            dispatch=perf.dispatch_report(),
            dedup=(dict(self.dedup.stats(), enabled=True,
                        rows_per_band=self._dedup_rows_per_band,
                        probe_bands=self._dedup_probe_bands)
                   if self.dedup is not None else {"enabled": False}),
        )
        return snap

    def flush(self):
        """Dispatch every queued request now instead of waiting out the
        coalescing window (end-of-stream clients, graceful drain)."""
        self.batcher.flush()

    def close(self):
        self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def greedy_generate(api, params, prompt: np.ndarray, max_new: int,
                    max_len: Optional[int] = None,
                    extras: Optional[dict] = None) -> np.ndarray:
    """Greedy decode via prefill + cached steps; prompt (B, S0) int32."""
    b, s0 = prompt.shape
    if max_new <= 0:
        return np.asarray(prompt, dtype=np.int32).copy()
    max_len = max_len or (s0 + max_new)
    batch = {"tokens": jnp.asarray(prompt)}
    if extras:
        batch.update(extras)
    logits, cache = api.prefill(params, batch)
    # right-size the cache for generation (KV families only)
    full = api.init_cache(b, max_len)

    def grow(full_leaf, pre_leaf):
        if full_leaf.shape == pre_leaf.shape:
            return pre_leaf.astype(full_leaf.dtype)
        # find the (single) axis that differs — the sequence axis
        axes = [i for i, (a, c) in enumerate(
            zip(full_leaf.shape, pre_leaf.shape)) if a != c]
        ax = axes[0]
        return jax.lax.dynamic_update_slice_in_dim(
            full_leaf, pre_leaf.astype(full_leaf.dtype), 0, axis=ax)

    cache = jax.tree.map(grow, full, cache)
    # token bookkeeping is one preallocated buffer + vectorized numpy
    # argmax/assignment per step — not O(B) Python int()/appends
    out = np.empty((b, s0 + max_new), dtype=np.int32)
    out[:, :s0] = prompt
    nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
    out[:, s0] = nxt
    cache_len = s0
    for t in range(1, max_new):
        logits, cache = api.decode_step(
            params, {"token": jnp.asarray(nxt[:, None])}, cache,
            jnp.asarray(cache_len, jnp.int32))
        nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        out[:, s0 + t] = nxt
        cache_len += 1
    return out
