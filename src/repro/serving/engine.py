"""Serving engines.

``HashedClassifierEngine`` — the paper's inference path as a service:
raw sparse documents → hashing scheme (k-way min-hash, or OPH at 1/k
the hash cost — any scheme from ``repro.core.schemes``) → b-bit codes
→ linear scores.  Batched via DynamicBatcher; hashing and scoring
jit-compiled once per padded shape bucket (shape-bucketed padding
avoids recompiles).  The engine's ``scheme``/``seed`` must match the
ones the training-side preprocessing used.

``greedy_generate`` — reference LM decode loop over any ModelAPI
(prefill + KV-cache decode), used by the serving example and tests.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schemes import make_scheme
from repro.data.packing import bucket_width, pad_rows
from repro.models.linear import BBitLinearConfig, bbit_logits
from repro.serving.batcher import DynamicBatcher


def _bucket(n: int, buckets=(128, 512, 2048, 8192, 32768)) -> int:
    """Pad width for an nnz of ``n``: the smallest fixed bucket that
    fits, growing by powers of two past the largest one.  Clamping to
    ``buckets[-1]`` instead would hand ``_score`` an ``idx`` wider than
    its ``mask`` and crash the batcher thread on giant documents."""
    for b in buckets:
        if n <= b:
            return b
    return bucket_width(n, floor=buckets[-1])


class HashedClassifierEngine:
    def __init__(self, params, cfg: BBitLinearConfig, seed: int = 0,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 scheme: str = "minwise"):
        self.params = params
        self.cfg = cfg
        self.scheme = make_scheme(scheme, cfg.k, seed)
        self.family = getattr(self.scheme, "family", None)

        @jax.jit
        def _score(idx, mask, params):
            codes, empty = self.scheme.encode_jnp(idx, mask, cfg.b)
            logits = bbit_logits(params, codes, cfg, empty=empty)
            return logits[:, 0] if cfg.n_classes == 2 else logits

        self._score = _score
        self.batcher = DynamicBatcher(self._run, max_batch=max_batch,
                                      max_wait_ms=max_wait_ms)

    def _run(self, docs: List[np.ndarray]) -> List[np.ndarray]:
        idx, nnz = pad_rows(docs, pad_to_multiple=1)
        m = _bucket(idx.shape[1])
        if idx.shape[1] < m:
            idx = np.pad(idx, ((0, 0), (0, m - idx.shape[1])))
        mask = np.arange(m)[None, :] < nnz[:, None]
        scores = self._score(jnp.asarray(idx), jnp.asarray(mask),
                             self.params)
        return list(np.asarray(scores))

    def submit(self, doc: Sequence[int]):
        return self.batcher.submit(np.asarray(doc, dtype=np.int64))

    def close(self):
        self.batcher.close()


def greedy_generate(api, params, prompt: np.ndarray, max_new: int,
                    max_len: Optional[int] = None,
                    extras: Optional[dict] = None) -> np.ndarray:
    """Greedy decode via prefill + cached steps; prompt (B, S0) int32."""
    b, s0 = prompt.shape
    max_len = max_len or (s0 + max_new)
    batch = {"tokens": jnp.asarray(prompt)}
    if extras:
        batch.update(extras)
    logits, cache = api.prefill(params, batch)
    # right-size the cache for generation (KV families only)
    full = api.init_cache(b, max_len)

    def grow(full_leaf, pre_leaf):
        if full_leaf.shape == pre_leaf.shape:
            return pre_leaf.astype(full_leaf.dtype)
        # find the (single) axis that differs — the sequence axis
        axes = [i for i, (a, c) in enumerate(
            zip(full_leaf.shape, pre_leaf.shape)) if a != c]
        ax = axes[0]
        return jax.lax.dynamic_update_slice_in_dim(
            full_leaf, pre_leaf.astype(full_leaf.dtype), 0, axis=ax)

    cache = jax.tree.map(grow, full, cache)
    out = [int(np.argmax(np.asarray(logits)[i])) for i in range(b)]
    tokens = [list(row) + [out[i]] for i, row in enumerate(prompt)]
    cur = jnp.asarray([[t[-1]] for t in tokens], jnp.int32)
    cache_len = s0
    for _ in range(max_new - 1):
        logits, cache = api.decode_step(
            params, {"token": cur}, cache,
            jnp.asarray(cache_len, jnp.int32))
        nxt = np.argmax(np.asarray(logits), axis=-1)
        for i in range(b):
            tokens[i].append(int(nxt[i]))
        cur = jnp.asarray(nxt[:, None].astype(np.int32))
        cache_len += 1
    return np.asarray(tokens, dtype=np.int32)
