"""Serving engines.

``HashedClassifierEngine`` — the paper's inference path as a service.
The headline claim (30 hashed values/point matching VW at 2^14,
arXiv:1108.3072) is ultimately an inference-cost argument: tiny codes
mean tiny per-request compute, IF the serving path doesn't squander it
on host round-trips and padding.  This engine serves raw sparse
documents through ONE fused device dispatch per micro-batch:

  raw idx/nnz ─▶ scheme.encode_packed_jit (hash → b-bit → pack; Pallas
  kernel on TPU, XLA elsewhere — ``ops.fused_encode_on_device``)
  ─▶ bbit_scores_packed (packed-input logits kernels) ─▶ scores

so on the kernel path no ``(B, k)`` int32 code matrix ever
materializes — codes travel packed (ceil(k·b/8) bytes/row) and unpack
in-register, exactly like the PR-4 training step.  Scores are
bit-identical to the reference ``encode_jnp`` + ``bbit_logits``
two-step (``fused=False`` keeps that path selectable for A/B benches).

Batching architecture (see ``serving.batcher.BucketBatcher``):

  * LANE ROUTING — ``submit`` validates the doc and routes it to an
    ``nnz``-bucket lane (pow-2-ish widths, growing past the largest
    bucket), so one giant document never inflates a whole batch's
    padding; drained batches pad rows to a pow-2 row bucket.
  * PRECOMPILE — every (row_bucket × nnz_bucket × replica) score
    function is compiled at engine startup, so steady-state serving
    never hits a first-request compile spike (``compile_misses`` counts
    any stray shape that does recompile, e.g. an over-bucket giant doc
    or a direct ``score_docs`` batch larger than ``max_batch``).
  * OVERLAP — the drain thread pads batch N+1 while the device runs
    batch N (async dispatch); a resolver thread owns the blocking
    device→host sync and future resolution.
  * REPLICAS — ``replicas=N`` device_puts the params once per device
    of a 1-D ``launch.mesh.make_replica_mesh`` mesh and round-robins
    micro-batches across them (no collectives; independent throughput
    scaling).

Input contract: docs are 1-D non-negative integer id arrays.  Empty
docs (nnz=0) are scheme-dependent: zero-coded OPH (``oph_zero``) scores
them through its all-empty-bins path (score = bias); schemes without
empty semantics (``minwise``, densified ``oph``) reject them at
``submit`` — their hash of an empty set is undefined sentinel garbage.

``greedy_generate`` — reference LM decode loop over any ModelAPI
(prefill + KV-cache decode), used by the serving example and tests.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schemes import make_scheme
from repro.data.packing import bucket_width, pad_rows
from repro.launch.mesh import make_replica_mesh
from repro.models.linear import (BBitLinearConfig, bbit_scores,
                                 bbit_scores_packed)
from repro.serving.batcher import BucketBatcher

DEFAULT_NNZ_BUCKETS = (128, 512, 2048, 8192, 32768)


def _grow_bucket(n: int, buckets: Sequence[int]) -> int:
    """Pad width for an nnz of ``n``: the smallest fixed bucket that
    fits, growing by powers of two past the largest one.  Clamping to
    ``buckets[-1]`` instead would hand the scorer an ``idx`` wider than
    its ``nnz`` mask and corrupt giant-document scores."""
    for b in buckets:
        if n <= b:
            return b
    return bucket_width(n, floor=buckets[-1])


class HashedClassifierEngine:
    def __init__(self, params, cfg: BBitLinearConfig, seed: int = 0,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 scheme: str = "minwise", *,
                 fused: bool = True,
                 replicas: int = 1,
                 nnz_buckets: Sequence[int] = DEFAULT_NNZ_BUCKETS,
                 row_buckets: Optional[Sequence[int]] = None,
                 precompile: bool = True,
                 pipeline_depth: int = 2):
        self.cfg = cfg
        self.scheme = make_scheme(scheme, cfg.k, seed)
        self.family = getattr(self.scheme, "family", None)
        self.fused = fused
        # zero-coded schemes give an empty doc exact semantics (every
        # bin empty → contributions masked out → score == bias)
        self._allows_empty = getattr(self.scheme, "densify", True) is False
        self.nnz_buckets = tuple(sorted(int(b) for b in nnz_buckets))
        if not self.nnz_buckets:
            raise ValueError("need at least one nnz bucket")
        if row_buckets is None:
            top = bucket_width(max_batch, floor=1)
            row_buckets = tuple(1 << i for i in range(top.bit_length()))
        self.row_buckets = tuple(sorted(int(r) for r in row_buckets))

        self.mesh = make_replica_mesh(replicas)
        self.devices = list(self.mesh.devices.flat)
        # params replicated ONCE — each micro-batch reuses its
        # replica's resident copy, no per-request weight traffic
        self._params = [jax.device_put(params, d) for d in self.devices]
        self.params = self._params[0]
        self._rr = 0
        self._rr_lock = threading.Lock()
        self.device_batches = [0] * len(self.devices)

        scheme_obj, lcfg = self.scheme, cfg

        @jax.jit
        def _score_fused(idx, nnz, params):
            packed, empty = scheme_obj.encode_packed_jit(idx, nnz, lcfg.b)
            return bbit_scores_packed(params, packed, lcfg,
                                      empty_packed=empty)

        @jax.jit
        def _score_reference(idx, nnz, params):
            mask = (jnp.arange(idx.shape[1], dtype=jnp.int32)[None, :]
                    < nnz[:, None])
            codes, empty = scheme_obj.encode_jnp(idx, mask, lcfg.b)
            return bbit_scores(params, codes, lcfg, empty=empty)

        self._score_fused = _score_fused
        self._score_reference = _score_reference
        self._score_fn = _score_fused if fused else _score_reference

        self._compiled: set = set()
        self.compile_misses = 0
        self.precompile_seconds = 0.0
        if precompile:
            self._precompile()

        self.batcher = BucketBatcher(
            self._dispatch_batch, self._resolve_batch,
            route=lambda doc: self._nnz_bucket(len(doc)),
            max_batch=max_batch, max_wait_ms=max_wait_ms,
            depth=pipeline_depth)

    # ---------------------------------------------------------- buckets --
    def _nnz_bucket(self, n: int) -> int:
        return _grow_bucket(n, self.nnz_buckets)

    def _row_bucket(self, n: int) -> int:
        for r in self.row_buckets:
            if n <= r:
                return r
        return bucket_width(n, floor=self.row_buckets[-1])

    def _precompile(self) -> None:
        """Compile every (row_bucket, nnz_bucket, replica) lane shape up
        front — steady-state traffic then never pays a compile spike."""
        t0 = time.perf_counter()
        for d, dev in enumerate(self.devices):
            for m in self.nnz_buckets:
                idx = jax.device_put(np.zeros((1, m), np.int32), dev)
                nnz = jax.device_put(np.ones((1,), np.int32), dev)
                for r in self.row_buckets:
                    ib = jnp.broadcast_to(idx, (r, m))
                    zb = jnp.broadcast_to(nnz, (r,))
                    self._score_fn(ib, zb, self._params[d]) \
                        .block_until_ready()
                    self._compiled.add((r, m, d))
        self.precompile_seconds = time.perf_counter() - t0

    # ----------------------------------------------------------- scoring --
    def _validate(self, doc) -> np.ndarray:
        arr = np.asarray(doc)
        if arr.ndim != 1 or not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(
                f"doc must be a 1-D integer id array, got shape "
                f"{arr.shape} dtype {arr.dtype}")
        if arr.size and int(arr.min()) < 0:
            raise ValueError("doc has negative feature indices")
        if arr.size == 0 and not self._allows_empty:
            raise ValueError(
                f"empty document: scheme {self.scheme.name!r} has no "
                "empty semantics (its min over zero hashes is sentinel "
                "garbage) — reject upstream or serve with the "
                "zero-coded 'oph_zero' scheme, whose all-empty-bins "
                "path scores it as the bias")
        return arr.astype(np.int64, copy=False)

    def _next_device(self) -> int:
        with self._rr_lock:
            d = self._rr % len(self.devices)
            self._rr += 1
        return d

    def _dispatch_batch(self, key: int, docs: List[np.ndarray],
                        device_index: Optional[int] = None) -> Tuple:
        """Pad ``docs`` to the (row_bucket, key) lane shape and issue
        the fused scorer asynchronously (runs on the drain thread; the
        blocking sync happens in ``_resolve_batch``)."""
        n = len(docs)
        rows = self._row_bucket(n)
        # pad_rows owns the id-folding policy (indices ≥ 2^31 fold to
        # [0, 2^31), same as training-side preprocessing) — only the
        # row/width padding to the lane's bucket shape happens here
        packed_idx, packed_nnz = pad_rows(docs, pad_to_multiple=1)
        idx = np.zeros((rows, key), np.int32)
        nnz = np.zeros((rows,), np.int32)
        idx[:n, :packed_idx.shape[1]] = packed_idx
        nnz[:n] = packed_nnz
        d = self._next_device() if device_index is None else device_index
        dev = self.devices[d]
        self.device_batches[d] += 1
        scores = self._score_fn(jax.device_put(idx, dev),
                                jax.device_put(nnz, dev),
                                self._params[d])
        shape_key = (rows, key, d)
        if shape_key not in self._compiled:
            self.compile_misses += 1
            self._compiled.add(shape_key)
        return scores, n

    def _resolve_batch(self, handle: Tuple) -> List:
        scores, n = handle
        return list(np.asarray(scores)[:n])

    # ------------------------------------------------------------- API ----
    def submit(self, doc: Sequence[int]):
        """Validate + route one doc; returns a Future of its score."""
        return self.batcher.submit(self._validate(doc))

    def score_docs(self, docs: Sequence[Sequence[int]],
                   device_index: Optional[int] = None) -> np.ndarray:
        """Synchronous batch scoring, bypassing the batcher (the
        batcher-off baseline; also what tests use as the oracle).
        Thread-safe.  Batches wider than the configured buckets compile
        on first use (counted in ``compile_misses``)."""
        items = [self._validate(d) for d in docs]
        key = self._nnz_bucket(max((len(d) for d in items), default=1))
        handle = self._dispatch_batch(key, items,
                                      device_index=device_index)
        return np.asarray(self._resolve_batch(handle))

    def close(self):
        self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def greedy_generate(api, params, prompt: np.ndarray, max_new: int,
                    max_len: Optional[int] = None,
                    extras: Optional[dict] = None) -> np.ndarray:
    """Greedy decode via prefill + cached steps; prompt (B, S0) int32."""
    b, s0 = prompt.shape
    if max_new <= 0:
        return np.asarray(prompt, dtype=np.int32).copy()
    max_len = max_len or (s0 + max_new)
    batch = {"tokens": jnp.asarray(prompt)}
    if extras:
        batch.update(extras)
    logits, cache = api.prefill(params, batch)
    # right-size the cache for generation (KV families only)
    full = api.init_cache(b, max_len)

    def grow(full_leaf, pre_leaf):
        if full_leaf.shape == pre_leaf.shape:
            return pre_leaf.astype(full_leaf.dtype)
        # find the (single) axis that differs — the sequence axis
        axes = [i for i, (a, c) in enumerate(
            zip(full_leaf.shape, pre_leaf.shape)) if a != c]
        ax = axes[0]
        return jax.lax.dynamic_update_slice_in_dim(
            full_leaf, pre_leaf.astype(full_leaf.dtype), 0, axis=ax)

    cache = jax.tree.map(grow, full, cache)
    # token bookkeeping is one preallocated buffer + vectorized numpy
    # argmax/assignment per step — not O(B) Python int()/appends
    out = np.empty((b, s0 + max_new), dtype=np.int32)
    out[:, :s0] = prompt
    nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
    out[:, s0] = nxt
    cache_len = s0
    for t in range(1, max_new):
        logits, cache = api.decode_step(
            params, {"token": jnp.asarray(nxt[:, None])}, cache,
            jnp.asarray(cache_len, jnp.int32))
        nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        out[:, s0 + t] = nxt
        cache_len += 1
    return out
