"""Versioned weight hot-reload for the serving engine.

The contract (what ``POST /reload`` promises):

  * ATOMIC — the engine's live weights are one immutable ``WeightSet``
    (version string + the per-replica device-resident param handles).
    A reload builds the WHOLE new set off to the side — checkpoint
    read, dtype/shape match against the live tree, ``device_put`` onto
    every replica, block until resident — and then publishes it with a
    single reference swap.  A micro-batch dispatch reads that reference
    exactly once, so every score is computed against exactly the old or
    exactly the new weights, never a mix, and the version echoed with
    the score is the version that actually produced it.
  * NON-DISRUPTIVE — requests in flight during the swap keep their
    already-captured WeightSet; nothing is dropped, cancelled or
    re-queued, and the old params are garbage-collected once the last
    in-flight batch holding them resolves.
  * VERSIONED — every response carries the model version
    (``ckpt-<step>`` for checkpoint loads unless overridden), so
    clients and canary checks can pin scores to weights bitwise.

Checkpoint sources, tried in order by ``reload_from_checkpoint``:

  1. ``<ckpt_dir>/serve`` — the params-only snapshots ``fit_streaming``
     publishes at every checkpoint boundary (``ckpt.checkpoint
     .publish_params``).  This is the paper-loop deployment path: a
     streaming trainer writes shard-boundary checkpoints, the server
     picks up the freshest averaged iterate without a restart.
  2. ``<ckpt_dir>`` itself, when it holds params-only checkpoints
     (a tree structurally identical to the engine's params, e.g. saved
     via ``ckpt.checkpoint.save(dir, step, params)``).

A full training-state checkpoint without a published ``serve/`` subdir
fails loudly with the fix (the leaf counts cannot match), rather than
half-loading an optimizer state as weights.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt


@dataclasses.dataclass(frozen=True)
class WeightSet:
    """One immutable generation of serving weights: the version tag and
    the per-replica device-resident param trees (index-aligned with the
    engine's device list)."""
    version: str
    params: Tuple[Any, ...]
    created_at: float = 0.0

    def on(self, device_index: int) -> Any:
        return self.params[device_index]


def load_serving_params(ckpt_dir: str, template: Any,
                        step: Optional[int] = None) -> Tuple[Any, int]:
    """Load a params tree shaped like ``template`` from ``ckpt_dir``
    (published ``serve/`` snapshots first, then params-only checkpoints
    at the root).  → (params, step)."""
    if ckpt.latest_published(ckpt_dir) is not None:
        return ckpt.restore_published(ckpt_dir, template, step)
    try:
        return ckpt.restore(ckpt_dir, template, step)
    except FileNotFoundError:
        raise FileNotFoundError(
            f"no checkpoints under {ckpt_dir!r} (neither published "
            f"serving params in {ckpt_dir}/{ckpt.SERVE_SUBDIR} nor a "
            "root manifest)")
    except ValueError as e:
        raise ValueError(
            f"checkpoint under {ckpt_dir!r} is not a params-only tree "
            "and has no published serving params — train through "
            "fit_streaming(ckpt_dir=...), which publishes the averaged "
            "iterate under <ckpt_dir>/serve at every boundary, or save "
            f"raw params with ckpt.checkpoint.save: {e}") from e


class ReloadManager:
    """Serialized hot-reloads against one engine.

    One reload at a time (a lock, not a queue: concurrent ``/reload``
    posts would otherwise race device_put work and publish out of
    order); scoring traffic is never blocked — it keeps reading
    whichever ``WeightSet`` is current.
    """

    def __init__(self, engine):
        self.engine = engine
        self._lock = threading.Lock()
        self.history: List[dict] = []

    @property
    def version(self) -> str:
        return self.engine.version

    def swap(self, params: Any, version: Optional[str] = None) -> dict:
        """Swap in an in-memory params tree (must match the live tree's
        structure); → {"version", "previous"}."""
        with self._lock:
            previous = self.engine.version
            ver = self.engine.swap_weights(params, version)
            info = {"version": ver, "previous": previous,
                    "reloads": self.engine.reloads, "at": time.time()}
            self.history.append(info)
            return dict(info)

    def reload_from_checkpoint(self, ckpt_dir: str,
                               step: Optional[int] = None,
                               version: Optional[str] = None) -> dict:
        """Load + swap; → {"version", "previous", "step", "ckpt_dir"}.

        Raises ``FileNotFoundError`` (no checkpoint there) or
        ``ValueError`` (structure mismatch) without touching the live
        weights — a failed reload leaves serving exactly as it was.
        """
        with self._lock:
            template = jax.tree.map(np.asarray,
                                    jax.device_get(self.engine.params))
            params, got_step = load_serving_params(ckpt_dir, template,
                                                   step)
            previous = self.engine.version
            ver = self.engine.swap_weights(
                params, version or f"ckpt-{got_step}")
            info = {"version": ver, "previous": previous,
                    "step": int(got_step), "ckpt_dir": ckpt_dir,
                    "reloads": self.engine.reloads, "at": time.time()}
            self.history.append(info)
            return dict(info)
