"""Rolling serving statistics: latency window, tenants, nnz histogram.

Production serving needs live numbers without a metrics dependency and
without a hot-path lock fight.  Two primitives, both O(1) per request
and lock-cheap (one short critical section around an index bump —
percentile math happens on a copied slice at ``snapshot()`` time, never
under the lock):

  * ``StatsWindow`` — a fixed-size ring buffer of per-request
    ``(done_at, latency, rows)`` samples plus per-tenant request
    counters.  ``snapshot()`` returns rolling p50/p95/p99 latency,
    rows/s over the window's actual time span, error and total counts.
    Old samples fall out by being overwritten, so the window always
    reflects *recent* traffic — exactly what ``GET /status`` should
    show after a traffic shift, not a lifetime average.

  * ``NnzHistogram`` — power-of-two-binned counts of observed document
    sizes (bin ``j`` holds nnz in ``(2^(j-1), 2^j]``), feeding
    ``suggest_buckets()``: re-derive a padded-width bucket grid from
    live traffic instead of static config.  The suggestion covers
    ``coverage`` of the observed mass with at most ``max_buckets``
    pow-2 edges placed at cumulative-count quantiles, so a skewed
    workload (say, everything around nnz≈40 under a default grid that
    starts at 128) converges to a tighter grid with ~3× less padding
    per batch.  Traffic above the grid still serves — the engine grows
    past the top bucket by powers of two, it just pays a compile.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


class StatsWindow:
    """Fixed-size ring of per-request samples; thread-safe."""

    def __init__(self, size: int = 2048):
        if size < 1:
            raise ValueError(f"window size must be >= 1, got {size}")
        self.size = size
        self._lat = np.zeros(size, np.float64)     # seconds
        self._rows = np.zeros(size, np.int64)
        self._done = np.zeros(size, np.float64)    # perf_counter stamps
        self._n = 0                                # lifetime count
        self._errors = 0
        self._tenants: collections.Counter = collections.Counter()
        self._lock = threading.Lock()

    def record(self, latency_s: float, rows: int = 1,
               tenant: Optional[str] = None, error: bool = False) -> None:
        now = time.perf_counter()
        with self._lock:
            i = self._n % self.size
            self._lat[i] = latency_s
            self._rows[i] = rows
            self._done[i] = now
            self._n += 1
            if error:
                self._errors += 1
            if tenant is not None:
                self._tenants[str(tenant)] += rows

    def record_batch(self, latency_s: float, count: int,
                     tenant: Optional[str] = None) -> None:
        """``count`` identical single-row samples under ONE lock
        acquisition + vectorized ring write — the batch front door's
        cache-hit path resolves whole chunks at the same instant, and
        per-row ``record`` locking is measurable at that rate."""
        if count <= 0:
            return
        now = time.perf_counter()
        with self._lock:
            idx = (self._n + np.arange(count)) % self.size
            self._lat[idx] = latency_s
            self._rows[idx] = 1
            self._done[idx] = now
            self._n += count
            if tenant is not None:
                self._tenants[str(tenant)] += count

    @property
    def count(self) -> int:
        return self._n

    def snapshot(self) -> Dict:
        """Rolling percentiles + throughput over the live window (copy
        under the lock, math outside it)."""
        with self._lock:
            m = min(self._n, self.size)
            lat = self._lat[:m].copy()
            rows = self._rows[:m].copy()
            done = self._done[:m].copy()
            n, errors = self._n, self._errors
            tenants = dict(self._tenants)
        out = {"count": n, "errors": errors, "window": m,
               "per_tenant_rows": tenants,
               "p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
               "rows_per_s": 0.0, "window_span_s": 0.0}
        if m == 0:
            return out
        ms = lat * 1e3
        out["p50_ms"] = float(np.percentile(ms, 50))
        out["p95_ms"] = float(np.percentile(ms, 95))
        out["p99_ms"] = float(np.percentile(ms, 99))
        # throughput over the span the window actually covers; a
        # single-sample window has no span — report 0 rather than inf
        span = float(done.max() - done.min())
        out["window_span_s"] = span
        if span > 0:
            out["rows_per_s"] = float(rows.sum()) / span
        return out


def _pow2_edge(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


class NnzHistogram:
    """Pow-2-binned document-size counts; thread-safe, O(1) record."""

    MAX_BIN = 32          # nnz up to 2^32 — beyond any real document

    def __init__(self):
        self._counts = [0] * (self.MAX_BIN + 1)
        self._lock = threading.Lock()

    def record(self, n: int) -> None:
        j = min(max(int(n) - 1, 0).bit_length(), self.MAX_BIN)
        with self._lock:
            self._counts[j] += 1

    def record_many(self, ns: Sequence[int]) -> None:
        """Batch ``record`` under one lock acquisition."""
        if not ns:
            return
        with self._lock:
            for n in ns:
                j = min(max(int(n) - 1, 0).bit_length(), self.MAX_BIN)
                self._counts[j] += 1

    @property
    def total(self) -> int:
        with self._lock:
            return sum(self._counts)

    def counts(self) -> Dict[int, int]:
        """→ {pow2_upper_edge: count} for non-empty bins."""
        with self._lock:
            c = list(self._counts)
        return {1 << j: c[j] for j in range(len(c)) if c[j]}

    def suggest_buckets(self, max_buckets: int = 6,
                        coverage: float = 0.995,
                        min_samples: int = 64) -> Optional[Tuple[int, ...]]:
        """Derive a padded-width bucket grid from observed traffic.

        Drops the ``1 - coverage`` upper tail (one outlier must not pin
        a giant top bucket), then places at most ``max_buckets`` pow-2
        edges at cumulative-count quantiles so each bucket carries a
        comparable share of traffic.  Returns ``None`` when fewer than
        ``min_samples`` documents have been seen — too little signal to
        re-derive a grid from.
        """
        if max_buckets < 1:
            raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
        with self._lock:
            c = list(self._counts)
        total = sum(c)
        if total < min_samples:
            return None
        # cutoff bin: smallest prefix holding >= coverage of the mass
        target = coverage * total
        cum, cutoff = 0, len(c) - 1
        for j, cnt in enumerate(c):
            cum += cnt
            if cum >= target:
                cutoff = j
                break
        live = [j for j in range(cutoff + 1) if c[j]]
        if not live:
            return None
        if len(live) <= max_buckets:
            return tuple(1 << j for j in live)
        # thin to quantile edges; the cutoff bin always stays (it is
        # what makes the grid cover `coverage` of traffic)
        covered = sum(c[: cutoff + 1])
        edges, cum, want = [], 0, 1
        for j in live:
            cum += c[j]
            if cum >= covered * want / max_buckets:
                edges.append(j)
                want += 1
        if edges[-1] != live[-1]:
            edges[-1] = live[-1]
        return tuple(1 << j for j in sorted(set(edges)))
