"""Duplicate-traffic score cache keyed by minhash band signatures.

The million-user serving case is heavy on duplicates — many clients
posting the same viral document.  The codes the engine already computes
are a content fingerprint, so a bounded LRU over them short-circuits
the device entirely: a repeat document costs one host-side hash pass
(``scheme.encode_packed_numpy`` — bit-identical to the device encode)
plus a dict probe, instead of a padded device round-trip.

Key contract (bands are the probe, full-code equality is the guard):

  * PROBE — the dict key is the tuple of the first ``probe_bands`` LSH
    band keys of the packed code row (``retrieval.bands``).  A subset
    on purpose: all bands concatenated would just *be* the full code.
  * GUARD — a probe hit only returns a score after exact bytes-equality
    of the full packed code (and the ``oph_zero`` empty bitmask).  Band
    collisions of non-identical docs are counted (``guard_rejects``)
    and miss — no false-positive score can ever leave the cache.  The
    host encode is bit-exact vs the device encode per scheme, so
    byte-equality here transfers exactly to score-equality there
    (the serving bench's bitwise parity canary re-proves it end-to-end).
  * VERSION — every entry is pinned to the ``WeightSet`` version that
    produced its score; ``invalidate(new_version)`` (called under the
    engine's swap lock) atomically drops everything, and a late
    ``put`` racing a swap is discarded (``stale_drops``).

Hit/miss/eviction/bytes counters surface through ``engine.stats()`` →
``GET /status``; hit document sizes feed an ``NnzHistogram`` (the same
adaptive-bucket primitive the batcher uses) so operators can see WHICH
traffic is duplicated.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.stats import NnzHistogram

_SIG_KEY_BYTES = 8      # one uint64 per probe band


class DedupCache:
    """Bounded LRU: band-signature probe → (packed code, score)."""

    def __init__(self, max_entries: int = 4096, *, version: str = "v0"):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = int(max_entries)
        self._lock = threading.Lock()
        # sig -> (packed bytes, empty bytes | None, result, version)
        self._entries: "OrderedDict[Tuple[int, ...], Tuple]" = OrderedDict()
        self._bytes = 0
        self._version = version
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.insertions = 0
        self.guard_rejects = 0
        self.stale_drops = 0
        self.invalidations = 0
        self.hit_sizes = NnzHistogram()

    @staticmethod
    def _entry_bytes(sig, packed: bytes, empty: Optional[bytes],
                     result) -> int:
        size = _SIG_KEY_BYTES * len(sig) + len(packed)
        if empty is not None:
            size += len(empty)
        size += getattr(result, "nbytes", 8)
        return size

    def get(self, sig: Tuple[int, ...], packed: bytes,
            empty: Optional[bytes], version: str,
            nnz: Optional[int] = None):
        """Probe → guarded lookup; returns the cached result or None."""
        with self._lock:
            entry = self._entries.get(sig)
            if entry is None:
                self.misses += 1
                return None
            e_packed, e_empty, result, e_version = entry
            if e_packed != packed or e_empty != empty:
                self.guard_rejects += 1
                self.misses += 1
                return None
            if e_version != version:
                # belt over the invalidate() suspenders: a stale entry
                # must never serve a new version's traffic
                self.misses += 1
                return None
            self._entries.move_to_end(sig)
            self.hits += 1
        if nnz is not None:
            self.hit_sizes.record(nnz)
        return result

    def get_many(self, keys, version: str,
                 sizes: Optional[Sequence[int]] = None) -> List:
        """Batched ``get``: same probe → guard → version pipeline per
        key, but ONE lock acquisition for the whole chunk (per-row
        locking is a measurable slice of the hit path at batch-front-
        door rates).  ``keys`` is a sequence of (sig, packed, empty)
        triples; returns a same-length list with None at misses."""
        out = []
        hit_sizes = []
        with self._lock:
            for i, (sig, packed, empty) in enumerate(keys):
                entry = self._entries.get(sig)
                if entry is None:
                    self.misses += 1
                    out.append(None)
                    continue
                e_packed, e_empty, result, e_version = entry
                if e_packed != packed or e_empty != empty:
                    self.guard_rejects += 1
                    self.misses += 1
                    out.append(None)
                    continue
                if e_version != version:
                    self.misses += 1
                    out.append(None)
                    continue
                self._entries.move_to_end(sig)
                self.hits += 1
                out.append(result)
                if sizes is not None:
                    hit_sizes.append(sizes[i])
        if hit_sizes:
            self.hit_sizes.record_many(hit_sizes)
        return out

    def put(self, sig: Tuple[int, ...], packed: bytes,
            empty: Optional[bytes], result, version: str) -> None:
        """Insert after a miss resolves; drops stale-version writes."""
        with self._lock:
            if version != self._version:
                self.stale_drops += 1
                return
            old = self._entries.pop(sig, None)
            if old is not None:
                self._bytes -= self._entry_bytes(sig, old[0], old[1], old[2])
            self._entries[sig] = (packed, empty, result, version)
            self._bytes += self._entry_bytes(sig, packed, empty, result)
            self.insertions += 1
            while len(self._entries) > self.max_entries:
                k, (p, e, r, _) = self._entries.popitem(last=False)
                self._bytes -= self._entry_bytes(k, p, e, r)
                self.evictions += 1

    def invalidate(self, version: str) -> None:
        """New weight version ⇒ every cached score is wrong: one
        atomic clear (the engine calls this under its swap lock)."""
        with self._lock:
            self._entries = OrderedDict()
            self._bytes = 0
            self._version = version
            self.invalidations += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            hits, misses = self.hits, self.misses
            out = {
                "entries": len(self._entries),
                "max_entries": self.max_entries,
                "bytes": self._bytes,
                "hits": hits,
                "misses": misses,
                "evictions": self.evictions,
                "insertions": self.insertions,
                "guard_rejects": self.guard_rejects,
                "stale_drops": self.stale_drops,
                "invalidations": self.invalidations,
                "version": self._version,
            }
        out["hit_rate"] = hits / (hits + misses) if hits + misses else 0.0
        out["hit_nnz"] = {str(e): c for e, c
                          in self.hit_sizes.counts().items()}
        return out
