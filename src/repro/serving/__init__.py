"""Serving: bucketed dynamic batching + fused hashed-classifier / LM
decode engines."""
from repro.serving.batcher import BucketBatcher, DynamicBatcher
from repro.serving.engine import HashedClassifierEngine, greedy_generate

__all__ = ["BucketBatcher", "DynamicBatcher", "HashedClassifierEngine",
           "greedy_generate"]
