"""Serving: dynamic batching + hashed-classifier / LM decode engines."""
from repro.serving.batcher import DynamicBatcher
from repro.serving.engine import HashedClassifierEngine, greedy_generate

__all__ = ["DynamicBatcher", "HashedClassifierEngine", "greedy_generate"]
