"""Serving: bucketed dynamic batching + fused hashed-classifier / LM
decode engines, and the stdlib-only HTTP tier on top (admission
control, live stats, graceful drain, versioned hot-reload)."""
from repro.serving.admission import (AdmissionController, Draining,
                                     Overloaded)
from repro.serving.batcher import BucketBatcher, DynamicBatcher
from repro.serving.dedup import DedupCache
from repro.serving.engine import (HashedClassifierEngine, VersionedScore,
                                  VersionedVector, greedy_generate)
from repro.serving.reload import (ReloadManager, WeightSet,
                                  load_serving_params)
from repro.serving.server import (HTTPStatusError, ScoreClient,
                                  ScoreServer)
from repro.serving.stats import NnzHistogram, StatsWindow

__all__ = ["AdmissionController", "BucketBatcher", "DedupCache",
           "Draining",
           "DynamicBatcher", "HTTPStatusError", "HashedClassifierEngine",
           "NnzHistogram", "Overloaded", "ReloadManager", "ScoreClient",
           "ScoreServer", "StatsWindow", "VersionedScore",
           "VersionedVector", "WeightSet", "greedy_generate",
           "load_serving_params"]
