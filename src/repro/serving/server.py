"""Async HTTP front end over ``HashedClassifierEngine`` — stdlib only.

The network tier that turns the fused scoring engine into a service:
``asyncio`` + hand-rolled HTTP/1.1 (keep-alive, chunked responses), so
CI and production images need no framework dependency.  One event loop
thread does all parsing and response writing; the only blocking work —
the device→host sync — stays on the batcher's resolver thread, bridged
back with ``asyncio.wrap_future`` over the engine's
``concurrent.futures`` handles, so a slow batch never stalls the
accept loop.

Endpoints:

  * ``POST /score`` — body ``{"docs": [[id, ...], ...]}`` (or a bare
    list of docs) → ``{"scores": [...], "version": ..., "model": ...}``.
    SINGLE-VERSION: every score in one response was produced by the
    same model version.  If a hot-reload lands exactly between the
    micro-batches of one request, the whole request is re-scored
    pinned to one ``WeightSet`` (rare, bounded, and version-exact) —
    a response never mixes versions.
  * ``POST /score_ndjson`` — streaming: body is NDJSON (one JSON doc
    array per line), the response streams one
    ``{"i", "score", "version"}`` line per doc over chunked encoding
    AS EACH resolves — first scores arrive while later docs are still
    queued.  Per-line version echo (a reload may legitimately flip
    versions mid-stream; each score's tag is exact).
  * ``GET /status`` — engine stats snapshot (rolling p50/p95/p99,
    rows/s, per-lane occupancy, ``compile_misses``, per-tenant rows),
    admission counters, and ``health``: ``ok`` | ``degraded`` (batcher
    watchdog detected a stalled drain/resolve thread) | ``draining``.
  * ``GET /healthz`` — 200 when ok, 503 when degraded/draining (load-
    balancer probe).
  * ``POST /reload`` — ``{"ckpt_dir": ..., "step"?: ..., "version"?:
    ...}`` → versioned hot swap via ``serving.reload.ReloadManager``;
    404 when no checkpoint is there, 409 when it doesn't match the
    live model; a failed reload never touches the live weights.

Admission & drain (see ``serving.admission``): a request acquires
``len(docs)`` rows of the bounded in-flight budget before any engine
work — beyond the budget it is rejected immediately with 429 +
``Retry-After`` (lanes saturate ⇒ reject fast, never queue unboundedly).
SIGTERM/SIGINT (or ``request_drain()``) triggers graceful drain: new
work is refused with 503, in-flight requests finish and respond, the
engine's ``close()`` flushes every accepted future, then the sockets
close and ``run()`` returns — no request is ever silently dropped.

Per-request rows/latency land in the engine's stats window keyed by an
optional tenant header (default ``X-Tenant``) for per-tenant accounting.
"""
from __future__ import annotations

import asyncio
import http.client
import json
import signal
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ft.retry import BackoffPolicy
from repro.serving.admission import (AdmissionController, Draining,
                                     Overloaded)
from repro.serving.reload import ReloadManager

_MAX_HEADER_BYTES = 64 * 1024
_MAX_BODY_BYTES = 64 << 20
_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 409: "Conflict",
            413: "Payload Too Large", 429: "Too Many Requests",
            431: "Request Header Fields Too Large",
            500: "Internal Server Error", 503: "Service Unavailable"}


class _HttpError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def _json_bytes(obj) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def _jsonable(score):
    """Engine result → JSON value (binary margin float or multiclass
    score list)."""
    arr = np.asarray(score)
    if arr.ndim == 0:
        return float(arr)
    return [float(x) for x in arr]


class ScoreServer:
    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 *, admission: Optional[AdmissionController] = None,
                 reload_manager: Optional[ReloadManager] = None,
                 drain_timeout_s: float = 30.0,
                 tenant_header: str = "x-tenant",
                 max_body_bytes: int = _MAX_BODY_BYTES,
                 model_name: str = "bbit-hashed-linear",
                 on_started=None):
        self.engine = engine
        self.host = host
        self.port = port               # 0 → ephemeral; real port after start
        self.admission = admission or AdmissionController.for_engine(engine)
        self.reloader = reload_manager or ReloadManager(engine)
        self.drain_timeout_s = drain_timeout_s
        self.tenant_header = tenant_header.lower()
        self.max_body_bytes = max_body_bytes
        self.model_name = model_name
        self.on_started = on_started
        self.http_requests = 0
        self._t0 = time.time()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop_event: Optional[asyncio.Event] = None
        self._writers: set = set()
        self._started = threading.Event()
        self._finished = threading.Event()
        self.drained_clean: Optional[bool] = None

    # ------------------------------------------------------- lifecycle ----
    def run(self, install_signals: bool = True) -> None:
        """Serve until SIGTERM/SIGINT/``request_drain()``, then drain
        gracefully and return.  Blocks the calling thread."""
        asyncio.run(self._amain(install_signals))

    def start_in_thread(self, timeout: float = 60.0) -> threading.Thread:
        """Run the server on a daemon thread (tests/examples); returns
        once the socket is bound and ``self.port`` is real."""
        t = threading.Thread(target=self.run, name="score-server",
                             kwargs={"install_signals": False},
                             daemon=True)
        t.start()
        if not self._started.wait(timeout):
            raise RuntimeError("server failed to start listening")
        return t

    def request_drain(self) -> None:
        """Thread-safe graceful-shutdown trigger (same path as SIGTERM)."""
        loop, stop = self._loop, self._stop_event
        if loop is not None and stop is not None:
            loop.call_soon_threadsafe(stop.set)

    def wait_finished(self, timeout: Optional[float] = None) -> bool:
        return self._finished.wait(timeout)

    async def _amain(self, install_signals: bool) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop_event = asyncio.Event()
        server = await asyncio.start_server(self._client, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._loop.add_signal_handler(sig,
                                                  self._stop_event.set)
                except (NotImplementedError, RuntimeError):
                    pass               # non-main thread / platform quirk
        self._started.set()
        if self.on_started is not None:
            self.on_started(self)
        try:
            await self._stop_event.wait()
            await self._drain()
        finally:
            server.close()
            await server.wait_closed()
            for w in list(self._writers):   # idle keep-alive connections
                try:
                    w.close()
                except Exception:  # noqa: BLE001
                    pass
            self._finished.set()

    async def _drain(self) -> None:
        """The graceful-shutdown sequence.  Ordering is the contract:
        (1) refuse new work (503), (2) wait for every admitted row to
        answer, (3) flush the batcher so even a straggling accepted
        future resolves — only then do sockets close."""
        self.admission.begin_drain()
        loop = asyncio.get_running_loop()
        idle = await loop.run_in_executor(
            None, self.admission.wait_idle, self.drain_timeout_s)
        await loop.run_in_executor(None, self.engine.close)
        self.drained_clean = bool(idle)

    # ------------------------------------------------------ HTTP layer ----
    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self._writers.add(writer)
        try:
            while True:
                try:
                    req = await self._read_request(reader)
                except _HttpError as e:
                    await self._respond(writer, e.status,
                                        {"error": e.message}, keep=False)
                    break
                if req is None:
                    break
                if not await self._handle(req, writer):
                    break
        except (asyncio.IncompleteReadError, ConnectionError,
                BrokenPipeError):
            pass
        finally:
            self._writers.discard(writer)
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:  # noqa: BLE001
                pass

    async def _read_request(self, reader) -> Optional[Dict]:
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").strip().split()
        if len(parts) != 3:
            raise _HttpError(400, "malformed request line")
        method, target, _version = parts
        headers: Dict[str, str] = {}
        total = len(line)
        while True:
            h = await reader.readline()
            total += len(h)
            if total > _MAX_HEADER_BYTES:
                raise _HttpError(431, "headers too large")
            if h in (b"\r\n", b"\n", b""):
                break
            key, _, val = h.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        try:
            n = int(headers.get("content-length", "0") or 0)
        except ValueError:
            raise _HttpError(400, "bad Content-Length")
        if n > self.max_body_bytes:
            raise _HttpError(413,
                             f"body {n} bytes > {self.max_body_bytes}")
        body = await reader.readexactly(n) if n else b""
        return {"method": method, "path": target.split("?", 1)[0],
                "headers": headers, "body": body}

    async def _respond(self, writer, status: int, obj,
                       headers: Optional[Dict[str, str]] = None,
                       keep: bool = True) -> None:
        body = obj if isinstance(obj, bytes) else _json_bytes(obj)
        head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
                "Content-Type: application/json",
                f"Content-Length: {len(body)}",
                f"Connection: {'keep-alive' if keep else 'close'}"]
        for k, v in (headers or {}).items():
            head.append(f"{k}: {v}")
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)
        await writer.drain()

    async def _handle(self, req: Dict, writer) -> bool:
        """Route one request; returns keep-alive."""
        self.http_requests += 1
        method, path = req["method"], req["path"]
        keep = req["headers"].get("connection", "").lower() != "close"
        try:
            if path == "/score" and method == "POST":
                return await self._score(req, writer, keep)
            if path == "/score_ndjson" and method == "POST":
                return await self._score_ndjson(req, writer, keep)
            if path == "/status" and method == "GET":
                await self._respond(writer, 200, self.status(), keep=keep)
                return keep
            if path == "/healthz" and method == "GET":
                st = self.status()
                ok = st["health"] == "ok"
                await self._respond(writer, 200 if ok else 503,
                                    {"health": st["health"]}, keep=keep)
                return keep
            if path == "/reload" and method == "POST":
                return await self._reload(req, writer, keep)
            if path in ("/score", "/score_ndjson", "/reload", "/status",
                        "/healthz"):
                raise _HttpError(405, f"{method} not allowed on {path}")
            raise _HttpError(404, f"no route {method} {path}")
        except Overloaded as e:
            await self._respond(
                writer, 429,
                {"error": "overloaded",
                 "retry_after_s": e.retry_after_s, "detail": str(e)},
                headers={"Retry-After": f"{e.retry_after_s:.3f}"},
                keep=keep)
            return keep
        except Draining:
            await self._respond(writer, 503,
                                {"error": "draining",
                                 "detail": "server is shutting down"},
                                keep=False)
            return False
        except _HttpError as e:
            await self._respond(writer, e.status, {"error": e.message},
                                keep=keep)
            return keep
        except Exception as e:  # noqa: BLE001 — never kill the connection loop silently
            await self._respond(writer, 500,
                                {"error": f"{type(e).__name__}: {e}"},
                                keep=keep)
            return keep

    # ------------------------------------------------------- endpoints ----
    def _parse_docs(self, body: bytes) -> List[np.ndarray]:
        try:
            obj = json.loads(body or b"null")
        except json.JSONDecodeError:
            raise _HttpError(400, "body is not valid JSON")
        docs = obj.get("docs") if isinstance(obj, dict) else obj
        if not isinstance(docs, list) or not docs \
                or not all(isinstance(d, list) for d in docs):
            raise _HttpError(
                400, 'expected {"docs": [[id, ...], ...]} with at '
                     'least one doc')
        out = []
        for i, d in enumerate(docs):
            try:
                out.append(np.asarray(d, dtype=np.int64))
            except (TypeError, ValueError, OverflowError):
                raise _HttpError(400,
                                 f"doc {i} is not an integer id list")
        return out

    def _submit_all(self, docs: List[np.ndarray],
                    tenant: Optional[str]) -> List:
        try:
            # batch submit: with the dedup cache on, the whole request
            # keys in one vectorized host-encode pass; duck-typed so
            # an engine exposing only ``submit`` still serves
            submit_many = getattr(self.engine, "submit_many", None)
            if submit_many is not None:
                return submit_many(docs, tenant=tenant)
            return [self.engine.submit(d, tenant=tenant) for d in docs]
        except (TypeError, ValueError) as e:   # engine-side validation
            raise _HttpError(400, str(e))

    async def _score(self, req: Dict, writer, keep: bool) -> bool:
        docs = self._parse_docs(req["body"])
        tenant = req["headers"].get(self.tenant_header)
        self.admission.acquire(len(docs))
        try:
            scores, version = await self._score_single_version(docs,
                                                               tenant)
        finally:
            self.admission.release(len(docs))
        await self._respond(writer, 200,
                            {"scores": scores, "version": version,
                             "model": self.model_name}, keep=keep)
        return keep

    async def _score_single_version(self, docs, tenant
                                    ) -> Tuple[list, str]:
        loop = asyncio.get_running_loop()
        futs = self._submit_all(docs, tenant)
        results = await asyncio.gather(
            *[asyncio.wrap_future(f, loop=loop) for f in futs])
        versions = {getattr(r, "version", None) for r in results}
        if len(versions) == 1:
            ver = versions.pop() or self.engine.version
            return [_jsonable(r) for r in results], ver
        # a hot-reload landed between this request's micro-batches:
        # re-score the WHOLE batch pinned to one WeightSet so the
        # response is version-exact (rare — only the swap instant)
        w = self.engine.current_weights()
        pinned = await loop.run_in_executor(
            None, lambda: self.engine.score_docs(docs, weights=w))
        return [_jsonable(x) for x in pinned], w.version

    async def _score_ndjson(self, req: Dict, writer,
                            keep: bool) -> bool:
        lines = [ln for ln in req["body"].splitlines() if ln.strip()]
        if not lines:
            raise _HttpError(400, "empty NDJSON body")
        docs = []
        for i, ln in enumerate(lines):
            try:
                obj = json.loads(ln)
            except json.JSONDecodeError:
                raise _HttpError(400, f"line {i} is not valid JSON")
            if isinstance(obj, dict):
                obj = obj.get("doc")
            if not isinstance(obj, list):
                raise _HttpError(
                    400, f"line {i}: expected [id, ...] or "
                         '{"doc": [id, ...]}')
            try:
                docs.append(np.asarray(obj, dtype=np.int64))
            except (TypeError, ValueError, OverflowError):
                raise _HttpError(400,
                                 f"line {i} is not an integer id list")
        tenant = req["headers"].get(self.tenant_header)
        self.admission.acquire(len(docs))
        try:
            loop = asyncio.get_running_loop()
            futs = self._submit_all(docs, tenant)
            # headers first, then one chunk per resolved score — the
            # client sees early scores while later docs still queue
            writer.write(
                b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: application/x-ndjson\r\n"
                b"Transfer-Encoding: chunked\r\n"
                b"Connection: " +
                (b"keep-alive" if keep else b"close") + b"\r\n\r\n")
            try:
                for i, f in enumerate(futs):
                    r = await asyncio.wrap_future(f, loop=loop)
                    payload = _json_bytes(
                        {"i": i, "score": _jsonable(r),
                         "version": getattr(r, "version",
                                            self.engine.version)}
                    ) + b"\n"
                    writer.write(b"%x\r\n%s\r\n" % (len(payload),
                                                    payload))
                    await writer.drain()
                writer.write(b"0\r\n\r\n")
                await writer.drain()
            except Exception as e:  # noqa: BLE001 — headers already sent
                payload = _json_bytes(
                    {"error": f"{type(e).__name__}: {e}"}) + b"\n"
                writer.write(b"%x\r\n%s\r\n0\r\n\r\n"
                             % (len(payload), payload))
                await writer.drain()
                return False
        finally:
            self.admission.release(len(docs))
        return keep

    async def _reload(self, req: Dict, writer, keep: bool) -> bool:
        try:
            obj = json.loads(req["body"] or b"null")
        except json.JSONDecodeError:
            raise _HttpError(400, "body is not valid JSON")
        if not isinstance(obj, dict) or not obj.get("ckpt_dir"):
            raise _HttpError(400, 'expected {"ckpt_dir": ..., '
                                  '"step"?: int, "version"?: str}')
        loop = asyncio.get_running_loop()
        try:
            info = await loop.run_in_executor(
                None, lambda: self.reloader.reload_from_checkpoint(
                    obj["ckpt_dir"], step=obj.get("step"),
                    version=obj.get("version")))
        except FileNotFoundError as e:
            await self._respond(writer, 404, {"error": str(e)},
                                keep=keep)
            return keep
        except ValueError as e:
            await self._respond(writer, 409, {"error": str(e)},
                                keep=keep)
            return keep
        await self._respond(writer, 200, info, keep=keep)
        return keep

    def status(self) -> Dict:
        """Full engine ``stats()`` merged at the top level (keys are a
        superset of the engine's, so new engine sections — ``dedup``,
        ``dispatch`` — surface here without server changes), with the
        server's own scalars layered on top: ``health`` flattens to the
        drain-aware string, ``uptime_s``/``version`` are the server's
        view, and the verbatim engine snapshot stays nested under
        ``engine`` for existing consumers."""
        eng = self.engine.stats()
        adm = self.admission.snapshot()
        health = ("draining" if adm["draining"]
                  else eng["health"]["state"])
        out = dict(eng)
        out.update({"health": health, "version": eng["version"],
                    "model": self.model_name,
                    "uptime_s": time.time() - self._t0,
                    "http_requests": self.http_requests,
                    "engine": eng, "admission": adm})
        return out


class HTTPStatusError(RuntimeError):
    """Non-2xx from the server; carries status + parsed payload."""

    def __init__(self, status: int, payload, retry_after_s=None):
        super().__init__(f"HTTP {status}: {payload}")
        self.status = status
        self.payload = payload
        self.retry_after_s = retry_after_s


class ScoreClient:
    """Minimal blocking keep-alive client for examples/benches/tests
    (stdlib ``http.client``; one instance per thread).

    ``retries > 0`` opts JSON calls into bounded retry on 429
    (admission Overloaded) and 503 (Draining): each rejected attempt
    waits out max(the server's ``Retry-After`` hint, the capped
    exponential backoff with deterministic jitter from
    ``repro.ft.retry.BackoffPolicy(seed=retry_seed)``), then reissues
    the request.  Other statuses (and exhausted retries) raise
    ``HTTPStatusError`` exactly as with ``retries=0`` (the default —
    no behavior change for existing callers).
    """

    def __init__(self, host: str, port: int, timeout: float = 120.0,
                 *, retries: int = 0,
                 backoff: Optional["BackoffPolicy"] = None,
                 retry_seed: int = 0):
        self.host, self.port, self.timeout = host, port, timeout
        self.retries = int(retries)
        self.backoff = (BackoffPolicy(base_s=0.02, factor=2.0,
                                      cap_s=1.0, jitter_frac=0.1,
                                      seed=retry_seed)
                        if backoff is None else backoff)
        self._conn: Optional[http.client.HTTPConnection] = None

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
        return self._conn

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def request(self, method: str, path: str, body=None,
                headers: Optional[Dict[str, str]] = None):
        """→ (status, headers dict, parsed-JSON body or raw response
        object for streams).  Retries once on a dropped keep-alive."""
        payload = _json_bytes(body) if isinstance(body, (dict, list)) \
            else body
        hdrs = {"Content-Type": "application/json"}
        hdrs.update(headers or {})
        for attempt in (0, 1):
            conn = self._connection()
            try:
                conn.request(method, path, body=payload, headers=hdrs)
                resp = conn.getresponse()
                break
            except (http.client.HTTPException, ConnectionError,
                    BrokenPipeError):
                self.close()
                if attempt:
                    raise
        return resp

    def _json_call(self, method, path, body=None, headers=None):
        for attempt in range(self.retries + 1):
            resp = self.request(method, path, body, headers)
            data = resp.read()
            try:
                obj = json.loads(data) if data else None
            except json.JSONDecodeError:
                obj = data.decode("latin-1", "replace")
            if resp.status < 300:
                return obj
            ra = resp.getheader("Retry-After")
            err = HTTPStatusError(resp.status, obj,
                                  retry_after_s=float(ra) if ra else None)
            if resp.status not in (429, 503) or attempt >= self.retries:
                raise err
            # back-pressure statuses: honor the server's Retry-After
            # hint, floored by our own deterministic backoff curve
            time.sleep(max(err.retry_after_s or 0.0,
                           self.backoff.delay_s(attempt)))

    def score(self, docs: Sequence[Sequence[int]],
              tenant: Optional[str] = None) -> Dict:
        docs = [np.asarray(d).tolist() for d in docs]
        hdrs = {"X-Tenant": tenant} if tenant else None
        return self._json_call("POST", "/score", {"docs": docs}, hdrs)

    def score_ndjson(self, docs: Sequence[Sequence[int]],
                     tenant: Optional[str] = None) -> List[Dict]:
        body = b"".join(_json_bytes(np.asarray(d).tolist()) + b"\n"
                        for d in docs)
        hdrs = {"Content-Type": "application/x-ndjson"}
        if tenant:
            hdrs["X-Tenant"] = tenant
        resp = self.request("POST", "/score_ndjson", body, hdrs)
        if resp.status >= 300:
            raise HTTPStatusError(resp.status,
                                  json.loads(resp.read() or b"null"))
        out = []
        for line in resp.read().splitlines():   # http.client de-chunks
            if line.strip():
                out.append(json.loads(line))
        for entry in out:
            if "error" in entry:
                raise HTTPStatusError(500, entry)
        return out

    def status(self) -> Dict:
        return self._json_call("GET", "/status")

    def healthz(self) -> Dict:
        return self._json_call("GET", "/healthz")

    def reload(self, ckpt_dir: str, step: Optional[int] = None,
               version: Optional[str] = None) -> Dict:
        body = {"ckpt_dir": ckpt_dir}
        if step is not None:
            body["step"] = step
        if version is not None:
            body["version"] = version
        return self._json_call("POST", "/reload", body)
