"""Admission control: bounded in-flight work, fast rejection, drain.

The batcher's lanes and its dispatch queue are bounded; the one place
unbounded queueing could creep back in is the network front door.  An
``AdmissionController`` closes that hole with a single rule: the rows
admitted but not yet answered never exceed ``limit``.

  * BUDGET — ``limit`` defaults to what the engine pipeline can
    genuinely hold concurrently: ``(pipeline depth + 1) dispatched or
    draining batches × the max row bucket per batch × the number of
    nnz lanes`` (``for_engine``).  Rows beyond that would only sit in
    an unbounded queue inflating tail latency, so they are REJECTED
    FAST instead: ``Overloaded`` → HTTP 429 with ``Retry-After``, the
    client's signal to back off or go to another replica.  A single
    request asking for more rows than the whole budget can never be
    admitted and is rejected immediately for the same reason.
  * DRAIN — ``begin_drain()`` flips the controller one-way into
    refusing all new work (``Draining`` → HTTP 503) while already-
    admitted rows keep their slots until released; ``wait_idle()``
    blocks until the last one finishes.  Together with the batcher's
    ``close()`` flush contract this yields the shutdown guarantee: no
    request is ever silently dropped — each either resolves normally
    or is refused with a clear retriable status before any work is
    done on it.

Thread-safe; ``acquire``/``release`` are O(1) under one lock shared
with the idle-waiter condition.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional


class Overloaded(RuntimeError):
    """In-flight budget exhausted — reject fast, retry after a beat."""

    def __init__(self, message: str, retry_after_s: float):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class Draining(RuntimeError):
    """The server is shutting down and refuses new work."""


class AdmissionController:
    def __init__(self, limit: int, retry_after_s: float = 0.05):
        if limit < 1:
            raise ValueError(f"in-flight limit must be >= 1, got {limit}")
        self.limit = int(limit)
        self.retry_after_s = float(retry_after_s)
        self._cond = threading.Condition()
        self._inflight = 0
        self._draining = False
        self.admitted = 0          # rows ever admitted
        self.rejected = 0          # rows refused with Overloaded
        self.refused_draining = 0  # rows refused because draining

    @classmethod
    def for_engine(cls, engine, retry_after_s: float = 0.05,
                   headroom: float = 1.0) -> "AdmissionController":
        """Budget derived from the engine's real concurrency: one batch
        being assembled plus ``pipeline_depth`` dispatched batches, per
        nnz lane, each at the largest row bucket."""
        depth = getattr(engine.batcher, "depth", 1)
        rows = max(engine.row_buckets)
        lanes = max(len(engine.nnz_buckets), 1)
        limit = max(1, int((depth + 1) * rows * lanes * headroom))
        return cls(limit, retry_after_s=retry_after_s)

    # ------------------------------------------------------ lifecycle ----
    @property
    def inflight(self) -> int:
        with self._cond:
            return self._inflight

    @property
    def draining(self) -> bool:
        with self._cond:
            return self._draining

    def acquire(self, rows: int = 1) -> None:
        """Admit ``rows`` units of work or raise (never queues)."""
        if rows < 1:
            raise ValueError(f"rows must be >= 1, got {rows}")
        with self._cond:
            if self._draining:
                self.refused_draining += rows
                raise Draining("server is draining; no new work accepted")
            if self._inflight + rows > self.limit:
                self.rejected += rows
                raise Overloaded(
                    f"in-flight budget exhausted ({self._inflight}"
                    f"/{self.limit} rows in flight, {rows} requested)",
                    retry_after_s=self.retry_after_s)
            self._inflight += rows
            self.admitted += rows

    def release(self, rows: int = 1) -> None:
        with self._cond:
            self._inflight -= rows
            if self._inflight < 0:          # release without acquire
                self._inflight = 0
            if self._inflight == 0:
                self._cond.notify_all()

    @contextlib.contextmanager
    def slot(self, rows: int = 1):
        self.acquire(rows)
        try:
            yield
        finally:
            self.release(rows)

    def begin_drain(self) -> None:
        """One-way flip into refusing new work (idempotent)."""
        with self._cond:
            self._draining = True
            self._cond.notify_all()

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted row has been released (True) or
        the timeout expires (False)."""
        with self._cond:
            return self._cond.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)

    def snapshot(self) -> Dict:
        with self._cond:
            return {"inflight": self._inflight, "limit": self.limit,
                    "draining": self._draining,
                    "admitted": self.admitted,
                    "rejected": self.rejected,
                    "refused_draining": self.refused_draining}
