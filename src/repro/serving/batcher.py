"""Dynamic request batching for the serving engines.

Two batchers share the submit()→Future contract:

``DynamicBatcher`` — the classic single-queue front half: requests
queue up, a background worker drains up to ``max_batch`` at a time (or
whatever arrived within ``max_wait_ms``), runs them as one batch and
resolves per-request futures.  One queue means one shape lane: a giant
document inflates the padding of every batch-mate, and the worker
blocks on the device round-trip before it pads the next batch.

``BucketBatcher`` — the shape-bucketed, overlapped replacement (the
serving analogue of ``data.prefetch``):

  * LANE ROUTING — ``route(item)`` assigns each request a lane key at
    submit time (the engine keys lanes by padded-nnz bucket), so
    requests only ever batch with shape-compatible peers and a giant
    document never inflates a small batch's padding;
  * OVERLAP — the drain thread pads and DISPATCHES a batch (jax's
    async dispatch returns an un-synced device array) and immediately
    starts padding the next one, while a separate resolver thread
    blocks on the device→host sync and resolves futures.  Up to
    ``depth`` dispatched batches wait in a bounded queue (backpressure:
    the drain thread stalls rather than flooding the device), so host
    padding of batch N+1 overlaps device compute of batch N;
  * DETERMINISTIC CLOSE — ``close()`` refuses new submits, flushes
    every pending request (or fails its future if the dispatch fn
    raises) and joins both threads; no future ever hangs.
  * OBSERVABILITY — ``depths()`` snapshots per-lane occupancy and the
    in-flight dispatch queue; a ``ft.watchdog.StepWatchdog`` over
    per-batch dispatch+resolve latency backs ``health()``: a drain or
    resolve call stuck past ``stall_after_s`` (or far past the rolling
    median) reports ``degraded`` so a front end can fail its health
    check instead of letting clients hang on silent futures.
  * ADAPTIVE BUCKETS — ``submit`` records each item's size (``size``
    hook, default ``len``) into a pow-2 histogram;
    ``suggest_buckets()`` re-derives a lane grid from that observed
    traffic (see ``serving.stats.NnzHistogram``) so a skewed workload
    converges to tighter padding than the static config grid.

Both batchers guarantee on ``close()``: every future returned by a
successful ``submit`` is done (result or exception) before ``close``
returns, and a ``submit`` racing with ``close`` either wins (its future
resolves) or raises ``RuntimeError`` — it cannot silently hang.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, Hashable, List, Optional, Sequence, \
    Tuple

from repro.ft.watchdog import StepWatchdog
from repro.serving.stats import NnzHistogram

_CLOSE = object()          # queue sentinel: enqueued once, after the
                           # last accepted submit (submits after close
                           # raise, so nothing ever follows it)


def _set_result(fut: Future, out) -> None:
    """Resolve a future a client may have cancel()ed meanwhile (a
    pending concurrent.futures.Future always accepts cancel): a raw
    set_result would raise InvalidStateError and either kill the
    worker thread or poison its batch-mates' futures."""
    if not fut.done():
        try:
            fut.set_result(out)
        except Exception:  # noqa: BLE001 — lost the cancel race
            pass


def _set_exception(fut: Future, exc: BaseException) -> None:
    if not fut.done():
        try:
            fut.set_exception(exc)
        except Exception:  # noqa: BLE001 — lost the cancel race
            pass


class DynamicBatcher:
    def __init__(self, run_batch: Callable[[List], List],
                 max_batch: int = 64, max_wait_ms: float = 2.0):
        self._run_batch = run_batch
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._closed = False
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self.batches_run = 0
        self.requests_served = 0

    def submit(self, item) -> Future:
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("DynamicBatcher is closed")
            self._q.put((item, fut))
        return fut

    def _drain(self) -> Tuple[List[Tuple[object, Future]], bool]:
        """→ (items, closing).  FIFO queue + single consumer: once the
        close sentinel surfaces, every accepted request has already
        been drained (possibly into this very batch)."""
        items: List[Tuple[object, Future]] = []
        try:
            first = self._q.get(timeout=0.05)
        except queue.Empty:
            return items, False
        if first is _CLOSE:
            return items, True
        items.append(first)
        deadline = time.perf_counter() + self.max_wait
        while len(items) < self.max_batch:
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            try:
                nxt = self._q.get(timeout=timeout)
            except queue.Empty:
                break
            if nxt is _CLOSE:
                return items, True
            items.append(nxt)
        return items, False

    def _loop(self) -> None:
        closing = False
        while not closing:
            batch, closing = self._drain()
            if not batch:
                continue
            inputs = [b[0] for b in batch]
            try:
                outputs = self._run_batch(inputs)
                for (_, fut), out in zip(batch, outputs):
                    _set_result(fut, out)
            except Exception as e:  # noqa: BLE001
                for _, fut in batch:
                    _set_exception(fut, e)
            self.batches_run += 1
            self.requests_served += len(batch)

    def close(self) -> None:
        """Flush-or-fail every pending request, then join the worker.

        Requests already accepted are still batched and resolved (or
        failed with ``run_batch``'s exception); submits from here on
        raise.  Idempotent.  Raises if the worker cannot flush within
        the timeout — returning silently would break the every-future-
        is-done contract."""
        with self._lock:
            if not self._closed:
                self._closed = True
                self._q.put(_CLOSE)
        self._worker.join(timeout=60.0)
        if self._worker.is_alive():
            raise RuntimeError(
                "DynamicBatcher worker failed to flush within 60s — "
                "pending futures may be unresolved (run_batch stuck?)")


class BucketBatcher:
    """Per-lane micro-batching with dispatch/resolve overlap.

    ``route(item) -> key`` picks the lane; ``dispatch(key, items) ->
    handle`` runs on the drain thread (pad + async device dispatch —
    it must NOT block on device completion); ``resolve(handle) ->
    per-item results`` runs on the resolver thread (the blocking
    device→host sync lives here, off the drain loop).

    A lane is drained when it reaches its cap (``lane_caps[key]`` where
    given — the cost model's measured throughput-optimal micro-batch
    for that lane — else the global ``max_batch``) or its oldest
    request has waited ``max_wait_ms``; a full lane dispatches
    immediately (never queues behind another lane's not-yet-ripe head),
    otherwise lanes compete oldest-head-first so none starves.  At most
    ``depth`` dispatched-but-unresolved batches are in flight (bounded
    handoff queue).
    """

    def __init__(self, dispatch: Callable[[Hashable, List], object],
                 resolve: Callable[[object], Sequence],
                 route: Callable[[object], Hashable],
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 depth: int = 2,
                 size: Callable[[object], int] = len,
                 watchdog: Optional[StepWatchdog] = None,
                 stall_after_s: float = 10.0,
                 lane_caps: Optional[Dict[Hashable, int]] = None):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self._dispatch = dispatch
        self._resolve = resolve
        self._route = route
        self._size = size
        self.max_batch = max_batch
        self.lane_caps = dict(lane_caps or {})
        self.max_wait = max_wait_ms / 1000.0
        self.depth = depth
        self._cond = threading.Condition()
        self._lanes: dict = {}     # key -> deque[(item, fut, t_enq)]
        self._flush_before = -1.0  # heads enqueued at/before this are ripe
        self._closed = False
        self._resq: "queue.Queue" = queue.Queue(maxsize=depth)
        self.batches_run = 0
        self.requests_served = 0
        self.size_hist = NnzHistogram()
        # per-batch dispatch+resolve latency window; a batch far past
        # the rolling median flags slow, and a dispatch/resolve call
        # that never returns shows up as a live stall in ``health()``
        self.watchdog = watchdog or StepWatchdog(threshold=4.0,
                                                 window=64,
                                                 escalate_after=3)
        self.stall_after_s = stall_after_s
        self._dispatch_started: Optional[float] = None
        self._resolve_started: Optional[float] = None
        self._drainer = threading.Thread(target=self._drain_loop,
                                         daemon=True, name="serve-drain")
        self._resolver = threading.Thread(target=self._resolve_loop,
                                          daemon=True,
                                          name="serve-resolve")
        self._drainer.start()
        self._resolver.start()

    def submit(self, item) -> Future:
        fut: Future = Future()
        key = self._route(item)
        try:
            n = int(self._size(item))
        except TypeError:
            n = 0
        with self._cond:
            if self._closed:
                raise RuntimeError("BucketBatcher is closed")
            self._lanes.setdefault(key, collections.deque()).append(
                (item, fut, time.perf_counter()))
            self._cond.notify()
        self.size_hist.record(n)
        return fut

    def flush(self) -> None:
        """Ripen every currently queued head NOW: the drain thread
        dispatches all pending lanes without waiting out ``max_wait``.
        For end-of-stream clients and graceful drain — a caller that
        knows no more traffic is coming should not leave the tail
        request sitting in a half-full lane for a full coalescing
        window.  Requests submitted after the call batch normally."""
        with self._cond:
            self._flush_before = time.perf_counter()
            self._cond.notify_all()

    # ------------------------------------------------- observability --
    def depths(self) -> Dict:
        """Queue-depth snapshot: per-lane occupancy + dispatched-but-
        unresolved batches (the bounded overlap queue)."""
        with self._cond:
            lanes = {key: len(lane) for key, lane in self._lanes.items()
                     if lane}
        return {"lanes": lanes, "queued": sum(lanes.values()),
                "inflight_batches": self._resq.qsize(),
                "depth": self.depth}

    def suggest_buckets(self, max_buckets: int = 6,
                        coverage: float = 0.995,
                        min_samples: int = 64):
        """Lane grid re-derived from the observed item-size histogram
        (``None`` until ``min_samples`` items have been seen)."""
        return self.size_hist.suggest_buckets(
            max_buckets=max_buckets, coverage=coverage,
            min_samples=min_samples)

    def health(self) -> Dict:
        """→ {"state": "ok"|"degraded", ...}.  Degraded when the drain
        (dispatch) or resolver thread has been inside one call longer
        than ``stall_after_s`` — the precursor to every client future
        hanging — or when the watchdog escalated a persistent-straggler
        verdict on recent batches."""
        now = time.perf_counter()
        stalled, stalled_s = None, 0.0
        for name, t0 in (("dispatch", self._dispatch_started),
                         ("resolve", self._resolve_started)):
            if t0 is not None and now - t0 > self.stall_after_s:
                if now - t0 > stalled_s:
                    stalled, stalled_s = name, now - t0
        state = "degraded" if (stalled or self.watchdog.escalations) \
            else "ok"
        return {"state": state, "stalled_thread": stalled,
                "stalled_s": round(stalled_s, 3),
                "slow_batches": len(self.watchdog.flagged_steps),
                "escalations": len(self.watchdog.escalations)}

    def _lane_cap(self, key) -> int:
        cap = self.lane_caps.get(key, self.max_batch)
        return max(1, min(int(cap), self.max_batch))

    def _pick_locked(self):
        """→ (key, head_enq_time, full) or None.  A FULL lane (≥ its
        cap) wins outright — it is dispatchable NOW and must not
        wait behind an older-but-not-yet-ripe head in another lane;
        otherwise the oldest head (latency fairness)."""
        best = None
        for key, lane in self._lanes.items():
            if not lane:
                continue
            if len(lane) >= self._lane_cap(key):
                return (key, lane[0][2], True)
            if best is None or lane[0][2] < best[1]:
                best = (key, lane[0][2], False)
        return best

    def _drain_loop(self) -> None:
        while True:
            with self._cond:
                batch = key = None
                while True:
                    pick = self._pick_locked()
                    if pick is None:
                        if self._closed:
                            break
                        self._cond.wait()
                        continue
                    key, t_head, full = pick
                    lane = self._lanes[key]
                    age = time.perf_counter() - t_head
                    if (full or self._closed or age >= self.max_wait
                            or t_head <= self._flush_before):
                        batch = [lane.popleft() for _ in
                                 range(min(len(lane),
                                           self._lane_cap(key)))]
                        break
                    # head not ripe: sleep at most until it is (an
                    # incoming submit notifies earlier)
                    self._cond.wait(timeout=self.max_wait - age)
            if batch is None:       # closed + everything flushed
                self._resq.put(_CLOSE)
                return
            futs = [f for _, f, _ in batch]
            t_disp = time.perf_counter()
            self._dispatch_started = t_disp
            try:
                handle = self._dispatch(key, [x for x, _, _ in batch])
            except Exception as e:  # noqa: BLE001
                self._dispatch_started = None
                for f in futs:
                    _set_exception(f, e)
                continue
            self._dispatch_started = None
            self.batches_run += 1
            self._resq.put((handle, futs, t_disp))  # bounded → backpressure

    def _resolve_loop(self) -> None:
        while True:
            entry = self._resq.get()
            if entry is _CLOSE:
                return
            handle, futs, t_disp = entry
            self._resolve_started = time.perf_counter()
            try:
                outs = self._resolve(handle)
                for f, out in zip(futs, outs):
                    _set_result(f, out)
            except Exception as e:  # noqa: BLE001
                for f in futs:
                    _set_exception(f, e)
            self._resolve_started = None
            self.requests_served += len(futs)
            # one watchdog step per batch: dispatch → futures resolved
            self.watchdog.end_step(
                self.batches_run,
                duration=time.perf_counter() - t_disp)

    def close(self) -> None:
        """Flush every lane (or fail futures on dispatch/resolve
        errors), then join both threads.  Idempotent.  Raises if the
        pipeline cannot flush within the timeout — returning silently
        would break the every-future-is-done contract."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._drainer.join(timeout=60.0)
        self._resolver.join(timeout=60.0)
        if self._drainer.is_alive() or self._resolver.is_alive():
            raise RuntimeError(
                "BucketBatcher failed to flush within 60s — pending "
                "futures may be unresolved (dispatch/resolve stuck?)")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
