"""Dynamic request batching for the serving engine.

Requests queue up; a background worker drains up to ``max_batch`` at a
time (or whatever arrived within ``max_wait_ms``), pads them into one
device batch, and resolves per-request futures.  This is the standard
continuous-batching front half; the paper's inference workload
(hash → score) is embarrassingly batchable, so throughput scales with
batch size until the device saturates.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Sequence, Tuple


class DynamicBatcher:
    def __init__(self, run_batch: Callable[[List], List],
                 max_batch: int = 64, max_wait_ms: float = 2.0):
        self._run_batch = run_batch
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1000.0
        self._q: "queue.Queue" = queue.Queue()
        self._stop = False
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self.batches_run = 0
        self.requests_served = 0

    def submit(self, item) -> Future:
        fut: Future = Future()
        self._q.put((item, fut))
        return fut

    def _drain(self) -> List[Tuple[object, Future]]:
        items = []
        try:
            items.append(self._q.get(timeout=0.05))
        except queue.Empty:
            return items
        deadline = time.perf_counter() + self.max_wait
        while len(items) < self.max_batch:
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            try:
                items.append(self._q.get(timeout=timeout))
            except queue.Empty:
                break
        return items

    def _loop(self) -> None:
        while not self._stop:
            batch = self._drain()
            if not batch:
                continue
            inputs = [b[0] for b in batch]
            try:
                outputs = self._run_batch(inputs)
                for (_, fut), out in zip(batch, outputs):
                    fut.set_result(out)
            except Exception as e:  # noqa: BLE001
                for _, fut in batch:
                    if not fut.done():
                        fut.set_exception(e)
            self.batches_run += 1
            self.requests_served += len(batch)

    def close(self) -> None:
        self._stop = True
