"""Polyak(-Ruppert) iterate averaging — the online-SGD variance killer.

VW's online mode (and the averaged-SGD baseline of arXiv:1205.2958 §5)
reports the *averaged* iterate: after a burn-in, the running mean of
the SGD parameters converges at the optimal O(1/t) rate even though
the raw iterate keeps bouncing at O(lr).  ``polyak_update`` is the
jit-able hook ``train.steps.build_averaged_train_step`` folds into the
train step; *tail* averaging (start averaging only after a fraction of
the run, controlled by the caller via ``active``) avoids polluting the
mean with far-from-optimum early iterates.

The update is the numerically-stable running mean

    count' = count + active
    avg'   = avg + active · (params − avg) / max(count', 1)

so ``active`` ∈ {0, 1} gates averaging without a second jit variant:
with ``active = 0`` both avg and count pass through untouched, and the
first active step makes ``avg = params`` exactly.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_average(params: Any) -> Tuple[Any, jax.Array]:
    """→ (zeros-like f32 average tree, count 0.0) — the state pair
    ``polyak_update`` threads."""
    avg = jax.tree.map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params)
    return avg, jnp.zeros((), jnp.float32)


def polyak_update(avg: Any, count: jax.Array, params: Any,
                  active) -> Tuple[Any, jax.Array]:
    """One running-mean step over the param tree; ``active`` (0/1 or
    bool) gates whether this iterate joins the average."""
    a = jnp.asarray(active, jnp.float32)
    new_count = count + a
    denom = jnp.maximum(new_count, 1.0)
    new_avg = jax.tree.map(
        lambda m, p: m + a * (p.astype(jnp.float32) - m) / denom,
        avg, params)
    return new_avg, new_count


def average_or_none(avg: Any, count) -> Any:
    """The averaged tree if any step was averaged, else ``None`` (the
    caller never steered into the averaging window)."""
    return avg if float(count) > 0 else None
