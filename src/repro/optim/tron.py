"""TRON — trust-region Newton-CG, LIBLINEAR's primal solver [11, 15].

The paper trains every experiment with LIBLINEAR; its `-s 0` (logistic)
and `-s 2` (L2-loss SVM) solvers are trust-region Newton methods.  This
is the same algorithm in JAX: Steihaug conjugate-gradient inner solves
with Hessian-vector products from ``jax.jvp(jax.grad(f))`` — no Hessian
materialization, every piece jittable, and data parallelism comes for
free when the objective closure is pjit'd (gradients/Hv psum inside).

Hyper-parameters follow LIBLINEAR's tron.cpp: eta0/1/2 = 1e-4/0.25/0.75,
sigma1/2/3 = 0.25/0.5/4.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


@dataclasses.dataclass
class TronResult:
    params: object
    fun: float
    grad_norm: float
    n_iter: int
    converged: bool
    trace: list


def _cg_steihaug(hvp, g, delta, cg_tol, cg_max):
    """Solves H s = -g within ||s|| ≤ delta.  Returns (s, hit_boundary)."""
    s = jnp.zeros_like(g)
    r = -g
    d = r
    rTr = r @ r
    g_norm = jnp.sqrt(g @ g)
    for _ in range(cg_max):
        if jnp.sqrt(rTr) <= cg_tol * g_norm:
            return s, False
        Hd = hvp(d)
        dHd = d @ Hd
        if dHd <= 0:
            tau = _boundary_tau(s, d, delta)
            return s + tau * d, True
        alpha = rTr / dHd
        s_next = s + alpha * d
        if jnp.sqrt(s_next @ s_next) >= delta:
            tau = _boundary_tau(s, d, delta)
            return s + tau * d, True
        s = s_next
        r = r - alpha * Hd
        rTr_new = r @ r
        d = r + (rTr_new / rTr) * d
        rTr = rTr_new
    return s, False


def _boundary_tau(s, d, delta):
    """Positive root of ||s + tau·d|| = delta."""
    sd = s @ d
    dd = d @ d
    ss = s @ s
    rad = jnp.sqrt(sd * sd + dd * (delta * delta - ss))
    return (rad - sd) / dd


def tron_minimize(
    fun: Callable,
    w0,
    *,
    hvp: Optional[Callable] = None,
    max_iter: int = 100,
    cg_max: int = 30,
    cg_tol: float = 0.1,
    grad_tol: float = 1e-4,
    verbose: bool = False,
) -> TronResult:
    """Minimizes ``fun(params)`` (full-batch, deterministic closure).

    ``hvp(params, v) -> pytree`` optionally supplies an analytic
    Hessian-vector product (required when the forward pass contains
    custom_vjp kernels, which forward-mode AD cannot pierce; for linear
    models it is also cheaper: Hv = v + C·Xᵀ(ℓ″(m)⊙Xv)).
    """
    flat0, unravel = ravel_pytree(w0)

    def f_flat(w):
        return fun(unravel(w))

    val_and_grad = jax.jit(jax.value_and_grad(f_flat))
    val_only = jax.jit(f_flat)

    if hvp is None:
        @jax.jit
        def hvp_at(w, v):
            return jax.jvp(jax.grad(f_flat), (w,), (v,))[1]
    else:
        @jax.jit
        def hvp_at(w, v):
            return ravel_pytree(hvp(unravel(w), unravel(v)))[0]

    w = flat0
    f, g = val_and_grad(w)
    g0_norm = float(jnp.linalg.norm(g))
    delta = g0_norm
    trace = [float(f)]
    eta0, eta1, eta2 = 1e-4, 0.25, 0.75
    sigma1, sigma2, sigma3 = 0.25, 0.5, 4.0

    converged = False
    it = 0
    for it in range(1, max_iter + 1):
        gnorm = float(jnp.linalg.norm(g))
        if gnorm <= grad_tol * max(g0_norm, 1e-12):
            converged = True
            break
        s, _ = _cg_steihaug(lambda v: hvp_at(w, v), g, delta, cg_tol, cg_max)
        f_new = val_only(w + s)
        gs = float(g @ s)
        sHs = float(s @ hvp_at(w, s))
        pred = -(gs + 0.5 * sHs)                 # predicted decrease
        actual = float(f - f_new)
        rho = actual / pred if pred > 0 else -1.0
        snorm = float(jnp.linalg.norm(s))
        # LIBLINEAR-style delta update
        if rho < eta0:
            delta = sigma1 * min(delta, snorm)
        elif rho < eta1:
            delta = max(sigma1 * delta, min(snorm, sigma2 * delta))
        elif rho < eta2:
            delta = max(sigma1 * delta, min(snorm * sigma3, delta))
        else:
            delta = max(delta, min(snorm * sigma3, 1e10))
        if rho > eta0:
            w = w + s
            f, g = val_and_grad(w)
            trace.append(float(f))
            if verbose:
                print(f"tron it={it} f={float(f):.6f} |g|={gnorm:.3e} "
                      f"delta={delta:.3e} rho={rho:.2f}")
    return TronResult(params=unravel(w), fun=float(f),
                      grad_norm=float(jnp.linalg.norm(g)), n_iter=it,
                      converged=converged, trace=trace)
