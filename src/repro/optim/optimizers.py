"""Minimal optax-style gradient transformations (no external deps).

``Optimizer`` is an (init, update) pair over arbitrary pytrees.
AdamW supports fp32 / bf16 / int8 moment storage (int8 via blockwise
absmax quantization — see quantized_state.py) so trillion-parameter
configs fit HBM; the dtype is a config knob surfaced per-arch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.quantized_state import maybe_dequantize, maybe_quantize

Schedule = Callable[[jax.Array], jax.Array]
LR = Union[float, Schedule]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]
    # update(grads, state, params, step) -> (new_params, new_state)


def _lr_at(lr: LR, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr: LR, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        if momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr_t * g, params, grads)
            return new, ()
        vel = jax.tree.map(lambda v, g: momentum * v + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
        else:
            upd = vel
        new = jax.tree.map(lambda p, u: p - lr_t * u, params, upd)
        return new, vel

    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    moment_dtype: str = "float32"  # 'float32' | 'bfloat16' | 'int8'
    quant_block: int = 256
    # Leaves above this many elements update via lax.map over their
    # leading axis so the f32-dequantized moments never materialize
    # whole (a 1T-param stacked MoE leaf would otherwise spike tens of
    # GB of f32 transients per device — measured in the kimi dry-run).
    chunked_update_threshold: int = 1 << 28


def adamw(lr: LR, cfg: AdamWConfig = AdamWConfig()) -> Optimizer:
    def init(params):
        def mk():
            return jax.tree.map(
                lambda p: maybe_quantize(jnp.zeros(p.shape, jnp.float32),
                                         cfg.moment_dtype,
                                         cfg.quant_block),
                params)
        # m and v MUST be distinct buffers: donating a TrainState whose
        # moments alias the same array aborts with "donate the same
        # buffer twice" at execute time.
        return {"m": mk(), "v": mk()}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - cfg.b1 ** t
        c2 = 1.0 - cfg.b2 ** t

        def upd_core(p, g, m_q, v_q):
            g = g.astype(jnp.float32)
            m = cfg.b1 * maybe_dequantize(m_q) + (1 - cfg.b1) * g
            v = cfg.b2 * maybe_dequantize(v_q) + (1 - cfg.b2) * g * g
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
            return (newp,
                    maybe_quantize(m, cfg.moment_dtype, cfg.quant_block),
                    maybe_quantize(v, cfg.moment_dtype, cfg.quant_block))

        def upd_one(p, g, m_q, v_q):
            size = 1
            for d in p.shape:
                size *= d
            if size <= cfg.chunked_update_threshold or p.ndim < 2:
                return upd_core(p, g, m_q, v_q)
            # chunked: stream the update over the leading (layer) axis
            return jax.lax.map(
                lambda args: upd_core(*args), (p, g, m_q, v_q))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd_one(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def make_optimizer(name: str, lr: LR, *, weight_decay: float = 0.0,
                   momentum: float = 0.9,
                   moment_dtype: str = "float32") -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return sgd(lr, momentum=momentum)
    if name == "adamw":
        return adamw(lr, AdamWConfig(weight_decay=weight_decay,
                                     moment_dtype=moment_dtype))
    raise ValueError(f"unknown optimizer {name!r}")
