"""Learning-rate schedules as pure ``step -> lr`` functions."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return f


def inverse_sqrt(peak_lr: float, warmup_steps: int):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        decay = peak_lr * jnp.sqrt(warmup_steps / jnp.maximum(step, 1.0))
        return jnp.where(step < warmup_steps, warm, decay)
    return f


def make(name: str, lr: float, total_steps: int = 10000,
         warmup_steps: int = 100):
    if name == "constant":
        return constant(lr)
    if name == "warmup_cosine":
        return warmup_cosine(lr, warmup_steps, total_steps)
    if name == "inverse_sqrt":
        return inverse_sqrt(lr, warmup_steps)
    raise ValueError(f"unknown schedule {name!r}")
