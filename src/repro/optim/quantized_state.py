"""b-bit quantized optimizer state — the paper's storage idea applied to
optimizer moments (required to fit the 1T-param kimi-k2 config; see
DESIGN.md §6).

Moments use ROW-WISE absmax int8: ``q`` keeps the parameter's shape
(int8) and ``scale`` collapses the last dim — so both quantized payload
and scales shard under exactly the parameter's PartitionSpec (scale's
last entry dropped), with no quantization block ever straddling a shard
boundary.  (Gradient compression uses flat block-256 quantization —
that runs *inside* shard_map on local shards, where blocks are local.)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedArray:
    """int8 payload (param-shaped) + f32 row scales (last dim = 1)."""

    q: jax.Array          # int8, same shape as the source array
    scale: jax.Array      # f32, shape[:-1] + (1,)

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, _, children):
        q, scale = children
        return cls(q=q, scale=scale)

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return jnp.int8


def quantize(x: jax.Array) -> QuantizedArray:
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
        absmax = jnp.max(jnp.abs(xf), keepdims=True)
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return QuantizedArray(q=q[0], scale=scale[0])
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QuantizedArray(q=q, scale=scale)


def dequantize(qa: QuantizedArray) -> jax.Array:
    if qa.q.ndim == 0:
        return qa.q.astype(jnp.float32) * qa.scale
    return qa.q.astype(jnp.float32) * qa.scale


def maybe_quantize(x: jax.Array, dtype: str, block: int = 0):
    """'float32' | 'bfloat16' | 'int8' storage for a moment tensor."""
    del block
    if dtype == "int8":
        return quantize(x)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def maybe_dequantize(x) -> jax.Array:
    if isinstance(x, QuantizedArray):
        return dequantize(x)
    return x.astype(jnp.float32)


def moment_pspec(param_spec, moment_dtype: str):
    """PartitionSpec tree entry for one moment of one parameter."""
    from jax.sharding import PartitionSpec as P
    if moment_dtype != "int8":
        return param_spec
    entries = tuple(param_spec)
    scale_spec = P(*(entries[:-1] + (None,))) if entries else P()
    return QuantizedArray(q=param_spec, scale=scale_spec)
