"""Optimizers: SGD/momentum/AdamW (quantizable moments), TRON,
schedules, Polyak iterate averaging."""
from repro.optim.optimizers import (
    Optimizer, AdamWConfig, sgd, adamw, make_optimizer,
)
from repro.optim.schedules import constant, warmup_cosine, inverse_sqrt, make
from repro.optim.tron import tron_minimize, TronResult
from repro.optim.averaging import (
    init_average, polyak_update, average_or_none,
)
from repro.optim.quantized_state import (
    QuantizedArray, quantize, dequantize, maybe_quantize, maybe_dequantize,
)

__all__ = [
    "Optimizer", "AdamWConfig", "sgd", "adamw", "make_optimizer",
    "constant", "warmup_cosine", "inverse_sqrt", "make",
    "tron_minimize", "TronResult",
    "init_average", "polyak_update", "average_or_none",
    "QuantizedArray", "quantize", "dequantize", "maybe_quantize",
    "maybe_dequantize",
]
