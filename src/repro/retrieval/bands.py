"""LSH band keys extracted directly from packed b-bit codes.

A packed row (core.bbit.pack_codes) is the row-major bitstream of k
b-bit codes, LSB-first within each byte: code j occupies bits
[j*b, (j+1)*b).  Band ``i`` of ``r`` codes is therefore the contiguous
bit span [i*r*b, (i+1)*r*b) — extracting it needs no unpack, just an
unaligned little-endian load:

    start = i*r*b;  byte0 = start // 8;  shift = start % 8
    key   = (Σ_t bytes[byte0+t] << 8t) >> shift  &  (2^(r·b) − 1)

With r·b ≤ 56 the gather fits one uint64 (worst case shift 7 + 56 bits
≤ 63).  When r·b is a whole number of bytes the bands tile the row and
the shift vanishes (fast path).  ``band_keys_ref`` recomputes the same
keys from unpacked codes; tests assert bit-parity for aligned and
unaligned b alike.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.bbit import packed_width

# One uint64 must hold shift (≤7) + r·b band bits.
MAX_BAND_BITS = 56


def band_geometry(k: int, b: int, rows_per_band: int) -> int:
    """Validates (k, b, r) banding and returns the band count k/r."""
    r = int(rows_per_band)
    if r < 1:
        raise ValueError(f"rows_per_band must be >= 1, got {r}")
    if k % r:
        raise ValueError(
            f"rows_per_band must divide k: k={k}, rows_per_band={r}")
    if r * b > MAX_BAND_BITS:
        raise ValueError(
            f"band of {r}x{b}-bit codes = {r * b} bits exceeds the "
            f"{MAX_BAND_BITS}-bit uint64 extraction limit")
    return k // r


def band_keys_packed(
    packed: np.ndarray, k: int, b: int, rows_per_band: int,
) -> np.ndarray:
    """Packed uint8 (n, ceil(k·b/8)) → uint64 band keys (n, k/r).

    No unpack: each key is one unaligned little-endian uint64 load from
    the row bitstream (module docstring).  Bit-exact against
    ``band_keys_ref`` over ``unpack_codes``.
    """
    r = int(rows_per_band)
    nb = band_geometry(k, b, r)
    packed = np.asarray(packed, dtype=np.uint8)
    if packed.ndim != 2 or packed.shape[1] != packed_width(k, b):
        raise ValueError(
            f"expected packed shape (n, {packed_width(k, b)}), "
            f"got {packed.shape}")
    n = packed.shape[0]
    rb = r * b
    if rb % 8 == 0:
        bb = rb // 8                       # whole-byte bands tile the row
        v = packed[:, : nb * bb].reshape(n, nb, bb).astype(np.uint64)
        weights = (np.arange(bb, dtype=np.uint64) * np.uint64(8))
        return (v << weights[None, None, :]).sum(axis=2, dtype=np.uint64)
    starts = np.arange(nb, dtype=np.int64) * rb
    byte0 = starts // 8
    shift = (starts % 8).astype(np.uint64)
    span = (rb + 7) // 8 + 1               # bytes covering shift + rb bits
    padded = np.pad(packed, ((0, 0), (0, span)))
    cols = byte0[:, None] + np.arange(span, dtype=np.int64)[None, :]
    v = padded[:, cols].astype(np.uint64)  # (n, nb, span)
    weights = (np.arange(span, dtype=np.uint64) * np.uint64(8))
    acc = (v << weights[None, None, :]).sum(axis=2, dtype=np.uint64)
    mask = np.uint64((1 << rb) - 1)
    return (acc >> shift[None, :]) & mask


def band_keys_ref(
    codes: np.ndarray, b: int, rows_per_band: int,
) -> np.ndarray:
    """Unpacked uint16 codes (n, k) → uint64 band keys (n, k/r).

    The reference: within a band, code t contributes bits [t·b, (t+1)·b)
    — exactly the packed bitstream's layout.
    """
    r = int(rows_per_band)
    n, k = codes.shape
    nb = band_geometry(k, b, r)
    mask = np.uint64((1 << b) - 1)
    c = codes.astype(np.uint64).reshape(n, nb, r) & mask
    weights = (np.arange(r, dtype=np.uint64) * np.uint64(b))
    return (c << weights[None, None, :]).sum(axis=2, dtype=np.uint64)


def band_signature(
    packed_row: np.ndarray,
    k: int,
    b: int,
    rows_per_band: int,
    probe_bands: Optional[int] = None,
) -> Tuple[int, ...]:
    """One packed row → hashable probe tuple of its first bands.

    The dedup cache's probe key: a *subset* of bands (all bands
    concatenated would just be the full code, making the equality guard
    redundant).  ``probe_bands=None`` keeps every band.
    """
    keys = band_keys_packed(np.asarray(packed_row)[None, :], k, b,
                            rows_per_band)[0]
    if probe_bands is not None:
        if probe_bands < 1:
            raise ValueError(f"probe_bands must be >= 1, got {probe_bands}")
        keys = keys[:probe_bands]
    return tuple(int(x) for x in keys)
