"""Banded LSH inverted index over packed b-bit code rows.

Insert puts each document's k/r band keys into per-band posting dicts;
query gathers the union of posting lists for the query's bands (any
shared band ⇒ candidate — collision probability ~R^r for resemblance
R), then ranks the candidate set by exact packed-popcount Hamming
similarity through ``ops.hamming_topk`` (Pallas kernel or XLA
``population_count``, the cost model's call).  Distances are over the
b-bit codes themselves, so similarity here estimates the paper's code
agreement P_b, a monotone proxy for resemblance (Eq. 6 regime) —
``benchmarks/retrieval_bench.py`` measures recall@k against exact
brute-force resemblance.

Deletes tombstone the slot (posting entries are removed eagerly; the
row array keeps its position so candidate slots stay stable).  The
index is for densified fixed-width codes (minwise / oph); zero-coded
rows would need mask-aware distances.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.bbit import packed_width
from repro.retrieval.bands import band_geometry, band_keys_packed


class BandedLSHIndex:
    """Insert/query/delete over packed codes, banded at r rows/band."""

    def __init__(self, k: int, b: int, rows_per_band: int = 4):
        self.k = int(k)
        self.b = int(b)
        self.rows_per_band = int(rows_per_band)
        self.n_bands = band_geometry(self.k, self.b, self.rows_per_band)
        self.width = packed_width(self.k, self.b)
        self._lock = threading.Lock()
        self._rows: List[np.ndarray] = []          # slot -> packed row
        self._ids: List[Optional[object]] = []     # slot -> id | tombstone
        self._slot_of: Dict[object, int] = {}
        self._postings: List[Dict[int, Set[int]]] = [
            {} for _ in range(self.n_bands)]

    def __len__(self) -> int:
        return len(self._slot_of)

    def _keys(self, packed: np.ndarray) -> np.ndarray:
        return band_keys_packed(packed, self.k, self.b, self.rows_per_band)

    def insert(self, ids: Sequence[object], packed: np.ndarray) -> None:
        """Adds rows; an already-present id is replaced (delete+insert)."""
        packed = np.atleast_2d(np.asarray(packed, dtype=np.uint8))
        if packed.shape[1] != self.width:
            raise ValueError(
                f"expected packed width {self.width}, got {packed.shape[1]}")
        if len(ids) != packed.shape[0]:
            raise ValueError("ids/rows length mismatch")
        keys = self._keys(packed)
        with self._lock:
            for i, doc_id in enumerate(ids):
                if doc_id in self._slot_of:
                    self._delete_locked(doc_id)
                slot = len(self._rows)
                self._rows.append(packed[i].copy())
                self._ids.append(doc_id)
                self._slot_of[doc_id] = slot
                for j in range(self.n_bands):
                    self._postings[j].setdefault(
                        int(keys[i, j]), set()).add(slot)

    def _delete_locked(self, doc_id: object) -> None:
        slot = self._slot_of.pop(doc_id)
        keys = self._keys(self._rows[slot][None, :])[0]
        for j in range(self.n_bands):
            key = int(keys[j])
            bucket = self._postings[j].get(key)
            if bucket is not None:
                bucket.discard(slot)
                if not bucket:
                    del self._postings[j][key]
        self._ids[slot] = None

    def delete(self, ids: Sequence[object]) -> int:
        """Removes ids (missing ones ignored); returns how many existed."""
        removed = 0
        with self._lock:
            for doc_id in ids:
                if doc_id in self._slot_of:
                    self._delete_locked(doc_id)
                    removed += 1
        return removed

    def candidates(self, packed_q: np.ndarray,
                   probe_bands: Optional[int] = None) -> List[int]:
        """Sorted candidate slots colliding with the query in ≥1 of the
        first ``probe_bands`` bands (all bands by default)."""
        packed_q = np.asarray(packed_q, dtype=np.uint8).reshape(1, -1)
        keys = self._keys(packed_q)[0]
        probe = self.n_bands if probe_bands is None else min(
            int(probe_bands), self.n_bands)
        out: Set[int] = set()
        with self._lock:
            for j in range(probe):
                out |= self._postings[j].get(int(keys[j]), set())
        return sorted(out)

    def query(
        self,
        packed_q: np.ndarray,
        top_k: int = 10,
        probe_bands: Optional[int] = None,
    ) -> Tuple[List[object], np.ndarray]:
        """One query row → (ids, sims) of its top-k band-collision
        candidates, ranked by exact packed Hamming similarity."""
        from repro.kernels import ops
        packed_q = np.asarray(packed_q, dtype=np.uint8).reshape(-1)
        if packed_q.shape[0] != self.width:
            raise ValueError(
                f"expected packed width {self.width}, got {packed_q.shape[0]}")
        slots = self.candidates(packed_q, probe_bands)
        if not slots:
            return [], np.zeros((0,), dtype=np.float32)
        with self._lock:
            cands = np.stack([self._rows[s] for s in slots])
        idx, sims = ops.hamming_topk(packed_q, cands, k=self.k, bits=self.b,
                                     topk=top_k)
        idx = np.asarray(idx)
        ids = [self._ids[slots[i]] for i in idx]
        return ids, np.asarray(sims)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            buckets = sum(len(p) for p in self._postings)
            posting_refs = sum(len(s) for p in self._postings
                               for s in p.values())
            # rows + per-band dict entries (key uint64 + slot refs, ~16B
            # each as a flat-array bound; python dicts cost more, this
            # tracks the scaling not the interpreter constant)
            bytes_est = (len(self._rows) * self.width
                         + 16 * (buckets + posting_refs))
            return {
                "entries": len(self._slot_of),
                "tombstones": len(self._rows) - len(self._slot_of),
                "bands": self.n_bands,
                "rows_per_band": self.rows_per_band,
                "band_bits": self.rows_per_band * self.b,
                "buckets": buckets,
                "posting_refs": posting_refs,
                "bytes_est": bytes_est,
            }
