"""Banded LSH retrieval over packed b-bit minwise codes.

The codes this repo already packs (core.bbit: row-major bitstream,
LSB-first) are LSH-ready: split each row's k codes into k/r bands of r
consecutive codes and two documents collide in a band with probability
~R^r (R = resemblance, paper Eq. 6 regime).  ``bands`` extracts band
keys straight from the packed bytes (no unpack), ``index`` is the
banded inverted index, and candidate sets are ranked by packed-popcount
Hamming similarity through the ``hamming_topk`` dispatch op
(kernels/hamming.py Pallas kernel on TPU, XLA ``population_count``
elsewhere).  The serving dedup cache (serving/dedup.py) reuses the same
band machinery inward as a probe key for duplicate traffic.
"""
from repro.retrieval.bands import (
    band_geometry,
    band_keys_packed,
    band_keys_ref,
    band_signature,
)
from repro.retrieval.index import BandedLSHIndex

__all__ = [
    "BandedLSHIndex",
    "band_geometry",
    "band_keys_packed",
    "band_keys_ref",
    "band_signature",
]
