"""Pallas TPU kernels: fused hash → b-bit → pack encode pipeline.

The unfused pipeline (`kernels/minhash.py`, `kernels/oph.py`) ships the
full uint32 minima — n·k·4 bytes — back to the host, where b-bit
extraction (`core/bbit.py`) and numpy bit-packing run serially.  At the
paper's claimed throughput (§6 Table 2: GPU hashing ≪ data loading)
that host round-trip IS the pipeline; these kernels remove it by
emitting the on-disk representation directly:

  * the running min lives in a VMEM scratch accumulator, revisited
    across the nnz grid dimension (HBM traffic identical to the
    unfused kernels — each nonzero block is still read once);
  * on the FINAL nnz grid step the accumulator is finished in-register:
    b-bit mask (and for OPH, rotation densification or zero-coding),
    then 8/b codes packed per output byte — so only n·ceil(k·b/8)
    packed bytes ever leave the device instead of n·k·4.

Packing layout is bit-exact with ``core.bbit.pack_codes`` (row-major
bitstream, LSB-first within each byte): byte j of a row holds codes
j·(8/b) … (j+1)·(8/b)−1, code t at bit offset t·b.  Requires b ∈
{1, 2, 4, 8} so codes never straddle bytes (other b fall back to the
XLA path, ``core.bbit.pack_codes_jnp``).  The ``oph_zero`` variant
additionally packs the empty-bin bitmask MSB-first — the
``np.packbits`` layout the shard format stores.

In-kernel densification mirrors ``core.oph.densify_rotation``: the
next-non-empty-bin search is a reverse cummin over doubled (circular)
lanes, and the borrow gather is lane-broadcast compare-select — the
same VPU-style trick as the scatter-min — since a true gather is
TPU-hostile.  O(k²) selects per row, done ONCE per row versus O(k·nnz)
work in the main loop.

Layout caveat: packed output rows are ceil(k·b/8) bytes, which for
small k·b is narrower than the 128-lane tile; interpret mode (CPU CI)
is exact for any shape, while a compiled TPU deployment should keep
k·b ≥ 1024 (e.g. k=256, b≥4) or accept lane padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.minhash import _fmix32

PACK_BITS = (1, 2, 4, 8)   # b where codes never straddle byte bounds

# Rotation offset constant — must match core.oph._ROT_C bit-exactly.
_ROT_C = 0x9E3779B1


def _check_bits(bits: int) -> None:
    if bits not in PACK_BITS:
        raise ValueError(
            f"fused packing needs b ∈ {PACK_BITS}, got {bits}")


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pack_lanes(codes, width: int, bits: int):
    """(bn, L) uint32 codes < 2^bits → (bn, width) uint8, LSB-first.

    L must equal width·(8/bits); lanes beyond the logical k are expected
    to be zeroed by the caller so padding bits match ``pack_codes``.
    """
    r = 8 // bits
    packed = jnp.zeros((codes.shape[0], width), jnp.uint32)
    for t in range(r):
        packed = packed | (codes[:, t::r] << jnp.uint32(t * bits))
    return packed.astype(jnp.uint8)


def _pack_mask_lanes(mask, width: int):
    """(bn, width·8) bool → (bn, width) uint8, MSB-first (packbits)."""
    packed = jnp.zeros((mask.shape[0], width), jnp.uint32)
    for t in range(8):
        packed = packed | (mask[:, t::8].astype(jnp.uint32)
                           << jnp.uint32(7 - t))
    return packed.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Fused minwise: k-permutation min-hash → b-bit → packed bytes.
# ---------------------------------------------------------------------------
def _minhash_pack_kernel(idx_ref, nnz_ref, a_ref, b_ref, out_ref, acc_ref, *,
                         mc: int, bits: int, k: int, bk: int, nc: int):
    """One (doc-block, hash-block, nnz-block) grid step.

    Minima accumulate in VMEM scratch across grid dim 2; the final step
    masks to b bits, zeroes lanes ≥ k (param padding), and packs.
    """
    j = pl.program_id(1)
    c = pl.program_id(2)
    sentinel = jnp.uint32(0xFFFFFFFF)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sentinel)

    idx = idx_ref[...].astype(jnp.uint32)            # (BN, MC)
    nnz = nnz_ref[...]                               # (BN,)
    a = a_ref[...]                                   # (BK,)
    b = b_ref[...]                                   # (BK,)
    bn = idx.shape[0]
    col = c * mc + jax.lax.broadcasted_iota(jnp.int32, (bn, mc), 1)
    valid = col < nnz[:, None]                       # (BN, MC)
    h = _fmix32(a[None, None, :] * idx[:, :, None] + b[None, None, :])
    h = jnp.where(valid[:, :, None], h, sentinel)    # (BN, MC, BK)
    acc_ref[...] = jnp.minimum(acc_ref[...], jnp.min(h, axis=1))

    @pl.when(c == nc - 1)
    def _finish():
        codes = acc_ref[...] & jnp.uint32((1 << bits) - 1)
        lane = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
        codes = jnp.where(lane < k, codes, jnp.uint32(0))
        out_ref[...] = _pack_lanes(codes, bk * bits // 8, bits)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_n", "block_k", "block_m", "interpret"),
)
def minhash_pack_pallas(
    indices: jax.Array,
    nnz: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    block_n: int = 8,
    block_k: int = 128,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """uint8 (n, ceil(k·bits/8)) packed b-bit min-hash codes.

    Bit-identical to ``pack_codes(bbit_codes(minhash_pallas(...), bits),
    bits)`` — validated by tests/test_fused_encode.py — at 1/(32/bits)
    of the device→host traffic.

    Args:
      indices: int32 (n, m), contiguously padded rows.
      nnz:     int32 (n,) valid prefix length per row.
      a, b:    uint32 (k,) multiply-shift params (a odd).
      bits:    b ∈ {1, 2, 4, 8}.
    """
    _check_bits(bits)
    n, m = indices.shape
    k = a.shape[0]
    bn = min(block_n, n)
    # hash-block must be a multiple of 8 so each out byte is intra-block
    bk = _round_up(min(block_k, _round_up(k, 8)), 8)
    mc = min(block_m, m)

    def _pad_to(x, mult, axis, value):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths, constant_values=value)

    idx_p = _pad_to(_pad_to(indices, bn, 0, 0), mc, 1, 0)
    nnz_p = _pad_to(nnz, bn, 0, 0)
    a_p = _pad_to(a, bk, 0, jnp.uint32(1))
    b_p = _pad_to(b, bk, 0, jnp.uint32(0))
    np_, mp_ = idx_p.shape
    kp_ = a_p.shape[0]
    nc = mp_ // mc
    ob = bk * bits // 8                   # packed bytes per hash-block

    grid = (np_ // bn, kp_ // bk, nc)
    out = pl.pallas_call(
        functools.partial(_minhash_pack_kernel, mc=mc, bits=bits, k=k,
                          bk=bk, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, mc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bn,), lambda i, j, c: (i,)),
            pl.BlockSpec((bk,), lambda i, j, c: (j,)),
            pl.BlockSpec((bk,), lambda i, j, c: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, ob), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, kp_ * bits // 8), jnp.uint8),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.uint32)],
        interpret=interpret,
    )(idx_p, nnz_p, a_p, b_p)
    return out[:n, :(k * bits + 7) // 8]


# ---------------------------------------------------------------------------
# Fused OPH: bin minima → densify/zero-code → b-bit → packed bytes.
# ---------------------------------------------------------------------------
def _oph_pack_kernel(a_ref, b_ref, idx_ref, nnz_ref, out_ref, eout_ref,
                     acc_ref, *, mc: int, shift: int, k: int, kp: int,
                     bits: int, densify: bool, nc: int, ow: int, ew: int):
    """One (doc-block, nnz-block) grid step: hash once, min-scatter into
    scratch; densify + pack on the final step."""
    c = pl.program_id(1)
    sentinel = jnp.uint32(0xFFFFFFFF)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sentinel)

    idx = idx_ref[...].astype(jnp.uint32)            # (BN, MC)
    nnz = nnz_ref[...]                               # (BN,)
    bn = idx.shape[0]
    col = c * mc + jax.lax.broadcasted_iota(jnp.int32, (bn, mc), 1)
    valid = col < nnz[:, None]

    h = _fmix32(a_ref[0, 0] * idx + b_ref[0, 0])     # ONE hash per nonzero
    bins = (h >> jnp.uint32(shift)).astype(jnp.int32)
    hv = jnp.where(valid, h, sentinel)
    lane = jax.lax.broadcasted_iota(jnp.int32, (bn, mc, kp), 2)
    scat = jnp.where(bins[:, :, None] == lane, hv[:, :, None], sentinel)
    acc_ref[...] = jnp.minimum(acc_ref[...], jnp.min(scat, axis=1))

    @pl.when(c == nc - 1)
    def _finish():
        vals = acc_ref[...]                          # (BN, KP)
        vk = vals[:, :k] if kp > k else vals         # logical bins only
        ek = vk == sentinel                          # (BN, K) empty bins
        mask_b = jnp.uint32((1 << bits) - 1)
        if densify:
            # next non-empty bin at-or-after j, circular: reverse cummin
            # over doubled lanes (== core.oph.densify_rotation).
            ne2 = jnp.concatenate([~ek, ~ek], axis=1)            # (BN, 2K)
            iota2 = jax.lax.broadcasted_iota(jnp.int32, (bn, 2 * k), 1)
            cand = jnp.where(ne2, iota2, jnp.int32(2 * k))
            nxt = jax.lax.cummin(cand, axis=1, reverse=True)[:, :k]
            iota_k = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1)
            dist = nxt - iota_k
            src = jnp.where(nxt < 2 * k, nxt & (k - 1), 0)
            # borrow gather, the VPU way: broadcast-compare src against a
            # k-lane iota and select (exactly one lane matches).
            lane_j = jax.lax.broadcasted_iota(jnp.int32, (bn, k, k), 2)
            borrowed = jnp.min(
                jnp.where(src[:, :, None] == lane_j, vk[:, None, :],
                          sentinel), axis=2)
            borrowed = borrowed + dist.astype(jnp.uint32) * jnp.uint32(
                _ROT_C)
            all_empty = jnp.all(ek, axis=1, keepdims=True)
            dense = jnp.where(all_empty | (nxt >= 2 * k), sentinel,
                              borrowed)
            codes = dense & mask_b    # all-empty rows → all-ones bits,
        else:                         # matching the packed reference
            codes = jnp.where(ek, jnp.uint32(0), vk & mask_b)
        kpad = ow * (8 // bits)
        if kpad > k:
            codes = jnp.concatenate(
                [codes, jnp.zeros((bn, kpad - k), jnp.uint32)], axis=1)
        out_ref[...] = _pack_lanes(codes, ow, bits)
        e = ek
        if ew * 8 > k:
            e = jnp.concatenate(
                [ek, jnp.zeros((bn, ew * 8 - k), jnp.bool_)], axis=1)
        eout_ref[...] = _pack_mask_lanes(e, ew)


@functools.partial(
    jax.jit,
    static_argnames=("k", "bits", "densify", "block_n", "block_m",
                     "interpret"),
)
def oph_pack_pallas(
    indices: jax.Array,
    nnz: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    k: int,
    bits: int,
    densify: bool = True,
    block_n: int = 8,
    block_m: int = 256,
    interpret: bool = False,
):
    """(packed uint8 (n, ceil(k·bits/8)), empty uint8 (n, ceil(k/8))).

    Fused OPH encode: one hash evaluation per nonzero, running bin
    minima in VMEM scratch, then — in the same kernel pass —
    densification by rotation (``densify=True``; bit-identical to
    ``core.oph.densify_rotation``) or zero-coding (empty bins → code 0,
    reported in the MSB-first packed ``empty`` bitmask), b-bit masking
    and byte packing.  ``empty`` marks raw empty bins in both modes
    (the densified shard format simply doesn't store it).

    Args:
      indices: int32 (n, m), contiguously padded rows.
      nnz:     int32 (n,) valid prefix length per row.
      a, b:    uint32 (1,) single multiply-shift params (a odd).
      k:       number of bins; power of two.
      bits:    b ∈ {1, 2, 4, 8}.
    """
    _check_bits(bits)
    if k < 2 or (k & (k - 1)) != 0:
        raise ValueError(f"OPH kernel needs k = power of two, got {k}")
    shift = 32 - (int(k).bit_length() - 1)
    n, m = indices.shape
    bn = min(block_n, n)
    mc = min(block_m, m)
    kp = max(k, 128)
    ow = (k * bits + 7) // 8
    ew = (k + 7) // 8

    def _pad_to(x, mult, axis):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    idx_p = _pad_to(_pad_to(indices, bn, 0), mc, 1)
    nnz_p = _pad_to(nnz, bn, 0)
    np_, mp_ = idx_p.shape
    nc = mp_ // mc

    grid = (np_ // bn, nc)
    packed, empty = pl.pallas_call(
        functools.partial(_oph_pack_kernel, mc=mc, shift=shift, k=k,
                          kp=kp, bits=bits, densify=densify, nc=nc,
                          ow=ow, ew=ew),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, c: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, c: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bn, mc), lambda i, c: (i, c)),
            pl.BlockSpec((bn,), lambda i, c: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, ow), lambda i, c: (i, 0)),
            pl.BlockSpec((bn, ew), lambda i, c: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, ow), jnp.uint8),
            jax.ShapeDtypeStruct((np_, ew), jnp.uint8),
        ],
        scratch_shapes=[pltpu.VMEM((bn, kp), jnp.uint32)],
        interpret=interpret,
    )(a.reshape(1, 1), b.reshape(1, 1), idx_p, nnz_p)
    return packed[:n], empty[:n]
