"""Pallas TPU kernel: packed-popcount Hamming distance for candidate
scoring.

The retrieval index ranks band-collision candidates by Hamming distance
between packed b-bit code rows (core.bbit layout).  Both rows pad the
final partial byte with zeros, so the distance is simply

    dist[i] = Σ_w popcount(cands[i, w] XOR query[w])

— no bit masking needed.  The kernel XORs a (BN, W) candidate block
against the broadcast query row and popcounts bytes with the SWAR
ladder (three shifts/adds in uint32; every value stays < 256 so the
8-bit constants suffice), accumulating int32 row sums.  The XLA twin
uses ``jax.lax.population_count`` — bit-identical (integer arithmetic),
which tests/test_retrieval.py asserts.  Top-k selection happens in the
``ops.hamming_topk`` wrapper (``jax.lax.top_k`` over negated
distances), shared by both arms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount_bytes(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of uint32 lanes holding byte values (< 256)."""
    x = x - ((x >> jnp.uint32(1)) & jnp.uint32(0x55))
    x = (x & jnp.uint32(0x33)) + ((x >> jnp.uint32(2)) & jnp.uint32(0x33))
    return (x + (x >> jnp.uint32(4))) & jnp.uint32(0x0F)


def _hamming_kernel(cands_ref, q_ref, out_ref):
    """Grid (n/BN,): one candidate block per step, full row width."""
    x = cands_ref[...].astype(jnp.uint32)           # (BN, W)
    q = q_ref[...].astype(jnp.uint32)               # (1, W)
    pc = _popcount_bytes(x ^ q)
    out_ref[...] = jnp.sum(pc.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def hamming_distance_pallas(
    query: jax.Array,
    cands: jax.Array,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """int32 (n,) popcount distances: query (w,) uint8 vs cands (n, w)."""
    n, w = cands.shape
    q = query.reshape(1, w)
    bn = min(block_n, n)
    pad_n = (-n) % bn
    cands_p = jnp.pad(cands, ((0, pad_n), (0, 0)))
    np_ = cands_p.shape[0]
    out = pl.pallas_call(
        _hamming_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.int32),
        interpret=interpret,
    )(cands_p, q)
    return out[:n, 0]


@jax.jit
def hamming_distance_xla(query: jax.Array, cands: jax.Array) -> jax.Array:
    """XLA twin: ``population_count`` over the XORed bytes."""
    x = jnp.bitwise_xor(cands, query[None, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=1)
