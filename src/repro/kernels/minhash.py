"""Pallas TPU kernel: b-bit minwise hashing preprocessing (paper §6, Table 2).

The paper showed GPU hashing cuts preprocessing to <1/7 of data-loading
time.  TPU adaptation: the hot loop is k independent multiply-shift
hashes + a min-reduction over each document's nonzeros.  We map

  * documents   → sublane-tiled grid dim 0 (BN rows),
  * hash index  → 128-lane grid dim 1 (BK lanes; k lives in lanes so the
                  VPU evaluates 128 hash functions per cycle),
  * nonzeros    → innermost grid dim 2, streamed HBM→VMEM in MC-column
                  blocks with a running min accumulated in the output
                  block (revisited across grid dim 2).

VMEM working set per step: BN·MC (indices) + BN·MC·BK (hash values)
≈ 8·256·128·4 B ≈ 1 MiB — well inside the ~16 MiB/core budget, with
MXU-free pure-VPU arithmetic (uint32 mul/add/xor/shift/min).

This kernel returns the raw uint32 minima (n·k·4 bytes to the host).
The preprocessing hot path uses ``repro.kernels.fused_encode``'s
``minhash_pack_pallas`` instead, which shares this hash loop (and
``_fmix32``) but accumulates minima in VMEM scratch and emits packed
b-bit bytes in the final nnz grid step — n·ceil(k·b/8) bytes off the
device instead of n·k·4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UINT32_MAX = jnp.uint32(0xFFFFFFFF)


def _fmix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _minhash_kernel(idx_ref, nnz_ref, a_ref, b_ref, out_ref, *, mc: int):
    """One (doc-block, hash-block, nnz-block) grid step."""
    c = pl.program_id(2)
    sentinel = jnp.uint32(0xFFFFFFFF)  # local literal: no captured consts

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, sentinel)

    idx = idx_ref[...].astype(jnp.uint32)            # (BN, MC)
    nnz = nnz_ref[...]                               # (BN,)
    a = a_ref[...]                                   # (BK,)
    b = b_ref[...]                                   # (BK,)

    bn = idx.shape[0]
    col0 = c * mc
    col = col0 + jax.lax.broadcasted_iota(jnp.int32, (bn, mc), 1)
    valid = col < nnz[:, None]                       # (BN, MC)

    h = _fmix32(a[None, None, :] * idx[:, :, None] + b[None, None, :])
    h = jnp.where(valid[:, :, None], h, sentinel)    # (BN, MC, BK)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(h, axis=1))


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_k", "block_m", "interpret"),
)
def minhash_pallas(
    indices: jax.Array,
    nnz: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    block_n: int = 8,
    block_k: int = 128,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """uint32 (n, k) min-hashes of each row's first nnz[i] indices.

    Args:
      indices: int32 (n, m), contiguously padded rows.
      nnz:     int32 (n,) valid prefix length per row.
      a, b:    uint32 (k,) multiply-shift params (a odd).
    """
    n, m = indices.shape
    k = a.shape[0]
    bn = min(block_n, n)
    bk = min(block_k, k)
    mc = min(block_m, m)

    def _pad_to(x, mult, axis, value):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths, constant_values=value)

    idx_p = _pad_to(_pad_to(indices, bn, 0, 0), mc, 1, 0)
    nnz_p = _pad_to(nnz, bn, 0, 0)
    a_p = _pad_to(a, bk, 0, jnp.uint32(1))
    b_p = _pad_to(b, bk, 0, jnp.uint32(0))
    np_, mp_ = idx_p.shape
    kp_ = a_p.shape[0]

    grid = (np_ // bn, kp_ // bk, mp_ // mc)
    out = pl.pallas_call(
        functools.partial(_minhash_kernel, mc=mc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, mc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bn,), lambda i, j, c: (i,)),
            pl.BlockSpec((bk,), lambda i, j, c: (j,)),
            pl.BlockSpec((bk,), lambda i, j, c: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, kp_), jnp.uint32),
        interpret=interpret,
    )(idx_p, nnz_p, a_p, b_p)
    return out[:n, :k]
