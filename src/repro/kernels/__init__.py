"""Pallas TPU kernels for the perf-critical hot spots.

  minhash      — k-way multiply-shift min-hash preprocessing (paper §6)
  oph          — one-permutation hashing bin minima (arXiv:1208.1259):
                 ONE hash per nonzero vs minhash's k
  bbit_linear  — fused one-hot-expansion linear fwd/bwd (paper §3)
  vw_sketch    — VW signed feature hashing (paper §5.2)

Import ``repro.kernels.ops`` for the dispatching public API and
``repro.kernels.ref`` for the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
