"""Pallas TPU kernel: One Permutation Hashing bin minima (OPH subsystem).

The k-permutation kernel (`repro.kernels.minhash`) streams every
nonzero k/BK times — once per hash-block of grid dim 1 — and runs a
full fmix32 per (nonzero, hash) pair: O(k·nnz) hash arithmetic.  OPH
(arXiv:1208.1259) needs ONE hash per nonzero; this kernel therefore has
no hash-block grid dimension at all:

  * documents  → sublane-tiled grid dim 0 (BN rows),
  * nonzeros   → grid dim 1, streamed HBM→VMEM in MC-column blocks
                 (each nonzero is read ONCE),
  * bins       → all k live in lanes of the output block, revisited
                 across grid dim 1 with a running min.

Scatter-min into k lanes is TPU-hostile as a true scatter, so it is
done the VPU way: broadcast-compare the bin id of each nonzero against
a k-lane iota and select-min — 3 cheap VPU ops per lane versus a ~10-op
fmix32 re-evaluation per lane in the minwise kernel, on top of the k/BK×
fewer HBM reads of the index stream.

VMEM working set per step: BN·MC (indices) + BN·MC·K (compare/select)
≈ 8·256·256·4 B ≈ 2 MiB at k=256 — inside the ~16 MiB/core budget.
k must be a power of two (bin = top log2(k) bits of the hash) and is
padded to the 128-lane boundary; padded lanes never match a bin id and
fall off at the final slice.

This kernel returns the raw uint32 minima (n·k·4 bytes to the host).
The preprocessing hot path uses ``repro.kernels.fused_encode``'s
``oph_pack_pallas`` instead, which shares this kernel's grid and
scatter-min body but densifies, b-bit-masks and byte-packs in the
final grid step so only n·ceil(k·b/8) bytes leave the device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fmix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _oph_kernel(a_ref, b_ref, idx_ref, nnz_ref, out_ref, *,
                mc: int, shift: int, kp: int):
    """One (doc-block, nnz-block) grid step: hash once, min-scatter."""
    c = pl.program_id(1)
    sentinel = jnp.uint32(0xFFFFFFFF)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, sentinel)

    idx = idx_ref[...].astype(jnp.uint32)            # (BN, MC)
    nnz = nnz_ref[...]                               # (BN,)
    bn = idx.shape[0]
    col = c * mc + jax.lax.broadcasted_iota(jnp.int32, (bn, mc), 1)
    valid = col < nnz[:, None]                       # (BN, MC)

    h = _fmix32(a_ref[0, 0] * idx + b_ref[0, 0])     # ONE hash per nonzero
    bins = (h >> jnp.uint32(shift)).astype(jnp.int32)
    hv = jnp.where(valid, h, sentinel)

    # lane-parallel scatter-min: out[n, j] = min over m with bins==j
    lane = jax.lax.broadcasted_iota(jnp.int32, (bn, mc, kp), 2)
    scat = jnp.where(bins[:, :, None] == lane, hv[:, :, None], sentinel)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(scat, axis=1))


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_n", "block_m", "interpret"),
)
def oph_pallas(
    indices: jax.Array,
    nnz: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    k: int,
    block_n: int = 8,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """uint32 (n, k) OPH bin minima of each row's first nnz[i] indices.

    Empty bins hold 0xFFFFFFFF (densification / zero-coding is a cheap
    O(n·k) post-pass in ``repro.core.oph``, outside the hot loop).

    Args:
      indices: int32 (n, m), contiguously padded rows.
      nnz:     int32 (n,) valid prefix length per row.
      a, b:    uint32 (1,) single multiply-shift params (a odd).
      k:       number of bins; power of two.
    """
    if k < 2 or (k & (k - 1)) != 0:
        raise ValueError(f"OPH kernel needs k = power of two, got {k}")
    shift = 32 - (int(k).bit_length() - 1)
    n, m = indices.shape
    bn = min(block_n, n)
    mc = min(block_m, m)
    kp = max(k, 128)                      # bins live in lanes

    def _pad_to(x, mult, axis):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    idx_p = _pad_to(_pad_to(indices, bn, 0), mc, 1)
    nnz_p = _pad_to(nnz, bn, 0)
    np_, mp_ = idx_p.shape

    grid = (np_ // bn, mp_ // mc)
    out = pl.pallas_call(
        functools.partial(_oph_kernel, mc=mc, shift=shift, kp=kp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, c: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, c: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bn, mc), lambda i, c: (i, c)),
            pl.BlockSpec((bn,), lambda i, c: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, kp), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, kp), jnp.uint32),
        interpret=interpret,
    )(a.reshape(1, 1), b.reshape(1, 1), idx_p, nnz_p)
    return out[:n, :k]
