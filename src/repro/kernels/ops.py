"""Public jit'd wrappers around the Pallas kernels with jnp fallbacks.

Dispatch policy (cost-model driven, see docs/DESIGN.md §2):
  * ``minhash``      — kernel always (pure VPU streaming).
  * ``oph``          — kernel always (single-pass scatter-min; k must be
                       a power of two — the core jnp path covers the
                       rest).
  * ``bbit_linear``  — kernel for 2^b ≤ BBIT_KERNEL_MAX_V (one-hot MXU
                       contraction streams the table at line rate);
                       XLA gather for larger b where the table stream
                       would dominate.  custom_vjp wires the backward
                       kernel in.
  * ``vw_sketch``    — kernel for power-of-two buckets, jnp otherwise.

On non-TPU backends (this CPU container) the wrappers run the kernels
in interpret mode when ``interpret=None`` (auto) — the same code path a
TPU deployment exercises, minus Mosaic lowering.

Every branch here is a thin client of ``perf.choose`` — the measured
cost-model dispatch layer.  Without a loaded profile the choices are
bit-identical to the historical static policy; with one, each
(op, shape-bucket) picks whichever arm actually measured faster.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.minhash import minhash_pallas
from repro.kernels.oph import oph_pallas
from repro.kernels.fused_encode import (
    PACK_BITS,
    minhash_pack_pallas,
    oph_pack_pallas,
)
from repro.kernels.bbit_linear import (
    bbit_linear_fwd_pallas,
    bbit_linear_bwd_dw_pallas,
    bbit_linear_packed_fwd_pallas,
    bbit_linear_packed_bwd_dw_pallas,
)
from repro.kernels.hamming import (
    hamming_distance_pallas,
    hamming_distance_xla,
)
from repro.kernels.vw_sketch import vw_sketch_pallas
from repro import perf
from repro.perf import BBIT_KERNEL_MAX_V  # canonical home is perf; noqa


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return perf.choose("pallas_mode") != "compiled"


# ---------------------------------------------------------------------------
def minhash(indices, nnz, a, b, *, interpret: Optional[bool] = None):
    """uint32 (n, k) min-hashes (kernel-backed)."""
    return minhash_pallas(indices, nnz, a, b,
                          interpret=_auto_interpret(interpret))


def minhash_bbit(indices, nnz, a, b, bits: int,
                 *, interpret: Optional[bool] = None):
    """Fused min-hash + b-bit extraction → uint16 (n, k) codes."""
    z = minhash(indices, nnz, a, b, interpret=interpret)
    return (z & jnp.uint32((1 << bits) - 1)).astype(jnp.uint16)


def oph(indices, nnz, a, b, k: int, *, interpret: Optional[bool] = None):
    """uint32 (n, k) OPH bin minima (kernel-backed; k = power of two).

    Single hash pass over the nonzeros — the k×-cheaper preprocessing
    scheme.  Empty bins hold 0xFFFFFFFF; densify / zero-code via
    ``repro.core.oph``.
    """
    return oph_pallas(indices, nnz, a, b, k=k,
                      interpret=_auto_interpret(interpret))


# ---------------------------------------------------------------------------
def fused_pack_supported(bits: int) -> bool:
    """Fused hash→b-bit→pack kernels need codes that never straddle a
    byte boundary (b ∈ {1, 2, 4, 8}); other b pack on-device via XLA
    (``core.bbit.pack_codes_jnp``)."""
    return bits in PACK_BITS


def fused_encode_on_device(bits: int, *, scheme: Optional[str] = None,
                           k: Optional[int] = None,
                           rows: Optional[int] = None,
                           nnz: Optional[int] = None,
                           impl: Optional[str] = None) -> bool:
    """THE dispatch predicate for the fused encode kernels — now a thin
    client of ``perf.choose("encode_packed", ...)``.
    ``schemes.encode_packed_device`` (offline preprocessing) and
    ``schemes.encode_packed_jit`` (the serving engine's jitted
    encode→score pass) both branch on it, so the serving hot path can
    never diverge from the preprocessing dispatch policy.  Without a
    profile this reproduces the old static predicate exactly: TPU
    backend AND byte-aligned b (interpret-mode Pallas on CPU would
    crawl; XLA covers it)."""
    shape = {"b": int(bits)}
    if scheme is not None:
        shape["scheme"] = scheme
    if k is not None:
        shape["k"] = int(k)
    if rows is not None:
        shape["rows"] = int(rows)
    if nnz is not None:
        shape["nnz"] = int(nnz)
    return perf.choose("encode_packed", shape, impl=impl) == "pallas"


def minhash_packed(indices, nnz, a, b, bits: int,
                   *, interpret: Optional[bool] = None):
    """Fused min-hash + b-bit + pack → uint8 (n, ceil(k·bits/8)).

    Only the packed bytes leave the device — 1/(32/bits) of the
    ``minhash_bbit`` host↔device traffic.
    """
    return minhash_pack_pallas(indices, nnz, a, b, bits=bits,
                               interpret=_auto_interpret(interpret))


def oph_packed(indices, nnz, a, b, k: int, bits: int, *,
               densify: bool = True,
               interpret: Optional[bool] = None):
    """Fused OPH + densify/zero-code + b-bit + pack.

    Returns (packed uint8 (n, ceil(k·bits/8)), empty uint8 (n,
    ceil(k/8)) — the np.packbits empty-bin bitmask, meaningful for the
    zero-coded variant).
    """
    return oph_pack_pallas(indices, nnz, a, b, k=k, bits=bits,
                           densify=densify,
                           interpret=_auto_interpret(interpret))


# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def bbit_linear(codes: jax.Array, weights: jax.Array,
                interpret: Optional[bool] = None) -> jax.Array:
    """logits (n, C) = Σ_j W[j, codes[n,j], :] — differentiable in W."""
    return _bbit_linear_fwd_impl(codes, weights, interpret)


def _bbit_linear_fwd_impl(codes, weights, interpret):
    v = weights.shape[1]
    if v <= BBIT_KERNEL_MAX_V:
        return bbit_linear_fwd_pallas(
            codes.astype(jnp.int32), weights,
            interpret=_auto_interpret(interpret))
    return ref.bbit_linear_fwd(codes, weights)


def _bbit_linear_vjp_fwd(codes, weights, interpret):
    return _bbit_linear_fwd_impl(codes, weights, interpret), (codes, weights)


def _bbit_linear_vjp_bwd(interpret, res, dout):
    codes, weights = res
    v = weights.shape[1]
    shape = {"v": v, "k": codes.shape[1], "rows": codes.shape[0]}
    if perf.choose("logits_bwd", shape) == "kernel":
        dw = bbit_linear_bwd_dw_pallas(
            codes.astype(jnp.int32), dout.astype(jnp.float32), v,
            interpret=_auto_interpret(interpret))
    else:
        dw = ref.bbit_linear_bwd_dw(codes, dout, v)
    return (None, dw.astype(weights.dtype))


bbit_linear.defvjp(_bbit_linear_vjp_fwd, _bbit_linear_vjp_bwd)


# ---------------------------------------------------------------------------
def packed_kernel_supported(bits: int, v: int) -> bool:
    """Whether the packed-input kernels handle (b=bits, V=v): the
    in-register unpack needs byte-aligned codes, and beyond MAX_V the
    table stream dominates so the gather fallback is memory-optimal.
    The single eligibility predicate — models.linear dispatches on it
    too, so policy changes here cannot diverge from the vjp's own
    dispatch below."""
    return bits in PACK_BITS and v <= BBIT_KERNEL_MAX_V


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _bbit_linear_packed(k, bits, interpret, packed, empty, weights):
    return _bbit_linear_packed_fwd_impl(k, bits, interpret, packed,
                                        empty, weights)


def _bbit_linear_packed_fwd_impl(k, bits, interpret, packed, empty,
                                 weights):
    if packed_kernel_supported(bits, weights.shape[1]):
        return bbit_linear_packed_fwd_pallas(
            packed, weights, k=k, bits=bits, empty=empty,
            interpret=_auto_interpret(interpret))
    return ref.bbit_linear_packed_fwd(packed, weights, k, bits,
                                      empty=empty)


def _bbit_linear_packed_vjp_fwd(k, bits, interpret, packed, empty,
                                weights):
    out = _bbit_linear_packed_fwd_impl(k, bits, interpret, packed, empty,
                                       weights)
    return out, (packed, empty, weights)


def _bbit_linear_packed_vjp_bwd(k, bits, interpret, res, dout):
    packed, empty, weights = res
    v = weights.shape[1]
    shape = {"v": v, "k": k, "b": bits, "rows": packed.shape[0]}
    if perf.choose("logits_packed_bwd", shape) == "kernel":
        dw = bbit_linear_packed_bwd_dw_pallas(
            packed, dout.astype(jnp.float32), v, k=k, bits=bits,
            empty=empty, interpret=_auto_interpret(interpret))
    else:
        dw = ref.bbit_linear_packed_bwd_dw(packed, dout, v, k, bits,
                                           empty=empty)
    return (None, None, dw.astype(weights.dtype))


_bbit_linear_packed.defvjp(_bbit_linear_packed_vjp_fwd,
                           _bbit_linear_packed_vjp_bwd)


def bbit_linear_packed(packed: jax.Array, weights: jax.Array, k: int,
                       bits: int, *, empty: Optional[jax.Array] = None,
                       interpret: Optional[bool] = None) -> jax.Array:
    """logits (n, C) straight from PACKED uint8 rows — differentiable
    in W; the (n, k) int32 code matrix never materializes on the
    kernel path (in-register unpack, see bbit_linear.py).

    ``empty`` (uint8 (n, ceil(k/8)), np.packbits layout) is the
    ``oph_zero`` empty-bin bitmask: marked bins contribute nothing in
    either direction.  Integer inputs carry no gradient; the vjp
    returns dW only.
    """
    return _bbit_linear_packed(k, bits, interpret, packed, empty, weights)


# ---------------------------------------------------------------------------
def hamming_topk(query, cands, *, k: int, bits: int, topk: int,
                 impl: Optional[str] = None,
                 interpret: Optional[bool] = None):
    """Top-k nearest candidates by packed-code Hamming similarity.

    ``query`` uint8 (w,), ``cands`` uint8 (n, w) — packed b-bit code
    rows (``core.bbit`` layout, w = ceil(k·bits/8)).  Returns
    (idx int32 (t,), sims f32 (t,)) with t = min(topk, n), sims sorted
    descending: sim = 1 − popcount_dist/(k·bits), the fraction of
    agreeing code bits.  Distance arm routed through
    ``perf.choose("hamming_topk")`` — Pallas SWAR popcount vs XLA
    ``population_count`` (bit-identical integers, so the choice can
    never change results).
    """
    n = int(cands.shape[0])
    shape = {"b": int(bits), "k": int(k), "rows": n,
             "width": int(cands.shape[1])}
    if perf.choose("hamming_topk", shape, impl=impl) == "pallas":
        dist = hamming_distance_pallas(query, cands,
                                       interpret=_auto_interpret(interpret))
    else:
        dist = hamming_distance_xla(query, cands)
    t = min(int(topk), n)
    neg, idx = jax.lax.top_k(-dist, t)
    sims = 1.0 + neg.astype(jnp.float32) / jnp.float32(k * bits)
    return idx, sims


# ---------------------------------------------------------------------------
def vw_sketch(indices, values, nnz, m_buckets: int, seed: int = 0,
              *, interpret: Optional[bool] = None):
    """f32 (n, m) VW sketch (kernel for pow-2 m, jnp fallback otherwise)."""
    if m_buckets & (m_buckets - 1) == 0:
        return vw_sketch_pallas(indices, values, nnz, m_buckets, seed,
                                interpret=_auto_interpret(interpret))
    return ref.vw_sketch(indices, values, nnz, m_buckets, seed)
