"""Pallas TPU kernel: VW signed feature hashing (paper §5.2, Eq. 14).

GPU implementations scatter-add each nonzero into its bucket; TPUs have
no fast random scatter, so the TPU-native form is a masked compare
against the bucket-block's lane iota (a one-hot in registers) reduced on
the VPU — every nonzero contributes ``sign·value`` to the lane whose
bucket id matches.  Buckets are tiled in the lane dimension, nonzeros
streamed in the innermost grid dimension.

Bucket/sign hash streams are bit-identical to ``repro.core.vw`` (and
``kernels.ref.vw_sketch``); m must be a power of two (the paper sweeps
m = 2^5..2^14), else ops.py falls back to the jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fmix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _vw_kernel(idx_ref, val_ref, nnz_ref, out_ref, *, mc: int,
               m_buckets: int, bm: int, seed: int):
    """Grid (n/BN, m/BM, nnz/MC); accumulate over nnz blocks (dim 2)."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...].astype(jnp.uint32)            # (BN, MC)
    val = val_ref[...]                               # (BN, MC) f32
    nnz = nnz_ref[...]                               # (BN,)
    bn = idx.shape[0]

    col = c * mc + jax.lax.broadcasted_iota(jnp.int32, (bn, mc), 1)
    valid = col < nnz[:, None]

    hb = _fmix32(idx * jnp.uint32(0x9E3779B1) + jnp.uint32(2 * seed + 1))
    hs = _fmix32(idx ^ jnp.uint32(0x7FEB352D + seed))
    bucket = (hb & jnp.uint32(m_buckets - 1)).astype(jnp.int32)
    sign = jnp.where((hs >> jnp.uint32(31)) & 1 == 1, 1.0, -1.0)
    contrib = jnp.where(valid, val * sign, 0.0)      # (BN, MC)

    # Lane match against this bucket block: (BN, MC, BM) compare+reduce.
    lane0 = pl.program_id(1) * bm
    lanes = lane0 + jax.lax.broadcasted_iota(jnp.int32, (bn, mc, bm), 2)
    hit = (bucket[:, :, None] == lanes)
    out_ref[...] += jnp.sum(
        jnp.where(hit, contrib[:, :, None], 0.0), axis=1
    )


@functools.partial(
    jax.jit,
    static_argnames=("m_buckets", "seed", "block_n", "block_m", "block_mc",
                     "interpret"),
)
def vw_sketch_pallas(
    indices: jax.Array,
    values: jax.Array,
    nnz: jax.Array,
    m_buckets: int,
    seed: int = 0,
    *,
    block_n: int = 8,
    block_m: int = 512,
    block_mc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """f32 (n, m_buckets) VW sketches of a padded sparse batch."""
    if m_buckets & (m_buckets - 1):
        raise ValueError("vw_sketch_pallas requires power-of-two m_buckets")
    n, m = indices.shape
    bn = min(block_n, n)
    bm = min(block_m, m_buckets)
    mc = min(block_mc, m)

    def _pad(x, mult, axis, value=0):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths, constant_values=value)

    idx_p = _pad(_pad(indices, bn, 0), mc, 1)
    val_p = _pad(_pad(values, bn, 0), mc, 1)
    nnz_p = _pad(nnz, bn, 0)
    np_, mp_ = idx_p.shape

    out = pl.pallas_call(
        functools.partial(_vw_kernel, mc=mc, m_buckets=m_buckets, bm=bm,
                          seed=seed),
        grid=(np_ // bn, m_buckets // bm, mp_ // mc),
        in_specs=[
            pl.BlockSpec((bn, mc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bn, mc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bn,), lambda i, j, c: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, m_buckets), jnp.float32),
        interpret=interpret,
    )(idx_p, val_p, nnz_p)
    return out[:n]
