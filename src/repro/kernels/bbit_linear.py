"""Pallas TPU kernels: fused one-hot-expansion linear layer (paper §3).

The paper expands each hashed example into a 2^b·k-dim binary vector and
feeds it to LIBLINEAR.  Materializing that expansion costs 2^b× the
storage the method just saved.  These kernels compute

    fwd:  logits[n, c] = Σ_j  W[j, codes[n, j], c]
    bwd:  dW[j, v, c]  = Σ_n 1{codes[n, j] = v} · dout[n, c]

by building the one-hot tile *in VMEM registers* (a lane-iota compare)
and contracting it on the MXU against the (2^b, C) weight slab of each
hash function.  The expansion never touches HBM.

TPU-adaptive dispatch (see ops.py): for 2^b ≤ 4096 the streamed
one-hot·W matmul reads the whole table at HBM line rate and wins; for
b = 16 the 2^b·k·C table stream dominates and ops.py falls back to
XLA's dynamic gather (which is then memory-optimal).  This mirrors the
classic dense-vs-sparse embedding-lookup tradeoff on TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _fwd_kernel(codes_ref, w_ref, out_ref):
    """Grid (n/BN, k/BJ): accumulate over hash-function blocks (dim 1)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                          # (BN, BJ) int32
    w = w_ref[...]                                  # (BJ, V, C)
    bn, bj = codes.shape
    v = w.shape[1]

    acc = out_ref[...]
    # One-hot contraction per hash fn in the block: (BN, V) @ (V, C).
    # BJ is kept small (the weight slab BJ·V·C dominates VMEM), so this
    # unrolled loop stays short while each matmul feeds the MXU a
    # (BN × V)·(V × C) contraction with V = 2^b ∈ {2..4096}.
    for jj in range(bj):
        onehot = (codes[:, jj][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1))
        acc = acc + jax.lax.dot_general(
            onehot.astype(w.dtype), w[jj],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_j", "interpret")
)
def bbit_linear_fwd_pallas(
    codes: jax.Array,
    weights: jax.Array,
    *,
    block_n: int = 128,
    block_j: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """logits (n, C) f32 from codes (n, k) int32 and W (k, V, C)."""
    n, k = codes.shape
    _, v, c = weights.shape
    bn = min(block_n, n)
    bj = min(block_j, k)

    pad_n = (-n) % bn
    pad_k = (-k) % bj
    codes_p = jnp.pad(codes, ((0, pad_n), (0, pad_k)))
    w_p = jnp.pad(weights, ((0, pad_k), (0, 0), (0, 0)))
    np_, kp_ = codes_p.shape

    out = pl.pallas_call(
        _fwd_kernel,
        grid=(np_ // bn, kp_ // bj),
        in_specs=[
            pl.BlockSpec((bn, bj), lambda i, j: (i, j)),
            pl.BlockSpec((bj, v, c), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, c), jnp.float32),
        interpret=interpret,
    )(codes_p, w_p)
    return out[:n]


# ---------------------------------------------------------------------------
# Backward: dW (the dcodes gradient does not exist — codes are integers)
# ---------------------------------------------------------------------------
def _bwd_kernel(codes_ref, dout_ref, dw_ref):
    """Grid (k/BJ, n/BN): accumulate over example blocks (dim 1)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    codes = codes_ref[...]                          # (BN, BJ)
    dout = dout_ref[...]                            # (BN, C)
    bn, bj = codes.shape
    v = dw_ref.shape[1]

    acc = dw_ref[...]
    for jj in range(bj):
        onehot = (codes[:, jj][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1))
        # (V, BN) @ (BN, C) on the MXU.
        contrib = jax.lax.dot_general(
            onehot.astype(dout.dtype), dout,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc.at[jj].add(contrib)
    dw_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("vsize", "block_n", "block_j", "interpret")
)
def bbit_linear_bwd_dw_pallas(
    codes: jax.Array,
    dout: jax.Array,
    vsize: int,
    *,
    block_n: int = 128,
    block_j: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """dW (k, V, C) f32 from codes (n, k) and dout (n, C)."""
    n, k = codes.shape
    c = dout.shape[1]
    bn = min(block_n, n)
    bj = min(block_j, k)

    pad_n = (-n) % bn
    pad_k = (-k) % bj
    # Padded examples point at code 0 but carry zero dout → no effect;
    # padded hash fns produce rows sliced away below.
    codes_p = jnp.pad(codes, ((0, pad_n), (0, pad_k)))
    dout_p = jnp.pad(dout, ((0, pad_n), (0, 0)))
    np_, kp_ = codes_p.shape

    dw = pl.pallas_call(
        _bwd_kernel,
        grid=(kp_ // bj, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bj), lambda j, i: (i, j)),
            pl.BlockSpec((bn, c), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bj, vsize, c), lambda j, i: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kp_, vsize, c), jnp.float32),
        interpret=interpret,
    )(codes_p, dout_p)
    return dw[:k]
