"""Pallas TPU kernels: fused one-hot-expansion linear layer (paper §3).

The paper expands each hashed example into a 2^b·k-dim binary vector and
feeds it to LIBLINEAR.  Materializing that expansion costs 2^b× the
storage the method just saved.  These kernels compute

    fwd:  logits[n, c] = Σ_j  W[j, codes[n, j], c]
    bwd:  dW[j, v, c]  = Σ_n 1{codes[n, j] = v} · dout[n, c]

by building the one-hot tile *in VMEM registers* (a lane-iota compare)
and contracting it on the MXU against the (2^b, C) weight slab of each
hash function.  The expansion never touches HBM.

Two input formats share the one-hot contraction:

  * ``bbit_linear_fwd_pallas`` / ``bbit_linear_bwd_dw_pallas`` take an
    already-widened int32 ``(n, k)`` code matrix;
  * ``bbit_linear_packed_fwd_pallas`` / ``…_packed_bwd_dw_pallas`` take
    the ON-DISK packed rows — uint8 ``(n, ceil(k·b/8))``, the
    ``core.bbit.pack_codes`` bit layout — and unpack the b-bit codes
    in-register between the VMEM load and the compare, so the widened
    matrix never exists anywhere (the streaming trainer's hot path:
    n·ceil(k·b/8) bytes HBM→VMEM instead of n·k·4).  An optional
    packed empty bitmask (``np.packbits`` layout, the ``oph_zero``
    shard side file) zeroes the marked bins' one-hot rows, fusing the
    ragged-mask path that previously forced an XLA gather.  Requires
    b ∈ {1, 2, 4, 8} so codes never straddle bytes (other b fall back
    to the XLA unpack path — see ops.py).

TPU-adaptive dispatch (see ops.py): for 2^b ≤ 4096 the streamed
one-hot·W matmul reads the whole table at HBM line rate and wins; for
b = 16 the 2^b·k·C table stream dominates and ops.py falls back to
XLA's dynamic gather (which is then memory-optimal).  This mirrors the
classic dense-vs-sparse embedding-lookup tradeoff on TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _fwd_kernel(codes_ref, w_ref, out_ref):
    """Grid (n/BN, k/BJ): accumulate over hash-function blocks (dim 1)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                          # (BN, BJ) int32
    w = w_ref[...]                                  # (BJ, V, C)
    bn, bj = codes.shape
    v = w.shape[1]

    acc = out_ref[...]
    # One-hot contraction per hash fn in the block: (BN, V) @ (V, C).
    # BJ is kept small (the weight slab BJ·V·C dominates VMEM), so this
    # unrolled loop stays short while each matmul feeds the MXU a
    # (BN × V)·(V × C) contraction with V = 2^b ∈ {2..4096}.
    for jj in range(bj):
        onehot = (codes[:, jj][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1))
        acc = acc + jax.lax.dot_general(
            onehot.astype(w.dtype), w[jj],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_j", "interpret")
)
def bbit_linear_fwd_pallas(
    codes: jax.Array,
    weights: jax.Array,
    *,
    block_n: int = 128,
    block_j: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """logits (n, C) f32 from codes (n, k) int32 and W (k, V, C)."""
    n, k = codes.shape
    _, v, c = weights.shape
    bn = min(block_n, n)
    bj = min(block_j, k)

    pad_n = (-n) % bn
    pad_k = (-k) % bj
    codes_p = jnp.pad(codes, ((0, pad_n), (0, pad_k)))
    w_p = jnp.pad(weights, ((0, pad_k), (0, 0), (0, 0)))
    np_, kp_ = codes_p.shape

    out = pl.pallas_call(
        _fwd_kernel,
        grid=(np_ // bn, kp_ // bj),
        in_specs=[
            pl.BlockSpec((bn, bj), lambda i, j: (i, j)),
            pl.BlockSpec((bj, v, c), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, c), jnp.float32),
        interpret=interpret,
    )(codes_p, w_p)
    return out[:n]


# ---------------------------------------------------------------------------
# Backward: dW (the dcodes gradient does not exist — codes are integers)
# ---------------------------------------------------------------------------
def _bwd_kernel(codes_ref, dout_ref, dw_ref):
    """Grid (k/BJ, n/BN): accumulate over example blocks (dim 1)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    codes = codes_ref[...]                          # (BN, BJ)
    dout = dout_ref[...]                            # (BN, C)
    bn, bj = codes.shape
    v = dw_ref.shape[1]

    acc = dw_ref[...]
    for jj in range(bj):
        onehot = (codes[:, jj][:, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1))
        # (V, BN) @ (BN, C) on the MXU.
        contrib = jax.lax.dot_general(
            onehot.astype(dout.dtype), dout,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc.at[jj].add(contrib)
    dw_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("vsize", "block_n", "block_j", "interpret")
)
def bbit_linear_bwd_dw_pallas(
    codes: jax.Array,
    dout: jax.Array,
    vsize: int,
    *,
    block_n: int = 128,
    block_j: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """dW (k, V, C) f32 from codes (n, k) and dout (n, C)."""
    n, k = codes.shape
    c = dout.shape[1]
    bn = min(block_n, n)
    bj = min(block_j, k)

    pad_n = (-n) % bn
    pad_k = (-k) % bj
    # Padded examples point at code 0 but carry zero dout → no effect;
    # padded hash fns produce rows sliced away below.
    codes_p = jnp.pad(codes, ((0, pad_n), (0, pad_k)))
    dout_p = jnp.pad(dout, ((0, pad_n), (0, 0)))
    np_, kp_ = codes_p.shape

    dw = pl.pallas_call(
        _bwd_kernel,
        grid=(kp_ // bj, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bj), lambda j, i: (i, j)),
            pl.BlockSpec((bn, c), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bj, vsize, c), lambda j, i: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kp_, vsize, c), jnp.float32),
        interpret=interpret,
    )(codes_p, dout_p)
    return dw[:k]


# ---------------------------------------------------------------------------
# Packed-input variants: unpack b-bit codes in-register, no (n, k) int32
# intermediate.  Bit layout matches core.bbit.pack_codes (row-major
# bitstream, LSB-first: code j·(8/b)+t sits in byte j at bit offset t·b)
# and np.packbits (MSB-first) for the empty bitmask.
# ---------------------------------------------------------------------------
def _unpack_codes_block(pk, bits: int):
    """(BN, WB) uint8 packed block → (BN, WB·8/b) int32 codes."""
    r = 8 // bits
    p = pk.astype(jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1)
    cols = jnp.stack(
        [(p >> jnp.uint32(t * bits)) & mask for t in range(r)], axis=2)
    return cols.reshape(pk.shape[0], -1).astype(jnp.int32)


def _unpack_mask_block(em):
    """(BN, EB) uint8 packbits block → (BN, EB·8) bool (MSB-first)."""
    p = em.astype(jnp.uint32)
    cols = jnp.stack(
        [(p >> jnp.uint32(7 - t)) & 1 for t in range(8)], axis=2)
    return cols.reshape(em.shape[0], -1) != 0


def _make_packed_fwd_kernel(bits: int, masked: bool):
    def kernel(pk_ref, *rest):
        if masked:
            em_ref, w_ref, out_ref = rest
        else:
            w_ref, out_ref = rest
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        codes = _unpack_codes_block(pk_ref[...], bits)   # (BN, BJ) int32
        empty = _unpack_mask_block(em_ref[...]) if masked else None
        w = w_ref[...]                                   # (BJ, V, C)
        bn, bj = codes.shape
        v = w.shape[1]

        acc = out_ref[...]
        for jj in range(bj):
            onehot = (codes[:, jj][:, None]
                      == jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1))
            if masked:
                onehot = onehot & ~empty[:, jj][:, None]
            acc = acc + jax.lax.dot_general(
                onehot.astype(w.dtype), w[jj],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        out_ref[...] = acc
    return kernel


def _packed_blocks(n, k, bits, block_n, block_j):
    """Shared block geometry: BJ is a multiple of 8 so one code block is
    a whole number of packed bytes AND a whole number of mask bytes."""
    bj = min(block_j, ((k + 7) // 8) * 8)
    bj = ((bj + 7) // 8) * 8
    bn = min(block_n, n)
    kp = ((k + bj - 1) // bj) * bj
    return bn, bj, kp


def _pad_packed_inputs(packed, empty, weights, k, bits, bn, bj, kp):
    """Pads rows to a BN multiple and the k axis to a BJ multiple.

    Padding bytes unpack to code 0 and padded weight rows are zero, so
    padded lanes contribute exactly nothing — this is what makes
    non-lane-multiple k (and the pack format's own zero padding bits in
    the final byte) exact rather than approximately masked.
    """
    n = packed.shape[0]
    pad_n = (-n) % bn
    wp = kp * bits // 8
    packed_p = jnp.pad(packed,
                       ((0, pad_n), (0, wp - packed.shape[1])))
    w_p = jnp.pad(weights, ((0, kp - k), (0, 0), (0, 0)))
    empty_p = None
    if empty is not None:
        ep = kp // 8
        empty_p = jnp.pad(empty,
                          ((0, pad_n), (0, ep - empty.shape[1])))
    return packed_p, empty_p, w_p


@functools.partial(
    jax.jit,
    static_argnames=("k", "bits", "block_n", "block_j", "interpret"),
)
def bbit_linear_packed_fwd_pallas(
    packed: jax.Array,
    weights: jax.Array,
    *,
    k: int,
    bits: int,
    empty: jax.Array = None,
    block_n: int = 128,
    block_j: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """logits (n, C) f32 straight from packed uint8 (n, ceil(k·bits/8)).

    Bit-exact vs ``unpack_codes_jnp`` + the widened kernel/gather
    (tests/test_packed_linear.py property-sweeps b, ragged masks and
    non-lane-multiple k).  ``empty`` (uint8 (n, ceil(k/8)), packbits
    layout) drops the marked bins — the ``oph_zero`` ragged-mask path,
    fused here instead of falling back to an XLA gather.
    """
    n = packed.shape[0]
    _, v, c = weights.shape
    bn, bj, kp = _packed_blocks(n, k, bits, block_n, block_j)
    packed_p, empty_p, w_p = _pad_packed_inputs(
        packed, empty, weights, k, bits, bn, bj, kp)
    np_ = packed_p.shape[0]
    wb = bj * bits // 8

    masked = empty is not None
    in_specs = [pl.BlockSpec((bn, wb), lambda i, j: (i, j))]
    args = [packed_p]
    if masked:
        in_specs.append(pl.BlockSpec((bn, bj // 8), lambda i, j: (i, j)))
        args.append(empty_p)
    in_specs.append(pl.BlockSpec((bj, v, c), lambda i, j: (j, 0, 0)))
    args.append(w_p)

    out = pl.pallas_call(
        _make_packed_fwd_kernel(bits, masked),
        grid=(np_ // bn, kp // bj),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, c), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:n]


def _make_packed_bwd_kernel(bits: int, masked: bool):
    def kernel(pk_ref, *rest):
        if masked:
            em_ref, dout_ref, dw_ref = rest
        else:
            dout_ref, dw_ref = rest
        i = pl.program_id(1)

        @pl.when(i == 0)
        def _init():
            dw_ref[...] = jnp.zeros_like(dw_ref)

        codes = _unpack_codes_block(pk_ref[...], bits)   # (BN, BJ)
        empty = _unpack_mask_block(em_ref[...]) if masked else None
        dout = dout_ref[...]                             # (BN, C)
        bn, bj = codes.shape
        v = dw_ref.shape[1]

        acc = dw_ref[...]
        for jj in range(bj):
            onehot = (codes[:, jj][:, None]
                      == jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1))
            if masked:
                onehot = onehot & ~empty[:, jj][:, None]
            contrib = jax.lax.dot_general(
                onehot.astype(dout.dtype), dout,
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            acc = acc.at[jj].add(contrib)
        dw_ref[...] = acc
    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("k", "bits", "vsize", "block_n", "block_j",
                     "interpret"),
)
def bbit_linear_packed_bwd_dw_pallas(
    packed: jax.Array,
    dout: jax.Array,
    vsize: int,
    *,
    k: int,
    bits: int,
    empty: jax.Array = None,
    block_n: int = 128,
    block_j: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """dW (k, V, C) f32 from packed rows and dout (n, C), in-register
    unpack; ``empty`` bins contribute nothing (their one-hot row is
    zeroed, matching the forward)."""
    n = packed.shape[0]
    c = dout.shape[1]
    bn, bj, kp = _packed_blocks(n, k, bits, block_n, block_j)
    packed_p, empty_p, _w = _pad_packed_inputs(
        packed, empty, jnp.zeros((k, vsize, c), jnp.float32),
        k, bits, bn, bj, kp)
    np_ = packed_p.shape[0]
    # Padded examples unpack to code 0 but carry zero dout → no effect.
    dout_p = jnp.pad(dout.astype(jnp.float32), ((0, np_ - n), (0, 0)))
    wb = bj * bits // 8

    masked = empty is not None
    in_specs = [pl.BlockSpec((bn, wb), lambda j, i: (i, j))]
    args = [packed_p]
    if masked:
        in_specs.append(pl.BlockSpec((bn, bj // 8), lambda j, i: (i, j)))
        args.append(empty_p)
    in_specs.append(pl.BlockSpec((bn, c), lambda j, i: (i, 0)))
    args.append(dout_p)

    dw = pl.pallas_call(
        _make_packed_bwd_kernel(bits, masked),
        grid=(kp // bj, np_ // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bj, vsize, c), lambda j, i: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, vsize, c), jnp.float32),
        interpret=interpret,
    )(*args)
    return dw[:k]
