"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests/test_kernels.py sweeps shapes/dtypes and asserts
allclose / exact equality in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.universal_hash import _fmix32

UINT32_MAX = jnp.uint32(0xFFFFFFFF)


def minhash(indices: jax.Array, nnz: jax.Array, a: jax.Array,
            b: jax.Array) -> jax.Array:
    """Min of fmix32(a_j·t + b_j) over each row's first nnz indices.

    indices: int32 (n, m) contiguously padded; nnz: int32 (n,);
    a, b: uint32 (k,).  Returns uint32 (n, k).
    """
    m = indices.shape[1]
    mask = jnp.arange(m, dtype=jnp.int32)[None, :] < nnz[:, None]
    tu = indices.astype(jnp.uint32)
    h = _fmix32(a[None, None, :] * tu[:, :, None] + b[None, None, :])
    h = jnp.where(mask[:, :, None], h, UINT32_MAX)
    return jnp.min(h, axis=1)


def bbit_linear_fwd(codes: jax.Array, weights: jax.Array) -> jax.Array:
    """logits[n, c] = Σ_j W[j, codes[n, j], c].

    codes: int32 (n, k) in [0, 2^b);  weights: (k, 2^b, C) float.
    Returns (n, C) in weights.dtype's accumulation type (float32).
    """
    gathered = jnp.take_along_axis(
        weights[None],
        codes.astype(jnp.int32)[:, :, None, None],
        axis=2,
    )[:, :, 0, :]
    return gathered.astype(jnp.float32).sum(axis=1)


def bbit_linear_bwd_dw(codes: jax.Array, dout: jax.Array,
                       vsize: int) -> jax.Array:
    """dW[j, v, c] = Σ_n 1{codes[n,j]=v}·dout[n,c].  Returns (k, V, C) f32."""
    n, k = codes.shape
    onehot = jax.nn.one_hot(codes.astype(jnp.int32), vsize,
                            dtype=jnp.float32)            # (n, k, V)
    return jnp.einsum("nkv,nc->kvc", onehot, dout.astype(jnp.float32))


def bbit_linear_packed_fwd(packed: jax.Array, weights: jax.Array,
                           k: int, bits: int,
                           empty: jax.Array = None) -> jax.Array:
    """Packed-input oracle: unpack (XLA) → gather → mask → sum.

    packed: uint8 (n, ceil(k·bits/8)) in the ``core.bbit.pack_codes``
    layout; empty: uint8 (n, ceil(k/8)) packbits bitmask or None.
    Semantic ground truth for the packed Pallas kernels AND the non-TPU
    fallback ops.py dispatches to — the widened (n, k) matrix exists
    here only as a fused in-step temporary.
    """
    from repro.core.bbit import unpack_codes_jnp, unpack_mask_jnp

    codes = unpack_codes_jnp(packed, k, bits).astype(jnp.int32)
    gathered = jnp.take_along_axis(
        weights[None], codes[:, :, None, None], axis=2,
    )[:, :, 0, :].astype(jnp.float32)
    if empty is not None:
        mask = unpack_mask_jnp(empty, k)
        gathered = jnp.where(mask[:, :, None], 0.0, gathered)
    return gathered.sum(axis=1)


def bbit_linear_packed_bwd_dw(packed: jax.Array, dout: jax.Array,
                              vsize: int, k: int, bits: int,
                              empty: jax.Array = None) -> jax.Array:
    """dW[j, v, c] = Σ_n 1{codes[n,j]=v ∧ ¬empty[n,j]}·dout[n,c]."""
    from repro.core.bbit import unpack_codes_jnp, unpack_mask_jnp

    codes = unpack_codes_jnp(packed, k, bits).astype(jnp.int32)
    onehot = jax.nn.one_hot(codes, vsize, dtype=jnp.float32)   # (n, k, V)
    if empty is not None:
        mask = unpack_mask_jnp(empty, k)
        onehot = jnp.where(mask[:, :, None], 0.0, onehot)
    return jnp.einsum("nkv,nc->kvc", onehot, dout.astype(jnp.float32))


def vw_sketch(indices: jax.Array, values: jax.Array, nnz: jax.Array,
              m_buckets: int, seed: int) -> jax.Array:
    """Signed feature hashing into m buckets (paper Eq. 14), f32 (n, m).

    Bucket/sign streams must match the kernel bit-for-bit:
      hb = fmix32(i·0x9E3779B1 + (2·seed+1));  bucket = hb & (m-1)
      hs = fmix32(i ^ (0x7FEB352D + seed));    sign = ±1 from bit 31
    """
    n, mx = indices.shape
    mask = jnp.arange(mx, dtype=jnp.int32)[None, :] < nnz[:, None]
    iu = indices.astype(jnp.uint32)
    hb = _fmix32(iu * jnp.uint32(0x9E3779B1) + jnp.uint32(2 * seed + 1))
    hs = _fmix32(iu ^ jnp.uint32(0x7FEB352D + seed))
    bucket = (hb & jnp.uint32(m_buckets - 1)).astype(jnp.int32)
    sign = jnp.where((hs >> jnp.uint32(31)) & 1 == 1, 1.0, -1.0)
    contrib = jnp.where(mask, values * sign, 0.0)
    out = jnp.zeros((n, m_buckets), dtype=jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], indices.shape)
    return out.at[rows, bucket].add(contrib)
