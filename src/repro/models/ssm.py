"""Mamba2 (SSD) block — chunked state-space duality, pure JAX.

Faithful to the minimal SSD formulation (Dao & Gu 2024): per head h with
state size N, input x_t (head_dim P), gate dt_t > 0, decay A < 0:

    h_t = exp(dt_t·A) h_{t-1} + dt_t·B_t x_tᵀ       (N × P matrix state)
    y_t = C_tᵀ h_t + D x_t

Computed chunk-parallel: intra-chunk quadratic term + inter-chunk
state recurrence (a short ``lax.scan`` over chunks).  ``n_groups = 1``
(B/C shared across heads — Mamba2's default; noted in DESIGN.md).

``decode_step`` carries (matrix state, conv buffer) — O(1) per token,
which is what makes the zamba2/xlstm long_500k cells servable.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def init_mamba2_params(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    d_in, nh, n = ssm_dims(cfg)
    cw = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    proj_dim = 2 * d_in + 2 * n + nh      # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_dim)) * d ** -0.5
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cw, d_in + 2 * n)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_in + 2 * n,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5
                     ).astype(dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) → (..., L, L) lower-tri segment sums Σ_{s<i≤t} x_i."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H) fp32 (softplused)
    a: jax.Array,     # (H,) fp32 negative decay
    b_in: jax.Array,  # (B, S, N)
    c_in: jax.Array,  # (B, S, N)
    h0: jax.Array,    # (B, H, N, P) initial state
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final state (B,H,N,P))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_in.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    da = dtc * a[None, None, None, :]                # (B,nc,l,H)
    da_t = jnp.moveaxis(da, -1, -2)                  # (B,nc,H,l)
    # intra-chunk (diagonal block) term
    ell = jnp.exp(_segsum(da_t))                     # (B,nc,H,l,l)
    y_diag = jnp.einsum("bzln,bzmn,bzhlm,bzmhp,bzmh->bzlhp",
                        cc, bc, ell, xc, dtc)
    # per-chunk outgoing state
    da_cum = jnp.cumsum(da_t, axis=-1)               # (B,nc,H,l)
    decay_out = jnp.exp(da_cum[..., -1:] - da_cum)   # (B,nc,H,l)
    states = jnp.einsum("bzln,bzhl,bzlhp,bzlh->bzhnp",
                        bc, decay_out, xc, dtc)      # (B,nc,H,N,P)
    chunk_decay = jnp.exp(da_cum[..., -1])           # (B,nc,H)

    # inter-chunk recurrence
    def step(carry, inp):
        st, dec = inp                                # (B,H,N,P),(B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                            # emit incoming state

    final, h_in = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                  # (B,nc,H,N,P)

    # inter-chunk (off-diagonal) contribution
    state_decay_in = jnp.exp(da_cum)                 # (B,nc,H,l)
    y_off = jnp.einsum("bzln,bzhnp,bzhl->bzlhp", cc, h_in, state_decay_in)
    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, p)[:, :s]
    return y, final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array = None):
    """Depthwise causal conv; x (B,S,C), w (W,C).  Returns (y, new_state).

    ``state`` (B, W-1, C) carries the last W-1 inputs for decode.
    """
    width = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(x_pad[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(width))
    new_state = x_pad[:, -(width - 1):]
    return out + b[None, None], new_state


def mamba2_forward(
    params: dict, x: jax.Array, cfg: ArchConfig,
    *, h0=None, conv0=None, chunk: int = 128,
):
    """x (B,S,D) → (y (B,S,D), (state, conv_state)) — train & prefill."""
    bsz, s, d = x.shape
    d_in, nh, n = ssm_dims(cfg)
    proj = x @ params["in_proj"]                      # (B,S,proj)
    z, xin, b_raw, c_raw, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, b_raw, c_raw], axis=-1)
    if conv0 is None:
        conv0 = jnp.zeros((bsz, cfg.ssm_conv_width - 1,
                           d_in + 2 * n), x.dtype)
    conv_out, conv_state = _causal_conv(
        conv_in, params["conv_w"], params["conv_b"], conv0)
    conv_out = jax.nn.silu(conv_out)
    xs, bs, cs = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    a = -jnp.exp(params["a_log"])
    if h0 is None:
        h0 = jnp.zeros((bsz, nh, n, cfg.ssm_head_dim), jnp.float32)
    xh = xs.reshape(bsz, s, nh, cfg.ssm_head_dim)
    y, h_final = ssd_chunked(xh, dt, a, bs, cs, h0, chunk=chunk)
    y = y + params["d_skip"][None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    # gated RMSNorm (Mamba2's norm-before-out-proj)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(
        jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-6)
        * params["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    return y @ params["out_proj"], (h_final, conv_state)


def mamba2_decode_step(params: dict, x1: jax.Array, cfg: ArchConfig,
                       state):
    """Single-token step; x1 (B,1,D); state = (h, conv_state)."""
    h0, conv0 = state
    y, new_state = mamba2_forward(params, x1, cfg, h0=h0, conv0=conv0,
                                  chunk=1)
    return y, new_state
