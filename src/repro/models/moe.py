"""Mixture-of-Experts layer: token-choice top-k with capacity (GShard).

Two execution paths:

  * ``mesh=None`` (smoke tests, tiny expert counts): dense fallback —
    every expert runs on every token, combined with the gate matrix.
  * ``mesh`` given: ``shard_map`` expert parallelism over the 'model'
    axis.  Activations enter replicated across 'model' (they are only
    batch-sharded), so the cheapest correct dispatch is: every model
    shard packs the full (E·C, d) buffer (sort-based, no (T,E,C)
    one-hot), processes the expert slice it owns, scatters its partial
    per-token outputs, and a single bf16 ``psum`` over 'model' combines
    them.  Wire cost 2·T·d vs ≥ 2·k·cf·T·d for an all_to_all dispatch
    of replicated tokens — ~5× fewer bytes at top-8/cf=1.25.
  * Expert weights are FSDP-sharded over the data axes (d-dim) and
    all-gathered just-in-time inside the shard_map (ZeRO-3; required to
    fit kimi-k2's 1.04T params).

Expert-count padding: when E doesn't divide the model-axis size (e.g.
granite's 40 experts on 16-way TP), storage is padded to the next
multiple (dead slots never routed to — the router's logit matrix keeps
exactly E outputs).

Token-choice semantics match the published configs; overflow beyond
``capacity`` (factor 1.25) is dropped, GShard-style.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                   # jax >= 0.5
    from jax import shard_map
except ImportError:                    # older jax keeps it in experimental
    from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig

EXPERT_PAD_TO = 16   # default: model-axis size of the production mesh


def padded_experts(cfg: ArchConfig) -> int:
    e = cfg.moe_experts
    pad = max(getattr(cfg, "moe_pad_to", EXPERT_PAD_TO), EXPERT_PAD_TO)
    return ((e + pad - 1) // pad) * pad


def init_moe_params(cfg: ArchConfig, key, dtype) -> dict:
    e_store = padded_experts(cfg)
    d, f = cfg.d_model, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    p = {
        "router": (jax.random.normal(k1, (d, cfg.moe_experts)) * scale_in
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e_store, d, f)) * scale_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(k3, (e_store, d, f)) * scale_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(k4, (e_store, f, d)) * scale_out
                   ).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(ks[0], (d, fs)) * scale_in
                       ).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (d, fs)) * scale_in
                     ).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (fs, d)) * scale_out
                       ).astype(dtype),
        }
    return p


def moe_param_pspecs(cfg: ArchConfig, dp_axes=("data",)) -> dict:
    """Experts over 'model' (EP); d-dim over data axes (FSDP).

    weight_stationary serving mode 2D-shards the expert dim over
    (data…, model) instead — experts fully resident per device, tokens
    travel (§Perf: kimi decode collective term)."""
    dshard = tuple(dp_axes) if dp_axes else None
    if cfg.moe_serving_dispatch == "weight_stationary":
        all_axes = tuple(dp_axes) + ("model",)
        p = {
            "router": P(None, None),
            "w_gate": P(all_axes, None, None),
            "w_up": P(all_axes, None, None),
            "w_down": P(all_axes, None, None),
        }
        if cfg.n_shared_experts:
            p["shared"] = {"w_gate": P(None, "model"),
                           "w_up": P(None, "model"),
                           "w_down": P("model", None)}
        return p
    p = {
        "router": P(None, None),
        "w_gate": P("model", dshard, None),
        "w_up": P("model", dshard, None),
        "w_down": P("model", None, dshard),
    }
    if cfg.n_shared_experts:
        p["shared"] = {
            "w_gate": P(None, "model"),
            "w_up": P(None, "model"),
            "w_down": P("model", None),
        }
    return p


def _routing(x2d: jax.Array, router: jax.Array, top_k: int):
    """x2d (T, d) → gates (T, k) fp32, expert ids (T, k) int32."""
    logits = x2d.astype(jnp.float32) @ router          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)


def _dense_fallback(x2d, params, cfg: ArchConfig):
    """All experts on all tokens (smoke-test path; E is tiny there)."""
    gates, idx = _routing(x2d, params["router"], cfg.moe_top_k)
    t = x2d.shape[0]
    e = cfg.moe_experts
    dense_gates = jnp.zeros((t, e), jnp.float32)
    dense_gates = dense_gates.at[
        jnp.arange(t)[:, None], idx].add(gates)
    wg, wu, wd = (params["w_gate"][:e], params["w_up"][:e],
                  params["w_down"][:e])
    h = jnp.einsum("td,edf->tef", x2d, wg)
    h = jax.nn.silu(h) * jnp.einsum("td,edf->tef", x2d, wu)
    y = jnp.einsum("tef,efd->ted", h, wd)
    return jnp.einsum("ted,te->td", y.astype(jnp.float32),
                      dense_gates).astype(x2d.dtype)


def _pack_by_expert(x2d, gates, idx, n_slots: int, capacity: int):
    """Sort-based capacity packing into an (n_slots·C, d) buffer.

    Returns (buf, slot (T,k; n_slots·C = dropped), gates w/ drops zeroed).
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)
    sort_ix = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_ix]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_slots),
                                 side="left")
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < capacity
    slot_sorted = jnp.where(keep, sorted_e * capacity + pos_in_e,
                            n_slots * capacity)
    slot_flat = jnp.zeros((t * k,), jnp.int32).at[sort_ix].set(
        slot_sorted.astype(jnp.int32))
    slot = slot_flat.reshape(t, k)
    token_of_sorted = sort_ix // k
    buf = jnp.zeros((n_slots * capacity + 1, x2d.shape[1]), x2d.dtype)
    buf = buf.at[slot_sorted].set(x2d[token_of_sorted], mode="drop")
    gates = jnp.where(slot == n_slots * capacity, 0.0, gates)
    return buf[:-1], slot, gates


def _expert_ffn(xe, w_gate, w_up, w_down):
    """xe (E_l, C', d) through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _weight_stationary_ffn(x, params, cfg: ArchConfig, mesh):
    """Serving dispatch: experts 2D-sharded over (dp…, model), fully
    resident; tokens all_to_all over 'data' within each model column;
    bf16 psum over 'model' combines columns.  Wire bytes per layer ≈
    2·(E_col·C·d) instead of the FSDP weight gather (≈ E_local·3·d·f),
    a ~2000× reduction at decode batch sizes (EXPERIMENTS.md §Perf)."""
    b, s, d = x.shape
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mdl = mesh.shape["model"]
    dpn = 1
    for a in dp_axes:
        dpn *= mesh.shape[a]
    n_dev = dpn * mdl
    e_store = padded_experts(cfg)          # multiple of n_dev via config
    assert e_store % n_dev == 0, (e_store, n_dev)
    e_per_dev = e_store // n_dev
    all_axes = dp_axes + ("model",)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(None, None),
                  P(all_axes, None, None), P(all_axes, None, None),
                  P(all_axes, None, None)),
        out_specs=P(dp_axes, None, None),
    )
    def _ws(x_l, router, w_gate, w_up, w_down):
        bl, sl, _ = x_l.shape
        t_l = bl * sl
        m_idx = jax.lax.axis_index("model")
        x2d = x_l.reshape(t_l, d)
        gates, idx = _routing(x2d, router, cfg.moe_top_k)
        cap = int(cfg.moe_capacity * cfg.moe_top_k * t_l
                  // cfg.moe_experts) + 1
        buf, slot, gates = _pack_by_expert(x2d, gates, idx, e_store, cap)
        buf = buf.reshape(e_store, cap, d)
        # experts owned by model column m: e with (e//e_per_dev)%mdl==m;
        # i.e. e = (q*mdl + m)*e_per_dev + r over data-rows q
        col_experts = ((jnp.arange(dpn)[:, None] * mdl + m_idx)
                       * e_per_dev
                       + jnp.arange(e_per_dev)[None, :]).reshape(-1)
        sub = jnp.take(buf, col_experts, axis=0)     # (dpn·e_pd, cap, d)
        sub = sub.reshape(dpn, e_per_dev, cap, d)
        for ax in dp_axes:                           # tokens → owners
            sub = jax.lax.all_to_all(sub, ax, split_axis=0,
                                     concat_axis=0, tiled=False)
        # now leading dpn indexes SOURCE data-row; my experts' tokens
        xe = sub.transpose(1, 0, 2, 3).reshape(e_per_dev, dpn * cap, d)
        ye = _expert_ffn(xe, w_gate, w_up, w_down)
        ye = ye.reshape(e_per_dev, dpn, cap, d).transpose(1, 0, 2, 3)
        for ax in reversed(dp_axes):                 # results → sources
            ye = jax.lax.all_to_all(ye, ax, split_axis=0,
                                    concat_axis=0, tiled=False)
        ye = ye.reshape(dpn * e_per_dev, cap, d)
        # scatter column results into the global (E·C) slot space
        ye_col = jnp.zeros((e_store * cap + 1, d), x_l.dtype)
        rowsel = (col_experts[:, None] * cap
                  + jnp.arange(cap)[None, :]).reshape(-1)
        ye_col = ye_col.at[rowsel].set(
            ye.reshape(-1, d).astype(x_l.dtype))
        per_assign = ye_col[slot.reshape(-1)].reshape(
            t_l, cfg.moe_top_k, d)
        y = jnp.einsum("tkd,tk->td", per_assign,
                       gates.astype(x_l.dtype),
                       preferred_element_type=jnp.float32)
        return jax.lax.psum(y.astype(x_l.dtype), "model"
                            ).reshape(bl, sl, d)

    return _ws(x, params["router"], params["w_gate"], params["w_up"],
               params["w_down"])


def moe_ffn(
    x: jax.Array,                 # (B, S, d)
    params: dict,
    cfg: ArchConfig,
    mesh: Optional[Mesh] = None,
    serving: bool = False,
) -> jax.Array:
    """Top-k MoE FFN; EP over 'model' when a mesh is provided."""
    b, s, d = x.shape
    if mesh is None or "model" not in mesh.axis_names:
        y = _dense_fallback(x.reshape(-1, d), params, cfg)
        out = y.reshape(b, s, d)
    elif (serving and cfg.moe_serving_dispatch == "weight_stationary"
          and len([a for a in ("pod", "data")
                   if a in mesh.axis_names]) == 1):
        # (single data axis; the multi-pod variant would chain
        # all_to_alls hierarchically — not needed for the §Perf cells)
        out = _weight_stationary_ffn(x, params, cfg, mesh)
        if cfg.n_shared_experts:
            sh = params["shared"]
            h = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
            out = out + (h @ sh["w_down"]).astype(out.dtype)
        return out
    else:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        ep = mesh.shape["model"]
        e_store = padded_experts(cfg)
        e_local = e_store // ep
        w_specs = (P("model", dp_axes or None, None),
                   P("model", dp_axes or None, None),
                   P("model", None, dp_axes or None))

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(dp_axes, None, None), P(None, None)) + w_specs,
            out_specs=P(dp_axes, None, None),
        )
        def _sharded(x_l, router, w_gate, w_up, w_down):
            bl, sl, _ = x_l.shape
            t_l = bl * sl
            m_idx = jax.lax.axis_index("model")
            x2d = x_l.reshape(t_l, d)
            gates, idx = _routing(x2d, router, cfg.moe_top_k)
            cap = int(cfg.moe_capacity * cfg.moe_top_k * t_l
                      // cfg.moe_experts) + 1
            buf, slot, gates = _pack_by_expert(
                x2d, gates, idx, e_store, cap)
            # my expert slice: rows [m_idx·e_local·cap, +e_local·cap)
            xe = jax.lax.dynamic_slice_in_dim(
                buf, m_idx * (e_local * cap), e_local * cap, axis=0
            ).reshape(e_local, cap, d)
            # FSDP: gather expert weights' data-sharded dim just-in-time.
            # P(("pod","data")) tiles pod-major — regather minor-first.
            for ax_name in reversed(dp_axes):
                w_gate = jax.lax.all_gather(w_gate, ax_name, axis=1,
                                            tiled=True)
                w_up = jax.lax.all_gather(w_up, ax_name, axis=1,
                                          tiled=True)
                w_down = jax.lax.all_gather(w_down, ax_name, axis=2,
                                            tiled=True)
            ye = _expert_ffn(xe, w_gate, w_up, w_down)   # (E_l, cap, d)
            # per-assignment gather: local slots resolve, others → 0
            ye_flat = ye.reshape(e_local * cap, d).astype(x_l.dtype)
            local_slot = slot - m_idx * (e_local * cap)
            in_range = (local_slot >= 0) & (local_slot < e_local * cap)
            safe = jnp.where(in_range, local_slot, 0)
            per_assign = ye_flat[safe.reshape(-1)].reshape(
                t_l, cfg.moe_top_k, d)
            per_assign = jnp.where(in_range[..., None], per_assign,
                                   jnp.zeros((), x_l.dtype))
            # bf16 operands, f32 accumulation (keeps the (T,k,d) buffer
            # at input precision — it was the largest MoE transient)
            y = jnp.einsum("tkd,tk->td", per_assign,
                           gates.astype(x_l.dtype),
                           preferred_element_type=jnp.float32)
            y = jax.lax.psum(y.astype(x_l.dtype), "model")
            return y.reshape(bl, sl, d)

        out = _sharded(x, params["router"], params["w_gate"],
                       params["w_up"], params["w_down"])

    if cfg.n_shared_experts:
        sh = params["shared"]
        h = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        out = out + (h @ sh["w_down"]).astype(out.dtype)
    return out
