"""xLSTM blocks: chunk-parallel mLSTM + recurrent sLSTM (arXiv:2405.04517).

mLSTM (matrix memory, exponential gating) is computed chunkwise-parallel
exactly like SSD: heavy (q·k)⊙D·v einsums vectorized over all chunks
*outside* the inter-chunk scan, tiny (C, n, m) state carried through the
scan — so compiled FLOPs reflect the real work (see DESIGN.md roofline
notes on while-loop cost accounting).

Stabilized gating (per head, log-space):
    log f = logsigmoid(f̃),  F_t = Σ_{u≤t} log f_u  (within chunk)
    m_t   = max(m_in + F_t, max_{s≤t}(F_t − F_s + ĩ_s))
    C̃_t  = e^{m_in+F_t−m_t} C̃_in + Σ_{s≤t} e^{F_t−F_s+ĩ_s−m_t} v_s k_sᵀ
    h_t   = (C̃_t q_t) / max(|ñ_t·q_t|, e^{−m_t})

sLSTM (scalar memory, recurrent R h_{t−1} gate inputs) is inherently
sequential → lax.scan over time; its FLOPs are added analytically by
the roofline assembler (launch/roofline.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def xlstm_dims(cfg: ArchConfig) -> Tuple[int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    head_dim = d_in // cfg.n_heads
    return d_in, head_dim


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm_params(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    d_in, p = xlstm_dims(cfg)
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    blockdiag = lambda kk: (jax.random.normal(kk, (h, p, p)) * p ** -0.5
                            ).astype(dtype)
    return {
        "up_proj": (jax.random.normal(ks[0], (d, 2 * d_in)) * d ** -0.5
                    ).astype(dtype),
        "wq": blockdiag(ks[1]),
        "wk": blockdiag(ks[2]),
        "wv": blockdiag(ks[3]),
        "w_gates": (jax.random.normal(ks[4], (d_in, 2 * h)) * 0.01
                    ).astype(jnp.float32),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((h,)), 3.0 * jnp.ones((h,))]).astype(jnp.float32),
        "out_norm": jnp.ones((d_in,), dtype),
        "down_proj": (jax.random.normal(ks[5], (d_in, d)) * d_in ** -0.5
                      ).astype(dtype),
    }


def _mlstm_core(q, k, v, i_raw, f_raw, state, chunk: int):
    """q/k/v (B,S,H,P); i_raw/f_raw (B,S,H) fp32.

    state = (C (B,H,P,P), n (B,H,P), m (B,H)) — or None.
    Returns (h (B,S,H,P) fp32, new state).
    """
    bsz, s, h, p = q.shape
    if state is None:
        state = (jnp.zeros((bsz, h, p, p), jnp.float32),
                 jnp.zeros((bsz, h, p), jnp.float32),
                 jnp.full((bsz, h), -1e30, jnp.float32))
    c0, n0, m0 = state
    pad = (-s) % chunk
    if pad:
        z = lambda x, fill=0.0: jnp.pad(
            x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
            constant_values=fill)
        q, k, v = z(q), z(k), z(v)
        i_raw = z(i_raw, -1e30)   # padded steps contribute nothing
        f_raw = z(f_raw, 30.0)    # log f ≈ 0 → state preserved
    nc = (s + pad) // chunk
    l = chunk
    qc = q.reshape(bsz, nc, l, h, p).astype(jnp.float32)
    kc = k.reshape(bsz, nc, l, h, p).astype(jnp.float32)
    vc = v.reshape(bsz, nc, l, h, p).astype(jnp.float32)
    ic = i_raw.reshape(bsz, nc, l, h)
    fc = f_raw.reshape(bsz, nc, l, h)

    logf = jax.nn.log_sigmoid(fc)                     # (B,nc,l,H)
    F = jnp.cumsum(logf, axis=2)                      # F_t
    # pairwise log decay (t ≥ s): F_t − F_s + ĩ_s
    logD = F[:, :, :, None, :] - F[:, :, None, :, :] \
        + ic[:, :, None, :, :]                        # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((l, l), bool))
    logD = jnp.where(tri[None, None, :, :, None], logD, -jnp.inf)
    m_loc = jnp.max(logD, axis=3)                     # (B,nc,t,H)

    # chunk-end operator (for the state scan): decay a = e^{F_l}, and
    # stabilized end-state contributions with local stabilizer m_end
    log_end = F[:, :, -1:, :] - F + ic                # (B,nc,l,H)
    m_end = jnp.max(log_end, axis=2)                  # (B,nc,H)
    w_end = jnp.exp(log_end - m_end[:, :, None, :])   # (B,nc,l,H)
    c_add = jnp.einsum("bzlh,bzlhp,bzlhr->bzhpr", w_end, vc, kc)
    n_add = jnp.einsum("bzlh,bzlhp->bzhp", w_end, kc)
    a_log = F[:, :, -1, :]                            # (B,nc,H) log decay

    def step(carry, inp):
        c, n, m = carry
        c_a, n_a, a_l, m_e = inp
        m_new = jnp.maximum(m + a_l, m_e)
        sc_old = jnp.exp(m + a_l - m_new)[..., None, None]
        sc_add = jnp.exp(m_e - m_new)[..., None, None]
        c2 = c * sc_old + c_a * sc_add
        n2 = n * sc_old[..., 0] + n_a * sc_add[..., 0]
        return (c2, n2, m_new), (c, n, m)             # emit incoming

    (cT, nT, mT), (c_in, n_in, m_in) = jax.lax.scan(
        step, (c0, n0, m0),
        (jnp.moveaxis(c_add, 1, 0), jnp.moveaxis(n_add, 1, 0),
         jnp.moveaxis(a_log, 1, 0), jnp.moveaxis(m_end, 1, 0)))
    c_in = jnp.moveaxis(c_in, 0, 1)                   # (B,nc,H,P,P)
    n_in = jnp.moveaxis(n_in, 0, 1)
    m_in = jnp.moveaxis(m_in, 0, 1)                   # (B,nc,H)

    # final stabilizer per position
    m_t = jnp.maximum(m_in[:, :, None, :] + F, m_loc)  # (B,nc,t,H)
    w_intra = jnp.exp(logD - m_t[:, :, :, None, :])    # (B,nc,t,s,H)
    scores = jnp.einsum("bzthp,bzshp->bztsh", qc, kc)
    num_intra = jnp.einsum("bztsh,bzshp->bzthp", w_intra * scores, vc)
    den_intra = jnp.einsum("bztsh,bzshp,bzthp->bzth",
                           w_intra, kc, qc)
    g_in = jnp.exp(m_in[:, :, None, :] + F - m_t)      # (B,nc,t,H)
    num_inter = jnp.einsum("bzhpr,bzthr->bzthp", c_in, qc) * g_in[..., None]
    den_inter = jnp.einsum("bzhp,bzthp->bzth", n_in, qc) * g_in
    num = num_intra + num_inter
    den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
    hout = (num / den[..., None]).reshape(bsz, nc * l, h, p)[:, :s]
    return hout, (cT, nT, mT)


def mlstm_forward(params, x, cfg: ArchConfig, *, state=None,
                  chunk: int = 128):
    """x (B,S,D) → (y (B,S,D), state)."""
    bsz, s, d = x.shape
    d_in, p = xlstm_dims(cfg)
    h = cfg.n_heads
    up = x @ params["up_proj"]
    xm, z = jnp.split(up, 2, axis=-1)                 # (B,S,d_in) each
    xh = xm.reshape(bsz, s, h, p)
    q = jnp.einsum("bshp,hpr->bshr", xh, params["wq"])
    k = jnp.einsum("bshp,hpr->bshr", xh, params["wk"]) / jnp.sqrt(
        jnp.float32(p)).astype(x.dtype)
    v = jnp.einsum("bshp,hpr->bshr", xh, params["wv"])
    gates = xm.astype(jnp.float32) @ params["w_gates"] \
        + params["gate_bias"][None, None]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)       # (B,S,H)
    hout, new_state = _mlstm_core(q, k, v, i_raw, f_raw, state, chunk)
    y = hout.reshape(bsz, s, d_in).astype(x.dtype) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * params["out_norm"].astype(jnp.float32)).astype(x.dtype)
    return y @ params["down_proj"], new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm_params(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    ks = jax.random.split(key, 4)
    return {
        "w_in": (jax.random.normal(ks[0], (d, 4 * d)) * d ** -0.5
                 ).astype(dtype),
        "r": (jax.random.normal(ks[1], (h, p, 4 * p)) * p ** -0.5
              ).astype(dtype),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": jnp.ones((d,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d, d)) * d ** -0.5
                     ).astype(dtype),
    }


def slstm_forward(params, x, cfg: ArchConfig, *, state=None):
    """x (B,S,D) → (y, state); state = (c, n, h, m) each (B, D)-ish."""
    bsz, s, d = x.shape
    nh = cfg.n_heads
    p = d // nh
    if state is None:
        zeros = jnp.zeros((bsz, nh, p), jnp.float32)
        state = (zeros, zeros + 1.0, zeros, zeros - 1e30)
    pre = (x @ params["w_in"]).astype(jnp.float32) \
        + params["bias"][None, None]                  # (B,S,4D)
    pre = pre.reshape(bsz, s, nh, 4 * p)

    r = params["r"].astype(jnp.float32)

    def step(carry, inp):
        c, n, hprev, m = carry
        rec = jnp.einsum("bhp,hpr->bhr", hprev, r)    # (B,H,4P)
        zi, ii, fi, oi = jnp.split(inp + rec, 4, axis=-1)
        zg = jnp.tanh(zi)
        og = jax.nn.sigmoid(oi)
        # exponential gating with stabilizer (per head+unit)
        i_l = ii
        f_l = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(f_l + m, i_l)
        ig = jnp.exp(i_l - m_new)
        fg = jnp.exp(f_l + m - m_new)
        c2 = fg * c + ig * zg
        n2 = fg * n + ig
        h2 = og * c2 / jnp.maximum(n2, 1e-6)
        return (c2, n2, h2, m_new), h2

    (cT, nT, hT, mT), hs = jax.lax.scan(
        step, state, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, d).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * params["out_norm"].astype(jnp.float32)).astype(x.dtype)
    return y @ params["out_proj"], (cT, nT, hT, mT)
