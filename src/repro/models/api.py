"""Unified model API: one contract across all 10 architecture families.

``get_model_api(cfg)`` returns a ``ModelAPI`` whose members the
launchers (train/serve/dryrun) and smoke tests consume without
family-specific branches.  Batches are dicts:

  train:   {"tokens","targets"} (+"vision_embeds" | +"frames")
  prefill: {"tokens"} (+modality extras)
  decode:  {"token"} against (cache, cache_len)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import encdec as encdec_lib
from repro.models import hybrid as hybrid_lib
from repro.models import transformer as tf_lib


@dataclasses.dataclass
class ModelAPI:
    cfg: ArchConfig
    init_params: Callable
    loss_fn: Callable        # (params, batch, mesh) -> scalar
    prefill: Callable        # (params, batch, mesh) -> (logits, cache)
    decode_step: Callable    # (params, batch, cache, cache_len, mesh)
    init_cache: Callable     # (batch_size, max_len) -> cache pytree
    param_pspecs: Callable   # (mesh) -> pytree of PartitionSpec
    batch_shapes: Callable   # (batch, seq) -> {name: ShapeDtypeStruct}
    decode_shapes: Callable  # (batch,) -> {name: ShapeDtypeStruct}
    cache_pspecs: Callable = None   # (mesh) -> pytree of PartitionSpec


def _kv_cache_pspec(cfg: ArchConfig, mesh: Mesh, lead: int = 1):
    """(lead…, B, S, KV, hd): B over dp; heads over 'model' when they
    divide, otherwise the sequence dim (exact under masked softmax —
    XLA inserts the psum/pmax reductions)."""
    from repro.models.transformer import dp_axes_of
    dp = dp_axes_of(mesh) or None
    mdl = mesh.shape.get("model", 1)
    kv_eff = max(cfg.n_kv_heads, cfg.kv_repeat_to or 0)
    heads_ok = kv_eff % mdl == 0
    leadspec = (None,) * lead
    if heads_ok:
        spec = P(*leadspec, dp, None, "model", None)
    else:
        spec = P(*leadspec, dp, "model", None, None)
    return {"k": spec, "v": spec}


def _std_batch_shapes(cfg: ArchConfig):
    def f(batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
        s = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
             "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        if cfg.frontend == "vision_stub":
            s["vision_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.frontend_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio_stub":
            s["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.frontend_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return s
    return f


def _decode_shapes(cfg: ArchConfig):
    def f(batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
        return {"token": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    return f


def get_model_api(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def loss_fn(params, batch, mesh=None):
            logits = tf_lib.forward_train(
                params, batch["tokens"], cfg, mesh,
                vision_embeds=batch.get("vision_embeds"))
            return tf_lib.xent_loss(logits, batch["targets"])

        def prefill(params, batch, mesh=None):
            return tf_lib.prefill(params, batch["tokens"], cfg, mesh,
                                  vision_embeds=batch.get("vision_embeds"))

        def decode(params, batch, cache, cache_len, mesh=None):
            return tf_lib.decode_step(params, batch["token"], cache,
                                      cache_len, cfg, mesh)

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: tf_lib.init_decoder_params(cfg, key),
            loss_fn=loss_fn, prefill=prefill, decode_step=decode,
            init_cache=lambda b, s: tf_lib.init_cache(cfg, b, s),
            param_pspecs=lambda mesh: tf_lib.decoder_param_pspecs(cfg, mesh),
            batch_shapes=_std_batch_shapes(cfg),
            decode_shapes=_decode_shapes(cfg),
            cache_pspecs=lambda mesh: _kv_cache_pspec(cfg, mesh, lead=1),
        )

    if fam == "hybrid":
        def loss_fn(params, batch, mesh=None):
            logits = hybrid_lib.hybrid_forward_train(
                params, batch["tokens"], cfg, mesh)
            return tf_lib.xent_loss(logits, batch["targets"])

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: hybrid_lib.init_hybrid_params(cfg, key),
            loss_fn=loss_fn,
            prefill=lambda p, b, mesh=None: hybrid_lib.hybrid_prefill(
                p, b["tokens"], cfg, mesh),
            decode_step=lambda p, b, c, cl, mesh=None:
                hybrid_lib.hybrid_decode_step(p, b["token"], c, cl, cfg,
                                              mesh),
            init_cache=lambda b, s: hybrid_lib.init_hybrid_cache(cfg, b, s),
            param_pspecs=lambda mesh: hybrid_lib.hybrid_param_pspecs(
                cfg, mesh),
            batch_shapes=_std_batch_shapes(cfg),
            decode_shapes=_decode_shapes(cfg),
            cache_pspecs=lambda mesh: _hybrid_cache_pspecs(cfg, mesh),
        )

    if fam == "ssm":
        def loss_fn(params, batch, mesh=None):
            logits = hybrid_lib.xlstm_forward_train(
                params, batch["tokens"], cfg, mesh)
            return tf_lib.xent_loss(logits, batch["targets"])

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: hybrid_lib.init_xlstm_stack_params(
                cfg, key),
            loss_fn=loss_fn,
            prefill=lambda p, b, mesh=None: hybrid_lib.xlstm_prefill(
                p, b["tokens"], cfg, mesh),
            decode_step=lambda p, b, c, cl, mesh=None:
                hybrid_lib.xlstm_decode_step(p, b["token"], c, cl, cfg,
                                             mesh),
            init_cache=lambda b, s: hybrid_lib.init_xlstm_cache(cfg, b, s),
            param_pspecs=lambda mesh: hybrid_lib.xlstm_param_pspecs(
                cfg, mesh),
            batch_shapes=_std_batch_shapes(cfg),
            decode_shapes=_decode_shapes(cfg),
            cache_pspecs=lambda mesh: _xlstm_cache_pspecs(cfg, mesh),
        )

    if fam == "audio":
        def loss_fn(params, batch, mesh=None):
            logits = encdec_lib.forward_train(
                params, batch["tokens"], batch["frames"], cfg, mesh)
            return tf_lib.xent_loss(logits, batch["targets"])

        return ModelAPI(
            cfg=cfg,
            init_params=lambda key: encdec_lib.init_encdec_params(cfg, key),
            loss_fn=loss_fn,
            prefill=lambda p, b, mesh=None: encdec_lib.prefill(
                p, b["tokens"], b["frames"], cfg, mesh),
            decode_step=lambda p, b, c, cl, mesh=None:
                encdec_lib.decode_step(p, b["token"], c, cl, cfg, mesh),
            init_cache=lambda b, s: encdec_lib.init_cache(cfg, b, s),
            param_pspecs=lambda mesh: encdec_lib.encdec_param_pspecs(
                cfg, mesh),
            batch_shapes=_std_batch_shapes(cfg),
            decode_shapes=_decode_shapes(cfg),
            cache_pspecs=lambda mesh: {
                "self": _kv_cache_pspec(cfg, mesh, lead=1),
                "cross": _kv_cache_pspec(cfg, mesh, lead=1),
            },
        )

    raise ValueError(f"unknown family {fam!r}")


def _hybrid_cache_pspecs(cfg: ArchConfig, mesh: Mesh):
    from repro.models.transformer import dp_axes_of
    from repro.models.hybrid import _hybrid_layout
    from repro.models import ssm as ssm_lib
    dp = dp_axes_of(mesh) or None
    mdl = mesh.shape.get("model", 1)
    _, nh, _ = ssm_lib.ssm_dims(cfg)
    h_spec = "model" if nh % mdl == 0 else None
    ssm_spec = lambda lead: (
        P(*((None,) * lead), dp, h_spec, None, None),      # h state
        P(*((None,) * lead), dp, None, "model"),           # conv buffer
    )
    groups, per, tail = _hybrid_layout(cfg)
    out = {
        "mamba": ssm_spec(2),
        "attn": _kv_cache_pspec(cfg, mesh, lead=1),
    }
    if tail:
        out["mamba_tail"] = ssm_spec(1)
    return out


def _xlstm_cache_pspecs(cfg: ArchConfig, mesh: Mesh):
    from repro.models.transformer import dp_axes_of
    from repro.models import xlstm as xlstm_lib
    dp = dp_axes_of(mesh) or None
    mdl = mesh.shape.get("model", 1)
    _, p = xlstm_lib.xlstm_dims(cfg)
    p_spec = "model" if p % mdl == 0 else None
    ps = cfg.d_model // cfg.n_heads
    ps_spec = "model" if ps % mdl == 0 else None
    return {
        "mlstm": (P(None, None, dp, None, p_spec, None),   # C
                  P(None, None, dp, None, p_spec),         # n
                  P(None, None, dp, None)),                # m
        "slstm": (P(None, dp, None, ps_spec),) * 4,
    }
