"""Shared transformer building blocks (pure JAX, pjit-friendly).

Conventions:
  * activations (B, S, D); B shards over the data axes, head/ffn dims
    over 'model' via weight PartitionSpecs + XLA propagation.
  * math in cfg.dtype (bf16), accumulation/norms/softmax in fp32.
  * attention is blockwise (streaming softmax) — O(S·chunk) live
    scores, causal chunks skipped at trace time (no S×S buffer).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.universal_hash import _fmix32


# ---------------------------------------------------------------------------
# Norms / MLP
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
# RoPE family: standard / partial (chatglm) / M-RoPE (qwen2-vl)
# ---------------------------------------------------------------------------
def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (..., S) → angles (..., S, dim/2) fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def _apply_rotary(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x (B, S, H, D) rotated pairwise by angles (B, S, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def apply_rope(q: jax.Array, k: jax.Array, positions: jax.Array,
               *, variant: str = "standard", theta: float = 10000.0,
               mrope_sections: Tuple[int, ...] = (16, 24, 24)
               ) -> Tuple[jax.Array, jax.Array]:
    """q (B,S,H,D), k (B,S,KV,D); positions (B,S) or (B,S,3) for mrope."""
    d = q.shape[-1]
    if variant == "none":
        return q, k
    if variant == "partial":  # chatglm3: rotary on the first half dims
        dr = d // 2
        ang = _rope_angles(positions, dr, theta)
        q = jnp.concatenate(
            [_apply_rotary(q[..., :dr], ang), q[..., dr:]], axis=-1)
        k = jnp.concatenate(
            [_apply_rotary(k[..., :dr], ang), k[..., dr:]], axis=-1)
        return q, k
    if variant == "mrope":   # qwen2-vl: 3 position streams over sections
        # positions (B, S, 3): temporal / height / width ids
        half = d // 2
        secs = mrope_sections
        assert sum(secs) == half, (secs, half)
        parts = []
        lo = 0
        for i, sec in enumerate(secs):
            ang = _rope_angles(positions[..., i], d, theta)[..., lo:lo + sec]
            parts.append((ang, lo, sec))
            lo += sec
        ang_full = jnp.concatenate([p[0] for p in parts], axis=-1)
        return _apply_rotary(q, ang_full), _apply_rotary(k, ang_full)
    # standard
    ang = _rope_angles(positions, d, theta)
    return _apply_rotary(q, ang), _apply_rotary(k, ang)


# ---------------------------------------------------------------------------
# Blockwise (streaming-softmax) attention with GQA
# ---------------------------------------------------------------------------
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,H,D), k (B,Skv,KV,D) → scores (B,H,Sq,Skv) fp32."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(b, kv * g, sq, k.shape[1]) / jnp.sqrt(jnp.float32(d))


def _gqa_values(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,H,Sq,Skv) fp32, v (B,Skv,KV,D) → out (B,Sq,H,D) fp32."""
    b, h, sq, skv = p.shape
    kv = v.shape[2]
    g = h // kv
    pg = p.reshape(b, kv, g, sq, skv)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v.astype(jnp.float32))
    return o.reshape(b, sq, h, v.shape[-1])


def _attend_block(qc, kc, vc, m, l, acc, q_pos, kv_pos, causal,
                  kv_valid_len):
    """One (q-block × kv-block) online-softmax update.

    qc (B,qc,H,D); kc/vc (B,kc,KV,D); m/l (B,H,qc); acc (B,qc,H,D) f32.
    """
    s = _gqa_scores(qc, kc)                   # (B,H,qc,kc) fp32
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if kv_valid_len is not None:
        mask &= (kv_pos < kv_valid_len)[None, :]
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[None, None], p, 0.0)
    corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l = l * corr + jnp.sum(p, axis=-1)
    acc = acc * corr.transpose(0, 2, 1)[..., None] + _gqa_values(p, vc)
    return m_new, l, acc


def blockwise_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_valid_len: Optional[jax.Array] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    impl: str = "loop",
) -> jax.Array:
    """Streaming-softmax attention; q (B,Sq,H,D), k/v (B,Skv,KV,D).

    ``q_offset``: absolute position of q[0] (decode/prefill continuation).
    ``kv_valid_len``: scalar — keys at index ≥ this are masked (cache).
    ``impl``:
      * 'loop' — python loops; causally-impossible kv chunks skipped at
        trace time (compiled FLOPs ≈ triangular optimum).  Used by the
        roofline probes and all small-seq paths.
      * 'scan' — lax.scan over q and kv chunks; one block's f32 buffers
        live at a time (bounded memory for 32k–500k sequences), at the
        cost of masked-out work the cost model doesn't use anyway.
    """
    if impl == "scan":
        return _blockwise_attention_scan(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_valid_len=kv_valid_len, q_chunk=q_chunk,
            kv_chunk=kv_chunk)
    b, sq, h, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    n_q = (sq + q_chunk - 1) // q_chunk
    n_kv = (skv + kv_chunk - 1) // kv_chunk

    outs = []
    for qi in range(n_q):
        q_lo = qi * q_chunk
        q_hi = min(q_lo + q_chunk, sq)
        qc = q[:, q_lo:q_hi]
        q_pos = q_offset + q_lo + jnp.arange(q_hi - q_lo)
        m = jnp.full((b, h, q_hi - q_lo), -jnp.inf, jnp.float32)
        l = jnp.zeros((b, h, q_hi - q_lo), jnp.float32)
        acc = jnp.zeros((b, q_hi - q_lo, h, d), jnp.float32)
        # last kv chunk this q chunk can see (trace-time bound)
        if causal:
            max_kv = min(skv, q_offset + q_hi)
            n_kv_here = (max_kv + kv_chunk - 1) // kv_chunk
        else:
            n_kv_here = n_kv
        for ki in range(n_kv_here):
            k_lo = ki * kv_chunk
            k_hi = min(k_lo + kv_chunk, skv)
            kv_pos = k_lo + jnp.arange(k_hi - k_lo)
            m, l, acc = _attend_block(
                qc, k[:, k_lo:k_hi], v[:, k_lo:k_hi], m, l, acc,
                q_pos, kv_pos, causal, kv_valid_len)
        denom = jnp.maximum(l, 1e-20).transpose(0, 2, 1)[..., None]
        outs.append((acc / denom).astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def _blockwise_attention_scan(q, k, v, *, causal, q_offset, kv_valid_len,
                              q_chunk, kv_chunk):
    """lax.scan × lax.scan variant: O(1) live blocks (see docstring)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    pad_q = (-sq) % q_chunk
    pad_kv = (-skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq = qp.shape[1] // q_chunk
    nkv = kp.shape[1] // kv_chunk
    # padded keys must never win: mask them via kv_valid_len
    valid = jnp.asarray(skv if kv_valid_len is None else kv_valid_len)
    qb = jnp.moveaxis(qp.reshape(b, nq, q_chunk, h, d), 1, 0)
    kb = jnp.moveaxis(kp.reshape(b, nkv, kv_chunk, k.shape[2], d), 1, 0)
    vb = jnp.moveaxis(vp.reshape(b, nkv, kv_chunk, v.shape[2], d), 1, 0)

    def per_q(carry_q, qi_qc):
        qi, qc = qi_qc
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def per_kv(carry, ki_kv):
            ki, kc, vc = ki_kv
            m, l, acc = carry
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            m, l, acc = _attend_block(qc, kc, vc, m, l, acc,
                                      q_pos, kv_pos, causal, valid)
            return (m, l, acc), ()

        init = (jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32),
                jnp.zeros((b, h, q_chunk), jnp.float32),
                jnp.zeros((b, q_chunk, h, d), jnp.float32))
        (m, l, acc), _unused = jax.lax.scan(
            per_kv, init, (jnp.arange(nkv), kb, vb))
        denom = jnp.maximum(l, 1e-20).transpose(0, 2, 1)[..., None]
        return carry_q, (acc / denom).astype(q.dtype)

    _, outs = jax.lax.scan(per_q, 0, (jnp.arange(nq), qb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# Embeddings: dense and b-bit-hashed (the paper's technique, adapted)
# ---------------------------------------------------------------------------
def hashed_embed_params(vocab: int, d: int, hash_k: int, hash_b: int,
                        key, dtype) -> dict:
    """k tables of 2^b rows replace the (vocab, d) table — the paper's
    n·b·k storage argument applied to embedding matrices."""
    del vocab
    t = jax.random.normal(key, (hash_k, 1 << hash_b, d)) * 0.02
    return {"hash_tables": t.astype(dtype)}


def hashed_embed_lookup(params: dict, tokens: jax.Array,
                        hash_k: int, hash_b: int) -> jax.Array:
    """tokens (B,S) int32 → (B,S,D).  code_j(t) = low b bits of h_j(t)."""
    # deterministic multiply-shift params derived from j (seedless tables)
    j = jnp.arange(hash_k, dtype=jnp.uint32)
    a = (j * jnp.uint32(0x9E3779B1) + jnp.uint32(0x85EBCA6B)) | jnp.uint32(1)
    c = _fmix32(j + jnp.uint32(0x27D4EB2F))
    t = tokens.astype(jnp.uint32)[..., None]
    codes = (_fmix32(a * t + c) & jnp.uint32((1 << hash_b) - 1)
             ).astype(jnp.int32)                       # (B,S,k)
    tables = params["hash_tables"]                     # (k, 2^b, D)
    emb = jnp.sum(
        tables[jnp.arange(hash_k)[None, None], codes], axis=-2)  # (B,S,D)
    return (emb / jnp.sqrt(jnp.float32(hash_k))).astype(tables.dtype)
